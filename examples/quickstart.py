"""Quickstart: solve an ODE, differentiate through it with ACA, and
compare the three gradient methods (paper Eq. 27-29).

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import odeint

# --- 1. solve dz/dt = k z ---------------------------------------------
k, T = -2.0, 3.0


def f(t, z, k):
    return k * z


ts = jnp.linspace(0.0, T, 5)
ys, stats = odeint(f, jnp.float32(1.5), ts, (jnp.float32(k),),
                   solver="dopri5", grad_method="aca",
                   rtol=1e-6, atol=1e-6)
print("z(t):", np.round(np.asarray(ys), 5))
print("exact:", np.round(1.5 * np.exp(k * np.asarray(ts)), 5))
print(f"accepted steps: {int(stats.n_steps)}, NFE: {int(stats.nfe)}")

# --- 2. gradients: ACA vs adjoint vs naive vs MALI ---------------------
analytic = 2 * 1.5 * np.exp(2 * k * T)
print(f"\nanalytic dL/dz0 = {analytic:.6e}   (L = z(T)^2)")
for method in ("aca", "adjoint", "naive", "mali"):
    def loss(z0):
        # mali integrates with the reversible ALF pair stepper (no RK
        # tableau): solver resolves to "alf", and its 2nd-order steps
        # need a larger accepted-step budget at this tolerance
        ys, _ = odeint(f, z0, jnp.array([0.0, T]), (jnp.float32(k),),
                       solver=None if method == "mali" else "dopri5",
                       grad_method=method,
                       max_steps=4096 if method == "mali" else 256,
                       rtol=1e-5, atol=1e-5)
        return (ys[-1] ** 2).sum()

    g = float(jax.grad(loss)(jnp.float32(1.5)))
    print(f"{method:8s} dL/dz0 = {g:.6e}   "
          f"rel err = {abs(g - analytic) / abs(analytic):.2e}")

# --- 3. a NODE block: continuous-depth layer (paper Eq. 30 -> 31) ------
from repro.core import NodeConfig, node_block_apply

params = {"w1": jax.random.normal(jax.random.PRNGKey(0), (8, 32)) * 0.3,
          "w2": jax.random.normal(jax.random.PRNGKey(1), (32, 8)) * 0.3}


def block_fn(p, z, t):
    return jnp.tanh(z @ p["w1"]) @ p["w2"]


z = jax.random.normal(jax.random.PRNGKey(2), (4, 8))
zT = node_block_apply(block_fn, params, z,
                      NodeConfig(enabled=True, solver="heun_euler",
                                 grad_method="aca"))
print("\nNODE block: in", z.shape, "-> out", zT.shape,
      "| param count unchanged:", sum(p.size for p in params.values()))
