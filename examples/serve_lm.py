"""Serve a small LM with batched requests: prefill + autoregressive
decode over the fixed-capacity cache engine.

    PYTHONPATH=src python examples/serve_lm.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import RunConfig, build_model
from repro.serve import ServeConfig, ServeEngine

cfg = get_smoke_config("qwen2_72b")
model = build_model(cfg, RunConfig(compute_dtype=jnp.float32, max_seq=64))
params = model.init(jax.random.PRNGKey(0))

engine = ServeEngine(model, params,
                     ServeConfig(max_new_tokens=16, temperature=0.0))

# a batch of 4 "requests" (random prompts — the engine mechanics are
# the point; weights are untrained)
prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0,
                             cfg.vocab, jnp.int32)
out = engine.generate(prompts)
print("prompt shape:", prompts.shape, "-> output shape:",
      out["tokens"].shape)
for i, row in enumerate(out["tokens"]):
    print(f"req {i}: ...{list(map(int, row[-16:]))}")
