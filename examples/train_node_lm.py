"""End-to-end driver: train a continuous-depth (NODE) language model
with ACA gradients — the paper's ResNet→NODE transformation applied to
a transformer stack, through the full production substrate (config
registry, data pipeline, AdamW + cosine schedule, gradient clipping,
atomic checkpointing with auto-resume, straggler watch).

Default: the ~100M-param node18_cifar config at a CPU-feasible
(seq 128, batch 8) shape for a few hundred steps.  ``--smoke`` shrinks
the model for a fast demonstration; ``--discrete`` trains the same
stack without NODE mode for comparison; ``--grad-method`` switches
aca/adjoint/naive.

    PYTHONPATH=src python examples/train_node_lm.py --steps 300
    PYTHONPATH=src python examples/train_node_lm.py --smoke --steps 50
    PYTHONPATH=src python examples/train_node_lm.py --smoke --adaptive

``--adaptive`` trains with the paper-matching ``NODE_TRAIN`` config
(adaptive HeunEuler, rtol=atol=1e-2, ACA, fused Pallas solver path)
instead of the CPU-friendly fixed grid.
"""

import argparse
import dataclasses
import os

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.configs.node18_cifar import NODE_TRAIN
from repro.core import NodeConfig
from repro.data import TokenPipeline
from repro.models import RunConfig, build_model
from repro.optim import adamw, cosine_warmup
from repro.train import TrainLoop, TrainLoopConfig, make_train_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--discrete", action="store_true")
    ap.add_argument("--grad-method", default="aca",
                    choices=["aca", "adjoint", "naive", "mali"])
    ap.add_argument("--adaptive", action="store_true",
                    help="paper-matching adaptive NODE_TRAIN config "
                         "(HeunEuler 1e-2, fused Pallas solver)")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_node_lm")
    args = ap.parse_args()

    cfg = get_smoke_config("node18_cifar") if args.smoke \
        else get_config("node18_cifar")
    if args.adaptive:
        node = dataclasses.replace(
            NODE_TRAIN, enabled=not args.discrete,
            grad_method=args.grad_method,
            # segmented checkpointing is an ACA-only memory bound — drop
            # it when the CLI switches to adjoint/naive
            checkpoint_segments=(NODE_TRAIN.checkpoint_segments
                                 if args.grad_method == "aca" else None))
    else:
        node = NodeConfig(enabled=not args.discrete, regime="fixed",
                          solver="rk2", grad_method=args.grad_method,
                          steps_per_interval=2)
    rcfg = RunConfig(compute_dtype=jnp.float32 if args.smoke
                     else jnp.bfloat16, node=node, remat="none")
    model = build_model(cfg, rcfg)
    print(f"model: {cfg.name}  params={model.n_params()/1e6:.1f}M  "
          f"mode={'discrete' if args.discrete else 'NODE/' + args.grad_method}")

    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq,
                         global_batch=args.batch, seed=0)
    opt = adamw(cosine_warmup(3e-4, 20, args.steps), weight_decay=0.1)
    lcfg = TrainLoopConfig(
        microbatches=1, clip_norm=1.0,
        ckpt_dir=args.ckpt_dir, ckpt_every=100, log_every=10,
    )
    state = make_train_state(model, opt, jax.random.PRNGKey(0))
    loop = TrainLoop(model, opt, lcfg, state,
                     straggler_cb=lambda s, r: print(
                         f"  [straggler] step {s} {r:.1f}x slower"))
    if loop.step:
        print(f"resumed from checkpoint at step {loop.step}")

    loop.run(lambda s: pipe.batch(s), args.steps,
             log_cb=lambda s, m: print(
                 f"step {s:5d}  loss {m['loss']:.4f}  "
                 f"gnorm {m['grad_norm']:.2f}"))
    print(f"done at step {loop.step}; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
