"""Latent-ODE on irregularly-sampled time series (paper Sec. 4.3).

A GRU encoder maps irregular (t_i, y_i) observations to a latent
initial state; the decoder integrates latent dynamics through the
irregular time grid in ONE odeint call (multi-time outputs) with ACA
gradients.

    PYTHONPATH=src python examples/latent_timeseries.py
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))      # repo root (for benchmarks.*)

import jax
import jax.numpy as jnp

from benchmarks.bench_timeseries import (decode, gru_encode, init_params)
from repro.data import irregular_series_batch
from repro.optim import adamw, constant
from repro.optim.adamw import apply_updates

data = irregular_series_batch(batch=32, n_obs=16, obs_dim=8, seed=0)
test = irregular_series_batch(batch=8, n_obs=16, obs_dim=8, seed=123)


def mse(p, d):
    def one(ts, ys):
        z0 = gru_encode(p, ts, ys)
        return ((decode(p, z0, ts, "aca") - ys) ** 2).mean()
    return jax.vmap(one)(d["ts"], d["ys"]).mean()


p = init_params(jax.random.PRNGKey(0))
opt = adamw(constant(3e-3))
st = opt.init(p)


@jax.jit
def step(p, st):
    l, g = jax.value_and_grad(lambda p: mse(p, data))(p)
    up, st = opt.update(g, st, p)
    return apply_updates(p, up), st, l


for i in range(200):
    p, st, l = step(p, st)
    if i % 25 == 0:
        print(f"step {i:4d}  train mse {float(l):.5f}")

print(f"\ntest interpolation MSE: {float(mse(p, test)):.5f}")
