"""Latent-ODE on irregularly-sampled time series (paper Sec. 4.3).

A GRU encoder maps irregular (t_i, y_i) observations to a latent
initial state; the decoder integrates latent dynamics through the
irregular time grid in ONE odeint call (multi-time outputs) with ACA
gradients.

After training, the dense-output path is demonstrated: the *whole
batch* is decoded with a single per-sample batched solve through the
union of every sample's observation times
(``odeint(..., batch_axis=0, interpolate_ts=True)`` over
``merged_time_grid``) — the ~B·T union eval points are read off each
element's step interpolants instead of forcing ~B·T step landings.

    PYTHONPATH=src python examples/latent_timeseries.py
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))      # repo root (for benchmarks.*)

import jax
import jax.numpy as jnp

from benchmarks.bench_timeseries import (decode, gru_encode, init_params)
from repro.core import odeint
from repro.data import irregular_series_batch, merged_time_grid
from repro.optim import adamw, constant
from repro.optim.adamw import apply_updates

data = irregular_series_batch(batch=32, n_obs=16, obs_dim=8, seed=0)
test = irregular_series_batch(batch=8, n_obs=16, obs_dim=8, seed=123)


def mse(p, d):
    def one(ts, ys):
        z0 = gru_encode(p, ts, ys)
        return ((decode(p, z0, ts, "aca") - ys) ** 2).mean()
    return jax.vmap(one)(d["ts"], d["ys"]).mean()


p = init_params(jax.random.PRNGKey(0))
opt = adamw(constant(3e-3))
st = opt.init(p)


@jax.jit
def step(p, st):
    l, g = jax.value_and_grad(lambda p: mse(p, data))(p)
    up, st = opt.update(g, st, p)
    return apply_updates(p, up), st, l


for i in range(200):
    p, st, l = step(p, st)
    if i % 25 == 0:
        print(f"step {i:4d}  train mse {float(l):.5f}")

print(f"\ntest interpolation MSE: {float(mse(p, test)):.5f}")


# --- dense-output decode: ONE batched solve over the union grid ---------
def mse_union(p, d):
    grid = merged_time_grid(d["ts"])
    z0 = jax.vmap(lambda ts, ys: gru_encode(p, ts, ys))(d["ts"], d["ys"])

    def f(t, z, f1, f2):
        return jnp.tanh(z @ f1) @ f2

    ys_u, stats = odeint(f, z0, grid["t_union"], (p["f1"], p["f2"]),
                         solver="dopri5", rtol=1e-4, atol=1e-4,
                         max_steps=256, batch_axis=0, interpolate_ts=True)
    # ys_u: (M, B, LAT) — gather sample b's own observation times
    rows = jnp.arange(z0.shape[0])
    per = jax.vmap(lambda i, b: ys_u[i, b])(grid["idx"], rows)
    pred = per @ p["dec"]
    return ((pred - d["ys"]) ** 2).mean(), stats


mse_u, stats = mse_union(p, test)
n_union = int(merged_time_grid(test["ts"])["t_union"].shape[0])
print(f"union-grid dense decode MSE: {float(mse_u):.5f} "
      f"({n_union} union eval times, "
      f"mean accepted steps/elt {float(stats.n_steps.mean()):.1f})")
