"""Three-body problem with physical knowledge (paper Sec. 4.4).

Fits the three unknown planet masses by back-propagating through the
ODE solver with ACA: the dynamics f ARE Newton's equations (Eq. 32);
only 3 scalars are learned.

    PYTHONPATH=src python examples/three_body.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import odeint
from repro.data.threebody import simulate_three_body, three_body_rhs
from repro.optim import adamw, constant
from repro.optim.adamw import apply_updates

TRUE_MASSES = (1.0, 0.8, 1.2)

print("simulating ground truth (dopri5 @ rtol 1e-8)...")
ts, rs, vs, m_true = simulate_three_body(
    n_points=128, t_max=2.0, masses=TRUE_MASSES, rtol=1e-8, atol=1e-8)
n_train = 64                        # train on [0, 1] yr
state0 = {"r": rs[0], "v": vs[0]}

log_m = jnp.zeros(3)                # init: equal unit masses
opt = adamw(constant(0.05))
opt_state = opt.init(log_m)


@jax.jit
def step(log_m, opt_state):
    def loss(log_m):
        ys, _ = odeint(three_body_rhs, state0, ts[:n_train],
                       (jnp.exp(log_m),), solver="dopri5",
                       grad_method="aca", rtol=1e-5, atol=1e-5,
                       max_steps=512)
        return ((ys["r"] - rs[:n_train]) ** 2).mean()

    l, g = jax.value_and_grad(loss)(log_m)
    updates, opt_state = opt.update(g, opt_state, log_m)
    return apply_updates(log_m, updates), opt_state, l


for i in range(120):
    log_m, opt_state, l = step(log_m, opt_state)
    if i % 20 == 0:
        print(f"step {i:4d} loss {float(l):.3e} "
              f"masses {np.round(np.exp(np.asarray(log_m)), 4)}")

ys, _ = odeint(three_body_rhs, state0, ts, (jnp.exp(log_m),),
               solver="dopri5", grad_method="aca", rtol=1e-6, atol=1e-6,
               max_steps=1024)
mse = float(((ys["r"] - rs) ** 2).mean())
print(f"\nrecovered masses: {np.round(np.exp(np.asarray(log_m)), 4)} "
      f"(true: {np.asarray(m_true)})")
print(f"trajectory MSE over [0, 2] yr (train was [0, 1]): {mse:.3e}")
