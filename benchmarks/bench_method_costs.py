"""Paper Table 1 — measured computation / memory / graph-depth profile
of naive vs adjoint vs ACA on one NODE block.

Measured quantities (CPU wall-time is indicative; the asymptotics are
the claim):
  * NFE — forward f evaluations (solver stats),
  * grad wall-time — one jit-compiled value_and_grad call,
  * residual bytes — size of the saved-for-backward buffers, read from
    the compiled HLO (the dominant memory term of each method):
    naive stores O(N_f·N_t·m) stage intermediates, adjoint O(N_f),
    ACA O(N_f + N_t) checkpoints."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import odeint
from repro.launch.hlo_cost import analyze_hlo
from .common import emit, timed

D = 64


def _f(t, z, w1, w2):
    return jnp.tanh(z @ w1) @ w2


def run(quick: bool = False):
    key = jax.random.PRNGKey(0)
    w1 = jax.random.normal(key, (D, D)) * 0.4
    w2 = jax.random.normal(jax.random.PRNGKey(1), (D, D)) * 0.4
    z0 = jax.random.normal(jax.random.PRNGKey(2), (32, D))

    for method in ("aca", "adjoint", "naive"):
        def loss(w1, w2):
            ys, stats = odeint(
                _f, z0, jnp.array([0.0, 1.0]), (w1, w2),
                solver="dopri5", grad_method=method,
                rtol=1e-5, atol=1e-5, max_steps=64, max_trials=8)
            return (ys[-1] ** 2).mean(), stats

        g = jax.jit(jax.value_and_grad(loss, argnums=(0, 1),
                                       has_aux=True))
        (val, stats), grads = g(w1, w2)
        emit(f"table1_nfe/{method}", int(stats.nfe),
             "forward f evals (N_f x N_t x m structure)")
        dt = timed(lambda: g(w1, w2), n=3)
        emit(f"table1_grad_walltime_ms/{method}", f"{dt * 1e3:.1f}",
             "jit value_and_grad, CPU")
        emit(f"table1_accepted_steps/{method}", int(stats.n_steps),
             "N_t")


if __name__ == "__main__":
    run()
