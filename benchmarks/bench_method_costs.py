"""Paper Table 1 — measured computation / memory / graph-depth profile
of naive vs adjoint vs ACA on one NODE block.

Measured quantities (CPU wall-time is indicative; the asymptotics are
the claim):
  * NFE — forward f evaluations (solver stats),
  * grad wall-time — one jit-compiled value_and_grad call,
  * residual bytes — ``analyze_hlo`` over the compiled value_and_grad
    HLO: ``bytes_min`` counts only the algorithm-intrinsic memory
    traffic (dots, fusions, dynamic-update-slices of the
    saved-for-backward buffers), the dominant memory term of each
    method: naive stores O(N_f·N_t·m) stage intermediates, adjoint
    O(N_f), ACA O(N_f + N_t) checkpoints.

The ACA row is additionally measured with ``use_pallas=True``
(``aca_pallas``) so the fused flat-state stepper's wall-time and
traffic delta versus the pytree path lands in ``BENCH_*.json``.
NOTE: on CPU the kernels run in *interpret mode* (each pallas_call
lowers to many plain XLA ops), so the aca_pallas row validates
dispatch and parity only — its bytes/wall-time read HIGHER than aca
there.  The fused traffic cut is a property of TPU compilation; rerun
on a TPU backend for the real delta.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import odeint
from repro.launch.hlo_cost import analyze_hlo
from .common import emit, emit_json, timed

D = 64


def _f(t, z, w1, w2):
    return jnp.tanh(z @ w1) @ w2


def run(quick: bool = False):
    key = jax.random.PRNGKey(0)
    w1 = jax.random.normal(key, (D, D)) * 0.4
    w2 = jax.random.normal(jax.random.PRNGKey(1), (D, D)) * 0.4
    z0 = jax.random.normal(jax.random.PRNGKey(2), (32, D))
    max_steps = 32 if quick else 64
    reps = 1 if quick else 3

    variants = [("aca", False), ("adjoint", False), ("naive", False),
                ("aca_pallas", True)]
    headline = {}
    for label, use_pallas in variants:
        method = label.split("_")[0]

        def loss(w1, w2):
            ys, stats = odeint(
                _f, z0, jnp.array([0.0, 1.0]), (w1, w2),
                solver="dopri5", grad_method=method,
                rtol=1e-5, atol=1e-5, max_steps=max_steps, max_trials=8,
                use_pallas=use_pallas)
            return (ys[-1] ** 2).mean(), stats

        # AOT-compile once: the timed calls and the HLO analysis share
        # the same executable (naive's trial-budget trace is expensive)
        g = jax.jit(jax.value_and_grad(loss, argnums=(0, 1),
                                       has_aux=True)).lower(w1, w2).compile()
        (val, stats), grads = g(w1, w2)
        emit(f"table1_nfe/{label}", int(stats.nfe),
             "forward f evals (N_f x N_t x m structure)")
        dt = timed(lambda: g(w1, w2), n=reps)
        emit(f"table1_grad_walltime_ms/{label}", f"{dt * 1e3:.1f}",
             "jit value_and_grad, CPU")
        emit(f"table1_accepted_steps/{label}", int(stats.n_steps),
             "N_t")
        cost = analyze_hlo(g.as_text())
        emit(f"table1_residual_bytes/{label}", int(cost.bytes_min),
             "analyze_hlo bytes_min of value_and_grad HLO "
             "(saved-buffer + intrinsic traffic)")
        headline[f"nfe_{label}"] = int(stats.nfe)
        headline[f"grad_walltime_ms_{label}"] = round(dt * 1e3, 1)
        headline[f"residual_bytes_{label}"] = int(cost.bytes_min)
    emit_json("method_costs", headline)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(quick=ap.parse_args().quick)
