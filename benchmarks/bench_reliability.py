"""Paper Table 3 — test-retest reliability under random re-initialization.

The paper quantifies agreement between independently trained runs with
the intraclass correlation coefficient (ICC); NODE-ACA shows higher ICC
than the discrete net.  Here: N runs with independent seeds, then

  * ICC(1) over the per-example correctness matrix (one-way random,
    single rater) — the paper's ICC1,
  * mean pairwise prediction agreement (a model-free reliability proxy).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import spiral_classification
from repro.optim import adamw, constant
from repro.optim.adamw import apply_updates
from .bench_classification import forward, init_params
from .common import emit


def _train_seed(mode, seed, steps, x, y):
    p = init_params(jax.random.PRNGKey(seed))
    opt = adamw(constant(3e-3))
    st = opt.init(p)

    @jax.jit
    def step(p, st):
        def loss(p):
            lg = forward(p, x, mode=mode, grad_method="aca")
            ll = jax.nn.log_softmax(lg)
            return -jnp.take_along_axis(ll, y[:, None], 1).mean()

        l, g = jax.value_and_grad(loss)(p)
        up, st2 = opt.update(g, st, p)
        return apply_updates(p, up), st2, l

    for _ in range(steps):
        p, st, _ = step(p, st)
    return p


def icc1(mat: np.ndarray) -> float:
    """One-way random single-rater ICC over (targets, raters)."""
    n, k = mat.shape
    grand = mat.mean()
    row_means = mat.mean(axis=1)
    msb = k * ((row_means - grand) ** 2).sum() / max(n - 1, 1)
    msw = ((mat - row_means[:, None]) ** 2).sum() / max(n * (k - 1), 1)
    denom = msb + (k - 1) * msw
    return float((msb - msw) / denom) if denom > 0 else 0.0


def run(quick: bool = False):
    n_runs = 4 if quick else 8
    steps = 100 if quick else 300
    x, y = spiral_classification(400 if quick else 1200, seed=0)
    xt, yt = spiral_classification(300, seed=7)

    for mode in ("node", "discrete"):
        preds, accs = [], []
        for s in range(n_runs):
            p = _train_seed(mode, 1000 + s, steps, x, y)
            lg = forward(p, xt, mode=mode, grad_method="aca")
            pr = np.asarray(jnp.argmax(lg, -1))
            preds.append(pr)
            accs.append(float((pr == np.asarray(yt)).mean()))
        correct = np.stack([(p == np.asarray(yt)).astype(float)
                            for p in preds], axis=1)   # (targets, raters)
        agree = np.mean([
            (preds[i] == preds[j]).mean()
            for i in range(n_runs) for j in range(i + 1, n_runs)])
        emit(f"table3_icc1/{mode}", f"{icc1(correct):.4f}",
             f"{n_runs} runs, acc {np.mean(accs):.3f}±{np.std(accs):.3f}")
        emit(f"table3_pairwise_agreement/{mode}", f"{agree:.4f}",
             "mean pairwise prediction agreement")


if __name__ == "__main__":
    run()
