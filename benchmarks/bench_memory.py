"""Peak checkpoint memory vs horizon — the segmented-ACA memory claim.

ACA's full trajectory checkpoint stores every accepted state: O(N_f ·
dim) residual memory, which caps long-horizon workloads (three-body,
long time series, deep NODE stacks).  ``checkpoint_segments=K`` bounds
it to O((K + N_f/K) · dim) — K coarse snapshots plus one segment-length
replay buffer — at ~1 extra ψ per accepted step in the backward sweep.

Measured quantity: ``analyze_hlo`` ``bytes_min`` over the compiled
value_and_grad HLO — the algorithm-intrinsic traffic of the saved
buffers (the checkpoint dynamic-update-slices dominate; dynamic-trip
while loops are counted once, so the number scales with *buffer size*,
i.e. peak residency, not step count).  Two sweeps:

  * ``K sweep`` at a fixed horizon the full buffer can still hold:
    residual bytes must *shrink* as K grows toward ⌈√max_steps⌉
    (asserted — this is the acceptance gate for the segmented mode);
  * ``horizon sweep``: the full buffer grows ~linearly in max_steps
    while ``checkpoint_segments="auto"`` grows ~√max_steps, opening
    horizons the full buffer cannot hold.

Headline numbers land in the shared JSON schema (``common.emit_json``),
and therefore in ``BENCH_*.json`` when ``BENCH_ARTIFACT_DIR`` is set.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import odeint
from repro.launch.hlo_cost import analyze_hlo
from .common import emit, emit_json

D = 32
B = 8


def _f(t, z, w1, w2):
    return jnp.tanh(z @ w1) @ w2 - 0.1 * z


def _residual_bytes(horizon_steps: int, segments) -> int:
    """bytes_min of one compiled ACA value_and_grad at this capacity."""
    w1 = jax.random.normal(jax.random.PRNGKey(0), (D, D)) * 0.4
    w2 = jax.random.normal(jax.random.PRNGKey(1), (D, D)) * 0.4
    z0 = jax.random.normal(jax.random.PRNGKey(2), (B, D))

    def loss(w1, w2):
        ys, _ = odeint(
            _f, z0, jnp.array([0.0, 1.0]), (w1, w2),
            solver="dopri5", grad_method="aca", rtol=1e-5, atol=1e-5,
            max_steps=horizon_steps, max_trials=8,
            checkpoint_segments=segments)
        return (ys[-1] ** 2).mean()

    g = jax.jit(jax.value_and_grad(loss, argnums=(0, 1))
                ).lower(w1, w2).compile()
    return int(analyze_hlo(g.as_text()).bytes_min)


def run(quick: bool = False):
    base_steps = 192 if quick else 512
    horizons = [64, base_steps] if quick else [64, 192, base_steps]
    sqrt_k = int(-(-base_steps ** 0.5 // 1))

    # --- K sweep at a horizon the full buffer can still hold ----------
    k_values = [1, 4, sqrt_k]
    by_k = {}
    for k in [None] + k_values:
        label = "full" if k is None else f"k{k}"
        by_k[label] = _residual_bytes(base_steps, k)
        emit(f"memory_residual_bytes/{label}", by_k[label],
             f"analyze_hlo bytes_min, max_steps={base_steps}")

    # the acceptance gate: state memory must shrink monotonically as K
    # grows toward the sqrt(N) optimum of the O(K + N/K) cost model
    seq = [by_k[f"k{k}"] for k in k_values]
    assert seq == sorted(seq, reverse=True) and seq[-1] < by_k["full"], (
        "segmented checkpointing did not shrink residual bytes", by_k)

    # --- horizon sweep: full vs auto ----------------------------------
    growth = {}
    for steps in horizons:
        if steps == base_steps:
            # the K sweep already compiled these exact configurations
            # ("auto" at base_steps resolves to sqrt_k)
            full_b, auto_b = by_k["full"], by_k[f"k{sqrt_k}"]
        else:
            full_b = _residual_bytes(steps, None)
            auto_b = _residual_bytes(steps, "auto")
        growth[steps] = (full_b, auto_b)
        emit(f"memory_horizon_bytes/full_{steps}", full_b,
             "full buffer: O(N) state slots")
        emit(f"memory_horizon_bytes/auto_{steps}", auto_b,
             "checkpoint_segments='auto': O(sqrt N) state slots")

    lo, hi = horizons[0], horizons[-1]
    full_growth = growth[hi][0] / max(growth[lo][0], 1)
    auto_growth = growth[hi][1] / max(growth[lo][1], 1)
    emit_json("memory", {
        "max_steps": base_steps,
        "bytes_full": by_k["full"],
        "bytes_k1": by_k["k1"],
        f"bytes_k{sqrt_k}_sqrt": by_k[f"k{sqrt_k}"],
        "sqrt_vs_full_ratio": round(by_k[f"k{sqrt_k}"] / by_k["full"], 4),
        "horizon_growth_full": round(full_growth, 2),
        "horizon_growth_auto": round(auto_growth, 2),
    })


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(quick=ap.parse_args().quick)
