"""Paper Table 4 — latent-ODE on irregularly-sampled series (Mujoco
stand-in), interpolation MSE for ACA vs adjoint vs naive + GRU baseline.

Latent-ODE: a GRU encoder consumes (Δt, y) pairs backwards to produce
z0; the decoder integrates dz/dt = f(z) through the *irregular*
observation times with one odeint call (multi-time outputs) and reads
out ŷ(t_i).  The only difference between the three columns is the
gradient method — exactly the paper's ablation."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import odeint
from repro.data import irregular_series_batch
from repro.optim import adamw, constant
from repro.optim.adamw import apply_updates
from .common import emit

OBS, LAT, HID = 8, 8, 32


def init_params(key):
    ks = jax.random.split(key, 8)
    s = 0.3
    return {
        # GRU encoder
        "wz": jax.random.normal(ks[0], (OBS + 1 + HID, HID)) * s,
        "wr": jax.random.normal(ks[1], (OBS + 1 + HID, HID)) * s,
        "wh": jax.random.normal(ks[2], (OBS + 1 + HID, HID)) * s,
        "enc_out": jax.random.normal(ks[3], (HID, LAT)) * s,
        # latent dynamics
        "f1": jax.random.normal(ks[4], (LAT, HID)) * s,
        "f2": jax.random.normal(ks[5], (HID, LAT)) * s,
        # readout
        "dec": jax.random.normal(ks[6], (LAT, OBS)) * s,
    }


def gru_encode(p, ts, ys):
    """Backward-in-time GRU over (Δt, y)."""
    dts = jnp.diff(ts, append=ts[-1:])

    def cell(h, inp):
        x = jnp.concatenate([inp, h])
        z = jax.nn.sigmoid(x @ p["wz"])
        r = jax.nn.sigmoid(x @ p["wr"])
        hh = jnp.tanh(jnp.concatenate([inp, r * h]) @ p["wh"])
        return (1 - z) * h + z * hh, None

    inputs = jnp.concatenate([ys, dts[:, None]], axis=1)[::-1]
    h, _ = jax.lax.scan(cell, jnp.zeros(HID), inputs)
    return h @ p["enc_out"]


def decode(p, z0, ts, grad_method):
    def f(t, z, f1, f2):
        return jnp.tanh(z @ f1) @ f2

    ys, _ = odeint(f, z0, ts, (p["f1"], p["f2"]), solver="dopri5",
                   grad_method=grad_method, rtol=1e-4, atol=1e-4,
                   max_steps=128)
    return ys @ p["dec"]


def run(quick: bool = False):
    n_obs = 16
    batch = 24 if quick else 48
    steps = 120 if quick else 300
    data = irregular_series_batch(batch=batch, n_obs=n_obs, obs_dim=OBS,
                                  seed=0)
    test = irregular_series_batch(batch=16, n_obs=n_obs, obs_dim=OBS,
                                  seed=99)

    def mse(p, d, gm):
        def one(ts, ys):
            z0 = gru_encode(p, ts, ys)
            return ((decode(p, z0, ts, gm) - ys) ** 2).mean()
        return jax.vmap(one)(d["ts"], d["ys"]).mean()

    for gm in ("aca", "adjoint", "naive"):
        p = init_params(jax.random.PRNGKey(0))
        opt = adamw(constant(3e-3))
        st = opt.init(p)

        @jax.jit
        def step(p, st):
            l, g = jax.value_and_grad(lambda p: mse(p, data, gm))(p)
            up, st2 = opt.update(g, st, p)
            return apply_updates(p, up), st2, l

        for _ in range(steps):
            p, st, l = step(p, st)
        test_mse = float(mse(p, test, "aca"))
        emit(f"table4_latentode_mse/{gm}", f"{test_mse:.5f}",
             f"irregular-series stand-in, {steps} steps")

    # GRU-only baseline: predict y(t_i) from the encoder state directly
    p = init_params(jax.random.PRNGKey(0))
    opt = adamw(constant(3e-3))
    st = opt.init(p)

    def rnn_mse(p, d):
        def one(ts, ys):
            z0 = gru_encode(p, ts, ys)
            pred = jnp.broadcast_to(z0 @ p["dec"], ys.shape)
            return ((pred - ys) ** 2).mean()
        return jax.vmap(one)(d["ts"], d["ys"]).mean()

    @jax.jit
    def rstep(p, st):
        l, g = jax.value_and_grad(lambda p: rnn_mse(p, data))(p)
        up, st2 = opt.update(g, st, p)
        return apply_updates(p, up), st2, l

    for _ in range(steps):
        p, st, l = rstep(p, st)
    emit("table4_rnn_baseline_mse", f"{float(rnn_mse(p, test)):.5f}",
         "GRU encoder + static readout")


if __name__ == "__main__":
    run()
