"""Continuous-batching NODE serving vs a static-batch baseline.

The serving claim: when solve requests arrive with heavy-tailed
horizons and mixed tolerances, *continuous* batching (swap finished
slots at every chunk boundary) beats a static wave scheduler on tail
latency, because short requests no longer queue behind a wave's
straggler.  Both engines share the same coalesced per-row-tolerance
solver — the only variable is the admission policy.

Protocol: one seeded heavy-traffic trace (Poisson arrivals, horizon mix
0.5/1.0/4.0 physical time, tolerance mix 1e-3/1e-4/1e-5) is served
twice through ``NodeServeEngine`` — ``static_batch=False`` vs ``True``
— on identical slots/chunk/cost-model settings.  Time is the engine's
deterministic ``SimClock`` (rounds cost ``chunk_overhead + trial_cost ·
max_row_trials``), so the measurement is scheduler quality, not host
jitter, and replays bit-identically in CI.

Headline gates (quick and full):

  * every request completes OK in both modes, and its final state
    matches a one-shot solo ``odeint`` at the request's own tolerance
    within the documented chunked-parity bound
    ``(n_chunks + 1) · (atol + rtol · max(1, max|z_ref|))``
    (see ``docs/serving.md``);
  * static-p99 / continuous-p99 latency ≥ 1.5 at equal throughput
    (continuous drains the same trace no slower than static).

Emits BENCH_serve_node.json (p50/p99 per mode, throughput, occupancy)
into the artifact trajectory.
"""

from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from .common import emit, emit_json, latency_summary

DIM = 8
SLOTS = 4
CHUNK_DT = 0.5
ARRIVAL_MEAN = 4.0          # sim-time mean inter-arrival (heavy traffic)
HORIZONS = (0.5, 1.0, 4.0)  # heavy-tailed physical-time horizon mix
HORIZON_P = (0.55, 0.25, 0.2)
TOLS = (1e-3, 1e-4, 1e-5)
TOL_P = (0.5, 0.3, 0.2)
MIN_P99_RATIO = 1.5


def _field(t, z, w):
    return jnp.tanh(w * z) - 0.1 * z * jnp.sin(t)


def _traffic(rng: np.random.Generator, n: int):
    """Seeded Poisson arrivals with a heavy-tailed request mix."""
    from repro.serve import NodeRequest

    t = 0.0
    out = []
    for _ in range(n):
        t += float(rng.exponential(ARRIVAL_MEAN))
        horizon = float(rng.choice(HORIZONS, p=HORIZON_P))
        rtol = float(rng.choice(TOLS, p=TOL_P))
        z0 = rng.normal(size=(DIM,)).astype(np.float32)
        out.append((t, NodeRequest(z0=z0, t0=0.0, t1=horizon,
                                   rtol=rtol, atol=rtol * 1e-2)))
    return out


def _serve(traffic, static: bool):
    from repro.serve import NodeEngineConfig, NodeServeEngine

    eng = NodeServeEngine(
        _field, DIM, (jnp.float32(1.3),),
        NodeEngineConfig(slots=SLOTS, chunk_dt=CHUNK_DT,
                         static_batch=static))
    for arrival, req in traffic:
        eng.submit(req, arrival=arrival)
    results = eng.run()
    return eng, results


def _check_parity(traffic, results) -> float:
    """Every served request vs its one-shot solo solve; returns the
    worst error/bound ratio (must stay < 1)."""
    from repro.core import odeint

    worst = 0.0
    by_id = {r.req_id: r for r in results}
    for rid, (_, req) in enumerate(traffic):
        r = by_id[rid]
        ys, _ = odeint(_field, jnp.asarray(req.z0),
                       jnp.asarray([req.t0, req.t1], jnp.float32),
                       (jnp.float32(1.3),), rtol=req.rtol, atol=req.atol)
        ref = np.asarray(ys[-1])
        err = float(np.abs(r.z_final - ref).max())
        bound = (r.n_chunks + 1) * (
            req.atol + req.rtol * max(1.0, float(np.abs(ref).max())))
        worst = max(worst, err / bound)
    return worst


def run(quick: bool = False):
    n = 24 if quick else 40
    traffic = _traffic(np.random.default_rng(0), n)

    eng_c, res_c = _serve(traffic, static=False)
    eng_s, res_s = _serve(traffic, static=True)

    assert all(r.ok for r in res_c), [r.status for r in res_c]
    assert all(r.ok for r in res_s), [r.status for r in res_s]

    lat_c = latency_summary([r.latency for r in res_c])
    lat_s = latency_summary([r.latency for r in res_s])
    thr_c = n / eng_c.clock.now
    thr_s = n / eng_s.clock.now
    occ_c = sum(eng_c.occupancy_log) / max(1, len(eng_c.occupancy_log))
    occ_s = sum(eng_s.occupancy_log) / max(1, len(eng_s.occupancy_log))
    ratio = lat_s["p99"] / lat_c["p99"]

    worst_parity = max(_check_parity(traffic, res_c),
                       _check_parity(traffic, res_s))

    emit("serve_node/continuous_p50", f"{lat_c['p50']:.1f}", "sim-time")
    emit("serve_node/continuous_p99", f"{lat_c['p99']:.1f}", "sim-time")
    emit("serve_node/static_p50", f"{lat_s['p50']:.1f}", "sim-time")
    emit("serve_node/static_p99", f"{lat_s['p99']:.1f}", "sim-time")
    emit("serve_node/p99_ratio", f"{ratio:.2f}",
         f"gate >= {MIN_P99_RATIO}")
    emit("serve_node/throughput_continuous", f"{thr_c:.4f}", "req/sim-t")
    emit("serve_node/throughput_static", f"{thr_s:.4f}", "req/sim-t")
    emit("serve_node/parity_worst", f"{worst_parity:.3f}",
         "err/bound, gate < 1")
    emit_json("serve_node", {
        "n_requests": n,
        "slots": SLOTS,
        "p50_continuous": lat_c["p50"],
        "p99_continuous": lat_c["p99"],
        "p50_static": lat_s["p50"],
        "p99_static": lat_s["p99"],
        "p99_ratio": ratio,
        "throughput_continuous": thr_c,
        "throughput_static": thr_s,
        "mean_occupancy_continuous": occ_c,
        "mean_occupancy_static": occ_s,
        "parity_worst": worst_parity,
    })

    assert worst_parity < 1.0, (
        f"served result exceeded the documented chunked-parity bound: "
        f"worst err/bound = {worst_parity:.3f}")
    assert thr_c >= thr_s * (1.0 - 1e-9), (
        f"continuous batching drained slower than static: "
        f"{thr_c:.4f} < {thr_s:.4f} req/sim-t")
    assert ratio >= MIN_P99_RATIO, (
        f"continuous batching must cut p99 latency by >= "
        f"{MIN_P99_RATIO}x vs the static baseline at equal throughput; "
        f"got {ratio:.2f}x (p99 static {lat_s['p99']:.1f} vs "
        f"continuous {lat_c['p99']:.1f})")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(quick=ap.parse_args().quick)
