"""Paper Table 5 / Fig. 8 — the three-body problem.

Ground truth: our Dopri5 at rtol=1e-8 on Newton's equations (Eq. 32)
with unequal masses and arbitrary initial conditions.  Models:

  * ODE  — f is Eq. 32 itself, only the 3 masses are unknown (full
    physical knowledge), fit by gradient descent THROUGH the solver
    with each gradient method;
  * NODE — f = FC(augmented input) (partial knowledge, Eq. 33/34);
  * LSTM — sequence model on raw coordinates (no knowledge).

Train on t∈[0,1], report trajectory MSE on t∈[0,2] (extrapolation)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import odeint
from repro.data.threebody import simulate_three_body, three_body_rhs
from repro.optim import adamw, constant, exponential_decay
from repro.optim.adamw import apply_updates
from .common import emit


def _traj(masses_or_params, state0, ts, rhs, grad_method, args_builder):
    ys, _ = odeint(rhs, state0, ts, args_builder(masses_or_params),
                   solver="dopri5", grad_method=grad_method,
                   rtol=1e-5, atol=1e-5, max_steps=512)
    return ys


def _aug_features(state):
    """Eq. 33: positions, pairwise displacements at powers 1..3."""
    r, v = state["r"], state["v"]          # (3,3)
    feats = [r.reshape(-1), v.reshape(-1)]
    for i in range(3):
        for j in range(3):
            if i == j:
                continue
            d = r[i] - r[j]
            n = jnp.sqrt((d ** 2).sum() + 1e-8)
            feats += [d, d / n, d / n ** 2, d / n ** 3]
    return jnp.concatenate(feats)


def run(quick: bool = False):
    n_pts = 64 if quick else 128
    fit_steps = 60 if quick else 200

    ts_all, rs, vs, m_true = simulate_three_body(
        n_points=2 * n_pts, t_max=2.0, masses=(1.0, 0.8, 1.2),
        rtol=1e-8, atol=1e-8)
    n_half = n_pts
    ts_train = ts_all[:n_half]
    state0 = {"r": rs[0], "v": vs[0]}

    # ------------------------------------------------ ODE (mass fitting)
    for gm in ("aca", "adjoint", "naive"):
        log_m = jnp.zeros(3)               # start from equal unit masses
        opt = adamw(constant(0.05))
        st = opt.init(log_m)

        @jax.jit
        def step(log_m, st):
            def loss(log_m):
                ys = _traj(log_m, state0, ts_train, three_body_rhs, gm,
                           lambda lm: (jnp.exp(lm),))
                return ((ys["r"] - rs[:n_half]) ** 2).mean()

            l, g = jax.value_and_grad(loss)(log_m)
            up, st2 = opt.update(g, st, log_m)
            return apply_updates(log_m, up), st2, l

        for _ in range(fit_steps):
            log_m, st, l = step(log_m, st)

        ys = _traj(log_m, state0, ts_all, three_body_rhs, "aca",
                   lambda lm: (jnp.exp(lm),))
        mse = float(((ys["r"] - rs) ** 2).mean())
        emit(f"table5_ode_mse/{gm}", f"{mse:.6f}",
             f"[0,2]yr; fitted m={np.round(np.exp(np.asarray(log_m)), 3)}"
             f" true={np.asarray(m_true)}")

    # ------------------------------------------------ NODE (aug input)
    feat_dim = int(_aug_features(state0).shape[0])
    w = jax.random.normal(jax.random.PRNGKey(0), (feat_dim, 9)) * 0.01

    def node_rhs(t, state, w):
        acc = (_aug_features(state) @ w).reshape(3, 3)
        return {"r": state["v"], "v": acc}

    for gm in (("aca",) if quick else ("aca", "adjoint", "naive")):
        p = w
        opt = adamw(constant(3e-3))
        st = opt.init(p)

        @jax.jit
        def nstep(p, st):
            def loss(p):
                ys = _traj(p, state0, ts_train, node_rhs, gm,
                           lambda pp: (pp,))
                return ((ys["r"] - rs[:n_half]) ** 2).mean()

            l, g = jax.value_and_grad(loss)(p)
            up, st2 = opt.update(g, st, p)
            return apply_updates(p, up), st2, l

        for _ in range(fit_steps):
            p, st, l = nstep(p, st)
        ys = _traj(p, state0, ts_all, node_rhs, "aca", lambda pp: (pp,))
        mse = float(((ys["r"] - rs) ** 2).mean())
        emit(f"table5_node_mse/{gm}", f"{mse:.6f}", "aug-input FC dynamics")

    # ------------------------------------------------ LSTM (no knowledge)
    HID = 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    lstm = {
        "wx": jax.random.normal(ks[0], (9, 4 * HID)) * 0.2,
        "wh": jax.random.normal(ks[1], (HID, 4 * HID)) * 0.2,
        "out": jax.random.normal(ks[2], (HID, 9)) * 0.2,
    }

    def lstm_roll(p, x0, n):
        def cell(carry, _):
            h, c, x = carry
            z = x @ p["wx"] + h @ p["wh"]
            i, f, g, o = jnp.split(z, 4)
            c2 = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * \
                jnp.tanh(g)
            h2 = jax.nn.sigmoid(o) * jnp.tanh(c2)
            x2 = x + h2 @ p["out"]        # residual next-step prediction
            return (h2, c2, x2), x2

        (_, _, _), xs = jax.lax.scan(
            cell, (jnp.zeros(HID), jnp.zeros(HID), x0), None, length=n)
        return xs

    flat = rs.reshape(len(ts_all), 9)
    p = lstm
    opt = adamw(constant(3e-3))
    st = opt.init(p)

    @jax.jit
    def lstep(p, st):
        def loss(p):
            pred = lstm_roll(p, flat[0], n_half - 1)
            return ((pred - flat[1:n_half]) ** 2).mean()

        l, g = jax.value_and_grad(loss)(p)
        up, st2 = opt.update(g, st, p)
        return apply_updates(p, up), st2, l

    for _ in range(3 * fit_steps):
        p, st, l = lstep(p, st)
    pred = lstm_roll(p, flat[0], len(ts_all) - 1)
    mse = float(((pred - flat[1:]) ** 2).mean())
    emit("table5_lstm_mse", f"{mse:.6f}", "no physical knowledge")


if __name__ == "__main__":
    run()
