"""Benchmark driver — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Default is quick mode (CPU-friendly sizes); ``--full`` uses the larger
settings.  Output: ``name,value,derived`` CSV rows.
"""

from __future__ import annotations

import argparse
import time
import traceback

from . import (bench_batched_solve, bench_classification,
               bench_dense_eval, bench_failure_overhead,
               bench_mali_memory, bench_memory, bench_method_costs,
               bench_node_lm, bench_reliability, bench_reverse_error,
               bench_serve_node, bench_sharded_solve,
               bench_solver_robustness, bench_threebody,
               bench_timeseries, bench_toy_gradient)
from .common import emit

BENCHES = [
    ("toy_gradient (Fig.6)", bench_toy_gradient.run),
    ("reverse_error (Fig.4/5)", bench_reverse_error.run),
    ("method_costs (Table 1)", bench_method_costs.run),
    ("classification (Table 2/Fig.7)", bench_classification.run),
    ("reliability (Table 3)", bench_reliability.run),
    ("solver_robustness (Tables 6/7)", bench_solver_robustness.run),
    ("timeseries (Table 4)", bench_timeseries.run),
    ("threebody (Table 5/Fig.8)", bench_threebody.run),
    ("node_lm (beyond-paper: LM ablation)", bench_node_lm.run),
    ("batched_solve (beyond-paper: batch_axis)", bench_batched_solve.run),
    ("memory (beyond-paper: segmented ACA)", bench_memory.run),
    ("dense_eval (beyond-paper: interpolate_ts)", bench_dense_eval.run),
    ("mali_memory (beyond-paper: reversible MALI)", bench_mali_memory.run),
    ("failure_overhead (solve-health guard gate)",
     bench_failure_overhead.run),
    ("sharded_solve (beyond-paper: mesh scaling)",
     bench_sharded_solve.run),
    ("serve_node (beyond-paper: continuous batching)",
     bench_serve_node.run),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    failed = []
    for name, fn in BENCHES:
        if args.only and args.only not in name:
            continue
        print(f"# === {name} ===", flush=True)
        t0 = time.monotonic()
        try:
            fn(quick=not args.full)
            emit(f"bench_runtime_s/{name.split(' ')[0]}",
                 f"{time.monotonic() - t0:.1f}", "")
        except Exception:
            # per-bench isolation: one crashing bench reports and the
            # suite continues; the summary + exit code carry the failure
            failed.append(name)
            traceback.print_exc()
            emit(f"bench_failed/{name.split(' ')[0]}", "1", "")
    if failed:
        print(f"# {len(failed)} benchmark(s) failed: "
              + ", ".join(failed), flush=True)
        raise SystemExit(f"{len(failed)} benchmarks failed: "
                         + ", ".join(n.split(" ")[0] for n in failed))


if __name__ == "__main__":
    main()
