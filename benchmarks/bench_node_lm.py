"""Beyond-paper: the gradient-method comparison on a transformer LM.

The paper compares ACA/adjoint/naive on CNN classifiers and MLP
dynamics; this framework makes the same ablation one flag on a
continuous-depth *transformer LM* (the NODE18 config family, fixed-grid
rk2, identical init/data): train N steps with each method and compare
the loss trajectory and step wall-time.  Expected: ACA ≈ naive loss
(same discretization), adjoint drifts; ACA fastest of the accurate
methods.  Also reports the discrete-stack reference."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import NodeConfig
from repro.data import TokenPipeline
from repro.models import RunConfig, build_model
from repro.optim import adamw, cosine_warmup
from repro.optim.grad_utils import CompressionState
from repro.train.loop import TrainLoopConfig, build_train_step
from repro.train.state import make_train_state
from .common import emit


def _train(node: NodeConfig, steps: int, pipe: TokenPipeline):
    cfg = get_smoke_config("node18_cifar")
    m = build_model(cfg, RunConfig(compute_dtype=jnp.float32, node=node))
    opt = adamw(cosine_warmup(3e-3, 5, steps))
    step = jax.jit(build_train_step(m, opt, TrainLoopConfig()),
                   donate_argnums=(0,))
    state = make_train_state(m, opt, jax.random.PRNGKey(0))
    comp = CompressionState(error=())
    losses = []
    batch0 = pipe.batch(0)
    state, comp, mt = step(state, batch0, comp)   # compile
    t0 = time.monotonic()
    for s in range(1, steps):
        state, comp, mt = step(state, pipe.batch(s), comp)
        losses.append(float(mt["loss"]))
    dt = (time.monotonic() - t0) / max(steps - 1, 1)
    return losses, dt


def run(quick: bool = False):
    steps = 25 if quick else 80
    pipe = TokenPipeline(vocab=512, seq_len=64, global_batch=8, seed=0)

    results = {}
    for gm in ("aca", "adjoint", "naive"):
        node = NodeConfig(enabled=True, regime="fixed", solver="rk2",
                          grad_method=gm, steps_per_interval=2)
        losses, dt = _train(node, steps, pipe)
        results[gm] = losses
        emit(f"nodelm_final_loss/{gm}", f"{losses[-1]:.4f}",
             f"{steps} steps, {dt*1e3:.0f} ms/step")
    losses, dt = _train(NodeConfig(enabled=False), steps, pipe)
    emit("nodelm_final_loss/discrete", f"{losses[-1]:.4f}",
         f"{steps} steps, {dt*1e3:.0f} ms/step")

    # ACA vs naive: same discrete solution -> loss curves track closely
    import numpy as np
    d_an = float(np.mean(np.abs(np.array(results["aca"])
                                - np.array(results["naive"]))))
    d_aj = float(np.mean(np.abs(np.array(results["aca"])
                                - np.array(results["adjoint"]))))
    emit("nodelm_curve_dist/aca_vs_naive", f"{d_an:.5f}",
         "mean |Δloss| over training (same discretization)")
    emit("nodelm_curve_dist/aca_vs_adjoint", f"{d_aj:.5f}",
         "adjoint drifts from the discretize-then-optimize pair")


if __name__ == "__main__":
    run()
