"""Paper Table 2 / Fig. 7 — classification: NODE (per grad method) vs
the discrete residual net, same parameter count.

CIFAR is unavailable offline; the stand-in is 3-arm spiral
classification lifted to 16-d (``repro.data.spiral_classification``) —
a task where depth/continuous dynamics matter and the *comparisons
between gradient methods* (the paper's claim) are preserved.

Model: z' = f(z) with f = W2·tanh(W1·z) per block (2 blocks), linear
head; the discrete baseline replaces each ODE block by z + f(z)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import odeint_final
from repro.data import spiral_classification
from repro.optim import adamw, constant
from repro.optim.adamw import apply_updates
from .common import emit

DIM, HID, CLASSES, BLOCKS = 16, 64, 3, 2


def init_params(key):
    ks = jax.random.split(key, 2 * BLOCKS + 1)
    p = {}
    for i in range(BLOCKS):
        p[f"w1_{i}"] = jax.random.normal(ks[2 * i], (DIM, HID)) * 0.3
        p[f"w2_{i}"] = jax.random.normal(ks[2 * i + 1], (HID, DIM)) * 0.3
    p["head"] = jax.random.normal(ks[-1], (DIM, CLASSES)) * 0.3
    return p


def forward(p, x, mode: str, grad_method: str = "aca",
            solver: str = "heun_euler", rtol: float = 1e-2,
            steps: int = 4):
    z = x
    for i in range(BLOCKS):
        w1, w2 = p[f"w1_{i}"], p[f"w2_{i}"]

        def f(t, z, w1, w2):
            return jnp.tanh(z @ w1) @ w2

        if mode == "node":
            kw = dict(rtol=rtol, atol=rtol, max_steps=32) \
                if solver in ("heun_euler", "bosh3", "dopri5") else \
                dict(steps_per_interval=steps)
            z, _ = odeint_final(f, z, 0.0, 1.0, (w1, w2), solver=solver,
                                grad_method=grad_method, **kw)
        else:                      # discrete residual block (ResNet)
            z = z + f(0.0, z, w1, w2)
    return z @ p["head"]


def accuracy(p, x, y, **kw):
    logits = forward(p, x, **kw)
    return float((jnp.argmax(logits, -1) == y).mean())


def train(mode: str, grad_method: str, steps: int, x, y, xt, yt,
          solver: str = "heun_euler"):
    p = init_params(jax.random.PRNGKey(0))
    opt = adamw(constant(3e-3))
    st = opt.init(p)

    @jax.jit
    def step(p, st, x, y):
        def loss(p):
            lg = forward(p, x, mode=mode, grad_method=grad_method,
                         solver=solver)
            ll = jax.nn.log_softmax(lg)
            return -jnp.take_along_axis(ll, y[:, None], 1).mean()

        l, g = jax.value_and_grad(loss)(p)
        up, st2 = opt.update(g, st, p)
        return apply_updates(p, up), st2, l

    for i in range(steps):
        p, st, l = step(p, st, x, y)
    return p, float(l)


def run(quick: bool = False):
    n_train, n_test = (400, 300) if quick else (1500, 600)
    steps = 100 if quick else 400
    x, y = spiral_classification(n_train, seed=0)
    xt, yt = spiral_classification(n_test, seed=7)  # same lift_seed=0

    for mode, gm in (("node", "aca"), ("node", "adjoint"),
                     ("node", "naive"), ("discrete", "-")):
        p, l = train(mode, gm if gm != "-" else "aca", steps, x, y, xt, yt)
        acc = accuracy(p, xt, yt, mode=mode,
                       grad_method="aca" if gm == "-" else gm)
        tag = f"{mode}" + (f"_{gm}" if gm != "-" else "")
        emit(f"table2_test_acc/{tag}", f"{acc:.4f}",
             f"spiral stand-in, {steps} steps, final loss {l:.3f}")


if __name__ == "__main__":
    run()
