"""O(1)-state reverse-gradient memory — the MALI claim, measured.

ACA's exactness costs a trajectory checkpoint: O(N_t · dim) residual
state (or O(√N_t · dim) segmented).  MALI stores **no states at all** —
the backward sweep re-derives each accepted state by inverting ALF
steps from the terminal pair — so the only per-step residual is the
scalar grid (t, h, out_idx): 3 scalars per step, independent of ``dim``.

Measured quantity: ``analyze_hlo`` ``bytes_min`` over the compiled
``value_and_grad`` HLO (same metric as ``bench_memory``; the residual
buffers' dynamic-update-slices dominate, so the number scales with peak
buffer residency).  Sweeping the step budget N = max_steps:

  * ``mali`` residual bytes must stay **flat**: ≤ 1.05× from N = 32 to
    N = 256 (the acceptance gate — the 3N scalar grid is noise next to
    the state-sized terminal pair and parameters);
  * ``aca`` (full buffer) must grow with N over the same sweep — the
    contrast that motivates the method-selection table
    (``docs/method-selection.md``).

Headline numbers land in the shared JSON schema (``common.emit_json``)
and therefore in ``BENCH_mali_memory.json`` when ``BENCH_ARTIFACT_DIR``
is set.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import odeint
from repro.launch.hlo_cost import analyze_hlo
from .common import emit, emit_json

D = 128
B = 4

MALI_FLATNESS_GATE = 1.05   # acceptance: mali residual growth N=32->256


def _f(t, z, w1, w2):
    return jnp.tanh(z @ w1) @ w2 - 0.1 * z


def _residual_bytes(max_steps: int, grad_method: str) -> int:
    """bytes_min of one compiled value_and_grad at this step budget."""
    w1 = jax.random.normal(jax.random.PRNGKey(0), (D, D)) * 0.4
    w2 = jax.random.normal(jax.random.PRNGKey(1), (D, D)) * 0.4
    z0 = jax.random.normal(jax.random.PRNGKey(2), (B, D))

    def loss(w1, w2):
        ys, _ = odeint(
            _f, z0, jnp.array([0.0, 1.0]), (w1, w2),
            solver=None if grad_method == "mali" else "dopri5",
            grad_method=grad_method, rtol=1e-4, atol=1e-4,
            max_steps=max_steps, max_trials=8)
        return (ys[-1] ** 2).mean()

    g = jax.jit(jax.value_and_grad(loss, argnums=(0, 1))
                ).lower(w1, w2).compile()
    return int(analyze_hlo(g.as_text()).bytes_min)


def run(quick: bool = False):
    horizons = [32, 256] if quick else [32, 128, 256, 512]
    lo, hi = horizons[0], horizons[-1]

    by = {}
    for method in ("mali", "aca"):
        for steps in horizons:
            by[(method, steps)] = _residual_bytes(steps, method)
            emit(f"mali_memory_bytes/{method}_{steps}",
                 by[(method, steps)],
                 "analyze_hlo bytes_min of value_and_grad")

    mali_growth = by[("mali", hi)] / max(by[("mali", lo)], 1)
    aca_growth = by[("aca", hi)] / max(by[("aca", lo)], 1)

    # acceptance gates: mali residual state is flat in step count while
    # the ACA full buffer grows with it
    assert mali_growth <= MALI_FLATNESS_GATE, (
        f"mali residual bytes grew {mali_growth:.3f}x from N={lo} to "
        f"N={hi} (gate {MALI_FLATNESS_GATE}x) — the O(1)-state claim "
        "regressed", by)
    assert aca_growth > mali_growth + 0.10, (
        "ACA full-buffer residuals did not grow past mali's — the "
        "measurement lost its contrast", by)

    emit_json("mali_memory", {
        "steps_lo": lo,
        "steps_hi": hi,
        "bytes_mali_lo": by[("mali", lo)],
        "bytes_mali_hi": by[("mali", hi)],
        "bytes_aca_lo": by[("aca", lo)],
        "bytes_aca_hi": by[("aca", hi)],
        "growth_mali": round(mali_growth, 4),
        "growth_aca": round(aca_growth, 4),
        "mali_vs_aca_at_hi": round(
            by[("mali", hi)] / max(by[("aca", hi)], 1), 4),
    })


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(quick=ap.parse_args().quick)
