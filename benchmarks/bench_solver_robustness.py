"""Paper Tables 6/7 — robustness to the *test-time* solver.

Train the NODE classifier with HeunEuler (rtol=1e-2, the paper's
setting), then evaluate with Euler/RK2/RK4 at several stepsizes and the
adaptive pairs at several tolerances WITHOUT retraining; repeat for the
discrete baseline (equivalently a 1-step-Euler NODE).  The paper's
finding: the NODE degrades ~1%, the discrete net ~7%."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.data import spiral_classification
from .bench_classification import accuracy, train
from .common import emit


def run(quick: bool = False):
    n_train, n_test = (400, 300) if quick else (1500, 600)
    steps = 100 if quick else 400
    x, y = spiral_classification(n_train, seed=0)
    xt, yt = spiral_classification(n_test, seed=7)  # same lift_seed=0

    # NODE trained with HeunEuler
    p_node, _ = train("node", "aca", steps, x, y, xt, yt,
                      solver="heun_euler")
    base = accuracy(p_node, xt, yt, mode="node", solver="heun_euler")
    emit("table7_node_base_acc/heun_euler", f"{base:.4f}",
         "train&test same solver")

    fixed = [("euler", 8), ("euler", 2), ("rk2", 4), ("rk4", 2)]
    adaptive = ["bosh3", "dopri5"]
    for sol, st in fixed:
        acc = accuracy(p_node, xt, yt, mode="node", solver=sol, steps=st)
        emit(f"table7_node_delta/{sol}_steps{st}",
             f"{base - acc:+.4f}", "acc drop vs train solver")
    for sol in adaptive:
        acc = accuracy(p_node, xt, yt, mode="node", solver=sol)
        emit(f"table7_node_delta/{sol}", f"{base - acc:+.4f}",
             "acc drop vs train solver")

    # discrete net evaluated as NODE with different solvers (Table 6)
    p_disc, _ = train("discrete", "aca", steps, x, y, xt, yt)
    base_d = accuracy(p_disc, xt, yt, mode="discrete")
    emit("table6_discrete_base_acc", f"{base_d:.4f}", "")
    for sol, st in fixed:
        acc = accuracy(p_disc, xt, yt, mode="node", solver=sol, steps=st)
        emit(f"table6_discrete_delta/{sol}_steps{st}",
             f"{base_d - acc:+.4f}",
             "discrete net re-read as ODE: depth sensitivity")


if __name__ == "__main__":
    run()
