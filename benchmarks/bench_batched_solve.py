"""Per-sample batched solving (``batch_axis``) — beyond-paper serving
benchmark.

Three ways to push a stiffness-heterogeneous batch through the adaptive
solver:

  * ``lockstep``   — stack the batch into ONE state and solve it with a
    single controller: one global error norm, one shared accept/reject.
    Every element pays the shared grid, and the stiff element's error is
    diluted by the batch RMS (the silent accuracy/cost degradation
    ``batch_axis`` removes).
  * ``vmap_solo``  — ``jax.vmap`` over the unbatched solver: per-element
    grids (the reference semantics), but each lane carries the full solo
    while_loop machinery.
  * ``per_sample`` — ``batch_axis=0``: one fused masked while_loop,
    per-element controllers.  Same trajectories as ``vmap_solo``.

Reported per strategy: forward wall-time, value_and_grad wall-time
(ACA), total f-evals in *sample-evals* (lockstep's one f-eval evaluates
all B samples) and the per-element accepted-step spread — the proof the
stepping is not lockstep.  Headline numbers additionally land in the
shared JSON schema (``common.emit_json``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import odeint
from .common import emit, emit_json, timed


def _f(t, z, w):
    x, logk = z[:-1], z[-1]
    dx = -jnp.exp(logk) * x + 0.1 * jnp.tanh(w @ x)
    return jnp.concatenate([dx, jnp.zeros((1,), z.dtype)])


def _batch(B: int, d: int):
    x0 = jax.random.normal(jax.random.PRNGKey(0), (B, d - 1))
    logk = jnp.linspace(0.0, 3.0, B)  # stiffness spread e^0 .. e^3
    return jnp.concatenate([x0, logk[:, None]], axis=1).astype(jnp.float32)


def run(quick: bool = False):
    B, d = (8, 16) if quick else (32, 64)
    reps = 2 if quick else 5
    ts = jnp.array([0.0, 1.0], jnp.float32)
    w = (jax.random.normal(jax.random.PRNGKey(1), (d - 1, d - 1))
         * 0.3).astype(jnp.float32)
    z0 = _batch(B, d)
    kw = dict(solver="dopri5", rtol=1e-5, atol=1e-5, max_steps=128,
              grad_method="aca")

    def solve_per_sample(w, z0):
        return odeint(_f, z0, ts, (w,), batch_axis=0, **kw)

    def solve_vmap_solo(w, z0):
        return jax.vmap(lambda z: odeint(_f, z, ts, (w,), **kw),
                        in_axes=0, out_axes=(1, 0))(z0)

    fb = lambda t, zb, w: jax.vmap(lambda z: _f(t, z, w))(zb)

    def solve_lockstep(w, z0):
        return odeint(fb, z0, ts, (w,), **kw)

    strategies = [("per_sample", solve_per_sample),
                  ("vmap_solo", solve_vmap_solo),
                  ("lockstep", solve_lockstep)]

    headline = {"batch": B, "dim": d}
    for name, solve in strategies:
        fwd = jax.jit(lambda w, z0: solve(w, z0)[0])

        def loss(w, z0):
            ys, _ = solve(w, z0)
            return jnp.sum(ys[-1] ** 2)

        grad = jax.jit(jax.value_and_grad(loss))

        _, stats = jax.jit(solve)(w, z0)
        n_steps = np.atleast_1d(np.asarray(stats.n_steps))
        nfe = np.atleast_1d(np.asarray(stats.nfe))
        # lockstep: one recorded f-eval touches all B samples
        sample_evals = int(nfe.sum()) if nfe.shape[0] == B \
            else int(nfe.sum()) * B

        t_fwd = timed(fwd, w, z0, n=reps)
        t_grad = timed(grad, w, z0, n=reps)

        emit(f"batched_solve_fwd_s/{name}", f"{t_fwd:.4f}")
        emit(f"batched_solve_grad_s/{name}", f"{t_grad:.4f}")
        emit(f"batched_solve_sample_evals/{name}", sample_evals)
        emit(f"batched_solve_steps_min_max/{name}",
             f"{int(n_steps.min())}", f"{int(n_steps.max())}")
        headline[f"{name}_fwd_s"] = round(t_fwd, 4)
        headline[f"{name}_grad_s"] = round(t_grad, 4)
        headline[f"{name}_sample_evals"] = sample_evals

    # per-element grids must actually differ (else the heterogeneous
    # batch degenerated and the comparison is meaningless)
    _, st = jax.jit(solve_per_sample)(w, z0)
    spread = np.asarray(st.n_steps)
    assert len(np.unique(spread)) > 1, spread
    headline["per_sample_step_spread"] = f"{spread.min()}..{spread.max()}"
    emit_json("batched_solve", headline)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(quick=ap.parse_args().quick)
