"""Paper Fig. 6 — |gradient error| vs end time T for the toy problem
dz/dt = k z,  L = z(T)²,  dL/dz0 = 2 z0 e^{2kT}  (Eq. 27–29).

All methods use Dopri5 at rtol=atol=1e-5 like the paper.  Two regimes:

  * k < 0 — forward decays ⇒ the adjoint's reverse-time re-integration
    is *unstable* (the DΦ⁻¹ term of Theorem 3.2 amplifies truncation
    error as e^{|k|T}): adjoint error grows ~10-100× above ACA with T,
    while ACA (≈ naive: both are discretize-then-optimize) stays at the
    forward-tolerance floor — the paper's Fig. 6 mechanism;
  * k > 0 — reverse-time is stable; all methods sit at the tolerance
    floor (reported for completeness/honesty).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import odeint
from .common import emit

Z0 = 1.5


def grad_rel_error(method: str, k: float, t_end: float) -> float:
    def loss(z0):
        ys, _ = odeint(lambda t, z, kk: kk * z, z0,
                       jnp.array([0.0, t_end]), (jnp.float32(k),),
                       solver="dopri5", grad_method=method,
                       rtol=1e-5, atol=1e-5, max_steps=512)
        return (ys[-1] ** 2).sum()

    g = float(jax.grad(loss)(jnp.float32(Z0)))
    analytic = 2 * Z0 * float(np.exp(2 * k * t_end))
    return abs(g - analytic) / abs(analytic)


def run(quick: bool = False):
    ts = [1.0, 2.0, 4.0] if quick else [0.5, 1.0, 2.0, 3.0, 4.0]
    for k in (-2.0, 2.0):
        for t_end in ts:
            errs = {m: grad_rel_error(m, k, t_end)
                    for m in ("aca", "adjoint", "naive")}
            for m, e in errs.items():
                emit(f"fig6_grad_relerr/k={k:+.0f}/{m}/T={t_end}",
                     f"{e:.3e}", "rel err vs Eq.29")
            rel = errs["adjoint"] / max(errs["aca"], 1e-12)
            emit(f"fig6_adjoint_over_aca/k={k:+.0f}/T={t_end}",
                 f"{rel:.2f}", "adjoint err / ACA err (>1 favors ACA)")


if __name__ == "__main__":
    run()
