"""Paper Fig. 4/5 — reverse-time trajectory mismatch.

Integrate forward 0→T, then re-integrate T→0 from z(T) (what the
adjoint method does) and measure ‖z̄(0) − z(0)‖.  ACA's checkpoints
recover z(0) exactly by construction; the reverse solve drifts:

  * van der Pol (paper Fig. 4/9): stiff limit cycle,
  * random conv-style linear ODE (paper Fig. 5): a 3×3-kernel
    convolution on a small image, dz/dt = conv(z)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import odeint
from .common import emit


def reverse_roundtrip_error(f, z0, t_end, args=(), tol=1e-5):
    ts = jnp.array([0.0, t_end])
    ys, _ = odeint(f, z0, ts, args, solver="dopri5", grad_method="aca",
                   rtol=tol, atol=tol, max_steps=2048, max_trials=20)
    zT = jax.tree.map(lambda y: y[-1], ys)

    # reverse-time IVP from z(T) (the adjoint's z̄ trajectory)
    def f_rev(s, z, *a):
        return jax.tree.map(jnp.negative, f(t_end - s, z, *a))

    ys_rev, _ = odeint(f_rev, zT, ts, args, solver="dopri5",
                       grad_method="aca", rtol=tol, atol=tol,
                       max_steps=2048, max_trials=20)
    z0_rec = jax.tree.map(lambda y: y[-1], ys_rev)
    num = jnp.sqrt(sum(jnp.sum((a - b) ** 2) for a, b in zip(
        jax.tree.leaves(z0_rec), jax.tree.leaves(z0))))
    den = jnp.sqrt(sum(jnp.sum(b ** 2) for b in jax.tree.leaves(z0)))
    return float(num / jnp.maximum(den, 1e-12))


def run(quick: bool = False):
    # --- van der Pol (Appendix D Eq. 81-82: mu = 0.15 is mild; the
    # mismatch explodes for stiffer mu) --------------------------------
    for mu in ([0.15, 4.0] if quick else [0.15, 1.0, 4.0, 8.0]):
        def vdp(t, z, mu):
            return jnp.stack(
                [z[1], mu * (1 - z[0] ** 2) * z[1] - z[0]])

        err = reverse_roundtrip_error(
            vdp, jnp.array([2.0, 0.0]), 5.0, (jnp.float32(mu),))
        emit(f"fig4_vdp_reverse_relerr/mu={mu}", f"{err:.3e}",
             "adjoint z̄(0) drift; ACA=0 by construction")

    # --- conv ODE (Fig. 5): dz/dt = conv3x3(z) -------------------------
    key = jax.random.PRNGKey(0)
    kern = jax.random.normal(key, (3, 3, 1, 1)) * 0.5
    img = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8, 1))

    def conv_ode(t, z, k):
        return jax.lax.conv_general_dilated(
            z, k, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO",
                                                     "NHWC"))

    for t_end in ([1.0] if quick else [0.5, 1.0, 2.0]):
        err = reverse_roundtrip_error(conv_ode, img, t_end, (kern,))
        emit(f"fig5_conv_reverse_relerr/T={t_end}", f"{err:.3e}",
             "conv-ODE reconstruction drift")


if __name__ == "__main__":
    run()
