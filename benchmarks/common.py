"""Shared benchmark utilities: timing + CSV / JSON emission."""

from __future__ import annotations

import json
import time
from typing import Callable, Mapping

import jax

ROWS = []


def emit(name: str, value, derived: str = "") -> None:
    row = f"{name},{value},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def emit_json(bench: str, metrics: Mapping) -> None:
    """Emit one headline JSON line in the shared schema:

        {"bench": <name>, "metrics": {<metric>: <number|string>, ...}}

    One line per benchmark, greppable as ``^{"bench"`` — the machine
    counterpart of the ``emit`` CSV rows.  Values must be plain
    JSON-serializable scalars (floats/ints/strings).
    """
    line = json.dumps({"bench": bench, "metrics": dict(metrics)},
                      sort_keys=True)
    ROWS.append(line)
    print(line, flush=True)


def timed(fn: Callable, *args, n: int = 3, warmup: int = 1) -> float:
    """Median wall-time (seconds) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(n):
        t0 = time.monotonic()
        jax.block_until_ready(fn(*args))
        ts.append(time.monotonic() - t0)
    ts.sort()
    return ts[len(ts) // 2]
