"""Shared benchmark utilities: timing + CSV / JSON emission.

With ``BENCH_ARTIFACT_DIR`` set, every ``emit_json`` headline is also
appended to ``$BENCH_ARTIFACT_DIR/BENCH_<bench>.json`` (one JSON object
per line) — the per-commit perf-trajectory artifacts CI uploads and
``tools/check_bench_schema.py`` validates.
"""

from __future__ import annotations

import json
import os
import pathlib
import re
import time
from typing import Callable, Mapping

import jax

ROWS = []


def emit(name: str, value, derived: str = "") -> None:
    row = f"{name},{value},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def emit_json(bench: str, metrics: Mapping) -> None:
    """Emit one headline JSON line in the shared schema:

        {"bench": <name>, "metrics": {<metric>: <number|string>, ...}}

    One line per benchmark, greppable as ``^{"bench"`` — the machine
    counterpart of the ``emit`` CSV rows.  Values must be plain
    JSON-serializable scalars (floats/ints/strings).  When the
    ``BENCH_ARTIFACT_DIR`` env var names a directory, the line is also
    appended to ``BENCH_<bench>.json`` there (see module docstring).
    """
    line = json.dumps({"bench": bench, "metrics": dict(metrics)},
                      sort_keys=True)
    ROWS.append(line)
    print(line, flush=True)
    art_dir = os.environ.get("BENCH_ARTIFACT_DIR")
    if art_dir:
        path = pathlib.Path(art_dir)
        path.mkdir(parents=True, exist_ok=True)
        slug = re.sub(r"[^A-Za-z0-9_.-]+", "_", bench)
        with open(path / f"BENCH_{slug}.json", "a") as fh:
            fh.write(line + "\n")


def percentile(xs, q: float) -> float:
    """Percentile by linear interpolation over the sorted sample.

    ``q`` in [0, 100].  Deterministic pure-Python (no numpy dtype
    surprises in the artifact pipeline): ``q=50`` of an even-sized
    sample is the mean of the middle pair; a single sample is every
    percentile of itself.  Raises ``ValueError`` on an empty sample —
    an empty latency list means the benchmark produced nothing, which
    should fail loudly rather than emit a silent 0.
    """
    xs = sorted(float(x) for x in xs)
    if not xs:
        raise ValueError("percentile of an empty sample")
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"q must be in [0, 100]; got {q}")
    if len(xs) == 1:
        return xs[0]
    pos = (q / 100.0) * (len(xs) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(xs) - 1)
    frac = pos - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


def latency_summary(latencies) -> dict:
    """p50/p99/mean/max over a latency sample (serving benchmarks).

    Returns plain floats keyed ``p50``/``p99``/``mean``/``max`` plus the
    sample size ``n`` — ready for ``emit_json`` metrics.
    """
    xs = [float(x) for x in latencies]
    if not xs:
        raise ValueError("latency_summary of an empty sample")
    return {
        "n": len(xs),
        "p50": percentile(xs, 50.0),
        "p99": percentile(xs, 99.0),
        "mean": sum(xs) / len(xs),
        "max": max(xs),
    }


def timed(fn: Callable, *args, n: int = 3, warmup: int = 1) -> float:
    """Median wall-time (seconds) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(n):
        t0 = time.monotonic()
        jax.block_until_ready(fn(*args))
        ts.append(time.monotonic() - t0)
    ts.sort()
    return ts[len(ts) // 2]
