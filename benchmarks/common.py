"""Shared benchmark utilities: timing + CSV / JSON emission.

With ``BENCH_ARTIFACT_DIR`` set, every ``emit_json`` headline is also
appended to ``$BENCH_ARTIFACT_DIR/BENCH_<bench>.json`` (one JSON object
per line) — the per-commit perf-trajectory artifacts CI uploads and
``tools/check_bench_schema.py`` validates.
"""

from __future__ import annotations

import json
import os
import pathlib
import re
import time
from typing import Callable, Mapping

import jax

ROWS = []


def emit(name: str, value, derived: str = "") -> None:
    row = f"{name},{value},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def emit_json(bench: str, metrics: Mapping) -> None:
    """Emit one headline JSON line in the shared schema:

        {"bench": <name>, "metrics": {<metric>: <number|string>, ...}}

    One line per benchmark, greppable as ``^{"bench"`` — the machine
    counterpart of the ``emit`` CSV rows.  Values must be plain
    JSON-serializable scalars (floats/ints/strings).  When the
    ``BENCH_ARTIFACT_DIR`` env var names a directory, the line is also
    appended to ``BENCH_<bench>.json`` there (see module docstring).
    """
    line = json.dumps({"bench": bench, "metrics": dict(metrics)},
                      sort_keys=True)
    ROWS.append(line)
    print(line, flush=True)
    art_dir = os.environ.get("BENCH_ARTIFACT_DIR")
    if art_dir:
        path = pathlib.Path(art_dir)
        path.mkdir(parents=True, exist_ok=True)
        slug = re.sub(r"[^A-Za-z0-9_.-]+", "_", bench)
        with open(path / f"BENCH_{slug}.json", "a") as fh:
            fh.write(line + "\n")


def timed(fn: Callable, *args, n: int = 3, warmup: int = 1) -> float:
    """Median wall-time (seconds) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(n):
        t0 = time.monotonic()
        jax.block_until_ready(fn(*args))
        ts.append(time.monotonic() - t0)
    ts.sort()
    return ts[len(ts) // 2]
