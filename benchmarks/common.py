"""Shared benchmark utilities: timing + CSV emission."""

from __future__ import annotations

import time
from typing import Callable

import jax

ROWS = []


def emit(name: str, value, derived: str = "") -> None:
    row = f"{name},{value},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def timed(fn: Callable, *args, n: int = 3, warmup: int = 1) -> float:
    """Median wall-time (seconds) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(n):
        t0 = time.monotonic()
        jax.block_until_ready(fn(*args))
        ts.append(time.monotonic() - t0)
    ts.sort()
    return ts[len(ts) // 2]
