"""Mesh-sharded batched solve: host-device scaling of odeint(mesh=...).

The per-sample batched engine is embarrassingly parallel over the batch
— but on ONE device it still runs *lockstep in time*: every while_loop
iteration advances all B controller lanes, so the whole batch pays the
global straggler's iteration count.  Sharding the batch over a mesh
gives every shard its own trip count; with a heavy-tailed stiffness
batch (most elements easy, one very stiff) the per-shard work collapses
from ``B × max_b(trials)`` to ``Σ_s B_s × max_{b∈s}(trials)``, which is
why this benchmark speeds up even on a single CPU core running the
shards serially — it measures eliminated lockstep waste, not core
count, so it is stable in CI.

Protocol: the SAME B=64 dopri5/ACA solve (d=256 state, stiffness
``logk = 0.5 + 6.6·frac⁵`` — top element ≈40× more trials than the
median) is timed in a fresh subprocess per device count n ∈ {1,2,4,8}
(``--xla_force_host_platform_device_count`` is locked at jax init, so
each rung needs its own process), with per-device trial counts read
back from ``SolveStats``.  Headline gates (full and quick):

  * per-element trial counts identical on every rung (the sharded
    solve IS the unsharded solve, shard-locally);
  * throughput at 8 devices ≥ 3× the 1-device rung.

Emits BENCH_sharded_solve.json (speedups, scaling efficiency, straggler
trial spread) into the artifact trajectory.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from .common import emit, emit_json

DEVICE_LADDER = (1, 2, 4, 8)
B = 64
DIM = 256
MIN_SPEEDUP_8 = 3.0

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _child(n_dev: int, n_iter: int) -> None:
    """One rung: time the sharded solve on ``n_dev`` forced host devices
    (XLA_FLAGS comes from the parent's env) and print a JSON line."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import odeint
    from repro.distributed import shard_mesh

    assert jax.device_count() == n_dev, (jax.device_count(), n_dev)

    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    w = (jax.random.normal(k1, (DIM, DIM))
         * (0.3 / DIM ** 0.5)).astype(jnp.float32)
    x0 = (jax.random.normal(k2, (B, DIM - 1)) * 0.5).astype(jnp.float32)
    # heavy-tailed stiffness: most elements easy, the top shard stiff
    frac = jnp.arange(B) / (B - 1.0)
    logk = (0.5 + 6.6 * frac ** 5).astype(jnp.float32)
    z0 = jnp.concatenate([x0, logk[:, None]], axis=1)
    ts = jnp.array([0.0, 1.0], jnp.float32)

    def f(t, z, w):
        x, logk = z[:-1], z[-1]
        dx = -jnp.exp(logk) * x + 0.5 * jnp.tanh(x @ w[:-1, :-1])
        return jnp.concatenate([dx, jnp.zeros((1,), z.dtype)])

    mesh = shard_mesh()
    run = jax.jit(lambda z0, w: odeint(
        f, z0, ts, (w,), solver="dopri5", rtol=1e-7, atol=1e-7,
        max_steps=1024, grad_method="aca", batch_axis=0, mesh=mesh))

    ys, st = jax.block_until_ready(run(z0, w))
    t0 = time.monotonic()
    for _ in range(n_iter):
        jax.block_until_ready(run(z0, w))
    dt = (time.monotonic() - t0) / n_iter

    trials = np.asarray(st.n_trials)
    per_dev = trials.reshape(n_dev, -1).max(axis=1)
    print(json.dumps({
        "n_dev": n_dev,
        "t_s": dt,
        "throughput_el_s": B / dt,
        "trials_min": int(trials.min()),
        "trials_max": int(trials.max()),
        "trials_sum": int(trials.sum()),
        "dev_straggler_trials": per_dev.tolist(),
        "ys_sum": float(jnp.sum(ys)),
    }), flush=True)


def _run_rung(n_dev: int, n_iter: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in [os.path.join(_REPO, "src"),
                    env.get("PYTHONPATH", "")] if p)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_sharded_solve",
         "--child", str(n_dev), "--iters", str(n_iter)],
        cwd=_REPO, env=env, capture_output=True, text=True, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(
            f"sharded-solve rung n_dev={n_dev} failed:\n{proc.stdout}\n"
            f"{proc.stderr}")
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("{")][-1]
    return json.loads(line)


def run(quick: bool = True) -> None:
    n_iter = 3 if quick else 10
    rungs = {}
    for n_dev in DEVICE_LADDER:
        rungs[n_dev] = r = _run_rung(n_dev, n_iter)
        emit(f"sharded_solve/t_ms/{n_dev}dev", f"{r['t_s'] * 1e3:.1f}")
        emit(f"sharded_solve/throughput_el_s/{n_dev}dev",
             f"{r['throughput_el_s']:.1f}")
        emit(f"sharded_solve/straggler_trials/{n_dev}dev",
             f"{max(r['dev_straggler_trials'])}")

    base = rungs[DEVICE_LADDER[0]]
    # the sharded solve must BE the unsharded solve: identical
    # per-element trial counts (and forward sums) on every rung
    for n_dev, r in rungs.items():
        same = (r["trials_min"] == base["trials_min"]
                and r["trials_max"] == base["trials_max"]
                and r["trials_sum"] == base["trials_sum"])
        if not same:
            raise AssertionError(
                f"per-element trial counts changed under sharding at "
                f"n_dev={n_dev}: {r} vs 1-device {base}")

    speedups = {n: base["t_s"] / rungs[n]["t_s"] for n in DEVICE_LADDER}
    for n_dev in DEVICE_LADDER[1:]:
        emit(f"sharded_solve/speedup/{n_dev}dev", f"{speedups[n_dev]:.2f}")
        emit(f"sharded_solve/scaling_eff/{n_dev}dev",
             f"{speedups[n_dev] / n_dev:.2f}")

    s8 = speedups[8]
    ok = s8 >= MIN_SPEEDUP_8
    emit("sharded_solve/speedup_8dev_ge_3x", f"{int(ok)}",
         f"measured {s8:.2f}x")
    emit_json("sharded_solve", {
        "batch": B,
        "dim": DIM,
        "t_ms_1dev": base["t_s"] * 1e3,
        "t_ms_8dev": rungs[8]["t_s"] * 1e3,
        "speedup_2dev": speedups[2],
        "speedup_4dev": speedups[4],
        "speedup_8dev": s8,
        "scaling_eff_8dev": s8 / 8.0,
        "throughput_el_s_8dev": rungs[8]["throughput_el_s"],
        "straggler_trials": base["trials_max"],
        "median_shard_trials_8dev": sorted(
            rungs[8]["dev_straggler_trials"])[4],
        "gate_speedup_8dev_ge_3x": int(ok),
    })
    if not ok:
        raise AssertionError(
            f"sharded solve speedup at 8 devices is {s8:.2f}x < "
            f"{MIN_SPEEDUP_8}x — lockstep waste is not being eliminated "
            "(per-shard trip counts should collapse to shard-local "
            "stragglers)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--child", type=int, default=None,
                    help="internal: run one rung at this device count")
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args()
    if args.child is not None:
        _child(args.child, args.iters)
    else:
        run(quick=args.quick)


if __name__ == "__main__":
    main()
