"""Solve-health guard overhead gate (docs/robustness.md).

The non-finite guards run *inside* the trial loop of every adaptive
solve — one ``isfinite`` read of the already-computed error ratio per
ψ trial (a non-finite trial state always poisons it).  This bench
prices them on the stiff van der Pol hot loop by timing the same jitted
``adaptive_while_solve`` with ``guard_nonfinite=True`` vs ``False``
(the flag compiles the guards out entirely) and **gates** the overhead
at ≤5% of trials-runtime: if the guards ever grow a real cost — an
extra reduction over the state, a second pass over the trial — this
bench fails the suite rather than letting the default path regress.

A sub-millisecond noise floor escape keeps the gate meaningful on
machines where the whole solve is too fast to time at 5% resolution.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import ControllerConfig, adaptive_while_solve
from repro.core.tableaus import get_tableau

from .common import emit, emit_json

GATE_FRAC = 0.05          # guards may cost at most 5% of trials-runtime
NOISE_FLOOR_S = 1e-3      # below this, timing noise > gate resolution


def _vdp(t, z, mu):
    x, v = z[0], z[1]                     # (2, K) ensemble state
    return jnp.stack([v, mu * ((1.0 - x * x) * v) - x])


def run(quick: bool = False):
    mu = jnp.float32(8.0)                # stiff regime: rejection-heavy
    # K-wide ensemble: per-trial stage math is O(K), so the guard's two
    # extra mask reads are priced against real work, not loop dispatch
    K = 256
    x0 = 2.0 + 0.1 * jnp.arange(K, dtype=jnp.float32) / K
    z0 = jnp.stack([x0, jnp.zeros((K,), jnp.float32)])
    # long horizon + tight tolerance: thousands of trials, so the solve
    # clears the noise floor and 5% is actually resolvable
    ts = jnp.linspace(0.0, 40.0 if quick else 120.0, 8, dtype=jnp.float32)
    rtol = atol = 1e-9
    tab = get_tableau("dopri5")
    cfg = ControllerConfig(max_steps=65536, max_trials=12)
    reps = 20 if quick else 50

    def solve(guard):
        def fn(z):
            ys, _, stats = adaptive_while_solve(
                tab, _vdp, z, ts, (mu,), rtol, atol, cfg,
                guard_nonfinite=guard)
            return ys, stats.n_trials
        return jax.jit(fn)

    guarded, bare = solve(True), solve(False)
    # identical trials => identical work: the gate measures pure guard
    # cost, not a solver behavior change
    _, n_g = jax.block_until_ready(guarded(z0))
    _, n_b = jax.block_until_ready(bare(z0))
    assert int(n_g) == int(n_b), (int(n_g), int(n_b))

    # interleaved min-time pairs: back-to-back timing of the two
    # variants cancels clock/thermal drift, and the per-variant minimum
    # is the noise-robust estimator — a one-sided median here was
    # measurably order-biased at the few-ms scale the gate works at
    t_g, t_b = float("inf"), float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(guarded(z0))
        t1 = time.perf_counter()
        jax.block_until_ready(bare(z0))
        t2 = time.perf_counter()
        t_g, t_b = min(t_g, t1 - t0), min(t_b, t2 - t1)
    overhead = (t_g - t_b) / t_b

    emit("failure_overhead/trials", int(n_g), "stiff vdp, dopri5")
    emit("failure_overhead/guarded_s", f"{t_g:.5f}", "")
    emit("failure_overhead/bare_s", f"{t_b:.5f}", "")
    emit("failure_overhead/frac", f"{overhead:+.4f}",
         f"gate <= {GATE_FRAC:.2f}")
    emit_json("failure_overhead", {
        "trials": int(n_g),
        "guarded_s": float(t_g),
        "bare_s": float(t_b),
        "overhead_frac": float(overhead),
        "gate_frac": GATE_FRAC,
    })

    if t_b < NOISE_FLOOR_S:
        emit("failure_overhead/gate", "SKIP",
             f"bare runtime {t_b:.2e}s under noise floor")
        return
    assert overhead <= GATE_FRAC, (
        f"solve-health guards cost {overhead:.1%} of trials-runtime "
        f"(gate {GATE_FRAC:.0%}): t_guarded={t_g:.5f}s t_bare={t_b:.5f}s")
    emit("failure_overhead/gate", "PASS", "")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(quick=ap.parse_args().quick)
