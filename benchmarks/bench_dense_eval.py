"""Dense eval grids: ``interpolate_ts`` natural-grid solving vs forced
step landings — the tentpole claim of the dense-output subsystem.

A 64-point eval grid on the stiff van der Pol problem (μ = 4, the
paper's reverse-error testbed) forces the classic engine to land on
every eval time: the controller's natural steps get chopped to ~1/64 of
the horizon regardless of what the error control wants, inflating the ψ
trial count.  With ``interpolate_ts=True`` the controller advances on
its natural grid and eval times are read off each accepted step's
4th-order interpolant.

Acceptance gates (asserted):
  * ≥1.5× fewer ψ trials at 64 eval points;
  * ≤2e-4 max interpolation error against a 10³×-tighter reference.

Headline numbers land in the shared JSON schema (``common.emit_json``),
so CI's ``BENCH_dense_eval.json`` artifact records both.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import odeint
from .common import emit, emit_json

MU = 4.0
T1 = 3.0
N_EVAL = 64
TOL = 1e-5


def _vdp(t, z, mu):
    return jnp.stack([z[1], mu * (1 - z[0] ** 2) * z[1] - z[0]])


def run(quick: bool = False):
    z0 = jnp.array([2.0, 0.0])
    mu = jnp.float32(MU)
    ts = jnp.linspace(0.0, T1, N_EVAL)
    kw = dict(solver="dopri5", grad_method="aca", rtol=TOL, atol=TOL,
              max_steps=4096, max_trials=20)

    ys_land, st_land = odeint(_vdp, z0, ts, (mu,), **kw)
    ys_int, st_int = odeint(_vdp, z0, ts, (mu,), interpolate_ts=True,
                            **kw)
    ys_ref, _ = odeint(_vdp, z0, ts, (mu,), solver="dopri5",
                       grad_method="aca", rtol=1e-9, atol=1e-9,
                       max_steps=8192, max_trials=20)

    ref = np.asarray(ys_ref)
    err_land = float(np.abs(np.asarray(ys_land) - ref).max())
    err_int = float(np.abs(np.asarray(ys_int) - ref).max())
    trials_land = int(st_land.n_trials)
    trials_int = int(st_int.n_trials)
    speedup = trials_land / max(trials_int, 1)

    emit("dense_eval_trials/landing", trials_land,
         f"dopri5 aca tol={TOL}, {N_EVAL} forced landings")
    emit("dense_eval_trials/interpolate_ts", trials_int,
         "natural grid + per-step interpolant reads")
    emit("dense_eval_trials/ratio", f"{speedup:.2f}",
         "landing / interpolated trials")
    emit("dense_eval_err/landing", f"{err_land:.3e}",
         "max |y - ref(1e-9)|")
    emit("dense_eval_err/interpolate_ts", f"{err_int:.3e}",
         "max |y - ref(1e-9)| incl. interpolation")

    # the tentpole acceptance gates
    assert speedup >= 1.5, (
        "interpolate_ts must cut >= 1.5x trials on the dense grid",
        trials_land, trials_int)
    assert err_int <= 2e-4, (
        "interpolation error above the 2e-4 gate", err_int)

    # reverse-time spot check rides along: descending ts hits the same
    # natural-grid machinery (negated clock).  Short window only — the
    # vdp limit cycle attracts forward, so long reverse integrations
    # are genuinely ill-posed (that instability is the paper's Fig. 4
    # point, not a solver defect)
    t_rev0 = T1 / 8
    ys_fwd, _ = odeint(_vdp, z0, jnp.linspace(0.0, t_rev0, 8), (mu,),
                       **kw)
    ts_rev = jnp.linspace(t_rev0, 0.0, 8)
    ys_rev, st_rev = odeint(_vdp, jnp.asarray(ys_fwd[-1]), ts_rev,
                            (mu,), interpolate_ts=True, **kw)
    rev_gap = float(np.abs(np.asarray(ys_rev)[-1] - np.asarray(z0)).max())
    emit("dense_eval_reverse/trials", int(st_rev.n_trials),
         "descending-ts natural-grid solve back to t0")
    emit("dense_eval_reverse/roundtrip_gap", f"{rev_gap:.3e}",
         "|z(0) roundtrip - z0| (forward + reverse solve error)")
    # loose gate: the roundtrip conditioning number of reverse vdp
    # amplifies the forward solve's own tolerance-level error
    assert rev_gap < 1e-2, ("reverse-time roundtrip drifted", rev_gap)

    emit_json("dense_eval", {
        "n_eval": N_EVAL,
        "tol": TOL,
        "trials_landing": trials_land,
        "trials_interpolated": trials_int,
        "trial_ratio": round(speedup, 3),
        "max_err_landing": err_land,
        "max_err_interpolated": err_int,
        "reverse_roundtrip_gap": rev_gap,
    })


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(quick=ap.parse_args().quick)
