"""MALI — reversible asynchronous-leapfrog integrator tests.

Covers the ``grad_method="mali"`` contract end to end:

* ``alf_step_inverse(alf_step(s)) == s`` **bitwise** — the fixed-point
  lattice pair makes every state update an exact wrapping integer add,
  so inversion is a bijection for any input (deterministic pins across
  dtypes/scales + a hypothesis sweep when hypothesis is installed);
* full-trajectory reverse reconstruction is bit-identical to the
  forward trajectory on the solo engine (under jit — eager per-op
  dispatch may fuse the field by an ulp differently);
* gradients match ``grad_method="naive"`` to ≤1e-5 rel on the stiff
  van-der-Pol smoke problem, solo + batched × pytree + pallas;
* api surface: solver="alf" pairing rules, checkpoint_segments /
  interpolate_ts rejection, reverse-time ``ts``, multi-time outputs,
  NodeConfig threading.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import NodeConfig, node_block_apply, odeint
from repro.core.controller import ControllerConfig
from repro.core.integrate import mali_adaptive_solve
from repro.core.stepper import (
    alf_lattice_exponent,
    alf_step,
    alf_step_batched,
    alf_step_inverse,
    alf_step_inverse_batched,
    lattice_decode,
    lattice_encode,
)

MU = 2.0


def vdp(t, z, mu):
    """Stiff-ish van der Pol — the MALI smoke problem."""
    x, y = z[..., 0], z[..., 1]
    return jnp.stack([y, mu * (1.0 - x**2) * y - x], axis=-1)


def linear(t, z, k):
    return k * z


Z0_VDP = np.array([2.0, 0.0], np.float32)
TS_VDP = np.array([0.0, 0.5])


def _tree_bits_equal(a, b):
    return all(
        bool(jnp.all(x == y))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# bit-exact inversion of the lattice pair step
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [
    jnp.float32,
    pytest.param(jnp.float64, marks=pytest.mark.skipif(
        not jax.config.jax_enable_x64, reason="needs JAX_ENABLE_X64")),
])
@pytest.mark.parametrize("scale", [1e-20, 1e-3, 1.0, 37.0, 1e8, 1e30])
def test_alf_roundtrip_bitexact_scales(dtype, scale):
    """inverse(step(s)) == s bitwise, across dtypes and 50 orders of
    magnitude of state scale (lattice wraparound included)."""
    k = jnp.asarray(-0.7, dtype)
    z = (jax.random.normal(jax.random.PRNGKey(0), (17,)) * scale
         ).astype(dtype)
    v = linear(0.0, z, k)
    se = alf_lattice_exponent(z, v)
    zq, vq = lattice_encode(z, se), lattice_encode(v, se)
    t, h = jnp.asarray(0.3, dtype), jnp.asarray(0.05, dtype)
    res = jax.jit(lambda zq, vq: alf_step(
        linear, t, h, zq, vq, se, z, (k,)))(zq, vq)
    back = jax.jit(lambda zq, vq: alf_step_inverse(
        linear, t, h, zq, vq, se, z, (k,)))(res.zq_next, res.vq_next)
    assert _tree_bits_equal(back, (zq, vq))


def test_alf_roundtrip_bitexact_pytree_chain():
    """50 chained steps forward then 50 inversions recover every
    intermediate pair bitwise, on a nested pytree state."""
    def f(t, z, k):
        return {"a": k * z["a"], "b": -0.3 * z["b"] + jnp.mean(z["a"])}

    k = jnp.float32(-0.5)
    z = {"a": jax.random.normal(jax.random.PRNGKey(1), (8,)),
         "b": jax.random.normal(jax.random.PRNGKey(2), (3, 2))}
    v = f(0.0, z, k)
    se = alf_lattice_exponent(z, v)
    step = jax.jit(lambda t, zq, vq: alf_step(f, t, 0.02, zq, vq, se, z,
                                              (k,)))
    inv = jax.jit(lambda t, zq, vq: alf_step_inverse(
        f, t, 0.02, zq, vq, se, z, (k,)))
    states = [(lattice_encode(z, se), lattice_encode(v, se))]
    for i in range(50):
        r = step(jnp.float32(0.02 * i), *states[-1])
        states.append((r.zq_next, r.vq_next))
    cur = states[-1]
    for i in range(49, -1, -1):
        cur = inv(jnp.float32(0.02 * i), *cur)
        assert _tree_bits_equal(cur, states[i]), f"mismatch at step {i}"


def test_alf_roundtrip_bitexact_batched():
    """Per-row inversion is bitwise exact with per-row stepsizes,
    including h = 0 rows (the batched sweep inverts then masks)."""
    k = jnp.float32(-0.9)
    z = jax.random.normal(jax.random.PRNGKey(3), (4, 6))
    v = jax.vmap(lambda zi: linear(0.0, zi, k))(z)
    se = alf_lattice_exponent(z, v)
    zq, vq = lattice_encode(z, se), lattice_encode(v, se)
    t = jnp.array([0.0, 0.1, 0.2, 0.3], jnp.float32)
    h = jnp.array([0.05, 0.0, 0.11, 0.02], jnp.float32)
    res = jax.jit(lambda zq, vq: alf_step_batched(
        linear, t, h, zq, vq, se, z, (k,)))(zq, vq)
    back = jax.jit(lambda zq, vq: alf_step_inverse_batched(
        linear, t, h, zq, vq, se, z, (k,)))(res.zq_next, res.vq_next)
    assert _tree_bits_equal(back, (zq, vq))


def test_alf_step_order():
    """One ALF step is 2nd order: halving h cuts the one-step error ~8x
    (local O(h³)) on dz/dt = kz against the exact flow."""
    k = jnp.float32(-1.3)
    z = jnp.asarray([1.5], jnp.float32)
    v = linear(0.0, z, k)
    se = alf_lattice_exponent(z, v)

    def one_step_err(h):
        r = alf_step(linear, 0.0, jnp.float32(h), lattice_encode(z, se),
                     lattice_encode(v, se), se, z, (k,))
        return abs(float(r.z_next[0]) - 1.5 * np.exp(float(k) * h))

    e1, e2 = one_step_err(0.2), one_step_err(0.1)
    assert e1 / e2 > 5.0, (e1, e2)


# ---------------------------------------------------------------------------
# hypothesis sweep (optional module)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # extra coverage only — deterministic pins above
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=30, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        scale=st.floats(1e-6, 1e6),
        h=st.floats(1e-6, 10.0),
        k=st.floats(-5.0, 5.0),
    )
    def test_alf_roundtrip_bitexact_property(seed, scale, h, k):
        """inverse(step(s)) == s bitwise for arbitrary states/steps."""
        kk = jnp.float32(k)
        z = (jax.random.normal(jax.random.PRNGKey(seed), (9,))
             * scale).astype(jnp.float32)
        v = linear(0.0, z, kk)
        se = alf_lattice_exponent(z, v)
        zq, vq = lattice_encode(z, se), lattice_encode(v, se)
        hh = jnp.float32(h)
        res = jax.jit(lambda a, b: alf_step(
            linear, 0.0, hh, a, b, se, z, (kk,)))(zq, vq)
        back = jax.jit(lambda a, b: alf_step_inverse(
            linear, 0.0, hh, a, b, se, z, (kk,)))(res.zq_next,
                                                  res.vq_next)
        assert _tree_bits_equal(back, (zq, vq))


# ---------------------------------------------------------------------------
# full-trajectory reverse reconstruction (solo engine)
# ---------------------------------------------------------------------------


def test_reverse_reconstruction_bit_identical():
    """Inverting from the terminal pair reproduces every accepted
    forward state bitwise — the O(1)-memory contract of the MALI
    backward sweep (acceptance gate)."""
    z0 = jnp.asarray(Z0_VDP)
    mu = jnp.float32(MU)
    ts = jnp.asarray(TS_VDP, jnp.float32)
    _, grid, stats = mali_adaptive_solve(
        vdp, z0, ts, (mu,), 1e-5, 1e-5, ControllerConfig(max_steps=1024))
    assert not bool(stats.overflow)
    n = int(grid.n)
    assert n > 20  # the smoke problem must exercise a real grid

    def fwd_buf(z0, mu, tg, hg):
        v0 = vdp(jnp.float32(0.0), z0, mu)
        zq = lattice_encode(z0, grid.scale_exp)
        vq = lattice_encode(v0, grid.scale_exp)
        zb = jnp.zeros((n + 1,) + zq.shape, zq.dtype).at[0].set(zq)
        vb = jnp.zeros((n + 1,) + vq.shape, vq.dtype).at[0].set(vq)

        def body(i, c):
            zq, vq, zb, vb = c
            r = alf_step(vdp, tg[i], hg[i], zq, vq, grid.scale_exp, z0,
                         (mu,))
            return (r.zq_next, r.vq_next, zb.at[i + 1].set(r.zq_next),
                    vb.at[i + 1].set(r.vq_next))

        _, _, zb, vb = jax.lax.fori_loop(0, n, body, (zq, vq, zb, vb))
        return zb, vb

    def bwd_buf(zT, vT, z0, mu, tg, hg):
        zb = jnp.zeros((n + 1,) + zT.shape, zT.dtype).at[n].set(zT)
        vb = jnp.zeros((n + 1,) + vT.shape, vT.dtype).at[n].set(vT)

        def body(j, c):
            zq, vq, zb, vb = c
            i = n - 1 - j
            pz, pv = alf_step_inverse(vdp, tg[i], hg[i], zq, vq,
                                      grid.scale_exp, z0, (mu,))
            return (pz, pv, zb.at[i].set(pz), vb.at[i].set(pv))

        _, _, zb, vb = jax.lax.fori_loop(0, n, body, (zT, vT, zb, vb))
        return zb, vb

    zb, vb = jax.jit(fwd_buf)(z0, mu, grid.t, grid.h)
    # the while_loop engine and the fori_loop replay agree bitwise
    assert bool(jnp.all(zb[n] == grid.zT)) and bool(jnp.all(vb[n] == grid.vT))
    rzb, rvb = jax.jit(bwd_buf)(grid.zT, grid.vT, z0, mu, grid.t, grid.h)
    assert bool(jnp.all(rzb == zb)) and bool(jnp.all(rvb == vb))


# ---------------------------------------------------------------------------
# forward accuracy + gradient match vs the naive method
# ---------------------------------------------------------------------------


def test_forward_tracks_tolerance():
    ts = jnp.linspace(0.0, 2.0, 5)
    k = jnp.float32(-0.8)
    ys, stats = odeint(linear, jnp.float32(1.5), ts, (k,),
                       grad_method="mali", rtol=1e-5, atol=1e-5,
                       max_steps=2048)
    exact = 1.5 * np.exp(-0.8 * np.asarray(ts))
    assert not bool(stats.overflow)
    assert np.abs(np.asarray(ys) - exact).max() < 1e-4


def test_one_feval_per_trial():
    """ALF costs exactly one field evaluation per ψ trial (+3 setup:
    v0 and the two hinit evals)."""
    ts = jnp.array([0.0, 1.0])
    _, stats = odeint(linear, jnp.float32(1.0), ts, (jnp.float32(-0.5),),
                      grad_method="mali", rtol=1e-4, atol=1e-4,
                      max_steps=1024)
    assert int(stats.nfe) == int(stats.n_trials) + 3


def _vdp_grads(method, *, rtol, max_steps, use_pallas=False,
               batch=False, solver=None):
    z0 = jnp.asarray(Z0_VDP)
    if batch:
        z0 = jnp.stack([z0, jnp.array([1.0, 0.5]), jnp.array([0.3, -0.2])]
                       ).astype(jnp.float32)
    ts = jnp.asarray(TS_VDP, jnp.float32)

    def L(z0, mu):
        ys, _ = odeint(vdp, z0, ts, (mu,), grad_method=method,
                       solver=solver, rtol=rtol, atol=rtol,
                       max_steps=max_steps, use_pallas=use_pallas,
                       batch_axis=0 if batch else None)
        return jnp.sum(ys[-1] ** 2)

    return jax.grad(L, argnums=(0, 1))(z0, jnp.float32(MU))


@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("batch", [False, True])
def test_grads_match_naive_vdp(use_pallas, batch):
    """MALI gradients match naive direct-backprop ≤1e-5 rel on the
    stiff vdp smoke problem (acceptance gate), solo + batched ×
    pytree + pallas."""
    g_ref = _vdp_grads("naive", rtol=1e-8, max_steps=512, batch=batch,
                       solver="dopri5")
    g_mali = _vdp_grads("mali", rtol=1e-7, max_steps=8192, batch=batch,
                        use_pallas=use_pallas)
    for gm, gr in zip(g_mali, g_ref):
        denom = float(jnp.max(jnp.abs(gr)))
        assert float(jnp.max(jnp.abs(gm - gr))) <= 1e-5 * denom, (
            use_pallas, batch, gm, gr)


def test_grads_match_naive_pytree():
    """Pytree-state gradients (dict of mixed-shape leaves)."""
    def f(t, z, k):
        return {"a": k * z["a"], "b": -0.4 * z["b"] + jnp.mean(z["a"])}

    z0 = {"a": jnp.array([1.0, -0.5], jnp.float32),
          "b": jnp.array([[0.2], [0.7]], jnp.float32)}
    ts = jnp.array([0.0, 0.8])

    def L(method, rtol, ms, solver):
        def loss(z0, k):
            ys, _ = odeint(f, z0, ts, (k,), grad_method=method,
                           solver=solver, rtol=rtol, atol=rtol,
                           max_steps=ms)
            return sum(jnp.sum(l ** 2)
                       for l in jax.tree.leaves(
                           jax.tree.map(lambda y: y[-1], ys)))
        return jax.grad(loss, argnums=(0, 1))(z0, jnp.float32(-0.6))

    g_ref = L("naive", 1e-8, 512, "dopri5")
    g_mali = L("mali", 1e-7, 8192, None)
    for gm, gr in zip(jax.tree.leaves(g_mali), jax.tree.leaves(g_ref)):
        denom = float(jnp.max(jnp.abs(gr)))
        assert float(jnp.max(jnp.abs(gm - gr))) <= 1e-5 * max(denom, 1e-6)


def test_batched_matches_vmap_of_solo():
    """Per-element adaptive grids: batched outputs/grads track vmap of
    the solo solver (within the shared-lattice quantum)."""
    z0b = jnp.stack([jnp.array([2.0, 0.0]), jnp.array([1.0, 0.5]),
                     jnp.array([0.3, -0.2])]).astype(jnp.float32)
    ts = jnp.asarray(TS_VDP, jnp.float32)
    mu = jnp.float32(MU)

    ysb, stb = odeint(vdp, z0b, ts, (mu,), grad_method="mali",
                      batch_axis=0, rtol=1e-5, atol=1e-5, max_steps=2048)
    # heterogeneous stiffness must produce genuinely per-element grids
    assert len(set(np.asarray(stb.n_steps).tolist())) > 1

    def solo_solve(z):
        return odeint(vdp, z, ts, (mu,), grad_method="mali", rtol=1e-5,
                      atol=1e-5, max_steps=2048)

    ys_solo, st_solo = jax.vmap(solo_solve, out_axes=(1, 0))(z0b)
    # per-element lattices: the batched engine IS vmap of the solo
    # engine — identical grids and bit-equal outputs
    np.testing.assert_array_equal(np.asarray(stb.n_steps),
                                  np.asarray(st_solo.n_steps))
    np.testing.assert_array_equal(np.asarray(ysb), np.asarray(ys_solo))

    gb = jax.grad(lambda z: jnp.sum(odeint(
        vdp, z, ts, (mu,), grad_method="mali", batch_axis=0, rtol=1e-5,
        atol=1e-5, max_steps=2048)[0][-1] ** 2))(z0b)
    gs = jax.vmap(jax.grad(
        lambda z: jnp.sum(solo_solve(z)[0][-1] ** 2)))(z0b)
    assert float(jnp.max(jnp.abs(gb - gs))) < 1e-6


def test_multi_time_outputs_and_grad():
    """Interior eval times land exactly and carry cotangents through
    the inverting sweep."""
    ts = jnp.linspace(0.0, 1.0, 5)
    k = jnp.float32(-1.1)

    def L(z0):
        ys, _ = odeint(linear, z0, ts, (k,), grad_method="mali",
                       rtol=1e-6, atol=1e-6, max_steps=4096)
        return jnp.sum(ys ** 2)  # every eval time contributes

    g = jax.grad(L)(jnp.float32(1.3))
    exact = sum(2 * 1.3 * np.exp(2 * float(k) * t) for t in np.asarray(ts))
    assert abs(float(g) - exact) < 1e-3 * abs(exact)


def test_reverse_time_descending_ts():
    """Descending ts solves in reverse time under mali (front-door clock
    negation), gradients included."""
    k = jnp.float32(-0.8)
    ts = jnp.array([2.0, 0.0])

    def L(z0):
        ys, _ = odeint(linear, z0, ts, (k,), grad_method="mali",
                       rtol=1e-5, atol=1e-5, max_steps=2048)
        return ys[-1]

    val, g = jax.value_and_grad(L)(jnp.float32(1.0))
    assert abs(float(val) - np.exp(1.6)) < 1e-3
    assert abs(float(g) - np.exp(1.6)) < 1e-3 * np.exp(1.6)


# ---------------------------------------------------------------------------
# api surface
# ---------------------------------------------------------------------------


def test_api_solver_pairing():
    ts = jnp.array([0.0, 1.0])
    z0 = jnp.float32(1.0)
    with pytest.raises(ValueError, match="alf"):
        odeint(linear, z0, ts, (jnp.float32(-1.0),), grad_method="mali",
               solver="dopri5")
    with pytest.raises(ValueError, match="mali"):
        odeint(linear, z0, ts, (jnp.float32(-1.0),), grad_method="aca",
               solver="alf")
    # default solver resolves per method: both of these must run
    odeint(linear, z0, ts, (jnp.float32(-1.0),), grad_method="mali",
           rtol=1e-3, atol=1e-3)
    odeint(linear, z0, ts, (jnp.float32(-1.0),), grad_method="aca")


def test_api_rejects_checkpoint_segments():
    with pytest.raises(ValueError, match="checkpoint"):
        odeint(linear, jnp.float32(1.0), jnp.array([0.0, 1.0]),
               (jnp.float32(-1.0),), grad_method="mali",
               checkpoint_segments=4)


def test_api_rejects_interpolate_ts():
    with pytest.raises(ValueError, match="interpolate_ts"):
        odeint(linear, jnp.float32(1.0), jnp.array([0.0, 1.0]),
               (jnp.float32(-1.0),), grad_method="mali",
               interpolate_ts=True)


def test_node_block_mali():
    """NodeConfig(grad_method='mali') threads through the block apply;
    the fixed regime is rejected."""
    def block_fn(p, z, t):
        return jnp.tanh(z @ p)

    p = jax.random.normal(jax.random.PRNGKey(0), (8, 8)) * 0.3
    z0 = jax.random.normal(jax.random.PRNGKey(1), (4, 8))
    cfg = NodeConfig(enabled=True, solver="alf", grad_method="mali",
                     rtol=1e-3, atol=1e-3, max_steps=256)
    zT = node_block_apply(block_fn, p, z0, cfg)
    assert zT.shape == z0.shape and bool(jnp.all(jnp.isfinite(zT)))
    g = jax.grad(lambda p: jnp.sum(
        node_block_apply(block_fn, p, z0, cfg) ** 2))(p)
    assert bool(jnp.all(jnp.isfinite(g)))

    with pytest.raises(ValueError, match="fixed"):
        node_block_apply(block_fn, p, z0,
                         NodeConfig(enabled=True, grad_method="mali",
                                    regime="fixed"))


def test_pallas_backward_dispatches_increment_kernel(monkeypatch):
    """use_pallas=True must route the backward replay's half-drifts
    through the fused ``rk_stage_increment`` kernel (not silently fall
    back to the pytree path)."""
    from repro.kernels import ops
    ops.set_interpret(True)
    try:
        calls = {"increment": 0}
        orig = ops.rk_stage_increment
        monkeypatch.setattr(
            ops, "rk_stage_increment",
            lambda *a, **k: (calls.__setitem__(
                "increment", calls["increment"] + 1) or orig(*a, **k)))
        g = jax.grad(lambda z0: odeint(
            linear, z0, jnp.array([0.0, 1.0]), (jnp.float32(-0.5),),
            grad_method="mali", rtol=1e-3, atol=1e-3, max_steps=256,
            use_pallas=True)[0][-1].sum())(jnp.ones((4,), jnp.float32))
        assert calls["increment"] > 0
        assert bool(jnp.all(jnp.isfinite(g)))
    finally:
        ops.set_interpret(None)


def test_stats_shape_batched():
    z0b = jnp.stack([jnp.array([1.0, 0.0]), jnp.array([0.5, 0.2])]
                    ).astype(jnp.float32)
    _, st = odeint(vdp, z0b, jnp.array([0.0, 0.3]), (jnp.float32(MU),),
                   grad_method="mali", batch_axis=0, rtol=1e-4,
                   atol=1e-4, max_steps=1024)
    assert st.n_steps.shape == (2,)
    assert not bool(jnp.any(st.overflow))
