"""Meta-tooling coverage: pass/fail fixture cases for check_bench_schema,
check_docs, and the solver_lint CLI (the ISSUE-8 gap: the CI gates
themselves had zero tests)."""

import json
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))

from tools import check_bench_schema, check_docs  # noqa: E402


def _cli(args, **env_extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("REPRO_PALLAS_INTERPRET", "1")
    return subprocess.run(
        [sys.executable] + args, cwd=REPO, env={**env, **env_extra},
        capture_output=True, text=True)


# ---------------------------------------------------------------------------
# check_bench_schema


GOOD_LINE = json.dumps({"bench": "solve", "metrics": {"ms": 1.5, "n": 3}})


def test_bench_schema_accepts_valid_artifacts(tmp_path):
    (tmp_path / "BENCH_solve.json").write_text(GOOD_LINE + "\n")
    assert check_bench_schema.main(["prog", str(tmp_path)]) == 0


def test_bench_schema_rejects_bad_lines(tmp_path, capsys):
    bad = "\n".join([
        GOOD_LINE,
        json.dumps({"bench": "", "metrics": {"ms": 1.0}}),
        json.dumps({"bench": "x", "metrics": {}}),
        json.dumps({"bench": "x", "metrics": {"ms": float("inf")}}),
        "not json at all",
    ])
    (tmp_path / "BENCH_bad.json").write_text(bad + "\n")
    assert check_bench_schema.main(["prog", str(tmp_path)]) == 1
    out = capsys.readouterr().out
    assert "non-empty string" in out
    assert "not valid JSON" in out


def test_bench_schema_rejects_empty_artifact_dir(tmp_path):
    assert check_bench_schema.main(["prog", str(tmp_path)]) == 1


# ---------------------------------------------------------------------------
# check_docs


def _docs_fixture(tmp_path, readme, doc=""):
    (tmp_path / "README.md").write_text(textwrap.dedent(readme))
    (tmp_path / "docs").mkdir()
    (tmp_path / "docs" / "page.md").write_text(textwrap.dedent(doc))
    return tmp_path


def test_check_docs_passes_on_good_fixture(tmp_path, monkeypatch):
    _docs_fixture(
        tmp_path,
        """\
        # readme
        [page](docs/page.md) and `repro.core.odeint` live here.

        ```python
        x = 1 + 1
        ```
        """,
    )
    monkeypatch.setattr(check_docs, "ROOT", tmp_path)
    assert check_docs.check_links() == []
    assert check_docs.check_snippets() == []
    assert check_docs.check_symbol_refs() == []


def test_check_docs_catches_broken_link(tmp_path, monkeypatch):
    _docs_fixture(tmp_path, "[gone](docs/missing.md)\n")
    monkeypatch.setattr(check_docs, "ROOT", tmp_path)
    errors = check_docs.check_links()
    assert errors and "broken link" in errors[0]


def test_check_docs_catches_bad_snippet(tmp_path, monkeypatch):
    _docs_fixture(tmp_path, "```python\ndef f(:\n```\n")
    monkeypatch.setattr(check_docs, "ROOT", tmp_path)
    errors = check_docs.check_snippets()
    assert errors and "does not parse" in errors[0]


def test_check_docs_catches_dead_symbol_ref(tmp_path, monkeypatch):
    _docs_fixture(
        tmp_path,
        "see `repro.core.odeint` (fine) and `repro.core.not_a_symbol` (dead)\n",
    )
    monkeypatch.setattr(check_docs, "ROOT", tmp_path)
    errors = check_docs.check_symbol_refs()
    assert len(errors) == 1
    assert "repro.core.not_a_symbol" in errors[0]
    assert "README.md:1" in errors[0]


def test_check_docs_skips_refs_inside_fences(tmp_path, monkeypatch):
    _docs_fixture(
        tmp_path,
        "```python\n# `repro.core.not_a_symbol` in code is snippet-gated\n```\n",
    )
    monkeypatch.setattr(check_docs, "ROOT", tmp_path)
    assert check_docs.check_symbol_refs() == []


def test_check_docs_cli_passes_on_repo():
    res = _cli([str(REPO / "tools" / "check_docs.py")])
    assert res.returncode == 0, res.stdout + res.stderr


# ---------------------------------------------------------------------------
# solver_lint CLI


def test_solver_lint_cli_fails_on_violation_and_baseline_suppresses(tmp_path):
    target = tmp_path / "core" / "api.py"
    target.parent.mkdir(parents=True)
    target.write_text('def f(grad_method="definitely_not_real"):\n    pass\n')

    res = _cli(["-m", "tools.solver_lint", str(target), "--baseline", "",
                "--root", str(tmp_path)])
    assert res.returncode == 1, res.stdout + res.stderr
    assert "registry-drift" in res.stdout
    assert "core/api.py:1" in res.stdout

    baseline = tmp_path / "baseline.json"
    baseline.write_text(json.dumps([{
        "rule": "registry-drift", "path": "core/api.py",
        "match": "definitely_not_real",
        "justification": "test fixture"}]))
    res = _cli(["-m", "tools.solver_lint", str(target),
                "--baseline", str(baseline), "--root", str(tmp_path)])
    assert res.returncode == 0, res.stdout + res.stderr
    assert "1 suppressed" in res.stdout


def test_solver_lint_cli_clean_on_repo_src():
    res = _cli(["-m", "tools.solver_lint", "src/"])
    assert res.returncode == 0, res.stdout + res.stderr
    assert "0 finding(s)" in res.stdout


def test_jaxpr_analyzer_cli_single_config(tmp_path):
    report = tmp_path / "report.txt"
    res = _cli(["-m", "repro.analysis", "--configs", "naive-solo",
                "--report", str(report)])
    assert res.returncode == 0, res.stdout + res.stderr
    assert report.exists() and "0 finding(s)" in report.read_text()


def test_jaxpr_analyzer_cli_lists_full_matrix():
    res = _cli(["-m", "repro.analysis", "--list"])
    assert res.returncode == 0
    names = res.stdout.split()
    assert len(names) == 37
    for probe in ("aca-seg-pallas-sharded", "mali-batched", "aca-full-warn",
                  "aca-full-rowtol-pallas-batched", "serve-chunk",
                  "serve-chunk-mali"):
        assert probe in names


# --------------------------------------------------------------------------
# benchmarks.common percentile / latency math (serving benchmarks)

from benchmarks.common import latency_summary, percentile  # noqa: E402


def test_percentile_known_distribution():
    xs = list(range(1, 101))          # 1..100
    assert percentile(xs, 0) == 1.0
    assert percentile(xs, 100) == 100.0
    assert percentile(xs, 50) == 50.5  # even n: mean of middle pair
    # p99 of 1..100 by linear interpolation: 99.01
    assert abs(percentile(xs, 99) - 99.01) < 1e-9
    # order-independent
    import random
    sh = xs[:]
    random.Random(0).shuffle(sh)
    assert percentile(sh, 99) == percentile(xs, 99)


def test_percentile_odd_median_exact():
    assert percentile([3.0, 1.0, 2.0], 50) == 2.0


def test_percentile_single_sample_is_every_percentile():
    for q in (0, 1, 50, 99, 100):
        assert percentile([7.25], q) == 7.25


def test_percentile_empty_and_bad_q_raise():
    with pytest.raises(ValueError, match="empty"):
        percentile([], 50)
    with pytest.raises(ValueError, match="q must be"):
        percentile([1.0], 101)
    with pytest.raises(ValueError, match="q must be"):
        percentile([1.0], -0.1)


def test_latency_summary_fields():
    s = latency_summary([4, 1, 3, 2])
    assert s["n"] == 4
    assert s["p50"] == 2.5
    assert s["max"] == 4.0
    assert s["mean"] == 2.5
    assert s["p99"] == pytest.approx(3.97)
    with pytest.raises(ValueError, match="empty"):
        latency_summary([])
