"""Segmented (O(K)-state) ACA checkpointing — gradient parity.

``checkpoint_segments=K`` must not change gradients: the backward sweep
re-integrates each segment from its snapshot with the *saved* stepsizes
and a re-chained FSAL k0 carry, so every replayed ψ is the forward ψ.
We assert **exact** float equality in the configurations where the
compiled replay is bit-stable — the solo engine on both stepper paths
and the batched engine on the fused-kernel path (Pallas calls compile
identically in any loop context) — and ulp-level agreement on the
batched *pytree* path, where XLA CPU fuses the per-row vector-field
arithmetic differently between the forward while_loop and the replay
fori_loop.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import odeint
from repro.core.controller import ControllerConfig
from repro.core.integrate import (
    adaptive_while_solve,
    batched_adaptive_while_solve,
    resolve_checkpoint_segments,
    resolve_segmentation,
    segment_length,
)
from repro.core.tableaus import get_tableau
from repro.kernels import ops

MAX_STEPS = 48
TS = (0.0, 0.6, 1.3)
# per-solver tolerances calibrated so every grid has enough accepted
# steps to segment without overflowing the checkpoint capacity
SOLO_TOL = {"dopri5": 1e-7, "bosh3": 1e-6, "heun_euler": 1e-4}
BATCHED_CFG = {"dopri5": (1e-4, 64), "heun_euler": (1e-3, 96)}


@pytest.fixture(autouse=True)
def _interpret_kernels():
    ops.set_interpret(True)
    yield
    ops.set_interpret(None)


def _assert_trees_bitequal(a, b, what=""):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=what)


# ---------------------------------------------------------------- solo --

def _f_solo(t, z, w):
    return {"x": jnp.tanh(w @ z["x"]) - 0.3 * z["x"],
            "y": -0.5 * z["y"] + 0.1 * jnp.sin(z["y"]) * z["x"][:2][None]}


def _solo_problem():
    w = jax.random.normal(jax.random.PRNGKey(0), (5, 5)) * 0.5
    z0 = {"x": jax.random.normal(jax.random.PRNGKey(1), (5,)),
          "y": jax.random.normal(jax.random.PRNGKey(2), (3, 2))}
    return z0, w


@functools.lru_cache(maxsize=None)
def _solo_grads(solver, use_pallas, segments, max_steps=MAX_STEPS):
    z0, w = _solo_problem()
    tol = SOLO_TOL[solver]

    def loss(z0, w):
        ys, stats = odeint(_f_solo, z0, jnp.asarray(TS), (w,),
                           solver=solver, rtol=tol, atol=tol,
                           max_steps=max_steps, use_pallas=use_pallas,
                           checkpoint_segments=segments)
        return ((ys["x"][-1] ** 2).sum() + (ys["y"][1] ** 3).sum(),
                stats)
    (_, stats), g = jax.value_and_grad(
        loss, argnums=(0, 1), has_aux=True)(z0, w)
    return g, stats


@pytest.mark.parametrize("solver", ["dopri5", "heun_euler"])
@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("segments", [1, 3, "auto"])
def test_solo_grads_bitmatch_full_buffer(solver, use_pallas, segments):
    g_full, stats = _solo_grads(solver, use_pallas, None)
    g_seg, stats_seg = _solo_grads(solver, use_pallas, segments)
    assert int(stats.n_steps) > 4  # the grid is long enough to segment
    assert int(stats_seg.n_steps) == int(stats.n_steps)
    _assert_trees_bitequal(g_seg, g_full,
                           f"{solver}/pallas={use_pallas}/K={segments}")


def test_solo_bosh3_auto_bitmatch():
    _assert_trees_bitequal(_solo_grads("bosh3", False, "auto")[0],
                           _solo_grads("bosh3", False, None)[0])


def test_K_at_least_max_steps_is_the_full_buffer():
    # seg_len == 1 delegates to the classic sweep: exactly equal, and
    # oversized K clamps to max_steps first
    for K in (MAX_STEPS, 10_000):
        _assert_trees_bitequal(_solo_grads("dopri5", False, K)[0],
                               _solo_grads("dopri5", False, None)[0])


# ------------------------------------------------------------- batched --

def _f_batched(t, z, w):
    x, logk = z[:-1], z[-1]
    dx = -jnp.exp(logk) * x + 0.1 * jnp.tanh(w @ x)
    return jnp.concatenate([dx, jnp.zeros((1,), z.dtype)])


def _batched_problem(B=4, d=8):
    x0 = jax.random.normal(jax.random.PRNGKey(0), (B, d - 1))
    logk = jnp.linspace(0.0, 2.5, B)  # stiffness spread -> ragged grids
    z0 = jnp.concatenate([x0, logk[:, None]], axis=1).astype(jnp.float32)
    w = (jax.random.normal(jax.random.PRNGKey(1), (d - 1, d - 1))
         * 0.3).astype(jnp.float32)
    return z0, w


@functools.lru_cache(maxsize=None)
def _batched_grads(solver, use_pallas, segments):
    z0, w = _batched_problem()
    tol, max_steps = BATCHED_CFG[solver]

    def loss(z0, w):
        ys, stats = odeint(_f_batched, z0, jnp.asarray(TS, jnp.float32),
                           (w,), solver=solver, batch_axis=0, rtol=tol,
                           atol=tol, max_steps=max_steps,
                           use_pallas=use_pallas,
                           checkpoint_segments=segments)
        return (ys[-1] ** 2).sum() + (ys[1] ** 3).sum(), stats
    (_, stats), g = jax.value_and_grad(
        loss, argnums=(0, 1), has_aux=True)(z0, w)
    return g, stats


@pytest.mark.parametrize("solver", ["dopri5", "heun_euler"])
@pytest.mark.parametrize("segments", [1, 3, "auto"])
def test_batched_pallas_grads_bitmatch(solver, segments):
    g_full, stats = _batched_grads(solver, True, None)
    g_seg, _ = _batched_grads(solver, True, segments)
    # the stiffness spread must actually produce ragged per-element
    # grids, otherwise the end-aligned replay is not exercised
    assert len(set(np.asarray(stats.n_steps).tolist())) > 1
    _assert_trees_bitequal(g_seg, g_full, f"{solver}/K={segments}")


@pytest.mark.parametrize("segments", [1, 3, "auto"])
def test_batched_pytree_grads_near_exact(segments):
    (dz0_f, dw_f), _ = _batched_grads("dopri5", False, None)
    (dz0_s, dw_s), _ = _batched_grads("dopri5", False, segments)
    # the replayed states pick up ~1 ulp from XLA CPU fusing the per-row
    # field arithmetic differently inside the fori_loop than inside the
    # forward while_loop (see module docstring) — agreement is at fp
    # noise level, far below the adjoint method's systematic error
    np.testing.assert_allclose(np.asarray(dz0_s), np.asarray(dz0_f),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(dw_s), np.asarray(dw_f),
                               rtol=1e-5, atol=1e-7)


def test_batched_heun_euler_pytree_bitmatch():
    # the non-FSAL 2-stage tableau compiles bit-stably even on the
    # batched pytree path — full exactness holds there
    g_full, _ = _batched_grads("heun_euler", False, None)
    g_seg, _ = _batched_grads("heun_euler", False, "auto")
    _assert_trees_bitequal(g_seg, g_full)


# ------------------------------------------------- overflow / raggedness --

def test_overflow_still_bitmatches_full_buffer():
    """A segment can never exceed its replay budget (seg_len is derived
    from max_steps), so the overflow mode is the *solve* running out of
    accepted steps: both buffers then hold the same truncated grid and
    gradients must still agree exactly."""
    g_full, stats_full = _solo_grads("dopri5", False, None, max_steps=3)
    g_seg, stats_seg = _solo_grads("dopri5", False, 2, max_steps=3)
    assert bool(stats_full.overflow) and bool(stats_seg.overflow)
    _assert_trees_bitequal(g_seg, g_full)


# ------------------------------------------------------- plumbing/shapes --

def test_snapshot_buffer_shapes():
    tab = get_tableau("dopri5")
    cfg = ControllerConfig(max_steps=32, max_trials=12)
    z0, w = _solo_problem()
    _, ck, _ = jax.jit(lambda z0, w: adaptive_while_solve(
        tab, _f_solo, z0, jnp.asarray(TS), (w,), 1e-4, 1e-4, cfg,
        checkpoint_segments=4))(z0, w)
    assert ck.z["x"].shape == (4, 5) and ck.z["y"].shape == (4, 3, 2)
    assert ck.k0["x"].shape == (4, 5)
    assert ck.t.shape == (32,)  # scalar grids keep every step

    z0b, wb = _batched_problem()
    _, ckb, _ = jax.jit(lambda z0, w: batched_adaptive_while_solve(
        tab, _f_batched, z0, jnp.asarray(TS, jnp.float32), (w,), 1e-4,
        1e-4, cfg, checkpoint_segments=4))(z0b, wb)
    assert ckb.z.shape == (4, 4, 8) and ckb.k0.shape == (4, 4, 8)
    assert ckb.t.shape == (4, 32)


def test_resolve_checkpoint_segments():
    assert resolve_checkpoint_segments(None, 64) is None
    assert resolve_checkpoint_segments("auto", 64) == 8
    assert resolve_checkpoint_segments("auto", 50) == 8  # ceil(sqrt)
    assert resolve_checkpoint_segments(200, 64) == 64    # clamped
    with pytest.raises(ValueError):
        resolve_checkpoint_segments(0, 64)
    # K segments of seg_len steps always cover the whole grid
    for max_steps in (7, 32, 50, 64):
        for K in (1, 2, 3, 5, max_steps):
            assert K * segment_length(K, max_steps) >= max_steps
    # degenerate seg_len == 1 resolves to the full buffer
    assert resolve_segmentation(None, 64) == (None, None)
    assert resolve_segmentation(64, 64) == (None, None)
    assert resolve_segmentation(8, 64) == (8, 8)


def test_rejected_for_non_aca_and_fixed_solvers():
    z0, w = _solo_problem()
    for kw in (dict(grad_method="adjoint"), dict(grad_method="naive"),
               dict(solver="rk4")):
        with pytest.raises(ValueError, match="checkpoint_segments"):
            odeint(_f_solo, z0, jnp.asarray(TS), (w,),
                   checkpoint_segments=4, **kw)
