"""Mesh-sharded batched solving: ``odeint(..., mesh=...)`` parity tier.

The multi-device tests need 8 devices, which jax locks at first init —
so this file runs twice:

* under plain tier-1 (1 CPU device) every multi-device test skips and
  ``test_suite_under_forced_devices`` re-runs *this same file* in a
  subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
  (where the wrapper itself skips — no recursion);
* under the CI ``multidevice`` job (flag already exported) the tests
  run directly, with per-test granularity.

Parity contract proven here, per gradient method × {pytree, pallas}:
the sharded solve IS the unsharded ``batch_axis=0`` solve — outputs
and per-element stats bit-equal, z0-cotangents bit-equal — and the
pytree path also matches ``jax.vmap``-of-solo bit-for-bit.  Only the
shared-``args`` gradient may move: ``shard_map``'s transpose psums the
per-shard partial sums in a different association order (≤1e-6 rel for
the RK methods; MALI's longer per-step accumulation chain amplifies
the reorder to a few 1e-6).

Also here: solve-health status isolation per shard (a poisoned element
fails alone), mesh validation errors, per-element ``h0`` placement, a
2-D (data, model) mesh, ``NodeConfig.mesh`` threading, and the elastic
mesh-shape derivation (pure at any device count; constructed meshes at
{1, 8, 16, 32} forced host devices in a subprocess).
"""

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SolveStatus, odeint
from repro.core.node_block import NodeConfig, node_block_apply
from repro.distributed import (batch_partition_axes, batch_shard_count,
                               shard_mesh)
from repro.launch.mesh import elastic_mesh_shape

from faults import faulty_field

MULTI = jax.device_count() >= 8
multi = pytest.mark.skipif(
    not MULTI, reason="needs 8 forced host devices (subprocess wrapper "
    "covers this under tier-1)")

B, D = 8, 4
TS = jnp.array([0.0, 0.5, 1.0])
METHODS = ["aca", "adjoint", "naive", "mali"]
# shared-args cotangent tolerance: the psum reorders the per-shard
# partial sums; mali accumulates over ~10x more (lattice) steps
ARGS_RTOL = {"aca": 1e-6, "adjoint": 1e-6, "naive": 1e-6, "mali": 5e-6}


def _f(t, z, w):
    """Per-sample field with state-embedded stiffness: z[-1] holds the
    element's log-rate (derivative 0), so one batch spans easy → stiff
    and every element earns its own adaptive grid."""
    x, logk = z[:-1], z[-1]
    dx = -jnp.exp(logk) * x + 0.1 * jnp.tanh(w * x)
    return jnp.concatenate([dx, jnp.zeros((1,), z.dtype)])


def _hetero_batch(b=B, d=D, top=3.5):
    x0 = jax.random.normal(jax.random.PRNGKey(0), (b, d - 1)) * 0.5
    logk = jnp.linspace(0.0, top, b)
    return jnp.concatenate([x0, logk[:, None]], axis=1).astype(jnp.float32)


def _kw(method):
    kw = dict(rtol=1e-5, atol=1e-5, grad_method=method, batch_axis=0)
    kw.update(dict(max_steps=2048) if method == "mali"
              else dict(solver="dopri5", max_steps=64))
    return kw


def _batch_for(method):
    # the 2nd-order ALF pair needs ~e^logk steps at this tolerance: a
    # 3.5 top overflows max_steps=2048, so mali gets a milder ladder
    # (still stiffness-heterogeneous: ~25x trial spread)
    return _hetero_batch(top=1.5 if method == "mali" else 3.5)


@pytest.fixture
def _interpret_kernels():
    from repro.kernels import ops
    ops.set_interpret(True)
    yield
    ops.set_interpret(None)


def _assert_tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------- parity

@multi
@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("pallas", [False, True], ids=["pytree", "pallas"])
def test_sharded_matches_unsharded(method, pallas, _interpret_kernels):
    """ys/stats bit-equal, z0-grad bit-equal, args-grad ≤tol."""
    mesh = shard_mesh()
    z0, w = _batch_for(method), jnp.float32(0.7)
    kw = _kw(method)
    kw["use_pallas"] = pallas

    ref = jax.jit(lambda z, w: odeint(_f, z, TS, (w,), **kw))
    shd = jax.jit(lambda z, w: odeint(_f, z, TS, (w,), **kw, mesh=mesh))
    ys0, st0 = ref(z0, w)
    ys1, st1 = shd(z0, w)
    _assert_tree_equal(ys0, ys1)
    _assert_tree_equal(tuple(st0), tuple(st1))
    assert bool((np.asarray(st1.status) == SolveStatus.OK).all())

    def loss(z, w, mesh=None):
        ys, _ = odeint(_f, z, TS, (w,), **kw, mesh=mesh)
        return jnp.sum(ys * ys)

    g0 = jax.jit(lambda z, w: jax.grad(loss, argnums=(0, 1))(z, w))(z0, w)
    g1 = jax.jit(
        lambda z, w: jax.grad(loss, argnums=(0, 1))(z, w, mesh))(z0, w)
    _assert_tree_equal(g0[0], g1[0])           # z0-grad: shard-local
    np.testing.assert_allclose(np.asarray(g0[1]), np.asarray(g1[1]),
                               rtol=ARGS_RTOL[method])


@multi
@pytest.mark.parametrize("method", METHODS)
def test_sharded_matches_vmap_of_solo(method):
    """The pytree sharded solve == jax.vmap of the solo solver, bitwise
    (the batch_axis=0 engine's contract, preserved under shard_map)."""
    mesh = shard_mesh()
    z0, w = _batch_for(method), jnp.float32(0.7)
    kw = _kw(method)
    solo_kw = dict(kw)
    solo_kw.pop("batch_axis")

    shd = jax.jit(lambda z, w: odeint(_f, z, TS, (w,), **kw, mesh=mesh))
    vm = jax.jit(jax.vmap(
        lambda zi, w: odeint(_f, zi, TS, (w,), **solo_kw)[0],
        in_axes=(0, None), out_axes=1))
    ys1, _ = shd(z0, w)
    np.testing.assert_array_equal(np.asarray(ys1), np.asarray(vm(z0, w)))


@multi
def test_per_element_h0_shards_with_the_batch():
    mesh = shard_mesh()
    z0, w = _hetero_batch(), jnp.float32(0.7)
    h0 = jnp.full((B,), 1e-3, jnp.float32)
    kw = _kw("aca")
    ys0, st0 = jax.jit(
        lambda z: odeint(_f, z, TS, (w,), **kw, h0=h0))(z0)
    ys1, st1 = jax.jit(
        lambda z: odeint(_f, z, TS, (w,), **kw, h0=h0, mesh=mesh))(z0)
    _assert_tree_equal(ys0, ys1)
    _assert_tree_equal(tuple(st0), tuple(st1))


@multi
@pytest.mark.parametrize("method", METHODS)
def test_scalar_args_grad_wrt_z0_only(method):
    """Rank-0 args leaves under mesh with grads taken wrt z0 ONLY.

    jax 0.4.x shard_map dies with a _SpecError when a custom_vjp inside
    the body saves a rank-0 residual and that residual is a *known*
    (non-differentiated) value — grad wrt (z0, args) works, grad wrt z0
    alone does not.  odeint promotes scalar args leaves to (1,) around
    the shard_map (field code still sees true scalars), so both
    argnums shapes must work and match the unsharded path.
    """
    mesh = shard_mesh()
    z0, w = _batch_for(method), jnp.float32(0.7)
    kw = _kw(method)

    def loss(z, w, mesh=None):
        ys, _ = odeint(_f, z, TS, (w,), **kw, mesh=mesh)
        return jnp.sum(ys * ys)

    g0 = jax.jit(lambda z: jax.grad(loss)(z, w))(z0)
    g1 = jax.jit(lambda z: jax.grad(loss)(z, w, mesh))(z0)
    _assert_tree_equal(g0, g1)
    # dict-shaped args with a scalar leaf, eager grad (no jit)
    f2 = lambda t, z, a: _f(t, z, a["w"])
    ge = jax.grad(lambda z: jnp.sum(
        odeint(f2, z, TS, {"w": w}, **kw, mesh=mesh)[0]))(z0)
    gu = jax.grad(lambda z: jnp.sum(
        odeint(f2, z, TS, {"w": w}, **kw)[0]))(z0)
    _assert_tree_equal(gu, ge)


@multi
def test_2d_mesh_shards_data_axis_only():
    """On a (data=4, model=2) mesh the batch splits 4-way over 'data'
    and replicates over 'model' — same answers, 4 shards."""
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    assert batch_partition_axes(mesh) == ("data",)
    assert batch_shard_count(mesh) == 4
    z0, w = _hetero_batch(), jnp.float32(0.7)
    kw = _kw("aca")
    ys0, _ = jax.jit(lambda z: odeint(_f, z, TS, (w,), **kw))(z0)
    ys1, _ = jax.jit(
        lambda z: odeint(_f, z, TS, (w,), **kw, mesh=mesh))(z0)
    _assert_tree_equal(ys0, ys1)


@multi
def test_composes_with_segmented_checkpoints():
    mesh = shard_mesh()
    z0, w = _hetero_batch(), jnp.float32(0.7)
    kw = _kw("aca")
    ys0, _ = jax.jit(lambda z: odeint(
        _f, z, TS, (w,), **kw, checkpoint_segments=4))(z0)
    ys1, _ = jax.jit(lambda z: odeint(
        _f, z, TS, (w,), **kw, checkpoint_segments=4, mesh=mesh))(z0)
    _assert_tree_equal(ys0, ys1)


@multi
def test_composes_with_interpolate_ts():
    """Dense-output eval under sharding: the step grid (stats) and the
    endpoint states are bit-equal; *interior* interpolated reads are
    weighted stage sums whose fusion the sharded module reassociates —
    equal only to a few ulp, well inside the solve tolerance."""
    mesh = shard_mesh()
    z0, w = _hetero_batch(), jnp.float32(0.7)
    kw = _kw("aca")
    ys0, st0 = jax.jit(lambda z: odeint(
        _f, z, TS, (w,), **kw, interpolate_ts=True))(z0)
    ys1, st1 = jax.jit(lambda z: odeint(
        _f, z, TS, (w,), **kw, interpolate_ts=True, mesh=mesh))(z0)
    _assert_tree_equal(tuple(st0), tuple(st1))
    np.testing.assert_array_equal(np.asarray(ys0[0]), np.asarray(ys1[0]))
    np.testing.assert_array_equal(np.asarray(ys0[-1]), np.asarray(ys1[-1]))
    np.testing.assert_allclose(np.asarray(ys0), np.asarray(ys1),
                               rtol=1e-5, atol=1e-6)


# ------------------------------------------------- solve-health isolation

@multi
def test_fault_isolation_per_shard():
    """A NaN-poisoned element fails alone under sharding: only its
    status flips to NONFINITE_STATE (solve-health is per element, per
    shard), outputs stay finite, and the whole faulty solve takes the
    *same trajectory* as the unsharded faulty solve — statuses, trial
    counts and f-evals bit-equal per element; the output values agree
    to a few ulp (the fault wrapper's extra where-ops fuse differently
    inside the shard_map module, reassociating the stage combines —
    the clean-field parity test above stays fully bitwise).  The
    clean-vs-faulty inertness of the guards is PR 6's property, covered
    in test_solve_health_properties."""
    mesh = shard_mesh()
    z0, w = _hetero_batch(), jnp.float32(0.7)
    bad = 5
    tag = float(z0[bad, -1])
    fbad = faulty_field(_f, "nan", t_ge=0.5,
                        predicate=lambda t, z: jnp.abs(z[-1] - tag) < 1e-4)
    kw = _kw("aca")
    ys0, st0 = jax.jit(
        lambda z: odeint(fbad, z, TS, (w,), **kw))(z0)
    ys, stats = jax.jit(
        lambda z: odeint(fbad, z, TS, (w,), **kw, mesh=mesh))(z0)
    status = np.asarray(stats.status)
    assert status[bad] == SolveStatus.NONFINITE_STATE
    for b in range(B):
        if b != bad:
            assert status[b] == SolveStatus.OK
    _assert_tree_equal(tuple(st0), tuple(stats))
    np.testing.assert_allclose(np.asarray(ys), np.asarray(ys0),
                               rtol=1e-6, atol=1e-6)
    assert bool(jnp.isfinite(ys).all())


# ------------------------------------------------------- validation errors

@multi
def test_uneven_batch_raises():
    mesh = shard_mesh()
    z0 = _hetero_batch(b=6)  # 6 % 8 != 0
    with pytest.raises(ValueError, match="does not divide evenly"):
        odeint(_f, z0, TS, (jnp.float32(0.7),), **_kw("aca"), mesh=mesh)


@multi
def test_mesh_requires_batch_axis():
    mesh = shard_mesh()
    kw = _kw("aca")
    kw.pop("batch_axis")
    with pytest.raises(ValueError, match="mesh requires batch_axis"):
        odeint(_f, _hetero_batch()[0], TS, (jnp.float32(0.7),), **kw,
               mesh=mesh)


@multi
def test_mesh_without_data_axis_raises():
    mesh = jax.make_mesh((8,), ("model",))
    with pytest.raises(ValueError, match="no data-parallel axis"):
        odeint(_f, _hetero_batch(), TS, (jnp.float32(0.7),), **_kw("aca"),
               mesh=mesh)


# ------------------------------------------------------ NodeConfig thread

@multi
def test_node_block_mesh_threading():
    mesh = shard_mesh()
    z0 = _hetero_batch()

    def block_fn(params, z, t):
        return _f(t, z, params)

    base = NodeConfig(enabled=True, solver="dopri5", grad_method="aca",
                      rtol=1e-4, atol=1e-4, max_steps=64, batch_axis=0)
    cfg = dataclasses.replace(base, mesh=mesh)
    w = jnp.float32(0.7)
    zT0 = jax.jit(lambda z: node_block_apply(block_fn, w, z, base))(z0)
    zT1 = jax.jit(lambda z: node_block_apply(block_fn, w, z, cfg))(z0)
    _assert_tree_equal(zT0, zT1)


# -------------------------------------------------- elastic mesh shapes

def test_elastic_mesh_shape_pure():
    """Shape derivation at the satellite's device counts {1, 8, 16, 32}
    (model_parallel=1) plus the production TP=16 ladder — pure, so it
    runs at any live device count."""
    assert elastic_mesh_shape(1, 1) == (1, 1, 1)
    assert elastic_mesh_shape(8, 1) == (1, 8, 1)
    assert elastic_mesh_shape(16, 1) == (1, 16, 1)
    assert elastic_mesh_shape(32, 1) == (2, 16, 1)
    assert elastic_mesh_shape(16) == (1, 1, 16)
    assert elastic_mesh_shape(256) == (1, 16, 16)
    assert elastic_mesh_shape(512) == (2, 16, 16)
    assert elastic_mesh_shape(1024) == (4, 16, 16)


def test_elastic_mesh_shape_always_consistent():
    """pods·data·model == n_devices for every divisible count —
    including dp not a multiple of 16 (the old derivation violated
    this: dp=33 gave pods=2, data=16, product 32) — with pods the
    largest divisor of dp not exceeding max(dp // 16, 1)."""
    for mp in (1, 2, 16):
        for dp in range(1, 67):
            n = dp * mp
            pods, data, model = elastic_mesh_shape(n, mp)
            assert pods * data * model == n, (n, mp, pods, data, model)
            assert dp % pods == 0 and pods <= max(dp // 16, 1)


def test_elastic_mesh_shape_raises_readably():
    with pytest.raises(ValueError, match="not a multiple"):
        elastic_mesh_shape(8, 16)
    with pytest.raises(ValueError, match="at least one device"):
        elastic_mesh_shape(0)


_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=32"
import jax
from repro.launch.mesh import make_elastic_mesh

devs = jax.devices()
assert len(devs) == 32
for n, mp in [(1, 1), (8, 2), (16, 4), (32, 8)]:
    mesh = make_elastic_mesh(devices=devs[:n], model_parallel=mp)
    assert mesh.axis_names == ("pod", "data", "model"), mesh
    assert mesh.devices.size == n, (n, mesh)
    assert mesh.shape["model"] == mp, (mp, mesh)
try:
    make_elastic_mesh(devices=devs[:8], model_parallel=16)
    raise SystemExit("expected ValueError")
except ValueError:
    pass
print("ELASTIC_MESH_OK")
"""


def test_make_elastic_mesh_forced_devices():
    """Constructed meshes at {1, 8, 16, 32} forced host devices (a
    subprocess: the device count is locked at jax init)."""
    r = _run_sub([sys.executable, "-c", _MESH_SCRIPT])
    assert "ELASTIC_MESH_OK" in r.stdout, (r.stdout[-2000:],
                                           r.stderr[-4000:])


# ------------------------------------------------------ tier-1 wrapper

def _run_sub(cmd, extra_env=None, timeout=900):
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + \
        os.path.join(root, "tests") + os.pathsep + env.get("PYTHONPATH", "")
    env.update(extra_env or {})
    return subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=timeout)


@pytest.mark.slow
@pytest.mark.skipif(MULTI, reason="already running on >=8 devices")
def test_suite_under_forced_devices():
    """Tier-1 entry point: re-run this file on 8 forced host devices so
    the parity tier executes under the plain pytest invocation too."""
    r = _run_sub(
        [sys.executable, "-m", "pytest", "-q", "-x", os.path.abspath(__file__)],
        extra_env={"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
                   "REPRO_PALLAS_INTERPRET": "1"})
    assert r.returncode == 0, (r.stdout[-4000:], r.stderr[-4000:])
