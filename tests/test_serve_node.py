"""Deterministic serving tier: per-row tolerance QoS + the
continuous-batching NODE engine.

Everything runs on simulated time (``SimClock``) with seeded traffic —
no wall-clock anywhere — so slot-swap order, latencies, and admission
logs are pinned exactly and replay bit-for-bit.

Covers, per ISSUE 10:
  * the (B,) per-row rtol/atol plumbing through the batched adaptive
    engines (bitwise scalar-parity, per-row controller isolation,
    validation errors);
  * the canonical-chunk augmentation (``augment_field``/``augment_state``);
  * queue/clock/request-model unit behaviour;
  * engine serving semantics: solo parity, QoS bitwise isolation,
    failure isolation via fault injection, retry/status policies,
    deadlines, static-vs-continuous scheduling, determinism.

The hypothesis vmap-of-solo property lives in
``test_serve_node_properties.py`` (skipped when hypothesis is absent
so this tier still runs).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from faults import faulty_field
from repro.core import odeint
from repro.core.integrate import SolveStatus
from repro.serve import (
    STATUS_DEADLINE_MISS,
    NodeEngineConfig,
    NodeRequest,
    NodeServeEngine,
    RequestQueue,
    augment_field,
    augment_state,
)
from repro.serve.node_engine import SimClock

DIM = 6
W = jnp.float32(1.3)
ARGS = (W,)


def field(t, z, w):
    return jnp.tanh(w * z) - 0.1 * z * jnp.sin(t)


def _z0(seed, n=1):
    z = np.random.default_rng(seed).normal(size=(n, DIM)).astype(np.float32)
    return z[0] if n == 1 else z


def _parity_bound(res, req, ref):
    """The documented chunked-serving parity bound (docs/serving.md)."""
    return (res.n_chunks + 1) * (
        req.atol + req.rtol * max(1.0, float(np.abs(ref).max())))


# --------------------------------------------------------- shared engines
# Module-scoped so each static configuration compiles its chunk solve
# once; every test takes them through the function-scoped reset wrappers.

@pytest.fixture(scope="module")
def _eng_default():
    return NodeServeEngine(field, DIM, ARGS,
                           NodeEngineConfig(slots=4, chunk_dt=0.5))


@pytest.fixture(scope="module")
def _eng_static():
    return NodeServeEngine(
        field, DIM, ARGS,
        NodeEngineConfig(slots=2, chunk_dt=0.5, static_batch=True))


@pytest.fixture(scope="module")
def _eng_mali():
    return NodeServeEngine(field, DIM, ARGS,
                           NodeEngineConfig(slots=2, grad_method="mali"))


@pytest.fixture
def eng(_eng_default):
    _eng_default.reset()
    return _eng_default


@pytest.fixture
def eng_static(_eng_static):
    _eng_static.reset()
    return _eng_static


@pytest.fixture
def eng_mali(_eng_mali):
    _eng_mali.reset()
    return _eng_mali


# ------------------------------------------------- per-row tolerance core

class TestRowTolerances:
    TS = jnp.asarray([0.0, 0.8], jnp.float32)

    def _batch(self, B=4, seed=0):
        return jnp.asarray(_z0(seed, B))

    def test_rowtol_requires_batch_axis(self):
        with pytest.raises(ValueError, match="per-element"):
            odeint(field, self._batch()[0], self.TS, ARGS,
                   rtol=jnp.full((4,), 1e-4))

    def test_rowtol_rank2_raises(self):
        with pytest.raises(ValueError, match="rank-1"):
            odeint(field, self._batch(), self.TS, ARGS,
                   rtol=jnp.full((4, 1), 1e-4), batch_axis=0)

    def test_rowtol_wrong_length_raises(self):
        with pytest.raises(ValueError, match="one entry per batch row"):
            odeint(field, self._batch(), self.TS, ARGS,
                   rtol=jnp.full((3,), 1e-4), batch_axis=0)

    def test_rowtol_fixed_solver_raises(self):
        with pytest.raises(ValueError, match="adaptive"):
            odeint(field, self._batch(), self.TS, ARGS, solver="rk4",
                   grad_method="naive", rtol=jnp.full((4,), 1e-4),
                   batch_axis=0)

    def test_rowtol_mesh_raises(self):
        from repro.distributed import shard_mesh
        mesh = shard_mesh()
        with pytest.raises(ValueError, match="mesh"):
            odeint(field, self._batch(), self.TS, ARGS,
                   rtol=jnp.full((4,), 1e-4), batch_axis=0, mesh=mesh)

    @pytest.mark.parametrize("use_pallas", [False, True],
                             ids=["pytree", "pallas"])
    @pytest.mark.parametrize("gm", ["aca", "adjoint", "naive", "mali"])
    def test_equal_rowtol_bitwise_matches_scalar(self, gm, use_pallas):
        """(B,) arrays of one tolerance == the scalar solve, bit for bit
        — the scalar fast path and the row-tol kernel compute identical
        f32 arithmetic."""
        z = self._batch()
        kw = dict(grad_method=gm, use_pallas=use_pallas, batch_axis=0)
        ys_s, st_s = odeint(field, z, self.TS, ARGS, rtol=1e-4,
                            atol=1e-6, **kw)
        ys_r, st_r = odeint(field, z, self.TS, ARGS,
                            rtol=jnp.full((4,), 1e-4),
                            atol=jnp.full((4,), 1e-6), **kw)
        assert np.array_equal(np.asarray(ys_s), np.asarray(ys_r))
        assert np.array_equal(np.asarray(st_s.n_trials),
                              np.asarray(st_r.n_trials))

    @pytest.mark.parametrize("use_pallas", [False, True],
                             ids=["pytree", "pallas"])
    def test_mixed_rowtol_rows_match_uniform_batches(self, use_pallas):
        """Row b of a mixed-tolerance batch is bit-identical to row b of
        the all-that-tolerance batch: every row runs its own controller
        and rows never interact (the QoS-isolation primitive)."""
        z = self._batch()
        tols = [1e-3, 1e-4, 1e-5, 1e-6]
        kw = dict(use_pallas=use_pallas, batch_axis=0)
        ys_mix, st_mix = odeint(field, z, self.TS, ARGS,
                                rtol=jnp.asarray(tols),
                                atol=jnp.asarray(tols) * 1e-2, **kw)
        trials = np.asarray(st_mix.n_trials)
        for b, tol in enumerate(tols):
            ys_u, st_u = odeint(field, z, self.TS, ARGS, rtol=tol,
                                atol=tol * 1e-2, **kw)
            assert np.array_equal(np.asarray(ys_mix)[:, b],
                                  np.asarray(ys_u)[:, b]), (b, tol)
            assert trials[b] == np.asarray(st_u.n_trials)[b]
        # per-row controllers really differ: tighter tol, more trials
        assert trials[0] < trials[-1]

    def test_rowtol_grad_finite(self):
        z = self._batch()

        def loss(z0):
            ys, _ = odeint(field, z0, self.TS, ARGS,
                           rtol=jnp.asarray([1e-3, 1e-4, 1e-5, 1e-6]),
                           atol=1e-7, batch_axis=0)
            return jnp.sum(ys[-1] ** 2)

        g = jax.grad(loss)(z)
        assert np.isfinite(np.asarray(g)).all()


# ------------------------------------------------- canonical augmentation

class TestAugmentation:
    def test_augment_state_layout(self):
        z = jnp.arange(3.0)
        zaug = augment_state(z, 2.5, 0.5)
        assert zaug.shape == (5,)
        assert np.allclose(np.asarray(zaug), [0, 1, 2, 2.5, 0.5])

    def test_augment_field_matches_physical_window(self):
        """The canonical solve over s ∈ [0, 1] equals the physical solve
        over [t_off, t_off + delta] (same accuracy class)."""
        z0 = _z0(3)
        t_off, delta = 1.2, 0.7
        zaug = augment_state(jnp.asarray(z0), t_off, delta)
        ys, st = odeint(augment_field(field), zaug,
                        jnp.asarray([0.0, 1.0], jnp.float32), ARGS,
                        rtol=1e-6, atol=1e-8)
        ys_p, _ = odeint(field, jnp.asarray(z0),
                         jnp.asarray([t_off, t_off + delta], jnp.float32),
                         ARGS, rtol=1e-6, atol=1e-8)
        assert int(st.status) == SolveStatus.OK
        np.testing.assert_allclose(np.asarray(ys[-1][:DIM]),
                                   np.asarray(ys_p[-1]), atol=1e-4)

    def test_augment_aux_components_exactly_constant(self):
        zaug = augment_state(jnp.asarray(_z0(4)), 1.2, 0.7)
        ys, _ = odeint(augment_field(field), zaug,
                       jnp.asarray([0.0, 1.0], jnp.float32), ARGS,
                       rtol=1e-4, atol=1e-6)
        out = np.asarray(ys[-1])
        assert out[DIM] == np.float32(1.2)
        assert out[DIM + 1] == np.float32(0.7)

    def test_empty_slot_is_identity(self):
        """delta = 0 zeroes the field: the padding row passes through."""
        zaug = augment_state(jnp.zeros(DIM), 0.0, 0.0)
        ys, st = odeint(augment_field(field), zaug,
                        jnp.asarray([0.0, 1.0], jnp.float32), ARGS,
                        rtol=1e-3, atol=1e-3)
        assert np.array_equal(np.asarray(ys[-1]), np.zeros(DIM + 2))
        assert int(st.status) == SolveStatus.OK


# ------------------------------------------------------ queue/clock/model

class TestQueueAndClock:
    def test_queue_fifo_within_arrival(self):
        q = RequestQueue()
        r = NodeRequest(z0=np.zeros(DIM, np.float32))
        ids = [q.push(1.0, r), q.push(1.0, r), q.push(0.5, r)]
        order = [q.pop_ready(10.0)[1] for _ in range(3)]
        assert order == [ids[2], ids[0], ids[1]]

    def test_queue_pop_ready_respects_arrival(self):
        q = RequestQueue()
        r = NodeRequest(z0=np.zeros(DIM, np.float32))
        q.push(5.0, r)
        assert q.pop_ready(4.9) is None
        assert q.next_arrival() == 5.0
        assert q.pop_ready(5.0) is not None
        assert len(q) == 0

    def test_simclock_round_cost(self):
        c = SimClock(trial_cost=2.0, chunk_overhead=3.0)
        assert c.advance_round(5) == 13.0
        assert c.now == 13.0
        c.jump_to(10.0)          # never rewinds
        assert c.now == 13.0
        c.jump_to(20.0)
        assert c.now == 20.0

    def test_request_validation(self):
        z = np.zeros(DIM, np.float32)
        with pytest.raises(ValueError, match="t1 > t0"):
            NodeRequest(z0=z, t0=1.0, t1=1.0)
        with pytest.raises(ValueError, match="on_failure"):
            NodeRequest(z0=z, on_failure="explode")
        with pytest.raises(ValueError, match="h0"):
            NodeRequest(z0=z, h0=0.0)

    def test_config_validation(self):
        with pytest.raises(ValueError, match="slots"):
            NodeEngineConfig(slots=0)
        with pytest.raises(ValueError, match="chunk_dt"):
            NodeEngineConfig(chunk_dt=0.0)

    def test_submit_shape_check(self, eng):
        with pytest.raises(ValueError, match="shape"):
            eng.submit(NodeRequest(z0=np.zeros(DIM + 1, np.float32)))


# --------------------------------------------------------- engine serving

class TestEngineServing:
    def test_single_request_matches_solo_odeint(self, eng):
        req = NodeRequest(z0=_z0(10), t0=0.0, t1=1.3, rtol=1e-5,
                          atol=1e-7)
        eng.submit(req, arrival=0.0)
        res = eng.run()
        assert len(res) == 1 and res[0].ok
        ys, _ = odeint(field, jnp.asarray(req.z0),
                       jnp.asarray([0.0, 1.3], jnp.float32), ARGS,
                       rtol=1e-5, atol=1e-7)
        ref = np.asarray(ys[-1])
        err = np.abs(res[0].z_final - ref).max()
        assert err <= _parity_bound(res[0], req, ref)

    def test_drain_returns_every_request(self, eng):
        for i in range(7):
            eng.submit(NodeRequest(z0=_z0(i), t1=0.5 + 0.25 * i),
                       arrival=float(i))
        res = eng.run()
        assert [r.req_id for r in res] == list(range(7))
        assert all(r.ok for r in res)
        assert all(r.t_finished >= r.t_admitted >= r.t_arrival
                   for r in res)

    def test_admission_log_pins_slot_swap_order(self, eng):
        """Golden slot-swap trace on a fixed traffic pattern: short
        requests free their slots and the queue backfills them in FIFO
        order at chunk boundaries."""
        horizons = [0.5, 2.0, 0.5, 0.5, 0.5, 0.5]
        for i, h in enumerate(horizons):
            eng.submit(NodeRequest(z0=_z0(i), t1=h), arrival=0.0)
        res = eng.run()
        assert all(r.ok for r in res)
        # 4 slots: 0-3 admitted in round 0; 4 and 5 backfill slots freed
        # by the short requests (slot 0 first — lowest index scanned
        # first), while the long request holds slot 1 throughout.
        assert eng.admission_log[:4] == [(0, 0, 0), (0, 1, 1),
                                         (0, 2, 2), (0, 3, 3)]
        assert eng.admission_log[4:] == [(1, 0, 4), (1, 2, 5)]
        assert len({s for (_, s, rid) in eng.admission_log
                    if rid == 1}) == 1

    def test_qos_bitwise_isolation(self, eng):
        """A request's trajectory is bit-identical whether it shares the
        batch with three tight-tolerance neighbours or runs alone —
        per-row controllers never interact."""
        victim = NodeRequest(z0=_z0(20), t1=1.6, rtol=1e-3, atol=1e-5)
        eng.submit(victim, arrival=0.0)
        solo = eng.run()[0]
        eng.reset()
        eng.submit(victim, arrival=0.0)
        for j in range(3):
            eng.submit(NodeRequest(z0=_z0(21 + j), t1=2.0, rtol=1e-6,
                                   atol=1e-8), arrival=0.0)
        mixed = [r for r in eng.run() if r.req_id == 0][0]
        assert np.array_equal(solo.z_final, mixed.z_final)
        assert solo.n_trials == mixed.n_trials

    def test_deterministic_replay(self, eng):
        def trace(e):
            for i in range(6):
                e.submit(NodeRequest(z0=_z0(30 + i), t1=0.5 + 0.3 * i,
                                     rtol=10.0 ** -(3 + i % 3)),
                         arrival=1.7 * i)
            return e.run()
        a = trace(eng)
        log_a = list(eng.admission_log)
        eng.reset()
        b = trace(eng)
        assert log_a == eng.admission_log
        assert [r.latency for r in a] == [r.latency for r in b]
        assert all(np.array_equal(x.z_final, y.z_final)
                   for x, y in zip(a, b))

    def test_continuous_beats_static_tail_latency(self, eng, eng_static):
        """One long request plus a stream of short ones: the static wave
        scheduler makes the shorts queue behind the straggler."""
        eng2 = NodeServeEngine(
            field, DIM, ARGS,
            NodeEngineConfig(slots=4, chunk_dt=0.5, static_batch=True))
        reqs = [NodeRequest(z0=_z0(40), t1=4.0)] + [
            NodeRequest(z0=_z0(41 + i), t1=0.5) for i in range(7)]
        for e in (eng, eng2):
            for i, r in enumerate(reqs):
                e.submit(r, arrival=0.5 * i)
        lat_c = sorted(r.latency for r in eng.run())
        lat_s = sorted(r.latency for r in eng2.run())
        assert lat_c[-1] < lat_s[-1]
        assert sum(lat_c) < sum(lat_s)

    def test_static_mode_admits_only_full_waves(self, eng_static):
        for i in range(5):
            eng_static.submit(NodeRequest(z0=_z0(50 + i), t1=1.0),
                              arrival=0.0)
        res = eng_static.run()
        assert all(r.ok for r in res)
        rounds = [rd for (rd, _, _) in eng_static.admission_log]
        # 2 slots -> admissions come in pairs sharing a round (the last
        # wave is the leftover single)
        assert rounds[0] == rounds[1]
        assert rounds[2] == rounds[3]
        assert rounds[2] > rounds[1]
        # no admission while any slot is busy: each wave's admission
        # round must see both slots free (logged pairs only)
        occ = eng_static.occupancy_log
        assert max(occ) <= 2

    def test_deadline_expired_in_queue_dropped(self):
        e = NodeServeEngine(field, DIM, ARGS,
                            NodeEngineConfig(slots=1, chunk_dt=0.5))
        e.submit(NodeRequest(z0=_z0(60), t1=3.0, rtol=1e-6), arrival=0.0)
        e.submit(NodeRequest(z0=_z0(61), t1=1.0, deadline=5.0),
                 arrival=0.0)
        res = e.run()
        assert res[0].ok
        assert res[1].status == STATUS_DEADLINE_MISS
        assert not res[1].ok and res[1].deadline_missed
        assert res[1].n_chunks == 0

    def test_deadline_late_completion_flagged(self, eng):
        eng.submit(NodeRequest(z0=_z0(62), t1=2.0, deadline=3.0),
                   arrival=0.0)
        r = eng.run()[0]
        assert r.status == SolveStatus.OK
        assert r.deadline_missed and not r.ok
        assert np.isfinite(r.z_final).all()

    def test_failure_isolated_to_faulty_request(self):
        """A NaN-poisoned request freezes with its own status while its
        batch-mates finish bitwise-identically to a run without it."""
        bad = faulty_field(field, kind="nan", t_ge=10.2)
        cfg = NodeEngineConfig(slots=4, chunk_dt=0.5)
        e1 = NodeServeEngine(bad, DIM, ARGS, cfg)
        # victim integrates over [10, 11] — only it enters the window
        e1.submit(NodeRequest(z0=_z0(70), t0=10.0, t1=11.0), arrival=0.0)
        for j in range(3):
            e1.submit(NodeRequest(z0=_z0(71 + j), t1=1.0), arrival=0.0)
        res = e1.run()
        assert res[0].status == SolveStatus.NONFINITE_STATE
        assert not res[0].ok and np.isfinite(res[0].z_final).all()
        e1.reset()
        for j in range(3):
            e1.submit(NodeRequest(z0=_z0(71 + j), t1=1.0), arrival=0.0)
        clean = e1.run()
        for j in range(3):
            assert np.array_equal(res[1 + j].z_final, clean[j].z_final)
            assert res[1 + j].ok

    def test_on_failure_retry_succeeds_at_loosened_tol(self):
        """An impossibly tight f32 tolerance fails its first pass; the
        retry policy re-enqueues once at retry_tol_factor× looser and
        completes."""
        e = NodeServeEngine(
            field, DIM, ARGS,
            NodeEngineConfig(slots=2, retry_tol_factor=1e6))
        e.submit(NodeRequest(z0=_z0(80), t1=1.0, rtol=1e-12, atol=1e-14,
                             on_failure="retry"), arrival=0.0)
        r = e.run()[0]
        assert r.ok and r.retried
        assert r.status == SolveStatus.OK

    def test_on_failure_retry_gives_up_after_one_retry(self):
        bad = faulty_field(field, kind="nan", t_ge=0.0)
        e = NodeServeEngine(bad, DIM, ARGS, NodeEngineConfig(slots=2))
        e.submit(NodeRequest(z0=_z0(81), t1=1.0, on_failure="retry"),
                 arrival=0.0)
        r = e.run()[0]
        assert r.retried and not r.ok
        assert r.status == SolveStatus.NONFINITE_STATE

    def test_all_requests_failing_still_drains(self):
        bad = faulty_field(field, kind="nan", t_ge=0.0)
        e = NodeServeEngine(bad, DIM, ARGS, NodeEngineConfig(slots=2))
        for i in range(4):
            e.submit(NodeRequest(z0=_z0(82 + i), t1=1.0), arrival=0.0)
        res = e.run()
        assert len(res) == 4
        assert all(not r.ok for r in res)
        assert all(np.isfinite(r.z_final).all() for r in res)

    def test_empty_engine_run_is_empty(self, eng):
        assert eng.run() == []

    def test_request_h0_changes_first_step(self, eng):
        base = NodeRequest(z0=_z0(90), t1=0.5, rtol=1e-4)
        eng.submit(base, arrival=0.0)
        r_auto = eng.run()[0]
        eng.reset()
        eng.submit(NodeRequest(z0=_z0(90), t1=0.5, rtol=1e-4, h0=1e-4),
                   arrival=0.0)
        r_tiny = eng.run()[0]
        assert r_auto.ok and r_tiny.ok
        # a deliberately tiny first step costs extra trials
        assert r_tiny.n_trials > r_auto.n_trials

    def test_mali_engine_serves(self, eng_mali):
        req = NodeRequest(z0=_z0(91), t1=1.0, rtol=1e-4)
        eng_mali.submit(req, arrival=0.0)
        r = eng_mali.run()[0]
        assert r.ok
        ys, _ = odeint(field, jnp.asarray(req.z0),
                       jnp.asarray([0.0, 1.0], jnp.float32), ARGS,
                       grad_method="mali", rtol=1e-4, atol=1e-6)
        ref = np.asarray(ys[-1])
        assert np.abs(r.z_final - ref).max() <= _parity_bound(r, req, ref)

    def test_pallas_engine_serves(self):
        e = NodeServeEngine(field, DIM, ARGS,
                            NodeEngineConfig(slots=2, use_pallas=True))
        e.submit(NodeRequest(z0=_z0(92), t1=1.0), arrival=0.0)
        r = e.run()[0]
        assert r.ok and np.isfinite(r.z_final).all()


# ------------------------------------- ServeEngine key-default determinism

class TestServeEngineKeyDeterminism:
    @pytest.fixture(scope="class")
    def lm(self):
        from repro.models import ModelConfig, RunConfig, build_model
        cfg = ModelConfig(name="t", family="dense", n_layers=2,
                          d_model=64, vocab=128, n_heads=4, n_kv_heads=2,
                          d_ff=128)
        m = build_model(cfg,
                        RunConfig(compute_dtype=jnp.float32, max_seq=32))
        return m, m.init(jax.random.PRNGKey(0))

    def _engine(self, lm, temperature):
        from repro.serve import ServeConfig, ServeEngine
        m, params = lm
        return ServeEngine(m, params,
                           ServeConfig(max_new_tokens=4,
                                       temperature=temperature),
                           jit=False)

    def test_keyless_temperature_sampling_reproducible(self, lm,
                                                       monkeypatch):
        """key=None is an explicit fixed PRNGKey(0): two keyless calls
        sample identical tokens, and the fallback warns once."""
        import warnings

        from repro.serve import engine as serve_engine_mod
        monkeypatch.setattr(serve_engine_mod, "_warned_default_key",
                            False)
        eng = self._engine(lm, temperature=0.8)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 128,
                                  jnp.int32)
        with pytest.warns(UserWarning, match="PRNGKey\\(0\\)"):
            a = eng.generate(toks)["tokens"]
        with warnings.catch_warnings():
            warnings.simplefilter("error")  # warns only once per process
            b = eng.generate(toks)["tokens"]
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_explicit_keys_vary_and_reproduce(self, lm):
        eng = self._engine(lm, temperature=0.8)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 128,
                                  jnp.int32)
        a1 = eng.generate(toks, key=jax.random.PRNGKey(7))["tokens"]
        a2 = eng.generate(toks, key=jax.random.PRNGKey(7))["tokens"]
        b = eng.generate(toks, key=jax.random.PRNGKey(8))["tokens"]
        np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
        assert not np.array_equal(np.asarray(a1), np.asarray(b))

    def test_greedy_keyless_does_not_warn(self, lm, monkeypatch):
        import warnings

        from repro.serve import engine as serve_engine_mod
        monkeypatch.setattr(serve_engine_mod, "_warned_default_key",
                            False)
        eng = self._engine(lm, temperature=0.0)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 128,
                                  jnp.int32)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            eng.generate(toks)
