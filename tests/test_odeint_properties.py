"""Hypothesis property tests on the solver/gradient invariants.

Skipped (not errored) when ``hypothesis`` is absent from the image —
these are extra coverage on top of the deterministic suites.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import odeint
from repro.core.controller import ControllerConfig, propose_stepsize
from repro.core.stepper import error_ratio

SET = dict(max_examples=20, deadline=None)


@settings(**SET)
@given(k=st.floats(-2.0, 2.0), z0=st.floats(-3.0, 3.0, exclude_min=False),
       t1=st.floats(0.1, 2.0))
def test_linear_ode_solution_accuracy(k, z0, t1):
    """dz/dt = k z: the numerical solution tracks z0·e^{kt} at the
    requested tolerance for any (k, z0, T) in range."""
    ys, stats = odeint(lambda t, z, kk: kk * z, jnp.float32(z0),
                       jnp.array([0.0, t1]), (jnp.float32(k),),
                       solver="dopri5", grad_method="aca",
                       rtol=1e-6, atol=1e-6)
    exact = z0 * np.exp(k * t1)
    assert not bool(stats.overflow)
    assert abs(float(ys[-1]) - exact) < 1e-3 * max(1.0, abs(exact))


@settings(**SET)
@given(k=st.floats(-1.5, 1.5), z0=st.floats(0.1, 2.0))
def test_gradient_matches_analytic_property(k, z0):
    """dL/dz0 for L = z(1)² equals 2 z0 e^{2k} for any k (Eq. 29)."""
    def loss(z):
        ys, _ = odeint(lambda t, zz, kk: kk * zz, z,
                       jnp.array([0.0, 1.0]), (jnp.float32(k),),
                       solver="dopri5", grad_method="aca",
                       rtol=1e-7, atol=1e-7)
        return (ys[-1] ** 2).sum()

    g = float(jax.grad(loss)(jnp.float32(z0)))
    analytic = 2 * z0 * np.exp(2 * k)
    assert abs(g - analytic) <= 2e-3 * max(1.0, abs(analytic))


@settings(**SET)
@given(h=st.floats(1e-4, 1.0), ratio=st.floats(1e-6, 100.0),
       prev=st.floats(1e-6, 100.0), order=st.integers(1, 5))
def test_controller_bounds(h, ratio, prev, order):
    """Proposed stepsizes stay within [min_factor, max_factor]·h and
    shrink when the error ratio exceeds 1."""
    cfg = ControllerConfig()
    h2 = float(propose_stepsize(cfg, jnp.float32(h), jnp.float32(ratio),
                                jnp.float32(prev), order))
    lo = cfg.min_factor * h * (1 - 1e-5)
    hi = cfg.max_factor * h * (1 + 1e-5)
    assert lo <= h2 <= hi, (h, ratio, prev, order, h2)
    if ratio > 3.0 and prev <= 1.0:       # PI term cannot fight the shrink
        assert h2 < h


@settings(**SET)
@given(scale=st.floats(0.01, 10.0))
def test_error_ratio_scale_invariance(scale):
    """error_ratio(s·e, s·z, s·z) with atol=0 is scale-invariant."""
    e = jnp.array([0.1, -0.2, 0.05])
    z = jnp.array([1.0, 2.0, -1.5])
    r1 = float(error_ratio(e, z, z, rtol=1e-3, atol=0.0))
    r2 = float(error_ratio(scale * e, scale * z, scale * z,
                           rtol=1e-3, atol=0.0))
    assert abs(r1 - r2) < 1e-3 * max(r1, 1.0)


@settings(**SET)
@given(seed=st.integers(0, 10_000))
def test_aca_checkpoint_replay_exactness(seed):
    """ACA's backward replays the forward trajectory exactly: for a
    LINEAR ODE, dz(T)/dz0 from ACA equals the product of per-step
    transition factors of the very same discrete trajectory — checked
    against naive AD (same discretization) at fp precision."""
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (3, 3)) * 0.5

    def f(t, z, w):
        return w @ z

    z0 = jnp.ones((3,))

    def out(z0, method):
        ys, _ = odeint(f, z0, jnp.array([0.0, 1.0]), (w,), solver="rk4",
                       grad_method=method, steps_per_interval=8)
        return jnp.sum(ys[-1] * jnp.arange(3.0))

    g_aca = jax.grad(lambda z: out(z, "aca"))(z0)
    g_naive = jax.grad(lambda z: out(z, "naive"))(z0)
    np.testing.assert_allclose(np.asarray(g_aca), np.asarray(g_naive),
                               rtol=5e-5, atol=5e-6)


@settings(**SET)
@given(n=st.integers(2, 6))
def test_outputs_at_all_eval_times(n):
    """ys[k] lands on z(ts[k]) for every requested time."""
    ts = jnp.linspace(0.0, 1.0, n)
    ys, stats = odeint(lambda t, z: -0.7 * z, jnp.float32(2.0), ts,
                       solver="dopri5", grad_method="aca",
                       rtol=1e-7, atol=1e-7)
    exact = 2.0 * np.exp(-0.7 * np.asarray(ts))
    assert not bool(stats.overflow)
    np.testing.assert_allclose(np.asarray(ys), exact, rtol=1e-4,
                               atol=1e-5)
