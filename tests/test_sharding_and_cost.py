"""Sharding rules, spec fitting, HLO cost model, data pipelines, serve."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (AxisRules, DEFAULT_TRAIN_RULES,
                                        fit_spec_to_shape, logical_to_spec)
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.roofline import (active_params, collective_bytes_from_hlo,
                                   model_flops)
from repro.configs import get_config
from repro.data import TokenPipeline, irregular_series_batch
from repro.data.threebody import simulate_three_body, three_body_rhs


# ------------------------------------------------------------- rules/specs
def test_logical_to_spec_basic():
    s = logical_to_spec(("batch", "seq", "embed_act"), DEFAULT_TRAIN_RULES)
    assert s == P(("pod", "data"), None, None)
    s = logical_to_spec(("embed", "mlp"), DEFAULT_TRAIN_RULES)
    assert s == P("data", "model")


def test_rules_override():
    r = DEFAULT_TRAIN_RULES.override(mlp=None)
    assert logical_to_spec(("mlp",), r) == P(None)
    # original unchanged
    assert logical_to_spec(("mlp",), DEFAULT_TRAIN_RULES) == P("model")


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_fit_spec_to_shape():
    mesh = _FakeMesh({"data": 16, "model": 16})
    # divisible: unchanged
    assert fit_spec_to_shape((152064, 5120), P("model", "data"), mesh) \
        == P("model", "data")
    # vocab not divisible -> replicated on that dim
    assert fit_spec_to_shape((50280, 2560), P("model", "data"), mesh) \
        == P(None, "data")
    # batch=1 over (pod,data) -> fully dropped
    mesh2 = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert fit_spec_to_shape((1, 32), P(("pod", "data"), None), mesh2) \
        == P(None, None)
    # partial: 32 over (pod=2, data=16) fits
    assert fit_spec_to_shape((32, 8), P(("pod", "data"), None), mesh2) \
        == P(("pod", "data"), None)
    # 2 over (pod=2, data=16): keeps pod only
    assert fit_spec_to_shape((2, 8), P(("pod", "data"), None), mesh2) \
        == P("pod", None)


# ---------------------------------------------------------- hlo cost model
def test_hlo_cost_matmul_exact():
    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    hlo = jax.jit(lambda x, y: x @ y).lower(a, a).compile().as_text()
    r = analyze_hlo(hlo)
    assert abs(r.flops - 2 * 512 ** 3) / (2 * 512 ** 3) < 0.05


def test_hlo_cost_scan_trip_scaling():
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((12, 256, 256), jnp.float32)

    def body(x, w):
        return jnp.tanh(x @ w), None

    f = jax.jit(lambda x, w: jax.lax.scan(body, x, w)[0])
    r = analyze_hlo(f.lower(a, ws).compile().as_text())
    want = 12 * 2 * 256 ** 3
    assert abs(r.flops - want) / want < 0.05
    assert r.dynamic_whiles == 0


def test_hlo_cost_grad_of_scan():
    a = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((6, 128, 128), jnp.float32)

    def body(x, w):
        return jnp.tanh(x @ w), None

    def loss(x, w):
        y, _ = jax.lax.scan(body, x, w)
        return (y ** 2).sum()

    f = jax.jit(jax.grad(loss, argnums=1))
    r = analyze_hlo(f.lower(a, ws).compile().as_text())
    want = 3 * 6 * 2 * 128 ** 3      # fwd + 2 bwd matmuls per layer
    assert abs(r.flops - want) / want < 0.1


def test_hlo_cost_dynamic_while_flagged():
    def cond(c):
        return c[0] < c[1]

    def bod(c):
        return (c[0] + 1, c[1], jnp.tanh(c[2] @ c[2]))

    f = jax.jit(lambda n, x: jax.lax.while_loop(cond, bod, (0, n, x))[2])
    hlo = f.lower(jax.ShapeDtypeStruct((), jnp.int32),
                  jax.ShapeDtypeStruct((64, 64), jnp.float32)) \
        .compile().as_text()
    r = analyze_hlo(hlo)
    assert r.dynamic_whiles >= 1


# ----------------------------------------------------------- roofline math
def test_active_params_moe_much_smaller_than_total():
    from repro.models import RunConfig, build_model
    cfg = get_config("qwen3_moe_235b_a22b")
    total = build_model(cfg, RunConfig()).n_params()
    act = active_params(cfg)
    assert act < total / 8           # 22B active vs 235B total
    assert 15e9 < act < 30e9, act


def test_model_flops_conventions():
    cfg = get_config("musicgen_medium")
    n = active_params(cfg)
    assert model_flops(cfg, "train", 4096, 256) == 6.0 * n * 4096 * 256
    assert model_flops(cfg, "prefill", 32768, 32) == 2.0 * n * 32768 * 32
    assert model_flops(cfg, "decode", 32768, 128) == 2.0 * n * 128


def test_collective_parse_smoke():
    txt = """
ENTRY %main () -> f32[8] {
  %ar = f32[1024,16]{1,0} all-reduce(f32[1024,16]{1,0} %x), replica_groups={}
  %ag = bf16[2048]{0} all-gather(bf16[128]{0} %y), dimensions={0}
}
"""
    total, by_kind = collective_bytes_from_hlo(txt)
    assert by_kind["all-reduce"] == 2 * 1024 * 16 * 4
    assert by_kind["all-gather"] == 2048 * 2


# ------------------------------------------------------------------- data
def test_token_pipeline_deterministic_and_sharded():
    p = TokenPipeline(vocab=1000, seq_len=8, global_batch=16, seed=3)
    b1 = p.batch(7)
    b2 = p.batch(7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(p.batch(8)["tokens"]),
                              np.asarray(b1["tokens"]))
    # host slices partition the global batch
    h0 = p.batch(7, host_slice=(0, 4))["tokens"]
    h1 = p.batch(7, host_slice=(1, 4))["tokens"]
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:4]),
                                  np.asarray(h0))
    np.testing.assert_array_equal(np.asarray(b1["tokens"][4:8]),
                                  np.asarray(h1))
    assert int(b1["labels"][0, 0]) == int(b1["tokens"][0, 1])


def test_irregular_series_shapes():
    b = irregular_series_batch(batch=3, n_obs=12, obs_dim=5, seed=1)
    assert b["ts"].shape == (3, 12) and b["ys"].shape == (3, 12, 5)
    assert bool((jnp.diff(b["ts"], axis=1) >= 0).all())


def test_three_body_energy_conservation():
    ts, rs, vs, m = simulate_three_body(n_points=60, t_max=0.5,
                                        rtol=1e-9, atol=1e-9)

    def energy(r, v):
        ke = 0.5 * jnp.sum(m[:, None] * v ** 2)
        diff = r[:, None, :] - r[None, :, :]
        dist = jnp.sqrt((diff ** 2).sum(-1) + jnp.eye(3))
        pe = -0.5 * jnp.sum(
            (m[:, None] * m[None, :]) * (1 - jnp.eye(3)) / dist)
        return ke + pe

    e0 = float(energy(rs[0], vs[0]))
    eT = float(energy(rs[-1], vs[-1]))
    assert abs(eT - e0) < 1e-3 * abs(e0), (e0, eT)


# ------------------------------------------------------------------ serve
def test_serve_engine_greedy_matches_manual_decode():
    from repro.models import ModelConfig, RunConfig, build_model
    from repro.serve import ServeConfig, ServeEngine

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      vocab=128, n_heads=4, n_kv_heads=2, d_ff=128)
    m = build_model(cfg, RunConfig(compute_dtype=jnp.float32, max_seq=32))
    params = m.init(jax.random.PRNGKey(0))
    eng = ServeEngine(m, params, ServeConfig(max_new_tokens=4), jit=False)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 128,
                              jnp.int32)
    out = eng.generate(toks)["tokens"]
    assert out.shape == (2, 12)
    # greedy decode must equal argmax over the full forward at each step
    full, _, _ = m.forward(params, {"tokens": out[:, :-1]}, mode="train")
    for j in range(4):
        want = jnp.argmax(full[:, 8 + j - 1], axis=-1)
        np.testing.assert_array_equal(np.asarray(out[:, 8 + j]),
                                      np.asarray(want))
