"""Per-architecture smoke tests: reduced same-family config, one forward
and one train step on CPU, asserting output shapes and no NaNs.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct,
no allocation) — see repro.launch.dryrun.
"""

import jax
import jax.numpy as jnp
import pytest

from conftest import tiny_batch
from repro.configs import ARCHS, get_config, get_smoke_config, shape_plan
from repro.models import RunConfig, build_model
from repro.optim import adamw, constant
from repro.train.loop import TrainLoopConfig, build_train_step
from repro.train.state import make_train_state
from repro.optim.grad_utils import CompressionState


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    m = build_model(cfg, RunConfig(compute_dtype=jnp.float32))
    batch = tiny_batch(cfg, B=2, S=16)

    logits, _, aux = m.forward(m.init(jax.random.PRNGKey(0)), batch,
                               mode="train")
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), arch

    opt = adamw(constant(1e-3))
    step = build_train_step(m, opt, TrainLoopConfig())
    state = make_train_state(m, opt, jax.random.PRNGKey(1))
    state2, _, metrics = step(state, batch, CompressionState(error=()))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(state2.step) == 1
    # parameters actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(state2.params)))
    assert moved, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_dims_match_assignment(arch):
    """The registry exposes the exact published dims."""
    cfg = get_config(arch)
    expected = {
        "qwen1_5_32b": (64, 5120, 40, 40, 27392, 152064),
        "qwen2_72b": (80, 8192, 64, 8, 29568, 152064),
        "command_r_plus_104b": (64, 12288, 96, 8, 33792, 256000),
        "command_r_35b": (40, 8192, 64, 8, 22528, 256000),
        "deepseek_moe_16b": (28, 2048, 16, 16, 1408, 102400),
        "qwen3_moe_235b_a22b": (94, 4096, 64, 4, 1536, 151936),
        "llava_next_34b": (60, 7168, 56, 8, 20480, 64000),
        "musicgen_medium": (48, 1536, 24, 24, 6144, 2048),
        "recurrentgemma_9b": (38, 4096, 16, 1, 12288, 256000),
        "mamba2_2_7b": (64, 2560, 0, 0, 0, 50280),
        "node18_cifar": (18, 768, 12, 12, 3072, 32768),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected, (arch, got, expected)


def test_moe_configs():
    c = get_config("deepseek_moe_16b")
    assert (c.n_experts, c.n_shared_experts, c.top_k) == (64, 2, 6)
    c = get_config("qwen3_moe_235b_a22b")
    assert (c.n_experts, c.n_shared_experts, c.top_k) == (128, 0, 8)
    assert c.resolved_head_dim == 128


def test_mamba_dims():
    c = get_config("mamba2_2_7b")
    assert c.ssm_state == 128 and c.d_inner == 5120 and c.ssm_heads == 80


def test_shape_plan_skips():
    # full-attention archs skip long_500k; ssm/hybrid run it
    assert shape_plan("qwen2_72b", "long_500k") is None
    assert shape_plan("command_r_plus_104b", "long_500k") is None
    assert shape_plan("mamba2_2_7b", "long_500k") == (524288, 1, "decode")
    assert shape_plan("recurrentgemma_9b", "long_500k") is not None
    assert shape_plan("qwen2_72b", "train_4k") == (4096, 256, "train")
    assert shape_plan("qwen2_72b", "decode_32k")[2] == "decode"


def test_recurrentgemma_stack_plan():
    from repro.models.transformer import stack_plan
    cfg = get_config("recurrentgemma_9b")
    unit, groups, tail = stack_plan(cfg)
    assert unit == ("rec", "rec", "attn")
    assert groups == 12 and tail == ["rec", "rec"]
    assert groups * len(unit) + len(tail) == cfg.n_layers


@pytest.mark.parametrize("arch", ["qwen2_72b", "deepseek_moe_16b",
                                  "mamba2_2_7b"])
def test_param_count_sane(arch):
    """Full-config parameter count is within 20% of the advertised size
    (embedding tables and norm params account for the slack)."""
    import re
    cfg = get_config(arch)
    m = build_model(cfg, RunConfig())
    n = m.n_params()
    advertised = {"qwen2_72b": 72e9, "deepseek_moe_16b": 16e9,
                  "mamba2_2_7b": 2.7e9}[arch]
    assert 0.75 * advertised < n < 1.35 * advertised, (arch, n)
