"""Solver tableau validation + empirical convergence order.

A solver of order p must show error ~ C·h^p on a smooth ODE: halving h
divides the error by ~2^p.  This pins every tableau to its advertised
order — a transcription error in any coefficient fails these tests.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fixed_grid_solve, get_tableau
from repro.core.tableaus import (ADAPTIVE_SOLVERS, FIXED_SOLVERS,
                                 _REGISTRY)


@pytest.mark.parametrize("name", sorted(_REGISTRY))
def test_tableau_consistency(name):
    get_tableau(name).validate()


def _solve_err(tab, steps):
    """Error of z' = z·cos(t), z(0)=1 (exact: exp(sin t)) at T=2."""
    def f(t, z):
        return z * jnp.cos(t)

    ts = jnp.array([0.0, 2.0])
    ys, _ = fixed_grid_solve(tab, f, jnp.float64(1.0)
                             if jax.config.jax_enable_x64
                             else jnp.float32(1.0),
                             ts, (), steps)
    exact = float(np.exp(np.sin(2.0)))
    return abs(float(ys[-1]) - exact)


@pytest.mark.parametrize("name,order", [
    ("euler", 1), ("midpoint", 2), ("rk2", 2), ("rk4", 4),
    ("heun_euler", 2), ("bosh3", 3), ("dopri5", 5),
])
def test_convergence_order(name, order):
    tab = get_tableau(name)
    # pick step counts where error is well above fp32 noise
    n0 = {1: 64, 2: 16, 3: 8, 4: 4, 5: 2}[order]
    e1 = _solve_err(tab, n0)
    e2 = _solve_err(tab, 2 * n0)
    rate = np.log2(max(e1, 1e-12) / max(e2, 1e-12))
    # allow generous slack (fp32, low-order error terms)
    assert rate > order - 0.7, (name, rate, order, e1, e2)


@pytest.mark.parametrize("name", ADAPTIVE_SOLVERS)
def test_embedded_error_nonzero(name):
    tab = get_tableau(name)
    assert tab.adaptive
    assert any(abs(x) > 0 for x in tab.b_err)


def test_fsal_flags():
    assert get_tableau("dopri5").fsal
    assert get_tableau("bosh3").fsal
    assert not get_tableau("heun_euler").fsal


def test_registry_aliases():
    assert get_tableau("rk45") is get_tableau("dopri5")
    assert get_tableau("rk23") is get_tableau("bosh3")
    with pytest.raises(KeyError):
        get_tableau("nope")
