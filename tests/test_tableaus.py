"""Solver tableau validation + empirical convergence order.

A solver of order p must show error ~ C·h^p on a smooth ODE: halving h
divides the error by ~2^p.  This pins every tableau to its advertised
order — a transcription error in any coefficient fails these tests.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fixed_grid_solve, get_tableau
from repro.core.tableaus import (ADAPTIVE_SOLVERS, FIXED_SOLVERS,
                                 _REGISTRY)


@pytest.mark.parametrize("name", sorted(_REGISTRY))
def test_tableau_consistency(name):
    get_tableau(name).validate()


def _solve_err(tab, steps):
    """Error of z' = z·cos(t), z(0)=1 (exact: exp(sin t)) at T=2."""
    def f(t, z):
        return z * jnp.cos(t)

    ts = jnp.array([0.0, 2.0])
    ys, _ = fixed_grid_solve(tab, f, jnp.float64(1.0)
                             if jax.config.jax_enable_x64
                             else jnp.float32(1.0),
                             ts, (), steps)
    exact = float(np.exp(np.sin(2.0)))
    return abs(float(ys[-1]) - exact)


@pytest.mark.parametrize("name,order", [
    ("euler", 1), ("midpoint", 2), ("rk2", 2), ("rk4", 4),
    ("heun_euler", 2), ("bosh3", 3), ("dopri5", 5),
])
def test_convergence_order(name, order):
    tab = get_tableau(name)
    # pick step counts where error is well above fp32 noise
    n0 = {1: 64, 2: 16, 3: 8, 4: 4, 5: 2}[order]
    e1 = _solve_err(tab, n0)
    e2 = _solve_err(tab, 2 * n0)
    rate = np.log2(max(e1, 1e-12) / max(e2, 1e-12))
    # allow generous slack (fp32, low-order error terms)
    assert rate > order - 0.7, (name, rate, order, e1, e2)


@pytest.mark.parametrize("name", ADAPTIVE_SOLVERS)
def test_embedded_error_nonzero(name):
    tab = get_tableau(name)
    assert tab.adaptive
    assert any(abs(x) > 0 for x in tab.b_err)


def test_fsal_flags():
    assert get_tableau("dopri5").fsal
    assert get_tableau("bosh3").fsal
    assert not get_tableau("heun_euler").fsal


def test_registry_aliases():
    assert get_tableau("rk45") is get_tableau("dopri5")
    assert get_tableau("rk23") is get_tableau("bosh3")
    assert get_tableau("bogacki_shampine") is get_tableau("bosh3")
    assert get_tableau("heuneuler") is get_tableau("heun_euler")
    with pytest.raises(KeyError):
        get_tableau("nope")


def test_solver_groups_cover_registry():
    """FIXED_SOLVERS/ADAPTIVE_SOLVERS are derived from the registry —
    aliases included — so they cannot drift from what get_tableau
    accepts."""
    assert set(FIXED_SOLVERS) | set(ADAPTIVE_SOLVERS) == set(_REGISTRY)
    assert {"rk45", "rk23", "heuneuler", "bogacki_shampine"} <= set(
        ADAPTIVE_SOLVERS)
    assert all(not _REGISTRY[n].adaptive for n in FIXED_SOLVERS)
    assert all(_REGISTRY[n].adaptive for n in ADAPTIVE_SOLVERS)


def test_unknown_solver_error_enumerates_accepted_names():
    """The error message is built from the derived groups: every
    accepted name — aliases like rk45/heuneuler included — appears."""
    with pytest.raises(KeyError) as ei:
        get_tableau("does_not_exist")
    msg = str(ei.value)
    for name in FIXED_SOLVERS + ADAPTIVE_SOLVERS:
        assert name in msg, f"{name} missing from: {msg}"
