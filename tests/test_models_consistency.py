"""prefill → decode must reproduce the full-forward logits (KV caches,
ring buffers, SSM/conv states) and NODE mode must train for every
grad method."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_batch
from repro.core import NodeConfig
from repro.models import ModelConfig, RunConfig, build_model

CONFIGS = {
    "dense-gqa": ModelConfig(
        name="t", family="dense", n_layers=3, d_model=64, vocab=128,
        n_heads=4, n_kv_heads=2, d_ff=128, qkv_bias=True),
    "dense-parallel-tied": ModelConfig(
        name="t", family="dense", n_layers=2, d_model=64, vocab=128,
        n_heads=4, n_kv_heads=2, d_ff=128, parallel_block=True,
        tie_embeddings=True, norm="layernorm"),
    "hybrid-window": ModelConfig(
        name="t", family="hybrid", n_layers=8, d_model=64, vocab=128,
        n_heads=4, n_kv_heads=1, d_ff=128, window=8,
        pattern=("rec", "rec", "attn"), d_rnn=64),
    "ssm": ModelConfig(
        name="t", family="ssm", n_layers=3, d_model=64, vocab=128,
        ssm_state=16, ssm_head_dim=16, ssm_chunk=8),
}


@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_prefill_decode_matches_forward(name):
    cfg = CONFIGS[name]
    S, NEW = 16, 3
    m = build_model(cfg, RunConfig(compute_dtype=jnp.float32,
                                   max_seq=S + NEW + 4))
    params = m.init(jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(0), (2, S + NEW), 0,
                              cfg.vocab, jnp.int32)
    full, _, _ = m.forward(params, {"tokens": toks}, mode="train")

    last, caches = m.prefill(params, {"tokens": toks[:, :S]})
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(full[:, S - 1]),
                               rtol=1e-4, atol=1e-4)
    for j in range(NEW):
        lg, caches = m.decode_step(
            params, {"tokens": toks[:, S + j:S + j + 1]}, caches,
            jnp.asarray(S + j, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full[:, S + j]),
                                   rtol=1e-4, atol=1e-4, err_msg=name)


def test_windowed_decode_beyond_window():
    """Ring-buffer decode stays consistent once the cache wraps."""
    cfg = CONFIGS["hybrid-window"]          # window = 8
    S, NEW = 12, 6                          # decode positions 12..17 wrap
    m = build_model(cfg, RunConfig(compute_dtype=jnp.float32, max_seq=32))
    params = m.init(jax.random.PRNGKey(2))
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, S + NEW), 0,
                              cfg.vocab, jnp.int32)
    full, _, _ = m.forward(params, {"tokens": toks}, mode="train")
    _, caches = m.prefill(params, {"tokens": toks[:, :S]})
    for j in range(NEW):
        lg, caches = m.decode_step(
            params, {"tokens": toks[:, S + j:S + j + 1]}, caches,
            jnp.asarray(S + j, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full[:, S + j]),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("regime,gm", [("fixed", "aca"),
                                       ("adaptive", "aca"),
                                       ("fixed", "adjoint"),
                                       ("fixed", "naive")])
def test_node_mode_trains(regime, gm):
    cfg = CONFIGS["dense-gqa"]
    node = NodeConfig(enabled=True, regime=regime, grad_method=gm,
                      steps_per_interval=2, max_steps=16)
    m = build_model(cfg, RunConfig(compute_dtype=jnp.float32, node=node))
    params = m.init(jax.random.PRNGKey(1))
    batch = tiny_batch(cfg)
    (loss, _), grads = jax.value_and_grad(m.loss_fn, has_aux=True)(
        params, batch)
    assert bool(jnp.isfinite(loss))
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))


def test_node_mode_run_config_use_pallas_reaches_solver():
    """RunConfig.use_pallas must flow into every NODE block's odeint:
    the fused flat-state path (interpret mode here) reproduces the
    pytree path's loss exactly and its gradients to fp tolerance."""
    from repro.kernels import ops

    ops.set_interpret(True)
    try:
        cfg = CONFIGS["dense-gqa"]
        node = NodeConfig(enabled=True, regime="adaptive",
                          grad_method="aca", max_steps=16)
        batch = tiny_batch(cfg)
        out = {}
        for up in (False, True):
            m = build_model(cfg, RunConfig(compute_dtype=jnp.float32,
                                           node=node, use_pallas=up))
            params = m.init(jax.random.PRNGKey(1))
            (loss, _), grads = jax.value_and_grad(
                m.loss_fn, has_aux=True)(params, batch)
            out[up] = (float(loss), grads)
        assert out[False][0] == out[True][0]
        for a, b in zip(jax.tree.leaves(out[False][1]),
                        jax.tree.leaves(out[True][1])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
    finally:
        ops.set_interpret(None)


def test_node_mode_param_count_unchanged():
    """Eq. 30→31: the NODE transform preserves the parameter count."""
    cfg = CONFIGS["dense-gqa"]
    m_disc = build_model(cfg, RunConfig())
    m_node = build_model(cfg, RunConfig(
        node=NodeConfig(enabled=True, regime="fixed")))
    assert m_disc.n_params() == m_node.n_params()


def test_node_fixed_aca_equals_naive_gradient():
    """Fixed-grid NODE: ACA and naive differentiate the same discrete
    solution -> near-identical model gradients."""
    cfg = CONFIGS["dense-gqa"]
    batch = tiny_batch(cfg)
    grads = {}
    for gm in ("aca", "naive"):
        node = NodeConfig(enabled=True, regime="fixed", grad_method=gm,
                          steps_per_interval=2)
        m = build_model(cfg, RunConfig(compute_dtype=jnp.float32,
                                       node=node))
        params = m.init(jax.random.PRNGKey(1))
        _, g = jax.value_and_grad(m.loss_fn, has_aux=True)(params, batch)
        grads[gm] = g
    for a, b in zip(jax.tree.leaves(grads["aca"]),
                    jax.tree.leaves(grads["naive"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-5)


def test_scan_vs_unrolled_stack_identical():
    cfg = CONFIGS["dense-gqa"]
    batch = tiny_batch(cfg)
    m1 = build_model(cfg, RunConfig(compute_dtype=jnp.float32,
                                    scan_layers=True))
    m2 = build_model(cfg, RunConfig(compute_dtype=jnp.float32,
                                    scan_layers=False))
    params = m1.init(jax.random.PRNGKey(1))
    l1, _ = m1.loss_fn(params, batch)
    l2, _ = m2.loss_fn(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_remat_matches_no_remat():
    cfg = CONFIGS["dense-gqa"]
    batch = tiny_batch(cfg)
    m1 = build_model(cfg, RunConfig(compute_dtype=jnp.float32))
    m2 = build_model(cfg, RunConfig(compute_dtype=jnp.float32,
                                    remat="block"))
    params = m1.init(jax.random.PRNGKey(1))
    g1 = jax.grad(lambda p: m1.loss_fn(p, batch)[0])(params)
    g2 = jax.grad(lambda p: m2.loss_fn(p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
