"""Solve-health subsystem: status codes, non-finite guards, freeze
semantics, cotangent masking, policies, fallback ladder, and the
training/serving-layer guards that compose with them.

Fault injection comes from ``tests/faults.py``; the default
(``on_failure="status"``, no faults) path is asserted bitwise-identical
with the guards compiled out (``guard_nonfinite=False``), which is the
same property the ``bench_failure_overhead`` gate prices.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ControllerConfig,
    SolveStatus,
    adaptive_while_solve,
    batched_adaptive_while_solve,
    odeint,
    odeint_checked,
    solve_with_fallback,
)
from repro.core.integrate import mali_adaptive_solve
from repro.core.tableaus import get_tableau

from faults import faulty_field

METHODS = ("aca", "adjoint", "naive", "mali")
TOL = dict(rtol=1e-3, atol=1e-3)      # keeps mali inside its step budget


def _kw(method, **extra):
    kw = dict(TOL, grad_method=method, **extra)
    if method != "mali":
        kw["solver"] = "dopri5"
    return kw


def _decay(t, z):
    return -z


def _assert_bitwise(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------------ status
@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("batched", [False, True])
def test_clean_solve_status_ok(method, batched):
    z0 = jnp.ones((3, 4)) if batched else jnp.ones((4,))
    ts = jnp.linspace(0.0, 1.0, 4)
    kw = _kw(method, batch_axis=0) if batched else _kw(method)
    ys, stats = odeint(_decay, z0, ts, **kw)
    assert bool(jnp.all(stats.status == SolveStatus.OK)), stats.status
    assert bool(jnp.isfinite(ys).all())


@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("batched", [False, True])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_nan_fault_detected_and_frozen(method, batched, use_pallas):
    """Mid-solve NaN: NONFINITE_STATE status, finite outputs, and the
    pre-fault eval prefix bit-equal to the unfaulted solve."""
    z0 = jnp.ones((3, 4)) if batched else jnp.ones((4,))
    ts = jnp.linspace(0.0, 1.0, 5)
    t_fault = 0.45
    kw = _kw(method, use_pallas=use_pallas)
    if batched:
        kw["batch_axis"] = 0
    ys_ok, _ = odeint(_decay, z0, ts, **kw)
    ys, stats = odeint(faulty_field(_decay, "nan", t_ge=t_fault),
                       z0, ts, **kw)
    assert bool(jnp.all(stats.status == SolveStatus.NONFINITE_STATE)), \
        stats.status
    assert bool(jnp.isfinite(ys).all())
    # eval times strictly before the trigger never saw a faulted stage
    n_pre = int((np.asarray(ts) < t_fault).sum())
    _assert_bitwise(ys[:n_pre], ys_ok[:n_pre])
    # post-fault slots are all the frozen last-accepted state
    for k in range(n_pre + 1, ts.shape[0]):
        _assert_bitwise(ys[k], ys[n_pre])


@pytest.mark.parametrize("kind", ["nan", "inf", "spike"])
def test_fault_kinds_all_unhealthy(kind):
    """Every injector kind ends with a non-OK status (NaN/Inf are
    detected as NONFINITE; a finite 1e30 spike wrecks the error test
    instead and surfaces as underflow/budget exhaustion)."""
    z0 = jnp.ones((4,))
    ts = jnp.linspace(0.0, 1.0, 4)
    ys, stats = odeint(faulty_field(_decay, kind, t_ge=0.45), z0, ts,
                       **_kw("aca"))
    assert int(stats.status) != SolveStatus.OK
    if kind in ("nan", "inf"):
        assert int(stats.status) == SolveStatus.NONFINITE_STATE
    assert bool(jnp.isfinite(ys).all())


def test_status_underflow_budget_overflow():
    """The three degradation codes are distinguishable: a discontinuity
    rails h at h_min while still failing the error test (UNDERFLOW); a
    1-trial budget exhausts trials (BUDGET); a tight tolerance with a
    tiny step cap runs out of checkpoints (OVERFLOW)."""
    z0 = jnp.ones((2,))
    ts = jnp.linspace(0.0, 1.0, 3)

    def fjump(t, z):
        return jnp.where(t < 0.5, 1.0, -1e6) * jnp.ones_like(z)

    _, stats = odeint(fjump, z0, ts, rtol=1e-6, atol=1e-9, max_steps=256)
    assert int(stats.status) == SolveStatus.STEPSIZE_UNDERFLOW

    def fstiff(t, z):
        return -1e5 * z

    _, stats = odeint(fstiff, z0, ts, rtol=1e-12, atol=1e-14,
                      max_steps=64, max_trials=1)
    assert int(stats.status) == SolveStatus.TRIAL_BUDGET_EXHAUSTED

    _, stats = odeint(_decay, z0, ts, rtol=1e-12, atol=1e-14, max_steps=8)
    assert int(stats.status) == SolveStatus.CHECKPOINT_OVERFLOW


def test_status_describe():
    assert SolveStatus.describe(SolveStatus.OK) == "OK"
    assert SolveStatus.describe(
        SolveStatus.NONFINITE_STATE) == "NONFINITE_STATE"
    for code in range(5):
        assert "UNKNOWN" not in SolveStatus.describe(code)
    assert "UNKNOWN" in SolveStatus.describe(99)


# ------------------------------------------------- batched isolation/grads
@pytest.mark.parametrize("method", METHODS)
def test_batched_single_element_fault_isolated(method):
    """One poisoned batch element: its status flips, every other
    element's trajectory is bit-identical to the unfaulted batch, and
    (aca/adjoint/mali) gradients stay finite with the failed row's
    dz0 exactly zero."""
    # state = [x, tag]; the tag channel is constant and marks element 1
    def f(t, z):
        return jnp.stack([-z[0], 0.0 * z[1]])

    z0 = jnp.stack([jnp.array([1.0, 0.0]), jnp.array([1.0, 1.0]),
                    jnp.array([1.0, 2.0])])
    ts = jnp.linspace(0.0, 1.0, 4)
    # tolerant tag match: MALI's lattice quantization perturbs the tag
    # channel by ~1 ulp (1.0 decodes as 0.99999994), so exact equality
    # would never trigger the fault there
    fbad = faulty_field(f, "nan", t_ge=0.45,
                        predicate=lambda t, z: jnp.abs(z[1] - 1.0) < 0.5)
    kw = _kw(method, batch_axis=0)

    ys_ok, _ = odeint(f, z0, ts, **kw)
    ys, stats = odeint(fbad, z0, ts, **kw)
    assert [int(s) for s in stats.status] == [
        SolveStatus.OK, SolveStatus.NONFINITE_STATE, SolveStatus.OK]
    assert bool(jnp.isfinite(ys).all())
    _assert_bitwise(ys[:, 0], ys_ok[:, 0])
    _assert_bitwise(ys[:, 2], ys_ok[:, 2])

    if method == "naive":
        # naive keeps the faulted trial on its differentiable tape, so
        # post-fault gradients are not guaranteed finite (documented in
        # docs/robustness.md); the train-loop skip-step guard is the
        # mitigation there
        return

    def loss(z):
        ys, _ = odeint(fbad, z, ts, **kw)
        return jnp.sum(ys[-1, :, 0] ** 2)

    g = jax.grad(loss)(z0)
    assert bool(jnp.isfinite(g).all()), g
    _assert_bitwise(g[1], jnp.zeros_like(g[1]))  # failed row: exact zeros
    assert float(jnp.abs(g[0]).max()) > 0.0      # healthy rows still flow


# -------------------------------------------------- default-path identity
def test_guards_are_bitwise_noop_on_healthy_solve():
    """guard_nonfinite=True vs False: identical trajectories and
    counters on a healthy solve — the status field is the only
    addition."""
    tab = get_tableau("dopri5")
    cfg = ControllerConfig()
    z0 = jnp.ones((4,))
    ts = jnp.linspace(0.0, 1.0, 4)

    ys_g, _, st_g = adaptive_while_solve(
        tab, _decay, z0, ts, (), 1e-6, 1e-6, cfg, guard_nonfinite=True)
    ys_n, _, st_n = adaptive_while_solve(
        tab, _decay, z0, ts, (), 1e-6, 1e-6, cfg, guard_nonfinite=False)
    _assert_bitwise(ys_g, ys_n)
    _assert_bitwise(st_g.n_steps, st_n.n_steps)
    _assert_bitwise(st_g.n_trials, st_n.n_trials)
    assert int(st_g.status) == SolveStatus.OK

    z0b = jnp.ones((3, 4))
    ys_g, _, st_g = batched_adaptive_while_solve(
        tab, _decay, z0b, ts, (), 1e-6, 1e-6, cfg, guard_nonfinite=True)
    ys_n, _, st_n = batched_adaptive_while_solve(
        tab, _decay, z0b, ts, (), 1e-6, 1e-6, cfg, guard_nonfinite=False)
    _assert_bitwise(ys_g, ys_n)
    _assert_bitwise(st_g.n_trials, st_n.n_trials)

    ys_g, _, st_g = mali_adaptive_solve(
        _decay, z0, ts, (), 1e-3, 1e-3, cfg, guard_nonfinite=True)
    ys_n, _, st_n = mali_adaptive_solve(
        _decay, z0, ts, (), 1e-3, 1e-3, cfg, guard_nonfinite=False)
    _assert_bitwise(ys_g, ys_n)
    _assert_bitwise(st_g.n_trials, st_n.n_trials)


# ------------------------------------------------------------- policies
def test_on_failure_validation():
    z0, ts = jnp.ones((2,)), jnp.linspace(0.0, 1.0, 3)
    with pytest.raises(ValueError, match="on_failure"):
        odeint(_decay, z0, ts, on_failure="explode")
    with pytest.raises(ValueError, match="h0"):
        odeint(_decay, z0, ts, solver="rk4", h0=0.1)


def test_on_failure_warn_smoke():
    z0, ts = jnp.ones((2,)), jnp.linspace(0.0, 1.0, 3)
    fbad = faulty_field(_decay, "nan", t_ge=0.45)
    ys, stats = odeint(fbad, z0, ts, on_failure="warn", **_kw("aca"))
    jax.effects_barrier()
    assert int(stats.status) == SolveStatus.NONFINITE_STATE
    # healthy solve must not warn (and must stay bit-identical)
    ys, stats = odeint(_decay, z0, ts, on_failure="warn", **_kw("aca"))
    assert int(stats.status) == SolveStatus.OK


def test_odeint_checked_raises_on_fault():
    from jax.experimental import checkify

    z0, ts = jnp.ones((2,)), jnp.linspace(0.0, 1.0, 3)
    ys, stats = odeint_checked(_decay, z0, ts, **_kw("aca"))
    assert int(stats.status) == SolveStatus.OK
    fbad = faulty_field(_decay, "nan", t_ge=0.45)
    with pytest.raises(checkify.JaxRuntimeError, match="status"):
        odeint_checked(fbad, z0, ts, **_kw("aca"))


def test_node_config_threads_on_failure():
    from repro.core import NodeConfig, node_block_apply

    cfg = NodeConfig(enabled=True, on_failure="status")
    params = {"w": jnp.ones((3,)) * 0.1}

    def block(p, z, t):
        return -p["w"] * z

    zT = node_block_apply(block, params, jnp.ones((3,)), cfg)
    assert bool(jnp.isfinite(zT).all())


# ------------------------------------------------------------- fallback
def test_solve_with_fallback_recovers():
    z0, ts = jnp.ones((2,)), jnp.linspace(0.0, 1.0, 3)
    # tight tolerance + tiny step cap fails; the ladder's fixed-rk4
    # rung has no stepsize search left to exhaust
    ys, stats, report = solve_with_fallback(
        _decay, z0, ts, rtol=1e-12, atol=1e-14, max_steps=8)
    assert bool(jnp.all(stats.status == SolveStatus.OK))
    assert bool(jnp.isfinite(ys).all())
    assert report[0]["ok"] is False
    assert report[-1]["ok"] is True
    assert any("rk4" in r["note"] for r in report)
    np.testing.assert_allclose(np.asarray(ys[-1]),
                               np.exp(-1.0) * np.ones(2), rtol=1e-4)


def test_solve_with_fallback_healthy_short_circuits():
    z0, ts = jnp.ones((2,)), jnp.linspace(0.0, 1.0, 3)
    ys, stats, report = solve_with_fallback(_decay, z0, ts, **_kw("aca"))
    assert len(report) == 1 and report[0]["note"] == "original"
    assert report[0]["ok"] is True


def test_solve_with_fallback_unrecoverable_returns_frozen():
    z0, ts = jnp.ones((2,)), jnp.linspace(0.0, 1.0, 3)
    fbad = faulty_field(_decay, "nan", t_ge=0.45)
    ys, stats, report = solve_with_fallback(fbad, z0, ts, **_kw("aca"))
    assert all(not r.get("ok") for r in report)
    assert int(stats.status) == SolveStatus.NONFINITE_STATE
    assert bool(jnp.isfinite(ys).all())   # frozen, not garbage


# -------------------------------------------------------- train guards
def test_clip_by_global_norm_nonfinite():
    from repro.optim.grad_utils import clip_by_global_norm

    g = {"a": jnp.ones((3,)), "b": jnp.array([jnp.inf, 1.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert not bool(jnp.isfinite(norm))       # raw norm surfaces the Inf
    for leaf in jax.tree.leaves(clipped):      # default: zeroed, not NaN
        _assert_bitwise(leaf, jnp.zeros_like(leaf))
    clipped, norm = clip_by_global_norm(g, 1.0, on_nonfinite="keep")
    _assert_bitwise(clipped["a"], g["a"])      # kept unclipped, unscaled
    with pytest.raises(ValueError, match="on_nonfinite"):
        clip_by_global_norm(g, 1.0, on_nonfinite="explode")
    # healthy path unchanged
    g2 = {"a": jnp.ones((3,)) * 3.0}
    clipped, norm = clip_by_global_norm(g2, 1.0)
    np.testing.assert_allclose(float(norm), 3.0 * np.sqrt(3.0), rtol=1e-6)
    np.testing.assert_allclose(
        float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-6)


class _ToyModel:
    """Quadratic toy whose loss goes NaN whenever the batch does."""

    def loss_fn(self, params, batch):
        loss = jnp.mean((params["w"] * batch["x"] - 1.0) ** 2)
        return loss, {}


def test_train_step_skips_nonfinite_update():
    from repro.optim.adamw import adamw
    from repro.train import TrainState, build_train_step
    from repro.train.loop import TrainLoopConfig

    model, opt = _ToyModel(), adamw(lambda s: 1e-2)
    params = {"w": jnp.ones((4,))}
    state = TrainState(step=jnp.zeros((), jnp.int32), params=params,
                       opt_state=opt.init(params))
    step = build_train_step(model, opt, TrainLoopConfig())

    from repro.optim.grad_utils import CompressionState
    comp = CompressionState(error=())
    clean = {"x": jnp.ones((4,)) * 2.0}
    poison = {"x": jnp.full((4,), jnp.nan)}

    s1, comp, m1 = step(state, clean, comp)
    assert int(m1["skipped"]) == 0
    assert float(jnp.abs(s1.params["w"] - params["w"]).max()) > 0.0

    s2, comp, m2 = step(s1, poison, comp)
    assert int(m2["skipped"]) == 1
    assert int(s2.step) == int(s1.step) + 1   # step advances anyway
    _assert_bitwise(s2.params["w"], s1.params["w"])   # update held
    for a, b in zip(jax.tree.leaves(s2.opt_state),
                    jax.tree.leaves(s1.opt_state)):
        _assert_bitwise(a, b)

    # guard off: no skip metric, and params stay finite only because
    # clip_by_global_norm zeroes the non-finite grads (defense in
    # depth) — but the held-update contract is gone: adamw's weight
    # decay + stale momentum still move the params on the poisoned step
    step_raw = build_train_step(
        model, opt, TrainLoopConfig(skip_nonfinite=False))
    s3, _, m3 = step_raw(s1, poison, comp)
    assert "skipped" not in m3
    assert not bool(jnp.isfinite(m3["loss"]))          # loss is NaN
    assert bool(jnp.isfinite(s3.params["w"]).all())    # clip guard held
    assert float(jnp.abs(s3.params["w"] - s1.params["w"]).max()) > 0.0


def test_train_loop_counts_skipped_steps():
    from repro.optim.adamw import adamw
    from repro.train import TrainLoop, TrainState
    from repro.train.loop import TrainLoopConfig

    model, opt = _ToyModel(), adamw(lambda s: 1e-2)
    params = {"w": jnp.ones((4,))}
    state = TrainState(step=jnp.zeros((), jnp.int32), params=params,
                       opt_state=opt.init(params))
    loop = TrainLoop(model, opt, TrainLoopConfig(log_every=1), state,
                     jit=False)

    def batch_fn(s):
        if s == 1:
            return {"x": jnp.full((4,), jnp.nan)}
        return {"x": jnp.ones((4,)) * 2.0}

    loop.run(batch_fn, 3)
    assert loop.skipped_steps == 1
    assert bool(jnp.isfinite(loop.state.params["w"]).all())


# --------------------------------------------------------------- serve
class _ScriptedModel:
    """Serving stub that emits a scripted token sequence per row."""

    def __init__(self, script, vocab=16):
        self.script = np.asarray(script)     # (B, T) new-token ids
        self.vocab = vocab

    def _logits(self, idx):
        return jax.nn.one_hot(jnp.asarray(self.script[:, idx]),
                              self.vocab) * 10.0

    def prefill(self, params, batch):
        self._s = batch["tokens"].shape[1]
        return self._logits(0), jnp.zeros((), jnp.int32)

    def decode_step(self, params, batch, caches, pos):
        idx = int(pos) - self._s + 1
        return self._logits(idx), caches


def test_serve_generate_breaks_early_on_eos():
    from repro.serve import ServeConfig, ServeEngine

    eos = 7
    # rows finish after 3, 5 and 2 new tokens respectively
    script = [[1, 2, eos, 3, 3, 3, 3, 3],
              [1, 2, 3, 4, eos, 3, 3, 3],
              [1, eos, 3, 3, 3, 3, 3, 3]]
    model = _ScriptedModel(script)
    eng = ServeEngine(model, params={},
                      cfg=ServeConfig(max_new_tokens=8, eos_id=eos),
                      jit=False)
    toks = jnp.zeros((3, 4), jnp.int32)
    out = eng.generate(toks)["tokens"]
    # loop stops right after the slowest row's eos: 4 decode steps,
    # not max_new_tokens - 1 = 7
    assert eng.last_decode_steps == 4
    assert out.shape == (3, 4 + 5)
    got = np.asarray(out[:, 4:])
    np.testing.assert_array_equal(got[0], [1, 2, eos, eos, eos])
    np.testing.assert_array_equal(got[1], [1, 2, 3, 4, eos])
    np.testing.assert_array_equal(got[2], [1, eos, eos, eos, eos])


def test_serve_generate_all_eos_at_first_token():
    from repro.serve import ServeConfig, ServeEngine

    eos = 7
    script = [[eos] * 8, [eos] * 8]
    eng = ServeEngine(_ScriptedModel(script), params={},
                      cfg=ServeConfig(max_new_tokens=8, eos_id=eos),
                      jit=False)
    out = eng.generate(jnp.zeros((2, 4), jnp.int32))["tokens"]
    assert eng.last_decode_steps == 0     # decode loop never entered
    assert out.shape == (2, 5)
    np.testing.assert_array_equal(np.asarray(out[:, -1]), [eos, eos])


def test_serve_generate_no_eos_runs_full_budget():
    from repro.serve import ServeConfig, ServeEngine

    script = [[1] * 8, [2] * 8]
    eng = ServeEngine(_ScriptedModel(script), params={},
                      cfg=ServeConfig(max_new_tokens=8), jit=False)
    out = eng.generate(jnp.zeros((2, 4), jnp.int32))["tokens"]
    assert eng.last_decode_steps == 7
    assert out.shape == (2, 12)
