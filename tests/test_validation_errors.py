"""Pin every bare-assert -> named-ValueError conversion (solver-lint PR).

Each test drives the converted validation through a user-reachable call
and asserts a ValueError with a recognizable message — the checks must
survive ``python -O`` and name what is wrong (asserts did neither).
"""

import dataclasses
import types

import jax
import jax.numpy as jnp
import pytest

from repro.core import tableaus
from repro.models import ModelConfig, RunConfig, build_model


# ---------------------------------------------------------------------------
# core/tableaus.py: Tableau.validate


def test_tableau_bad_b_sum():
    bad = dataclasses.replace(tableaus.RK4, b=(0.5, 0.0, 0.0, 0.0))
    with pytest.raises(ValueError, match=r"sum\(b\) != 1"):
        bad.validate()


def test_tableau_not_explicit():
    bad = dataclasses.replace(
        tableaus.HEUN2, a=((1.0,), (1.0, 0.0)), c=(1.0, 1.0))
    with pytest.raises(ValueError, match="not explicit"):
        bad.validate()


def test_tableau_row_sums():
    bad = dataclasses.replace(tableaus.HEUN2, c=(0.0, 0.5))
    with pytest.raises(ValueError, match="row sums"):
        bad.validate()


def test_tableau_bad_b_err_sum():
    bad = dataclasses.replace(tableaus.HEUN_EULER, b_err=(0.5, 0.5))
    with pytest.raises(ValueError, match=r"sum\(b_err\) != 0"):
        bad.validate()


def test_tableau_bad_b_mid():
    with pytest.raises(ValueError, match="b_mid"):
        dataclasses.replace(tableaus.DOPRI5, b_mid=(0.5,)).validate()
    bad_sum = tuple(2 * w for w in tableaus.DOPRI5.b_mid)
    with pytest.raises(ValueError, match=r"sum\(b_mid\)"):
        dataclasses.replace(tableaus.DOPRI5, b_mid=bad_sum).validate()


# ---------------------------------------------------------------------------
# kernels: divisibility contracts


def test_rg_lru_chunk_divisibility():
    from repro.kernels.rg_lru import rg_lru_pallas

    la = jnp.zeros((1, 3, 4), jnp.float32)
    with pytest.raises(ValueError, match="not divisible by chunk"):
        rg_lru_pallas(la, la, chunk=2, interpret=True)


def test_rg_lru_c_tile_divisibility():
    from repro.kernels.rg_lru import rg_lru_pallas

    la = jnp.zeros((1, 4, 6), jnp.float32)
    with pytest.raises(ValueError, match="not divisible by c_tile"):
        rg_lru_pallas(la, la, chunk=2, c_tile=4, interpret=True)


def test_flash_attention_block_divisibility():
    from repro.kernels.flash_attention import flash_attention_pallas

    q = jnp.zeros((1, 2, 3, 4), jnp.float32)  # (B, H, S=3, dh)
    with pytest.raises(ValueError, match="must divide"):
        flash_attention_pallas(q, q, q, block_q=2, interpret=True)


def test_ssd_scan_chunk_divisibility():
    from repro.kernels.ssd_scan import ssd_scan_pallas

    x = jnp.zeros((1, 3, 2, 4), jnp.float32)
    dt = jnp.zeros((1, 3, 2), jnp.float32)
    a = jnp.zeros((2,), jnp.float32)
    bm = jnp.zeros((1, 3, 1, 4), jnp.float32)
    with pytest.raises(ValueError, match="not divisible by chunk"):
        ssd_scan_pallas(x, dt, a, bm, bm, 2, interpret=True)


# ---------------------------------------------------------------------------
# models


def test_mamba2_ssd_chunk_divisibility():
    from repro.models.mamba2 import ssd_chunked

    x = jnp.zeros((1, 3, 2, 4), jnp.float32)
    dt = jnp.zeros((1, 3, 2), jnp.float32)
    a = jnp.zeros((2,), jnp.float32)
    bm = jnp.zeros((1, 3, 1, 4), jnp.float32)
    with pytest.raises(ValueError, match="not divisible by chunk"):
        ssd_chunked(x, dt, a, bm, bm, 2)


def test_chunked_attention_block_divisibility():
    from repro.models.attention import chunked_attention

    q = jnp.zeros((1, 3, 2, 4), jnp.float32)
    with pytest.raises(ValueError, match="not divisible by"):
        chunked_attention(q, q, q, block=2)


def test_param_def_rank_mismatch():
    from repro.models.common import ParamDef

    with pytest.raises(ValueError, match="different ranks"):
        ParamDef(shape=(2, 3), dtype=jnp.float32, logical=("embed",))


@pytest.mark.parametrize(
    "cfg",
    [
        ModelConfig(name="t", family="dense", n_layers=1, d_model=32,
                    vocab=64, n_heads=2, n_kv_heads=1, d_ff=64),
        ModelConfig(name="t", family="ssm", n_layers=1, d_model=32,
                    vocab=64, ssm_state=8, ssm_head_dim=8, ssm_chunk=4),
        ModelConfig(name="t", family="hybrid", n_layers=3, d_model=32,
                    vocab=64, n_heads=2, n_kv_heads=1, d_ff=64, d_rnn=32,
                    pattern=("rec", "rec", "attn"), window=4),
    ],
    ids=["dense", "ssm", "hybrid"],
)
def test_decode_without_cache_raises(cfg):
    m = build_model(cfg, RunConfig(compute_dtype=jnp.float32, max_seq=8))
    params = m.init(jax.random.PRNGKey(0))
    toks = jnp.zeros((1, 1), jnp.int32)
    with pytest.raises(ValueError, match="decode mode needs a cache"):
        m.forward(params, {"tokens": toks}, mode="decode")


def test_moe_expert_mesh_divisibility():
    from repro.models.moe import moe_apply

    fake_mesh = types.SimpleNamespace(
        empty=False, axis_names=("model",), shape={"model": 3})
    cfg = types.SimpleNamespace(n_experts=5, top_k=2)
    rcfg = types.SimpleNamespace(
        compute_dtype=jnp.float32, mesh=fake_mesh, rules=None)
    p = {"router": jnp.zeros((4, 5), jnp.float32)}
    x = jnp.zeros((2, 3, 4), jnp.float32)
    with pytest.raises(ValueError, match="n_experts=5 not divisible"):
        moe_apply(p, x, cfg, rcfg)


# ---------------------------------------------------------------------------
# train + launch


def test_split_microbatches_divisibility():
    from repro.train.loop import _split_microbatches

    batch = {"x": jnp.zeros((3, 2), jnp.float32)}
    with pytest.raises(ValueError, match="not divisible by 2 microbatches"):
        _split_microbatches(batch, 2)


def test_dryrun_requires_arch_and_shape(monkeypatch):
    import sys

    from repro.launch.dryrun import main

    monkeypatch.setattr(sys, "argv", ["dryrun"])
    with pytest.raises(ValueError, match="--arch and --shape"):
        main()
