"""Deterministic fault injection for solve-health tests.

``faulty_field`` wraps any vector field ``f(t, z, *args)`` so that it
emits a configured corruption (NaN / Inf / a large finite spike) once
the integration clock enters a trigger window — deterministic,
jit-compatible (the trigger is a traced ``jnp.where``, no host
branching), and usable under every gradient method and batch mode
because the wrapped field keeps ``f``'s signature exactly.

The corrupted value *replaces* the field output, so a single accepted
step inside the window is enough to poison the state — which is what
the solve-health guards must detect (``SolveStatus.NONFINITE_STATE``)
and freeze.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

_KINDS = ("nan", "inf", "spike")
_SPIKE = 1e30


def fault_value(kind: str, dtype=jnp.float32):
    """The scalar a faulted leaf is overwritten with: ``"nan"`` →
    NaN, ``"inf"`` → +Inf, ``"spike"`` → 1e30 (finite but large
    enough that one RK stage overflows the state downstream)."""
    if kind == "nan":
        return jnp.asarray(jnp.nan, dtype)
    if kind == "inf":
        return jnp.asarray(jnp.inf, dtype)
    if kind == "spike":
        return jnp.asarray(_SPIKE, dtype)
    raise ValueError(f"kind must be one of {_KINDS}; got {kind!r}")


def faulty_field(
    f: Callable,
    kind: str = "nan",
    t_ge: float = 0.5,
    t_until: Optional[float] = None,
    predicate: Optional[Callable] = None,
) -> Callable:
    """Wrap ``f`` to emit ``kind`` whenever ``t`` is in the trigger
    window ``[t_ge, t_until)`` (``t_until=None`` → open-ended).

    ``predicate(t, z) -> bool array`` further gates the trigger when
    given (e.g. fault only one batch element by shape-matching ``z``).
    The corruption is applied leaf-wise with ``jnp.where`` so the
    wrapper traces under jit/vmap/while_loop like the original field.
    """
    if kind not in _KINDS:
        raise ValueError(f"kind must be one of {_KINDS}; got {kind!r}")

    def wrapped(t, z, *args):
        out = f(t, z, *args)
        trig = t >= t_ge
        if t_until is not None:
            trig = trig & (t < t_until)
        if predicate is not None:
            trig = trig & predicate(t, z)
        return jax.tree.map(
            lambda leaf: jnp.where(trig, fault_value(kind, leaf.dtype),
                                   leaf), out)

    return wrapped
