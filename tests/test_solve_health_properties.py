"""Hypothesis property tests for the solve-health guards: a NaN
injected at *any* time inside the solve window is detected with
``SolveStatus.NONFINITE_STATE`` under every gradient method, outputs
stay finite, and the pre-fault eval prefix is bit-equal to the
unfaulted solve (the guards are inert until the fault fires).

Skipped (not errored) when ``hypothesis`` is absent from the image.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import SolveStatus, odeint  # noqa: E402

from faults import faulty_field  # noqa: E402

SET = dict(max_examples=8, deadline=None)
TS = jnp.linspace(0.0, 1.0, 5)


def _decay(t, z):
    return -z


def _kw(method):
    kw = dict(rtol=1e-3, atol=1e-3, grad_method=method)
    if method != "mali":
        kw["solver"] = "dopri5"
    return kw


@pytest.mark.parametrize("method", ["aca", "adjoint", "naive", "mali"])
@settings(**SET)
@given(t_fault=st.floats(0.26, 0.8))
def test_nan_at_any_time_detected(method, t_fault):
    z0 = jnp.ones((4,))
    kw = _kw(method)
    ys_ok, _ = odeint(_decay, z0, TS, **kw)
    ys, stats = odeint(faulty_field(_decay, "nan", t_ge=t_fault),
                       z0, TS, **kw)
    assert int(stats.status) == SolveStatus.NONFINITE_STATE
    assert bool(jnp.isfinite(ys).all())
    n_pre = int((np.asarray(TS) < t_fault).sum())
    np.testing.assert_array_equal(np.asarray(ys[:n_pre]),
                                  np.asarray(ys_ok[:n_pre]))


@settings(**SET)
@given(t_fault=st.floats(0.26, 0.8), b_fault=st.integers(0, 2))
def test_batched_fault_isolation_any_element(t_fault, b_fault):
    """Whichever element is poisoned, at whatever time: only that
    element's status flips and the others stay bit-identical."""
    def f(t, z):
        return jnp.stack([-z[0], 0.0 * z[1]])

    tag = float(b_fault)
    z0 = jnp.stack([jnp.array([1.0, float(b)]) for b in range(3)])
    fbad = faulty_field(f, "nan", t_ge=t_fault,
                        predicate=lambda t, z: jnp.abs(z[1] - tag) < 0.5)
    kw = dict(rtol=1e-3, atol=1e-3, solver="dopri5", grad_method="aca",
              batch_axis=0)
    ys_ok, _ = odeint(f, z0, TS, **kw)
    ys, stats = odeint(fbad, z0, TS, **kw)
    for b in range(3):
        if b == b_fault:
            assert int(stats.status[b]) == SolveStatus.NONFINITE_STATE
        else:
            assert int(stats.status[b]) == SolveStatus.OK
            np.testing.assert_array_equal(np.asarray(ys[:, b]),
                                          np.asarray(ys_ok[:, b]))
    assert bool(jnp.isfinite(ys).all())
