"""Dryrun/roofline coverage on ODE workloads (``launch/node_dryrun.py``).

Golden-file test: the ``run_cell``-style NODE dry-run must emit the
report structure pinned in ``tests/golden/node_dryrun_keys.json`` with
*finite* bytes/FLOPs/collective numbers, and ``analyze_hlo`` must see
the expected psum (an ``all-reduce``) in the **adjoint** sharded
backward — the one collective the shared-args cotangent crosses
devices with.  The serve (forward-only) cell must show *no* all-reduce
at all: the forward solve is embarrassingly parallel.

The cells compile on 8 forced host devices, so the measurement runs in
a subprocess (device count locks at jax init); the parent validates
the JSON reports against the golden schema.
"""

import json
import os
import subprocess
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json

from repro.launch.node_dryrun import run_node_cell

reports = [
    run_node_cell("train", batch=16, dim=8, grad_method="adjoint",
                  save=False),
    run_node_cell("serve", batch=16, dim=8, grad_method="aca",
                  save=False),
]
print("REPORTS=" + json.dumps(reports))
"""


def _finite(x):
    return isinstance(x, (int, float)) and not isinstance(x, bool) \
        and x == x and abs(x) != float("inf")


def test_node_dryrun_reports_match_golden():
    env = dict(os.environ)
    root = os.path.dirname(_HERE)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    lines = [ln for ln in r.stdout.splitlines()
             if ln.startswith("REPORTS=")]
    assert lines, (r.stdout[-2000:], r.stderr[-4000:])
    train, serve = json.loads(lines[-1][len("REPORTS="):])

    with open(os.path.join(_HERE, "golden",
                           "node_dryrun_keys.json")) as fh:
        golden = json.load(fh)

    for rep in (train, serve):
        for k in golden["report"]:
            assert k in rep, (rep["cell"], k)
        for k in golden["measured"]:
            assert k in rep["measured"], (rep["cell"], k)
        for k in golden["hlo_static"]:
            assert _finite(rep["hlo_static"][k]), (rep["cell"], k)
        for k in golden["roofline_finite"]:
            assert _finite(rep["roofline"][k]), (rep["cell"], k)
        # a healthy measured solve, with a real dynamic-trip while loop
        assert rep["measured"]["all_ok"] is True
        assert rep["measured"]["while_trips_straggler"] >= 1
        assert rep["measured"]["nfe_total"] > 0
        assert rep["hlo_static"]["dynamic_whiles"] >= 1
        # the verdict this dry-run exists to assert: never
        # collective-bound (the args-psum is one small transfer)
        assert rep["collective_bound"] is False

    # the adjoint train cell's backward crosses devices exactly through
    # the shared-args cotangent psum — analyze_hlo must see it
    assert train["roofline"]["coll_by_kind"].get("all-reduce", 0) > 0, \
        train["roofline"]["coll_by_kind"]
    # the forward-only serve cell has nothing to reduce
    assert serve["roofline"]["coll_by_kind"].get("all-reduce", 0) == 0, \
        serve["roofline"]["coll_by_kind"]
