"""Train loop: loss goes down, checkpoint/restart is exact, compression
error feedback is sound, straggler hook fires."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, save_checkpoint
from repro.data import TokenPipeline
from repro.models import ModelConfig, RunConfig, build_model
from repro.optim import adamw, cosine_warmup, sgd, step_decay
from repro.optim.grad_utils import (clip_by_global_norm, global_norm,
                                    init_compression_state,
                                    int8_compress_decompress,
                                    topk_sparsify)
from repro.train import TrainLoop, TrainLoopConfig, make_train_state

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  vocab=256, n_heads=4, n_kv_heads=2, d_ff=128)


def _loop(tmpdir, **kw):
    m = build_model(CFG, RunConfig(compute_dtype=jnp.float32))
    opt = adamw(cosine_warmup(3e-3, 5, 200), weight_decay=0.01)
    lcfg = TrainLoopConfig(ckpt_dir=str(tmpdir) if tmpdir else None,
                           ckpt_every=5, log_every=1, **kw)
    state = make_train_state(m, opt, jax.random.PRNGKey(0))
    return m, opt, lcfg, TrainLoop(m, opt, lcfg, state)


def test_loss_decreases(tmp_path):
    pipe = TokenPipeline(vocab=256, seq_len=32, global_batch=8)
    _, _, _, loop = _loop(None)
    losses = []
    loop.run(lambda s: pipe.batch(0), 25,        # overfit one batch
             log_cb=lambda s, mt: losses.append(mt["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[:3] + losses[-3:]


def test_checkpoint_restart_exact(tmp_path):
    pipe = TokenPipeline(vocab=256, seq_len=32, global_batch=8)
    m, opt, lcfg, loop = _loop(tmp_path)
    loop.run(lambda s: pipe.batch(s), 10)
    params_10 = jax.tree.leaves(loop.state.params)

    # a fresh loop restores step 10 exactly and continues
    state2 = make_train_state(m, opt, jax.random.PRNGKey(42))
    loop2 = TrainLoop(m, opt, lcfg, state2)
    assert loop2.step == 10
    for a, b in zip(params_10, jax.tree.leaves(loop2.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # deterministic data: running 10->12 equals an uninterrupted run
    loop2.run(lambda s: pipe.batch(s), 12)
    _, _, _, loop3 = _loop(None)
    loop3.run(lambda s: pipe.batch(s), 12)
    for a, b in zip(jax.tree.leaves(loop2.state.params),
                    jax.tree.leaves(loop3.state.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_ckpt_atomicity_and_fallback(tmp_path):
    tree = {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.zeros((3,))}
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(1, tree)
    mgr.save(2, jax.tree.map(lambda x: x + 1, tree))
    # corrupt the newest manifest -> restore falls back to step 1
    os.remove(os.path.join(str(tmp_path), "step_0000000002",
                           "manifest.json"))
    step, restored = mgr.restore(tree)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


def test_ckpt_keep_k_gc(tmp_path):
    tree = {"x": jnp.ones((2,))}
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    names = sorted(os.listdir(str(tmp_path)))
    assert names == ["step_0000000003", "step_0000000004"]


def test_ckpt_shape_mismatch_rejected(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"x": jnp.ones((2,))})
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.restore({"x": jnp.ones((3,))}) is None


def test_microbatch_accumulation_matches_full_batch():
    pipe = TokenPipeline(vocab=256, seq_len=16, global_batch=8)
    m = build_model(CFG, RunConfig(compute_dtype=jnp.float32))
    opt = sgd(step_decay(0.1, [1000]), momentum=0.0)
    from repro.optim.grad_utils import CompressionState
    from repro.train.loop import build_train_step
    batch = pipe.batch(0)
    s1 = build_train_step(m, opt, TrainLoopConfig(microbatches=1,
                                                  clip_norm=1e9))
    s4 = build_train_step(m, opt, TrainLoopConfig(microbatches=4,
                                                  clip_norm=1e9))
    st = make_train_state(m, opt, jax.random.PRNGKey(0))
    r1, _, _ = s1(st, batch, CompressionState(error=()))
    st = make_train_state(m, opt, jax.random.PRNGKey(0))
    r4, _, _ = s4(st, batch, CompressionState(error=()))
    for a, b in zip(jax.tree.leaves(r1.params),
                    jax.tree.leaves(r4.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 3.0, "b": jnp.ones((2, 2)) * 4.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 1.0
    # below the threshold: unchanged
    clipped2, _ = clip_by_global_norm(g, 1e9)
    np.testing.assert_allclose(np.asarray(clipped2["a"]),
                               np.asarray(g["a"]))


def test_int8_compression_error_feedback():
    """Error feedback makes repeated compression of a constant gradient
    unbiased: the mean dequantized value converges to the truth."""
    g = {"w": jnp.linspace(-1.0, 1.0, 101) * 1e-3}
    state = init_compression_state(g)
    total = jnp.zeros_like(g["w"])
    n = 50
    for _ in range(n):
        out, state = int8_compress_decompress(g, state)
        total = total + out["w"]
    np.testing.assert_allclose(np.asarray(total / n),
                               np.asarray(g["w"]), rtol=0.02, atol=2e-7)


def test_topk_sparsity_and_feedback():
    g = {"w": jnp.arange(1.0, 101.0)}
    out, state = topk_sparsify(g, 0.1)
    nz = int(jnp.sum(out["w"] != 0))
    assert nz == 10
    # the residual holds everything that was dropped
    np.testing.assert_allclose(
        np.asarray(out["w"] + state.error["w"]), np.asarray(g["w"]),
        rtol=1e-6)


def test_straggler_hook_fires():
    pipe = TokenPipeline(vocab=256, seq_len=16, global_batch=4)
    hits = []
    m = build_model(CFG, RunConfig(compute_dtype=jnp.float32))
    opt = adamw(cosine_warmup(1e-3, 5, 100))
    lcfg = TrainLoopConfig(straggler_factor=3.0)
    state = make_train_state(m, opt, jax.random.PRNGKey(0))
    # injected clock: step 2 takes 31 fake-seconds (a straggler)
    seq = [0.0, 1.0, 1.0, 2.0, 2.0, 33.0, 33.0, 34.0, 34.0, 35.0]
    calls = [0]

    def fake_clock():
        i = calls[0]
        calls[0] += 1
        return seq[i] if i < len(seq) else seq[-1] + (i - len(seq)) + 1.0

    loop = TrainLoop(m, opt, lcfg, state, clock=fake_clock,
                     straggler_cb=lambda s, ratio: hits.append((s, ratio)))
    loop.run(lambda s: pipe.batch(s), 5)
    assert hits, "straggler callback never fired"
    assert max(r for _, r in hits) > 5
