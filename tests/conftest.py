"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see 1 CPU device; only launch/dryrun.py forces 512 placeholders
(and the sharded-solve/dryrun suites re-exec themselves in subprocesses
with 8 forced devices)."""

import os

import jax
import jax.numpy as jnp
import pytest

try:
    # deterministic property tier: the CI profile pins a derandomized
    # (seeded-from-test-name) run with no deadline — hypothesis examples
    # jit/compile, so wall-time-per-example limits only cause flakes.
    # Select another profile with HYPOTHESIS_PROFILE=<name>.
    from hypothesis import settings

    settings.register_profile("ci", derandomize=True, deadline=None,
                              print_blob=True)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ImportError:
    # image without hypothesis: the property suites importorskip
    pass


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-device / subprocess suites (still part "
        "of tier-1; deselect with -m 'not slow' for a quick pass)")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def tiny_batch(cfg, B=2, S=16, seed=0):
    if cfg.frontend != "none":
        from repro.models.frontends import frontend_batch_synthetic
        return frontend_batch_synthetic(cfg, B, S, jax.random.PRNGKey(seed))
    key = jax.random.PRNGKey(seed)
    t = jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32)
    return {"tokens": t, "labels": jnp.roll(t, -1, axis=1),
            "mask": jnp.ones((B, S), jnp.float32)}
