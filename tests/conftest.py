"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see 1 CPU device; only launch/dryrun.py forces 512 placeholders."""

import jax
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def tiny_batch(cfg, B=2, S=16, seed=0):
    if cfg.frontend != "none":
        from repro.models.frontends import frontend_batch_synthetic
        return frontend_batch_synthetic(cfg, B, S, jax.random.PRNGKey(seed))
    key = jax.random.PRNGKey(seed)
    t = jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32)
    return {"tokens": t, "labels": jnp.roll(t, -1, axis=1),
            "mask": jnp.ones((B, S), jnp.float32)}
