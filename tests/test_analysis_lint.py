"""AST-lint layer: every rule catches an injected violation with correct
file:line provenance, the baseline mechanism round-trips, and the repo at
HEAD is clean under the checked-in baseline."""

import json
import pathlib
import textwrap

import pytest

from repro.analysis import (
    BaselineEntry,
    Finding,
    Report,
    lint_file,
    lint_paths,
    load_baseline,
)

REPO = pathlib.Path(__file__).resolve().parent.parent


def _lint_snippet(tmp_path, rel, source):
    """Write ``source`` at tmp_path/rel and lint it with repo-relative paths."""
    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(source))
    return lint_file(str(path), root=str(tmp_path))


# ---------------------------------------------------------------------------
# rule injections


def test_bare_assert_caught_with_provenance(tmp_path):
    findings = _lint_snippet(
        tmp_path,
        "pkg/mod.py",
        """\
        def f(x):
            y = x + 1
            assert y > 0, "bad"
            return y
        """,
    )
    byrule = [f for f in findings if f.rule == "bare-assert"]
    assert len(byrule) == 1
    assert byrule[0].path == "pkg/mod.py"
    assert byrule[0].line == 3
    assert byrule[0].snippet == 'assert y > 0, "bad"'


def test_shard_map_direct_import_caught(tmp_path):
    findings = _lint_snippet(
        tmp_path,
        "pkg/bad_import.py",
        """\
        from jax.experimental.shard_map import shard_map

        def f(fn, mesh):
            return shard_map(fn, mesh=mesh, in_specs=None, out_specs=None)
        """,
    )
    hits = [f for f in findings if f.rule == "shard-map-direct"]
    assert len(hits) == 1 and hits[0].line == 1


def test_shard_map_direct_attribute_caught(tmp_path):
    findings = _lint_snippet(
        tmp_path,
        "pkg/bad_attr.py",
        """\
        import jax

        def f(fn, mesh):
            return jax.shard_map(fn, mesh=mesh, in_specs=None, out_specs=None)
        """,
    )
    hits = [f for f in findings if f.rule == "shard-map-direct"]
    assert len(hits) == 1 and hits[0].line == 4


def test_shard_map_allowed_in_compat_module(tmp_path):
    findings = _lint_snippet(
        tmp_path,
        "distributed/sharding.py",
        """\
        from jax.experimental.shard_map import shard_map
        """,
    )
    assert not [f for f in findings if f.rule == "shard-map-direct"]


def test_jit_host_leak_caught(tmp_path):
    findings = _lint_snippet(
        tmp_path,
        "core/integrate.py",
        """\
        import numpy as np

        def step(z):
            n = int(z.sum())
            s = z.max().item()
            m = np.minimum(n, s)
            return m
        """,
    )
    hits = sorted(
        (f.line, f.message.split(" ")[0]) for f in findings if f.rule == "jit-host-leak"
    )
    assert [ln for ln, _ in hits] == [4, 5, 6]


def test_jit_host_leak_ignores_non_engine_files(tmp_path):
    findings = _lint_snippet(
        tmp_path,
        "data/loader.py",
        """\
        import numpy as np

        def load():
            return np.zeros(3)
        """,
    )
    assert not [f for f in findings if f.rule == "jit-host-leak"]


def test_jit_host_leak_allows_static_casts(tmp_path):
    # float()/int() of a plain name is a static-parameter cast, not a leak
    findings = _lint_snippet(
        tmp_path,
        "core/stepper.py",
        """\
        def order_scale(order):
            return float(order)
        """,
    )
    assert not [f for f in findings if f.rule == "jit-host-leak"]


def test_registry_drift_caught(tmp_path):
    findings = _lint_snippet(
        tmp_path,
        "core/api.py",
        """\
        def solve(grad_method="aca", on_failure="explode"):
            if grad_method == "bogus_method":
                pass
            ladder = [{"solver": "nope5", "grad_method": "aca"}]
            solver = "alf" if grad_method == "mali" else "dopri5"
            return ladder
        """,
    )
    hits = {(f.line, f.snippet.split()[0]) for f in findings if f.rule == "registry-drift"}
    lines = sorted(ln for ln, _ in hits)
    assert lines == [1, 2, 4]  # bad on_failure default, bad compare, bad rung


def test_registry_drift_accepts_live_names(tmp_path):
    findings = _lint_snippet(
        tmp_path,
        "core/api.py",
        """\
        def solve(solver="dopri5", grad_method="mali", on_failure="warn"):
            solver = "alf" if grad_method == "mali" else "rk4"
            return get_tableau("bosh3")
        """,
    )
    assert not [f for f in findings if f.rule == "registry-drift"]


# ---------------------------------------------------------------------------
# baseline mechanics


def test_baseline_requires_justification(tmp_path):
    bad = tmp_path / "baseline.json"
    bad.write_text(json.dumps(
        [{"rule": "bare-assert", "path": "x.py", "match": "assert",
          "justification": "  "}]))
    with pytest.raises(ValueError, match="justification"):
        load_baseline(str(bad))


def test_baseline_requires_all_keys(tmp_path):
    bad = tmp_path / "baseline.json"
    bad.write_text(json.dumps([{"rule": "bare-assert"}]))
    with pytest.raises(ValueError, match="missing keys"):
        load_baseline(str(bad))


def test_baseline_covers_by_rule_path_and_snippet():
    entry = BaselineEntry(
        rule="bare-assert", path="kernels/rk_stage.py",
        match="assert z.shape == (n,)", justification="internal invariant")
    f = Finding(rule="bare-assert", path="src/repro/kernels/rk_stage.py",
                line=145, message="m", snippet="assert z.shape == (n,)")
    assert entry.covers(f)
    # different rule, different file, or different snippet -> not covered
    assert not entry.covers(Finding(rule="jit-host-leak", path=f.path,
                                    line=1, message="m", snippet=f.snippet))
    assert not entry.covers(Finding(rule="bare-assert", path="src/other.py",
                                    line=1, message="m", snippet=f.snippet))
    assert not entry.covers(Finding(rule="bare-assert", path=f.path,
                                    line=1, message="m", snippet="assert q"))


def test_report_active_suppressed_and_stale():
    entries = [
        BaselineEntry(rule="r", path="a.py", match="x", justification="j"),
        BaselineEntry(rule="r", path="gone.py", match="y", justification="j"),
    ]
    rep = Report(baseline=entries)
    rep.add(Finding(rule="r", path="a.py", line=1, message="m", snippet="x"))
    rep.add(Finding(rule="r", path="b.py", line=2, message="m", snippet="z"))
    assert [f.path for f in rep.active()] == ["b.py"]
    assert [f.path for f in rep.suppressed()] == ["a.py"]
    assert [e.path for e in rep.stale_baseline()] == ["gone.py"]
    assert not rep.ok
    assert "1 finding(s), 1 suppressed" in rep.render()


# ---------------------------------------------------------------------------
# the repo at HEAD is clean under the checked-in baseline


def test_repo_is_clean_under_baseline():
    baseline = load_baseline(str(REPO / "tools" / "solver_lint_baseline.json"))
    report = Report(baseline=baseline)
    report.extend(lint_paths([str(REPO / "src")], root=str(REPO)))
    assert report.active() == [], report.render()
    # and the baseline carries no dead entries
    assert report.stale_baseline() == []
