"""Per-sample batched adaptive solving (``odeint(..., batch_axis=)``).

Three properties are on trial:

* **Not lockstep** — on a stiffness-heterogeneous batch every element
  must record its *own* accepted grid (per-element ``n_steps`` differ),
  unlike integrating the stacked state as one system where a single
  accept/reject decision is shared.
* **vmap parity** — outputs and gradients of the batched solve must
  match ``jax.vmap`` of the unbatched solver to ≤1e-5 rel for every
  grad_method × use_pallas combination (the batched engine is the same
  per-element math, fused into one loop).
* **Freezing** — an element that lands on its last eval time is frozen
  by the masking; its outputs and stats must be bit-stable no matter how
  long the stragglers keep the loop alive.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GRAD_METHODS, odeint

# dz/dt over z = [x (d-1,), logk (1,)]: per-sample stiffness exp(logk)
# rides inside the state, so a shared-args batch can still be
# heterogeneous.  Elementwise ops only (bit-stable under row slicing).


def _f(t, z, w):
    x, logk = z[:-1], z[-1]
    dx = -jnp.exp(logk) * x + 0.1 * jnp.tanh(w * x)
    return jnp.concatenate([dx, jnp.zeros((1,), z.dtype)])


def _hetero_batch(B=4, d=4, seed=1):
    x0 = jax.random.normal(jax.random.PRNGKey(seed), (B, d - 1))
    logk = jnp.linspace(0.0, 3.5, B)
    return jnp.concatenate([x0, logk[:, None]], axis=1).astype(jnp.float32)


TS = jnp.array([0.0, 0.5, 1.0], jnp.float32)
KW = dict(solver="dopri5", rtol=1e-5, atol=1e-5, max_steps=64)
W = jnp.float32(0.7)


def _kw(method):
    """Per-method solve kwargs: mali has no RK tableau and — being 2nd
    order with a 1st-order embedded estimate — needs a larger accepted-
    step budget on the stiff rows of the heterogeneous batch."""
    if method == "mali":
        return dict(solver=None, rtol=1e-5, atol=1e-5, max_steps=2048)
    return KW


@pytest.fixture
def _interpret_kernels():
    from repro.kernels import ops
    ops.set_interpret(True)
    yield
    ops.set_interpret(None)


def test_per_element_grids_not_lockstep():
    """Heterogeneous stiffness ⇒ per-element accepted grids differ; the
    lockstep solve (stacked state, one controller) can't represent that."""
    z0 = _hetero_batch()
    _, stats = odeint(_f, z0, TS, (W,), grad_method="aca", batch_axis=0,
                      **KW)
    n = np.asarray(stats.n_steps)
    assert n.shape == (z0.shape[0],)
    assert len(np.unique(n)) > 1, n  # NOT one shared grid

    # lockstep baseline: same batch integrated as ONE stacked state.
    # A single global error norm means one shared grid: easy elements
    # are dragged onto it (paying more steps than their own grid), and
    # the stiff element's error is diluted by the batch RMS (the
    # degraded stepsize search batch_axis exists to avoid).
    fb = lambda t, zb, w: jax.vmap(lambda z: _f(t, z, w))(zb)
    _, st_lock = odeint(fb, z0, TS, (W,), grad_method="aca", **KW)
    assert np.asarray(st_lock.n_steps).shape == ()  # one shared decision
    assert int(st_lock.n_steps) > int(n.min())  # easy elements overpay


def _batched_case(method, use_pallas, z0, batch_axis=0):
    def loss(w, z0):
        ys, stats = odeint(_f, z0, TS, (w,), grad_method=method,
                           batch_axis=batch_axis, use_pallas=use_pallas,
                           **_kw(method))
        return jnp.sum(ys[-1] ** 2), (ys, stats)

    (_, (ys, stats)), (gw, gz) = jax.value_and_grad(
        loss, argnums=(0, 1), has_aux=True)(W, z0)
    return ys, stats, gw, gz


def _vmap_case(method, use_pallas, z0):
    def loss(w, z0):
        ys, stats = jax.vmap(
            lambda z: odeint(_f, z, TS, (w,), grad_method=method,
                             use_pallas=use_pallas, **_kw(method)),
            in_axes=0, out_axes=(1, 0))(z0)
        return jnp.sum(ys[-1] ** 2), (ys, stats)

    (_, (ys, stats)), (gw, gz) = jax.value_and_grad(
        loss, argnums=(0, 1), has_aux=True)(W, z0)
    return ys, stats, gw, gz


@pytest.mark.parametrize("method", GRAD_METHODS)
@pytest.mark.parametrize("use_pallas", [False, True])
def test_matches_vmap_of_solo(method, use_pallas, _interpret_kernels):
    """batch_axis=0 ≡ jax.vmap of the unbatched solver: same per-element
    grids, outputs and gradients to ≤1e-5 rel — for every grad method,
    with and without the fused kernels."""
    z0 = _hetero_batch()
    ys_b, st_b, gw_b, gz_b = _batched_case(method, use_pallas, z0)
    ys_s, st_s, gw_s, gz_s = _vmap_case(method, use_pallas, z0)

    np.testing.assert_array_equal(np.asarray(st_b.n_steps),
                                  np.asarray(st_s.n_steps))
    assert len(np.unique(np.asarray(st_b.n_steps))) > 1  # heterogeneous
    np.testing.assert_allclose(np.asarray(ys_b), np.asarray(ys_s),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(gz_b), np.asarray(gz_s),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(gw_b), np.asarray(gw_s),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("method", GRAD_METHODS)
def test_finished_elements_freeze_bit_stable(method):
    """Adding a stiff straggler to the batch keeps the easy elements'
    outputs AND stats bit-identical: once an element lands on its last
    ts[k] the masking freezes it completely."""
    if method == "mali":
        # ALF is non-dissipative (reversibility forbids damping: a
        # bijective map cannot contract), so very stiff rows pin its
        # stepsize at the atol floor — exercise the freezing contract
        # inside its effective stiffness range instead
        x0 = jax.random.normal(jax.random.PRNGKey(1), (3, 3))
        logk = jnp.array([0.0, 1.2, 1.6])
        z_more = jnp.concatenate([x0, logk[:, None]],
                                 axis=1).astype(jnp.float32)
        z_easy = z_more[:2]
    else:
        z_easy = _hetero_batch(B=2)
        stiff = jnp.concatenate([jnp.ones((1, 3)) * 0.5,
                                 jnp.full((1, 1), 4.2)], axis=1)
        z_more = jnp.concatenate([z_easy, stiff.astype(jnp.float32)],
                                 axis=0)

    ys2, st2 = odeint(_f, z_easy, TS, (W,), grad_method=method,
                      batch_axis=0, **_kw(method))
    ys3, st3 = odeint(_f, z_more, TS, (W,), grad_method=method,
                      batch_axis=0, **_kw(method))
    assert int(np.asarray(st3.n_steps)[2]) > int(
        np.asarray(st3.n_steps)[:2].max())
    np.testing.assert_array_equal(np.asarray(ys2), np.asarray(ys3)[:, :2])
    for a, b in zip(st2, st3):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b)[:2])


def test_batch_axis_nonzero():
    """batch_axis=1 is batch_axis=0 on the moved state, moved back; a
    negative batch_axis normalizes to the same thing (regression: the
    output restore used the raw negative axis and scrambled ys)."""
    z0 = _hetero_batch()
    ys0, st0 = odeint(_f, z0, TS, (W,), grad_method="aca", batch_axis=0,
                      **KW)
    for ba in (1, -1):
        ys1, st1 = odeint(_f, z0.T, TS, (W,), grad_method="aca",
                          batch_axis=ba, **KW)
        np.testing.assert_array_equal(np.asarray(ys0),
                                      np.asarray(jnp.swapaxes(ys1, 1, 2)))
        np.testing.assert_array_equal(np.asarray(st0.n_steps),
                                      np.asarray(st1.n_steps))


@pytest.mark.parametrize("method", GRAD_METHODS)
def test_fixed_grid_batched(method):
    """Fixed grids are shared exactly — batch_axis must equal vmap of the
    solo fixed-grid solve, with (B,)-broadcast stats."""
    if method == "mali":
        pytest.skip("the reversible pair integrator is adaptive-only "
                    "(no fixed-grid regime)")
    z0 = _hetero_batch(B=3)

    def loss_b(z0):
        ys, st = odeint(_f, z0, TS, (W,), solver="rk4", grad_method=method,
                        steps_per_interval=8, batch_axis=0)
        return jnp.sum(ys[-1] ** 2), (ys, st)

    def loss_s(z0):
        ys, _ = jax.vmap(
            lambda z: odeint(_f, z, TS, (W,), solver="rk4",
                             grad_method=method, steps_per_interval=8),
            in_axes=0, out_axes=(1, 0))(z0)
        return jnp.sum(ys[-1] ** 2), (ys, None)

    (_, (ys_b, st_b)), g_b = jax.value_and_grad(
        loss_b, has_aux=True)(z0)
    (_, (ys_s, _)), g_s = jax.value_and_grad(loss_s, has_aux=True)(z0)
    assert np.asarray(st_b.n_steps).shape == (3,)
    np.testing.assert_allclose(np.asarray(ys_b), np.asarray(ys_s),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(g_b), np.asarray(g_s),
                               rtol=1e-5, atol=1e-7)


def test_pytree_state_batched(_interpret_kernels):
    """Dict states batch too; the fused path ravels per sample into one
    (B, N) carry (maybe_flatten_batched)."""
    def f(t, z, w):
        return {"a": -1.5 * z["a"] + 0.1 * jnp.tanh(w * z["b"]),
                "b": -0.5 * z["b"]}

    z0 = {"a": jax.random.normal(jax.random.PRNGKey(0), (3, 4)),
          "b": jax.random.normal(jax.random.PRNGKey(1), (3, 4))}

    outs = {}
    for up in (False, True):
        def loss(w):
            ys, _ = odeint(f, z0, TS, (w,), grad_method="aca",
                           batch_axis=0, use_pallas=up, **KW)
            return sum(jnp.sum(v[-1] ** 2) for v in ys.values()), ys
        (_, ys), g = jax.value_and_grad(loss, has_aux=True)(W)
        outs[up] = (ys, g)
    for k in outs[False][0]:
        assert outs[False][0][k].shape == (TS.shape[0], 3, 4)
        # 1-ulp tolerance: the flat path computes the initial-stepsize
        # norm over one raveled leaf, the pytree path per leaf — a
        # different (legitimate) reduction order for multi-leaf states
        np.testing.assert_allclose(np.asarray(outs[False][0][k]),
                                   np.asarray(outs[True][0][k]),
                                   rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(outs[True][1]),
                               np.asarray(outs[False][1]),
                               rtol=1e-5, atol=1e-7)


def test_per_element_overflow():
    """max_steps exhaustion is per element: the stiff element overflows,
    the easy one still lands on its eval times."""
    z0 = jnp.stack([
        jnp.concatenate([jnp.ones((3,)) * 0.3, jnp.array([0.0])]),
        jnp.concatenate([jnp.ones((3,)) * 0.3, jnp.array([5.5])]),
    ]).astype(jnp.float32)
    _, stats = odeint(_f, z0, TS, (W,), grad_method="aca", batch_axis=0,
                      solver="dopri5", rtol=1e-7, atol=1e-7, max_steps=12)
    ov = np.asarray(stats.overflow)
    assert not ov[0] and ov[1], ov
