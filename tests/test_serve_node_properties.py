"""Hypothesis property: random request mixes (grad method × tolerance ×
horizon) served by the coalesced continuous-batching engine match the
one-shot vmap-of-solo reference within the documented chunked-parity
bound (docs/serving.md), and every request completes OK.

The reference is a single ``odeint(..., batch_axis=0)`` over each
request's *whole* horizon as one canonical chunk with its own row
tolerance — literally vmap-of-solo, compiled once for the padded
(MAX_REQ, DIM+2) shape.

Runs under the ``ci`` hypothesis profile (derandomized, no deadline —
examples jit/compile).  Skipped (not errored) when ``hypothesis`` is
absent from the image.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import odeint  # noqa: E402
from repro.core.integrate import SolveStatus  # noqa: E402
from repro.serve import (  # noqa: E402
    NodeEngineConfig,
    NodeRequest,
    NodeServeEngine,
    augment_field,
    augment_state,
)

from test_serve_node import ARGS, DIM, _parity_bound, _z0, field  # noqa: E402

MAX_REQ = 5
H_CHOICES = (0.4, 0.8, 1.3, 2.1)
TOL_CHOICES = (1e-3, 1e-4, 1e-5)


@pytest.fixture(scope="module")
def engines():
    return {
        "aca": NodeServeEngine(field, DIM, ARGS,
                               NodeEngineConfig(slots=4, chunk_dt=0.5)),
        "mali": NodeServeEngine(
            field, DIM, ARGS,
            NodeEngineConfig(slots=4, chunk_dt=0.5, grad_method="mali")),
    }


@pytest.fixture(scope="module")
def ref_solve():
    fa = augment_field(field)
    ts = jnp.asarray([0.0, 1.0], jnp.float32)

    @jax.jit
    def ref(Z, rt, at):
        ys, stats = odeint(fa, Z, ts, ARGS, rtol=rt, atol=at,
                           batch_axis=0, max_steps=256)
        return ys[-1], stats.status

    return ref


@settings(max_examples=8, deadline=None, derandomize=True,
          print_blob=True)
@given(data=st.data())
def test_random_request_mix_matches_vmap_of_solo(data, engines,
                                                 ref_solve):
    method = data.draw(st.sampled_from(["aca", "mali"]), label="method")
    n = data.draw(st.integers(1, MAX_REQ), label="n_requests")
    seeds = data.draw(st.lists(st.integers(0, 2 ** 16), min_size=n,
                               max_size=n), label="seeds")
    mix = data.draw(st.lists(
        st.tuples(st.sampled_from(TOL_CHOICES),
                  st.sampled_from(H_CHOICES)),
        min_size=n, max_size=n), label="tol_horizon")

    e = engines[method]
    e.reset()
    reqs = []
    for i, ((tol, horizon), seed) in enumerate(zip(mix, seeds)):
        req = NodeRequest(z0=_z0(seed), t1=horizon, rtol=tol,
                          atol=tol * 1e-2)
        reqs.append(req)
        e.submit(req, arrival=0.3 * i)
    results = {r.req_id: r for r in e.run()}
    assert all(r.ok for r in results.values())

    Z = np.zeros((MAX_REQ, DIM + 2), np.float32)
    rt = np.full((MAX_REQ,), 1e-3, np.float32)
    at = np.full((MAX_REQ,), 1e-3, np.float32)
    for i, req in enumerate(reqs):
        Z[i] = np.asarray(augment_state(jnp.asarray(req.z0), req.t0,
                                        req.t1 - req.t0))
        rt[i], at[i] = req.rtol, req.atol
    ref, status = ref_solve(jnp.asarray(Z), jnp.asarray(rt),
                            jnp.asarray(at))
    ref = np.asarray(ref)
    assert (np.asarray(status)[:len(reqs)] == SolveStatus.OK).all()
    for i, req in enumerate(reqs):
        err = np.abs(results[i].z_final - ref[i, :DIM]).max()
        assert err <= _parity_bound(results[i], req, ref[i, :DIM]), (
            i, req.rtol, req.t1, err)
