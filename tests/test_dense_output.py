"""Dense output: ``interpolate_ts`` natural-grid solving and
``odeint_dense`` / ``DenseSolution``.

Coverage matrix per the acceptance gate: gradcheck + compatibility for
``interpolate_ts`` across {aca, adjoint, naive} × {pytree, pallas} ×
{solo, batched}, plus the step-count reduction it exists for.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GRAD_METHODS, odeint, odeint_dense
from repro.core.stepper import interp_eval, interp_fit, rk_step
from repro.core.tableaus import BOGACKI_SHAMPINE, DOPRI5
from repro.data import merged_time_grid


@pytest.fixture
def _interpret_kernels():
    from repro.kernels import ops
    ops.set_interpret(True)
    yield
    ops.set_interpret(None)


# ----------------------------------------------------- interpolant unit

def test_dopri5_b_mid_consistency():
    assert DOPRI5.b_mid is not None
    assert abs(sum(DOPRI5.b_mid) - 0.5) < 1e-12
    DOPRI5.validate()


@pytest.mark.parametrize("tab", [DOPRI5, BOGACKI_SHAMPINE])
def test_interpolant_tracks_solution(tab):
    """P(0) is z0 bitwise; P(θ) tracks the true solution to O(h⁴) on
    one step of dz/dt = -z."""
    f = lambda t, z: -z
    z0 = jnp.ones((3,))
    h = 0.25
    res = rk_step(tab, f, 0.0, z0, h, dense=True)
    k1 = res.k_last if tab.fsal else f(h, res.z_next)
    co = interp_fit(z0, res.z_next, res.k_first, k1, h, res.z_mid)
    th = jnp.linspace(0.0, 1.0, 11)
    vals = np.asarray(interp_eval(co, th))
    exact = np.exp(-h * np.asarray(th))[:, None] * np.ones(3)
    np.testing.assert_array_equal(vals[0], np.asarray(z0))
    # bound includes the step's own local error (bosh3 is order 3, so
    # z_next itself sits ~1e-4 off at h = 0.25), not just interp error
    assert np.abs(vals - exact).max() < 1e-3 * h


# ----------------------------------------- natural grid: fewer steps

def test_interpolate_ts_cuts_trials_on_dense_grid():
    """The headline effect: 33 eval points no longer force 33 landings."""
    ts = jnp.linspace(0.0, 3.0, 33)
    kw = dict(solver="dopri5", grad_method="aca", rtol=1e-6, atol=1e-6)
    ys0, st0 = odeint(lambda t, z: -0.7 * z, jnp.float32(2.0), ts, **kw)
    ys1, st1 = odeint(lambda t, z: -0.7 * z, jnp.float32(2.0), ts,
                      interpolate_ts=True, **kw)
    assert int(st0.n_trials) >= 2 * int(st1.n_trials)
    exact = 2.0 * np.exp(-0.7 * np.asarray(ts))
    np.testing.assert_allclose(np.asarray(ys1), exact, atol=2e-5)
    # endpoints stay exact solver states
    assert float(ys1[0]) == 2.0


# --------------------------------------------------------- gradients

@pytest.mark.parametrize("method", GRAD_METHODS)
def test_interpolated_multi_time_gradient_analytic(method):
    """Cotangents of interpolated outputs flow correctly: dL/dz0 of
    L = Σ_k z(t_k)² matches 2 z0 Σ e^{2 t_k} under every method."""
    if method == "mali":
        pytest.skip("interpolate_ts is not supported under mali "
                    "(odeint raises; see docs/method-selection.md)")
    ts = jnp.linspace(0.0, 1.0, 9)

    def loss(z0):
        ys, _ = odeint(lambda t, z, k: k * z, z0, ts, (jnp.float32(1.0),),
                       solver="dopri5", grad_method=method, rtol=1e-7,
                       atol=1e-7, interpolate_ts=True)
        return jnp.sum(ys ** 2)

    z0 = jnp.float32(0.7)
    g = float(jax.grad(loss)(z0))
    analytic = 2 * 0.7 * float(np.sum(np.exp(2 * np.asarray(ts))))
    assert abs(g - analytic) / analytic < 1e-3, (method, g, analytic)


def _interp_case(method, use_pallas, batched, interpolate, **kw):
    def f(t, z, w):
        return jnp.tanh(w @ z)

    w = jax.random.normal(jax.random.PRNGKey(0), (6, 6)) * 0.4
    z0 = jax.random.normal(jax.random.PRNGKey(1), (6,))
    if batched:
        z0 = jnp.stack([z0, 2.0 * z0, -0.7 * z0])
        kw["batch_axis"] = 0
    ts = jnp.linspace(0.0, 1.0, 9)

    def loss(w):
        ys, stats = odeint(f, z0, ts, (w,), solver="dopri5",
                           grad_method=method, rtol=1e-5, atol=1e-5,
                           max_steps=64, use_pallas=use_pallas,
                           interpolate_ts=interpolate, **kw)
        return jnp.sum(ys ** 2), (ys, stats)

    (_, (ys, stats)), g = jax.value_and_grad(loss, has_aux=True)(w)
    return np.asarray(ys), np.asarray(g), stats


@pytest.mark.parametrize("method", GRAD_METHODS)
@pytest.mark.parametrize("batched", [False, True])
def test_interpolated_close_to_landed(method, batched):
    """Interpolated outputs sit within tolerance-scale distance of the
    forced-landing solve, and gradients agree to matching precision."""
    if method == "mali":
        pytest.skip("interpolate_ts is not supported under mali "
                    "(odeint raises; see docs/method-selection.md)")
    ys0, g0, st0 = _interp_case(method, False, batched, False)
    ys1, g1, st1 = _interp_case(method, False, batched, True)
    np.testing.assert_allclose(ys1, ys0, atol=5e-4)
    scale = max(np.abs(g0).max(), 1e-12)
    assert np.abs(g1 - g0).max() / scale < 5e-3, method
    # and it genuinely takes fewer accepted steps
    assert int(np.asarray(st1.n_steps).sum()) < \
        int(np.asarray(st0.n_steps).sum())


@pytest.mark.parametrize("method", GRAD_METHODS)
@pytest.mark.parametrize("batched", [False, True])
def test_interpolate_pallas_parity(method, batched, _interpret_kernels):
    """Pallas vs pytree under interpolate_ts: identical accepted grids,
    bit-equal endpoint states; interior interpolant reads may differ by
    a few ulp of the coefficient scale (XLA fuses the polynomial-eval
    chains differently per program), gradients to ≤1e-5 rel."""
    if method == "mali":
        pytest.skip("interpolate_ts is not supported under mali "
                    "(odeint raises; see docs/method-selection.md)")
    if jax.config.jax_enable_x64:
        pytest.skip("pallas kernels are f32; x64 pytree math diverges "
                    "by design (same policy as the grad-suite parity "
                    "tests)")
    ys0, g0, st0 = _interp_case(method, False, batched, True)
    ys1, g1, st1 = _interp_case(method, True, batched, True)
    np.testing.assert_array_equal(np.asarray(st0.n_steps),
                                  np.asarray(st1.n_steps))
    np.testing.assert_array_equal(ys0[0], ys1[0])
    np.testing.assert_array_equal(ys0[-1], ys1[-1])
    np.testing.assert_allclose(ys1, ys0, atol=2e-5)
    scale = max(np.abs(g0).max(), 1e-12)
    assert np.abs(g1 - g0).max() / scale < 1e-5, method


def test_interpolate_batched_matches_vmap_of_solo():
    """batch_axis + interpolate_ts keeps the vmap-equivalence contract."""
    def f(t, z, w):
        return jnp.tanh(w @ z)

    w = jax.random.normal(jax.random.PRNGKey(0), (6, 6)) * 0.4
    z0 = jax.random.normal(jax.random.PRNGKey(1), (6,))
    z0b = jnp.stack([z0, 2.0 * z0, -0.7 * z0])
    ts = jnp.linspace(0.0, 1.0, 9)
    kw = dict(solver="dopri5", grad_method="aca", rtol=1e-5, atol=1e-5,
              max_steps=64, interpolate_ts=True)

    ys_b, st_b = odeint(f, z0b, ts, (w,), batch_axis=0, **kw)
    ys_v, st_v = jax.vmap(
        lambda z: odeint(f, z, ts, (w,), **kw), out_axes=(1, 0))(z0b)
    np.testing.assert_array_equal(np.asarray(st_b.n_steps),
                                  np.asarray(st_v.n_steps))
    np.testing.assert_allclose(np.asarray(ys_b), np.asarray(ys_v),
                               atol=1e-6)


def test_interpolate_composes_with_segmented_aca():
    """checkpoint_segments + interpolate_ts: the segmented sweep replays
    interval + interpolant from re-integrated states — gradients match
    the full-buffer sweep."""
    def f(t, z, w):
        return jnp.tanh(w @ z)

    w = jax.random.normal(jax.random.PRNGKey(0), (6, 6)) * 0.4
    z0 = jax.random.normal(jax.random.PRNGKey(1), (6,))
    ts = jnp.linspace(0.0, 2.0, 17)

    def g_of(segs, batched):
        zz = jnp.stack([z0, 1.3 * z0]) if batched else z0
        def loss(w):
            ys, _ = odeint(f, zz, ts, (w,), solver="dopri5",
                           grad_method="aca", rtol=1e-6, atol=1e-6,
                           max_steps=64, interpolate_ts=True,
                           checkpoint_segments=segs,
                           batch_axis=0 if batched else None)
            return jnp.sum(ys ** 2)
        return np.asarray(jax.grad(loss)(w))

    for batched in (False, True):
        g_full = g_of(None, batched)
        g_seg = g_of(4, batched)
        np.testing.assert_allclose(g_seg, g_full, rtol=1e-6, atol=1e-8)


# ------------------------------------------------------- odeint_dense

def test_dense_solution_accuracy_and_knots():
    sol, stats = odeint_dense(lambda t, z, k: k * z, jnp.array([2.0]),
                              0.0, 3.0, (jnp.float32(-0.8),),
                              rtol=1e-7, atol=1e-7)
    assert not bool(stats.overflow)
    tq = jnp.linspace(0.0, 3.0, 64)
    vals = np.asarray(sol.evaluate(tq))[:, 0]
    exact = 2.0 * np.exp(-0.8 * np.asarray(tq))
    np.testing.assert_allclose(vals, exact, atol=1e-5)
    # t0 evaluation is the stored step-start state bitwise (P(0) = z0)
    assert float(sol.evaluate(jnp.float32(0.0))[0]) == 2.0


def test_dense_solution_reverse_time():
    sol, stats = odeint_dense(lambda t, z, k: k * z, jnp.array([2.0]),
                              3.0, 0.0, (jnp.float32(-0.8),),
                              rtol=1e-7, atol=1e-7)
    assert not bool(stats.overflow)
    tq = jnp.linspace(3.0, 0.0, 16)
    vals = np.asarray(sol.evaluate(tq))[:, 0]
    # the solution GROWS backwards to 2·e^2.4 ≈ 22: relative tolerance
    exact = 2.0 * np.exp(-0.8 * (np.asarray(tq) - 3.0))
    np.testing.assert_allclose(vals, exact, rtol=1e-5, atol=1e-5)


def test_dense_solution_shapes_and_jit():
    sol, _ = odeint_dense(lambda t, z: -z, jnp.ones((4,)), 0.0, 1.0,
                          rtol=1e-6, atol=1e-6)
    assert np.asarray(sol.evaluate(0.5)).shape == (4,)
    assert np.asarray(sol.evaluate(jnp.zeros((3, 2)))).shape == (3, 2, 4)
    # DenseSolution is a pytree: evaluate jits/vmaps freely
    v = jax.jit(lambda s, t: s.evaluate(t))(sol, jnp.float32(0.25))
    np.testing.assert_allclose(np.asarray(v),
                               np.asarray(sol.evaluate(0.25)))


def test_dense_rejects_fixed_solver():
    with pytest.raises(ValueError, match="adaptive"):
        odeint_dense(lambda t, z: -z, jnp.ones(2), 0.0, 1.0, solver="rk4")


def test_dense_overflow_flagged():
    _, stats = odeint_dense(lambda t, z: 50 * jnp.cos(50 * t) * z,
                            jnp.float32(1.0), 0.0, 10.0,
                            rtol=1e-9, atol=1e-9, max_steps=4)
    assert bool(stats.overflow)


# ------------------------------------------------- merged irregular grid

def test_merged_time_grid_roundtrip():
    ts = jnp.asarray([[0.0, 0.5, 1.0], [0.0, 0.25, 1.0]])
    grid = merged_time_grid(ts)
    tu, idx = np.asarray(grid["t_union"]), np.asarray(grid["idx"])
    assert (np.diff(tu) > 0).all()          # strictly increasing: odeint-legal
    np.testing.assert_array_equal(tu[idx], np.asarray(ts))
