"""Pallas kernel sweeps: shapes × dtypes vs the ref.py pure-jnp oracles.

All kernels run in interpret mode (the kernel body executes in Python on
CPU); on a TPU backend the same calls compile natively.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.tableaus import BOGACKI_SHAMPINE, DOPRI5, HEUN_EULER, RK4
from repro.kernels import ops, ref


@pytest.fixture(autouse=True)
def _force_interpret():
    ops.set_interpret(True)
    yield
    ops.set_interpret(None)


# ----------------------------------------------------------------- rk_stage
@pytest.mark.parametrize("tab", [HEUN_EULER, BOGACKI_SHAMPINE, DOPRI5, RK4])
@pytest.mark.parametrize("n", [37, 1000, 5000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rk_stage_combine(tab, n, dtype):
    key = jax.random.PRNGKey(n)
    z = jax.random.normal(key, (n,)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(n + 1),
                          (tab.stages, n)).astype(dtype)
    h = jnp.float32(0.05)
    o1, e1 = ops.rk_stage_combine(z, k, h, tab.b, tab.b_err, block=512)
    o2, e2 = ref.rk_stage_combine_ref(z, k, h, tab.b, tab.b_err)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("tab", [BOGACKI_SHAMPINE, DOPRI5])
@pytest.mark.parametrize("stage", [1, 2, 3])
@pytest.mark.parametrize("n", [37, 1000])
def test_rk_stage_increment(tab, stage, n):
    key = jax.random.PRNGKey(n + stage)
    z = jax.random.normal(key, (n,))
    k = jax.random.normal(jax.random.PRNGKey(n), (stage, n))
    h = jnp.float32(0.03)
    o1 = ops.rk_stage_increment(z, k, h, tab.a[stage], block=512)
    o2 = ref.rk_stage_increment_ref(z, k, h, tab.a[stage])
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("tab", [HEUN_EULER, BOGACKI_SHAMPINE, DOPRI5])
@pytest.mark.parametrize("n", [37, 1000, 5000])
def test_rk_stage_combine_err_partial_norm(tab, n):
    """The extended combine kernel's per-tile partial sums must total the
    oracle's full-array scaled error norm (and padding lanes must
    contribute exactly zero)."""
    rtol, atol = 1e-3, 1e-4
    z = jax.random.normal(jax.random.PRNGKey(n), (n,))
    k = jax.random.normal(jax.random.PRNGKey(n + 1), (tab.stages, n))
    h = jnp.float32(0.05)
    o1, e1, sq1 = ops.rk_stage_combine_err(z, k, h, tab.b, tab.b_err,
                                           rtol, atol, block=512)
    o2, e2, sq2 = ref.rk_stage_combine_err_ref(z, k, h, tab.b, tab.b_err,
                                               rtol, atol)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(float(sq1), float(sq2), rtol=1e-5)
    # solver-loop variant: err store skipped, z_next/norm unchanged
    o3, e3, sq3 = ops.rk_stage_combine_err(z, k, h, tab.b, tab.b_err,
                                           rtol, atol, with_err=False,
                                           block=512)
    assert e3 is None
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o3))
    np.testing.assert_array_equal(float(sq1), float(sq3))


def test_rk_ops_differentiable():
    """The kernel wrappers carry a custom_vjp (pallas_call itself has no
    transpose rule) whose backward must match AD through the oracle."""
    tab = DOPRI5
    n = 300
    z = jax.random.normal(jax.random.PRNGKey(0), (n,))
    k = jax.random.normal(jax.random.PRNGKey(1), (tab.stages, n))
    h = jnp.float32(0.07)

    def loss_op(z, k, h):
        zn, err, sq = ops.rk_stage_combine_err(
            z, k, h, tab.b, tab.b_err, 1e-3, 1e-4, block=128)
        return jnp.sum(zn ** 2) + jnp.sum(err ** 2) + sq

    def loss_ref(z, k, h):
        zn, err, sq = ref.rk_stage_combine_err_ref(
            z, k, h, tab.b, tab.b_err, 1e-3, 1e-4)
        return jnp.sum(zn ** 2) + jnp.sum(err ** 2) + sq

    g1 = jax.grad(loss_op, argnums=(0, 1, 2))(z, k, h)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(z, k, h)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)

    gi1 = jax.grad(lambda z: jnp.sum(
        ops.rk_stage_increment(z, k[:3], h, tab.a[3], block=128) ** 2))(z)
    gi2 = jax.grad(lambda z: jnp.sum(
        ref.rk_stage_increment_ref(z, k[:3], h, tab.a[3]) ** 2))(z)
    np.testing.assert_allclose(np.asarray(gi1), np.asarray(gi2),
                               rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------------ rmsnorm
@pytest.mark.parametrize("shape", [(4, 64), (3, 17, 128), (2, 5, 7, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(0), shape) * 3).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (shape[-1],))
    r1 = ops.rmsnorm(x, w, rows=8)
    r2 = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(r1, np.float32),
                               np.asarray(r2, np.float32),
                               rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------- flash attention
@pytest.mark.parametrize("hkv", [1, 2, 4])
@pytest.mark.parametrize("s,bq,bk", [(128, 64, 64), (256, 64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(hkv, s, bq, bk, dtype):
    B, H, D = 2, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = (jax.random.normal(ks[0], (B, H, s, D)) * 0.4).astype(dtype)
    k = (jax.random.normal(ks[1], (B, hkv, s, D)) * 0.4).astype(dtype)
    v = (jax.random.normal(ks[2], (B, hkv, s, D)) * 0.4).astype(dtype)
    out = ops.flash_attention(q, k, v, block_q=bq, block_k=bk)
    want = ref.flash_attention_ref(q, k, v)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [64, 128])
def test_flash_attention_windowed(window):
    B, H, HKV, S, D = 1, 2, 1, 256, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, S, D)) * 0.4
    k = jax.random.normal(ks[1], (B, HKV, S, D)) * 0.4
    v = jax.random.normal(ks[2], (B, HKV, S, D)) * 0.4
    out = ops.flash_attention(q, k, v, window=window, block_q=64,
                              block_k=64)
    want = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------- ssd scan
@pytest.mark.parametrize("s,chunk", [(64, 16), (128, 32)])
@pytest.mark.parametrize("g", [1, 2])
def test_ssd_scan(s, chunk, g):
    B, H, P, N = 2, 4, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (B, s, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, s, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)))
    bm = jax.random.normal(ks[3], (B, s, g, N)) * 0.5
    cm = jax.random.normal(ks[4], (B, s, g, N)) * 0.5
    out = ops.ssd_scan(x, dt, a, bm, cm, chunk)
    want = ref.ssd_scan_ref(x, dt, a, bm, cm, chunk)
    seq = ref.ssd_scan_sequential_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # the chunked algorithm itself equals the O(S) sequential SSM
    np.testing.assert_allclose(np.asarray(want), np.asarray(seq),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------- rg_lru
@pytest.mark.parametrize("s,chunk", [(64, 16), (128, 64)])
@pytest.mark.parametrize("c,ct", [(32, 32), (64, 32)])
def test_rg_lru(s, chunk, c, ct):
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    log_a = -jax.nn.softplus(jax.random.normal(ks[0], (2, s, c)))
    b = jax.random.normal(ks[1], (2, s, c))
    out = ops.rg_lru(log_a, b, chunk=chunk, c_tile=ct)
    want = ref.rg_lru_ref(log_a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_rg_lru_strong_decay_stability():
    """The log-clamped closed form must not produce inf/nan under decay
    strong enough to underflow the naive cumprod."""
    s, c = 256, 16
    log_a = jnp.full((1, s, c), -2.0)     # a = e^-2: cumprod -> e^-512
    b = jnp.ones((1, s, c))
    out = ops.rg_lru(log_a, b, chunk=64, c_tile=16)
    want = ref.rg_lru_ref(log_a, b)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
