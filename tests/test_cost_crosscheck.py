"""The analyzer's static residual-bytes model and ``launch/hlo_cost``'s
measured ``bytes_min`` must agree *directionally* on the benched configs:
segmentation shrinks ACA residual memory, and MALI sits below full-buffer
ACA regardless of step count.  (Absolute numbers differ by design — the
static model counts only custom_vjp residuals, the HLO model counts whole
live buffers — but if the orderings ever disagree, one of the two cost
models has rotted.)"""

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import SolveConfig, static_residual_bytes
from repro.core.api import odeint
from repro.launch.hlo_cost import analyze_hlo

DIM, N_STEPS, K = 32, 64, 8

CONFIGS = {
    "aca-full": SolveConfig("x-aca-full", "aca", dim=DIM, max_steps=N_STEPS),
    "aca-seg": SolveConfig("x-aca-seg", "aca", dim=DIM, max_steps=N_STEPS,
                           segmented=True, segments=K),
    "mali": SolveConfig("x-mali", "mali", dim=DIM, max_steps=N_STEPS),
}


def _measured_bytes(cfg: SolveConfig) -> int:
    """The benches' metric: residual-driven min live bytes of the lowered
    value_and_grad, measured on the compiled HLO."""
    kw = cfg.odeint_kwargs()

    def loss(z0, w):
        ys, _ = odeint(lambda t, z, w: -(w * z), z0,
                       jnp.linspace(0.0, 1.0, cfg.n_eval), (w,), **kw)
        return jnp.sum(ys)

    z0 = jnp.ones((cfg.dim,), jnp.float32)
    w = jnp.ones((cfg.dim,), jnp.float32)
    g = jax.jit(jax.value_and_grad(loss, argnums=(0, 1))).lower(z0, w).compile()
    return int(analyze_hlo(g.as_text()).bytes_min)


@pytest.fixture(scope="module")
def costs():
    static = {k: static_residual_bytes(c) for k, c in CONFIGS.items()}
    measured = {k: _measured_bytes(c) for k, c in CONFIGS.items()}
    return static, measured


def test_static_model_orders_the_methods(costs):
    static, _ = costs
    assert static["aca-full"] > static["aca-seg"] > static["mali"] > 0, static


def test_measured_model_orders_the_methods(costs):
    _, measured = costs
    assert measured["aca-full"] > measured["aca-seg"], measured
    assert measured["aca-full"] > measured["mali"], measured


def test_static_and_measured_agree_directionally(costs):
    static, measured = costs
    pairs = [("aca-full", "aca-seg"), ("aca-full", "mali")]
    for hi, lo in pairs:
        s_dir = static[hi] - static[lo]
        m_dir = measured[hi] - measured[lo]
        assert s_dir > 0 and m_dir > 0, (
            f"cost models diverged on {hi} vs {lo}: "
            f"static delta {s_dir}, measured delta {m_dir}")
