"""Gradient correctness for ACA / adjoint / naive (paper Sec. 3, Fig. 6).

The toy problem dz/dt = k·z, L = z(T)² has the analytic gradient
dL/dz0 = 2 z0 e^{2kT} (paper Eq. 27–29); all methods must match it at
tight tolerance, and ACA must match the *naive* method (both are
discretize-then-optimize of the same trajectory) to much tighter
precision than either matches the adjoint (which re-integrates).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GRAD_METHODS, odeint, odeint_final

K, T = 2.0, 1.0


def _toy_grad(method, solver="dopri5", **kw):
    if method == "mali":
        solver = None  # the ALF pair integrator; no RK tableau
    def loss(z0):
        ys, _ = odeint(lambda t, z, k: k * z, z0, jnp.array([0.0, T]),
                       (jnp.float32(K),), solver=solver,
                       grad_method=method, **kw)
        return (ys[-1] ** 2).sum()

    z0 = jnp.float32(1.5)
    g = jax.grad(loss)(z0)
    analytic = 2 * 1.5 * np.exp(2 * K * T)
    return float(g), analytic


@pytest.mark.parametrize("method", GRAD_METHODS)
def test_toy_gradient_matches_analytic(method):
    # mali's 2nd-order pair stepper needs a larger accepted-step budget
    # at this tolerance (1st-order embedded estimate)
    kw = dict(max_steps=8192) if method == "mali" else {}
    g, analytic = _toy_grad(method, rtol=1e-6, atol=1e-6, **kw)
    assert abs(g - analytic) / analytic < 1e-4, (method, g, analytic)


@pytest.mark.parametrize("method", GRAD_METHODS)
@pytest.mark.parametrize("solver", ["euler", "rk2", "rk4"])
def test_fixed_grid_gradient(method, solver):
    if method == "mali":
        pytest.skip("the reversible pair integrator is adaptive-only")
    g, analytic = _toy_grad(method, solver=solver, steps_per_interval=64)
    tol = 0.2 if solver == "euler" else 5e-3
    assert abs(g - analytic) / analytic < tol, (method, solver, g)


def test_aca_equals_naive_discretize_then_optimize():
    """On the same fixed grid, ACA and naive differentiate the *same*
    discrete solution — gradients agree to fp tolerance."""
    def f(t, z, w):
        return jnp.tanh(w @ z)

    w = jax.random.normal(jax.random.PRNGKey(0), (6, 6)) * 0.4
    z0 = jax.random.normal(jax.random.PRNGKey(1), (6,))

    def loss(w, method):
        ys, _ = odeint(f, z0, jnp.array([0.0, 1.0]), (w,), solver="rk4",
                       grad_method=method, steps_per_interval=16)
        return jnp.sum(ys[-1] ** 2)

    g_aca = jax.grad(lambda w: loss(w, "aca"))(w)
    g_naive = jax.grad(lambda w: loss(w, "naive"))(w)
    np.testing.assert_allclose(np.asarray(g_aca), np.asarray(g_naive),
                               rtol=2e-4, atol=2e-6)


def test_adjoint_reverse_error_vs_aca_stiff():
    """Paper Sec 3.2 (van der Pol): the adjoint's reverse-time
    re-integration drifts on stiff dynamics.  Ground truth = ACA at a
    10⁴× tighter tolerance (discretize-then-optimize converges to the
    true gradient); at the loose tolerance ACA must beat the adjoint."""
    mu = 4.0

    def vdp(t, z, mu):
        return jnp.stack([z[1], mu * (1 - z[0] ** 2) * z[1] - z[0]])

    z0 = jnp.array([2.0, 0.0])

    def loss(z0, method, tol):
        ys, _ = odeint(vdp, z0, jnp.array([0.0, 3.0]), (jnp.float32(mu),),
                       solver="dopri5", grad_method=method,
                       rtol=tol, atol=tol, max_steps=4096,
                       max_trials=20)
        return jnp.sum(ys[-1] ** 2)

    g_ref = jax.grad(lambda z: loss(z, "aca", 1e-8))(z0)
    g_aca = jax.grad(lambda z: loss(z, "aca", 1e-4))(z0)
    g_adj = jax.grad(lambda z: loss(z, "adjoint", 1e-4))(z0)

    err_adj = float(jnp.abs(g_adj - g_ref).max())
    err_aca = float(jnp.abs(g_aca - g_ref).max())
    assert err_aca < err_adj, (err_aca, err_adj)


def test_pytree_state_and_param_grads():
    def f(t, z, w):
        return {"a": jnp.tanh(w @ z["b"]), "b": jnp.tanh(w @ z["a"])}

    w = jax.random.normal(jax.random.PRNGKey(0), (4, 4)) * 0.3
    z0 = {"a": jnp.ones((4,)), "b": jnp.zeros((4,))}

    grads = {}
    for m in GRAD_METHODS:
        def loss(w):
            ys, _ = odeint(f, z0, jnp.array([0.0, 1.0]), (w,),
                           solver=None if m == "mali" else "heun_euler",
                           grad_method=m, rtol=1e-5, atol=1e-5,
                           max_steps=2048 if m == "mali" else 256)
            return sum(jnp.sum(v[-1] ** 2) for v in ys.values())
        grads[m] = jax.grad(loss)(w)
    np.testing.assert_allclose(grads["aca"], grads["naive"],
                               rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(grads["aca"], grads["adjoint"],
                               rtol=2e-2, atol=1e-3)
    # mali differentiates its own (ALF) discretization: agreement at
    # solve-tolerance scale, like the adjoint comparison
    np.testing.assert_allclose(grads["aca"], grads["mali"],
                               rtol=2e-2, atol=1e-3)


def test_multi_time_outputs_latent_ode_style():
    """Cotangents injected at every eval time (latent-ODE use case)."""
    ts = jnp.array([0.0, 0.3, 0.7, 1.0])

    def f(t, z, k):
        return k * z

    def loss(z0, method):
        mali = method == "mali"
        ys, _ = odeint(f, z0, ts, (jnp.float32(1.0),),
                       solver=None if mali else "dopri5",
                       grad_method=method,
                       rtol=1e-6 if mali else 1e-7,
                       atol=1e-6 if mali else 1e-7,
                       max_steps=8192 if mali else 256)
        return jnp.sum(ys ** 2)

    # analytic: sum_i z0^2 e^{2 t_i}; d/dz0 = 2 z0 sum e^{2 t_i}
    z0 = jnp.float32(0.7)
    analytic = 2 * 0.7 * float(np.sum(np.exp(2 * np.asarray(ts))))
    for m in GRAD_METHODS:
        g = float(jax.grad(lambda z: loss(z, m))(z0))
        assert abs(g - analytic) / analytic < 1e-3, (m, g, analytic)


def test_grad_methods_inside_scan():
    """NODE blocks live inside lax.scan over layers; the custom_vjp
    plumbing must not leak tracers (regression test)."""
    def f(t, z, p):
        return jnp.tanh(z @ p)

    P = jax.random.normal(jax.random.PRNGKey(0), (3, 4, 4)) * 0.1
    z0 = jax.random.normal(jax.random.PRNGKey(1), (4,))

    for m in GRAD_METHODS:
        if m == "mali":
            cases = [(None, dict(rtol=1e-3, atol=1e-3, max_steps=64))]
        else:
            cases = [("rk2", dict(steps_per_interval=2)),
                     ("heun_euler",
                      dict(rtol=1e-3, atol=1e-3, max_steps=32))]
        for solver, kw in cases:
            def block(z, p):
                zT, _ = odeint_final(f, z, 0.0, 1.0, (p,), solver=solver,
                                     grad_method=m, **kw)
                return zT, None

            def loss(P):
                z, _ = jax.lax.scan(block, z0, P)
                return (z ** 2).sum()

            g = jax.grad(loss)(P)
            assert jnp.isfinite(g).all(), (m, solver)


# ------------------------------------------------- fused flat-state path

@pytest.fixture
def _interpret_kernels():
    from repro.kernels import ops
    ops.set_interpret(True)
    yield
    ops.set_interpret(None)


def _parity_case(method, solver, use_pallas, **kw):
    def f(t, z, w):
        return jnp.tanh(w @ z)

    w = jax.random.normal(jax.random.PRNGKey(0), (8, 8)) * 0.4
    z0 = jax.random.normal(jax.random.PRNGKey(1), (8,))

    def loss(w):
        ys, _ = odeint(f, z0, jnp.array([0.0, 0.5, 1.0]), (w,),
                       solver=solver, grad_method=method,
                       use_pallas=use_pallas, **kw)
        return jnp.sum(ys[-1] ** 2), ys

    (_, ys), g = jax.value_and_grad(loss, has_aux=True)(w)
    return np.asarray(ys), np.asarray(g)


@pytest.mark.parametrize("method", GRAD_METHODS)
@pytest.mark.parametrize("solver", ["heun_euler", "bosh3", "dopri5"])
def test_pallas_parity_adaptive(method, solver, _interpret_kernels):
    """The fused flat-state path (interpret mode) must reproduce the
    pytree path bit-for-bit on the forward trajectory — same accepted
    grid, same accept/reject decisions — and match its gradients."""
    kw = dict(rtol=1e-5, atol=1e-5, max_steps=64)
    if method == "mali":
        if solver != "dopri5":
            pytest.skip("mali has no RK tableau — one parity case "
                        "suffices")
        solver = None
        kw["max_steps"] = 2048  # 2nd-order pair stepper at 1e-5
    ys0, g0 = _parity_case(method, solver, False, **kw)
    ys1, g1 = _parity_case(method, solver, True, **kw)
    np.testing.assert_array_equal(ys0, ys1)
    np.testing.assert_allclose(g1, g0, rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("method", GRAD_METHODS)
@pytest.mark.parametrize("solver", ["rk4", "rk2"])
def test_pallas_parity_fixed_grid(method, solver, _interpret_kernels):
    if method == "mali":
        pytest.skip("the reversible pair integrator is adaptive-only")
    kw = dict(steps_per_interval=8)
    ys0, g0 = _parity_case(method, solver, False, **kw)
    ys1, g1 = _parity_case(method, solver, True, **kw)
    np.testing.assert_array_equal(ys0, ys1)
    np.testing.assert_allclose(g1, g0, rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("method", GRAD_METHODS)
def test_pallas_parity_pytree_state(method, _interpret_kernels):
    """Multi-leaf states go through the per-solve ravel adapter: one
    ravel_pytree per solve, flat (N,) carry inside."""
    def f(t, z, w):
        return {"a": jnp.tanh(w @ z["b"]), "b": jnp.tanh(w @ z["a"])}

    z0 = {"a": jnp.ones((4,)), "b": jnp.zeros((4,))}
    w = jax.random.normal(jax.random.PRNGKey(0), (4, 4)) * 0.3

    def loss(w, up):
        mali = method == "mali"
        ys, _ = odeint(f, z0, jnp.array([0.0, 1.0]), (w,),
                       solver=None if mali else "dopri5",
                       grad_method=method, rtol=1e-5, atol=1e-5,
                       max_steps=2048 if mali else 256, use_pallas=up)
        return sum(jnp.sum(v[-1] ** 2) for v in ys.values()), ys

    (_, ys0), g0 = jax.value_and_grad(lambda w: loss(w, False),
                                      has_aux=True)(w)
    (_, ys1), g1 = jax.value_and_grad(lambda w: loss(w, True),
                                      has_aux=True)(w)
    for k in ys0:
        if method == "mali":
            # the lattice quantize runs on differently-shaped arrays
            # (per-leaf vs raveled) whose XLA fusion may differ by an
            # ulp -> a few quanta, not bitwise, across the ravel
            # boundary (each path is individually bit-reversible)
            np.testing.assert_allclose(np.asarray(ys0[k]),
                                       np.asarray(ys1[k]),
                                       rtol=0, atol=1e-6)
        else:
            np.testing.assert_array_equal(np.asarray(ys0[k]),
                                          np.asarray(ys1[k]))
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g0),
                               rtol=1e-5, atol=1e-7)


def test_pallas_path_actually_dispatches(monkeypatch, _interpret_kernels):
    """use_pallas=True must hit the fused kernels (not silently fall
    back): count the dispatch-layer calls during an adaptive solve."""
    from repro.kernels import ops

    calls = {"combine_err": 0, "increment": 0}
    orig_ce, orig_inc = ops.rk_stage_combine_err, ops.rk_stage_increment
    monkeypatch.setattr(
        ops, "rk_stage_combine_err",
        lambda *a, **k: (calls.__setitem__(
            "combine_err", calls["combine_err"] + 1) or orig_ce(*a, **k)))
    monkeypatch.setattr(
        ops, "rk_stage_increment",
        lambda *a, **k: (calls.__setitem__(
            "increment", calls["increment"] + 1) or orig_inc(*a, **k)))

    ys, _ = odeint(lambda t, z: -z, jnp.ones((4,)), jnp.array([0.0, 1.0]),
                   solver="dopri5", grad_method="aca", rtol=1e-6,
                   atol=1e-6, use_pallas=True)
    assert calls["combine_err"] > 0 and calls["increment"] > 0
    assert jnp.isfinite(ys).all()


def test_solver_stats():
    ys, stats = odeint(lambda t, z: -z, jnp.float32(1.0),
                       jnp.array([0.0, 1.0]), solver="dopri5",
                       grad_method="aca", rtol=1e-6, atol=1e-6)
    assert int(stats.n_steps) > 0
    assert int(stats.nfe) >= int(stats.n_steps) * 6
    assert not bool(stats.overflow)


def test_overflow_flag():
    # max_steps too small for the requested tolerance -> overflow
    _, stats = odeint(lambda t, z: 50 * jnp.cos(50 * t) * z,
                      jnp.float32(1.0), jnp.array([0.0, 10.0]),
                      solver="dopri5", grad_method="aca",
                      rtol=1e-9, atol=1e-9, max_steps=4)
    assert bool(stats.overflow)
