"""Jaxpr analyzer layer: each rule pass catches an injected violation with
provenance pointing at this file, and a representative slice of the real
entry-point matrix is clean."""

import pathlib

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import SolveConfig, analyze_config, get_config
from repro.analysis.rules_jaxpr import (
    check_collectives,
    check_dtype_contract,
    check_host_sync,
    check_residual_budget,
)
from repro.analysis.jaxpr_walk import engine_custom_vjp_eqns, residual_info

THIS_FILE = pathlib.Path(__file__).name


def _assert_provenance(finding):
    assert finding.path.endswith(THIS_FILE), finding
    assert finding.line > 0, finding


# ---------------------------------------------------------------------------
# collective placement


def test_collective_inside_loop_caught():
    from jax.sharding import PartitionSpec as P

    from repro.distributed import shard_mesh
    from repro.distributed.sharding import shard_map_compat

    mesh = shard_mesh()

    def inner(x):
        def body(c, _):
            return c + jax.lax.psum(c, "data"), None

        out, _ = jax.lax.scan(body, x, None, length=3)
        return out

    fn = shard_map_compat(inner, mesh=mesh, in_specs=P("data"), out_specs=P("data"))
    closed = jax.make_jaxpr(fn)(jnp.zeros((mesh.size,), jnp.float32))
    findings = check_collectives(closed, "inj")
    assert findings, "psum inside scan body must be caught"
    assert all(f.rule == "collective-in-loop" for f in findings)
    _assert_provenance(findings[0])


def test_collective_outside_loop_allowed():
    from jax.sharding import PartitionSpec as P

    from repro.distributed import shard_mesh
    from repro.distributed.sharding import shard_map_compat

    mesh = shard_mesh()

    def inner(x):
        return jax.lax.psum(x, "data")

    fn = shard_map_compat(inner, mesh=mesh, in_specs=P("data"), out_specs=P())
    closed = jax.make_jaxpr(fn)(jnp.zeros((mesh.size,), jnp.float32))
    assert check_collectives(closed, "inj") == []


# ---------------------------------------------------------------------------
# host sync


def test_debug_print_in_loop_caught():
    def fn(x):
        def body(c):
            jax.debug.print("c={c}", c=c)
            return c + 1

        return jax.lax.while_loop(lambda c: c < 3, body, x)

    closed = jax.make_jaxpr(fn)(jnp.int32(0))
    findings = check_host_sync(closed, "inj")
    assert findings and findings[0].rule == "host-sync"
    assert "loop depth" in findings[0].message
    _assert_provenance(findings[0])


def test_debug_print_outside_loop_outside_api_caught():
    def fn(x):
        jax.debug.print("x={x}", x=x)
        return x + 1

    closed = jax.make_jaxpr(fn)(jnp.float32(0))
    findings = check_host_sync(closed, "inj")
    assert findings and "documented" in findings[0].message
    _assert_provenance(findings[0])


def test_documented_warn_site_is_allowed():
    # the real on_failure="warn" config: its jax.debug.print lives in
    # core/api.py outside any loop body, which the pass permits
    assert analyze_config(get_config("aca-full-warn")) == []


# ---------------------------------------------------------------------------
# dtype contract


def test_weak_typed_loop_carry_caught():
    def fn(x):
        return jax.lax.while_loop(lambda c: c < 3.0, lambda c: c + 1.0, x)

    closed = jax.make_jaxpr(fn)(1.0)  # python float -> weak f32 carry
    findings = check_dtype_contract(closed, "inj")
    assert findings and "weak-typed floating carry" in findings[0].message
    _assert_provenance(findings[0])


def test_float_width_cast_in_loop_caught():
    def fn(x):
        def body(c, _):
            y = c.astype(jnp.float16).astype(jnp.float32)
            return y, None

        out, _ = jax.lax.scan(body, x, None, length=2)
        return out

    closed = jax.make_jaxpr(fn)(jnp.zeros((4,), jnp.float32))
    findings = check_dtype_contract(closed, "inj")
    assert findings, "f32<->f16 cast inside a scan body must be caught"
    assert any("cast" in f.message for f in findings)
    _assert_provenance(findings[0])


def test_strong_typed_carries_pass():
    def fn(x):
        return jax.lax.while_loop(
            lambda c: c < 3.0, lambda c: c + 1.0, x)

    closed = jax.make_jaxpr(fn)(jnp.float32(1.0))  # strong f32
    assert check_dtype_contract(closed, "inj") == []


# ---------------------------------------------------------------------------
# residual budget


def _make_fat_custom_vjp(n_steps, dim):
    @jax.custom_vjp
    def f(z):
        return z

    def fwd(z):
        # an O(n_steps * dim) residual — the bug class the gate exists for
        return z, jnp.zeros((n_steps, dim), jnp.float32) + z[None, :]

    def bwd(res, g):
        return (g + res[0],)

    f.defvjp(fwd, bwd)
    return f


def test_oversized_residual_caught():
    cfg = SolveConfig("inj-mali", "mali", dim=96, max_steps=64)
    f = _make_fat_custom_vjp(cfg.max_steps, cfg.dim)
    closed = jax.make_jaxpr(f)(jnp.zeros((cfg.dim,), jnp.float32))
    findings = check_residual_budget(closed, cfg)
    assert findings and findings[0].rule == "residual-budget"
    assert "exceed" in findings[0].message


def test_missing_engine_custom_vjp_caught():
    cfg = SolveConfig("inj-missing", "aca", dim=8)
    closed = jax.make_jaxpr(lambda z: z * 2)(jnp.zeros((8,), jnp.float32))
    findings = check_residual_budget(closed, cfg)
    assert findings and "lost sight" in findings[0].message


def test_residual_info_names_checkpoint_leaves():
    cfg = get_config("aca-full-solo")
    closed = cfg.forward_trace()
    eqns = list(engine_custom_vjp_eqns(closed))
    assert len(eqns) == 1
    info = residual_info(eqns[0])
    assert info.total_bytes > 0
    # the checkpoint state buffer is a named leaf of the residual pytree
    assert any(".z" in p for p, _ in info.named_leaves), info.named_leaves


# ---------------------------------------------------------------------------
# the real matrix (representative slice; the full matrix runs in CI)


@pytest.mark.parametrize(
    "name",
    ["aca-full-solo", "aca-seg-batched", "adjoint-solo", "naive-batched",
     "mali-sharded", "aca-seg-pallas-solo"],
)
def test_registered_configs_are_clean(name):
    assert analyze_config(get_config(name)) == []
