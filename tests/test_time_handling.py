"""Time-handling contracts: dtype derivation, ts validation, the Hairer
hinit exponent, and reverse-time (descending-``ts``) solving.

The reverse-time acceptance gate: a descending-``ts`` ACA solve must
match the negated-time ascending solve *bit-exactly* on the forward
trajectory and to ≤1e-6 relative on gradients for all three methods,
across {pytree, pallas} × {solo, batched}.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GRAD_METHODS, odeint, odeint_final
from repro.core.controller import initial_stepsize


# ------------------------------------------------------- hinit exponent

def test_hinit_uses_order_plus_one_exponent():
    """Hairer I.4 step (f): h1 = (0.01 / max(d1, d2))^(1/(p+1)) — the
    exponent must be 1/(order + 1), not 1/order (regression pin)."""
    rtol = atol = 1e-3

    def f(t, z):
        return z

    # z0 = 1: scale = 2e-3, d0 = d1 = 500, h0 = 0.01·d0/d1 = 0.01,
    # f1 = 1.01 -> d2 = (0.01/2e-3)/0.01 = 500 = dmax
    for order, dmax in [(5, 500.0), (2, 500.0)]:
        h = float(initial_stepsize(f, 0.0, jnp.float32(1.0), (), order,
                                   rtol, atol))
        expected = min(100.0 * 0.01, (0.01 / dmax) ** (1.0 / (order + 1)))
        wrong = (0.01 / dmax) ** (1.0 / order)
        assert abs(h - expected) < 1e-4 * expected, (order, h, expected)
        assert abs(h - wrong) > 1e-2 * expected  # the old exponent fails


# ------------------------------------------------------ time dtype (x64)

def test_odeint_final_time_dtype_follows_x64():
    """odeint_final must not hardcode float32 eval times: under
    JAX_ENABLE_X64 the [t0, t1] grid is float64, so t0/t1 are not
    silently truncated."""
    seen = {}

    def f(t, z):
        seen["tdt"] = jnp.result_type(t)
        return -z

    with jax.experimental.enable_x64():
        odeint_final(f, jnp.ones(2, jnp.float32), 0.0, 1.0,
                     solver="dopri5", rtol=1e-4, atol=1e-4)
    assert seen["tdt"] == jnp.float64

    with jax.experimental.disable_x64():
        odeint_final(f, jnp.ones(2, jnp.float32), 0.0, 1.0,
                     solver="dopri5", rtol=1e-4, atol=1e-4)
    assert seen["tdt"] == jnp.float32

    # explicit endpoint dtypes win over the default
    with jax.experimental.enable_x64():
        odeint_final(f, jnp.ones(2, jnp.float32),
                     jnp.float32(0.0), jnp.float32(1.0),
                     solver="dopri5", rtol=1e-4, atol=1e-4)
        assert seen["tdt"] == jnp.float32


# --------------------------------------------------- batch_axis rank-0

def test_batch_axis_rank0_leaf_raises_named_error():
    z0 = {"vec": jnp.ones((4, 3)), "scalar": jnp.float32(1.0)}
    with pytest.raises(ValueError, match="scalar.*rank-0"):
        odeint(lambda t, z: jax.tree.map(jnp.negative, z), z0,
               jnp.array([0.0, 1.0]), batch_axis=0)


# ----------------------------------------------------- ts validation

def test_unsorted_ts_rejected():
    with pytest.raises(ValueError, match="strictly monotone"):
        odeint(lambda t, z: -z, jnp.float32(1.0),
               jnp.array([0.0, 2.0, 1.0]))


def test_repeated_ts_rejected():
    with pytest.raises(ValueError, match="strictly monotone"):
        odeint(lambda t, z: -z, jnp.float32(1.0),
               jnp.array([0.0, 1.0, 1.0]))


def test_descending_ts_accepted():
    ys, stats = odeint(lambda t, z: -z, jnp.float32(1.0),
                       jnp.array([1.0, 0.5, 0.0]), solver="dopri5",
                       rtol=1e-6, atol=1e-6)
    # z(t) = z(1)·e^{1-t} going backwards from t=1
    exact = np.exp(1.0 - np.array([1.0, 0.5, 0.0]))
    np.testing.assert_allclose(np.asarray(ys), exact, rtol=1e-4)
    assert not bool(stats.overflow)


# ------------------------------------------------- reverse-time solving

@pytest.fixture
def _interpret_kernels():
    from repro.kernels import ops
    ops.set_interpret(True)
    yield
    ops.set_interpret(None)


def _field(t, z, w):
    # time-dependent so the internal clock negation is actually exercised
    return jnp.tanh(w @ z) * (0.6 + 0.4 * jnp.cos(t))


def _reverse_case(method, use_pallas, batched):
    w = jax.random.normal(jax.random.PRNGKey(0), (6, 6)) * 0.4
    z0 = jax.random.normal(jax.random.PRNGKey(1), (6,))
    kw = dict(solver="dopri5", grad_method=method, rtol=1e-6, atol=1e-6,
              max_steps=128, use_pallas=use_pallas)
    if method == "mali":
        # the ALF pair integrator: no RK tableau; 2nd order with a
        # 1st-order embedded estimate -> larger step budget
        kw.update(solver=None, max_steps=4096)
    if batched:
        z0 = jnp.stack([z0, 1.5 * z0, -0.5 * z0])
        kw["batch_axis"] = 0
    ts_desc = jnp.linspace(1.0, 0.0, 5)

    def loss_desc(w):
        ys, _ = odeint(_field, z0, ts_desc, (w,), **kw)
        return jnp.sum(ys ** 2), ys

    def loss_neg(w):
        # the hand-negated ascending reference problem
        f_neg = lambda s, z, ww: jax.tree.map(
            jnp.negative, _field(-s, z, ww))
        ys, _ = odeint(f_neg, z0, -ts_desc, (w,), **kw)
        return jnp.sum(ys ** 2), ys

    (_, ys_d), g_d = jax.value_and_grad(loss_desc, has_aux=True)(w)
    (_, ys_n), g_n = jax.value_and_grad(loss_neg, has_aux=True)(w)
    return map(np.asarray, (ys_d, g_d, ys_n, g_n))


@pytest.mark.parametrize("method", GRAD_METHODS)
@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("batched", [False, True])
def test_descending_equals_negated_ascending(method, use_pallas, batched,
                                             _interpret_kernels):
    """The acceptance gate: descending ``ts`` == the negated-time
    ascending solve, bit-exactly on the forward trajectory and ≤1e-6
    relative on gradients, for every method × stepper path × batching."""
    ys_d, g_d, ys_n, g_n = _reverse_case(method, use_pallas, batched)
    np.testing.assert_array_equal(ys_d, ys_n)
    scale = max(float(np.abs(g_n).max()), 1e-12)
    assert float(np.abs(g_d - g_n).max()) / scale <= 1e-6, method


def test_reverse_solve_inverts_forward():
    """Semantics: integrating forward then backwards lands back on z0
    (up to solve tolerance) — the three-body / time-series use case."""
    w = jax.random.normal(jax.random.PRNGKey(2), (5, 5)) * 0.5
    z0 = jax.random.normal(jax.random.PRNGKey(3), (5,))
    kw = dict(solver="dopri5", rtol=1e-8, atol=1e-8)
    ys, _ = odeint(_field, z0, jnp.array([0.0, 2.0]), (w,), **kw)
    back, _ = odeint(_field, ys[-1], jnp.array([2.0, 0.0]), (w,), **kw)
    np.testing.assert_allclose(np.asarray(back[-1]), np.asarray(z0),
                               rtol=1e-5, atol=1e-6)


def test_odeint_final_reverse_window():
    """odeint_final(t0 > t1) runs the descending path (NodeConfig.t0)."""
    zT, stats = odeint_final(lambda t, z: -z, jnp.float32(1.0), 1.0, 0.0,
                             solver="dopri5", rtol=1e-7, atol=1e-7)
    assert abs(float(zT) - np.e) < 1e-4
    assert not bool(stats.overflow)
