"""Distributed correctness: the sharded paths (pjit constraints, MoE
expert-parallel shard_map, flash-decode seq-sharding) must reproduce the
mesh-less numerics bit-for-bit (up to fp reduction order).

Runs in a subprocess with 8 forced host devices so the main pytest
process keeps seeing 1 device (smoke tests depend on that).
"""

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import ModelConfig, RunConfig, build_model
from conftest import tiny_batch

assert len(jax.devices()) == 8
mesh = jax.make_mesh((2, 4), ("data", "model"))

CONFIGS = [
    ModelConfig(name="dense", family="dense", n_layers=2, d_model=64,
                vocab=128, n_heads=8, n_kv_heads=2, d_ff=128),
    ModelConfig(name="moe", family="moe", n_layers=2, d_model=64,
                vocab=128, n_heads=8, n_kv_heads=8, d_ff=64, n_experts=8,
                n_shared_experts=1, top_k=2, d_expert=64,
                capacity_factor=8.0),   # high capacity: no drops -> exact
    ModelConfig(name="ssm", family="ssm", n_layers=2, d_model=64,
                vocab=128, ssm_state=16, ssm_head_dim=16, ssm_chunk=8),
]

for cfg in CONFIGS:
    m0 = build_model(cfg, RunConfig(compute_dtype=jnp.float32))
    m1 = build_model(cfg, RunConfig(compute_dtype=jnp.float32, mesh=mesh))
    params = m0.init(jax.random.PRNGKey(0))
    batch = tiny_batch(cfg, B=4, S=16)

    l0, _ = jax.jit(m0.loss_fn)(params, batch)
    l1, _ = jax.jit(m1.loss_fn)(params, batch)
    err = abs(float(l0) - float(l1))
    assert err < 5e-4, (cfg.name, float(l0), float(l1))
    print(f"loss {cfg.name}: unsharded={float(l0):.6f} sharded={float(l1):.6f}")

    # gradient agreement
    g0 = jax.jit(jax.grad(lambda p: m0.loss_fn(p, batch)[0]))(params)
    g1 = jax.jit(jax.grad(lambda p: m1.loss_fn(p, batch)[0]))(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-2, atol=2e-4)
    print(f"grads {cfg.name}: ok")

# flash-decode seq-sharding vs local decode
cfg = CONFIGS[0]
S = 16
m0 = build_model(cfg, RunConfig(compute_dtype=jnp.float32, max_seq=S + 4,
                                decode_seq_shard=False))
m1 = build_model(cfg, RunConfig(compute_dtype=jnp.float32, max_seq=S + 4,
                                mesh=mesh, decode_seq_shard=True))
params = m0.init(jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (4, S + 1), 0, cfg.vocab,
                          jnp.int32)
_, c0 = m0.prefill(params, {"tokens": toks[:, :S]})
_, c1 = m1.prefill(params, {"tokens": toks[:, :S]})
lg0, _ = m0.decode_step(params, {"tokens": toks[:, S:]}, c0,
                        jnp.asarray(S, jnp.int32))
lg1, _ = m1.decode_step(params, {"tokens": toks[:, S:]}, c1,
                        jnp.asarray(S, jnp.int32))
np.testing.assert_allclose(np.asarray(lg0), np.asarray(lg1), rtol=2e-4,
                           atol=2e-4)
print("flash-decode: ok")
print("ALL_DISTRIBUTED_OK")
"""


@pytest.mark.slow
def test_sharded_equals_unsharded():
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + \
        os.path.join(root, "tests") + os.pathsep + \
        env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert "ALL_DISTRIBUTED_OK" in r.stdout, (r.stdout[-2000:],
                                              r.stderr[-4000:])
