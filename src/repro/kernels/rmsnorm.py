"""Fused RMSNorm kernel: one HBM pass, fp32 statistics, bf16 IO.

XLA emits (read x, reduce) + (read x, scale) for the naive formulation;
the fused kernel reads each (rows, D) tile once, computes the row
rsqrt(mean-square) on the VPU in fp32 and writes the scaled output —
2·N·D bytes moved instead of 3·N·D.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_ROWS = 256  # rows per tile


def _kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(
    x: jnp.ndarray,      # (..., D)
    w: jnp.ndarray,      # (D,)
    *,
    eps: float = 1e-6,
    rows: int = _ROWS,
    interpret: bool = False,
) -> jnp.ndarray:
    shape = x.shape
    d = shape[-1]
    x2 = x.reshape(-1, d)
    n = x2.shape[0]
    pad = (-n) % rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = ((n + pad) // rows,)

    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + pad, d), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out[:n].reshape(shape)
