"""Causal (optionally sliding-window) GQA flash attention for TPU.

Block-tiled online-softmax attention (Rabe & Staats / FlashAttention)
mapped onto the TPU grid:

  grid = (B, H, nq, nk), kv innermost; running (m, l, acc) live in VMEM
  scratch across the kv sweep of one q tile.

Beyond the XLA fallback (``repro.models.attention.chunked_attention``),
the kernel *skips* fully-masked kv tiles — upper-triangle blocks
(``j > i``) and out-of-window blocks — via ``pl.when``:  ~2× fewer MXU
FLOPs for causal, and O(S·w) instead of O(S²) for windowed attention.
GQA is native (the kv tile index maps ``h -> h // group``), so no
expanded-KV materialization happens on TPU.

Tiles default to (block_q=512, block_k=512) with dh lanes — MXU-aligned
(multiples of 128) and < 4 MB VMEM per operand at dh=128/bf16.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, block_q, block_k, window, nk):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal tile filter: kv tile j intersects q tile i iff j*bk <= i*bq+bq-1
    live = (j * block_k) <= (i * block_q + block_q - 1)
    if window > 0:
        # out-of-window tiles contribute nothing
        live = live & ((j * block_k + block_k) > (i * block_q - window))

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)

        qpos = i * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kpos = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                          # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        l_new = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    # the diagonal tile is always the LAST live tile of the row
    last = jnp.minimum((i * block_q + block_q - 1) // block_k, nk - 1)

    @pl.when(j == last)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,      # (B, H, S, dh)
    k: jnp.ndarray,      # (B, Hkv, S, dh)
    v: jnp.ndarray,      # (B, Hkv, S, dh)
    *,
    window: int = 0,
    scale: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    b, h, s, dh = q.shape
    hkv = k.shape[1]
    group = h // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    if s % block_q != 0 or s % block_k != 0:
        raise ValueError(
            f"flash_attention: sequence length {s} must divide by "
            f"block_q={block_q} and block_k={block_k}")
    nq, nk = s // block_q, s // block_k

    grid = (b, h, nq, nk)
    kern = functools.partial(
        _kernel, scale=scale, block_q=block_q, block_k=block_k,
        window=window, nk=nk)

    scratch = [
        pltpu.VMEM((block_q, 1), jnp.float32),
        pltpu.VMEM((block_q, 1), jnp.float32),
        pltpu.VMEM((block_q, dh), jnp.float32),
    ] if pltpu is not None else [
        pl.MemorySpace.ANY((block_q, 1), jnp.float32),  # pragma: no cover
    ]

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh),
                         lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b_, h_, i, j: (b_, h_ // group, j, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b_, h_, i, j: (b_, h_ // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, dh), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v)
