"""jit'd dispatch layer over the Pallas kernels.

On a TPU backend the compiled kernels run natively; elsewhere (this
container) ``interpret=True`` executes the kernel body in Python on CPU
— the mode the test suite validates against the ``ref.py`` oracles.
``set_interpret(True)`` (or the REPRO_PALLAS_INTERPRET env var) forces
interpret mode explicitly.
"""

from __future__ import annotations

import os
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_pallas
from .rg_lru import rg_lru_pallas
from .rk_stage import rk_stage_combine_pallas
from .rmsnorm import rmsnorm_pallas
from .ssd_scan import ssd_scan_pallas

_FORCE_INTERPRET: Optional[bool] = None


def set_interpret(value: Optional[bool]) -> None:
    global _FORCE_INTERPRET
    _FORCE_INTERPRET = value


def _interpret() -> bool:
    if _FORCE_INTERPRET is not None:
        return _FORCE_INTERPRET
    if os.environ.get("REPRO_PALLAS_INTERPRET"):
        return True
    return jax.default_backend() != "tpu"


def rk_stage_combine(z, k, h, b, e=None, **kw):
    return rk_stage_combine_pallas(z, k, h, b, e,
                                   interpret=_interpret(), **kw)


def rmsnorm(x, w, eps: float = 1e-6, **kw):
    return rmsnorm_pallas(x, w, eps=eps, interpret=_interpret(), **kw)


def flash_attention(q, k, v, *, window: int = 0, scale=None, **kw):
    return flash_attention_pallas(q, k, v, window=window, scale=scale,
                                  interpret=_interpret(), **kw)


def ssd_scan(x, dt, a, b_mat, c_mat, chunk: int, **kw):
    return ssd_scan_pallas(x, dt, a, b_mat, c_mat, chunk,
                           interpret=_interpret(), **kw)


def rg_lru(log_a, b, **kw):
    return rg_lru_pallas(log_a, b, interpret=_interpret(), **kw)
