"""jit'd dispatch layer over the Pallas kernels.

On a TPU backend the compiled kernels run natively; elsewhere (this
container) ``interpret=True`` executes the kernel body in Python on CPU
— the mode the test suite validates against the ``ref.py`` oracles.
``set_interpret(True)`` (or the REPRO_PALLAS_INTERPRET env var) forces
interpret mode explicitly.
"""

from __future__ import annotations

import os
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_pallas
from .rg_lru import rg_lru_pallas
from .rk_stage import (
    _BLOCK,
    combine_err_batched_jnp,
    combine_err_jnp,
    combine_jnp,
    increment_batched_jnp,
    increment_jnp,
    rk_stage_combine_err_batched_pallas,
    rk_stage_combine_err_batched_rowtol_pallas,
    rk_stage_combine_err_pallas,
    rk_stage_combine_pallas,
    rk_stage_increment_batched_pallas,
    rk_stage_increment_pallas,
)
from .rmsnorm import rmsnorm_pallas
from .ssd_scan import ssd_scan_pallas

_FORCE_INTERPRET: Optional[bool] = None

_FALSY = ("0", "false", "no", "off", "")


def set_interpret(value: Optional[bool]) -> None:
    global _FORCE_INTERPRET
    _FORCE_INTERPRET = value


def _interpret() -> bool:
    if _FORCE_INTERPRET is not None:
        return _FORCE_INTERPRET
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None and env.strip().lower() not in _FALSY:
        return True
    return jax.default_backend() != "tpu"


# --------------------------------------------------------------- rk kernels
# The RK kernels sit on every gradient method's differentiation path (the
# naive method differentiates straight through the solver; ACA replays
# local steps under jax.vjp), and pallas_call has no transpose rule —
# each op is therefore a custom_vjp whose forward runs the kernel and
# whose backward is jax.vjp of the bit-matching pure-jnp twin from
# ``rk_stage.py``.  Weights/tolerances are static (baked into the kernel).

@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _rk_combine(z, k, h, b, e, block, interpret):
    return rk_stage_combine_pallas(z, k, h, b, e, block=block,
                                   interpret=interpret)


def _rk_combine_fwd(z, k, h, b, e, block, interpret):
    return _rk_combine(z, k, h, b, e, block, interpret), (z, k, h)


def _rk_combine_bwd(b, e, block, interpret, res, g):
    z, k, h = res
    _, vjp = jax.vjp(lambda z_, k_, h_: combine_jnp(z_, k_, h_, b, e),
                     z, k, h)
    return vjp(g)


_rk_combine.defvjp(_rk_combine_fwd, _rk_combine_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _rk_increment(z, k, h, a, block, interpret):
    return rk_stage_increment_pallas(z, k, h, a, block=block,
                                     interpret=interpret)


def _rk_increment_fwd(z, k, h, a, block, interpret):
    return _rk_increment(z, k, h, a, block, interpret), (z, k, h)


def _rk_increment_bwd(a, block, interpret, res, g):
    z, k, h = res
    _, vjp = jax.vjp(lambda z_, k_, h_: increment_jnp(z_, k_, h_, a),
                     z, k, h)
    return vjp(g)


_rk_increment.defvjp(_rk_increment_fwd, _rk_increment_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _rk_combine_err(z, k, h, b, e, rtol, atol, with_err, block, interpret):
    zn, err, partials = rk_stage_combine_err_pallas(
        z, k, h, b, e, rtol, atol, with_err=with_err, block=block,
        interpret=interpret)
    sq = partials.sum()
    return (zn, err, sq) if with_err else (zn, sq)


def _rk_combine_err_fwd(z, k, h, b, e, rtol, atol, with_err, block,
                        interpret):
    return (_rk_combine_err(z, k, h, b, e, rtol, atol, with_err, block,
                            interpret), (z, k, h))


def _rk_combine_err_bwd(b, e, rtol, atol, with_err, block, interpret,
                        res, g):
    z, k, h = res
    _, vjp = jax.vjp(
        lambda z_, k_, h_: combine_err_jnp(z_, k_, h_, b, e, rtol, atol,
                                           with_err), z, k, h)
    return vjp(g)


_rk_combine_err.defvjp(_rk_combine_err_fwd, _rk_combine_err_bwd)


def rk_stage_combine(z, k, h, b, e=None, *, block=None):
    """Fused (z + h·Σ b_i k_i, h·Σ e_i k_i); differentiable."""
    e_t = tuple(float(x) for x in e) if e is not None else None
    return _rk_combine(z, k, h, tuple(float(x) for x in b), e_t,
                       _BLOCK if block is None else int(block),
                       _interpret())


def rk_stage_increment(z, k, h, a, *, block=None):
    """Fused stage argument z + h·Σ_j a_j k_j; differentiable."""
    return _rk_increment(z, k, h, tuple(float(x) for x in a),
                         _BLOCK if block is None else int(block),
                         _interpret())


def rk_stage_combine_err(z, k, h, b, e, rtol, atol, *, with_err=True,
                         block=None):
    """Fused combine + scalar Σ (err/(atol+rtol·max|z|))²; differentiable.

    Returns (z_next, err, sq_sum); sqrt(sq_sum / N) is ``error_ratio``.
    ``with_err=False`` skips the (N,) err store — the solver loop needs
    only z_next and the norm — and returns None in the err slot.
    """
    out = _rk_combine_err(z, k, h, tuple(float(x) for x in b),
                          tuple(float(x) for x in e), float(rtol),
                          float(atol), bool(with_err),
                          _BLOCK if block is None else int(block),
                          _interpret())
    if with_err:
        return out
    zn, sq = out
    return zn, None, sq


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _rk_increment_batched(z, k, h, a, block, interpret):
    return rk_stage_increment_batched_pallas(z, k, h, a, block=block,
                                             interpret=interpret)


def _rk_increment_batched_fwd(z, k, h, a, block, interpret):
    return _rk_increment_batched(z, k, h, a, block, interpret), (z, k, h)


def _rk_increment_batched_bwd(a, block, interpret, res, g):
    z, k, h = res
    _, vjp = jax.vjp(
        lambda z_, k_, h_: increment_batched_jnp(z_, k_, h_, a), z, k, h)
    return vjp(g)


_rk_increment_batched.defvjp(_rk_increment_batched_fwd,
                             _rk_increment_batched_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _rk_combine_err_batched(z, k, h, b, e, rtol, atol, block, interpret):
    zn, partials = rk_stage_combine_err_batched_pallas(
        z, k, h, b, e, rtol, atol, block=block, interpret=interpret)
    return zn, partials.sum(axis=-1)


def _rk_combine_err_batched_fwd(z, k, h, b, e, rtol, atol, block,
                                interpret):
    return (_rk_combine_err_batched(z, k, h, b, e, rtol, atol, block,
                                    interpret), (z, k, h))


def _rk_combine_err_batched_bwd(b, e, rtol, atol, block, interpret, res,
                                g):
    z, k, h = res
    _, vjp = jax.vjp(
        lambda z_, k_, h_: combine_err_batched_jnp(z_, k_, h_, b, e, rtol,
                                                   atol), z, k, h)
    return vjp(g)


_rk_combine_err_batched.defvjp(_rk_combine_err_batched_fwd,
                               _rk_combine_err_batched_bwd)


# Per-row-tolerance variant: rtol/atol are *traced* (B,) arrays instead
# of static floats, so they ride the kernel as loaded refs.  They carry
# no cotangent (zeros returned) — the same convention as the static
# path, where tolerances are nondiff: the error norm's dependence on
# the tolerance is control-flow plumbing, not a differentiable quantity.
@partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _rk_combine_err_batched_rowtol(z, k, h, rtol, atol, b, e, block,
                                   interpret):
    zn, partials = rk_stage_combine_err_batched_rowtol_pallas(
        z, k, h, b, e, rtol, atol, block=block, interpret=interpret)
    return zn, partials.sum(axis=-1)


def _rk_combine_err_batched_rowtol_fwd(z, k, h, rtol, atol, b, e, block,
                                       interpret):
    return (_rk_combine_err_batched_rowtol(z, k, h, rtol, atol, b, e,
                                           block, interpret),
            (z, k, h, rtol, atol))


def _rk_combine_err_batched_rowtol_bwd(b, e, block, interpret, res, g):
    z, k, h, rtol, atol = res
    _, vjp = jax.vjp(
        lambda z_, k_, h_: combine_err_batched_jnp(z_, k_, h_, b, e, rtol,
                                                   atol), z, k, h)
    dz, dk, dh = vjp(g)
    return dz, dk, dh, jnp.zeros_like(rtol), jnp.zeros_like(atol)


_rk_combine_err_batched_rowtol.defvjp(_rk_combine_err_batched_rowtol_fwd,
                                      _rk_combine_err_batched_rowtol_bwd)


def rk_stage_increment_batched(z, k, h, a, *, block=None):
    """Per-row fused stage argument z + h_b·Σ_j a_j k_j over a (B, N)
    batch; differentiable.  Rows with h_b = 0 pass through bit-exactly
    (frozen-element masking of the batched solver)."""
    return _rk_increment_batched(z, k, h, tuple(float(x) for x in a),
                                 _BLOCK if block is None else int(block),
                                 _interpret())


def rk_stage_combine_err_batched(z, k, h, b, e, rtol, atol, *, block=None):
    """Per-row fused combine + per-row Σ (err/(atol+rtol·max|z|))² over a
    (B, N) batch; differentiable.

    Returns (z_next (B, N), sq_sum (B,)); sqrt(sq_sum / N) is each batch
    element's own ``error_ratio`` — the per-sample accept/reject signal.
    The (B, N) err buffer is never materialized.

    ``rtol``/``atol`` are static scalars (baked into the kernel, the
    classic path) or (B,) arrays — then each row is error-controlled
    against its own tolerance (per-request QoS), loaded per grid row
    like ``h``.  Tolerances never carry gradient on either path.
    """
    bw = tuple(float(x) for x in b)
    ew = tuple(float(x) for x in e)
    blk = _BLOCK if block is None else int(block)
    if jnp.ndim(rtol) > 0 or jnp.ndim(atol) > 0:
        bsz = z.shape[0]
        rt = jnp.broadcast_to(jnp.asarray(rtol, jnp.float32), (bsz,))
        at = jnp.broadcast_to(jnp.asarray(atol, jnp.float32), (bsz,))
        return _rk_combine_err_batched_rowtol(z, k, h, rt, at, bw, ew,
                                              blk, _interpret())
    return _rk_combine_err_batched(
        z, k, h, bw, ew, float(rtol), float(atol), blk, _interpret())


def rmsnorm(x, w, eps: float = 1e-6, **kw):
    return rmsnorm_pallas(x, w, eps=eps, interpret=_interpret(), **kw)


def flash_attention(q, k, v, *, window: int = 0, scale=None, **kw):
    return flash_attention_pallas(q, k, v, window=window, scale=scale,
                                  interpret=_interpret(), **kw)


def ssd_scan(x, dt, a, b_mat, c_mat, chunk: int, **kw):
    return ssd_scan_pallas(x, dt, a, b_mat, c_mat, chunk,
                           interpret=_interpret(), **kw)


def rg_lru(log_a, b, **kw):
    return rg_lru_pallas(log_a, b, interpret=_interpret(), **kw)
