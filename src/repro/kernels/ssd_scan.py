"""Mamba-2 SSD chunk-scan kernel with VMEM state carry.

One grid step processes one (batch, head, chunk) tile:

    Y_diag  = ((C_c B_cᵀ) ⊙ L) · (dt ⊙ X_c)        (MXU, intra-chunk)
    Y_inter = C_c · h_prev ⊙ decay_from_start        (MXU, inter-chunk)
    h_next  = h_prev · exp(Σ dA) + (B_c ⊙ decay)ᵀ X  (state update)

The (P, N) SSM state h lives in VMEM scratch and is carried across the
chunk grid dimension (innermost, sequential on TPU) — the HBM traffic
is exactly X/B/C/dt in + Y out; the O(S/Q) intermediate chunk states
never touch HBM, unlike the XLA fallback which materializes them for
the inter-chunk ``lax.scan``.  This is the paper's checkpoint idea
applied intra-layer: chunk boundaries are the trajectory checkpoints.

Grid: (B, H, nc) — nc innermost carries the recurrence.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None


def _segsum_exp(da: jnp.ndarray, q: int) -> jnp.ndarray:
    """L[i, j] = exp(sum_{k=j+1..i} da_k) for j <= i else 0.  da (Q,)."""
    cs = jnp.cumsum(da)
    diff = cs[:, None] - cs[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    return jnp.where(ii >= jj, jnp.exp(diff), 0.0)


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_scr, *, q):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0, 0].astype(jnp.float32)           # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)         # (Q,)
    a = a_ref[0, 0]                                  # scalar decay rate
    bm = b_ref[0, 0, 0].astype(jnp.float32)          # (Q, N)
    cm = c_ref[0, 0, 0].astype(jnp.float32)          # (Q, N)

    da = dt * a                                      # (Q,)
    da_cum = jnp.cumsum(da)
    da_tot = da_cum[-1]

    # intra-chunk
    l_mat = _segsum_exp(da, q)                       # (Q, Q)
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y_diag = jax.lax.dot_general(
        cb * l_mat, x * dt[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # (Q, P)

    # inter-chunk from carried state h (P, N)
    h = h_scr[...]
    y_inter = jax.lax.dot_general(
        cm, h, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * jnp.exp(da_cum)[:, None]

    y_ref[0, 0, 0] = (y_diag + y_inter).astype(y_ref.dtype)

    # state update: h' = h·exp(da_tot) + Σ_t decay_to_end_t · dt_t x_t B_tᵀ
    decay_to_end = jnp.exp(da_tot - da_cum)          # (Q,)
    xb = jax.lax.dot_general(
        x * (dt * decay_to_end)[:, None], bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # (P, N)
    h_scr[...] = h * jnp.exp(da_tot) + xb


def ssd_scan_pallas(
    x: jnp.ndarray,      # (B, S, H, P)
    dt: jnp.ndarray,     # (B, S, H) fp32, post-softplus
    a: jnp.ndarray,      # (H,) fp32, negative decay rates
    b_mat: jnp.ndarray,  # (B, S, G, N) — G must divide H
    c_mat: jnp.ndarray,  # (B, S, G, N)
    chunk: int,
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns y (B, S, H, P).  (h_last stays on-chip; the model's prefill
    path uses the jnp reference when it needs the final state.)"""
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    if s % chunk != 0:
        raise ValueError(
            f"ssd_scan: sequence length {s} not divisible by chunk {chunk}")
    nc = s // chunk

    # layout: (B, H, nc, Q, ·) tiles
    xt = x.transpose(0, 2, 1, 3).reshape(bsz, h, nc, chunk, p)
    dtt = dt.transpose(0, 2, 1).reshape(bsz, h, nc, chunk)
    a_bh = jnp.broadcast_to(a[None, :], (bsz, h))
    bt = jnp.repeat(b_mat.transpose(0, 2, 1, 3), rep, axis=1) \
        .reshape(bsz, h, nc, chunk, n)
    ct = jnp.repeat(c_mat.transpose(0, 2, 1, 3), rep, axis=1) \
        .reshape(bsz, h, nc, chunk, n)

    grid = (bsz, h, nc)
    scratch = [pltpu.VMEM((p, n), jnp.float32)] if pltpu is not None else []

    y = pl.pallas_call(
        functools.partial(_kernel, q=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p),
                         lambda b_, h_, c_: (b_, h_, c_, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk),
                         lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1), lambda b_, h_, c_: (b_, h_)),
            pl.BlockSpec((1, 1, 1, chunk, n),
                         lambda b_, h_, c_: (b_, h_, c_, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, n),
                         lambda b_, h_, c_: (b_, h_, c_, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, chunk, p),
                               lambda b_, h_, c_: (b_, h_, c_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, nc, chunk, p), x.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(xt, dtt, a_bh, bt, ct)

    return y.reshape(bsz, h, s, p).transpose(0, 2, 1, 3)
