"""Pure-jnp oracles for every Pallas kernel (the test targets).

These are the *definitions* of correct behaviour; the kernel tests sweep
shapes/dtypes and assert_allclose against them.  Where the model code
already contains the reference computation it is reused directly so the
kernel, the model fallback and the oracle cannot drift apart.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.rk_stage import combine_err_jnp, combine_jnp, \
    increment_jnp
from repro.models.attention import full_attention
from repro.models.common import rmsnorm as _rmsnorm_model
from repro.models.mamba2 import ssd_chunked as _ssd_chunked_model


def rk_stage_combine_ref(z, k, h, b, e) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """z (N,), k (s, N), h scalar -> (z + h Σ b_i k_i,  h Σ e_i k_i).

    Shares the pure-jnp twin that the kernels' custom_vjp backward
    differentiates (same pattern as the model-code reuse below): the
    kernel, the backward pass and the oracle cannot drift apart.
    """
    return combine_jnp(z, k, h, tuple(b),
                       tuple(e) if e is not None else None)


def rk_stage_increment_ref(z, k, h, a) -> jnp.ndarray:
    """z (N,), k (j, N), h scalar -> z + h Σ_j a_j k_j (in z.dtype)."""
    return increment_jnp(z, k, h, tuple(a))


def rk_stage_combine_err_ref(
    z, k, h, b, e, rtol: float, atol: float
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Combine + scalar Σ (err/(atol+rtol·max(|z|,|z_next|)))².

    The kernel emits per-tile partials of the same sum; the oracle
    returns the total (what ``error_ratio`` squares to, times N).
    """
    return combine_err_jnp(z, k, h, tuple(b), tuple(e), rtol, atol)


def rmsnorm_ref(x, w, eps: float = 1e-6) -> jnp.ndarray:
    return _rmsnorm_model(x, w, eps)


def flash_attention_ref(q, k, v, *, window: int = 0,
                        scale: Optional[float] = None) -> jnp.ndarray:
    """q (B,H,S,dh), k/v (B,Hkv,S,dh) -> (B,H,S,dh), causal (+window)."""
    h, hkv = q.shape[1], k.shape[1]
    ke = jnp.repeat(k, h // hkv, axis=1)
    ve = jnp.repeat(v, h // hkv, axis=1)
    # full_attention uses (B,S,H,dh) layout
    out = full_attention(q.transpose(0, 2, 1, 3), ke.transpose(0, 2, 1, 3),
                         ve.transpose(0, 2, 1, 3), window=window,
                         scale=scale)
    return out.transpose(0, 2, 1, 3)


def ssd_scan_ref(x, dt, a, b_mat, c_mat, chunk) -> jnp.ndarray:
    """Shares the model's chunked SSD implementation (y only)."""
    y, _ = _ssd_chunked_model(x, dt, a, b_mat, c_mat, chunk)
    return y


def ssd_scan_sequential_ref(x, dt, a, b_mat, c_mat) -> jnp.ndarray:
    """Independent O(S) sequential SSM — validates the chunked algebra."""
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    bf = jnp.repeat(b_mat.astype(jnp.float32), rep, axis=2)
    cf = jnp.repeat(c_mat.astype(jnp.float32), rep, axis=2)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(hstate, inp):
        xt, dtt, bt, ct = inp
        da = jnp.exp(dtt * a[None])                     # (B,H)
        hstate = hstate * da[..., None, None] + jnp.einsum(
            "bhn,bh,bhp->bhpn", bt, dtt, xt)
        y = jnp.einsum("bhn,bhpn->bhp", ct, hstate)
        return hstate, y

    h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(
        step, h0, (xf.swapaxes(0, 1), dtf.swapaxes(0, 1),
                   bf.swapaxes(0, 1), cf.swapaxes(0, 1)))
    return ys.swapaxes(0, 1)                            # (B,S,H,P)


def rg_lru_ref(log_a, b) -> jnp.ndarray:
    """h_t = exp(log_a_t) h_{t-1} + b_t via associative scan (fp32)."""
    a = jnp.exp(log_a.astype(jnp.float32))
    bf = b.astype(jnp.float32)

    def combine(p, q):
        a1, b1 = p
        a2, b2 = q
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, bf), axis=1)
    return h
