"""RG-LRU linear-recurrence kernel with VMEM state carry.

Computes  h_t = a_t ⊙ h_{t-1} + b_t  over (B, S, C) in chunks: one grid
step processes a (Q, C-tile) block, carrying the (1, C-tile) running
state in VMEM scratch across the chunk dimension (innermost, sequential
on TPU).  Within the chunk the recurrence is evaluated *sequentially*
(``fori_loop`` over Q steps of (C-tile,) VPU ops) — the op is memory-
bound, so the per-step latency hides under the tile DMA, and the direct
recurrence is unconditionally stable (closed-form cumprod formulations
corrupt recent contributions once within-chunk decay underflows; this is
also how the production RecurrentGemma TPU kernel is written).

The XLA fallback (``lax.associative_scan``) materializes O(S log S)
elementwise intermediates in HBM; the kernel is one streaming pass:
in log_a + b, out h — 3·S·C·4 bytes total.

Grid: (B, C/Ct, nc) — chunk dim innermost carries the state.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None


def _kernel(loga_ref, b_ref, y_ref, h_scr, *, q):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = jnp.exp(loga_ref[0].astype(jnp.float32))     # (Q, Ct)
    b = b_ref[0].astype(jnp.float32)                 # (Q, Ct)

    def step(i, h):
        h = a[i] * h + b[i]                          # (1, Ct) carried
        y_ref[0, i, :] = h[0]
        return h

    h_scr[...] = jax.lax.fori_loop(0, q, step, h_scr[...])


def rg_lru_pallas(
    log_a: jnp.ndarray,   # (B, S, C) log decay (<= 0), fp32
    b: jnp.ndarray,       # (B, S, C) input term, fp32
    *,
    chunk: int = 256,
    c_tile: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns h (B, S, C) fp32 solving h_t = exp(log_a_t) h_{t-1} + b_t."""
    bsz, s, c = log_a.shape
    if s % chunk != 0:
        raise ValueError(
            f"rg_lru: sequence length {s} not divisible by chunk {chunk}")
    c_tile = min(c_tile, c)
    if c % c_tile != 0:
        raise ValueError(
            f"rg_lru: channel count {c} not divisible by c_tile {c_tile}")
    nc = s // chunk

    grid = (bsz, c // c_tile, nc)
    scratch = [pltpu.VMEM((1, c_tile), jnp.float32)] \
        if pltpu is not None else []

    return pl.pallas_call(
        functools.partial(_kernel, q=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, c_tile),
                         lambda b_, ct, c_: (b_, c_, ct)),
            pl.BlockSpec((1, chunk, c_tile),
                         lambda b_, ct, c_: (b_, c_, ct)),
        ],
        out_specs=pl.BlockSpec((1, chunk, c_tile),
                               lambda b_, ct, c_: (b_, c_, ct)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, c), jnp.float32),
        scratch_shapes=scratch,
        interpret=interpret,
    )(log_a, b)
