"""repro.kernels — Pallas TPU kernels for the compute hot spots.

Each kernel module provides ``<op>_pallas(..., interpret=...)`` built on
``pl.pallas_call`` with explicit VMEM BlockSpecs; ``ops.py`` is the jit'd
dispatch layer (kernel on TPU, interpret-mode kernel or jnp reference on
CPU); ``ref.py`` holds the pure-jnp oracles the tests sweep against.

Kernels:
  rk_stage        — fused RK stage combine + embedded error (ACA hot loop)
  rmsnorm         — fused RMSNorm (fp32 statistics, bf16 IO)
  flash_attention — causal (windowed) GQA flash attention, block-skipping
  ssd_scan        — Mamba-2 SSD chunk scan with VMEM state carry
  rg_lru          — RG-LRU linear recurrence, chunked with VMEM state carry
"""

from . import ops, ref

__all__ = ["ops", "ref"]
