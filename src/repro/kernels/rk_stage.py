"""Fused Runge-Kutta kernels — the ACA inner-loop hot spot.

The per-trial cost of ψ over a flat (N,) state is three memory-bound
passes, each fused into one Pallas kernel here:

  * ``rk_stage_increment_pallas`` — per-stage state  z + h · Σ_j a_ij k_j
    (the argument of the i-th f evaluation); weights baked per tableau
    row, zero weights skipped at compile time.
  * ``rk_stage_combine_pallas`` — the accepted-solution combine
    z_next = z + h·Σ b_i k_i  and embedded error  err = h·Σ e_i k_i in a
    single pass.  Unfused, XLA materializes s intermediate AXPY results
    in HBM (s = #stages, up to 7 for Dopri5): ~(2s+2)·N bytes moved; the
    fused pass moves (s+3)·N — a ~2× cut of the memory-bound term.
  * ``rk_stage_combine_err_pallas`` — the combine *plus* per-tile
    partial sums of the scaled error norm
    Σ (err / (atol + rtol·max(|z|, |z_next|)))², so the accept/reject
    loop's ``error_ratio`` costs no extra full-array pass at all.

Layout: k is stacked (s, N); the grid tiles N.  Weights/tolerances are
baked into the kernel as compile-time constants (they come from the
tableau), h arrives as a (1, 1) SMEM scalar.  ``*_ref`` companions in
``ref.py`` are the oracles; the differentiable dispatch wrappers live in
``ops.py``.

Batched variants (``*_batched_pallas``) serve the per-sample batched
solver (``odeint(..., batch_axis=0)``): the state is (B, N) with one
stepsize *per row*, k is stacked (s, B, N), the grid is (rows × tiles)
and the error norm is reduced **per row** — every batch element gets its
own scaled-error partial sums, so the accept/reject decision is
per-element instead of one global reduction over the whole batch.
Masking of rejected/finished elements is by zeroed per-row h: a row with
h = 0 computes z + 0·Σ… which round-trips bit-exactly through the f32
accumulator, so frozen elements pass through unchanged.

The ``*_rowtol`` variant additionally loads **per-row tolerances**: rtol
and atol arrive as (B,) arrays through (1, 1) row blocks — the ``h``
pattern — instead of baked compile-time floats, so every batch element
is error-controlled against its own (rtol, atol).  This is the
per-request tolerance QoS knob of the serving engine; the arithmetic is
unchanged, so equal-tolerance rows stay bitwise identical to the baked
kernel's.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific memory spaces; absent on CPU-only installs
    from jax.experimental.pallas import tpu as pltpu
    _SMEM = pltpu.MemorySpace.SMEM
except Exception:  # pragma: no cover
    pltpu = None
    _SMEM = None

_BLOCK = 2048  # lanes per tile: multiple of 128 (VPU lane width)


# --- pure-jnp twins -------------------------------------------------------
# Pallas calls have no transpose rule, so ``ops.py`` wraps each kernel in a
# custom_vjp whose backward is jax.vjp of these functions.  They must
# compute exactly what the kernel computes (same dtypes, same weight
# handling); the independent oracles used by the tests live in ``ref.py``.

def combine_jnp(z, k, h, b, e):
    kf = k.astype(jnp.float32)
    bw = jnp.asarray(b, jnp.float32)[:, None]
    zn = (z.astype(jnp.float32) + h * (bw * kf).sum(0)).astype(z.dtype)
    if e is None:
        err = jnp.zeros(z.shape, jnp.float32)
    else:
        ew = jnp.asarray(e, jnp.float32)[:, None]
        err = (h * (ew * kf).sum(0)).astype(jnp.float32)
    return zn, err


def increment_jnp(z, k, h, a):
    aw = jnp.asarray(tuple(a)[: k.shape[0]], jnp.float32)[:, None]
    incr = (aw * k.astype(jnp.float32)).sum(0)
    return (z.astype(jnp.float32) + h * incr).astype(z.dtype)


def combine_err_jnp(z, k, h, b, e, rtol, atol, with_err=True):
    zn, err = combine_jnp(z, k, h, b, e)
    scale = atol + rtol * jnp.maximum(
        jnp.abs(z.astype(jnp.float32)), jnp.abs(zn.astype(jnp.float32)))
    r = err / scale
    sq = jnp.sum(r * r)
    return (zn, err, sq) if with_err else (zn, sq)


def increment_batched_jnp(z, k, h, a):
    """(B, N) twin of ``increment_jnp`` with per-row stepsizes h (B,)."""
    aw = jnp.asarray(tuple(a)[: k.shape[0]], jnp.float32)[:, None, None]
    incr = (aw * k.astype(jnp.float32)).sum(0)          # (B, N)
    hv = h.astype(jnp.float32)[:, None]
    return (z.astype(jnp.float32) + hv * incr).astype(z.dtype)


def combine_err_batched_jnp(z, k, h, b, e, rtol, atol):
    """(B, N) twin of ``combine_err_jnp``: per-row combine + per-row
    scaled-error square sums (B,).

    ``rtol``/``atol`` may be scalars or per-row (B,) arrays (the
    per-request tolerance QoS path): a row's tolerance broadcasts down
    its lanes exactly like the baked scalar — same f32 arithmetic, so a
    row solved at tolerance τ is bitwise the all-τ batch's row.
    """
    kf = k.astype(jnp.float32)                          # (s, B, N)
    bw = jnp.asarray(b, jnp.float32)[:, None, None]
    ew = jnp.asarray(e, jnp.float32)[:, None, None]
    hv = h.astype(jnp.float32)[:, None]
    zn = (z.astype(jnp.float32) + hv * (bw * kf).sum(0)).astype(z.dtype)
    err = hv * (ew * kf).sum(0)
    rt = jnp.asarray(rtol, jnp.float32)
    at = jnp.asarray(atol, jnp.float32)
    rt = rt[:, None] if rt.ndim else rt
    at = at[:, None] if at.ndim else at
    scale = at + rt * jnp.maximum(
        jnp.abs(z.astype(jnp.float32)), jnp.abs(zn.astype(jnp.float32)))
    r = err / scale
    return zn, jnp.sum(r * r, axis=-1)


def _h_spec(interpret: bool):
    smem = _SMEM if (_SMEM is not None and not interpret) else None
    if smem is not None:
        return pl.BlockSpec(memory_space=smem)
    return pl.BlockSpec((1, 1), lambda i: (0, 0))


def _kernel(h_ref, z_ref, k_ref, out_ref, err_ref, *, b, e):
    h = h_ref[0, 0]
    z = z_ref[...].astype(jnp.float32)
    acc = jnp.zeros_like(z)
    err = jnp.zeros_like(z)
    for i, (bi, ei) in enumerate(zip(b, e)):
        ki = k_ref[i, :].astype(jnp.float32)
        if bi != 0.0:
            acc = acc + bi * ki
        if ei != 0.0:
            err = err + ei * ki
    out_ref[...] = (z + h * acc).astype(out_ref.dtype)
    err_ref[...] = (h * err).astype(err_ref.dtype)


def rk_stage_combine_pallas(
    z: jnp.ndarray,          # (N,) flattened state
    k: jnp.ndarray,          # (s, N) stacked stage derivatives
    h: jnp.ndarray,          # scalar stepsize
    b: Sequence[float],      # solution weights
    e: Optional[Sequence[float]],  # embedded-error weights (None -> zeros)
    *,
    block: int = _BLOCK,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (z_next (N,), err (N,))."""
    s, n = k.shape
    assert z.shape == (n,)
    e = tuple(e) if e is not None else tuple(0.0 for _ in b)
    b = tuple(b)

    pad = (-n) % block
    if pad:
        z = jnp.pad(z, (0, pad))
        k = jnp.pad(k, ((0, 0), (0, pad)))
    npad = n + pad
    grid = (npad // block,)

    h2d = jnp.asarray(h, jnp.float32).reshape(1, 1)

    out, err = pl.pallas_call(
        functools.partial(_kernel, b=b, e=e),
        grid=grid,
        in_specs=[
            _h_spec(interpret),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((s, block), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad,), z.dtype),
            jax.ShapeDtypeStruct((npad,), jnp.float32),
        ],
        interpret=interpret,
    )(h2d, z, k)
    if pad:
        out, err = out[:n], err[:n]
    return out, err


def _incr_kernel(h_ref, z_ref, k_ref, out_ref, *, a):
    h = h_ref[0, 0]
    z = z_ref[...].astype(jnp.float32)
    acc = jnp.zeros_like(z)
    for j, aj in enumerate(a):
        if aj != 0.0:
            acc = acc + aj * k_ref[j, :].astype(jnp.float32)
    out_ref[...] = (z + h * acc).astype(out_ref.dtype)


def rk_stage_increment_pallas(
    z: jnp.ndarray,          # (N,) flattened state
    k: jnp.ndarray,          # (j, N) stage derivatives computed so far
    h: jnp.ndarray,          # scalar stepsize
    a: Sequence[float],      # tableau row a[i][:j]
    *,
    block: int = _BLOCK,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns z + h · Σ_j a_j k_j  (the i-th stage argument), shape (N,)."""
    s, n = k.shape
    assert z.shape == (n,)
    a = tuple(a)[:s]

    pad = (-n) % block
    if pad:
        z = jnp.pad(z, (0, pad))
        k = jnp.pad(k, ((0, 0), (0, pad)))
    npad = n + pad
    grid = (npad // block,)
    h2d = jnp.asarray(h, jnp.float32).reshape(1, 1)

    out = pl.pallas_call(
        functools.partial(_incr_kernel, a=a),
        grid=grid,
        in_specs=[
            _h_spec(interpret),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((s, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), z.dtype),
        interpret=interpret,
    )(h2d, z, k)
    return out[:n] if pad else out


def _combine_err_kernel(h_ref, z_ref, k_ref, out_ref, *out_rest,
                        b, e, rtol, atol, with_err):
    err_ref, nrm_ref = out_rest if with_err else (None, out_rest[0])
    h = h_ref[0, 0]
    z = z_ref[...].astype(jnp.float32)
    acc = jnp.zeros_like(z)
    err = jnp.zeros_like(z)
    for i, (bi, ei) in enumerate(zip(b, e)):
        ki = k_ref[i, :].astype(jnp.float32)
        if bi != 0.0:
            acc = acc + bi * ki
        if ei != 0.0:
            err = err + ei * ki
    zn = z + h * acc
    err = h * err
    out_ref[...] = zn.astype(out_ref.dtype)
    if with_err:
        err_ref[...] = err
    scale = atol + rtol * jnp.maximum(jnp.abs(z), jnp.abs(zn))
    r = err / scale
    nrm_ref[0] = jnp.sum(r * r)


def rk_stage_combine_err_pallas(
    z: jnp.ndarray,          # (N,) flattened state
    k: jnp.ndarray,          # (s, N) stacked stage derivatives
    h: jnp.ndarray,          # scalar stepsize
    b: Sequence[float],      # solution weights
    e: Sequence[float],      # embedded-error weights
    rtol: float,
    atol: float,
    *,
    with_err: bool = True,
    block: int = _BLOCK,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray], jnp.ndarray]:
    """Returns (z_next (N,), err (N,) | None, norm_partials (n_tiles,)).

    ``norm_partials[t]`` is the tile-t partial sum of
    (err / (atol + rtol·max(|z|, |z_next|)))² — summing it and dividing
    by N gives ``error_ratio``² without a second full-array pass.
    Padded lanes are filled with z=1, k=0 so err=0 there and the scale
    stays positive: they contribute exactly 0 to the norm.

    ``with_err=False`` skips the (N,) err store entirely (the adaptive
    solver loop consumes only z_next and the norm) and returns None in
    its slot.
    """
    s, n = k.shape
    assert z.shape == (n,)
    b = tuple(b)
    e = tuple(e)

    pad = (-n) % block
    if pad:
        z = jnp.pad(z, (0, pad), constant_values=1)
        k = jnp.pad(k, ((0, 0), (0, pad)))
    npad = n + pad
    grid = (npad // block,)
    h2d = jnp.asarray(h, jnp.float32).reshape(1, 1)

    err_specs = [pl.BlockSpec((block,), lambda i: (i,))] if with_err \
        else []
    err_shapes = [jax.ShapeDtypeStruct((npad,), jnp.float32)] \
        if with_err else []
    outs = pl.pallas_call(
        functools.partial(_combine_err_kernel, b=b, e=e,
                          rtol=float(rtol), atol=float(atol),
                          with_err=with_err),
        grid=grid,
        in_specs=[
            _h_spec(interpret),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((s, block), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            *err_specs,
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad,), z.dtype),
            *err_shapes,
            jax.ShapeDtypeStruct((npad // block,), jnp.float32),
        ],
        interpret=interpret,
    )(h2d, z, k)
    out = outs[0][:n] if pad else outs[0]
    nrm = outs[-1]
    if not with_err:
        return out, None, nrm
    err = outs[1][:n] if pad else outs[1]
    return out, err, nrm


# --- batched (per-sample) kernels ----------------------------------------
# One grid row per batch element; h is (B,) — each row advances with its
# own trial stepsize, and the error norm partials are per row so the
# controller can accept/reject elements independently (the whole point of
# batch_axis: no lockstep).

def _incr_batched_kernel(h_ref, z_ref, k_ref, out_ref, *, a):
    h = h_ref[0, 0]
    z = z_ref[...].astype(jnp.float32)
    acc = jnp.zeros_like(z)
    for j, aj in enumerate(a):
        if aj != 0.0:
            acc = acc + aj * k_ref[j, ...].astype(jnp.float32)
    out_ref[...] = (z + h * acc).astype(out_ref.dtype)


def rk_stage_increment_batched_pallas(
    z: jnp.ndarray,          # (B, N) flattened per-sample states
    k: jnp.ndarray,          # (s, B, N) stacked stage derivatives
    h: jnp.ndarray,          # (B,) per-row stepsizes
    a: Sequence[float],      # tableau row a[i][:j]
    *,
    block: int = _BLOCK,
    interpret: bool = False,
) -> jnp.ndarray:
    """Per-row z + h_b · Σ_j a_j k_j, shape (B, N).

    A row whose h_b is 0 passes through bit-exactly (the f32 round trip
    of z + 0 is the identity) — the masking contract used by the batched
    solver to freeze rejected/finished elements.
    """
    s, bsz, n = k.shape
    assert z.shape == (bsz, n)
    a = tuple(a)[:s]

    pad = (-n) % block
    if pad:
        z = jnp.pad(z, ((0, 0), (0, pad)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad)))
    npad = n + pad
    grid = (bsz, npad // block)
    h2d = jnp.asarray(h, jnp.float32).reshape(bsz, 1)

    out = pl.pallas_call(
        functools.partial(_incr_batched_kernel, a=a),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda r, i: (r, 0)),
            pl.BlockSpec((1, block), lambda r, i: (r, i)),
            pl.BlockSpec((s, 1, block), lambda r, i: (0, r, i)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda r, i: (r, i)),
        out_shape=jax.ShapeDtypeStruct((bsz, npad), z.dtype),
        interpret=interpret,
    )(h2d, z, k)
    return out[:, :n] if pad else out


def _combine_err_batched_kernel(h_ref, z_ref, k_ref, out_ref, nrm_ref, *,
                                b, e, rtol, atol):
    h = h_ref[0, 0]
    z = z_ref[...].astype(jnp.float32)
    acc = jnp.zeros_like(z)
    err = jnp.zeros_like(z)
    for i, (bi, ei) in enumerate(zip(b, e)):
        ki = k_ref[i, ...].astype(jnp.float32)
        if bi != 0.0:
            acc = acc + bi * ki
        if ei != 0.0:
            err = err + ei * ki
    zn = z + h * acc
    err = h * err
    out_ref[...] = zn.astype(out_ref.dtype)
    scale = atol + rtol * jnp.maximum(jnp.abs(z), jnp.abs(zn))
    r = err / scale
    nrm_ref[0, 0] = jnp.sum(r * r)


def rk_stage_combine_err_batched_pallas(
    z: jnp.ndarray,          # (B, N) flattened per-sample states
    k: jnp.ndarray,          # (s, B, N) stacked stage derivatives
    h: jnp.ndarray,          # (B,) per-row stepsizes
    b: Sequence[float],      # solution weights
    e: Sequence[float],      # embedded-error weights
    rtol: float,
    atol: float,
    *,
    block: int = _BLOCK,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (z_next (B, N), norm_partials (B, n_tiles)).

    ``norm_partials[b, t]`` is element b's tile-t partial sum of
    (err / (atol + rtol·max(|z|, |z_next|)))² — a **per-row** reduction:
    summing axis -1 and dividing by N gives each element's own
    ``error_ratio``², the quantity that makes per-sample accept/reject
    possible.  Padded lanes use z=1, k=0 so they contribute exactly 0.
    The err buffer is never materialized (the batched solver loop reads
    only z_next and the norms); rows with h_b = 0 return z unchanged and
    a zero norm (frozen-element masking).
    """
    s, bsz, n = k.shape
    assert z.shape == (bsz, n)
    b = tuple(b)
    e = tuple(e)

    pad = (-n) % block
    if pad:
        z = jnp.pad(z, ((0, 0), (0, pad)), constant_values=1)
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad)))
    npad = n + pad
    grid = (bsz, npad // block)
    h2d = jnp.asarray(h, jnp.float32).reshape(bsz, 1)

    out, nrm = pl.pallas_call(
        functools.partial(_combine_err_batched_kernel, b=b, e=e,
                          rtol=float(rtol), atol=float(atol)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda r, i: (r, 0)),
            pl.BlockSpec((1, block), lambda r, i: (r, i)),
            pl.BlockSpec((s, 1, block), lambda r, i: (0, r, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda r, i: (r, i)),
            pl.BlockSpec((1, 1), lambda r, i: (r, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, npad), z.dtype),
            jax.ShapeDtypeStruct((bsz, npad // block), jnp.float32),
        ],
        interpret=interpret,
    )(h2d, z, k)
    return (out[:, :n] if pad else out), nrm


def _combine_err_batched_rowtol_kernel(h_ref, rtol_ref, atol_ref, z_ref,
                                       k_ref, out_ref, nrm_ref, *, b, e):
    h = h_ref[0, 0]
    rtol = rtol_ref[0, 0]
    atol = atol_ref[0, 0]
    z = z_ref[...].astype(jnp.float32)
    acc = jnp.zeros_like(z)
    err = jnp.zeros_like(z)
    for i, (bi, ei) in enumerate(zip(b, e)):
        ki = k_ref[i, ...].astype(jnp.float32)
        if bi != 0.0:
            acc = acc + bi * ki
        if ei != 0.0:
            err = err + ei * ki
    zn = z + h * acc
    err = h * err
    out_ref[...] = zn.astype(out_ref.dtype)
    scale = atol + rtol * jnp.maximum(jnp.abs(z), jnp.abs(zn))
    r = err / scale
    nrm_ref[0, 0] = jnp.sum(r * r)


def rk_stage_combine_err_batched_rowtol_pallas(
    z: jnp.ndarray,          # (B, N) flattened per-sample states
    k: jnp.ndarray,          # (s, B, N) stacked stage derivatives
    h: jnp.ndarray,          # (B,) per-row stepsizes
    b: Sequence[float],      # solution weights
    e: Sequence[float],      # embedded-error weights
    rtol: jnp.ndarray,       # (B,) per-row relative tolerances
    atol: jnp.ndarray,       # (B,) per-row absolute tolerances
    *,
    block: int = _BLOCK,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row-tolerance twin of ``rk_stage_combine_err_batched_pallas``.

    Identical combine arithmetic, but ``rtol``/``atol`` arrive as (B,)
    arrays loaded per grid row through (1, 1) blocks — the same pattern
    as the per-row stepsize ``h`` — instead of being baked into the
    kernel as compile-time constants.  A row whose loaded tolerance
    equals a baked scalar computes bit-identical f32 values (same ops,
    same tile partial-sum order), which is what lets tight- and
    loose-tolerance batch elements share one solve while each matches
    its own solo trajectory bitwise (the serving QoS contract).
    """
    s, bsz, n = k.shape
    assert z.shape == (bsz, n)
    b = tuple(b)
    e = tuple(e)

    pad = (-n) % block
    if pad:
        z = jnp.pad(z, ((0, 0), (0, pad)), constant_values=1)
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad)))
    npad = n + pad
    grid = (bsz, npad // block)
    h2d = jnp.asarray(h, jnp.float32).reshape(bsz, 1)
    rt2d = jnp.broadcast_to(
        jnp.asarray(rtol, jnp.float32), (bsz,)).reshape(bsz, 1)
    at2d = jnp.broadcast_to(
        jnp.asarray(atol, jnp.float32), (bsz,)).reshape(bsz, 1)

    row_spec = pl.BlockSpec((1, 1), lambda r, i: (r, 0))
    out, nrm = pl.pallas_call(
        functools.partial(_combine_err_batched_rowtol_kernel, b=b, e=e),
        grid=grid,
        in_specs=[
            row_spec,
            row_spec,
            row_spec,
            pl.BlockSpec((1, block), lambda r, i: (r, i)),
            pl.BlockSpec((s, 1, block), lambda r, i: (0, r, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda r, i: (r, i)),
            pl.BlockSpec((1, 1), lambda r, i: (r, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, npad), z.dtype),
            jax.ShapeDtypeStruct((bsz, npad // block), jnp.float32),
        ],
        interpret=interpret,
    )(h2d, rt2d, at2d, z, k)
    return (out[:, :n] if pad else out), nrm
