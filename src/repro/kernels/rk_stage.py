"""Fused Runge-Kutta stage combine — the ACA inner-loop hot spot.

Every accepted ODE step evaluates

    z_next = z + h · Σ_i b_i k_i          (solution combine)
    err    =     h · Σ_i e_i k_i          (embedded error estimate)

over the flattened state.  Unfused, XLA materializes s intermediate
AXPY results in HBM (s = #stages, up to 7 for Dopri5): ~(2s+2)·N bytes
moved.  The kernel streams one VMEM tile of every stage derivative and
the state, producing both outputs in a single pass: (s+3)·N bytes —
a ~2× cut of the memory-bound term of the solver loop.

Layout: k is stacked (s, N); the grid tiles N.  b/e weights are baked
into the kernel as compile-time constants (they come from the tableau),
h arrives as a (1, 1) SMEM scalar.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific memory spaces; absent on CPU-only installs
    from jax.experimental.pallas import tpu as pltpu
    _SMEM = pltpu.MemorySpace.SMEM
except Exception:  # pragma: no cover
    pltpu = None
    _SMEM = None

_BLOCK = 2048  # lanes per tile: multiple of 128 (VPU lane width)


def _kernel(h_ref, z_ref, k_ref, out_ref, err_ref, *, b, e):
    h = h_ref[0, 0]
    z = z_ref[...].astype(jnp.float32)
    acc = jnp.zeros_like(z)
    err = jnp.zeros_like(z)
    for i, (bi, ei) in enumerate(zip(b, e)):
        ki = k_ref[i, :].astype(jnp.float32)
        if bi != 0.0:
            acc = acc + bi * ki
        if ei != 0.0:
            err = err + ei * ki
    out_ref[...] = (z + h * acc).astype(out_ref.dtype)
    err_ref[...] = (h * err).astype(err_ref.dtype)


def rk_stage_combine_pallas(
    z: jnp.ndarray,          # (N,) flattened state
    k: jnp.ndarray,          # (s, N) stacked stage derivatives
    h: jnp.ndarray,          # scalar stepsize
    b: Sequence[float],      # solution weights
    e: Optional[Sequence[float]],  # embedded-error weights (None -> zeros)
    *,
    block: int = _BLOCK,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (z_next (N,), err (N,))."""
    s, n = k.shape
    assert z.shape == (n,)
    e = tuple(e) if e is not None else tuple(0.0 for _ in b)
    b = tuple(b)

    pad = (-n) % block
    if pad:
        z = jnp.pad(z, (0, pad))
        k = jnp.pad(k, ((0, 0), (0, pad)))
    npad = n + pad
    grid = (npad // block,)

    h2d = jnp.asarray(h, jnp.float32).reshape(1, 1)
    smem = _SMEM if (_SMEM is not None and not interpret) else None
    h_spec = pl.BlockSpec(memory_space=smem) if smem is not None else \
        pl.BlockSpec((1, 1), lambda i: (0, 0))

    out, err = pl.pallas_call(
        functools.partial(_kernel, b=b, e=e),
        grid=grid,
        in_specs=[
            h_spec,
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((s, block), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad,), z.dtype),
            jax.ShapeDtypeStruct((npad,), jnp.float32),
        ],
        interpret=interpret,
    )(h2d, z, k)
    if pad:
        out, err = out[:n], err[:n]
    return out, err
