"""Learning-rate schedules (step functions of the int32 step counter)."""

from __future__ import annotations

import math
from typing import Sequence

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def step_decay(lr: float, boundaries: Sequence[int], factor: float = 0.1):
    """The paper's schedule: decay by ``factor`` at each boundary epoch."""
    bs = jnp.asarray(list(boundaries), jnp.int32)

    def f(step):
        n = (step >= bs).sum()
        return jnp.asarray(lr, jnp.float32) * factor ** n

    return f


def exponential_decay(lr: float, decay: float):
    """lr · decay^step (the paper's three-body experiments, Eq. 83)."""
    def f(step):
        return jnp.asarray(lr, jnp.float32) * decay ** step.astype(
            jnp.float32)
    return f


def cosine_warmup(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    """Linear warmup then cosine decay to final_frac·peak (LM training)."""
    def f(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup_steps, 1)
        t = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                     0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (
            1 + jnp.cos(math.pi * t))
        return jnp.where(s < warmup_steps, warm, peak_lr * cos)

    return f
