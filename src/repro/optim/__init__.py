"""repro.optim — optimizers, schedules, gradient utilities (no optax)."""

from .adamw import adamw
from .sgd import sgd
from .schedule import (constant, cosine_warmup, exponential_decay,
                       step_decay)
from .grad_utils import (clip_by_global_norm, global_norm,
                         int8_compress_decompress, topk_sparsify,
                         CompressionState)

__all__ = [
    "adamw", "sgd",
    "constant", "cosine_warmup", "exponential_decay", "step_decay",
    "clip_by_global_norm", "global_norm",
    "int8_compress_decompress", "topk_sparsify", "CompressionState",
]
