"""Gradient utilities: clipping, compression (with error feedback).

Gradient compression reduces the *data-parallel all-reduce* volume — the
cross-pod (DCN) traffic in the multi-pod mesh.  Two schemes:

* ``int8_compress_decompress`` — per-tensor symmetric int8 quantization
  with error feedback (the quantization residual is carried to the next
  step, keeping SGD unbiased in the long run): 4× DCN volume reduction.
* ``topk_sparsify`` — keep the top-k fraction by magnitude, accumulate
  the rest in the error buffer (Deep Gradient Compression style).

Both run as quantize→(all-reduce)→dequantize transforms around the
optimizer; on a real multi-pod deployment the int8 all-reduce happens in
the compressed domain via a custom reducer — here the compression math
and error-feedback state machine are what the tests exercise.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2)
                        for l in leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float,
                        on_nonfinite: str = "zero"
                        ) -> Tuple[PyTree, jnp.ndarray]:
    """Scale ``grads`` so their global norm is at most ``max_norm``.

    Returns (clipped grads, raw global norm).  A non-finite global norm
    (one Inf/NaN leaf poisons the whole reduction) used to scale every
    leaf to NaN; now ``on_nonfinite`` picks the recovery: ``"zero"``
    (default) returns all-zero gradients, ``"keep"`` returns the grads
    unclipped — either way the *raw* (non-finite) norm is still
    returned, so a downstream skip-step guard (``train/loop.py``) can
    see the failure and count it.
    """
    if on_nonfinite not in ("zero", "keep"):
        raise ValueError(
            f"on_nonfinite must be 'zero' or 'keep'; got {on_nonfinite!r}")
    norm = global_norm(grads)
    finite = jnp.isfinite(norm)
    safe_norm = jnp.where(finite, norm, jnp.asarray(1.0, norm.dtype))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(safe_norm, 1e-12))

    def clip(g):
        gc = (g.astype(jnp.float32) * scale).astype(g.dtype)
        if on_nonfinite == "zero":
            # select per-leaf against finite: Inf * 0 = NaN, so the bad
            # branch must never be multiplied
            return jnp.where(finite, gc, jnp.zeros_like(gc))
        return jnp.where(finite, gc, g)

    return jax.tree.map(clip, grads), norm


class CompressionState(NamedTuple):
    error: PyTree          # error-feedback residual, fp32


def init_compression_state(grads: PyTree) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                           grads))


def int8_compress_decompress(
    grads: PyTree,
    state: Optional[CompressionState] = None,
) -> Tuple[PyTree, CompressionState]:
    """Symmetric per-tensor int8 quantize→dequantize with error feedback.

    Returns (decompressed grads, new state).  The int8 payload +
    per-tensor fp32 scale is what would cross the DCN.
    """
    if state is None:
        state = init_compression_state(grads)

    def comp(g, e):
        gf = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.abs(gf).max(), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), gf - deq

    pairs = jax.tree.map(comp, grads, state.error)
    out = jax.tree.map(lambda x: x[0], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda x: x[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return out, CompressionState(error=err)


def topk_sparsify(
    grads: PyTree,
    frac: float,
    state: Optional[CompressionState] = None,
) -> Tuple[PyTree, CompressionState]:
    """Keep the top ``frac`` of entries per tensor (by |value|); the rest
    accumulates in the error buffer."""
    if state is None:
        state = init_compression_state(grads)

    def comp(g, e):
        gf = g.astype(jnp.float32) + e
        flat = jnp.abs(gf).reshape(-1)
        k = max(int(flat.size * frac), 1)
        thresh = jax.lax.top_k(flat, k)[0][-1]
        mask = jnp.abs(gf) >= thresh
        kept = jnp.where(mask, gf, 0.0)
        return kept.astype(g.dtype), gf - kept

    pairs = jax.tree.map(comp, grads, state.error)
    out = jax.tree.map(lambda x: x[0], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda x: x[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return out, CompressionState(error=err)
