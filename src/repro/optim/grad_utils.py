"""Gradient utilities: clipping, compression (with error feedback).

Gradient compression reduces the *data-parallel all-reduce* volume — the
cross-pod (DCN) traffic in the multi-pod mesh.  Two schemes:

* ``int8_compress_decompress`` — per-tensor symmetric int8 quantization
  with error feedback (the quantization residual is carried to the next
  step, keeping SGD unbiased in the long run): 4× DCN volume reduction.
* ``topk_sparsify`` — keep the top-k fraction by magnitude, accumulate
  the rest in the error buffer (Deep Gradient Compression style).

Both run as quantize→(all-reduce)→dequantize transforms around the
optimizer; on a real multi-pod deployment the int8 all-reduce happens in
the compressed domain via a custom reducer — here the compression math
and error-feedback state machine are what the tests exercise.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2)
                        for l in leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float
                        ) -> Tuple[PyTree, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


class CompressionState(NamedTuple):
    error: PyTree          # error-feedback residual, fp32


def init_compression_state(grads: PyTree) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                           grads))


def int8_compress_decompress(
    grads: PyTree,
    state: Optional[CompressionState] = None,
) -> Tuple[PyTree, CompressionState]:
    """Symmetric per-tensor int8 quantize→dequantize with error feedback.

    Returns (decompressed grads, new state).  The int8 payload +
    per-tensor fp32 scale is what would cross the DCN.
    """
    if state is None:
        state = init_compression_state(grads)

    def comp(g, e):
        gf = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.abs(gf).max(), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), gf - deq

    pairs = jax.tree.map(comp, grads, state.error)
    out = jax.tree.map(lambda x: x[0], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda x: x[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return out, CompressionState(error=err)


def topk_sparsify(
    grads: PyTree,
    frac: float,
    state: Optional[CompressionState] = None,
) -> Tuple[PyTree, CompressionState]:
    """Keep the top ``frac`` of entries per tensor (by |value|); the rest
    accumulates in the error buffer."""
    if state is None:
        state = init_compression_state(grads)

    def comp(g, e):
        gf = g.astype(jnp.float32) + e
        flat = jnp.abs(gf).reshape(-1)
        k = max(int(flat.size * frac), 1)
        thresh = jax.lax.top_k(flat, k)[0][-1]
        mask = jnp.abs(gf) >= thresh
        kept = jnp.where(mask, gf, 0.0)
        return kept.astype(g.dtype), gf - kept

    pairs = jax.tree.map(comp, grads, state.error)
    out = jax.tree.map(lambda x: x[0], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda x: x[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return out, CompressionState(error=err)
