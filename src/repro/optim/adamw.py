"""AdamW with decoupled weight decay (Loshchilov & Hutter).

Functional optax-style interface:

    opt = adamw(lr_schedule, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

Moments are stored in fp32 regardless of parameter dtype.  Under the
2-D (FSDP × TP) parameter sharding, moment trees inherit the parameter
PartitionSpecs — ZeRO: optimizer state is fully sharded.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple, Union

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: PyTree
    nu: PyTree


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], Any]
    update: Callable[..., Tuple[PyTree, Any]]


def _sched_value(s: Schedule, step) -> jnp.ndarray:
    return s(step) if callable(s) else jnp.asarray(s, jnp.float32)


def adamw(lr: Schedule, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          mask: Callable[[PyTree], PyTree] = None) -> Optimizer:
    """``mask(params)`` -> bool tree selects which leaves get decay
    (default: every leaf with ndim >= 2 — biases/norms are excluded)."""

    def default_mask(params):
        return jax.tree.map(lambda p: p.ndim >= 2, params)

    decay_mask = mask or default_mask

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(zeros, params),
                          nu=jax.tree.map(zeros, params))

    def update(grads, state, params):
        step = state.step + 1
        lr_t = _sched_value(lr, step)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, n):
            gf = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * gf
            n2 = b2 * n + (1 - b2) * gf * gf
            return m2, n2

        mn = jax.tree.map(upd, grads, state.mu, state.nu)
        mu = jax.tree.map(lambda x: x[0], mn,
                          is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda x: x[1], mn,
                          is_leaf=lambda x: isinstance(x, tuple))

        wd_tree = decay_mask(params)

        def step_fn(m, n, p, use_wd):
            u = -(lr_t * ((m / c1) / (jnp.sqrt(n / c2) + eps)))
            if weight_decay:
                u = u - lr_t * weight_decay * jnp.where(
                    use_wd, p.astype(jnp.float32), 0.0)
            return u.astype(p.dtype)

        updates = jax.tree.map(step_fn, mu, nu, params, wd_tree)
        return updates, AdamWState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: p + u, params, updates)
