"""SGD with (Nesterov) momentum — the optimizer of the paper's image
classification experiments (Sec. 4.2)."""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .adamw import Optimizer, Schedule, _sched_value

PyTree = Any


class SGDState(NamedTuple):
    step: jnp.ndarray
    velocity: PyTree


def sgd(lr: Schedule, momentum: float = 0.9, nesterov: bool = False,
        weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return SGDState(
            step=jnp.zeros((), jnp.int32),
            velocity=jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def update(grads, state, params):
        step = state.step + 1
        lr_t = _sched_value(lr, step)

        def upd(g, v, p):
            gf = g.astype(jnp.float32)
            if weight_decay:
                gf = gf + weight_decay * p.astype(jnp.float32)
            v2 = momentum * v + gf
            d = gf + momentum * v2 if nesterov else v2
            return (-lr_t * d).astype(p.dtype), v2

        pairs = jax.tree.map(upd, grads, state.velocity, params)
        updates = jax.tree.map(lambda x: x[0], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
        vel = jax.tree.map(lambda x: x[1], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
        return updates, SGDState(step=step, velocity=vel)

    return Optimizer(init=init, update=update)
