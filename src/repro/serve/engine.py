"""Batched serving engine: prefill once, decode step-by-step.

Static-batch engine (the serving counterpart of the dry-run's
``prefill_step`` / ``decode_step`` cells):

* ``prefill``  — one jitted forward over the (B, S_prompt) batch that
  writes the fixed-capacity per-layer caches (ring buffers for windowed
  attention, SSM/conv states for Mamba-2 / RG-LRU);
* ``generate`` — jitted ``decode_step`` applied autoregressively with
  greedy / temperature sampling; caches are donated (updated in place).

The KV-cache capacity is ``rcfg.max_seq``; with a mesh the cache
sequence dim is sharded over the model axis (flash-decode) so capacity
scales with the model-parallel degree — the mechanism behind the 500k
long-context cells.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.lm import Model

PyTree = Any

# generate() with temperature sampling and no explicit key falls back to
# a fixed seed; warn once per process so the silent determinism is at
# least visible (tests monkeypatch this back to False to re-trigger).
_warned_default_key = False


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 => greedy
    eos_id: int = -1              # -1 => never stop early


class ServeEngine:
    def __init__(self, model: Model, params: PyTree,
                 cfg: Optional[ServeConfig] = None, jit: bool = True):
        self.model = model
        self.params = params
        self.cfg = cfg or ServeConfig()
        self._prefill = jax.jit(model.prefill) if jit else model.prefill
        self._decode = jax.jit(model.decode_step,
                               donate_argnums=(2,)) if jit \
            else model.decode_step
        # decode iterations executed by the last generate() call —
        # observability for the eos early-break (and its tests)
        self.last_decode_steps = 0

    def prefill(self, tokens: jnp.ndarray) -> Tuple[jnp.ndarray, PyTree]:
        return self._prefill(self.params, {"tokens": tokens})

    def _sample(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.cfg.temperature, axis=-1).astype(jnp.int32)

    def generate(self, tokens: jnp.ndarray,
                 key: Optional[jax.Array] = None
                 ) -> Dict[str, jnp.ndarray]:
        """tokens (B, S_prompt) -> {"tokens": (B, S_prompt+new)}.

        ``key=None`` uses a *fixed* ``PRNGKey(0)``: with
        ``temperature > 0`` every keyless call then samples the same
        sequence — deterministic and reproducible, but not fresh
        randomness.  Pass your own key for varied samples; the fallback
        warns once per process when temperature sampling is active
        (greedy decoding ignores the key entirely).
        """
        if key is None:
            if self.cfg.temperature > 0.0:
                global _warned_default_key
                if not _warned_default_key:
                    _warned_default_key = True
                    import warnings
                    warnings.warn(
                        "ServeEngine.generate(key=None) with "
                        "temperature > 0 uses a fixed PRNGKey(0): every "
                        "keyless call samples identical tokens. Pass an "
                        "explicit key for fresh randomness.",
                        UserWarning, stacklevel=2)
            key = jax.random.PRNGKey(0)
        b, s = tokens.shape
        logits, caches = self.prefill(tokens)
        outs = [tokens]
        key, sub = jax.random.split(key)
        nxt = self._sample(logits, sub)
        outs.append(nxt[:, None])
        done = jnp.zeros((b,), bool)
        if self.cfg.eos_id >= 0:
            # the first sampled token can already be eos — seed `done`
            # from it so the row stops padding out and an all-finished
            # batch skips the decode loop entirely
            done = nxt == self.cfg.eos_id
        self.last_decode_steps = 0
        for i in range(self.cfg.max_new_tokens - 1):
            if self.cfg.eos_id >= 0 and bool(done.all()):
                break
            pos = jnp.asarray(s + i, jnp.int32)
            logits, caches = self._decode(
                self.params, {"tokens": nxt[:, None]}, caches, pos)
            self.last_decode_steps += 1
            key, sub = jax.random.split(key)
            nxt = self._sample(logits, sub)
            if self.cfg.eos_id >= 0:
                done = done | (nxt == self.cfg.eos_id)
                nxt = jnp.where(done, self.cfg.eos_id, nxt)
            outs.append(nxt[:, None])
        return {"tokens": jnp.concatenate(outs, axis=1)}
