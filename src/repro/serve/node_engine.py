"""Continuous-batching NODE inference engine with per-request QoS.

Serving a Neural ODE is unlike serving a static network: each request
is a *solve*, its cost is data-dependent (the adaptive controller
decides how many trials the request needs), and requests arrive with
different horizons and accuracy demands.  Padding every request to the
worst case in a static batch wastes exactly the adaptivity the paper's
solver stack provides.

``NodeServeEngine`` coalesces queued solve requests into one batched
adaptive solve (``odeint(..., batch_axis=0)``) and advances the live
batch in fixed *time chunks*.  Three repo capabilities make this work
without any dynamic shapes:

* **Per-row tolerances** — each slot passes its request's
  ``(rtol, atol)`` as one row of the (S,) tolerance arrays, so every
  request is error-controlled by its *own* controller inside the fused
  while_loop (the QoS knob).  Rows never interact: a request's
  trajectory is bit-identical to the same request served alone.
* **Per-row ``h0``** — the engine always passes an explicit (S,)
  initial stepsize (per-row Hairer heuristic, or the request's own
  ``h0`` on its first chunk), so admission order cannot perturb a
  neighbour's first step.
* **Per-element ``SolveStatus``** — a poisoned or budget-exhausted row
  freezes and reports its code while neighbours integrate on; the
  engine retires the slot per the request's ``on_failure`` policy and
  admits the next queued request at the chunk boundary (slot swap).

Every chunk is solved as the *canonical* problem ``s ∈ [0, 1]`` over an
augmented per-row state ``[z, t_off, delta]`` with field
``dz/ds = delta · f(t_off + s·delta, z)`` — rows at different physical
times and horizons share one static-shape solve, and an empty slot is
simply ``delta = 0`` (zero field, one cheap accepted step).  The aux
components have zero derivative, so they pass through the RK stages
exactly and the error norm sees them as constants.

Time is *simulated*, not wall-clock: a deterministic ``SimClock``
charges each coalescing round ``chunk_overhead + trial_cost · max_b
(n_trials_b)`` — the fused while_loop runs until its slowest live row
finishes, which is precisely the straggler cost continuous batching
amortizes.  Tests and benchmarks replay identical traffic bit-for-bit.

See ``docs/serving.md`` for the architecture, the QoS contract, and
the solo-parity caveats.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field, replace
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.api import odeint
from ..core.controller import initial_stepsize
from ..core.integrate import SolveStatus
from ..core.stepper import ALF_ORDER
from ..core.tableaus import get_tableau

__all__ = [
    "STATUS_DEADLINE_MISS",
    "NodeRequest",
    "RequestResult",
    "RequestQueue",
    "NodeEngineConfig",
    "NodeServeEngine",
    "augment_field",
    "augment_state",
]

#: Engine-level status for a request whose deadline elapsed while it
#: was still queued (it is dropped unsolved).  Distinct from every
#: solver-level ``SolveStatus`` code (those are small ints).
STATUS_DEADLINE_MISS = 100

_ON_FAILURE = ("status", "retry")

#: Defaults for an empty (padding) slot: delta = 0 makes the field
#: vanish, a loose tolerance and h0 = 1 land the row in one accepted
#: trial, so padding never dominates the round's straggler cost.
_EMPTY_RTOL = 1e-3
_EMPTY_ATOL = 1e-3
_EMPTY_H0 = 1.0


# ------------------------------------------------------------- augmentation

def augment_state(z, t_off, delta):
    """Pack one per-sample canonical-chunk state ``[z, t_off, delta]``.

    ``z`` is the (dim,) physical state, ``t_off`` the chunk's physical
    start time, ``delta`` its physical duration (0 for an empty slot).
    Both scalars ride as extra state components with zero derivative —
    exactly constant through every RK stage.
    """
    z = jnp.asarray(z)
    aux = jnp.asarray([t_off, delta], z.dtype)
    return jnp.concatenate([z, aux])


def augment_field(f: Callable) -> Callable:
    """Canonical-chunk field over the augmented state of ``augment_state``.

    ``fa(s, zaug, *args)`` computes ``dz/ds = delta · f(t_off + s·delta,
    z)`` and zeros for the two aux components.  Per-sample — the engine
    batches it via ``odeint(..., batch_axis=0)``.  Note the field is
    evaluated on empty slots too (``z = 0, t = 0``); fields undefined at
    the origin should guard (the result is multiplied by ``delta = 0``,
    but NaN·0 = NaN).
    """
    def fa(s, zaug, *args):
        z, t_off, delta = zaug[:-2], zaug[-2], zaug[-1]
        dz = delta * f(t_off + s * delta, z, *args)
        return jnp.concatenate([dz, jnp.zeros((2,), zaug.dtype)])
    return fa


# ------------------------------------------------------------ request model

@dataclass
class NodeRequest:
    """One NODE solve request: integrate ``z0`` from ``t0`` to ``t1``.

    ``rtol``/``atol`` are the request's QoS knob — its private error
    controller inside the coalesced batch.  ``h0`` (physical time)
    overrides the first chunk's initial stepsize.  ``deadline`` is an
    absolute sim-time bound: a request still queued past it is dropped
    (``STATUS_DEADLINE_MISS``); one that completes late is delivered
    with ``deadline_missed=True``.  ``on_failure`` picks the slot-swap
    policy when the solver reports a non-OK status for this row:
    ``"status"`` delivers the frozen state + code, ``"retry"``
    re-enqueues the request once from the failed chunk's start state at
    ``retry_tol_factor``× looser tolerances.
    """
    z0: Any
    t0: float = 0.0
    t1: float = 1.0
    rtol: float = 1e-4
    atol: float = 1e-6
    h0: Optional[float] = None
    deadline: Optional[float] = None
    on_failure: str = "status"
    tag: Optional[str] = None

    def __post_init__(self):
        if self.on_failure not in _ON_FAILURE:
            raise ValueError(
                f"on_failure must be one of {_ON_FAILURE}; "
                f"got {self.on_failure!r}")
        if not float(self.t1) > float(self.t0):
            raise ValueError(
                f"NodeRequest needs t1 > t0; got t0={self.t0}, "
                f"t1={self.t1} (reverse-time serving is not supported)")
        if self.h0 is not None and not float(self.h0) > 0.0:
            raise ValueError(f"h0 must be positive; got {self.h0}")


@dataclass
class RequestResult:
    """Delivered outcome of one request.

    ``status`` is the solver's ``SolveStatus`` code (or
    ``STATUS_DEADLINE_MISS`` for a queue-expired drop); ``ok`` means
    status OK *and* the deadline (if any) was met.  ``z_final`` is the
    state at ``t1`` (frozen last-good state on failure; the admission
    state for a queue-expired drop).  Sim-time stamps: ``t_arrival`` →
    ``t_admitted`` → ``t_finished``; ``latency`` is finish − arrival.
    """
    req_id: int
    tag: Optional[str]
    z_final: np.ndarray
    status: int
    ok: bool
    deadline_missed: bool
    t_arrival: float
    t_admitted: float
    t_finished: float
    n_chunks: int
    n_trials: int
    retried: bool

    @property
    def latency(self) -> float:
        return self.t_finished - self.t_arrival


class RequestQueue:
    """FIFO admission queue keyed by (arrival sim-time, submit order)."""

    def __init__(self):
        self._heap: List[Tuple[float, int, int, NodeRequest]] = []
        self._seq = itertools.count()

    def push(self, arrival: float, req: NodeRequest,
             req_id: Optional[int] = None) -> int:
        seq = next(self._seq)
        rid = seq if req_id is None else req_id
        heapq.heappush(self._heap, (float(arrival), seq, rid, req))
        return rid

    def pop_ready(self, now: float):
        """Pop the earliest request with ``arrival <= now`` (or None)."""
        if self._heap and self._heap[0][0] <= now:
            arrival, _, rid, req = heapq.heappop(self._heap)
            return arrival, rid, req
        return None

    def next_arrival(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)


@dataclass
class _Slot:
    """One live batch row: the request it serves and its chunk cursor."""
    index: int
    active: bool = False
    req_id: int = -1
    req: Optional[NodeRequest] = None
    z: Optional[np.ndarray] = None     # physical state at ``tau``
    tau: float = 0.0                   # physical time reached so far
    t_arrival: float = 0.0
    t_admitted: float = 0.0
    n_chunks: int = 0
    n_trials: int = 0
    retried: bool = False
    first_chunk: bool = True           # request h0 applies only here


# ---------------------------------------------------------------- sim clock

class SimClock:
    """Deterministic cost model for the coalesced solve loop.

    One coalescing round costs ``chunk_overhead`` (admission, dispatch,
    host sync) plus ``trial_cost · max_b(n_trials_b)`` — the fused
    while_loop's wall time is its slowest row's trial count.  Purely
    host-side float arithmetic: identical traffic replays identically.
    """

    def __init__(self, trial_cost: float, chunk_overhead: float):
        self.trial_cost = float(trial_cost)
        self.chunk_overhead = float(chunk_overhead)
        self.now = 0.0

    def advance_round(self, max_trials: int) -> float:
        dt = self.chunk_overhead + self.trial_cost * int(max_trials)
        self.now += dt
        return dt

    def jump_to(self, t: float) -> None:
        self.now = max(self.now, float(t))


# ------------------------------------------------------------------- config

@dataclass(frozen=True)
class NodeEngineConfig:
    """Static engine shape + solver + cost-model knobs.

    ``slots`` and ``chunk_dt`` fix the compiled solve's shapes: every
    round solves an (slots, dim+2) canonical batch regardless of
    occupancy.  ``static_batch=True`` is the baseline scheduler: admit
    only when *all* slots are free (wave semantics, no mid-wave swap).
    """
    slots: int = 4
    chunk_dt: float = 0.5
    solver: Optional[str] = None
    grad_method: str = "aca"
    use_pallas: bool = False
    max_steps: int = 64
    max_trials: int = 12
    static_batch: bool = False
    trial_cost: float = 1.0
    chunk_overhead: float = 2.0
    retry_tol_factor: float = 100.0

    def __post_init__(self):
        if self.slots < 1:
            raise ValueError(f"slots must be >= 1; got {self.slots}")
        if not self.chunk_dt > 0.0:
            raise ValueError(f"chunk_dt must be > 0; got {self.chunk_dt}")
        if self.retry_tol_factor < 1.0:
            raise ValueError("retry_tol_factor must be >= 1; got "
                             f"{self.retry_tol_factor}")


# ------------------------------------------------------------------- engine

class NodeServeEngine:
    """Continuous-batching solve server over one vector field.

    ``f(t, z, *args)`` is the per-sample field; ``dim`` the state size.
    ``submit()`` enqueues requests at explicit arrival sim-times;
    ``run()`` drains the queue and returns every ``RequestResult``.
    ``step()`` advances one coalescing round (admission → chunk solve →
    retire/swap) for tests that pin per-round behaviour.
    """

    def __init__(self, f: Callable, dim: int, args: Tuple = (),
                 config: Optional[NodeEngineConfig] = None):
        self.cfg = config or NodeEngineConfig()
        self.f = f
        self.dim = int(dim)
        self.args = args
        self.clock = SimClock(self.cfg.trial_cost, self.cfg.chunk_overhead)
        self.queue = RequestQueue()
        self.slots = [_Slot(i) for i in range(self.cfg.slots)]
        self.results: Dict[int, RequestResult] = {}
        self.round = 0
        #: admission trace for slot-swap golden tests:
        #: (round, slot_index, req_id) per admission.
        self.admission_log: List[Tuple[int, int, int]] = []
        #: per-round live-row counts (occupancy under the traffic).
        self.occupancy_log: List[int] = []

        fa = augment_field(f)
        mali = self.cfg.grad_method == "mali"
        order = ALF_ORDER if mali else get_tableau(
            self.cfg.solver or "dopri5").order
        ts = jnp.asarray([0.0, 1.0], jnp.float32)

        def _solve(Z, rt, at, h0):
            ys, stats = odeint(
                fa, Z, ts, self.args,
                solver=self.cfg.solver,
                grad_method=self.cfg.grad_method,
                rtol=rt, atol=at, h0=h0,
                max_steps=self.cfg.max_steps,
                max_trials=self.cfg.max_trials,
                use_pallas=self.cfg.use_pallas,
                batch_axis=0, on_failure="status")
            return ys[-1], stats.status, stats.n_trials

        self._solve = jax.jit(_solve)

        def _hinit(zaug, rt, at):
            return initial_stepsize(fa, 0.0, zaug, self.args, order, rt, at)

        # Per-row Hairer starting-step heuristic over the whole batch;
        # vmapped so each row's h0 depends only on its own state and
        # tolerance (solo-parity: admission order cannot change it).
        self._hinit = jax.jit(jax.vmap(_hinit))

    def reset(self) -> None:
        """Clear all scheduler state (queue, slots, clock, results, logs)
        while keeping the compiled chunk solve — cheap trace replay with
        the same engine (and the test tier's per-config engine reuse)."""
        self.clock = SimClock(self.cfg.trial_cost, self.cfg.chunk_overhead)
        self.queue = RequestQueue()
        self.slots = [_Slot(i) for i in range(self.cfg.slots)]
        self.results = {}
        self.round = 0
        self.admission_log = []
        self.occupancy_log = []

    # ---------------------------------------------------------- submission

    def submit(self, req: NodeRequest, arrival: Optional[float] = None,
               req_id: Optional[int] = None) -> int:
        """Enqueue ``req`` at sim-time ``arrival`` (default: now)."""
        z0 = np.asarray(req.z0, np.float32)
        if z0.shape != (self.dim,):
            raise ValueError(
                f"request z0 must have shape ({self.dim},); "
                f"got {z0.shape}")
        req = replace(req, z0=z0)
        t = self.clock.now if arrival is None else float(arrival)
        return self.queue.push(t, req, req_id)

    # ----------------------------------------------------------- scheduling

    def _record(self, req_id: int, req: NodeRequest, *, z_final, status,
                t_arrival, t_admitted, n_chunks, n_trials, retried):
        now = self.clock.now
        missed = req.deadline is not None and now > float(req.deadline)
        self.results[req_id] = RequestResult(
            req_id=req_id, tag=req.tag,
            z_final=np.asarray(z_final, np.float32),
            status=int(status),
            ok=(int(status) == SolveStatus.OK) and not missed,
            deadline_missed=missed,
            t_arrival=float(t_arrival), t_admitted=float(t_admitted),
            t_finished=now, n_chunks=int(n_chunks),
            n_trials=int(n_trials), retried=bool(retried))

    def _admit(self) -> None:
        """Fill free slots from the queue (continuous), or only when the
        whole batch is free (static baseline).  Queue-expired requests
        are dropped here with ``STATUS_DEADLINE_MISS``."""
        if self.cfg.static_batch and any(s.active for s in self.slots):
            return
        for slot in self.slots:
            if slot.active:
                continue
            while True:
                item = self.queue.pop_ready(self.clock.now)
                if item is None:
                    break
                arrival, rid, req = item
                if (req.deadline is not None
                        and self.clock.now > float(req.deadline)):
                    self._record(
                        rid, req, z_final=req.z0,
                        status=STATUS_DEADLINE_MISS,
                        t_arrival=arrival, t_admitted=self.clock.now,
                        n_chunks=0, n_trials=0, retried=False)
                    continue
                slot.active = True
                slot.req_id = rid
                slot.req = req
                slot.z = np.asarray(req.z0, np.float32)
                slot.tau = float(req.t0)
                slot.t_arrival = arrival
                slot.t_admitted = self.clock.now
                slot.n_chunks = 0
                slot.n_trials = 0
                # a re-enqueued retry keeps its flag via the tag below
                slot.retried = getattr(req, "_retried", False)
                slot.first_chunk = True
                self.admission_log.append((self.round, slot.index, rid))
                break

    def _build_batch(self):
        """Assemble the (S, dim+2) canonical chunk batch + row tols/h0."""
        S, D = self.cfg.slots, self.dim
        Z = np.zeros((S, D + 2), np.float32)
        rt = np.full((S,), _EMPTY_RTOL, np.float32)
        at = np.full((S,), _EMPTY_ATOL, np.float32)
        h0 = np.full((S,), _EMPTY_H0, np.float32)
        deltas = np.zeros((S,), np.float64)
        need_hinit = []
        for slot in self.slots:
            if not slot.active:
                continue
            req = slot.req
            delta = min(self.cfg.chunk_dt, float(req.t1) - slot.tau)
            deltas[slot.index] = delta
            Z[slot.index, :D] = slot.z
            Z[slot.index, D] = np.float32(slot.tau)
            Z[slot.index, D + 1] = np.float32(delta)
            rt[slot.index] = np.float32(req.rtol)
            at[slot.index] = np.float32(req.atol)
            if slot.first_chunk and req.h0 is not None:
                # request h0 is physical time; the canonical solve runs
                # over s ∈ [0, 1], so scale by 1/delta (clipped to one
                # whole chunk).
                h0[slot.index] = np.float32(
                    min(float(req.h0) / delta, 1.0))
            else:
                need_hinit.append(slot.index)
        if need_hinit:
            hh = np.asarray(self._hinit(
                jnp.asarray(Z), jnp.asarray(rt), jnp.asarray(at)),
                np.float32)
            for i in need_hinit:
                h0[i] = hh[i]
        return Z, rt, at, h0, deltas

    def _retire(self, slot: _Slot, z_end_row, status: int,
                deltas) -> None:
        """Apply the chunk outcome to one slot: advance, complete, or
        swap out per the request's failure policy."""
        req = slot.req
        D = self.dim
        if status != SolveStatus.OK:
            if req.on_failure == "retry" and not slot.retried:
                # Re-enqueue once from the failed chunk's *start* state
                # at loosened tolerances; arrival stays the original so
                # latency accounting charges the retry.
                fac = self.cfg.retry_tol_factor
                retry = replace(
                    req, z0=np.asarray(slot.z, np.float32),
                    t0=slot.tau,
                    rtol=float(req.rtol) * fac,
                    atol=float(req.atol) * fac,
                    h0=None)
                retry._retried = True
                self.queue.push(slot.t_arrival, retry,
                                req_id=slot.req_id)
            else:
                self._record(
                    slot.req_id, req, z_final=z_end_row[:D],
                    status=status, t_arrival=slot.t_arrival,
                    t_admitted=slot.t_admitted,
                    n_chunks=slot.n_chunks, n_trials=slot.n_trials,
                    retried=slot.retried)
            slot.active = False
            slot.req = None
            return
        slot.z = np.asarray(z_end_row[:D], np.float32)
        slot.tau = slot.tau + float(deltas[slot.index])
        slot.first_chunk = False
        horizon = float(req.t1) - float(req.t0)
        if slot.tau >= float(req.t1) - 1e-9 * max(1.0, abs(horizon)):
            self._record(
                slot.req_id, req, z_final=slot.z,
                status=SolveStatus.OK, t_arrival=slot.t_arrival,
                t_admitted=slot.t_admitted,
                n_chunks=slot.n_chunks, n_trials=slot.n_trials,
                retried=slot.retried)
            slot.active = False
            slot.req = None

    def step(self) -> bool:
        """One coalescing round.  Returns False when fully drained."""
        self._admit()
        if not any(s.active for s in self.slots):
            nxt = self.queue.next_arrival()
            if nxt is None:
                return False
            self.clock.jump_to(nxt)
            self._admit()
            if not any(s.active for s in self.slots):
                # queue held only expired-deadline requests
                return len(self.queue) > 0 or bool(
                    any(s.active for s in self.slots))
        Z, rt, at, h0, deltas = self._build_batch()
        z_end, status, trials = self._solve(
            jnp.asarray(Z), jnp.asarray(rt), jnp.asarray(at),
            jnp.asarray(h0))
        z_end = np.asarray(z_end, np.float32)
        status = np.asarray(status)
        trials = np.asarray(trials)
        live = [s for s in self.slots if s.active]
        self.occupancy_log.append(len(live))
        self.clock.advance_round(int(trials.max()))
        for slot in live:
            slot.n_chunks += 1
            slot.n_trials += int(trials[slot.index])
        self.round += 1
        for slot in live:
            self._retire(slot, z_end[slot.index],
                         int(status[slot.index]), deltas)
        return True

    def run(self, max_rounds: int = 100_000) -> List[RequestResult]:
        """Drain the queue; returns results ordered by ``req_id``."""
        for _ in range(max_rounds):
            if not self.step():
                break
        else:
            raise RuntimeError(
                f"engine did not drain within {max_rounds} rounds")
        return [self.results[k] for k in sorted(self.results)]
