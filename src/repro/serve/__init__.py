"""repro.serve — batched prefill + decode serving engine."""

from .engine import ServeEngine, ServeConfig

__all__ = ["ServeEngine", "ServeConfig"]
