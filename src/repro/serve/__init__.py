"""repro.serve — serving engines.

* ``ServeEngine`` — batched prefill + decode LM serving.
* ``NodeServeEngine`` — continuous-batching NODE solve serving with
  per-request tolerance QoS (see ``docs/serving.md``).
"""

from .engine import ServeEngine, ServeConfig
from .node_engine import (
    STATUS_DEADLINE_MISS,
    NodeEngineConfig,
    NodeRequest,
    NodeServeEngine,
    RequestQueue,
    RequestResult,
    augment_field,
    augment_state,
)

__all__ = [
    "ServeEngine",
    "ServeConfig",
    "STATUS_DEADLINE_MISS",
    "NodeEngineConfig",
    "NodeRequest",
    "NodeServeEngine",
    "RequestQueue",
    "RequestResult",
    "augment_field",
    "augment_state",
]
