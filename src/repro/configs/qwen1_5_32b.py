"""Qwen1.5-32B  [dense]  — 64L d_model=5120 40H (GQA kv=40, i.e. MHA)
d_ff=27392 vocab=152064, QKV bias.  [hf:Qwen/Qwen1.5-0.5B; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1e6,
    act="silu",
    norm="rmsnorm",
)

SMOKE = CONFIG.scaled(
    name="qwen1.5-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160, vocab=512)
