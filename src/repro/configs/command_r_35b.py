"""Command R 35B  [dense]  — 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000, no-bias, parallel block, tied embeddings.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab=256000,
    qkv_bias=False,
    rope_theta=8e6,
    act="silu",
    norm="layernorm",
    norm_eps=1e-5,
    parallel_block=True,
    tie_embeddings=True,
)

SMOKE = CONFIG.scaled(
    name="command-r-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=160, vocab=512)
