"""repro.configs — one module per assigned architecture + registry.

    from repro.configs import get_config, get_smoke_config, ARCHS, SHAPES

Every ``<arch>.py`` exports ``CONFIG`` (the exact published dims) and
``SMOKE`` (a reduced same-family config for CPU smoke tests).  ``SHAPES``
maps the assignment's input-shape names to (seq_len, global_batch, kind);
``shape_plan(arch, shape)`` resolves skips (long_500k is sub-quadratic
archs only — see DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import importlib
from typing import Dict, Optional, Tuple

from repro.models.config import ModelConfig

ARCHS = (
    "qwen1_5_32b",
    "qwen2_72b",
    "command_r_plus_104b",
    "command_r_35b",
    "deepseek_moe_16b",
    "qwen3_moe_235b_a22b",
    "llava_next_34b",
    "musicgen_medium",
    "recurrentgemma_9b",
    "mamba2_2_7b",
    # the paper's own model family (NODE-mode image classifier)
    "node18_cifar",
)

# assignment shape table: name -> (seq_len, global_batch, step kind)
SHAPES: Dict[str, Tuple[int, int, str]] = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

# archs with sub-quadratic attention that run long_500k
LONG_CONTEXT_ARCHS = ("recurrentgemma_9b", "mamba2_2_7b")


def _norm(name: str) -> str:
    return name.lower().replace("-", "_").replace(".", "_")


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_norm(name)}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_norm(name)}")
    return mod.SMOKE


def shape_plan(arch: str, shape: str) -> Optional[Tuple[int, int, str]]:
    """(seq_len, global_batch, kind) or None if the cell is skipped."""
    arch = _norm(arch)
    if shape not in SHAPES:
        raise KeyError(f"unknown shape {shape!r}; have {sorted(SHAPES)}")
    if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return None    # full-attention archs skip 500k (see DESIGN.md)
    return SHAPES[shape]
