"""LLaVA-NeXT 34B  [vlm]  — backbone 60L d_model=7168 56H (GQA kv=8)
d_ff=20480 vocab=64000; anyres tiling frontend is a STUB supplying
precomputed patch embeddings (``input_specs`` provides ``embeds``).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    qkv_bias=False,
    rope_theta=5e6,
    act="silu",
    norm="rmsnorm",
    frontend="vlm",
)

SMOKE = CONFIG.scaled(
    name="llava-next-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=160, vocab=512)
