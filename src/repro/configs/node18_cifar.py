"""NODE18 — the paper's own model family (Sec. 4.2).

The paper converts ResNet18's residual blocks into ODE blocks with the
same parameter count (Eq. 30 → 31) and trains with HeunEuler at
rtol=atol=1e-2 (Appendix D).  Offline, the image task is replaced by the
spiral classification stand-in (``repro.data.spiral_classification``);
here we keep a transformer-backbone counterpart so NODE mode exercises
the very same stack the LM archs use — this is the config the NODE-mode
dry-run rows lower.

``CONFIG`` is a ~100M-param continuous-depth LM; ``SMOKE`` the reduced
version.  NODE mode itself is switched on through ``RunConfig.node``;
``NODE_TRAIN`` is the paper-matching NodeConfig for this arch (HeunEuler,
rtol=atol=1e-2, ACA) with the fused flat-state Pallas solver path on —
on TPU the per-trial stage combine + error norm run as fused kernels,
elsewhere they run in interpret mode."""

from repro.core.node_block import NodeConfig
from repro.models.config import ModelConfig

NODE_TRAIN = NodeConfig(
    enabled=True,
    solver="heun_euler",
    grad_method="aca",
    rtol=1e-2,
    atol=1e-2,
    use_pallas=True,
    # O(sqrt(max_steps))-state ACA checkpointing: gradients are
    # unchanged (the backward re-integrates from coarse snapshots with
    # the saved stepsizes), the block's state-checkpoint memory drops
    # from O(max_steps) to O(2*sqrt(max_steps)) per solve
    checkpoint_segments="auto",
)

# Reversible-integrator variant: the asynchronous-leapfrog pair stepper
# with O(1)-state-memory exact-reverse gradients (grad_method="mali") —
# per-solve state memory drops to O(dim) regardless of step count, at
# one field evaluation per trial.  Same tolerance as the paper's setup;
# ALF is 2nd order like HeunEuler's advancing method, so the accepted
# grids are comparable.  See docs/method-selection.md for the
# memory/accuracy/wall-clock trade against NODE_TRAIN.
NODE_TRAIN_MALI = NodeConfig(
    enabled=True,
    solver="alf",
    grad_method="mali",
    rtol=1e-2,
    atol=1e-2,
    use_pallas=True,
)

CONFIG = ModelConfig(
    name="node18-cifar",
    family="dense",
    n_layers=18,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=32768,
    rope_theta=1e4,
    act="silu",
    norm="rmsnorm",
)

SMOKE = CONFIG.scaled(
    name="node18-smoke",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160, vocab=512)
