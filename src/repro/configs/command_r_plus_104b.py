"""Command R+ 104B  [dense]  — 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000, no-bias, parallel attn+ffn block, tied
embeddings, LayerNorm.  [hf:CohereForAI/c4ai-command-r-v01; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab=256000,
    qkv_bias=False,
    rope_theta=75e6,
    act="silu",
    norm="layernorm",
    norm_eps=1e-5,
    parallel_block=True,
    tie_embeddings=True,
)

SMOKE = CONFIG.scaled(
    name="command-r-plus-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=160, vocab=512)
