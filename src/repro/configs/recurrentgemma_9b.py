"""RecurrentGemma-9B  [hybrid]  — 38L d_model=4096 16H (MQA kv=1)
d_ff=12288 vocab=256000; RG-LRU + local attention at 1:2
(pattern rec,rec,attn; window 2048).  [arXiv:2402.19427; unverified]

38 = 12 × (rec, rec, attn) + 2 trailing rec layers; the stack scans the
12 repeating groups and applies the tail unscanned."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    window=2048,
    pattern=("rec", "rec", "attn"),
    d_rnn=4096,
    conv_width=4,
    rope_theta=1e4,
    act="gelu",
    norm="rmsnorm",
)

SMOKE = CONFIG.scaled(
    name="recurrentgemma-smoke",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=160, vocab=512, window=16, d_rnn=64)
