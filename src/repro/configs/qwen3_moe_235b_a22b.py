"""Qwen3-MoE 235B-A22B  [moe]  — 94L d_model=4096 64H (GQA kv=4,
head_dim=128) expert d_ff=1536 vocab=151936; 128 experts top-8, no
shared experts.  [hf:Qwen/Qwen3-30B-A3B; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab=151936,
    n_experts=128,
    n_shared_experts=0,
    top_k=8,
    d_expert=1536,
    capacity_factor=1.25,
    rope_theta=1e6,
    act="silu",
    norm="rmsnorm",
)

SMOKE = CONFIG.scaled(
    name="qwen3-moe-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab=512, n_experts=8, top_k=2, d_expert=96)
