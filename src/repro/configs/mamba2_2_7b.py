"""Mamba2-2.7B  [ssm]  — 64L d_model=2560 (attention-free) vocab=50280,
ssm_state=128, SSD (state-space duality) with chunk 256, expand 2,
head_dim 64 (80 SSM heads).  [arXiv:2405.21060; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    vocab=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    ssm_conv=4,
    ssm_ngroups=1,
    norm="rmsnorm",
)

SMOKE = CONFIG.scaled(
    name="mamba2-smoke",
    n_layers=3, d_model=64, vocab=512, ssm_state=16, ssm_head_dim=16,
    ssm_chunk=16)
