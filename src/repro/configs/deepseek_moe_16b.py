"""DeepSeek-MoE 16B  [moe]  — 28L d_model=2048 16H (kv=16) expert
d_ff=1408 vocab=102400; 2 shared + 64 routed experts, top-6
(fine-grained expert segmentation).  [arXiv:2401.06066; hf]

Per the assignment spec all 28 layers are MoE (the HF release keeps
layer 0 dense; the uniform stack matches the given table and keeps
scan-over-layers exact — noted in DESIGN.md)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    d_expert=1408,
    capacity_factor=1.25,
    rope_theta=1e4,
    act="silu",
    norm="rmsnorm",
)

SMOKE = CONFIG.scaled(
    name="deepseek-moe-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96, vocab=512,
    n_experts=8, n_shared_experts=1, top_k=2, d_expert=96)
