"""MusicGen-medium  [audio]  — decoder-only over EnCodec tokens:
48L d_model=1536 24H (kv=24, MHA) d_ff=6144 vocab=2048 (codebook size).
The EnCodec frontend is a STUB supplying precomputed frame embeddings.
[arXiv:2306.05284; hf]

MusicGen uses a plain (non-gated) GeLU FFN."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="dense",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,
    qkv_bias=False,
    rope_theta=1e4,
    act="gelu",
    norm="layernorm",
    norm_eps=1e-5,
    frontend="audio",
)

SMOKE = CONFIG.scaled(
    name="musicgen-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160, vocab=256)
