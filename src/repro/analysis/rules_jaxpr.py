"""Jaxpr-level rule passes over traced solver entry points.

Four passes, each the static twin of an invariant PRs 1-7 established at
runtime:

* ``residual-budget`` — walk every engine ``custom_vjp``'s residuals and
  gate their symbolic byte count: ACA at O((K + N/K)·dim), MALI at
  O(1)-state, adjoint at O(dim·n_eval).  The static twin of
  ``bench_memory``/``bench_mali_memory``, applied to *every* config.
* ``collective-in-loop`` — no ``psum``/``all_gather``/... primitive may
  appear inside a ``while``/``scan`` body (the PR 7 roofline assumption:
  the only collective is the one args-cotangent psum *outside* the
  solve loop, inserted by shard_map's transpose).
* ``dtype-contract`` — no weak-typed floating loop carries and no
  implicit f32↔f64 promotion inside loop bodies (the PR 4 bug class:
  weak-type time arithmetic silently truncating eval times).
* ``host-sync`` — no ``debug_callback``/``io_callback``/``pure_callback``
  in a loop body; outside loops, only the documented
  ``on_failure="warn"`` site in ``core/api.py`` may call back to host.
"""

from __future__ import annotations

from typing import Iterable, List

from .findings import Finding
from .jaxpr_walk import (
    engine_custom_vjp_eqns,
    eqn_provenance,
    iter_eqns,
    residual_info,
)

COLLECTIVE_PRIMS = frozenset(
    {
        "psum",
        "all_gather",
        "psum_scatter",
        "reduce_scatter",
        "all_to_all",
        "ppermute",
        "pmax",
        "pmin",
        "pmean",
    }
)

CALLBACK_PRIMS = frozenset({"debug_callback", "io_callback", "pure_callback"})

#: the one file whose module-level code may emit host callbacks outside
#: loops: ``_apply_on_failure``'s documented ``jax.debug.print`` warn site
HOST_SYNC_ALLOWED_FILES = ("core/api.py",)


def check_collectives(closed, config_name: str) -> List[Finding]:
    """No collective primitive inside a ``while``/``scan`` body."""
    out = []
    for eqn, depth in iter_eqns(closed):
        if eqn.primitive.name in COLLECTIVE_PRIMS and depth > 0:
            path, line = eqn_provenance(eqn)
            out.append(
                Finding(
                    rule="collective-in-loop",
                    path=path,
                    line=line,
                    message=(
                        f"[{config_name}] collective '{eqn.primitive.name}' at "
                        f"loop depth {depth}: per-iteration collectives break "
                        "the shard-local-sweep roofline"
                    ),
                    snippet=f"{config_name}:{eqn.primitive.name}",
                )
            )
    return out


def check_host_sync(closed, config_name: str) -> List[Finding]:
    """No host callbacks in loop bodies; elsewhere only the documented site."""
    out = []
    for eqn, depth in iter_eqns(closed):
        if eqn.primitive.name not in CALLBACK_PRIMS:
            continue
        path, line = eqn_provenance(eqn)
        if depth > 0:
            out.append(
                Finding(
                    rule="host-sync",
                    path=path,
                    line=line,
                    message=(
                        f"[{config_name}] host callback "
                        f"'{eqn.primitive.name}' at loop depth {depth}: "
                        "host round-trips serialize the hot loop"
                    ),
                    snippet=f"{config_name}:{eqn.primitive.name}",
                )
            )
        elif not any(path.endswith(allowed) for allowed in HOST_SYNC_ALLOWED_FILES):
            out.append(
                Finding(
                    rule="host-sync",
                    path=path,
                    line=line,
                    message=(
                        f"[{config_name}] host callback "
                        f"'{eqn.primitive.name}' outside the documented "
                        'on_failure="warn" site in core/api.py'
                    ),
                    snippet=f"{config_name}:{eqn.primitive.name}",
                )
            )
    return out


def _loop_carry_invars(eqn):
    """The carried invars of a ``while``/``scan`` eqn's body jaxpr."""
    name = eqn.primitive.name
    if name == "while":
        body = eqn.params["body_jaxpr"].jaxpr
        ncons = eqn.params["body_nconsts"]
        return body.invars[ncons:]
    if name == "scan":
        body = eqn.params["jaxpr"].jaxpr
        ncons = eqn.params["num_consts"]
        ncarry = eqn.params["num_carry"]
        return body.invars[ncons : ncons + ncarry]
    return []


def check_dtype_contract(closed, config_name: str) -> List[Finding]:
    """No weak-typed floating loop carries; no f32↔f64 casts inside loops."""
    import jax.numpy as jnp

    out = []
    for eqn, depth in iter_eqns(closed):
        name = eqn.primitive.name
        if name in ("while", "scan"):
            for i, var in enumerate(_loop_carry_invars(eqn)):
                aval = var.aval
                dtype = getattr(aval, "dtype", None)
                if (
                    dtype is not None
                    and jnp.issubdtype(dtype, jnp.floating)
                    and getattr(aval, "weak_type", False)
                ):
                    path, line = eqn_provenance(eqn)
                    out.append(
                        Finding(
                            rule="dtype-contract",
                            path=path,
                            line=line,
                            message=(
                                f"[{config_name}] weak-typed floating carry "
                                f"#{i} ({dtype}) in '{name}' body: weak types "
                                "let x64 promotion change time arithmetic "
                                "silently"
                            ),
                            snippet=f"{config_name}:weak-carry:{name}",
                        )
                    )
        elif name == "convert_element_type" and depth > 0:
            src = getattr(eqn.invars[0].aval, "dtype", None)
            dst = eqn.params.get("new_dtype")
            if (
                src is not None
                and dst is not None
                and jnp.issubdtype(src, jnp.floating)
                and jnp.issubdtype(dst, jnp.floating)
                and jnp.dtype(src).itemsize != jnp.dtype(dst).itemsize
            ):
                path, line = eqn_provenance(eqn)
                out.append(
                    Finding(
                        rule="dtype-contract",
                        path=path,
                        line=line,
                        message=(
                            f"[{config_name}] implicit {jnp.dtype(src).name}->"
                            f"{jnp.dtype(dst).name} cast at loop depth "
                            f"{depth}: mixed-precision time arithmetic"
                        ),
                        snippet=f"{config_name}:cast:{jnp.dtype(src).name}->"
                        f"{jnp.dtype(dst).name}",
                    )
                )
    return out


def check_residual_budget(closed, config) -> List[Finding]:
    """Gate each engine ``custom_vjp``'s symbolic residual bytes.

    ``config`` is a :class:`repro.analysis.entry_points.SolveConfig`;
    its ``residual_budget_bytes`` encodes the per-method memory claim.
    Returns one finding per over-budget engine, with a per-leaf byte
    breakdown so the offending buffer is named.
    """
    budget = config.residual_budget_bytes()
    if budget is None:  # naive: no engine custom_vjp to audit
        return []
    out = []
    eqns = list(engine_custom_vjp_eqns(closed))
    if not eqns:
        out.append(
            Finding(
                rule="residual-budget",
                path=config.name,
                line=0,
                message=(
                    f"[{config.name}] no engine custom_vjp found in forward "
                    "trace: the residual auditor has lost sight of the "
                    f"'{config.grad_method}' engine boundary"
                ),
                snippet=f"{config.name}:missing-custom-vjp",
            )
        )
        return out
    for eqn in eqns:
        info = residual_info(eqn)
        total = info.total_bytes
        if total > budget:
            top = sorted(
                info.bytes_by_leaf().items(), key=lambda kv: -kv[1]
            )[:4]
            detail = ", ".join(f"{k}={v}B" for k, v in top)
            out.append(
                Finding(
                    rule="residual-budget",
                    path=info.path,
                    line=info.line,
                    message=(
                        f"[{config.name}] residual bytes {total} exceed the "
                        f"{config.grad_method} budget {budget} "
                        f"(slots={config.state_slots()}, dim={config.dim}); "
                        f"largest leaves: {detail}"
                    ),
                    snippet=f"{config.name}:residual-budget",
                )
            )
    return out


def static_residual_bytes(config) -> int:
    """Total symbolic residual bytes of a config's forward trace.

    Exposed for the cost cross-check against ``launch/hlo_cost``'s
    measured ``bytes_min`` numbers.
    """
    closed = config.forward_trace()
    return sum(residual_info(e).total_bytes for e in engine_custom_vjp_eqns(closed))


def analyze_config(config) -> List[Finding]:
    """Run all four passes over one config (two traces)."""
    findings: List[Finding] = []
    fwd = config.forward_trace()
    findings += check_residual_budget(fwd, config)
    for closed in (fwd, config.grad_trace()):
        findings += check_collectives(closed, config.name)
        findings += check_host_sync(closed, config.name)
        findings += check_dtype_contract(closed, config.name)
    return findings


def analyze_matrix(configs: Iterable) -> List[Finding]:
    findings: List[Finding] = []
    for cfg in configs:
        findings += analyze_config(cfg)
    return findings
