"""Findings, reports, and the baseline/suppression mechanism for solver-lint.

Every static-analysis rule (jaxpr passes and AST passes alike) emits
:class:`Finding` records with file:line provenance.  A findings report is
just a sorted list of findings rendered one-per-line; CI fails on any
finding that is not matched by an entry in the baseline file.

Baseline entries suppress *intentional* exceptions and must carry a written
justification.  Matching is by (rule, path-suffix, source-substring) rather
than line number so the baseline survives unrelated edits to the file.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Iterable, Sequence


@dataclass(frozen=True)
class Finding:
    """One rule violation with provenance.

    ``path`` is repo-relative when the rule can produce one (AST rules),
    or the traceback file name for jaxpr rules.  ``line`` is 1-indexed;
    0 means "no line available" (e.g. a whole-config budget violation).
    ``snippet`` is the stripped source line (or a symbolic description for
    jaxpr findings) used for baseline matching.
    """

    rule: str
    path: str
    line: int
    message: str
    snippet: str = ""

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: [{self.rule}] {self.message}"


@dataclass(frozen=True)
class BaselineEntry:
    rule: str
    path: str
    match: str
    justification: str

    def covers(self, f: Finding) -> bool:
        if f.rule != self.rule:
            return False
        if not f.path.endswith(self.path):
            return False
        hay = f.snippet or f.message
        return self.match in hay


@dataclass
class Report:
    """Accumulated findings plus the baseline that filters them."""

    findings: list[Finding] = field(default_factory=list)
    baseline: Sequence[BaselineEntry] = ()

    def add(self, finding: Finding) -> None:
        self.findings.append(finding)

    def extend(self, findings: Iterable[Finding]) -> None:
        self.findings.extend(findings)

    def active(self) -> list[Finding]:
        """Findings not covered by any baseline entry."""
        out = []
        for f in self.findings:
            if not any(b.covers(f) for b in self.baseline):
                out.append(f)
        return out

    def suppressed(self) -> list[Finding]:
        return [f for f in self.findings if any(b.covers(f) for b in self.baseline)]

    def stale_baseline(self) -> list[BaselineEntry]:
        """Baseline entries that no longer match any finding (candidates for removal)."""
        return [b for b in self.baseline if not any(b.covers(f) for f in self.findings)]

    def render(self, *, verbose: bool = False) -> str:
        lines = []
        act = sorted(self.active(), key=lambda f: (f.path, f.line, f.rule))
        for f in act:
            lines.append(f.render())
        sup = self.suppressed()
        if verbose:
            for f in sorted(sup, key=lambda f: (f.path, f.line, f.rule)):
                lines.append(f"suppressed {f.render()}")
        lines.append(
            f"solver-lint: {len(act)} finding(s), {len(sup)} suppressed by baseline"
        )
        return "\n".join(lines)

    @property
    def ok(self) -> bool:
        return not self.active()


def load_baseline(path: str) -> list[BaselineEntry]:
    """Load the baseline/suppression file (JSON list of entries).

    Each entry must provide ``rule``, ``path``, ``match``, and a non-empty
    ``justification`` — suppressions without a written justification are a
    hard error so the baseline can't silently accrete.
    """
    with open(path, "r", encoding="utf-8") as fh:
        raw = json.load(fh)
    if not isinstance(raw, list):
        raise ValueError(f"baseline file {path!r} must be a JSON list of entries")
    entries = []
    for i, item in enumerate(raw):
        if not isinstance(item, dict):
            raise ValueError(f"baseline entry {i} in {path!r} is not an object")
        missing = {"rule", "path", "match", "justification"} - set(item)
        if missing:
            raise ValueError(
                f"baseline entry {i} in {path!r} missing keys: {sorted(missing)}"
            )
        if not str(item["justification"]).strip():
            raise ValueError(
                f"baseline entry {i} in {path!r} has an empty justification; "
                "every suppression must say why it is intentional"
            )
        entries.append(
            BaselineEntry(
                rule=str(item["rule"]),
                path=str(item["path"]),
                match=str(item["match"]),
                justification=str(item["justification"]),
            )
        )
    return entries
