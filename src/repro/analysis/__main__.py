"""CLI for the jaxpr analyzer layer: ``python -m repro.analysis``.

Traces the registered entry-point matrix (or a ``--configs`` subset)
without executing anything and runs the four jaxpr rule passes.  Exits
nonzero on any finding not covered by the baseline file.  Pallas
configs trace in interpret mode, so no accelerator is needed.
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jaxpr static analysis over the solver entry-point matrix",
    )
    parser.add_argument(
        "--configs",
        default=None,
        help="comma-separated config names (default: the full matrix); "
        "see --list",
    )
    parser.add_argument(
        "--list", action="store_true", help="list registered config names and exit"
    )
    parser.add_argument(
        "--baseline",
        default=None,
        help="baseline/suppression JSON (default: tools/solver_lint_baseline.json "
        "if present)",
    )
    parser.add_argument(
        "--report", default=None, help="also write the findings report to this file"
    )
    parser.add_argument(
        "-v", "--verbose", action="store_true", help="show suppressed findings too"
    )
    args = parser.parse_args(argv)

    # pallas configs must trace in interpret mode off-accelerator
    os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")

    from repro.analysis import (
        MATRIX,
        Report,
        analyze_config,
        config_names,
        get_config,
        load_baseline,
    )

    if args.list:
        print("\n".join(config_names()))
        return 0

    baseline = ()
    baseline_path = args.baseline
    if baseline_path is None:
        default = os.path.join("tools", "solver_lint_baseline.json")
        baseline_path = default if os.path.exists(default) else None
    if baseline_path:
        baseline = load_baseline(baseline_path)

    if args.configs:
        configs = [get_config(n.strip()) for n in args.configs.split(",") if n.strip()]
    else:
        configs = list(MATRIX)

    report = Report(baseline=baseline)
    for cfg in configs:
        t0 = time.monotonic()
        report.extend(analyze_config(cfg))
        dt = time.monotonic() - t0
        print(f"analyzed {cfg.name} ({dt:.1f}s)", file=sys.stderr)

    text = report.render(verbose=args.verbose)
    print(text)
    if args.report:
        with open(args.report, "w", encoding="utf-8") as fh:
            fh.write(text + "\n")
    return 0 if report.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
