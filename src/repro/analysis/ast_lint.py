"""AST-level repo lint: solver-stack rules plain grep can't state.

Rules (each one is a bug class a previous PR actually hit):

* ``shard-map-direct`` — calling ``jax.shard_map`` /
  ``jax.experimental.shard_map`` anywhere except the version-compat
  wrapper ``repro.distributed.sharding.shard_map_compat`` (the PR 7 bug
  class: the raw API's signature differs across the pinned jax line).
* ``bare-assert`` — ``assert`` used for validation: asserts vanish
  under ``python -O`` and produce unnamed errors; user-reachable checks
  must raise named ValueErrors (the ``elastic_mesh`` bug class).
  Internal kernel-wrapper invariants may be baselined with justification.
* ``jit-host-leak`` — ``.item()``, ``np.``-namespace calls, or
  ``float(...)``/``int(...)`` applied to computed values inside the
  jitted engine modules: these force a host sync or silently freeze a
  traced value at trace time.  Static (trace-time) index-plan
  construction is the intentional exception, baselined per site.
* ``registry-drift`` — string literals in ``core/api.py`` (defaults,
  comparisons, fallback-ladder rungs, ``get_tableau`` calls) that no
  longer resolve against the live ``GRAD_METHODS`` /
  ``ON_FAILURE_POLICIES`` / tableau registries.
"""

from __future__ import annotations

import ast
import os
from typing import Iterable, List

from .findings import Finding

#: files allowed to touch the raw shard_map API
SHARD_MAP_COMPAT_FILES = ("distributed/sharding.py",)

#: modules whose function bodies run inside jit on the solve hot path
ENGINE_FILE_SUFFIXES = tuple(
    f"core/{m}.py"
    for m in (
        "integrate",
        "stepper",
        "controller",
        "odeint_aca",
        "odeint_adjoint",
        "odeint_naive",
        "odeint_mali",
    )
)

#: solver names dispatched at the api level rather than the tableau registry
NON_TABLEAU_SOLVERS = frozenset({"alf"})


def _rel(path: str, root: str) -> str:
    try:
        return os.path.relpath(path, root)
    except ValueError:
        return path


def _norm(path: str) -> str:
    return path.replace(os.sep, "/")


def _is_engine_file(path: str) -> bool:
    p = _norm(path)
    return p.endswith(ENGINE_FILE_SUFFIXES) or (
        "/kernels/" in p and p.endswith(".py") and not p.endswith("__init__.py")
    )


def _source_line(lines: List[str], lineno: int) -> str:
    if 1 <= lineno <= len(lines):
        return lines[lineno - 1].strip()
    return ""


# ---------------------------------------------------------------------------
# per-file rules


def _check_shard_map_direct(tree, rel, lines) -> List[Finding]:
    if _norm(rel).endswith(SHARD_MAP_COMPAT_FILES):
        return []
    out = []

    def hit(node, what):
        out.append(
            Finding(
                rule="shard-map-direct",
                path=rel,
                line=node.lineno,
                message=(
                    f"{what}: call shard_map only through "
                    "repro.distributed.sharding.shard_map_compat (the raw "
                    "API's signature differs across jax versions)"
                ),
                snippet=_source_line(lines, node.lineno),
            )
        )

    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if "shard_map" in mod:
                hit(node, f"direct import from {mod!r}")
            elif mod == "jax" and any(a.name == "shard_map" for a in node.names):
                hit(node, "direct import of jax.shard_map")
        elif isinstance(node, ast.Import):
            for alias in node.names:
                if "shard_map" in alias.name:
                    hit(node, f"direct import of {alias.name!r}")
        elif isinstance(node, ast.Attribute) and node.attr == "shard_map":
            # jax.shard_map / jax.experimental.shard_map.shard_map
            base = node.value
            dotted = []
            while isinstance(base, ast.Attribute):
                dotted.append(base.attr)
                base = base.value
            if isinstance(base, ast.Name) and base.id == "jax":
                hit(node, "direct jax shard_map attribute access")
    return out


def _check_bare_assert(tree, rel, lines) -> List[Finding]:
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Assert):
            out.append(
                Finding(
                    rule="bare-assert",
                    path=rel,
                    line=node.lineno,
                    message=(
                        "bare assert: validation must raise a named "
                        "ValueError (asserts vanish under python -O); "
                        "baseline internal invariants with justification"
                    ),
                    snippet=_source_line(lines, node.lineno),
                )
            )
    return out


def _check_jit_host_leak(tree, rel, lines) -> List[Finding]:
    if not _is_engine_file(rel):
        return []
    out = []

    def hit(node, what):
        out.append(
            Finding(
                rule="jit-host-leak",
                path=rel,
                line=node.lineno,
                message=(
                    f"{what} in a jitted engine module: host syncs or "
                    "trace-time freezes of traced values; baseline "
                    "intentional static index plans with justification"
                ),
                snippet=_source_line(lines, node.lineno),
            )
        )

    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Attribute) and fn.attr == "item":
                hit(node, ".item() call")
            elif (
                isinstance(fn, ast.Name)
                and fn.id in ("float", "int")
                and node.args
                and isinstance(node.args[0], (ast.Call, ast.Subscript))
            ):
                hit(node, f"{fn.id}() applied to a computed value")
        elif isinstance(node, ast.Attribute):
            base = node.value
            if isinstance(base, ast.Name) and base.id in ("np", "numpy"):
                hit(node, f"numpy host op 'np.{node.attr}'")
    return out


def _collect_solver_strings(value) -> List[ast.Constant]:
    """Constant strings an assignment can bind to a registry-named variable.

    Only literal strings and conditional chains of them count
    (``solver = "alf" if ... else "dopri5"``); strings buried in the
    condition or in arbitrary calls (``akw.get("solver")``) are not
    values being bound and are ignored.
    """
    if isinstance(value, ast.Constant) and isinstance(value.value, str):
        return [value]
    if isinstance(value, ast.IfExp):
        return _collect_solver_strings(value.body) + _collect_solver_strings(
            value.orelse
        )
    return []


def _check_registry_drift(tree, rel, lines) -> List[Finding]:
    if not _norm(rel).endswith("core/api.py"):
        return []
    from repro.core.api import GRAD_METHODS, ON_FAILURE_POLICIES
    from repro.core.tableaus import get_tableau

    def solver_ok(name: str) -> bool:
        if name in NON_TABLEAU_SOLVERS:
            return True
        try:
            get_tableau(name)
            return True
        except KeyError:
            return False

    checkers = {
        "solver": (solver_ok, "tableau registry (or 'alf')"),
        "grad_method": (lambda s: s in GRAD_METHODS, f"GRAD_METHODS={GRAD_METHODS}"),
        "on_failure": (
            lambda s: s in ON_FAILURE_POLICIES,
            f"ON_FAILURE_POLICIES={ON_FAILURE_POLICIES}",
        ),
    }

    out = []

    def hit(node, key, value):
        _ok, registry = checkers[key]
        out.append(
            Finding(
                rule="registry-drift",
                path=rel,
                line=node.lineno,
                message=(
                    f"string {value!r} for {key!r} does not resolve against "
                    f"the live {registry}"
                ),
                snippet=_source_line(lines, node.lineno),
            )
        )

    def check(node, key, value):
        ok, _ = checkers[key]
        if not ok(value):
            hit(node, key, value)

    for node in ast.walk(tree):
        if isinstance(node, ast.Dict):
            # fallback-ladder rungs: {"solver": ..., "grad_method": ...}
            for k, v in zip(node.keys, node.values):
                if (
                    isinstance(k, ast.Constant)
                    and isinstance(k.value, str)
                    and k.value in checkers
                    and isinstance(v, ast.Constant)
                    and isinstance(v.value, str)
                ):
                    check(v, k.value, v.value)
        elif isinstance(node, ast.Compare) and isinstance(node.left, ast.Name):
            key = node.left.id
            if key in checkers:
                for comp in node.comparators:
                    if isinstance(comp, ast.Constant) and isinstance(comp.value, str):
                        check(comp, key, comp.value)
        elif isinstance(node, ast.Assign):
            for target in node.targets:
                if isinstance(target, ast.Name) and target.id in checkers:
                    for const in _collect_solver_strings(node.value):
                        check(const, target.id, const.value)
        elif isinstance(node, ast.Call):
            fn = node.func
            fname = fn.id if isinstance(fn, ast.Name) else getattr(fn, "attr", "")
            if fname == "get_tableau" and node.args:
                arg = node.args[0]
                if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
                    check(arg, "solver", arg.value)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # keyword defaults like solver="dopri5", grad_method="aca"
            a = node.args
            pos = a.posonlyargs + a.args
            for arg, default in zip(pos[len(pos) - len(a.defaults) :], a.defaults):
                if (
                    arg.arg in checkers
                    and isinstance(default, ast.Constant)
                    and isinstance(default.value, str)
                ):
                    check(default, arg.arg, default.value)
            for arg, default in zip(a.kwonlyargs, a.kw_defaults):
                if (
                    default is not None
                    and arg.arg in checkers
                    and isinstance(default, ast.Constant)
                    and isinstance(default.value, str)
                ):
                    check(default, arg.arg, default.value)
    return out


RULES = (
    _check_shard_map_direct,
    _check_bare_assert,
    _check_jit_host_leak,
    _check_registry_drift,
)


def lint_file(path: str, root: str = ".") -> List[Finding]:
    rel = _norm(_rel(path, root))
    with open(path, "r", encoding="utf-8") as fh:
        source = fh.read()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as exc:
        return [
            Finding(
                rule="syntax",
                path=rel,
                line=exc.lineno or 0,
                message=f"file does not parse: {exc.msg}",
                snippet="",
            )
        ]
    lines = source.splitlines()
    findings: List[Finding] = []
    for rule in RULES:
        findings += rule(tree, rel, lines)
    return findings


def iter_python_files(paths: Iterable[str]) -> List[str]:
    """Expand files/directories into a sorted list of .py files."""
    out = []
    for p in paths:
        if os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = [d for d in dirnames if d != "__pycache__"]
                for fn in filenames:
                    if fn.endswith(".py"):
                        out.append(os.path.join(dirpath, fn))
        elif p.endswith(".py"):
            out.append(p)
    return sorted(out)


def lint_paths(paths: Iterable[str], root: str = ".") -> List[Finding]:
    findings: List[Finding] = []
    for path in iter_python_files(paths):
        findings += lint_file(path, root)
    return findings
