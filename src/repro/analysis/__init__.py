"""Static analysis for the solver stack (``solver-lint``).

Two layers prove the invariants CI's runtime tests only sample:

* :mod:`repro.analysis.rules_jaxpr` traces every registered entry-point
  configuration (:mod:`repro.analysis.entry_points`) without executing
  and checks residual-memory budgets, collective placement, dtype
  contracts, and host-sync discipline on the jaxprs.
* :mod:`repro.analysis.ast_lint` lints the repo source for the
  shard_map-compat, bare-assert, trace-time-leak, and registry-drift
  bug classes.

Run ``python -m repro.analysis`` (jaxpr layer) and
``python -m tools.solver_lint src/`` (AST layer); both honor the shared
baseline file ``tools/solver_lint_baseline.json``.  See
``docs/static-analysis.md``.
"""

from .findings import BaselineEntry, Finding, Report, load_baseline
from .entry_points import MATRIX, SolveConfig, config_names, get_config
from .rules_jaxpr import analyze_config, analyze_matrix, static_residual_bytes
from .ast_lint import lint_file, lint_paths

__all__ = [
    "BaselineEntry",
    "Finding",
    "Report",
    "load_baseline",
    "MATRIX",
    "SolveConfig",
    "config_names",
    "get_config",
    "analyze_config",
    "analyze_matrix",
    "static_residual_bytes",
    "lint_file",
    "lint_paths",
]
