"""Registered solver entry points for the static analyzer.

The analyzer proves invariants by *tracing* (never executing) every
public solve configuration: 4 gradient methods × {solo, batched} ×
{pytree, pallas-interpret} × {full, segmented checkpoints} × {plain,
mesh-sharded}, plus the documented ``on_failure="warn"`` site, the
per-row tolerance (QoS) variants, and the serving engine's canonical
chunk solve (``repro.serve.node_engine``).  Each
:class:`SolveConfig` knows how to build its undifferentiated forward
trace (where the engine ``custom_vjp`` is visible, residuals and all)
and its gradient trace (where the backward sweeps' loops and the
shard_map-transpose collectives appear).

Shapes are chosen so the residual budget is *discriminating*: the state
terms (``dim``-sized buffers) dominate the scalar grid and ``args``
bytes, so a rogue O(N·dim) buffer sneaking into MALI or segmented-ACA
residuals blows the gate rather than hiding in slack.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# configuration


@dataclass(frozen=True)
class SolveConfig:
    """One analyzable entry-point configuration."""

    name: str
    grad_method: str
    use_pallas: bool = False
    batched: bool = False
    sharded: bool = False
    segmented: bool = False
    on_failure: str = "status"
    #: per-row (batch,) rtol/atol arrays instead of scalars — the
    #: serving QoS path through the row-tol kernel dispatch
    row_tol: bool = False
    #: trace the serving engine's canonical chunk solve: the augmented
    #: [z, t_off, delta] field over s ∈ [0, 1] with explicit per-row h0
    serving: bool = False
    dim: int = 96
    batch: int = 8
    n_eval: int = 2
    max_steps: int = 64
    segments: int = 8

    def odeint_kwargs(self) -> dict:
        kw: dict = dict(
            grad_method=self.grad_method,
            max_steps=self.max_steps,
            use_pallas=self.use_pallas,
            on_failure=self.on_failure,
        )
        if self.segmented:
            kw["checkpoint_segments"] = self.segments
        if self.batched:
            kw["batch_axis"] = 0
        if self.sharded:
            from repro.distributed import shard_mesh

            kw["mesh"] = shard_mesh()
        if self.row_tol:
            kw["rtol"] = jnp.logspace(-3, -6, self.batch).astype(jnp.float32)
            kw["atol"] = jnp.logspace(-5, -8, self.batch).astype(jnp.float32)
        if self.serving:
            kw["h0"] = jnp.full((self.batch,), 0.05, jnp.float32)
        return kw

    def example_args(self) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        d = self.dim + 2 if self.serving else self.dim
        z_shape = (self.batch, d) if self.batched else (d,)
        z0 = jnp.zeros(z_shape, jnp.float32)
        w = jnp.zeros((self.dim,), jnp.float32)
        ts = jnp.linspace(0.0, 1.0, self.n_eval).astype(jnp.float32)
        return z0, w, ts

    def _solve_fn(self):
        from repro.core.api import odeint

        kw = self.odeint_kwargs()

        def field_fn(t, z, w):
            return -(w * z)

        if self.serving:
            from repro.serve.node_engine import augment_field

            field_fn = augment_field(field_fn)

        def solve(z0, w, ts):
            return odeint(field_fn, z0, ts, (w,), **kw)

        return solve

    def forward_trace(self):
        """Undifferentiated trace — engine ``custom_vjp`` residuals visible."""
        solve = self._solve_fn()
        return jax.make_jaxpr(solve)(*self.example_args())

    def grad_trace(self):
        """Gradient trace — backward loops and transpose collectives visible."""
        solve = self._solve_fn()

        def loss(z0, w, ts):
            ys, _stats = solve(z0, w, ts)
            return jnp.sum(ys)

        return jax.make_jaxpr(jax.grad(loss, argnums=(0, 1)))(*self.example_args())

    # -- residual budget ----------------------------------------------------

    #: per-element dim-sized state slots each method may keep as residuals
    #: (the paper's memory claims, in slot units):
    #:   aca full       -> max_steps          (every accepted state)
    #:   aca segmented  -> 2 * K              (K z-snapshots + K k0-snapshots)
    #:   adjoint        -> n_eval             (only the outputs ys)
    #:   mali           -> 4                  (zT, vT, z0 + slack: O(1) in steps)
    #: naive has no engine-level custom_vjp (pure autodiff tape) -> no budget.
    RESIDUAL_SLACK = 1.5
    GRID_BYTES_PER_STEP = 48  # scalar t/h/index grid allowance per accepted step

    def state_slots(self) -> Optional[int]:
        if self.grad_method == "aca":
            return 2 * self.segments if self.segmented else self.max_steps
        if self.grad_method == "adjoint":
            return self.n_eval
        if self.grad_method == "mali":
            return 4
        return None  # naive

    def residual_budget_bytes(self) -> Optional[int]:
        slots = self.state_slots()
        if slots is None:
            return None
        n_elem = self.batch if self.batched else 1
        state = slots * self.dim * 4  # f32
        grid = self.max_steps * self.GRID_BYTES_PER_STEP
        args_ts = self.dim * 4 + self.n_eval * 4 + 64
        return int(self.RESIDUAL_SLACK * n_elem * (state + grid) + args_ts + 4096)


# ---------------------------------------------------------------------------
# the matrix


def _base_configs() -> list:
    return [
        SolveConfig("aca-full", "aca"),
        SolveConfig("aca-seg", "aca", segmented=True),
        SolveConfig("adjoint", "adjoint"),
        SolveConfig("naive", "naive"),
        SolveConfig("mali", "mali"),
    ]


def build_matrix() -> list:
    """The full registered matrix (37 configs)."""
    out = []
    for base in _base_configs():
        for pallas in (False, True):
            tag = "-pallas" if pallas else ""
            solo = replace(base, name=f"{base.name}{tag}-solo", use_pallas=pallas)
            bat = replace(
                base, name=f"{base.name}{tag}-batched", use_pallas=pallas, batched=True
            )
            shd = replace(
                base,
                name=f"{base.name}{tag}-sharded",
                use_pallas=pallas,
                batched=True,
                sharded=True,
            )
            out.extend([solo, bat, shd])
    # the documented jax.debug.print warn site must stay analyzable (and
    # stay *outside* any loop body — the host-sync pass checks exactly this)
    out.append(SolveConfig("aca-full-warn", "aca", on_failure="warn"))
    # per-row tolerance (QoS) entry points: the serving stack's kernel
    # dispatch — rowtol Pallas kernel, vmapped error_ratio, per-row h0
    out.extend([
        SolveConfig("aca-full-rowtol-batched", "aca", batched=True,
                    row_tol=True),
        SolveConfig("aca-full-rowtol-pallas-batched", "aca",
                    use_pallas=True, batched=True, row_tol=True),
        SolveConfig("naive-rowtol-batched", "naive", batched=True,
                    row_tol=True),
        SolveConfig("mali-rowtol-batched", "mali", batched=True,
                    row_tol=True),
        # the serving engine's jitted chunk solve: canonical s ∈ [0, 1]
        # over augmented [z, t_off, delta] rows, per-row tol + h0
        SolveConfig("serve-chunk", "aca", batched=True, row_tol=True,
                    serving=True),
        SolveConfig("serve-chunk-mali", "mali", batched=True,
                    row_tol=True, serving=True),
    ])
    return out


MATRIX = build_matrix()
_BY_NAME = {c.name: c for c in MATRIX}


def get_config(name: str) -> SolveConfig:
    if name not in _BY_NAME:
        raise KeyError(
            f"unknown analyzer config {name!r}; registered: {sorted(_BY_NAME)}"
        )
    return _BY_NAME[name]


def config_names() -> list:
    return [c.name for c in MATRIX]
