"""Jaxpr traversal and provenance utilities for the static analyzer.

The rule passes in :mod:`repro.analysis.rules_jaxpr` need three
capabilities that plain ``jax.make_jaxpr`` output does not hand them
directly:

* **Depth-aware equation iteration** — every primitive equation in a
  closed jaxpr, recursively through sub-jaxprs (``while``/``scan``
  bodies, ``cond`` branches, ``pjit``/``shard_map``/``custom_vjp``
  callees), annotated with how many ``while``/``scan`` loop bodies
  enclose it.  "No collectives inside the solver loop" is a statement
  about loop depth, not mere presence.

* **User-frame provenance** — findings must point at the repo source
  line that introduced the offending primitive, not at jax internals.
  ``jax._src.source_info_util.user_frames`` filters the traceback down
  to non-jax frames; we take the innermost one.

* **Residual recovery from ``custom_vjp``** — in an *undifferentiated*
  forward trace, each solver engine shows up as one
  ``custom_vjp_call_jaxpr`` equation whose ``fwd_jaxpr_thunk`` can be
  forced (with all-symbolic-zero flags) to yield the forward jaxpr.
  Its outputs are ordered **residuals first, then primal outputs**, and
  ``out_trees()`` gives the residual pytree structure, so residual
  avals can be unflattened back into named leaves (``.ckpts.z`` etc.)
  without executing anything.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Iterator, Tuple

import jax
import jax.tree_util as jtu

try:  # jax 0.4.x private module; guarded so import errors degrade gracefully
    from jax._src import source_info_util
except Exception:  # pragma: no cover - exercised only on incompatible jax
    source_info_util = None


#: primitive names whose sub-jaxprs execute once per loop iteration
LOOP_PRIMS = ("while", "scan")


def _sub_jaxprs(value: Any) -> Iterator[Any]:
    """Yield every (open) jaxpr reachable from one eqn-param value."""
    if hasattr(value, "jaxpr"):  # ClosedJaxpr
        yield value.jaxpr
    elif hasattr(value, "eqns"):  # open Jaxpr
        yield value
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from _sub_jaxprs(v)


def iter_eqns(jaxpr, loop_depth: int = 0) -> Iterator[Tuple[Any, int]]:
    """Yield ``(eqn, loop_depth)`` for every equation, recursively.

    ``loop_depth`` counts enclosing ``while``/``scan`` bodies (the cond
    jaxpr of a ``while`` also runs per iteration and counts as inside).
    Accepts an open or closed jaxpr.
    """
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in jaxpr.eqns:
        yield eqn, loop_depth
        child = loop_depth + 1 if eqn.primitive.name in LOOP_PRIMS else loop_depth
        for param in eqn.params.values():
            for sub in _sub_jaxprs(param):
                yield from iter_eqns(sub, child)


def eqn_provenance(eqn) -> Tuple[str, int]:
    """Best-effort ``(file_name, line)`` of the user frame that traced ``eqn``."""
    if source_info_util is None:
        return "<unknown>", 0
    try:
        frames = list(source_info_util.user_frames(eqn.source_info))
    except Exception:
        frames = []
    if frames:
        return frames[0].file_name, frames[0].start_line
    return "<unknown>", 0


# ---------------------------------------------------------------------------
# custom_vjp residual recovery


@dataclass
class ResidualInfo:
    """Symbolic view of one engine-level ``custom_vjp``'s saved residuals."""

    eqn: Any
    res_avals: list  # flat residual avals, residual-tree order
    named_leaves: list  # [(path_str, aval)] via the residual pytree
    path: str
    line: int

    @property
    def total_bytes(self) -> int:
        return sum(_aval_bytes(a) for a in self.res_avals)

    def bytes_by_leaf(self) -> dict:
        return {p: _aval_bytes(a) for p, a in self.named_leaves}


def _aval_bytes(aval) -> int:
    size = getattr(aval, "size", None)
    dtype = getattr(aval, "dtype", None)
    if size is None or dtype is None:
        return 0
    return int(size) * int(dtype.itemsize)


def engine_custom_vjp_eqns(closed) -> Iterator[Any]:
    """Yield the *outermost* ``custom_vjp_call_jaxpr`` eqns in a trace.

    Does not descend into a found ``custom_vjp``'s own body: the pallas
    kernel wrappers carry their own nested custom_vjps, and the residual
    budget applies to the solver-engine boundary, which saves them all.
    """

    def walk(jaxpr):
        jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "custom_vjp_call_jaxpr":
                yield eqn
                continue
            for param in eqn.params.values():
                for sub in _sub_jaxprs(param):
                    yield from walk(sub)

    yield from walk(closed)


def residual_info(eqn) -> ResidualInfo:
    """Recover the residual avals and named leaf paths of one custom_vjp eqn.

    Forces ``fwd_jaxpr_thunk`` with all-symbolic-zero tangent flags (pure
    tracing, nothing executes).  The forward jaxpr's outputs are ordered
    ``(*residuals, *primal_outputs)`` where the primal count comes from
    ``fun_jaxpr``; ``out_trees()`` yields ``(primal_tree, residual_tree)``.
    """
    fun_jaxpr = eqn.params["fun_jaxpr"]
    thunk = eqn.params["fwd_jaxpr_thunk"]
    # closed-over tracers (e.g. per-row tolerance arrays reaching the
    # engine custom_vjp under jit) are hoisted as leading consts of
    # fun_jaxpr; the thunk wants one zero-flag per *explicit* arg only
    num_consts = eqn.params.get("num_consts", 0)
    fwd, _consts = thunk(
        *[False] * (len(fun_jaxpr.jaxpr.invars) - num_consts))
    fwd = getattr(fwd, "jaxpr", fwd)
    out_avals = [v.aval for v in fwd.outvars]
    n_primal = len(fun_jaxpr.jaxpr.outvars)
    res_avals = out_avals[: len(out_avals) - n_primal]

    named = []
    try:
        _primal_tree, res_tree = eqn.params["out_trees"]()
        res_pytree = jtu.tree_unflatten(res_tree, res_avals)
        for path, leaf in jtu.tree_flatten_with_path(res_pytree)[0]:
            named.append((jtu.keystr(path), leaf))
    except Exception:
        named = [(f"[{i}]", a) for i, a in enumerate(res_avals)]

    path, line = eqn_provenance(eqn)
    return ResidualInfo(
        eqn=eqn, res_avals=res_avals, named_leaves=named, path=path, line=line
    )


def trace(fn, *example_args):
    """``jax.make_jaxpr`` wrapper: trace without executing or compiling."""
    return jax.make_jaxpr(fn)(*example_args)
