"""TrainState: params + optimizer state + step, with sharding specs."""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.lm import Model
from repro.optim.adamw import Optimizer

PyTree = Any


class TrainState(NamedTuple):
    step: jnp.ndarray
    params: PyTree
    opt_state: Any


def make_train_state(model: Model, opt: Optimizer, key) -> TrainState:
    params = model.init(key)
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      opt_state=opt.init(params))


def abstract_train_state(model: Model, opt: Optimizer) -> TrainState:
    """ShapeDtypeStruct TrainState — dry-run lowering, zero allocation."""
    params = model.abstract()
    opt_state = jax.eval_shape(opt.init, params)
    return TrainState(step=jax.ShapeDtypeStruct((), jnp.int32),
                      params=params, opt_state=opt_state)


def train_state_specs(model: Model, opt: Optimizer,
                      mesh=None) -> TrainState:
    """PartitionSpec tree matching TrainState (ZeRO: moments follow the
    parameter sharding — fully sharded optimizer state)."""
    pspecs = model.specs(mesh)
    abstract = abstract_train_state(model, opt)

    def like_params(opt_state):
        # moment trees mirror params; scalars replicate
        flat_p, treedef_p = jax.tree.flatten(pspecs)

        def map_node(node):
            return node
        # walk the opt_state: any subtree isomorphic to params gets pspecs
        def rec(o):
            if isinstance(o, tuple) and hasattr(o, "_fields"):
                return type(o)(*(rec(v) for v in o))
            try:
                if jax.tree.structure(o) == jax.tree.structure(pspecs):
                    return pspecs
            except Exception:
                pass
            return jax.tree.map(lambda _: P(), o)

        return rec(opt_state)

    return TrainState(step=P(), params=pspecs,
                      opt_state=like_params(abstract.opt_state))
