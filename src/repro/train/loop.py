"""Fault-tolerant training loop.

Production posture:

* one fully-jitted ``train_step`` with **microbatch gradient
  accumulation** (``lax.scan`` over microbatches inside the step: the
  data-parallel gradient reduce-scatter of microbatch *i* is exposed to
  XLA's latency-hiding scheduler against the compute of *i+1*);
* gradient clipping + optional int8/top-k **gradient compression**
  (error feedback carried in the loop state) ahead of the cross-pod
  all-reduce;
* **checkpoint/restart**: atomic CheckpointManager saves every
  ``ckpt_every`` steps; on construction the loop auto-resumes from the
  latest valid checkpoint; the step-indexed data pipeline makes resume
  exact without data-state snapshots;
* **straggler detection**: per-step wall-time EMA; steps slower than
  ``straggler_factor``× the EMA trip a callback (on a real cluster this
  feeds the controller that evicts/restarts the slow host — here it is
  surfaced in metrics and the hook is testable);
* **donated** state buffers (in-place update under jit).
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.lm import Model
from repro.optim.adamw import Optimizer, apply_updates
from repro.optim.grad_utils import (CompressionState, clip_by_global_norm,
                                    init_compression_state,
                                    int8_compress_decompress, topk_sparsify)
from .state import TrainState

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainLoopConfig:
    microbatches: int = 1
    clip_norm: float = 1.0
    compression: str = "none"      # none | int8 | topk
    topk_frac: float = 0.01
    ckpt_every: int = 100
    ckpt_dir: Optional[str] = None
    keep_ckpts: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10
    # skip-step guard: when the loss or raw gradient norm is non-finite
    # (e.g. a poisoned NODE solve, fp overflow), hold params/opt state
    # and count the skip in metrics instead of applying a NaN update
    skip_nonfinite: bool = True


def _split_microbatches(batch: Dict[str, jnp.ndarray], m: int):
    def split(x):
        b = x.shape[0]
        if b % m != 0:
            raise ValueError(
                f"batch size {b} not divisible by {m} microbatches")
        return x.reshape((m, b // m) + x.shape[1:])
    return jax.tree.map(split, batch)


def build_train_step(model: Model, opt: Optimizer,
                     cfg: TrainLoopConfig) -> Callable:
    """Returns train_step(state, batch, comp_state) ->
    (state, comp_state, metrics) — pure, jittable, donate-able."""

    def grads_of(params, mb):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, mb)
        return loss, metrics, grads

    def step(state: TrainState, batch, comp_state: CompressionState):
        comp_in = comp_state
        if cfg.microbatches > 1:
            mbs = _split_microbatches(batch, cfg.microbatches)

            def acc_fn(carry, mb):
                gacc, lacc = carry
                loss, _, grads = grads_of(state.params, mb)
                gacc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), gacc, grads)
                return (gacc, lacc + loss), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (gsum, lsum), _ = jax.lax.scan(acc_fn, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / cfg.microbatches, gsum)
            loss = lsum / cfg.microbatches
            metrics = {"ce_loss": loss}
        else:
            loss, metrics, grads = grads_of(state.params, batch)

        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
        if cfg.compression == "int8":
            grads, comp_state = int8_compress_decompress(grads, comp_state)
        elif cfg.compression == "topk":
            grads, comp_state = topk_sparsify(grads, cfg.topk_frac,
                                              comp_state)

        updates, opt_state = opt.update(grads, state.opt_state,
                                        state.params)
        params = apply_updates(state.params, updates)
        metrics = dict(metrics)
        if cfg.skip_nonfinite:
            # skip-step guard: a non-finite loss or raw grad norm means
            # this update is garbage — hold params/opt/compression state
            # (the step counter still advances so training can't spin on
            # one poisoned batch) and surface the skip in metrics.
            # clip_by_global_norm already zeroed the grads on a bad
            # norm, so `updates` is finite either way; the selects below
            # are what make the skip exact.
            ok = jnp.isfinite(loss) & jnp.isfinite(gnorm)
            sel = lambda new, old: jax.tree.map(
                lambda n, o: jnp.where(ok, n, o), new, old)
            params = sel(params, state.params)
            opt_state = sel(opt_state, state.opt_state)
            comp_state = sel(comp_state, comp_in)
            metrics["skipped"] = (~ok).astype(jnp.int32)
        new_state = TrainState(step=state.step + 1, params=params,
                               opt_state=opt_state)
        metrics["loss"] = loss
        metrics["grad_norm"] = gnorm
        return new_state, comp_state, metrics

    return step


class TrainLoop:
    """Drives ``train_step`` with checkpoint/restart + straggler watch."""

    def __init__(self, model: Model, opt: Optimizer, cfg: TrainLoopConfig,
                 state: TrainState,
                 straggler_cb: Optional[Callable[[int, float], None]] = None,
                 jit: bool = True,
                 clock: Callable[[], float] = time.monotonic):
        self.model, self.opt, self.cfg = model, opt, cfg
        self.state = state
        self._clock = clock
        self.comp_state = init_compression_state(state.params) \
            if cfg.compression != "none" else CompressionState(error=())
        self._step_fn = build_train_step(model, opt, cfg)
        if jit:
            self._step_fn = jax.jit(self._step_fn, donate_argnums=(0,))
        self.straggler_cb = straggler_cb
        self.skipped_steps = 0      # total non-finite updates skipped
        self._ema_dt: Optional[float] = None
        self.manager = None
        if cfg.ckpt_dir:
            from repro.ckpt import CheckpointManager
            self.manager = CheckpointManager(cfg.ckpt_dir, cfg.keep_ckpts)
            restored = self.manager.restore(self.state)
            if restored is not None:
                _, self.state = restored

    @property
    def step(self) -> int:
        return int(self.state.step)

    def run(self, batch_fn: Callable[[int], Dict[str, jnp.ndarray]],
            n_steps: int,
            log_cb: Optional[Callable[[int, Dict], None]] = None):
        """Run until global step reaches ``n_steps`` (resume-aware)."""
        metrics = {}
        while self.step < n_steps:
            s = self.step
            batch = batch_fn(s)
            t0 = self._clock()
            self.state, self.comp_state, metrics = self._step_fn(
                self.state, batch, self.comp_state)
            jax.block_until_ready(metrics["loss"])
            dt = self._clock() - t0
            if "skipped" in metrics:
                self.skipped_steps += int(metrics["skipped"])

            # straggler watch: EMA of step time, flag outliers
            if self._ema_dt is None:
                self._ema_dt = dt
            else:
                if dt > self.cfg.straggler_factor * self._ema_dt \
                        and self.straggler_cb is not None:
                    self.straggler_cb(s, dt / self._ema_dt)
                self._ema_dt = 0.9 * self._ema_dt + 0.1 * dt

            if self.manager and (s + 1) % self.cfg.ckpt_every == 0:
                self.manager.save(s + 1, self.state)

            if log_cb and (s + 1) % self.cfg.log_every == 0:
                log_cb(s + 1, {k: float(v) for k, v in metrics.items()})
        return metrics
