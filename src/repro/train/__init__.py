"""repro.train — train state, step builder, fault-tolerant loop."""

from .state import TrainState, make_train_state
from .loop import TrainLoop, TrainLoopConfig, build_train_step

__all__ = ["TrainState", "make_train_state", "TrainLoop",
           "TrainLoopConfig", "build_train_step"]
