"""Model / run configuration dataclasses.

``ModelConfig`` describes an architecture (one per assigned arch in
``repro.configs``); ``RunConfig`` describes how it is executed: mesh,
sharding rules, dtypes, NODE (continuous-depth) mode, remat policy.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp

from repro.core.node_block import NodeConfig
from repro.distributed.sharding import AxisRules, DEFAULT_TRAIN_RULES


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm
    n_layers: int
    d_model: int
    vocab: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0           # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e4
    window: int = 0             # sliding-window size (0 = full attention)
    # ffn
    d_ff: int = 0
    act: str = "silu"
    mlp_bias: bool = False
    norm: str = "rmsnorm"
    norm_eps: float = 1e-6
    parallel_block: bool = False  # command-r style: attn+ffn from same norm
    tie_embeddings: bool = False
    # moe
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_expert: int = 0           # per-expert FFN width
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # hybrid (recurrentgemma): repeating block pattern
    pattern: Tuple[str, ...] = ()      # e.g. ("rec", "rec", "attn")
    d_rnn: int = 0              # RG-LRU width (0 -> d_model)
    conv_width: int = 4
    # ssm (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv: int = 4
    ssm_ngroups: int = 1
    # frontend stub
    frontend: str = "none"      # none | vlm | audio

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:  # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def resolved_d_rnn(self) -> int:
        return self.d_rnn or self.d_model

    def scaled(self, **overrides) -> "ModelConfig":
        """Reduced config of the same family (smoke tests)."""
        return dataclasses.replace(self, **overrides)


@dataclasses.dataclass(frozen=True)
class RunConfig:
    mesh: Any = None                      # jax.sharding.Mesh or None
    rules: AxisRules = DEFAULT_TRAIN_RULES
    compute_dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    remat: str = "none"                   # none | block  (activation ckpt)
    node: NodeConfig = NodeConfig()       # continuous-depth (the paper)
    scan_layers: bool = True              # scan-over-layers (O(1) HLO size)
    # TPU kernels (interpret mode in tests) — also switches every NODE
    # block's ODE solve onto the fused flat-state stepper path
    use_pallas: bool = False
    decode_seq_shard: bool = True         # flash-decode KV-seq sharding
    max_seq: int = 0                      # KV-cache capacity (serving)
    zero1: bool = True                    # optimizer states sharded like params
    label_smoothing: float = 0.0

    def with_(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)
