"""Language-model assembly: embeddings, stack, head, loss, decode.

``build_model(cfg, rcfg)`` returns a ``Model`` facade with:

  * ``defs`` / ``init`` / ``abstract`` / ``specs`` — parameter tree,
  * ``loss_fn(params, batch)``        — train-mode forward + CE loss,
  * ``prefill(params, batch)``        — forward returning per-layer caches,
  * ``decode_step(params, batch, caches)`` — one-token serve step,
  * ``cache_defs(batch, max_seq)``    — KV/state cache ParamDefs.

Batches: ``{"tokens": (B,S) i32, "labels": (B,S) i32, "mask": (B,S)}``;
frontend-stub archs (VLM / audio) replace ``tokens`` with precomputed
``embeds`` (B,S,D) per the assignment (backbone-only).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from .common import (ParamDef, abstract_params, apply_norm, init_params,
                     norm_defs, param_count, param_specs)
from .config import ModelConfig, RunConfig
from .transformer import stack_apply, stack_cache_defs, stack_defs

PyTree = Any


def model_defs(cfg: ModelConfig, param_dtype) -> PyTree:
    d: Dict[str, PyTree] = {}
    if cfg.frontend == "none":
        d["embed"] = ParamDef((cfg.vocab, cfg.d_model), param_dtype,
                              ("vocab", "embed"), init="embed")
    d["stack"] = stack_defs(cfg, param_dtype)
    d["final_norm"] = norm_defs(cfg.norm, cfg.d_model, param_dtype)
    if not cfg.tie_embeddings or cfg.frontend != "none":
        d["lm_head"] = ParamDef((cfg.d_model, cfg.vocab), param_dtype,
                                ("embed", "vocab"), init="embed")
    return d


def _embed(params: PyTree, batch: Dict[str, jnp.ndarray],
           cfg: ModelConfig, rcfg: RunConfig) -> jnp.ndarray:
    if cfg.frontend != "none":
        x = batch["embeds"].astype(rcfg.compute_dtype)
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0) \
            .astype(rcfg.compute_dtype)
        if cfg.tie_embeddings:
            x = x * jnp.sqrt(jnp.asarray(cfg.d_model, rcfg.compute_dtype))
    return shard(x, ("batch", "res_seq", "embed_act"), rcfg.rules,
                 rcfg.mesh)


def _head(params: PyTree, x: jnp.ndarray, cfg: ModelConfig,
          rcfg: RunConfig) -> jnp.ndarray:
    x = apply_norm(cfg.norm, x, params["final_norm"], cfg.norm_eps)
    if "lm_head" in params:
        w = params["lm_head"].astype(rcfg.compute_dtype)
        logits = jnp.einsum("bsd,dv->bsv", x, w)
    else:
        w = params["embed"].astype(rcfg.compute_dtype)
        logits = jnp.einsum("bsd,vd->bsv", x, w)
    return shard(logits, ("batch", "seq", "vocab_act"), rcfg.rules,
                 rcfg.mesh)


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 mask: Optional[jnp.ndarray],
                 label_smoothing: float = 0.0) -> Tuple[jnp.ndarray,
                                                        jnp.ndarray]:
    """Mean CE over masked tokens, fp32.  Returns (loss, n_tokens)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if label_smoothing > 0.0:
        smooth = -lf.mean(axis=-1) + lse
        nll = (1 - label_smoothing) * nll + label_smoothing * smooth
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    mask = mask.astype(jnp.float32)
    n = jnp.maximum(mask.sum(), 1.0)
    return (nll * mask).sum() / n, n


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    rcfg: RunConfig
    defs: PyTree

    # -- parameters ------------------------------------------------------
    def init(self, key) -> PyTree:
        return init_params(self.defs, key)

    def abstract(self) -> PyTree:
        return abstract_params(self.defs)

    def specs(self, mesh=None) -> PyTree:
        return param_specs(self.defs, self.rcfg.rules,
                           mesh if mesh is not None else self.rcfg.mesh)

    def n_params(self) -> int:
        return param_count(self.defs)

    # -- forward ---------------------------------------------------------
    def forward(self, params: PyTree, batch: Dict[str, jnp.ndarray],
                *, mode: str = "train",
                caches: Optional[PyTree] = None,
                positions: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, Optional[PyTree], jnp.ndarray]:
        x = _embed(params, batch, self.cfg, self.rcfg)
        y, new_caches, aux = stack_apply(
            params["stack"], x, self.cfg, self.rcfg, mode=mode,
            positions=positions, caches=caches)
        logits = _head(params, y, self.cfg, self.rcfg)
        return logits, new_caches, aux

    def loss_fn(self, params: PyTree, batch: Dict[str, jnp.ndarray]
                ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        logits, _, aux = self.forward(params, batch, mode="train")
        loss, n = softmax_xent(logits, batch["labels"],
                               batch.get("mask"),
                               self.rcfg.label_smoothing)
        total = loss + self.cfg.router_aux_coef * aux
        return total, {"ce_loss": loss, "aux_loss": aux, "tokens": n}

    # -- serving ---------------------------------------------------------
    def prefill(self, params: PyTree, batch: Dict[str, jnp.ndarray]
                ) -> Tuple[jnp.ndarray, PyTree]:
        logits, caches, _ = self.forward(params, batch, mode="prefill")
        return logits[:, -1], caches

    def decode_step(self, params: PyTree, batch: Dict[str, jnp.ndarray],
                    caches: PyTree, position: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, PyTree]:
        """One new token.  batch['tokens'] (B,1) (or 'embeds' (B,1,D) for
        frontend-stub archs); position scalar i32."""
        ref = batch["tokens"] if "tokens" in batch else batch["embeds"]
        pos = jnp.broadcast_to(position, (ref.shape[0], 1))
        logits, caches, _ = self.forward(
            batch=batch, params=params, mode="decode", caches=caches,
            positions=pos)
        return logits[:, -1], caches

    def cache_defs(self, batch: int, max_seq: int,
                   cache_dtype=jnp.bfloat16) -> PyTree:
        return stack_cache_defs(self.cfg, batch, max_seq, cache_dtype)

    def abstract_caches(self, batch: int, max_seq: int,
                        cache_dtype=jnp.bfloat16) -> PyTree:
        return abstract_params(self.cache_defs(batch, max_seq, cache_dtype))

    def cache_specs(self, batch: int, max_seq: int,
                    cache_dtype=jnp.bfloat16, mesh=None) -> PyTree:
        return param_specs(self.cache_defs(batch, max_seq, cache_dtype),
                           self.rcfg.rules,
                           mesh if mesh is not None else self.rcfg.mesh)


def build_model(cfg: ModelConfig, rcfg: Optional[RunConfig] = None) -> Model:
    rcfg = rcfg or RunConfig()
    return Model(cfg=cfg, rcfg=rcfg,
                 defs=model_defs(cfg, rcfg.param_dtype))
