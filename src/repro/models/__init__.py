"""repro.models — transformer / MoE / hybrid / SSM model zoo.

Pure-functional model definitions: parameters are pytrees of arrays,
described by ``ParamDef`` trees that carry shapes, dtypes, logical sharding
axes and initializers — one source of truth serving real initialization
(smoke tests), abstract ``ShapeDtypeStruct`` instantiation (the multi-pod
dry-run) and ``PartitionSpec`` derivation (pjit in/out shardings).
"""

from .common import ParamDef, abstract_params, init_params, param_specs
from .config import ModelConfig, RunConfig
from .lm import build_model

__all__ = [
    "ParamDef",
    "abstract_params",
    "init_params",
    "param_specs",
    "ModelConfig",
    "RunConfig",
    "build_model",
]
