"""Feed-forward blocks: SwiGLU (gated) and plain MLP, with bias variants."""

from __future__ import annotations

from typing import Any

import jax.numpy as jnp

from repro.distributed.sharding import shard
from .common import ParamDef, activation, dense
from .config import ModelConfig, RunConfig

PyTree = Any


def ffn_defs(cfg: ModelConfig, param_dtype, d_ff: int = 0,
             gated: bool = True) -> PyTree:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    defs = {
        "w_in": ParamDef((d, f), param_dtype, ("embed", "mlp")),
        "w_out": ParamDef((f, d), param_dtype, ("mlp", "embed")),
    }
    if gated:
        defs["w_gate"] = ParamDef((d, f), param_dtype, ("embed", "mlp"))
    if cfg.mlp_bias:
        defs["b_in"] = ParamDef((f,), param_dtype, ("mlp_act",),
                                init="zeros")
        defs["b_out"] = ParamDef((d,), param_dtype, ("embed_act",),
                                 init="zeros")
    return defs


def ffn_apply(p: PyTree, x: jnp.ndarray, cfg: ModelConfig,
              rcfg: RunConfig) -> jnp.ndarray:
    """x (B,S,D) -> (B,S,D).  SwiGLU when a gate weight is present."""
    cd = rcfg.compute_dtype
    mesh, rules = rcfg.mesh, rcfg.rules
    h = dense(x, p["w_in"], p.get("b_in"), cd)
    h = shard(h, ("batch", "seq", "mlp_act"), rules, mesh)
    if "w_gate" in p:
        g = dense(x, p["w_gate"], None, cd)
        g = shard(g, ("batch", "seq", "mlp_act"), rules, mesh)
        h = activation(cfg.act, g) * h
    else:
        h = activation(cfg.act, h)
    y = dense(h, p["w_out"], p.get("b_out"), cd)
    return shard(y, ("batch", "res_seq", "embed_act"), rules, mesh)
