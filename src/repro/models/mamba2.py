"""Mamba-2 block: state-space duality (SSD) with chunked scan.

The selective SSM  h_t = exp(dt_t·A) h_{t-1} + dt_t·(B_t ⊗ x_t),
y_t = C_t·h_t + D⊙x_t  is computed with the SSD chunked algorithm
(Dao & Gu 2024): the sequence is split into chunks of length Q;

  * intra-chunk term — a masked (1-semiseparable) attention-like matmul
    Y_diag = ((C_c B_cᵀ) ⊙ L) X_c,
  * chunk boundary states — S_c = (B_c ⊙ decay_to_end)ᵀ X_c,
  * inter-chunk term — a *sequential scan over chunk states* (S/Q steps),
    which is exactly the paper's trajectory-checkpoint structure: chunk
    states are the checkpoints, intra-chunk work is recomputed locally.

All einsums are head-parallel: heads shard over the model axis (TP).
Decode is the O(1) recurrent update on a carried (B,H,P,N) state, which
is what makes the 500k-token decode cell feasible (no KV cache at all).

``ssd_chunked`` is the pure-jnp oracle shared with the Pallas kernel in
``repro.kernels.ssd_scan`` (ref.py imports it).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from .common import ParamDef, dense, rmsnorm
from .config import ModelConfig, RunConfig
from .rglru import causal_conv1d, conv_tail

PyTree = Any


def mamba2_defs(cfg: ModelConfig, param_dtype) -> PyTree:
    d = cfg.d_model
    di = cfg.d_inner
    h = cfg.ssm_heads
    n = cfg.ssm_state
    g = cfg.ssm_ngroups
    return {
        # separate projections (a fused (D, 2di+2gn+h) proj has identical
        # FLOPs; separate keeps sharding clean — see DESIGN perf notes)
        "w_z": ParamDef((d, di), param_dtype, ("embed", "mlp")),
        "w_x": ParamDef((d, di), param_dtype, ("embed", "mlp")),
        "w_b": ParamDef((d, g * n), param_dtype, ("embed", None)),
        "w_c": ParamDef((d, g * n), param_dtype, ("embed", None)),
        "w_dt": ParamDef((d, h), param_dtype, ("embed", None)),
        "dt_bias": ParamDef((h,), jnp.float32, (None,), init="zeros"),
        "a_log": ParamDef((h,), jnp.float32, (None,), init="uniform_ssm"),
        "d_skip": ParamDef((h,), jnp.float32, (None,), init="ones"),
        "conv_x": ParamDef((cfg.ssm_conv, di), param_dtype,
                           ("conv", "mlp_act")),
        "conv_b": ParamDef((cfg.ssm_conv, g * n), param_dtype,
                           ("conv", None)),
        "conv_c": ParamDef((cfg.ssm_conv, g * n), param_dtype,
                           ("conv", None)),
        "norm": ParamDef((di,), param_dtype, ("mlp_act",), init="ones"),
        "w_out": ParamDef((di, d), param_dtype, ("mlp", "embed")),
    }


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} x[..., k]
    for j < i, else -inf-ish (masked).  x (..., Q)."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]           # sum_(j..i]
    mask = jnp.arange(q)[:, None] >= jnp.arange(q)[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jnp.ndarray,      # (B, S, H, P)
    dt: jnp.ndarray,     # (B, S, H)  fp32, post-softplus
    a: jnp.ndarray,      # (H,)       fp32, negative (decay rate)
    b_mat: jnp.ndarray,  # (B, S, G, N)
    c_mat: jnp.ndarray,  # (B, S, G, N)
    chunk: int,
    h0: Optional[jnp.ndarray] = None,   # (B, H, P, N) initial state
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD chunked scan.  Returns (y (B,S,H,P), h_last (B,H,P,N))."""
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    if s % chunk != 0:
        raise ValueError(
            f"mamba2 ssd: sequence length {s} not divisible by chunk {chunk}")
    nc = s // chunk
    rep = h // g

    xf = x.astype(jnp.float32).reshape(bsz, nc, chunk, h, p)
    dtc = dt.astype(jnp.float32).reshape(bsz, nc, chunk, h)
    bf = jnp.repeat(b_mat.astype(jnp.float32), rep, axis=2) \
        .reshape(bsz, nc, chunk, h, n)
    cf = jnp.repeat(c_mat.astype(jnp.float32), rep, axis=2) \
        .reshape(bsz, nc, chunk, h, n)

    da = dtc * a[None, None, None, :]                    # (B,nc,Q,H)
    da_cum = jnp.cumsum(da, axis=2)                      # within-chunk cumsum
    da_total = da_cum[:, :, -1]                          # (B,nc,H)

    # ---- intra-chunk (diagonal-block) output --------------------------
    l_mat = jnp.exp(_segsum(da.transpose(0, 1, 3, 2)))   # (B,nc,H,Q,Q)
    cb = jnp.einsum("bcqhn,bckhn->bchqk", cf, bf)        # (B,nc,H,Q,Q)
    y_diag = jnp.einsum("bchqk,bckh,bckhp->bcqhp",
                        cb * l_mat, dtc, xf)

    # ---- chunk boundary states ---------------------------------------
    decay_to_end = jnp.exp(da_total[:, :, None, :] - da_cum)  # (B,nc,Q,H)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn",
                        bf, dtc * decay_to_end, xf)      # (B,nc,H,P,N)

    # ---- inter-chunk sequential scan over chunk states ----------------
    init = jnp.zeros((bsz, h, p, n), jnp.float32) if h0 is None \
        else h0.astype(jnp.float32)

    def step(h_prev, inp):
        st, datot = inp                                  # (B,H,P,N),(B,H)
        h_new = h_prev * jnp.exp(datot)[..., None, None] + st
        return h_new, h_prev                             # emit PRE-state

    h_last, h_prevs = jax.lax.scan(
        step, init, (states.swapaxes(0, 1), da_total.swapaxes(0, 1)))
    h_prevs = h_prevs.swapaxes(0, 1)                     # (B,nc,H,P,N)

    # ---- inter-chunk contribution to outputs --------------------------
    decay_from_start = jnp.exp(da_cum)                   # (B,nc,Q,H)
    y_inter = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp",
                         cf, h_prevs, decay_from_start)

    y = (y_diag + y_inter).reshape(bsz, s, h, p)
    return y, h_last


def ssd_decode_step(
    x: jnp.ndarray,      # (B, H, P)
    dt: jnp.ndarray,     # (B, H) fp32 post-softplus
    a: jnp.ndarray,      # (H,)
    b_vec: jnp.ndarray,  # (B, G, N)
    c_vec: jnp.ndarray,  # (B, G, N)
    state: jnp.ndarray,  # (B, H, P, N) fp32
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token SSM update.  Returns (y (B,H,P), new_state)."""
    h, g = x.shape[1], b_vec.shape[1]
    rep = h // g
    bf = jnp.repeat(b_vec.astype(jnp.float32), rep, axis=1)   # (B,H,N)
    cf = jnp.repeat(c_vec.astype(jnp.float32), rep, axis=1)
    da = jnp.exp(dt * a[None])                                # (B,H)
    new_state = state * da[..., None, None] + jnp.einsum(
        "bhn,bh,bhp->bhpn", bf, dt, x.astype(jnp.float32))
    y = jnp.einsum("bhn,bhpn->bhp", cf, new_state)
    return y, new_state


def mamba2_block_apply(
    p: PyTree,
    x: jnp.ndarray,
    cfg: ModelConfig,
    rcfg: RunConfig,
    *,
    mode: str = "train",
    cache: Optional[Dict[str, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Mamba-2 block.  x (B,S,D) -> (y (B,S,D), new_cache)."""
    cd = rcfg.compute_dtype
    mesh, rules = rcfg.mesh, rcfg.rules
    bsz, s, _ = x.shape
    hh, pp, nn = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    g = cfg.ssm_ngroups

    z = dense(x, p["w_z"], None, cd)
    u = dense(x, p["w_x"], None, cd)
    u = shard(u, ("batch", "seq", "mlp_act"), rules, mesh)
    bm = dense(x, p["w_b"], None, cd)
    cm = dense(x, p["w_c"], None, cd)
    dt_raw = dense(x, p["w_dt"], None, cd).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"][None, None])
    a = -jnp.exp(p["a_log"])

    new_cache = None
    if mode == "decode":
        if cache is None or s != 1:
            raise ValueError(
                "mamba2 decode mode needs a cache (from mode='prefill') "
                f"and a single-token input; got cache={cache is not None}, "
                f"seq_len={s}")
        w = p["conv_x"].shape[0]
        cs = cache["conv"]                   # (B, W-1, di + 2gn)
        di = u.shape[-1]
        cat = jnp.concatenate([u, bm, cm], axis=-1)
        u2 = causal_conv1d(u, p["conv_x"], state=cs[..., :di])
        bm2 = causal_conv1d(bm, p["conv_b"],
                            state=cs[..., di:di + g * nn])
        cm2 = causal_conv1d(cm, p["conv_c"], state=cs[..., di + g * nn:])
        u2, bm2, cm2 = (jax.nn.silu(t) for t in (u2, bm2, cm2))
        y1, st = ssd_decode_step(
            u2[:, 0].reshape(bsz, hh, pp), dt[:, 0], a,
            bm2[:, 0].reshape(bsz, g, nn), cm2[:, 0].reshape(bsz, g, nn),
            cache["ssm"])
        y = y1[:, None]
        conv_new = jnp.concatenate(
            [cs[:, 1:], cat.astype(cs.dtype)], axis=1) if w > 1 else cs
        new_cache = {"conv": conv_new, "ssm": st}
    else:
        u2 = jax.nn.silu(causal_conv1d(u, p["conv_x"]))
        bm2 = jax.nn.silu(causal_conv1d(bm, p["conv_b"]))
        cm2 = jax.nn.silu(causal_conv1d(cm, p["conv_c"]))
        h0 = cache["ssm"] if cache is not None else None
        # pad S to a chunk multiple (dt=0 padding is state-neutral)
        q = cfg.ssm_chunk
        pad = (-s) % q
        if pad:
            zp = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),)
                                   * (t.ndim - 2))
            u2p, bm2p, cm2p, dtp = zp(u2), zp(bm2), zp(cm2), zp(dt)
        else:
            u2p, bm2p, cm2p, dtp = u2, bm2, cm2, dt
        sp = s + pad
        y, h_last = ssd_chunked(
            u2p.reshape(bsz, sp, hh, pp), dtp, a,
            bm2p.reshape(bsz, sp, g, nn), cm2p.reshape(bsz, sp, g, nn),
            q, h0=h0)
        y = y[:, :s]
        if mode == "prefill":
            w = p["conv_x"].shape[0]
            cat = jnp.concatenate([u, bm, cm], axis=-1)
            conv_new = conv_tail(cat, w).astype(jnp.float32)
            new_cache = {"conv": conv_new, "ssm": h_last}

    y = y + (u2.reshape(bsz, s, hh, pp).astype(jnp.float32)
             * p["d_skip"][None, None, :, None]).astype(y.dtype)
    y = y.reshape(bsz, s, hh * pp).astype(cd)
    y = shard(y, ("batch", "seq", "mlp_act"), rules, mesh)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    out = dense(y, p["w_out"], None, cd)
    return shard(out, ("batch", "res_seq", "embed_act"), rules,
                 mesh), new_cache


def mamba2_cache_defs(cfg: ModelConfig, batch: int) -> PyTree:
    di = cfg.d_inner
    gn = cfg.ssm_ngroups * cfg.ssm_state
    return {
        "conv": ParamDef((batch, cfg.ssm_conv - 1, di + 2 * gn),
                         jnp.float32, ("batch", None, None), init="zeros"),
        "ssm": ParamDef((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                         cfg.ssm_state), jnp.float32,
                        ("batch", "heads_act", None, None), init="zeros"),
    }
