"""GQA attention: training, chunked long-context prefill, flash-decode.

Three compute paths, all numerically the online-softmax algorithm:

* ``full``     — dense causal (optionally sliding-window) attention for
  short sequences; scores materialize (B,H,S,S).
* ``chunked``  — lax.scan over KV blocks with running (m, l, o) —
  flash-attention in pure XLA; memory O(S·block) per device.  Used for
  long prefill where dense scores would not fit HBM.  FLOPs equal the
  dense formulation (both compute the masked upper triangle); the Pallas
  flash kernel (repro.kernels.flash_attention) additionally skips fully
  masked blocks on real TPUs.
* ``decode``   — single query against a KV cache.  With a mesh and
  ``decode_seq_shard`` the cache sequence dim is sharded over the model
  axis and partial softmax statistics are combined with psum/pmax
  (flash-decode); this is what makes 500k-token caches feasible per chip.

GQA is computed with keys/values expanded to the full head count
(`repeat` over groups).  Under GSPMD this keeps every attention einsum
local to its head shard; the Pallas kernel avoids the expansion natively.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import shard, shard_map_compat
from .common import ParamDef, apply_rope, dense
from .config import ModelConfig, RunConfig

PyTree = Any

NEG_INF = -1e30  # large-negative instead of -inf: keeps masked-all lanes finite


def attn_defs(cfg: ModelConfig, param_dtype) -> PyTree:
    d, h, hk = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    dh = cfg.resolved_head_dim
    defs = {
        "wq": ParamDef((d, h * dh), param_dtype, ("embed", "heads")),
        "wk": ParamDef((d, hk * dh), param_dtype, ("embed", "kv_heads")),
        "wv": ParamDef((d, hk * dh), param_dtype, ("embed", "kv_heads")),
        "wo": ParamDef((h * dh, d), param_dtype, ("heads", "embed")),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((h * dh,), param_dtype, ("heads_act",),
                              init="zeros")
        defs["bk"] = ParamDef((hk * dh,), param_dtype, ("kv_heads_act",),
                              init="zeros")
        defs["bv"] = ParamDef((hk * dh,), param_dtype, ("kv_heads_act",),
                              init="zeros")
    return defs


def _expand_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(B,S,Hkv,dh) -> (B,S,Hkv*groups,dh) repeating each kv head."""
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def _causal_mask(sq: int, skv: int, offset: int, window: int) -> jnp.ndarray:
    """(sq, skv) bool mask. query i attends key j iff
    j <= i+offset and (window == 0 or j > i+offset-window)."""
    qi = jnp.arange(sq)[:, None] + offset
    kj = jnp.arange(skv)[None, :]
    m = kj <= qi
    if window > 0:
        m &= kj > qi - window
    return m


def full_attention(q, k, v, *, offset: int = 0, window: int = 0,
                   scale: Optional[float] = None) -> jnp.ndarray:
    """Dense causal attention. q (B,Sq,H,dh), k/v (B,Skv,H,dh)."""
    dh = q.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    mask = _causal_mask(q.shape[1], k.shape[1], offset, window)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def chunked_attention(q, k, v, *, window: int = 0, block: int = 1024,
                      scale: Optional[float] = None) -> jnp.ndarray:
    """Causal flash-style attention: scan over KV blocks with running
    (max, sum, out) statistics.  Memory O(Sq·block); identical output to
    ``full_attention`` (same-seq case, offset 0)."""
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    if skv % block != 0:
        raise ValueError(
            f"attention: kv sequence length {skv} not divisible by "
            f"block {block}")
    nb = skv // block
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)

    qf = q.astype(jnp.float32) * scale
    kb = k.reshape(b, nb, block, h, dh)
    vb = v.reshape(b, nb, block, h, dh)

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    o0 = jnp.zeros((b, h, sq, dh), jnp.float32)

    qi = jnp.arange(sq)

    def body(carry, inp):
        m, l, o = carry
        jblk, kj, vj = inp                       # kj/vj: (B, block, H, dh)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kj.astype(jnp.float32))
        kpos = jblk * block + jnp.arange(block)
        mask = kpos[None, :] <= qi[:, None]
        if window > 0:
            mask &= kpos[None, :] > qi[:, None] - window
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        o_new = o * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vj.astype(jnp.float32))
        return (m_new, l_new, o_new), None

    (m, l, o), _ = jax.lax.scan(
        body, (m0, l0, o0),
        (jnp.arange(nb), kb.swapaxes(0, 1), vb.swapaxes(0, 1)))
    out = o / jnp.maximum(l[..., None], 1e-30)
    return out.swapaxes(1, 2).astype(q.dtype)      # (B,Sq,H,dh)


def sliding_window_attention(q, k, v, *, window: int,
                             scale: Optional[float] = None) -> jnp.ndarray:
    """Banded attention via same-chunk + previous-chunk blocks.

    Memory O(S·2w) instead of O(S²).  Requires S % window == 0.
    """
    b, s, h, dh = q.shape
    if s <= window or s % window != 0:
        # non-multiple lengths (tests, ragged tails): dense banded fallback
        return full_attention(q, k, v, window=window, scale=scale)
    nc = s // window
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)

    qc = q.reshape(b, nc, window, h, dh)
    kc = k.reshape(b, nc, window, h, dh)
    vc = v.reshape(b, nc, window, h, dh)
    # previous chunk (zeros before chunk 0)
    kp = jnp.pad(kc, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    vp = jnp.pad(vc, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    k2 = jnp.concatenate([kp, kc], axis=2)         # (B,nc,2w,H,dh)
    v2 = jnp.concatenate([vp, vc], axis=2)

    sc = jnp.einsum("bcqhd,bckhd->bchqk", qc,
                    k2).astype(jnp.float32) * scale
    # positions within the 2w key window: query i (0..w-1) at global w+i
    qi = jnp.arange(window)[:, None] + window
    kj = jnp.arange(2 * window)[None, :]
    mask = (kj <= qi) & (kj > qi - window)
    first = jnp.arange(nc) == 0                     # chunk 0 has no prev keys
    mask = mask[None] & ~(first[:, None, None] & (kj < window)[None])
    sc = jnp.where(mask[None, :, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1).astype(q.dtype)
    out = jnp.einsum("bchqk,bckhd->bcqhd", p, v2)
    return out.reshape(b, s, h, dh)


# ----------------------------------------------------------------------------
# Decode (single token vs KV cache)
# ----------------------------------------------------------------------------

def _decode_partial(q, k, v, valid, scale):
    """Partial flash-decode statistics over a KV shard.

    q (B,1,H,dh); k/v (B,Sl,H,dh); valid (B,Sl) bool.
    Returns m (B,H), l (B,H), o (B,H,dh) in fp32.
    """
    s = jnp.einsum("bqhd,bkhd->bhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale   # (B,H,Sl)
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid[:, None, :], p, 0.0)
    l = p.sum(axis=-1)
    o = jnp.einsum("bhk,bkhd->bhd", p, v.astype(jnp.float32))
    return m, l, o


def decode_attention(q, k_cache, v_cache, valid, *,
                     groups: int,
                     scale: Optional[float] = None,
                     mesh=None, rules=None,
                     seq_shard: bool = True) -> jnp.ndarray:
    """One-token attention against a cache (B,Smax,Hkv,dh).

    ``valid`` (B,Smax) bool marks live cache slots (the caller handles
    ring-buffer / length semantics).  With a mesh and ``seq_shard`` the
    cache sequence dim is sharded over the model axis and partial softmax
    statistics are combined with psum/pmax (flash-decode).
    """
    dh = q.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    smax = k_cache.shape[1]

    def local(q, k, v, valid):
        ke = _expand_kv(k, groups)
        ve = _expand_kv(v, groups)
        return _decode_partial(q, ke, ve, valid, scale)

    use_shard = (mesh is not None and not mesh.empty
                 and "model" in mesh.axis_names and seq_shard
                 and smax % mesh.shape["model"] == 0)
    if not use_shard:
        m, l, o = local(q, k_cache, v_cache, valid)
        out = o / jnp.maximum(l[..., None], 1e-30)
        return out[:, None].astype(q.dtype).reshape(q.shape)

    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_batch = 1
    for a in batch_axes:
        n_batch *= mesh.shape[a]
    # small batches (e.g. the 500k single-sequence cell) replicate
    bspec = batch_axes if (batch_axes and q.shape[0] % n_batch == 0) \
        else None

    def shard_fn(q, k, v, valid):
        # per-device: q (B_l,1,H,dh) replicated over model; k/v seq-shard
        m, l, o = local(q, k, v, valid)
        m_g = jax.lax.pmax(m, "model")
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, "model")
        o_g = jax.lax.psum(o * corr[..., None], "model")
        return o_g / jnp.maximum(l_g[..., None], 1e-30)

    out = shard_map_compat(
        shard_fn, mesh=mesh,
        in_specs=(P(bspec, None, None, None),
                  P(bspec, "model", None, None),
                  P(bspec, "model", None, None),
                  P(bspec, "model")),
        out_specs=P(bspec, None, None),
    )(q, k_cache, v_cache, valid)
    return out[:, None].astype(q.dtype)             # (B,1,H,dh)


# ----------------------------------------------------------------------------
# Attention block (projections + rope + core + output)
# ----------------------------------------------------------------------------

def attention_apply(
    p: PyTree,
    x: jnp.ndarray,
    cfg: ModelConfig,
    rcfg: RunConfig,
    *,
    mode: str,                            # train | prefill | decode
    positions: Optional[jnp.ndarray] = None,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    window: Optional[int] = None,
    dense_attn_max_seq: int = 8192,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Full attention sub-block.  x (B,S,D) -> (y (B,S,D), new_cache)."""
    b, s, d = x.shape
    h, hk = cfg.n_heads, cfg.n_kv_heads
    dh = cfg.resolved_head_dim
    groups = h // hk
    win = cfg.window if window is None else window
    cd = rcfg.compute_dtype
    mesh, rules = rcfg.mesh, rcfg.rules

    q = dense(x, p["wq"], p.get("bq"), cd).reshape(b, s, h, dh)
    k = dense(x, p["wk"], p.get("bk"), cd).reshape(b, s, hk, dh)
    v = dense(x, p["wv"], p.get("bv"), cd).reshape(b, s, hk, dh)

    if positions is None:
        positions = jnp.arange(s)[None, :]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    q = shard(q, ("batch", "seq", "heads_act", None), rules, mesh)
    k = shard(k, ("batch", "seq", "kv_heads_act", None), rules, mesh)
    v = shard(v, ("batch", "seq", "kv_heads_act", None), rules, mesh)

    new_cache = None
    if mode == "decode":
        if cache is None or s != 1:
            raise ValueError(
                "attention decode mode needs a cache (from mode='prefill') "
                f"and a single-token input; got cache={cache is not None}, "
                f"seq_len={s}")
        clen = cache["len"]                   # global position counter
        slots = cache["k"].shape[1]
        # ring-buffer write for windowed caches; plain append otherwise
        widx = clen % slots if win > 0 else clen
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), widx, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), widx, axis=1)
        k_cache = shard(k_cache, ("batch", "kv_seq", None, None), rules, mesh)
        v_cache = shard(v_cache, ("batch", "kv_seq", None, None), rules, mesh)
        new_cache = {"k": k_cache, "v": v_cache, "len": clen + 1}
        valid = jnp.arange(slots)[None, :] < jnp.minimum(clen + 1, slots)
        valid = jnp.broadcast_to(valid, (b, slots))
        out = decode_attention(
            q, k_cache, v_cache, valid, groups=groups,
            mesh=mesh, rules=rules, seq_shard=rcfg.decode_seq_shard)
    else:
        ke = _expand_kv(k, groups)
        ve = _expand_kv(v, groups)
        ke = shard(ke, ("batch", "seq", "heads_act", None), rules, mesh)
        ve = shard(ve, ("batch", "seq", "heads_act", None), rules, mesh)
        if win > 0 and s > win:
            out = sliding_window_attention(q, ke, ve, window=win)
        elif s <= dense_attn_max_seq:
            out = full_attention(q, ke, ve, window=win)
        else:
            out = chunked_attention(q, ke, ve, window=win)
        if mode == "prefill":
            # write k/v into a fixed-capacity (ring for windowed) cache
            slots = rcfg.max_seq if win == 0 else min(rcfg.max_seq, win)
            slots = max(slots, s if win == 0 else min(s, win))
            if win > 0 and s >= slots:
                kk = k[:, -slots:]
                vv = v[:, -slots:]
                shift = s % slots
                if shift:   # key at global pos p lives at slot p % slots
                    kk = jnp.roll(kk, shift, axis=1)
                    vv = jnp.roll(vv, shift, axis=1)
            else:
                pad = slots - s
                kk = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                vv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            kk = shard(kk, ("batch", "kv_seq", None, None), rules, mesh)
            vv = shard(vv, ("batch", "kv_seq", None, None), rules, mesh)
            new_cache = {"k": kk, "v": vv, "len": jnp.asarray(s, jnp.int32)}

    out = shard(out, ("batch", "seq", "heads_act", None), rules, mesh)
    y = dense(out.reshape(b, s, h * dh), p["wo"], None, cd)
    y = shard(y, ("batch", "res_seq", "embed_act"), rules, mesh)
    return y, new_cache
