"""Shared model building blocks: ParamDef trees, norms, RoPE, initializers.

``ParamDef`` is the single source of truth for every parameter: its shape,
dtype, *logical* sharding axes and initializer.  From one ParamDef tree we
derive

  * ``init_params``      — materialized arrays (CPU smoke tests, examples),
  * ``abstract_params``  — ``jax.ShapeDtypeStruct`` stand-ins (dry-run
    lowering: no allocation ever happens for the full-size configs),
  * ``param_specs``      — ``PartitionSpec`` tree for pjit shardings.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import AxisRules, logical_to_spec

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    dtype: Any
    logical: Tuple[Optional[str], ...]
    init: str = "normal"      # normal | zeros | ones | embed | uniform_ssm
    scale: Optional[float] = None  # stddev override for "normal"

    def __post_init__(self):
        if len(self.shape) != len(self.logical):
            raise ValueError(
                f"ParamDef: shape {self.shape} and logical axes "
                f"{self.logical} have different ranks")


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _fan_in(shape: Tuple[int, ...]) -> int:
    # weights are stored (in_dim..., out_dim); fan-in = prod of all but last
    if len(shape) == 1:
        return shape[0]
    return int(math.prod(shape[:-1]))


def _init_one(d: ParamDef, key) -> jnp.ndarray:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "embed":
        std = d.scale if d.scale is not None else 0.02
        return (jax.random.normal(key, d.shape) * std).astype(d.dtype)
    if d.init == "uniform_ssm":
        # A_log init for SSMs: A in [1, 16], stored as log
        u = jax.random.uniform(key, d.shape, minval=1.0, maxval=16.0)
        return jnp.log(u).astype(d.dtype)
    if d.init == "normal":
        std = d.scale if d.scale is not None else 1.0 / math.sqrt(
            max(_fan_in(d.shape), 1))
        return (jax.random.normal(key, d.shape) * std).astype(d.dtype)
    raise ValueError(f"unknown init {d.init!r}")


def init_params(defs: PyTree, key) -> PyTree:
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(d, k) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(defs: PyTree) -> PyTree:
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs,
        is_leaf=_is_def)


def param_specs(defs: PyTree, rules: AxisRules, mesh=None) -> PyTree:
    return jax.tree.map(
        lambda d: logical_to_spec(d.logical, rules, mesh), defs,
        is_leaf=_is_def)


def param_count(defs: PyTree) -> int:
    return sum(
        int(math.prod(d.shape))
        for d in jax.tree.leaves(defs, is_leaf=_is_def))


def param_bytes(defs: PyTree) -> int:
    return sum(
        int(math.prod(d.shape)) * jnp.dtype(d.dtype).itemsize
        for d in jax.tree.leaves(defs, is_leaf=_is_def))


# ----------------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, weight: jnp.ndarray,
            eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm in fp32 with cast back to input dtype (production practice)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(dt)


def layernorm(x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dt)


def apply_norm(kind: str, x, p, eps: float = 1e-6):
    if kind == "rmsnorm":
        return rmsnorm(x, p["w"], eps)
    if kind == "layernorm":
        return layernorm(x, p["w"], p["b"], eps)
    raise ValueError(kind)


def norm_defs(kind: str, dim: int, dtype) -> PyTree:
    if kind == "rmsnorm":
        return {"w": ParamDef((dim,), dtype, ("embed_act",), init="ones")}
    if kind == "layernorm":
        return {
            "w": ParamDef((dim,), dtype, ("embed_act",), init="ones"),
            "b": ParamDef((dim,), dtype, ("embed_act",), init="zeros"),
        }
    raise ValueError(kind)


# ----------------------------------------------------------------------------
# Rotary position embeddings
# ----------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies (head_dim/2,), fp32."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """Rotate (..., S, H, Dh) by positions (..., S); NeoX-style half-split."""
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)                       # (dh/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., S, dh/2)
    cos = jnp.cos(ang)[..., None, :]                  # (..., S, 1, dh/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ----------------------------------------------------------------------------
# Misc
# ----------------------------------------------------------------------------

def dense(x: jnp.ndarray, w: jnp.ndarray, b: Optional[jnp.ndarray] = None,
          compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    """x @ w (+ b) with params cast to the compute dtype."""
    y = jnp.einsum("...d,df->...f", x.astype(compute_dtype),
                   w.astype(compute_dtype))
    if b is not None:
        y = y + b.astype(compute_dtype)
    return y


def activation(name: str, x):
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu":
        return jax.nn.relu(x)
    raise ValueError(name)
