"""RG-LRU recurrent block (Griffin / RecurrentGemma).

The Real-Gated Linear Recurrent Unit is a gated leaky integrator

    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t),
    a_t = exp(-c · softplus(Λ) · r_t),     r_t, i_t = σ(block-diag gates)

— literally a (zero-order-hold discretized) diagonal linear ODE, which is
why this family is the paper's closest architectural relative: the
recurrence *is* a per-channel adaptive-stepsize integrator.

Training/prefill uses ``lax.associative_scan`` (log-depth on TPU);
decode is the O(1) single-step recurrence over a carried state.

Block structure (Griffin recurrent block):
    y = W_out( GeLU(W_gate x) ⊙ RG-LRU(conv1d_4(W_x x)) )
Gate matrices are block-diagonal with n_blocks blocks (sharded over the
model axis along the block dim).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from .common import ParamDef, dense
from .config import ModelConfig, RunConfig

PyTree = Any

_C = 8.0  # Griffin's fixed gate sharpness constant


def rglru_defs(cfg: ModelConfig, param_dtype, n_blocks: int = 16) -> PyTree:
    d, dr = cfg.d_model, cfg.resolved_d_rnn
    bw = dr // n_blocks
    return {
        "w_x": ParamDef((d, dr), param_dtype, ("embed", "mlp")),
        "w_gate": ParamDef((d, dr), param_dtype, ("embed", "mlp")),
        "w_out": ParamDef((dr, d), param_dtype, ("mlp", "embed")),
        "conv": ParamDef((cfg.conv_width, dr), param_dtype, ("conv", "mlp_act")),
        "conv_b": ParamDef((dr,), param_dtype, ("mlp_act",), init="zeros"),
        # block-diagonal recurrence / input gates
        "w_a": ParamDef((n_blocks, bw, bw), param_dtype,
                        ("mlp", None, None)),
        "b_a": ParamDef((dr,), param_dtype, ("mlp_act",), init="zeros"),
        "w_i": ParamDef((n_blocks, bw, bw), param_dtype,
                        ("mlp", None, None)),
        "b_i": ParamDef((dr,), param_dtype, ("mlp_act",), init="zeros"),
        # Λ init so that a^c ≈ U[0.9, 0.999] at r=1 (Griffin appendix)
        "lam": ParamDef((dr,), jnp.float32, ("mlp_act",), init="normal",
                        scale=0.5),
    }


def _blockdiag(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x (...,D) @ block-diag(w) with w (nb, bw, bw)."""
    nb, bw, _ = w.shape
    xb = x.reshape(x.shape[:-1] + (nb, bw))
    yb = jnp.einsum("...nb,nbc->...nc", xb, w)
    return yb.reshape(x.shape)


def conv_tail(x: jnp.ndarray, w: int) -> jnp.ndarray:
    """Last w-1 positions of x (B,S,C), left-padded with zeros if S < w-1
    — the decode-time conv state after a prefill."""
    if w <= 1:
        return x[:, :0]
    s = x.shape[1]
    tail = x[:, -min(s, w - 1):]
    if tail.shape[1] < w - 1:
        tail = jnp.pad(tail, ((0, 0), (w - 1 - tail.shape[1], 0), (0, 0)))
    return tail


def causal_conv1d(x: jnp.ndarray, kernel: jnp.ndarray,
                  bias: Optional[jnp.ndarray] = None,
                  state: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv.  x (B,S,C); kernel (W,C); state (B,W-1,C)
    prepends history (decode).  Returns same shape as x."""
    w = kernel.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], w - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * kernel[i].astype(x.dtype)
            for i in range(w))
    if bias is not None:
        y = y + bias.astype(x.dtype)
    return y


def rglru_scan(x: jnp.ndarray, r: jnp.ndarray, i: jnp.ndarray,
               lam: jnp.ndarray,
               h0: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray,
                                                          jnp.ndarray]:
    """The RG-LRU recurrence over (B,S,C) in fp32 via associative scan.

    Returns (h (B,S,C), h_last (B,C))."""
    xf = x.astype(jnp.float32)
    log_a = -_C * jax.nn.softplus(lam)[None, None] * r       # (B,S,C)
    a = jnp.exp(log_a)
    # sqrt(1-a^2) computed stably via expm1: 1-exp(2 log_a)
    b_scale = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    b = b_scale * (i * xf)

    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(p, q):
        a1, b1 = p
        a2, b2 = q
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_block_apply(
    p: PyTree,
    x: jnp.ndarray,
    cfg: ModelConfig,
    rcfg: RunConfig,
    *,
    mode: str = "train",
    cache: Optional[Dict[str, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Griffin recurrent block.  x (B,S,D) -> (y (B,S,D), new_cache)."""
    cd = rcfg.compute_dtype
    mesh, rules = rcfg.mesh, rcfg.rules
    b, s, _ = x.shape

    gate = jax.nn.gelu(dense(x, p["w_gate"], None, cd))
    gate = shard(gate, ("batch", "seq", "mlp_act"), rules, mesh)
    u_raw = dense(x, p["w_x"], None, cd)       # pre-conv (cached for decode)
    u_raw = shard(u_raw, ("batch", "seq", "mlp_act"), rules, mesh)

    conv_state = cache["conv"] if cache is not None else None
    u = causal_conv1d(u_raw, p["conv"], p["conv_b"], state=conv_state)

    r = jax.nn.sigmoid(
        _blockdiag(u.astype(jnp.float32), p["w_a"].astype(jnp.float32))
        + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(
        _blockdiag(u.astype(jnp.float32), p["w_i"].astype(jnp.float32))
        + p["b_i"].astype(jnp.float32))

    new_cache = None
    if mode == "decode":
        if cache is None or s != 1:
            raise ValueError(
                "rglru decode mode needs a cache (from mode='prefill') "
                f"and a single-token input; got cache={cache is not None}, "
                f"seq_len={s}")
        h_prev = cache["h"]                               # (B, Dr)
        log_a = -_C * jax.nn.softplus(p["lam"])[None] * r[:, 0]
        a = jnp.exp(log_a)
        bsc = jnp.sqrt(-jnp.expm1(2.0 * log_a))
        h_new = a * h_prev.astype(jnp.float32) + bsc * (
            i[:, 0] * u[:, 0].astype(jnp.float32))
        h = h_new[:, None].astype(cd)
        w = p["conv"].shape[0]
        conv_new = jnp.concatenate(
            [cache["conv"][:, 1:], u_raw.astype(cache["conv"].dtype)],
            axis=1) if w > 1 else cache["conv"]
        new_cache = {"conv": conv_new, "h": h_new}
    else:
        h0 = cache["h"] if cache is not None else None
        h, h_last = rglru_scan(u, r, i, p["lam"], h0=h0)
        if mode == "prefill":
            w = p["conv"].shape[0]
            conv_new = conv_tail(u_raw, w).astype(jnp.float32)
            new_cache = {"conv": conv_new, "h": h_last}

    y = dense(gate * h.astype(cd), p["w_out"], None, cd)
    y = shard(y, ("batch", "res_seq", "embed_act"), rules, mesh)
    return y, new_cache


def rglru_cache_defs(cfg: ModelConfig, batch: int, dtype) -> PyTree:
    dr = cfg.resolved_d_rnn
    return {
        "conv": ParamDef((batch, cfg.conv_width - 1, dr), jnp.float32,
                         ("batch", None, "mlp_act"), init="zeros"),
        "h": ParamDef((batch, dr), jnp.float32, ("batch", "mlp_act"),
                      init="zeros"),
    }
