"""Transformer stack: block composition, scan-over-layers, NODE mode.

A *block* is (norm → mixer → residual, norm → ffn/moe → residual), or the
parallel variant (Cohere Command-R style: attn and ffn both read one
norm).  The mixer is attention, an RG-LRU recurrent block, or a Mamba-2
SSM block depending on ``cfg.family`` / ``cfg.pattern``.

The stack runs as ``lax.scan`` over stacked per-layer parameters — HLO
size O(1) in depth, mandatory for 64–94-layer configs to compile on 512
devices.  Hybrid (RecurrentGemma) stacks scan over repeating *groups*
(("rec","rec","attn")); trailing remainder layers apply unscanned.

NODE mode — the paper's contribution as a first-class feature: each
block's residual branch becomes the dynamics of an ODE block
``z(1) = z(0) + ∫₀¹ f(z) dt`` (Eq. 30 → 31), solved with the configured
solver and differentiated with ACA (or adjoint/naive for the paper's
comparisons).  The ``fixed`` regime (static step count) is used for
multi-pod lowering; ``adaptive`` matches the paper's training setup.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.node_block import NodeConfig, node_block_apply
from .attention import attention_apply, attn_defs
from .common import ParamDef, apply_norm, norm_defs
from .config import ModelConfig, RunConfig
from .ffn import ffn_apply, ffn_defs
from .mamba2 import mamba2_block_apply, mamba2_cache_defs, mamba2_defs
from .moe import moe_apply, moe_defs
from .rglru import rglru_block_apply, rglru_cache_defs, rglru_defs

PyTree = Any


# ----------------------------------------------------------------------------
# Per-layer definitions
# ----------------------------------------------------------------------------

def layer_kinds(cfg: ModelConfig) -> List[str]:
    """Block kind per layer: 'attn' | 'moe_attn' | 'rec' | 'ssm'."""
    if cfg.family == "ssm":
        return ["ssm"] * cfg.n_layers
    if cfg.family == "hybrid":
        pat = cfg.pattern or ("rec", "rec", "attn")
        return [pat[i % len(pat)] for i in range(cfg.n_layers)]
    if cfg.family == "moe":
        return ["moe_attn"] * cfg.n_layers
    return ["attn"] * cfg.n_layers


def block_defs(cfg: ModelConfig, kind: str, param_dtype) -> PyTree:
    d = {"norm1": norm_defs(cfg.norm, cfg.d_model, param_dtype)}
    if kind == "ssm":
        d["mixer"] = mamba2_defs(cfg, param_dtype)
        return d  # mamba2 blocks are single-residual (no separate ffn)
    if kind == "rec":
        d["mixer"] = rglru_defs(cfg, param_dtype)
    else:
        d["mixer"] = attn_defs(cfg, param_dtype)
    if not cfg.parallel_block:
        d["norm2"] = norm_defs(cfg.norm, cfg.d_model, param_dtype)
    if kind == "moe_attn":
        d["moe"] = moe_defs(cfg, param_dtype)
    else:
        d["ffn"] = ffn_defs(cfg, param_dtype, gated=(cfg.act == "silu"))
    return d


def block_cache_defs(cfg: ModelConfig, kind: str, batch: int,
                     max_seq: int, cache_dtype) -> Optional[PyTree]:
    hk, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    if kind == "ssm":
        return mamba2_cache_defs(cfg, batch)
    if kind == "rec":
        return rglru_cache_defs(cfg, batch, cache_dtype)
    # attention KV cache; window-limited archs only need the window
    slots = max_seq if cfg.window == 0 else min(max_seq, cfg.window)
    return {
        "k": ParamDef((batch, slots, hk, dh), cache_dtype,
                      ("batch", "kv_seq", None, None), init="zeros"),
        "v": ParamDef((batch, slots, hk, dh), cache_dtype,
                      ("batch", "kv_seq", None, None), init="zeros"),
        "len": ParamDef((), jnp.int32, (), init="zeros"),
    }


# ----------------------------------------------------------------------------
# Block application
# ----------------------------------------------------------------------------

def _mixer_apply(kind: str, p, x, cfg, rcfg, *, mode, positions, cache):
    if kind == "ssm":
        return mamba2_block_apply(p, x, cfg, rcfg, mode=mode, cache=cache)
    if kind == "rec":
        return rglru_block_apply(p, x, cfg, rcfg, mode=mode, cache=cache)
    return attention_apply(p, x, cfg, rcfg, mode=mode, positions=positions,
                           cache=cache)


def block_apply(
    p: PyTree,
    x: jnp.ndarray,
    cfg: ModelConfig,
    rcfg: RunConfig,
    kind: str,
    *,
    mode: str = "train",
    positions: Optional[jnp.ndarray] = None,
    cache: Optional[PyTree] = None,
) -> Tuple[jnp.ndarray, Optional[PyTree], jnp.ndarray]:
    """One block with residuals.  Returns (y, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(cfg.norm, x, p["norm1"], cfg.norm_eps)
    mix, new_cache = _mixer_apply(kind, p["mixer"], h, cfg, rcfg,
                                  mode=mode, positions=positions,
                                  cache=cache)
    if kind == "ssm":
        return x + mix, new_cache, aux

    if cfg.parallel_block:
        # Command-R: y = x + attn(n(x)) + ffn(n(x))
        if kind == "moe_attn":
            f, aux = moe_apply(p["moe"], h, cfg, rcfg)
        else:
            f = ffn_apply(p["ffn"], h, cfg, rcfg)
        return x + mix + f, new_cache, aux

    y = x + mix
    h2 = apply_norm(cfg.norm, y, p["norm2"], cfg.norm_eps)
    if kind == "moe_attn":
        f, aux = moe_apply(p["moe"], h2, cfg, rcfg)
    else:
        f = ffn_apply(p["ffn"], h2, cfg, rcfg)
    return y + f, new_cache, aux


def _branch_fn(p, x, cfg, rcfg, kind, positions):
    """The residual *branch* (dy = block(x) - x) — NODE dynamics f."""
    y, _, _ = block_apply(p, x, cfg, rcfg, kind, mode="train",
                          positions=positions, cache=None)
    return y - x


# ----------------------------------------------------------------------------
# Stack
# ----------------------------------------------------------------------------

def _stack_defs(defs: PyTree, n: int) -> PyTree:
    """Prepend a stacked-layers dim to every ParamDef leaf."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, d.dtype,
                           ("layers",) + d.logical, init=d.init,
                           scale=d.scale),
        defs, is_leaf=lambda d: isinstance(d, ParamDef))


def stack_plan(cfg: ModelConfig) -> Tuple[Tuple[str, ...], int, List[str]]:
    """(repeating unit kinds, n_groups, tail kinds)."""
    kinds = layer_kinds(cfg)
    if cfg.family == "hybrid":
        pat = cfg.pattern or ("rec", "rec", "attn")
        n_groups = cfg.n_layers // len(pat)
        tail = kinds[n_groups * len(pat):]
        return tuple(pat), n_groups, tail
    return (kinds[0],), cfg.n_layers, []


def stack_defs(cfg: ModelConfig, param_dtype) -> PyTree:
    unit, n_groups, tail = stack_plan(cfg)
    d: Dict[str, PyTree] = {}
    for j, kind in enumerate(unit):
        d[f"u{j}_{kind}"] = _stack_defs(
            block_defs(cfg, kind, param_dtype), n_groups)
    for j, kind in enumerate(tail):
        d[f"tail{j}_{kind}"] = block_defs(cfg, kind, param_dtype)
    return d


def stack_cache_defs(cfg: ModelConfig, batch: int, max_seq: int,
                     cache_dtype) -> PyTree:
    unit, n_groups, tail = stack_plan(cfg)
    d: Dict[str, PyTree] = {}
    for j, kind in enumerate(unit):
        cd = block_cache_defs(cfg, kind, batch, max_seq, cache_dtype)
        d[f"u{j}_{kind}"] = _stack_defs(cd, n_groups)
    for j, kind in enumerate(tail):
        d[f"tail{j}_{kind}"] = block_cache_defs(cfg, kind, batch, max_seq,
                                                cache_dtype)
    return d


def _apply_one(p, x, cfg, rcfg, kind, mode, positions, cache):
    if rcfg.node.enabled and mode == "train":
        # the paper: residual block -> ODE block, ACA gradients.
        # RunConfig.use_pallas turns on the fused flat-state solver path
        # for every NODE block, matching the kernels used elsewhere.
        ncfg = rcfg.node
        if rcfg.use_pallas and not ncfg.use_pallas:
            ncfg = dataclasses.replace(ncfg, use_pallas=True)
        zT = node_block_apply(
            lambda pp, z, t: _branch_fn(pp, z, cfg, rcfg, kind, positions),
            p, x, ncfg)
        return zT, None, jnp.zeros((), jnp.float32)
    return block_apply(p, x, cfg, rcfg, kind, mode=mode,
                       positions=positions, cache=cache)


def stack_apply(
    params: PyTree,
    x: jnp.ndarray,
    cfg: ModelConfig,
    rcfg: RunConfig,
    *,
    mode: str = "train",
    positions: Optional[jnp.ndarray] = None,
    caches: Optional[PyTree] = None,
) -> Tuple[jnp.ndarray, Optional[PyTree], jnp.ndarray]:
    """Apply the full stack.  Returns (y, new_caches, aux_loss_sum)."""
    unit, n_groups, tail = stack_plan(cfg)
    need_cache = mode in ("prefill", "decode")
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, PyTree] = {}

    def group_body(x, layer_in):
        gp, gc = layer_in
        aux_g = jnp.zeros((), jnp.float32)
        outs = {}
        for j, kind in enumerate(unit):
            key = f"u{j}_{kind}"
            c = gc.get(key) if gc is not None else None
            x, nc, aux = _apply_one(gp[key], x, cfg, rcfg, kind, mode,
                                    positions, c)
            if need_cache:
                outs[key] = nc
            aux_g = aux_g + aux
        return x, (outs if need_cache else None, aux_g)

    group_params = {k: v for k, v in params.items() if k.startswith("u")}
    group_caches = None
    if caches is not None:
        group_caches = {k: v for k, v in caches.items()
                        if k.startswith("u")}

    if rcfg.scan_layers and n_groups > 1:
        body = group_body
        if rcfg.remat == "block":
            body = jax.checkpoint(group_body)
        x, (cache_out, aux_stack) = jax.lax.scan(
            body, x, (group_params,
                      group_caches if group_caches is not None
                      else _none_tree(group_params, n_groups)))
        aux_total = aux_total + aux_stack.sum()
        if need_cache:
            new_caches.update(cache_out)
    else:
        for i in range(n_groups):
            gp = jax.tree.map(lambda v: v[i], group_params)
            gc = jax.tree.map(lambda v: v[i], group_caches) \
                if group_caches is not None else None
            x, (outs, aux_g) = group_body(x, (gp, gc))
            aux_total = aux_total + aux_g
            if need_cache:
                for k, v in outs.items():
                    new_caches.setdefault(k, []).append(v)
        if need_cache and new_caches:
            new_caches = {
                k: jax.tree.map(lambda *ls: jnp.stack(ls), *v)
                for k, v in new_caches.items()}

    for j, kind in enumerate(tail):
        key = f"tail{j}_{kind}"
        c = caches.get(key) if caches is not None else None
        x, nc, aux = _apply_one(params[key], x, cfg, rcfg, kind, mode,
                                positions, c)
        aux_total = aux_total + aux
        if need_cache:
            new_caches[key] = nc

    return x, (new_caches if need_cache else None), aux_total


def _none_tree(group_params: PyTree, n: int):
    """Placeholder cache xs for scan when no cache is threaded."""
    return {k: None for k in group_params}
