"""Modality frontend STUBS (per assignment: backbone-only).

The ``[vlm]`` / ``[audio]`` architectures are exercised through their
transformer backbone; the image / audio encoders are represented by
precomputed embeddings supplied through ``input_specs()``:

  * llava-next  — "anyres" tiling produces N patch embeddings per image;
    the stub supplies ``embeds`` = concat(patch_embeds, text_embeds)
    already projected to d_model.
  * musicgen    — EnCodec tokenization produces 4-codebook frames; the
    stub supplies per-frame summed codebook embeddings at d_model.

These helpers produce ShapeDtypeStructs for the dry-run and synthetic
arrays for smoke tests; shapes match the (B, S) of the assigned input
shape with S counting frontend positions.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .config import ModelConfig

PyTree = Any


def frontend_batch_abstract(cfg: ModelConfig, batch: int, seq: int,
                            compute_dtype=jnp.bfloat16
                            ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct batch for a frontend-stub arch (train mode)."""
    return {
        "embeds": jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                       compute_dtype),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "mask": jax.ShapeDtypeStruct((batch, seq), jnp.float32),
    }


def frontend_batch_synthetic(cfg: ModelConfig, batch: int, seq: int, key,
                             compute_dtype=jnp.bfloat16
                             ) -> Dict[str, jnp.ndarray]:
    k1, k2 = jax.random.split(key)
    return {
        "embeds": (jax.random.normal(k1, (batch, seq, cfg.d_model)) * 0.02
                   ).astype(compute_dtype),
        "labels": jax.random.randint(k2, (batch, seq), 0, cfg.vocab,
                                     jnp.int32),
        "mask": jnp.ones((batch, seq), jnp.float32),
    }
