"""Mixture-of-Experts FFN with expert parallelism.

Design (1000+-chip posture):

* Experts are sharded over the **model** axis (EP): each model shard owns
  E/n_model experts.  Expert weight matrices additionally shard their
  d_model dim over the **data** axis (FSDP); the forward all-gathers them
  over "data" (transposed to a reduce-scatter in the backward) — ZeRO-3
  memory scaling for the dominant parameter block of MoE models.
* Activations stay **replicated over model** between blocks (standard TP
  residual stream).  Each model shard routes the full local token set,
  gathers the tokens assigned to *its* experts into a static-capacity
  buffer (scatter/gather, no (T,E,C) one-hot), runs a batched expert FFN,
  scatters back, and a single psum over "model" combines expert
  contributions — the same collective TP-FFN needs anyway, so EP adds no
  extra communication beyond the FSDP weight gathers.
* Routing: softmax router, top-k with renormalization, static capacity
  C = ceil(T_local·k/E·capacity_factor); overflow tokens are dropped
  (standard capacity-style MoE).  A Switch-style load-balancing aux loss
  is returned to the trainer.

The router's hard top-k is a discrete decision *inside* the dynamics f
when NODE mode wraps an MoE block; ACA only needs f a.e.-differentiable
(paper Appendix C), which holds.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (logical_to_spec, shard,
                                        shard_map_compat)
from .common import ParamDef, activation, dense
from .config import ModelConfig, RunConfig
from .ffn import ffn_apply, ffn_defs

PyTree = Any


def moe_defs(cfg: ModelConfig, param_dtype) -> PyTree:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_expert
    defs = {
        "router": ParamDef((d, e), param_dtype, ("embed_act", None),
                           scale=0.02),
        "w_gate": ParamDef((e, d, f), param_dtype, ("expert", "embed", None)),
        "w_in": ParamDef((e, d, f), param_dtype, ("expert", "embed", None)),
        "w_out": ParamDef((e, f, d), param_dtype, ("expert", None, "embed")),
    }
    if cfg.n_shared_experts:
        shared_cfg = cfg.scaled(d_ff=cfg.n_shared_experts * f, mlp_bias=False)
        defs["shared"] = ffn_defs(shared_cfg, param_dtype,
                                  d_ff=cfg.n_shared_experts * f)
    return defs


def _capacity(tokens_local: int, cfg: ModelConfig) -> int:
    c = tokens_local * cfg.top_k / cfg.n_experts * cfg.capacity_factor
    return max(int(math.ceil(c)), 1)


def _route(x: jnp.ndarray, router_w: jnp.ndarray,
           cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Router: returns (ids (B,S,k) int32, gates (B,S,k) fp32, probs)."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return ids.astype(jnp.int32), gates, probs


def aux_load_balance_loss(ids: jnp.ndarray, probs: jnp.ndarray,
                          n_experts: int) -> jnp.ndarray:
    """Switch-Transformer load-balancing loss: E · Σ_e f_e · p̄_e."""
    assign = jax.nn.one_hot(ids, n_experts, dtype=jnp.float32).sum(-2)
    f_e = assign.mean(axis=tuple(range(assign.ndim - 1)))
    p_e = probs.mean(axis=tuple(range(probs.ndim - 1)))
    return n_experts * jnp.sum(f_e * p_e / max(1, 1))


def _expert_compute(xe: jnp.ndarray, w_gate, w_in, w_out,
                    act: str, cd) -> jnp.ndarray:
    """Batched expert SwiGLU: xe (E_l, C, D) -> (E_l, C, D)."""
    g = jnp.einsum("ecd,edf->ecf", xe, w_gate.astype(cd))
    h = jnp.einsum("ecd,edf->ecf", xe, w_in.astype(cd))
    h = activation(act, g) * h
    return jnp.einsum("ecf,efd->ecd", h, w_out.astype(cd))


def _dispatch_compute_combine(
    x_flat: jnp.ndarray,        # (T, D)
    ids: jnp.ndarray,           # (T, k)
    gates: jnp.ndarray,         # (T, k) fp32
    w_gate, w_in, w_out,        # (E_l, D, F) / (E_l, F, D)
    e_offset: int,
    capacity: int,
    cfg: ModelConfig,
    cd,
) -> jnp.ndarray:
    """Capacity-dispatch for the E_l local experts.  Returns (T, D)."""
    t, d = x_flat.shape
    e_l = w_in.shape[0]
    c = capacity

    # local-expert assignment mask (T, E_l) and per-pair gate values
    local_ids = ids - e_offset                       # (T, k)
    onehot = jax.nn.one_hot(local_ids, e_l, dtype=jnp.float32)  # (T,k,E_l)
    assign = onehot.max(axis=1) > 0                  # (T, E_l) bool
    gate_te = jnp.einsum("tk,tke->te", gates, onehot)  # (T, E_l)

    # slot within each expert's capacity buffer
    pos = jnp.cumsum(assign.astype(jnp.int32), axis=0) - 1      # (T, E_l)
    keep = assign & (pos < c)
    slot = jnp.where(keep, pos, c)                   # overflow -> trash slot

    # build (E_l, C+1) token-index table via one scatter
    slots = jnp.full((e_l, c + 1), t, jnp.int32)     # sentinel = pad row
    e_idx = jnp.broadcast_to(jnp.arange(e_l)[None], (t, e_l))
    tok_idx = jnp.broadcast_to(jnp.arange(t)[:, None], (t, e_l))
    slots = slots.at[e_idx.reshape(-1), slot.reshape(-1)].set(
        tok_idx.reshape(-1), mode="drop")
    slots = slots[:, :c]                             # (E_l, C)

    # gather tokens (sentinel hits the zero pad row)
    x_pad = jnp.concatenate([x_flat, jnp.zeros((1, d), x_flat.dtype)], 0)
    xe = x_pad[slots]                                # (E_l, C, D)

    ye = _expert_compute(xe, w_gate, w_in, w_out, cfg.act, cd)

    # combine: scatter-add weighted outputs back to token positions
    g_pad = jnp.concatenate([gate_te, jnp.zeros((1, e_l), gate_te.dtype)], 0)
    gate_slots = g_pad[slots, jnp.arange(e_l)[:, None]]          # (E_l, C)
    y = jnp.zeros((t + 1, d), ye.dtype)
    y = y.at[slots.reshape(-1)].add(
        (ye * gate_slots[..., None].astype(ye.dtype)).reshape(-1, d))
    return y[:t]


def moe_apply(
    p: PyTree,
    x: jnp.ndarray,
    cfg: ModelConfig,
    rcfg: RunConfig,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """MoE block: x (B,S,D) -> (y (B,S,D), aux_loss scalar)."""
    b, s, d = x.shape
    cd = rcfg.compute_dtype
    mesh, rules = rcfg.mesh, rcfg.rules

    ids, gates, probs = _route(x, p["router"], cfg)
    aux = aux_load_balance_loss(ids, probs, cfg.n_experts)

    use_shard = (mesh is not None and not mesh.empty
                 and "model" in mesh.axis_names)

    if use_shard:
        n_model = mesh.shape["model"]
        n_data_total = math.prod(
            mesh.shape[a] for a in ("pod", "data") if a in mesh.axis_names)
        if cfg.n_experts % n_model != 0:
            raise ValueError(
                f"moe: n_experts={cfg.n_experts} not divisible by the "
                f"mesh's model dim {n_model}")
        e_l = cfg.n_experts // n_model
        batch_axes = tuple(a for a in ("pod", "data")
                           if a in mesh.axis_names)
        if not batch_axes or b % n_data_total != 0:
            batch_axes = ()        # small batch: replicate over data
            n_data_total = 1
        t_local = (b // n_data_total) * s
        c = _capacity(t_local, cfg)
        bspec = batch_axes if batch_axes else None
        has_data = "data" in mesh.axis_names
        wspec = logical_to_spec(("expert", "embed", None), rules, mesh)
        wspec_out = logical_to_spec(("expert", None, "embed"), rules, mesh)

        def shard_fn(x, ids, gates, w_gate, w_in, w_out):
            bl, sl, _ = x.shape
            if has_data:  # FSDP: gather expert weights over the data axis
                w_gate = jax.lax.all_gather(w_gate, "data", axis=1,
                                            tiled=True)
                w_in = jax.lax.all_gather(w_in, "data", axis=1, tiled=True)
                w_out = jax.lax.all_gather(w_out, "data", axis=2, tiled=True)
            e_off = jax.lax.axis_index("model") * e_l
            y = _dispatch_compute_combine(
                x.reshape(bl * sl, d), ids.reshape(bl * sl, -1),
                gates.reshape(bl * sl, -1),
                w_gate.astype(cd), w_in.astype(cd), w_out.astype(cd),
                e_off, c, cfg, cd)
            # each token's k experts live on different model shards: combine
            y = jax.lax.psum(y, "model")
            return y.reshape(bl, sl, d)

        y = shard_map_compat(
            shard_fn, mesh=mesh,
            in_specs=(P(bspec, None, None), P(bspec, None, None),
                      P(bspec, None, None), wspec, wspec, wspec_out),
            out_specs=P(bspec, None, None),
        )(x, ids, gates, p["w_gate"], p["w_in"], p["w_out"])
    else:
        t_local = b * s
        c = _capacity(t_local, cfg)
        y = _dispatch_compute_combine(
            x.reshape(b * s, d), ids.reshape(b * s, -1),
            gates.reshape(b * s, -1),
            p["w_gate"].astype(cd), p["w_in"].astype(cd),
            p["w_out"].astype(cd), 0, c, cfg, cd)
        y = y.reshape(b, s, d)

    if "shared" in p:
        shared_cfg = cfg.scaled(
            d_ff=cfg.n_shared_experts * cfg.d_expert, mlp_bias=False)
        y = y + ffn_apply(p["shared"], x, shared_cfg, rcfg)

    y = shard(y, ("batch", "res_seq", "embed_act"), rules, mesh)
    return y.astype(x.dtype), aux
