"""Forward integration engines shared by every gradient method.

Two engines over the same Runge-Kutta stepper:

* ``adaptive_while_solve`` — ``lax.while_loop`` with a flattened
  trial/accept loop (the paper's Algorithm 1 with the inner stepsize search
  and outer time advance fused into one loop).  Dynamic trip count, *not*
  reverse-differentiable — used by ACA forward (with trajectory
  checkpoints), by the adjoint method's forward and backward solves, and
  for inference.  Accepted discretization points (t_i, h_i, z_i) are
  written into a fixed-capacity buffer: the paper's trajectory checkpoint.

* ``fixed_grid_solve`` — ``lax.scan`` over a precomputed grid.  Fully
  differentiable (this is also the "naive" method for fixed-step solvers).

Both engines integrate through a sorted array of evaluation times ``ts``
(the solver is forced to land exactly on each ``ts[k]``), supporting
latent-ODE style multi-time outputs.  States are arbitrary pytrees.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .controller import ControllerConfig, initial_stepsize, propose_stepsize
from .stepper import error_ratio, maybe_flatten, rk_step
from .tableaus import Tableau

PyTree = Any


class SolveStats(NamedTuple):
    n_steps: jnp.ndarray      # accepted steps (paper's N_t)
    n_trials: jnp.ndarray     # total ψ trials (N_t * m)
    nfe: jnp.ndarray          # number of f evaluations
    overflow: jnp.ndarray     # bool: checkpoint buffer exhausted


class Checkpoints(NamedTuple):
    """The paper's trajectory checkpoint: accepted grid + states.

    ``z`` holds z_i at the *start* of accepted interval i; ``t``/``h`` its
    start time and accepted stepsize; ``out_idx`` the index into ``ts`` that
    the interval's endpoint landed on (or -1).  Only slots [0, n) are valid.
    """
    t: jnp.ndarray            # (max_steps,)
    h: jnp.ndarray            # (max_steps,)
    z: PyTree                 # (max_steps, ...) per leaf
    out_idx: jnp.ndarray      # (max_steps,) int32
    n: jnp.ndarray            # number of valid slots


def _empty_buffer(z0: PyTree, max_steps: int) -> PyTree:
    return jax.tree.map(
        lambda l: jnp.zeros((max_steps,) + l.shape, l.dtype), z0)


def _buffer_set(buf: PyTree, i, val: PyTree) -> PyTree:
    return jax.tree.map(lambda b, v: b.at[i].set(v), buf, val)


def _where_tree(pred, a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def adaptive_while_solve(
    tab: Tableau,
    f: Callable,
    z0: PyTree,
    ts: jnp.ndarray,
    args: Tuple,
    rtol: float,
    atol: float,
    cfg: ControllerConfig,
    h0: Optional[jnp.ndarray] = None,
    use_pallas: bool = False,
) -> Tuple[PyTree, Checkpoints, SolveStats]:
    """Integrate dz/dt = f(t, z, *args) through increasing times ``ts``.

    Returns (ys, checkpoints, stats); ``ys`` is stacked over len(ts) with
    ys[0] = z0.  Not reverse-differentiable (while_loop) — wrap in
    custom_vjp (ACA / adjoint) or use only for inference.

    ``use_pallas`` selects the fused flat-state stepper path; callers
    pass an already-flat (N,) state (see ``stepper.flatten_problem``) —
    the trial step and its error norm then run as fused Pallas kernels
    and the while_loop carry/checkpoint buffers hold one flat array per
    slot.  Non-flat states silently use the pytree stepper.
    """
    n_eval = ts.shape[0]
    tdt = ts.dtype
    max_steps = cfg.max_steps
    # trial budget: every accepted step costs >= 1 trial
    max_total_trials = max_steps * cfg.max_trials

    if h0 is None:
        h0 = initial_stepsize(f, ts[0], z0, args, tab.order, rtol, atol)
    h0 = jnp.asarray(h0, tdt)

    ys = _empty_buffer(z0, n_eval)
    ys = _buffer_set(ys, 0, z0)

    ckpt_t = jnp.zeros((max_steps,), tdt)
    ckpt_h = jnp.zeros((max_steps,), tdt)
    ckpt_z = _empty_buffer(z0, max_steps)
    ckpt_oi = jnp.full((max_steps,), -1, jnp.int32)

    k0 = f(ts[0], z0, *args)
    nfe0 = jnp.asarray(1 + 2, jnp.int32)  # hinit costs 2 evals when h0 is None

    carry0 = dict(
        t=ts[0], z=z0, k0=k0, h=h0,
        prev_ratio=jnp.asarray(1.0, jnp.float32),
        i=jnp.asarray(0, jnp.int32),            # accepted steps so far
        eval_idx=jnp.asarray(1, jnp.int32),     # next ts[] to hit
        trials=jnp.asarray(0, jnp.int32),
        nfe=nfe0,
        ys=ys, ckpt_t=ckpt_t, ckpt_h=ckpt_h, ckpt_z=ckpt_z, ckpt_oi=ckpt_oi,
    )

    tiny = jnp.asarray(jnp.finfo(tdt).eps, tdt)

    def cond(c):
        return (
            (c["eval_idx"] < n_eval)
            & (c["i"] < max_steps)
            & (c["trials"] < max_total_trials)
        )

    def body(c):
        t, z, h = c["t"], c["z"], c["h"]
        t_target = ts[c["eval_idx"]]
        # clamp trial step to land exactly on the next eval time
        h_min = 16.0 * tiny * jnp.maximum(jnp.abs(t), jnp.asarray(1.0, tdt))
        h_use = jnp.clip(h, h_min, t_target - t)
        res = rk_step(tab, f, t, z, h_use, args, k0=c["k0"],
                      use_pallas=use_pallas,
                      err_scale=(rtol, atol) if tab.adaptive else None)
        nfe = c["nfe"] + (tab.stages - 1)

        if tab.adaptive:
            # fused path: the scaled norm came out of the combine kernel
            ratio = res.err_ratio if res.err_ratio is not None else \
                error_ratio(res.err, z, res.z_next, rtol, atol)
            # forced-minimum steps are always accepted (cannot shrink further)
            accept = (ratio <= 1.0) | (h_use <= h_min * (1 + 1e-3))
        else:
            ratio = jnp.asarray(0.5, jnp.float32)
            accept = jnp.asarray(True)

        t_new = t + h_use
        hit = accept & (t_new >= t_target - 16.0 * tiny * jnp.maximum(
            jnp.abs(t_target), jnp.asarray(1.0, tdt)))

        # --- on accept: write trajectory checkpoint (t_i, h_i, z_i) -------
        i = c["i"]
        ckpt_t = c["ckpt_t"].at[i].set(jnp.where(accept, t, c["ckpt_t"][i]))
        ckpt_h = c["ckpt_h"].at[i].set(jnp.where(accept, h_use, c["ckpt_h"][i]))
        ckpt_z = jax.tree.map(
            lambda b, v: b.at[i].set(jnp.where(accept, v, b[i])),
            c["ckpt_z"], z)
        oi_val = jnp.where(hit, c["eval_idx"], jnp.asarray(-1, jnp.int32))
        ckpt_oi = c["ckpt_oi"].at[i].set(
            jnp.where(accept, oi_val, c["ckpt_oi"][i]))

        # --- on eval-time hit: record output ------------------------------
        ys = jax.tree.map(
            lambda b, v: b.at[c["eval_idx"]].set(
                jnp.where(hit, v, b[c["eval_idx"]])),
            c["ys"], res.z_next)

        # --- stepsize control ---------------------------------------------
        h_next = propose_stepsize(
            cfg, h_use, ratio, c["prev_ratio"], tab.order)
        # (the paper's Algo 1: shrink and retry on reject; grow on accept)
        h_next = jnp.asarray(h_next, tdt)

        # FSAL / first-stage reuse:
        #  - reject: (t, z) unchanged -> k0 still valid, 0 extra evals
        #  - accept + FSAL tableau: k0' = last stage of accepted step
        #  - accept + non-FSAL: recompute k0' = f(t', z')
        if tab.fsal:
            k0_acc = res.k_last
            nfe_acc = nfe
        else:
            k0_acc = f(t_new, res.z_next, *args)
            nfe_acc = nfe + 1
        k0_new = _where_tree(accept, k0_acc, c["k0"])
        nfe = jnp.where(accept, nfe_acc, nfe)

        return dict(
            t=jnp.where(accept, t_new, t),
            z=_where_tree(accept, res.z_next, z),
            k0=k0_new,
            h=h_next,
            prev_ratio=jnp.where(
                accept, jnp.maximum(ratio, 1e-10), c["prev_ratio"]),
            i=i + accept.astype(jnp.int32),
            eval_idx=c["eval_idx"] + hit.astype(jnp.int32),
            trials=c["trials"] + 1,
            nfe=nfe,
            ys=ys, ckpt_t=ckpt_t, ckpt_h=ckpt_h, ckpt_z=ckpt_z,
            ckpt_oi=ckpt_oi,
        )

    c = jax.lax.while_loop(cond, body, carry0)

    overflow = c["eval_idx"] < n_eval
    ckpts = Checkpoints(t=c["ckpt_t"], h=c["ckpt_h"], z=c["ckpt_z"],
                        out_idx=c["ckpt_oi"], n=c["i"])
    stats = SolveStats(n_steps=c["i"], n_trials=c["trials"], nfe=c["nfe"],
                       overflow=overflow)
    return c["ys"], ckpts, stats


def make_fixed_grid(ts: jnp.ndarray, steps_per_interval: int) -> jnp.ndarray:
    """Uniform sub-grid with ``steps_per_interval`` steps between each pair
    of eval times.  Returns (n_intervals * steps,) array of (t, h) pairs as
    two arrays (t_grid, h_grid)."""
    t_lo = ts[:-1]
    t_hi = ts[1:]
    frac = jnp.arange(steps_per_interval) / steps_per_interval
    # (n_intervals, steps)
    t_grid = t_lo[:, None] + (t_hi - t_lo)[:, None] * frac[None, :]
    h_grid = jnp.broadcast_to(
        ((t_hi - t_lo) / steps_per_interval)[:, None], t_grid.shape)
    return t_grid.reshape(-1), h_grid.reshape(-1)


def fixed_grid_solve(
    tab: Tableau,
    f: Callable,
    z0: PyTree,
    ts: jnp.ndarray,
    args: Tuple,
    steps_per_interval: int,
    use_pallas: bool = False,
) -> Tuple[PyTree, SolveStats]:
    """Differentiable fixed-grid integration via ``lax.scan``.

    Outputs at every ``ts``; ys[0] = z0.  Reverse-mode AD through the scan
    is the naive method for fixed-step solvers.

    ``use_pallas`` ravels the state once (``stepper.flatten_problem``)
    and runs every step through the fused flat-state kernels; the
    unravel is applied to the stacked outputs.  Fully differentiable —
    the flatten/unravel are plain jnp reshapes on the AD path.
    """
    f, z0, unravel, use_pallas = maybe_flatten(f, z0, use_pallas)

    t_grid, h_grid = make_fixed_grid(ts, steps_per_interval)
    n_intervals = ts.shape[0] - 1

    def step_fn(z, t_h):
        t, h = t_h
        z_next = rk_step(tab, f, t, z, h, args,
                         use_pallas=use_pallas).z_next
        return z_next, None

    # scan per interval so we can emit outputs
    def interval(z, idx):
        t_seg = jax.lax.dynamic_slice_in_dim(
            t_grid, idx * steps_per_interval, steps_per_interval)
        h_seg = jax.lax.dynamic_slice_in_dim(
            h_grid, idx * steps_per_interval, steps_per_interval)
        z_end, _ = jax.lax.scan(step_fn, z, (t_seg, h_seg))
        return z_end, z_end

    _, ys_tail = jax.lax.scan(interval, z0, jnp.arange(n_intervals))
    ys = jax.tree.map(
        lambda z0l, tail: jnp.concatenate([z0l[None], tail], axis=0),
        z0, ys_tail)
    if unravel is not None:
        ys = jax.vmap(unravel)(ys)

    n_steps = n_intervals * steps_per_interval
    stats = SolveStats(
        n_steps=jnp.asarray(n_steps, jnp.int32),
        n_trials=jnp.asarray(n_steps, jnp.int32),
        nfe=jnp.asarray(n_steps * tab.stages, jnp.int32),
        overflow=jnp.asarray(False),
    )
    return ys, stats
