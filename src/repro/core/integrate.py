"""Forward integration engines shared by every gradient method.

Two engines over the same Runge-Kutta stepper:

* ``adaptive_while_solve`` — ``lax.while_loop`` with a flattened
  trial/accept loop (the paper's Algorithm 1 with the inner stepsize search
  and outer time advance fused into one loop).  Dynamic trip count, *not*
  reverse-differentiable — used by ACA forward (with trajectory
  checkpoints), by the adjoint method's forward and backward solves, and
  for inference.  With ``use_pallas=True`` the trial step and its error
  norm run as fused flat-state Pallas kernels over the raveled state (see
  ``stepper.py``); the loop logic is identical.  Accepted discretization
  points (t_i, h_i, z_i) are written into a fixed-capacity buffer: the
  paper's trajectory checkpoint.  With ``checkpoint_segments=K`` the
  state buffer shrinks to K coarse snapshots (one every
  ``ceil(max_steps / K)`` accepted steps) while the scalar grid still
  records every step — the memory-bounded mode the segmented ACA
  backward sweep re-integrates from (``docs/memory.md``).

* ``batched_adaptive_while_solve`` — the per-sample batched engine behind
  ``odeint(..., batch_axis=0)``.  One fused ``lax.while_loop`` advances
  all live batch elements each iteration, but every element carries its
  *own* controller state (stepsize, PI memory, trial counter), its own
  accept/reject decision and its own ``Checkpoints`` row — Algorithm 1's
  stepsize search runs per trajectory, not in lockstep.  Rejected and
  finished elements are frozen with ``jnp.where`` masking (and h = 0
  through the stepper, an exact identity), so an element that has landed
  on its last ``ts[k]`` stops contributing f-evals to its ``SolveStats``
  and its buffers stay bit-stable while stragglers finish.  The loop
  terminates when *all* elements are done.

* ``fixed_grid_solve`` — ``lax.scan`` over a precomputed grid.  Fully
  differentiable (this is also the "naive" method for fixed-step solvers).

* ``mali_adaptive_solve`` / ``batched_mali_adaptive_solve`` — the
  reversible asynchronous-leapfrog engines behind ``odeint(...,
  grad_method="mali")``.  Same trial/accept loop shape as the RK
  engines, but the carried state is the integer-lattice pair (z, v) of
  ``stepper.alf_step`` and **no state checkpoint buffer exists at
  all**: only the scalar grid (t_i, h_i, out_idx_i) is recorded — the
  ``MaliGrid`` — because the backward sweep re-derives every accepted
  state by *inverting* steps from the terminal pair (bitwise, see the
  ALF section of ``stepper.py``).  State memory is O(dim), independent
  of the accepted-step count.

All engines integrate through a sorted array of evaluation times ``ts``
(the solver is forced to land exactly on each ``ts[k]``), supporting
latent-ODE style multi-time outputs.  States are arbitrary pytrees.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .controller import ControllerConfig, initial_stepsize, propose_stepsize
from .stepper import (
    ALF_ORDER,
    InterpCoeffs,
    alf_lattice_exponent,
    alf_lattice_exponent_batched,
    alf_step,
    alf_step_batched,
    error_ratio,
    interp_eval,
    interp_fit,
    lattice_decode,
    lattice_encode,
    maybe_flatten,
    rk_step,
    rk_step_batched,
)
from .tableaus import Tableau

PyTree = Any


def _as_tuple(args) -> Tuple:
    """Normalize an ``args`` pytree to the *args tuple ``f`` receives —
    the one rule shared by every odeint entry point."""
    return args if isinstance(args, tuple) else (args,)


class SolveStatus:
    """Structured health codes for a solve (``SolveStats.status``).

    Int codes, ordered by severity (0 = healthy).  Scalar for an
    unbatched solve, per-element (B,) int32 for ``batch_axis`` solves:

    * ``OK`` — every requested eval time was reached normally.
    * ``NONFINITE_STATE`` — a trial step produced a non-finite state (or
      error norm) even at the minimum stepsize.  The solve *froze* the
      affected element at its last accepted state instead of integrating
      garbage: outputs at un-reached eval times repeat that last-good
      state, and the backward sweeps zero the element's cotangents.
    * ``STEPSIZE_UNDERFLOW`` — at least one forced-minimum step (h railed
      at ``h_min``) was accepted while still failing the error test; the
      solve completed but local accuracy is not guaranteed.
    * ``TRIAL_BUDGET_EXHAUSTED`` — the global ψ-trial budget
      (``max_steps * max_trials``) ran out before the last eval time.
    * ``CHECKPOINT_OVERFLOW`` — the accepted-step budget (``max_steps``,
      the checkpoint capacity) ran out before the last eval time
      (the condition previously only visible as ``stats.overflow``).
    """
    OK = 0
    NONFINITE_STATE = 1
    STEPSIZE_UNDERFLOW = 2
    TRIAL_BUDGET_EXHAUSTED = 3
    CHECKPOINT_OVERFLOW = 4

    _NAMES = {0: "OK", 1: "NONFINITE_STATE", 2: "STEPSIZE_UNDERFLOW",
              3: "TRIAL_BUDGET_EXHAUSTED", 4: "CHECKPOINT_OVERFLOW"}

    @classmethod
    def describe(cls, code) -> str:
        """Human-readable name for one (host-side) status code."""
        return cls._NAMES.get(int(code), f"UNKNOWN({int(code)})")


class SolveStats(NamedTuple):
    """Solver cost counters + health status for one solve.

    Scalars for an unbatched solve; shape (B,) per-element arrays for a
    batched solve (``batch_axis``), where a finished element's counters
    stop advancing while stragglers integrate on.  ``status`` holds a
    ``SolveStatus`` code per solve/element — 0 (OK) on the healthy path.
    """
    n_steps: jnp.ndarray      # accepted steps (paper's N_t)
    n_trials: jnp.ndarray     # total ψ trials (N_t * m)
    nfe: jnp.ndarray          # number of f evaluations
    overflow: jnp.ndarray     # bool: checkpoint buffer exhausted
    status: jnp.ndarray       # int32 SolveStatus code


class Checkpoints(NamedTuple):
    """The paper's trajectory checkpoint: accepted grid + states.

    ``z`` holds z_i at the *start* of accepted interval i; ``t``/``h`` its
    start time and accepted stepsize; ``out_idx`` the index into ``ts`` that
    the interval's endpoint landed on (or -1).  Only slots [0, n) are valid.

    With ``checkpoint_segments=K`` the scalar grids keep one slot per
    accepted step (they are cheap) but ``z`` holds only K coarse
    snapshots: slot s is the state at accepted step ``s * seg_len``,
    ``seg_len = ceil(max_steps / K)``.  The ACA backward sweep then
    re-integrates each segment from its snapshot with the *saved*
    stepsizes before replaying it in reverse (see ``docs/memory.md``).

    ``k0`` (segmented mode only) snapshots the first-stage derivative
    carry alongside each state snapshot, so the segment re-integration
    can chain FSAL first-stage reuse exactly as the forward loop did —
    the replayed trajectory is the forward trajectory *bitwise*, not
    just up to the FSAL algebraic identity.

    Batched solves reuse the same structure with a leading batch dim:
    ``t``/``h``/``out_idx`` become (B, max_steps), ``z`` leaves
    (B, max_steps, ...) — or (B, K, ...) snapshots — and ``n`` (B,);
    each element records its *own* accepted grid, which the ACA backward
    sweep replays per element.

    Natural-grid mode (``interpolate_ts``): interior eval times are no
    longer step landings, so ``out_idx`` marks only the *final* eval
    time; ``ev_lo``/``ev_hi`` record the half-open range of eval indices
    whose times fall inside accepted interval i — the ACA backward sweep
    re-injects those cotangents through the interval's interpolant.
    ``coeffs`` (dense-solution mode only) stores the fitted interpolant
    coefficients of every accepted step.
    """
    t: jnp.ndarray            # (max_steps,)
    h: jnp.ndarray            # (max_steps,)
    z: PyTree                 # (max_steps, ...) or (K, ...) per leaf
    out_idx: jnp.ndarray      # (max_steps,) int32
    n: jnp.ndarray            # number of valid slots
    k0: Optional[PyTree] = None   # (K, ...) stage-0 derivative snapshots
    ev_lo: Optional[jnp.ndarray] = None   # (max_steps,) int32
    ev_hi: Optional[jnp.ndarray] = None   # (max_steps,) int32
    coeffs: Optional[Any] = None  # InterpCoeffs of (max_steps, ...) buffers


def resolve_checkpoint_segments(spec, max_steps: int) -> Optional[int]:
    """Normalize a ``checkpoint_segments`` spec to an int K (or None).

    ``None`` keeps the full O(max_steps) state buffer; ``"auto"`` picks
    K = ceil(sqrt(max_steps)), the memory-optimal point of the
    O(K + max_steps/K) segmented cost model; an int is clamped into
    [1, max_steps].
    """
    if spec is None:
        return None
    if spec == "auto":
        return max(1, int(-(-max_steps ** 0.5 // 1)))  # ceil(sqrt)
    k = int(spec)
    if k < 1:
        raise ValueError(
            f"checkpoint_segments must be >= 1 or 'auto'; got {spec}")
    return min(k, max_steps)


def segment_length(n_segments: int, max_steps: int) -> int:
    """Steps per checkpoint segment: ceil(max_steps / K)."""
    return -(-max_steps // n_segments)


def resolve_segmentation(
        spec, max_steps: int) -> Tuple[Optional[int], Optional[int]]:
    """Resolve a ``checkpoint_segments`` spec to ``(n_seg, seg_len)``.

    Returns ``(None, None)`` for the full buffer — including the
    degenerate K >= max_steps case, where seg_len would be 1 and every
    step is snapshotted anyway, so the classic sweep is strictly better
    (no pointless per-step re-integration).
    """
    n_seg = resolve_checkpoint_segments(spec, max_steps)
    if n_seg is None:
        return None, None
    seg_len = segment_length(n_seg, max_steps)
    if seg_len == 1:
        return None, None
    return n_seg, seg_len


def _snapshot_layout(n_seg: Optional[int],
                     max_steps: int) -> Tuple[int, int]:
    """State-buffer layout of an adaptive engine: (n_state_slots,
    seg_len), where ``n_seg=None`` means the classic full buffer."""
    if n_seg is None:
        return max_steps, 1
    return n_seg, segment_length(n_seg, max_steps)


def _init_checkpoint_buffers(
    z0: PyTree,
    max_steps: int,
    tdt,
    n_state_slots: int,
    batch_size: Optional[int] = None,
):
    """Zero-initialized Checkpoints buffers shared by the solo and
    batched adaptive engines.

    The scalar grids (t, h, out_idx) always get ``max_steps`` slots —
    they cost O(N_f) scalars and the backward sweep needs every accepted
    stepsize.  The state buffer gets ``n_state_slots`` slots per element:
    ``max_steps`` for the classic full buffer, or K coarse snapshots
    under ``checkpoint_segments=K``.  Returns (t, h, z, out_idx).
    """
    if batch_size is None:
        shape = (max_steps,)
        z = jax.tree.map(
            lambda l: jnp.zeros((n_state_slots,) + l.shape, l.dtype), z0)
    else:
        shape = (batch_size, max_steps)
        z = jax.tree.map(
            lambda l: jnp.zeros((l.shape[0], n_state_slots) + l.shape[1:],
                                l.dtype), z0)
    t = jnp.zeros(shape, tdt)
    oi = jnp.full(shape, -1, jnp.int32)
    return t, jnp.zeros_like(t), z, oi


def _empty_buffer(z0: PyTree, max_steps: int) -> PyTree:
    return jax.tree.map(
        lambda l: jnp.zeros((max_steps,) + l.shape, l.dtype), z0)


def _buffer_set(buf: PyTree, i, val: PyTree) -> PyTree:
    return jax.tree.map(lambda b, v: b.at[i].set(v), buf, val)


def _buffer_slot(buf: PyTree, i) -> PyTree:
    return jax.tree.map(lambda b: b[i], buf)


def _where_tree(pred, a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def _nonfinite_any(tree: PyTree) -> jnp.ndarray:
    """Scalar bool: any leaf of ``tree`` holds a NaN/Inf.  The cheap
    finite-mask read of the solve-health guards — pure reduction, no
    effect on the values it inspects."""
    out = None
    for leaf in jax.tree.leaves(tree):
        flag = jnp.any(~jnp.isfinite(leaf))
        out = flag if out is None else out | flag
    return out if out is not None else jnp.asarray(False)


def _nonfinite_rows(tree: PyTree) -> jnp.ndarray:
    """Per-element (B,) bool twin of ``_nonfinite_any`` over
    batch-leading leaves."""
    out = None
    for leaf in jax.tree.leaves(tree):
        flat = leaf.reshape((leaf.shape[0], -1))
        flag = jnp.any(~jnp.isfinite(flat), axis=1)
        out = flag if out is None else out | flag
    return out


def _compose_status(failed, uflow, finished, trials_out) -> jnp.ndarray:
    """Fold the engines' health flags into one ``SolveStatus`` code
    (elementwise for batched solves): non-finite failure dominates,
    then whichever budget truncated the solve, then the accepted-but-
    out-of-tolerance underflow warning."""
    budget = jnp.where(trials_out,
                       SolveStatus.TRIAL_BUDGET_EXHAUSTED,
                       SolveStatus.CHECKPOINT_OVERFLOW)
    tail = jnp.where(uflow, SolveStatus.STEPSIZE_UNDERFLOW, SolveStatus.OK)
    status = jnp.where(finished, tail, budget)
    return jnp.where(failed, SolveStatus.NONFINITE_STATE,
                     status).astype(jnp.int32)


def _mask_failed_cotangents(g_ys: PyTree, status: jnp.ndarray,
                            batched: bool = False) -> PyTree:
    """Zero the output cotangents of solves (or batch elements) whose
    status is ``NONFINITE_STATE`` before a backward sweep runs.

    A frozen solve's outputs are last-good placeholders, not solution
    values — their cotangents must not leak into dz0/dargs (for batched
    solves, into the *shared* dargs reduction).  Every backward sweep is
    linear in ``g_ys``, so zeroing here yields exact zeros for the
    failed element and leaves healthy elements bit-identical.
    ``g_ys`` leaves are (n_eval, ...) solo / (n_eval, B, ...) batched.
    """
    ok = status != SolveStatus.NONFINITE_STATE
    if not batched:
        return jax.tree.map(
            lambda g: jnp.where(ok, g, jnp.zeros_like(g)), g_ys)
    return jax.tree.map(
        lambda g: jnp.where(ok.reshape((1, -1) + (1,) * (g.ndim - 2)),
                            g, jnp.zeros_like(g)),
        g_ys)


def _freeze_fill(ys: PyTree, mask: jnp.ndarray, z_frozen: PyTree) -> PyTree:
    """Repeat a failed solve's last accepted state into its un-reached
    eval slots, so frozen elements return finite last-good values
    instead of zero-initialized buffer slots.  ``mask`` is (n_eval,)
    solo / (n_eval, B) batched; bitwise no-op where it is False."""
    return jax.tree.map(
        lambda b, v: jnp.where(
            mask.reshape(mask.shape + (1,) * (b.ndim - mask.ndim)),
            v[None], b),
        ys, z_frozen)


def natural_grid_outputs(ts, karr, tiny, t, t_new, h_use, accept, hit,
                         eval_idx, ys, z, z_next, k0, k1, z_mid):
    """One trial's output writes in natural-grid (``interpolate_ts``)
    mode, shared by the solo adaptive engine and the solo naive scan.

    Interior eval times covered by an accepted interval are read off its
    interpolant; ``ts[-1]`` stays an exact landing, and a final-landing
    ``hit`` covers every remaining interior time (θ clips to 1), so no
    eval index is ever skipped.  Returns ``(ys, coeffs, n_cov,
    eval_advance)`` — the updated output buffer, the fitted interpolant
    (for coefficient storage), the interior-cover count and the
    ``eval_idx`` increment.  All plain jnp: differentiable on the naive
    tape, masked no-op on rejected trials.
    """
    n_eval = ts.shape[0]
    covered = (accept & (karr >= eval_idx)
               & (karr < n_eval - 1) & ((ts <= t_new) | hit))
    # dtype pinned: x64 would promote a plain sum to int64 and break
    # the loop carry
    n_cov = jnp.sum(covered, dtype=jnp.int32)
    coeffs = interp_fit(z, z_next, k0, k1, h_use, z_mid)
    theta = jnp.clip((ts - t) / jnp.maximum(h_use, tiny), 0.0, 1.0)
    yint = interp_eval(coeffs, theta)
    ys = jax.tree.map(
        lambda b, v: jnp.where(
            covered.reshape((n_eval,) + (1,) * (v.ndim - 1)), v, b),
        ys, yint)
    ys = jax.tree.map(
        lambda b, v: b.at[n_eval - 1].set(
            jnp.where(hit, v, b[n_eval - 1])),
        ys, z_next)
    return ys, coeffs, n_cov, n_cov + hit.astype(jnp.int32)


def natural_grid_outputs_batched(ts, karr, tiny, rows, t, t_new, h_use,
                                 accept, hit, eval_idx, ys, z, z_next,
                                 k0, k1, z_mid):
    """Batched twin of ``natural_grid_outputs``: per-row times/steps,
    (n_eval, B) cover mask, per-row ``n_cov``/``eval_advance``."""
    n_eval = ts.shape[0]
    covered = (accept[None, :]
               & (karr[:, None] >= eval_idx[None, :])
               & (karr[:, None] < n_eval - 1)
               & ((ts[:, None] <= t_new[None, :])
                  | hit[None, :]))                      # (n_eval, B)
    n_cov = jnp.sum(covered, axis=0, dtype=jnp.int32)   # (B,)
    coeffs = interp_fit(z, z_next, k0, k1, h_use, z_mid)
    theta = jnp.clip(
        (ts[:, None] - t[None, :])
        / jnp.maximum(h_use, tiny)[None, :], 0.0, 1.0)
    yint = interp_eval(coeffs, theta)                   # (n_eval, B, ...)
    ys = jax.tree.map(
        lambda b, v: jnp.where(
            covered.reshape(covered.shape + (1,) * (v.ndim - 2)), v, b),
        ys, yint)
    ys = jax.tree.map(
        lambda b, v: b.at[n_eval - 1, rows].set(
            _bwhere(hit, v, b[n_eval - 1, rows])),
        ys, z_next)
    return ys, coeffs, n_cov, n_cov + hit.astype(jnp.int32)


def adaptive_while_solve(
    tab: Tableau,
    f: Callable,
    z0: PyTree,
    ts: jnp.ndarray,
    args: Tuple,
    rtol: float,
    atol: float,
    cfg: ControllerConfig,
    h0: Optional[jnp.ndarray] = None,
    use_pallas: bool = False,
    checkpoint_segments: Optional[int] = None,
    interpolate_ts: bool = False,
    store_coeffs: bool = False,
    guard_nonfinite: bool = True,
) -> Tuple[PyTree, Checkpoints, SolveStats]:
    """Integrate dz/dt = f(t, z, *args) through increasing times ``ts``.

    Returns (ys, checkpoints, stats); ``ys`` is stacked over len(ts) with
    ys[0] = z0.  Not reverse-differentiable (while_loop) — wrap in
    custom_vjp (ACA / adjoint) or use only for inference.

    ``use_pallas`` selects the fused flat-state stepper path; callers
    pass an already-flat (N,) state (see ``stepper.flatten_problem``) —
    the trial step and its error norm then run as fused Pallas kernels
    and the while_loop carry/checkpoint buffers hold one flat array per
    slot.  Non-flat states silently use the pytree stepper.

    ``checkpoint_segments=K`` (an already-resolved int — see
    ``resolve_checkpoint_segments``) switches the state buffer to K
    coarse snapshots written every ``segment_length(K, max_steps)``
    accepted steps; the scalar grids still record every step so a
    segmented ACA backward sweep can re-integrate losslessly.

    ``interpolate_ts`` switches to the *natural-grid* mode: the stepper
    is clamped only to the final time ``ts[-1]`` (not to every interior
    eval time), and interior outputs are read off each accepted step's
    local interpolant (``stepper.interp_fit``) — dense eval grids stop
    inflating the accepted-step count.  ``ys[0]`` and ``ys[-1]`` stay
    exact solver states; the checkpoint records ``ev_lo``/``ev_hi`` per
    interval so the ACA backward sweep can re-inject interpolated-output
    cotangents.  ``store_coeffs`` additionally saves every accepted
    step's interpolant coefficients in ``Checkpoints.coeffs`` (the
    dense-solution mode of ``odeint_dense``); it implies the natural
    grid.

    ``guard_nonfinite`` (default on) arms the solve-health guards: a
    trial producing a non-finite state or error norm is never accepted
    (even a forced-minimum one), and once the stepsize has railed at
    ``h_min`` with the trial still non-finite the solve *freezes* at its
    last accepted state and reports ``SolveStatus.NONFINITE_STATE``.
    The whole guard is one ``isfinite`` read of the already-computed
    error ratio — a non-finite trial state always poisons it (every
    stage feeding ``z_next`` has a nonzero embedded-error weight, and an
    Inf state turns the scaled norm into Inf/Inf = NaN) — so the healthy
    path stays bit-identical at ~zero cost; ``False`` reproduces the
    unguarded loop (used by ``bench_failure_overhead`` to price the
    guards).
    """
    n_eval = ts.shape[0]
    tdt = ts.dtype
    max_steps = cfg.max_steps
    # trial budget: every accepted step costs >= 1 trial
    max_total_trials = max_steps * cfg.max_trials
    n_snap, seg_len = _snapshot_layout(checkpoint_segments, max_steps)
    natural = interpolate_ts or store_coeffs

    hinit_evals = 2 if h0 is None else 0  # hinit costs 2 f-evals
    if h0 is None:
        h0 = initial_stepsize(f, ts[0], z0, args, tab.order, rtol, atol)
    h0 = jnp.asarray(h0, tdt)

    ys = _empty_buffer(z0, n_eval)
    ys = _buffer_set(ys, 0, z0)

    ckpt_t, ckpt_h, ckpt_z, ckpt_oi = _init_checkpoint_buffers(
        z0, max_steps, tdt, n_snap)

    k0 = f(ts[0], z0, *args)
    nfe0 = jnp.asarray(1 + hinit_evals, jnp.int32)

    # a non-finite initial state / derivative / h0 fails before stepping
    failed0 = _nonfinite_any((z0, k0, h0)) if guard_nonfinite \
        else jnp.asarray(False)

    carry0 = dict(
        t=ts[0], z=z0, k0=k0, h=h0,
        prev_ratio=jnp.asarray(1.0, jnp.float32),
        i=jnp.asarray(0, jnp.int32),            # accepted steps so far
        eval_idx=jnp.asarray(1, jnp.int32),     # next ts[] to hit
        trials=jnp.asarray(0, jnp.int32),
        nfe=nfe0,
        failed=failed0, uflow=jnp.asarray(False),
        ys=ys, ckpt_t=ckpt_t, ckpt_h=ckpt_h, ckpt_z=ckpt_z, ckpt_oi=ckpt_oi,
    )
    if checkpoint_segments is not None:
        # segmented replay re-chains FSAL reuse, so the k0 carry is
        # snapshotted next to the state at each segment boundary
        carry0["ckpt_k0"] = _empty_buffer(k0, n_snap)
    if natural:
        # per-interval half-open eval-index ranges for the ACA backward
        carry0["ckpt_elo"] = jnp.zeros((max_steps,), jnp.int32)
        carry0["ckpt_ehi"] = jnp.zeros((max_steps,), jnp.int32)
    if store_coeffs:
        carry0["ckpt_cf"] = InterpCoeffs(*(
            _empty_buffer(z0, max_steps) for _ in range(5)))

    tiny = jnp.asarray(jnp.finfo(tdt).eps, tdt)
    karr = jnp.arange(n_eval)

    def cond(c):
        return (
            (c["eval_idx"] < n_eval)
            & (c["i"] < max_steps)
            & (c["trials"] < max_total_trials)
            & ~c["failed"]
        )

    def body(c):
        t, z, h = c["t"], c["z"], c["h"]
        # natural grid: only the final time is a forced landing; the
        # controller otherwise picks its own accepted points
        t_target = ts[n_eval - 1] if natural else ts[c["eval_idx"]]
        # clamp trial step to land exactly on the target eval time
        h_min = 16.0 * tiny * jnp.maximum(jnp.abs(t), jnp.asarray(1.0, tdt))
        h_use = jnp.clip(h, h_min, t_target - t)
        res = rk_step(tab, f, t, z, h_use, args, k0=c["k0"],
                      use_pallas=use_pallas,
                      err_scale=(rtol, atol) if tab.adaptive else None,
                      dense=natural)
        nfe = c["nfe"] + (tab.stages - 1)

        if tab.adaptive:
            # fused path: the scaled norm came out of the combine kernel
            ratio = res.err_ratio if res.err_ratio is not None else \
                error_ratio(res.err, z, res.z_next, rtol, atol)
            railed = h_use <= h_min * (1 + 1e-3)
            if guard_nonfinite:
                # one scalar read guards the whole trial: a NaN/Inf
                # anywhere in the stage sums poisons the embedded error
                # (every stage feeding z_next carries a nonzero error
                # weight in our tableaus) and an Inf state makes the
                # scaled norm Inf/Inf = NaN — so ratio is non-finite
                # exactly when the trial is, at zero extra reductions
                bad = ~jnp.isfinite(ratio)
                # non-finite trials are never accepted; forced-minimum
                # steps are otherwise always accepted (cannot shrink)
                accept = ((ratio <= 1.0) | railed) & ~bad
            else:
                bad = jnp.asarray(False)
                accept = (ratio <= 1.0) | railed
        else:
            ratio = jnp.asarray(0.5, jnp.float32)
            # fixed-step: no retry possible, so a bad step is terminal
            railed = jnp.asarray(True)
            bad = _nonfinite_any(res.z_next) if guard_nonfinite \
                else jnp.asarray(False)
            accept = ~bad

        # health flags: railed + still non-finite -> freeze (terminal);
        # forced accept that still fails the error test -> underflow
        fail_now = bad & railed
        uflow_now = accept & railed & (ratio > 1.0)

        t_new = t + h_use
        hit = accept & (t_new >= t_target - 16.0 * tiny * jnp.maximum(
            jnp.abs(t_target), jnp.asarray(1.0, tdt)))

        # FSAL / first-stage reuse:
        #  - reject: (t, z) unchanged -> k0 still valid, 0 extra evals
        #  - accept + FSAL tableau: k0' = last stage of accepted step
        #  - accept + non-FSAL: recompute k0' = f(t', z')
        # (computed before the output writes: in natural-grid mode k0'
        # doubles as the interval-end derivative of the interpolant)
        if tab.fsal:
            k0_acc = res.k_last
            nfe_acc = nfe
        else:
            k0_acc = f(t_new, res.z_next, *args)
            nfe_acc = nfe + 1

        # --- on accept: write trajectory checkpoint (t_i, h_i, z_i) -------
        i = c["i"]
        ckpt_t = c["ckpt_t"].at[i].set(jnp.where(accept, t, c["ckpt_t"][i]))
        ckpt_h = c["ckpt_h"].at[i].set(jnp.where(accept, h_use, c["ckpt_h"][i]))
        ckpt_k0 = None
        if checkpoint_segments is None:
            ckpt_z = jax.tree.map(
                lambda b, v: b.at[i].set(jnp.where(accept, v, b[i])),
                c["ckpt_z"], z)
        else:
            # segmented: snapshot (z, k0) only at segment boundaries
            # (accepted step s * seg_len); c["k0"] is exactly the
            # first-stage derivative this accepted trial consumed
            s = jnp.minimum(i // seg_len, n_snap - 1)
            snap = accept & (i % seg_len == 0)
            ckpt_z = jax.tree.map(
                lambda b, v: b.at[s].set(jnp.where(snap, v, b[s])),
                c["ckpt_z"], z)
            ckpt_k0 = jax.tree.map(
                lambda b, v: b.at[s].set(jnp.where(snap, v, b[s])),
                c["ckpt_k0"], c["k0"])
        final_idx = jnp.asarray(n_eval - 1, jnp.int32)
        oi_val = jnp.where(hit, final_idx if natural else c["eval_idx"],
                           jnp.asarray(-1, jnp.int32))
        ckpt_oi = c["ckpt_oi"].at[i].set(
            jnp.where(accept, oi_val, c["ckpt_oi"][i]))

        # --- outputs ------------------------------------------------------
        extra = {}
        if natural:
            ys, coeffs, n_cov, eval_advance = natural_grid_outputs(
                ts, karr, tiny, t, t_new, h_use, accept, hit,
                c["eval_idx"], c["ys"], z, res.z_next, res.k_first,
                k0_acc, res.z_mid)
            extra["ckpt_elo"] = c["ckpt_elo"].at[i].set(
                jnp.where(accept, c["eval_idx"], c["ckpt_elo"][i]))
            extra["ckpt_ehi"] = c["ckpt_ehi"].at[i].set(
                jnp.where(accept, c["eval_idx"] + n_cov,
                          c["ckpt_ehi"][i]))
            if store_coeffs:
                extra["ckpt_cf"] = InterpCoeffs(*(
                    jax.tree.map(
                        lambda b, v: b.at[i].set(jnp.where(accept, v,
                                                           b[i])),
                        cb, cv)
                    for cb, cv in zip(c["ckpt_cf"], coeffs)))
        else:
            # --- on eval-time hit: record output --------------------------
            ys = jax.tree.map(
                lambda b, v: b.at[c["eval_idx"]].set(
                    jnp.where(hit, v, b[c["eval_idx"]])),
                c["ys"], res.z_next)
            eval_advance = hit.astype(jnp.int32)

        # --- stepsize control ---------------------------------------------
        # a non-finite error ratio would poison the controller's h chain
        # (NaN h never recovers); treat it as "error way too large" so
        # the retry shrinks at max rate.  Bitwise no-op when finite.
        ratio_c = jnp.where(bad, jnp.asarray(1e10, jnp.float32), ratio)
        h_next = propose_stepsize(
            cfg, h_use, ratio_c, c["prev_ratio"], tab.order)
        # (the paper's Algo 1: shrink and retry on reject; grow on accept)
        h_next = jnp.asarray(h_next, tdt)

        k0_new = _where_tree(accept, k0_acc, c["k0"])
        nfe = jnp.where(accept, nfe_acc, nfe)

        out = dict(
            t=jnp.where(accept, t_new, t),
            z=_where_tree(accept, res.z_next, z),
            k0=k0_new,
            h=h_next,
            prev_ratio=jnp.where(
                accept, jnp.maximum(ratio, 1e-10), c["prev_ratio"]),
            i=i + accept.astype(jnp.int32),
            eval_idx=c["eval_idx"] + eval_advance,
            trials=c["trials"] + 1,
            nfe=nfe,
            failed=c["failed"] | fail_now,
            uflow=c["uflow"] | uflow_now,
            ys=ys, ckpt_t=ckpt_t, ckpt_h=ckpt_h, ckpt_z=ckpt_z,
            ckpt_oi=ckpt_oi,
        )
        if ckpt_k0 is not None:
            out["ckpt_k0"] = ckpt_k0
        out.update(extra)
        return out

    c = jax.lax.while_loop(cond, body, carry0)

    overflow = c["eval_idx"] < n_eval
    status = _compose_status(c["failed"], c["uflow"], ~overflow,
                             c["trials"] >= max_total_trials)
    # frozen solve: repeat the last accepted state into un-reached slots
    ys_out = _freeze_fill(c["ys"], c["failed"] & (karr >= c["eval_idx"]),
                          c["z"])
    ckpts = Checkpoints(t=c["ckpt_t"], h=c["ckpt_h"], z=c["ckpt_z"],
                        out_idx=c["ckpt_oi"], n=c["i"],
                        k0=c.get("ckpt_k0"),
                        ev_lo=c.get("ckpt_elo"), ev_hi=c.get("ckpt_ehi"),
                        coeffs=c.get("ckpt_cf"))
    stats = SolveStats(n_steps=c["i"], n_trials=c["trials"], nfe=c["nfe"],
                       overflow=overflow, status=status)
    return ys_out, ckpts, stats


def _row_tolerances(rtol, atol, B):
    """Normalize a per-row tolerance pair to ((B,), (B,)) f32 arrays, or
    None when both are scalars (the classic solve-global path — kept
    untouched so scalar solves stay bit-compatible)."""
    if jnp.ndim(rtol) == 0 and jnp.ndim(atol) == 0:
        return None
    return (jnp.broadcast_to(jnp.asarray(rtol, jnp.float32), (B,)),
            jnp.broadcast_to(jnp.asarray(atol, jnp.float32), (B,)))


def _bwhere(pred, a, b):
    """jnp.where with a (B,) predicate broadcast over batch-leading leaves."""
    return jnp.where(pred.reshape((-1,) + (1,) * (a.ndim - 1)), a, b)


def _bwhere_tree(pred, a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(lambda x, y: _bwhere(pred, x, y), a, b)


def batched_adaptive_while_solve(
    tab: Tableau,
    f: Callable,
    z0: PyTree,
    ts: jnp.ndarray,
    args: Tuple,
    rtol: float,
    atol: float,
    cfg: ControllerConfig,
    h0: Optional[jnp.ndarray] = None,
    use_pallas: bool = False,
    checkpoint_segments: Optional[int] = None,
    interpolate_ts: bool = False,
    guard_nonfinite: bool = True,
) -> Tuple[PyTree, Checkpoints, SolveStats]:
    """Per-sample batched adaptive solve: one fused while_loop, one
    stepsize controller *per batch element*.

    ``z0`` leaves carry a leading batch dim B; ``f`` is the per-sample
    vector field (no batch dim — it is vmapped inside the stepper).
    Returns (ys, checkpoints, stats) where ``ys`` leaves are
    (len(ts), B, ...) with ys[0] = z0, checkpoints/stats carry per-element
    rows (see ``Checkpoints`` / ``SolveStats``).  Not
    reverse-differentiable (while_loop) — wrap in custom_vjp (ACA /
    adjoint) or use only for inference.

    Batch rows never interact (no cross-element reduction anywhere in
    the loop), so the solve is embarrassingly parallel over B: running
    it on a batch *shard* yields exactly the shard's rows of the full
    solve, with a shard-local trip count — the property
    ``odeint(..., mesh=...)`` builds its ``shard_map`` sharding on.

    Each iteration advances every *live* element one ψ trial with its own
    trial stepsize; per-element accept/reject masks (``jnp.where``
    freezing, h = 0 for dead rows) keep rejected and finished elements
    bit-stable, and the loop runs until all elements have landed on their
    last ``ts[k]`` (or exhausted their step/trial budget).  ``use_pallas``
    expects an already-flat (B, N) state (``stepper.maybe_flatten_batched``)
    and runs every trial through the batched fused kernels with per-row
    error norms.  ``checkpoint_segments`` as in ``adaptive_while_solve``:
    each element writes its own K snapshot rows at its own segment
    boundaries.  ``interpolate_ts`` as in ``adaptive_while_solve``:
    every element advances on its own natural grid and reads interior
    eval times off its own per-step interpolants (per-element
    ``ev_lo``/``ev_hi`` rows feed the batched ACA backward sweep).
    ``guard_nonfinite`` as in ``adaptive_while_solve``, per element: a
    failing element freezes (leaves the live set, h = 0 identity trials)
    and reports ``SolveStatus.NONFINITE_STATE`` in its status row while
    healthy elements integrate on bit-identically.

    ``rtol``/``atol`` may be scalars (one tolerance for the whole batch,
    the classic path) or (B,) arrays — then every element's stepsize
    controller targets its *own* tolerance (initial-stepsize heuristic
    and per-trial error norm included), the per-request QoS knob of the
    serving engine.  A row at tolerance τ is bitwise the all-τ batch's
    row either way.
    """
    if not tab.adaptive:
        raise ValueError("batched_adaptive_while_solve requires an "
                         "embedded adaptive tableau")
    B = jax.tree.leaves(z0)[0].shape[0]
    rows = jnp.arange(B)
    n_eval = ts.shape[0]
    tdt = ts.dtype
    max_steps = cfg.max_steps
    max_total_trials = max_steps * cfg.max_trials
    n_snap, seg_len = _snapshot_layout(checkpoint_segments, max_steps)
    targs = args

    row_tol = _row_tolerances(rtol, atol, B)
    hinit_evals = 2 if h0 is None else 0  # hinit costs 2 f-evals per elt
    if h0 is None:
        if row_tol is not None:
            h0 = jax.vmap(lambda z, rt, at: initial_stepsize(
                f, ts[0], z, targs, tab.order, rt, at))(z0, *row_tol)
        else:
            h0 = jax.vmap(lambda z: initial_stepsize(
                f, ts[0], z, targs, tab.order, rtol, atol))(z0)
    h0 = jnp.broadcast_to(jnp.asarray(h0, tdt), (B,))

    ys = _buffer_set(_empty_buffer(z0, n_eval), 0, z0)

    ckpt_t, ckpt_h, ckpt_z, ckpt_oi = _init_checkpoint_buffers(
        z0, max_steps, tdt, n_snap, batch_size=B)

    fb0 = jax.vmap(lambda ti, zi: f(ti, zi, *targs))
    k0 = fb0(jnp.full((B,), ts[0], tdt), z0)
    nfe0 = jnp.full((B,), 1 + hinit_evals, jnp.int32)

    # elements starting from a non-finite state/derivative/h0 fail at once
    failed0 = _nonfinite_rows((z0, k0, h0)) if guard_nonfinite \
        else jnp.zeros((B,), bool)

    carry0 = dict(
        t=jnp.full((B,), ts[0], tdt), z=z0, k0=k0, h=h0,
        prev_ratio=jnp.ones((B,), jnp.float32),
        i=jnp.zeros((B,), jnp.int32),           # accepted steps so far
        eval_idx=jnp.ones((B,), jnp.int32),     # next ts[] to hit
        trials=jnp.zeros((B,), jnp.int32),
        nfe=nfe0,
        failed=failed0, uflow=jnp.zeros((B,), bool),
        ys=ys, ckpt_t=ckpt_t, ckpt_h=ckpt_h, ckpt_z=ckpt_z, ckpt_oi=ckpt_oi,
    )
    if checkpoint_segments is not None:
        # segmented replay re-chains FSAL reuse per element: snapshot
        # each element's k0 carry next to its state snapshots
        carry0["ckpt_k0"] = jax.tree.map(
            lambda l: jnp.zeros((l.shape[0], n_snap) + l.shape[1:],
                                l.dtype), k0)
    if interpolate_ts:
        # per-element half-open eval-index ranges per accepted interval
        carry0["ckpt_elo"] = jnp.zeros((B, max_steps), jnp.int32)
        carry0["ckpt_ehi"] = jnp.zeros((B, max_steps), jnp.int32)

    tiny = jnp.asarray(jnp.finfo(tdt).eps, tdt)
    karr = jnp.arange(n_eval)

    def live_mask(c):
        return (
            (c["eval_idx"] < n_eval)
            & (c["i"] < max_steps)
            & (c["trials"] < max_total_trials)
            & ~c["failed"]
        )

    def cond(c):
        return jnp.any(live_mask(c))

    def body(c):
        live = live_mask(c)
        t, z, h = c["t"], c["z"], c["h"]
        # natural grid: only the final time is a forced landing
        t_target = ts[n_eval - 1] if interpolate_ts else \
            ts[jnp.minimum(c["eval_idx"], n_eval - 1)]          # (B,)
        h_min = 16.0 * tiny * jnp.maximum(jnp.abs(t), jnp.asarray(1.0, tdt))
        # dead elements step with h = 0: ψ degenerates to the identity
        h_use = jnp.where(live, jnp.clip(h, h_min, t_target - t),
                          jnp.zeros((), tdt))
        res = rk_step_batched(tab, f, t, z, h_use, targs, k0=c["k0"],
                              use_pallas=use_pallas,
                              err_scale=(rtol, atol),
                              dense=interpolate_ts)
        ratio = res.err_ratio                                   # (B,)
        railed = h_use <= h_min * (1 + 1e-3)
        if guard_nonfinite:
            # per-row scalar read: a non-finite row state forces a
            # non-finite row ratio (see adaptive_while_solve)
            bad = ~jnp.isfinite(ratio)
            accept = live & ((ratio <= 1.0) | railed) & ~bad
        else:
            bad = jnp.zeros((B,), bool)
            accept = live & ((ratio <= 1.0) | railed)
        # per-element health flags (dead rows: live False masks them out)
        fail_now = live & bad & railed
        uflow_now = accept & railed & (ratio > 1.0)

        t_new = t + h_use
        hit = accept & (t_new >= t_target - 16.0 * tiny * jnp.maximum(
            jnp.abs(t_target), jnp.asarray(1.0, tdt)))

        # FSAL / first-stage reuse, per element (hoisted before the
        # output writes: in natural-grid mode k0' doubles as the
        # interval-end derivative of each element's interpolant)
        if tab.fsal:
            k0_acc = res.k_last
            nfe_acc = jnp.zeros((B,), jnp.int32)
        else:
            k0_acc = jax.vmap(lambda ti, zi: f(ti, zi, *targs))(
                t_new, res.z_next)
            nfe_acc = jnp.ones((B,), jnp.int32)

        # --- on accept: write each element's own checkpoint row ----------
        i_c = jnp.minimum(c["i"], max_steps - 1)
        ckpt_t = c["ckpt_t"].at[rows, i_c].set(
            jnp.where(accept, t, c["ckpt_t"][rows, i_c]))
        ckpt_h = c["ckpt_h"].at[rows, i_c].set(
            jnp.where(accept, h_use, c["ckpt_h"][rows, i_c]))
        ckpt_k0 = None
        if checkpoint_segments is None:
            ckpt_z = jax.tree.map(
                lambda b, v: b.at[rows, i_c].set(_bwhere(accept, v,
                                                         b[rows, i_c])),
                c["ckpt_z"], z)
        else:
            # segmented: each element snapshots (z, k0) at ITS OWN
            # boundaries; c["k0"] rows are exactly the first-stage
            # derivatives this accepted trial consumed
            s = jnp.minimum(i_c // seg_len, n_snap - 1)       # (B,)
            snap = accept & (i_c % seg_len == 0)
            ckpt_z = jax.tree.map(
                lambda b, v: b.at[rows, s].set(_bwhere(snap, v,
                                                       b[rows, s])),
                c["ckpt_z"], z)
            ckpt_k0 = jax.tree.map(
                lambda b, v: b.at[rows, s].set(_bwhere(snap, v,
                                                       b[rows, s])),
                c["ckpt_k0"], c["k0"])
        final_idx = jnp.asarray(n_eval - 1, jnp.int32)
        oi_val = jnp.where(hit,
                           final_idx if interpolate_ts else c["eval_idx"],
                           jnp.full((B,), -1, jnp.int32))
        ckpt_oi = c["ckpt_oi"].at[rows, i_c].set(
            jnp.where(accept, oi_val, c["ckpt_oi"][rows, i_c]))

        # --- outputs ------------------------------------------------------
        extra = {}
        if interpolate_ts:
            # each element reads the eval times its accepted interval
            # covers off its own interpolant
            ys, _, n_cov, eval_advance = natural_grid_outputs_batched(
                ts, karr, tiny, rows, t, t_new, h_use, accept, hit,
                c["eval_idx"], c["ys"], z, res.z_next, res.k_first,
                k0_acc, res.z_mid)
            extra["ckpt_elo"] = c["ckpt_elo"].at[rows, i_c].set(
                jnp.where(accept, c["eval_idx"], c["ckpt_elo"][rows, i_c]))
            extra["ckpt_ehi"] = c["ckpt_ehi"].at[rows, i_c].set(
                jnp.where(accept, c["eval_idx"] + n_cov,
                          c["ckpt_ehi"][rows, i_c]))
        else:
            # --- on eval-time hit: record that element's output ----------
            e_c = jnp.minimum(c["eval_idx"], n_eval - 1)
            ys = jax.tree.map(
                lambda b, v: b.at[e_c, rows].set(
                    _bwhere(hit, v, b[e_c, rows])),
                c["ys"], res.z_next)
            eval_advance = hit.astype(jnp.int32)

        # --- per-element stepsize control ---------------------------------
        # sanitize non-finite ratios so the per-element h chain cannot
        # absorb a NaN (max-rate shrink instead); bitwise no-op when finite
        ratio_c = jnp.where(bad, jnp.asarray(1e10, jnp.float32), ratio)
        h_next = propose_stepsize(
            cfg, h_use, ratio_c, c["prev_ratio"], tab.order)
        h_next = jnp.asarray(h_next, tdt)

        k0_new = _bwhere_tree(accept, k0_acc, c["k0"])
        # finished elements take the h=0 identity trial for free: only
        # live elements pay f-evals in the per-element stats
        nfe = c["nfe"] + jnp.where(live, tab.stages - 1, 0) \
            + jnp.where(accept, nfe_acc, 0)

        out = dict(
            t=jnp.where(accept, t_new, t),
            z=_bwhere_tree(accept, res.z_next, z),
            k0=k0_new,
            h=jnp.where(live, h_next, h),
            prev_ratio=jnp.where(
                accept, jnp.maximum(ratio, 1e-10), c["prev_ratio"]),
            i=c["i"] + accept.astype(jnp.int32),
            eval_idx=c["eval_idx"] + eval_advance,
            trials=c["trials"] + live.astype(jnp.int32),
            nfe=nfe,
            failed=c["failed"] | fail_now,
            uflow=c["uflow"] | uflow_now,
            ys=ys, ckpt_t=ckpt_t, ckpt_h=ckpt_h, ckpt_z=ckpt_z,
            ckpt_oi=ckpt_oi,
        )
        if ckpt_k0 is not None:
            out["ckpt_k0"] = ckpt_k0
        out.update(extra)
        return out

    c = jax.lax.while_loop(cond, body, carry0)

    overflow = c["eval_idx"] < n_eval
    status = _compose_status(c["failed"], c["uflow"], ~overflow,
                             c["trials"] >= max_total_trials)
    fill = c["failed"][None, :] & (karr[:, None] >= c["eval_idx"][None, :])
    ys_out = _freeze_fill(c["ys"], fill, c["z"])
    ckpts = Checkpoints(t=c["ckpt_t"], h=c["ckpt_h"], z=c["ckpt_z"],
                        out_idx=c["ckpt_oi"], n=c["i"],
                        k0=c.get("ckpt_k0"),
                        ev_lo=c.get("ckpt_elo"), ev_hi=c.get("ckpt_ehi"))
    stats = SolveStats(n_steps=c["i"], n_trials=c["trials"], nfe=c["nfe"],
                       overflow=overflow, status=status)
    return ys_out, ckpts, stats


def make_fixed_grid(ts: jnp.ndarray, steps_per_interval: int) -> jnp.ndarray:
    """Uniform sub-grid with ``steps_per_interval`` steps between each pair
    of eval times.  Returns (n_intervals * steps,) array of (t, h) pairs as
    two arrays (t_grid, h_grid)."""
    t_lo = ts[:-1]
    t_hi = ts[1:]
    frac = jnp.arange(steps_per_interval) / steps_per_interval
    # (n_intervals, steps)
    t_grid = t_lo[:, None] + (t_hi - t_lo)[:, None] * frac[None, :]
    h_grid = jnp.broadcast_to(
        ((t_hi - t_lo) / steps_per_interval)[:, None], t_grid.shape)
    return t_grid.reshape(-1), h_grid.reshape(-1)


def fixed_grid_solve(
    tab: Tableau,
    f: Callable,
    z0: PyTree,
    ts: jnp.ndarray,
    args: Tuple,
    steps_per_interval: int,
    use_pallas: bool = False,
) -> Tuple[PyTree, SolveStats]:
    """Differentiable fixed-grid integration via ``lax.scan``.

    Outputs at every ``ts``; ys[0] = z0.  Reverse-mode AD through the scan
    is the naive method for fixed-step solvers.

    ``use_pallas`` ravels the state once (``stepper.flatten_problem``)
    and runs every step through the fused flat-state kernels; the
    unravel is applied to the stacked outputs.  Fully differentiable —
    the flatten/unravel are plain jnp reshapes on the AD path.
    """
    f, z0, unravel, use_pallas = maybe_flatten(f, z0, use_pallas)

    t_grid, h_grid = make_fixed_grid(ts, steps_per_interval)
    n_intervals = ts.shape[0] - 1

    def step_fn(z, t_h):
        t, h = t_h
        z_next = rk_step(tab, f, t, z, h, args,
                         use_pallas=use_pallas).z_next
        return z_next, None

    # scan per interval so we can emit outputs
    def interval(z, idx):
        t_seg = jax.lax.dynamic_slice_in_dim(
            t_grid, idx * steps_per_interval, steps_per_interval)
        h_seg = jax.lax.dynamic_slice_in_dim(
            h_grid, idx * steps_per_interval, steps_per_interval)
        z_end, _ = jax.lax.scan(step_fn, z, (t_seg, h_seg))
        return z_end, z_end

    _, ys_tail = jax.lax.scan(interval, z0, jnp.arange(n_intervals))
    ys = jax.tree.map(
        lambda z0l, tail: jnp.concatenate([z0l[None], tail], axis=0),
        z0, ys_tail)
    if unravel is not None:
        ys = jax.vmap(unravel)(ys)

    n_steps = n_intervals * steps_per_interval
    # fixed grids have no trial/accept loop to guard: the health check
    # is a single post-hoc finite-mask read over the outputs
    status = jnp.where(_nonfinite_any(ys),
                       SolveStatus.NONFINITE_STATE,
                       SolveStatus.OK).astype(jnp.int32)
    stats = SolveStats(
        n_steps=jnp.asarray(n_steps, jnp.int32),
        n_trials=jnp.asarray(n_steps, jnp.int32),
        nfe=jnp.asarray(n_steps * tab.stages, jnp.int32),
        overflow=jnp.asarray(False),
        status=status,
    )
    return ys, stats


# --------------------------------------------------------------------------
# MALI engines: reversible asynchronous-leapfrog adaptive solving
# --------------------------------------------------------------------------


class MaliGrid(NamedTuple):
    """The MALI solve's reverse-reconstruction record: scalars only.

    Where ACA's ``Checkpoints`` stores every accepted *state*, MALI
    stores none: ``t``/``h``/``out_idx`` are the accepted scalar grid
    (same conventions as ``Checkpoints`` — interval start time, accepted
    stepsize, eval-time landing index or -1; slots [0, n) valid), and
    ``zT``/``vT`` are the single terminal lattice pair the backward
    sweep starts inverting from.  ``scale_exp`` pins the per-solve
    lattice (``stepper.alf_lattice_exponent``) so the backward decodes
    on the identical quantum.  Batched solves carry a leading batch dim
    on the scalar grids ((B, max_steps)), per-element ``n`` (B,),
    batch-leading ``zT``/``vT`` leaves and per-element ``scale_exp``
    (B,) — each element quantizes on its own lattice, exactly as
    ``jax.vmap`` of the solo solve would.
    """
    t: jnp.ndarray            # (max_steps,) interval start times
    h: jnp.ndarray            # (max_steps,) accepted stepsizes
    out_idx: jnp.ndarray      # (max_steps,) int32 eval landing (or -1)
    n: jnp.ndarray            # number of valid slots
    zT: PyTree                # terminal position, integer lattice
    vT: PyTree                # terminal velocity, integer lattice
    scale_exp: jnp.ndarray    # lattice scale exponent (float32 scalar)


def mali_adaptive_solve(
    f: Callable,
    z0: PyTree,
    ts: jnp.ndarray,
    args: Tuple,
    rtol: float,
    atol: float,
    cfg: ControllerConfig,
    h0: Optional[jnp.ndarray] = None,
    guard_nonfinite: bool = True,
) -> Tuple[PyTree, MaliGrid, SolveStats]:
    """Adaptive asynchronous-leapfrog solve through increasing ``ts``.

    Same flattened trial/accept ``lax.while_loop`` as
    ``adaptive_while_solve`` (Algorithm 1's stepsize search), but the
    carry is the integer-lattice pair (z, v) of ``stepper.alf_step`` and
    the only per-step record is the scalar grid — O(dim) state memory at
    any horizon.  The embedded error is the free Euler-comparator gap
    h·(w − v); one f evaluation per trial (accepted or rejected — ALF
    has no extra stages and no FSAL to chain).  Returns (ys, grid,
    stats) with ``ys[0] = z0`` exactly; interior/final outputs are the
    decoded lattice states (within one quantum of the float trajectory).
    Not reverse-differentiable — ``odeint_mali`` wraps it in custom_vjp.
    """
    n_eval = ts.shape[0]
    tdt = ts.dtype
    max_steps = cfg.max_steps
    max_total_trials = max_steps * cfg.max_trials
    targs = args

    v0 = f(ts[0], z0, *targs)
    scale_exp = alf_lattice_exponent(z0, v0)
    zq0 = lattice_encode(z0, scale_exp)
    vq0 = lattice_encode(v0, scale_exp)

    hinit_evals = 2 if h0 is None else 0  # hinit costs 2 f-evals
    if h0 is None:
        h0 = initial_stepsize(f, ts[0], z0, targs, ALF_ORDER, rtol, atol)
    h0 = jnp.asarray(h0, tdt)

    ys = _buffer_set(_empty_buffer(z0, n_eval), 0, z0)

    failed0 = _nonfinite_any((z0, v0, h0)) if guard_nonfinite \
        else jnp.asarray(False)

    carry0 = dict(
        t=ts[0], zq=zq0, vq=vq0, h=h0,
        prev_ratio=jnp.asarray(1.0, jnp.float32),
        i=jnp.asarray(0, jnp.int32),
        eval_idx=jnp.asarray(1, jnp.int32),
        trials=jnp.asarray(0, jnp.int32),
        nfe=jnp.asarray(1 + hinit_evals, jnp.int32),  # + the v0 eval
        failed=failed0, uflow=jnp.asarray(False),
        ys=ys,
        grid_t=jnp.zeros((max_steps,), tdt),
        grid_h=jnp.zeros((max_steps,), tdt),
        grid_oi=jnp.full((max_steps,), -1, jnp.int32),
    )

    tiny = jnp.asarray(jnp.finfo(tdt).eps, tdt)

    def cond(c):
        return (
            (c["eval_idx"] < n_eval)
            & (c["i"] < max_steps)
            & (c["trials"] < max_total_trials)
            & ~c["failed"]
        )

    def body(c):
        t, h = c["t"], c["h"]
        t_target = ts[c["eval_idx"]]
        h_min = 16.0 * tiny * jnp.maximum(jnp.abs(t), jnp.asarray(1.0, tdt))
        h_use = jnp.clip(h, h_min, t_target - t)
        res = alf_step(f, t, h_use, c["zq"], c["vq"], scale_exp, z0,
                       targs)
        z_f = lattice_decode(c["zq"], scale_exp, z0)
        ratio = error_ratio(res.err, z_f, res.z_next, rtol, atol)
        railed = h_use <= h_min * (1 + 1e-3)
        if guard_nonfinite:
            # the lattice encode launders NaN ints into finite garbage,
            # so the decoded state is useless as a detector — but the
            # raw f eval still poisons res.err, so the ratio read is
            # both the cheap AND the only sound guard here
            bad = ~jnp.isfinite(ratio)
            accept = ((ratio <= 1.0) | railed) & ~bad
        else:
            bad = jnp.asarray(False)
            accept = (ratio <= 1.0) | railed
        fail_now = bad & railed
        uflow_now = accept & railed & (ratio > 1.0)

        t_new = t + h_use
        hit = accept & (t_new >= t_target - 16.0 * tiny * jnp.maximum(
            jnp.abs(t_target), jnp.asarray(1.0, tdt)))

        # --- on accept: record the scalar grid slot (t_i, h_i, oi) -----
        i = c["i"]
        grid_t = c["grid_t"].at[i].set(jnp.where(accept, t, c["grid_t"][i]))
        grid_h = c["grid_h"].at[i].set(
            jnp.where(accept, h_use, c["grid_h"][i]))
        oi_val = jnp.where(hit, c["eval_idx"], jnp.asarray(-1, jnp.int32))
        grid_oi = c["grid_oi"].at[i].set(
            jnp.where(accept, oi_val, c["grid_oi"][i]))

        # --- on eval-time hit: record the decoded output ---------------
        ys = jax.tree.map(
            lambda b, v: b.at[c["eval_idx"]].set(
                jnp.where(hit, v, b[c["eval_idx"]])),
            c["ys"], res.z_next)

        ratio_c = jnp.where(bad, jnp.asarray(1e10, jnp.float32), ratio)
        h_next = jnp.asarray(propose_stepsize(
            cfg, h_use, ratio_c, c["prev_ratio"], ALF_ORDER), tdt)

        return dict(
            t=jnp.where(accept, t_new, t),
            zq=_where_tree(accept, res.zq_next, c["zq"]),
            vq=_where_tree(accept, res.vq_next, c["vq"]),
            h=h_next,
            prev_ratio=jnp.where(
                accept, jnp.maximum(ratio, 1e-10), c["prev_ratio"]),
            i=i + accept.astype(jnp.int32),
            eval_idx=c["eval_idx"] + hit.astype(jnp.int32),
            trials=c["trials"] + 1,
            nfe=c["nfe"] + 1,  # one midpoint eval per ALF trial
            failed=c["failed"] | fail_now,
            uflow=c["uflow"] | uflow_now,
            ys=ys, grid_t=grid_t, grid_h=grid_h, grid_oi=grid_oi,
        )

    c = jax.lax.while_loop(cond, body, carry0)

    overflow = c["eval_idx"] < n_eval
    status = _compose_status(c["failed"], c["uflow"], ~overflow,
                             c["trials"] >= max_total_trials)
    karr = jnp.arange(n_eval)
    ys_out = _freeze_fill(c["ys"], c["failed"] & (karr >= c["eval_idx"]),
                          lattice_decode(c["zq"], scale_exp, z0))
    grid = MaliGrid(t=c["grid_t"], h=c["grid_h"], out_idx=c["grid_oi"],
                    n=c["i"], zT=c["zq"], vT=c["vq"], scale_exp=scale_exp)
    stats = SolveStats(n_steps=c["i"], n_trials=c["trials"], nfe=c["nfe"],
                       overflow=overflow, status=status)
    return ys_out, grid, stats


def batched_mali_adaptive_solve(
    f: Callable,
    z0: PyTree,
    ts: jnp.ndarray,
    args: Tuple,
    rtol: float,
    atol: float,
    cfg: ControllerConfig,
    h0: Optional[jnp.ndarray] = None,
    guard_nonfinite: bool = True,
) -> Tuple[PyTree, MaliGrid, SolveStats]:
    """Per-sample batched MALI forward: ``odeint(..., batch_axis=0,
    grad_method="mali")``.

    One fused while_loop, one controller per batch element (the
    ``batched_adaptive_while_solve`` contract), with the integer-lattice
    pair carried per element on a per-element lattice (``scale_exp``
    (B,) — each element quantizes exactly as a solo solve of its row
    would).  Freezing differs from the RK engines: an h = 0
    ALF trial is *not* the identity in v (the reflection still fires),
    so rejected/finished elements are frozen purely by the accept mask —
    integer ``where`` keeps their pair bit-stable.  Per-element scalar
    grids feed the per-element backward inversion.
    """
    B = jax.tree.leaves(z0)[0].shape[0]
    rows = jnp.arange(B)
    n_eval = ts.shape[0]
    tdt = ts.dtype
    max_steps = cfg.max_steps
    max_total_trials = max_steps * cfg.max_trials
    targs = args

    fb0 = jax.vmap(lambda ti, zi: f(ti, zi, *targs))
    v0 = fb0(jnp.full((B,), ts[0], tdt), z0)
    scale_exp = alf_lattice_exponent_batched(z0, v0)     # (B,)
    zq0 = lattice_encode(z0, scale_exp)
    vq0 = lattice_encode(v0, scale_exp)

    row_tol = _row_tolerances(rtol, atol, B)
    hinit_evals = 2 if h0 is None else 0  # hinit costs 2 f-evals per elt
    if h0 is None:
        if row_tol is not None:
            h0 = jax.vmap(lambda z, rt, at: initial_stepsize(
                f, ts[0], z, targs, ALF_ORDER, rt, at))(z0, *row_tol)
        else:
            h0 = jax.vmap(lambda z: initial_stepsize(
                f, ts[0], z, targs, ALF_ORDER, rtol, atol))(z0)
    h0 = jnp.broadcast_to(jnp.asarray(h0, tdt), (B,))

    ys = _buffer_set(_empty_buffer(z0, n_eval), 0, z0)

    failed0 = _nonfinite_rows((z0, v0, h0)) if guard_nonfinite \
        else jnp.zeros((B,), bool)

    carry0 = dict(
        t=jnp.full((B,), ts[0], tdt), zq=zq0, vq=vq0, h=h0,
        prev_ratio=jnp.ones((B,), jnp.float32),
        i=jnp.zeros((B,), jnp.int32),
        eval_idx=jnp.ones((B,), jnp.int32),
        trials=jnp.zeros((B,), jnp.int32),
        nfe=jnp.full((B,), 1 + hinit_evals, jnp.int32),
        failed=failed0, uflow=jnp.zeros((B,), bool),
        ys=ys,
        grid_t=jnp.zeros((B, max_steps), tdt),
        grid_h=jnp.zeros((B, max_steps), tdt),
        grid_oi=jnp.full((B, max_steps), -1, jnp.int32),
    )

    tiny = jnp.asarray(jnp.finfo(tdt).eps, tdt)

    def live_mask(c):
        return (
            (c["eval_idx"] < n_eval)
            & (c["i"] < max_steps)
            & (c["trials"] < max_total_trials)
            & ~c["failed"]
        )

    def cond(c):
        return jnp.any(live_mask(c))

    def body(c):
        live = live_mask(c)
        t, h = c["t"], c["h"]
        t_target = ts[jnp.minimum(c["eval_idx"], n_eval - 1)]     # (B,)
        h_min = 16.0 * tiny * jnp.maximum(jnp.abs(t), jnp.asarray(1.0, tdt))
        h_use = jnp.where(live, jnp.clip(h, h_min, t_target - t),
                          jnp.zeros((), tdt))
        res = alf_step_batched(f, t, h_use, c["zq"], c["vq"], scale_exp,
                               z0, targs)
        z_f = lattice_decode(c["zq"], scale_exp, z0)
        if row_tol is not None:
            ratio = jax.vmap(error_ratio)(
                res.err, z_f, res.z_next, *row_tol)               # (B,)
        else:
            ratio = jax.vmap(
                lambda e, a, b: error_ratio(e, a, b, rtol, atol))(
                    res.err, z_f, res.z_next)                     # (B,)
        railed = h_use <= h_min * (1 + 1e-3)
        if guard_nonfinite:
            # per-row ratio read (see mali_adaptive_solve: the decoded
            # lattice state can't carry the NaN, res.err does)
            bad = ~jnp.isfinite(ratio)
            accept = live & ((ratio <= 1.0) | railed) & ~bad
        else:
            bad = jnp.zeros((B,), bool)
            accept = live & ((ratio <= 1.0) | railed)
        fail_now = live & bad & railed
        uflow_now = accept & railed & (ratio > 1.0)

        t_new = t + h_use
        hit = accept & (t_new >= t_target - 16.0 * tiny * jnp.maximum(
            jnp.abs(t_target), jnp.asarray(1.0, tdt)))

        # --- on accept: record each element's scalar grid row ----------
        i_c = jnp.minimum(c["i"], max_steps - 1)
        grid_t = c["grid_t"].at[rows, i_c].set(
            jnp.where(accept, t, c["grid_t"][rows, i_c]))
        grid_h = c["grid_h"].at[rows, i_c].set(
            jnp.where(accept, h_use, c["grid_h"][rows, i_c]))
        oi_val = jnp.where(hit, c["eval_idx"], jnp.full((B,), -1,
                                                        jnp.int32))
        grid_oi = c["grid_oi"].at[rows, i_c].set(
            jnp.where(accept, oi_val, c["grid_oi"][rows, i_c]))

        # --- on eval-time hit: record that element's decoded output ----
        e_c = jnp.minimum(c["eval_idx"], n_eval - 1)
        ys = jax.tree.map(
            lambda b, v: b.at[e_c, rows].set(_bwhere(hit, v, b[e_c, rows])),
            c["ys"], res.z_next)

        ratio_c = jnp.where(bad, jnp.asarray(1e10, jnp.float32), ratio)
        h_next = jnp.asarray(propose_stepsize(
            cfg, h_use, ratio_c, c["prev_ratio"], ALF_ORDER), tdt)

        return dict(
            t=jnp.where(accept, t_new, t),
            zq=_bwhere_tree(accept, res.zq_next, c["zq"]),
            vq=_bwhere_tree(accept, res.vq_next, c["vq"]),
            h=jnp.where(live, h_next, h),
            prev_ratio=jnp.where(
                accept, jnp.maximum(ratio, 1e-10), c["prev_ratio"]),
            i=c["i"] + accept.astype(jnp.int32),
            eval_idx=c["eval_idx"] + hit.astype(jnp.int32),
            trials=c["trials"] + live.astype(jnp.int32),
            nfe=c["nfe"] + live.astype(jnp.int32),
            failed=c["failed"] | fail_now,
            uflow=c["uflow"] | uflow_now,
            ys=ys, grid_t=grid_t, grid_h=grid_h, grid_oi=grid_oi,
        )

    c = jax.lax.while_loop(cond, body, carry0)

    overflow = c["eval_idx"] < n_eval
    status = _compose_status(c["failed"], c["uflow"], ~overflow,
                             c["trials"] >= max_total_trials)
    karr = jnp.arange(n_eval)
    fill = c["failed"][None, :] & (karr[:, None] >= c["eval_idx"][None, :])
    ys_out = _freeze_fill(c["ys"], fill,
                          lattice_decode(c["zq"], scale_exp, z0))
    grid = MaliGrid(t=c["grid_t"], h=c["grid_h"], out_idx=c["grid_oi"],
                    n=c["i"], zT=c["zq"], vT=c["vq"], scale_exp=scale_exp)
    stats = SolveStats(n_steps=c["i"], n_trials=c["trials"], nfe=c["nfe"],
                       overflow=overflow, status=status)
    return ys_out, grid, stats
