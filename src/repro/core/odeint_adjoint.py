"""The adjoint method of Chen et al. 2018 — the paper's primary baseline.

Memory O(N_f): the forward trajectory is *forgotten*; the backward pass
re-integrates the augmented system

    d/dt [ z̄, λ, ḡ ] = [ f(t, z̄),  -(∂f/∂z)ᵀλ,  -(∂f/∂θ)ᵀλ ]

in reverse time starting from the boundary condition (z(T), ∂J/∂z(T), 0)
(paper Eqs. 6–8; we carry λ = +∂J/∂z so signs match autodiff convention).

Because z̄(t) is a *fresh* IVP solved backwards, it drifts from the forward
trajectory by the truncation-error term of Theorem 3.2
(e_k = DΦ + (−1)^{p+1}(DΦ)^{-1} ≠ 0), producing the systematic gradient
error that ACA eliminates.  This implementation exists so the paper's
comparisons (Fig. 6, Table 1/2/4/5) are reproducible like-for-like.

Sharding contract (relied on by ``odeint(..., mesh=...)``): the batched
backward re-integration is per-row (each element's augmented system has
its own controller), so it runs **shard-local** under ``shard_map``;
the summed ``θ``-cotangent ḡ is a per-shard partial sum that crosses
devices exactly once, in the psum ``shard_map``'s transpose inserts
for replicated ``args``.  See ``docs/distributed.md``.
"""

from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from .controller import ControllerConfig
from .integrate import (
    SolveStats,
    _as_tuple,
    _mask_failed_cotangents,
    adaptive_while_solve,
    batched_adaptive_while_solve,
    fixed_grid_solve,
)
from .stepper import flatten_problem, maybe_flatten, maybe_flatten_batched
from .tableaus import Tableau

PyTree = Any


def _solve_segment_adaptive(solver, g, aug, s_seg, args, rtol, atol, cfg,
                            use_pallas):
    """One reverse-time segment of the augmented system; when
    ``use_pallas`` the whole (z̄, λ, ḡ) pytree is raveled into a single
    flat carry for the fused stepper (falls back when dtypes mix)."""
    flat = flatten_problem(g, aug) if use_pallas else None
    if flat is not None:
        g_flat, aug_flat, unravel = flat
        ys_seg, _, _ = adaptive_while_solve(
            solver, g_flat, aug_flat, s_seg, (args,), rtol, atol, cfg,
            use_pallas=True)
        return unravel(jax.tree.map(lambda y: y[-1], ys_seg))
    ys_seg, _, _ = adaptive_while_solve(
        solver, g, aug, s_seg, (args,), rtol, atol, cfg)
    return jax.tree.map(lambda y: y[-1], ys_seg)


def _aug_dynamics(f: Callable):
    """Reverse-time augmented dynamics in the substituted variable s = -t."""

    def g(s, aug, args):
        z, lam, _ = aug
        t = -s
        fz, vjp_fn = jax.vjp(lambda zz, aa: f(t, zz, *_as_tuple(aa)), z,
                             args)
        dz_cot, darg_cot = vjp_fn(lam)
        # dA/dt = (f, -fᵀ_z λ, -fᵀ_θ λ);  dA/ds = -dA/dt
        return (
            jax.tree.map(jnp.negative, fz),
            dz_cot,
            darg_cot,
        )

    return g


def odeint_adjoint(
    f: Callable,
    z0: PyTree,
    ts: jnp.ndarray,
    args: PyTree = (),
    *,
    solver: Tableau,
    rtol: float = 1e-6,
    atol: float = 1e-6,
    cfg: Optional[ControllerConfig] = None,
    h0: Optional[jnp.ndarray] = None,
    use_pallas: bool = False,
    interpolate_ts: bool = False,
) -> Tuple[PyTree, SolveStats]:
    """Adjoint-method odeint: O(N_f) memory, reverse-time numerical error.

    ``h0`` overrides the automatic initial-stepsize heuristic for the
    forward solve (solve-health fallback ladders use this to retry with a
    tighter first step).  On non-finite detection the forward engine
    freezes the solve (``stats.status == SolveStatus.NONFINITE_STATE``)
    and the backward sweep zeroes the output cotangents, so a failed
    solve contributes exact-zero gradients instead of NaN.

    ``use_pallas`` runs the forward solve on the raveled state and each
    backward segment on the raveled augmented (z̄, λ, ḡ) state, both
    through the fused flat-state kernels.

    ``interpolate_ts`` makes the *forward* solve advance on its natural
    grid and read interior eval times off per-step interpolants; the
    backward pass is untouched — it re-integrates the augmented system
    from z(T) and injects the output cotangents at each ``ts[k]``
    exactly as before (the continuous-adjoint approximation already
    treats ``g_ys[k]`` as the cotangent of z(ts[k])).
    """
    if cfg is None:
        cfg = ControllerConfig()
    if not solver.adaptive:
        raise ValueError("adjoint baseline expects an adaptive tableau; "
                         "fixed-grid adjoint == ANODE-style, see "
                         "odeint_adjoint_fixed")

    f, z0, unravel, use_pallas = maybe_flatten(f, z0, use_pallas)

    # forward buffers are not kept: capacity-1 checkpoint buffer (writes
    # beyond slot 0 are dropped by XLA OOB-scatter semantics)
    fwd_cfg = ControllerConfig(
        safety=cfg.safety, min_factor=cfg.min_factor,
        max_factor=cfg.max_factor, pi_coeff=cfg.pi_coeff,
        max_steps=cfg.max_steps, max_trials=cfg.max_trials)

    # ``ts`` is threaded explicitly (no closures over trace-time values)
    @jax.custom_vjp
    def solve(z0, args, ts):
        ys, _, stats = adaptive_while_solve(
            solver, f, z0, ts, _as_tuple(args), rtol, atol, fwd_cfg,
            h0=h0, use_pallas=use_pallas, interpolate_ts=interpolate_ts)
        return ys, stats

    def solve_fwd(z0, args, ts):
        ys, _, stats = adaptive_while_solve(
            solver, f, z0, ts, _as_tuple(args), rtol, atol, fwd_cfg,
            h0=h0, use_pallas=use_pallas, interpolate_ts=interpolate_ts)
        # residuals: ONLY the eval-time states (z(T) et al.) — O(N_f) memory
        return (ys, stats), (ys, args, ts, stats.status)

    def solve_bwd(res, cot):
        ys, args, ts, status = res
        g_ys, _ = cot
        g_ys = _mask_failed_cotangents(g_ys, status)
        n_eval = ts.shape[0]
        g_aug = _aug_dynamics(f)

        zT = jax.tree.map(lambda y: y[-1], ys)
        lam = jax.tree.map(lambda g: g[-1], g_ys)
        gargs = jax.tree.map(jnp.zeros_like, args)
        aug = (zT, lam, gargs)

        # integrate segment [ts[k+1] -> ts[k]] in reverse; inject output
        # cotangents at each eval time (static python loop: n_eval is static)
        for k in range(n_eval - 2, -1, -1):
            s_seg = jnp.stack([-ts[k + 1], -ts[k]])
            aug = _solve_segment_adaptive(
                solver, lambda s, a, ar: g_aug(s, a, ar), aug, s_seg,
                args, rtol, atol, cfg, use_pallas)
            zk, lam, gargs = aug
            lam = jax.tree.map(lambda l, g: l + g[k], lam, g_ys)
            aug = (zk, lam, gargs)

        _, lam, gargs = aug
        return lam, gargs, jnp.zeros_like(ts)

    solve.defvjp(solve_fwd, solve_bwd)
    ys, stats = solve(z0, args, ts)
    if unravel is not None:
        ys = jax.vmap(unravel)(ys)
    return ys, stats


def _solve_segment_adaptive_batched(solver, g, aug, s_seg, args, rtol,
                                    atol, cfg, use_pallas):
    """One reverse-time segment of the batched augmented system: the
    per-sample augmented pytree (z̄_b, λ_b, ḡ_b) rides the same masked
    batched engine as the forward solve, so every element re-integrates
    on its own reverse grid; ``use_pallas`` ravels each sample's
    augmented state into one (B, N) carry for the batched kernels."""
    gf, augf, unravel, up = maybe_flatten_batched(g, aug, use_pallas)
    ys_seg, _, _ = batched_adaptive_while_solve(
        solver, gf, augf, s_seg, (args,), rtol, atol, cfg, use_pallas=up)
    end = jax.tree.map(lambda y: y[-1], ys_seg)
    if unravel is not None:
        end = jax.vmap(unravel)(end)
    return end


def odeint_adjoint_batched(
    f: Callable,
    z0: PyTree,
    ts: jnp.ndarray,
    args: PyTree = (),
    *,
    solver: Tableau,
    rtol: float = 1e-6,
    atol: float = 1e-6,
    cfg: Optional[ControllerConfig] = None,
    h0: Optional[jnp.ndarray] = None,
    use_pallas: bool = False,
    interpolate_ts: bool = False,
) -> Tuple[PyTree, SolveStats]:
    """Per-sample batched adjoint: ``odeint(..., batch_axis=0)``'s
    adjoint path.

    Forward: ``batched_adaptive_while_solve`` over the per-sample state
    (each element on its own grid, O(N_f) residuals kept).  Backward:
    the augmented system (z̄, λ, ḡ) is solved in reverse per element by
    the same masked batched engine; ḡ is carried per element and summed
    over the batch at the end (args are shared).  Returns (ys, stats)
    with ys leaves (len(ts), B, ...) and per-element stats.
    ``interpolate_ts`` affects only the forward solve (see
    ``odeint_adjoint``).
    """
    if cfg is None:
        cfg = ControllerConfig()
    if not solver.adaptive:
        raise ValueError("adjoint baseline expects an adaptive tableau; "
                         "fixed-grid adjoint == ANODE-style, see "
                         "odeint_adjoint_fixed")

    f, z0, unravel, use_pallas = maybe_flatten_batched(f, z0, use_pallas)

    @jax.custom_vjp
    def solve(z0, args, ts):
        ys, _, stats = batched_adaptive_while_solve(
            solver, f, z0, ts, _as_tuple(args), rtol, atol, cfg,
            h0=h0, use_pallas=use_pallas, interpolate_ts=interpolate_ts)
        return ys, stats

    def solve_fwd(z0, args, ts):
        ys, _, stats = batched_adaptive_while_solve(
            solver, f, z0, ts, _as_tuple(args), rtol, atol, cfg,
            h0=h0, use_pallas=use_pallas, interpolate_ts=interpolate_ts)
        # residuals: ONLY the eval-time states — O(N_f) memory per element
        return (ys, stats), (ys, args, ts, stats.status)

    def solve_bwd(res, cot):
        ys, args, ts, status = res
        g_ys, _ = cot
        g_ys = _mask_failed_cotangents(g_ys, status, batched=True)
        n_eval = ts.shape[0]
        B = jax.tree.leaves(ys)[0].shape[1]
        g_aug = _aug_dynamics(f)

        zT = jax.tree.map(lambda y: y[-1], ys)          # (B, ...)
        lam = jax.tree.map(lambda g: g[-1], g_ys)
        gargs = jax.tree.map(
            lambda a: jnp.zeros((B,) + jnp.shape(a),
                                jnp.result_type(a)), args)
        aug = (zT, lam, gargs)

        for k in range(n_eval - 2, -1, -1):
            s_seg = jnp.stack([-ts[k + 1], -ts[k]])
            aug = _solve_segment_adaptive_batched(
                solver, lambda s, a, ar: g_aug(s, a, ar), aug, s_seg,
                args, rtol, atol, cfg, use_pallas)
            zk, lam, gargs = aug
            lam = jax.tree.map(lambda l, g: l + g[k], lam, g_ys)
            aug = (zk, lam, gargs)

        _, lam, gargs = aug
        gargs = jax.tree.map(lambda g: g.sum(axis=0), gargs)
        return lam, gargs, jnp.zeros_like(ts)

    solve.defvjp(solve_fwd, solve_bwd)
    ys, stats = solve(z0, args, ts)
    if unravel is not None:
        ys = jax.vmap(jax.vmap(unravel))(ys)
    return ys, stats


def odeint_adjoint_fixed(
    f: Callable,
    z0: PyTree,
    ts: jnp.ndarray,
    args: PyTree = (),
    *,
    solver: Tableau,
    steps_per_interval: int = 8,
    use_pallas: bool = False,
) -> Tuple[PyTree, SolveStats]:
    """Fixed-grid adjoint (ANODE-family baseline): reverse-integrate the
    augmented system on the same uniform grid, O(N_f) memory, but the
    reverse z̄ trajectory still drifts from the forward one.
    ``fixed_grid_solve`` ravels/unravels internally under ``use_pallas``,
    both for the forward state and the backward augmented state."""

    @jax.custom_vjp
    def solve(z0, args, ts):
        return fixed_grid_solve(solver, f, z0, ts, _as_tuple(args),
                                steps_per_interval, use_pallas=use_pallas)

    def solve_fwd(z0, args, ts):
        out = fixed_grid_solve(solver, f, z0, ts, _as_tuple(args),
                               steps_per_interval, use_pallas=use_pallas)
        ys, stats = out
        return out, (ys, args, ts)

    def solve_bwd(res, cot):
        ys, args, ts = res
        g_ys, _ = cot
        n_eval = ts.shape[0]
        g_aug = _aug_dynamics(f)

        zT = jax.tree.map(lambda y: y[-1], ys)
        lam = jax.tree.map(lambda g: g[-1], g_ys)
        gargs = jax.tree.map(jnp.zeros_like, args)
        aug = (zT, lam, gargs)

        for k in range(n_eval - 2, -1, -1):
            s_seg = jnp.stack([-ts[k + 1], -ts[k]])
            ys_seg, _ = fixed_grid_solve(
                solver, lambda s, a, ar: g_aug(s, a, ar),
                aug, s_seg, (args,), steps_per_interval,
                use_pallas=use_pallas)
            aug = jax.tree.map(lambda y: y[-1], ys_seg)
            zk, lam, gargs = aug
            lam = jax.tree.map(lambda l, g: l + g[k], lam, g_ys)
            aug = (zk, lam, gargs)

        _, lam, gargs = aug
        return lam, gargs, jnp.zeros_like(ts)

    solve.defvjp(solve_fwd, solve_bwd)
    return solve(z0, args, ts)
