"""The naive method — direct back-propagation through the ODE solver.

The paper's second baseline (Sec. 3.3): every solver operation, *including
the stepsize search*, stays on the differentiation path.  The stepsize
update chain  h_{i+1} = h_i · decay(ê_i)  is itself differentiated, so the
computation graph has depth O(N_f · N_t · m) and reverse-mode AD stores the
stage intermediates of every trial — the paper's memory blow-up, realized
in JAX as scan-carried residuals over the full trial budget.

JAX cannot reverse-differentiate a dynamic-trip-count ``while_loop``, so the
adaptive naive solver is a *bounded* ``lax.scan`` over the flattened
trial/accept loop with where-masking once integration finishes — the
standard fixed-budget encoding; the budget (max_steps × max_trials) plays
the role of the tape length.

Sharding contract (relied on by ``odeint(..., mesh=...)``): the batched
scan tape is per-row, so reverse-mode AD through it is **shard-local**
under ``shard_map``; only the shared-``args`` cotangent crosses devices
(one psum from the transpose).  See ``docs/distributed.md``.
"""

from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from .controller import ControllerConfig, initial_stepsize, propose_stepsize
from .integrate import (
    SolveStats,
    _as_tuple,
    _buffer_set,
    _bwhere,
    _compose_status,
    _empty_buffer,
    _freeze_fill,
    _nonfinite_any,
    _nonfinite_rows,
    _row_tolerances,
    fixed_grid_solve,
    natural_grid_outputs,
    natural_grid_outputs_batched,
)
from .stepper import (
    error_ratio,
    maybe_flatten,
    maybe_flatten_batched,
    rk_step,
    rk_step_batched,
)
from .tableaus import Tableau

PyTree = Any


def odeint_naive(
    f: Callable,
    z0: PyTree,
    ts: jnp.ndarray,
    args: PyTree = (),
    *,
    solver: Tableau,
    rtol: float = 1e-6,
    atol: float = 1e-6,
    cfg: Optional[ControllerConfig] = None,
    trial_budget: Optional[int] = None,
    use_pallas: bool = False,
    interpolate_ts: bool = False,
    h0: Optional[jnp.ndarray] = None,
) -> Tuple[PyTree, SolveStats]:
    """Differentiable adaptive solve (naive method).

    ``trial_budget`` bounds the total number of ψ trials (accepted or
    rejected); defaults to cfg.max_steps * cfg.max_trials.  ``h0``
    overrides the Hairer initial stepsize (ignored on the fixed-grid
    fallback).

    Solve-health: non-finite trials are never accepted; once the
    stepsize rails at ``h_min`` with the trial still non-finite the
    element freezes at its last accepted state (post-failure iterations
    take the same discarded sliver trials as finished elements) and
    ``stats.status`` reports ``SolveStatus.NONFINITE_STATE``.  NOTE:
    unlike the custom-vjp methods, the naive method keeps *every* trial
    on the differentiation tape — including the non-finite one that
    tripped the guard — so gradients after a fault are not guaranteed
    finite here; pair with the train-loop skip-step guard
    (``docs/robustness.md``).

    ``use_pallas`` runs every recorded trial (step + error norm) through
    the fused flat-state kernels over the raveled state; reverse-mode AD
    goes through their custom_vjp, including the stepsize chain via the
    fused ``ratio``.

    ``interpolate_ts`` advances on the controller's natural grid and
    reads interior eval times off per-step interpolants; the
    interpolation arithmetic sits on the tape like everything else, so
    reverse-mode AD differentiates through it (including θ's dependence
    on the stepsize chain — everything stays on the naive tape).
    """
    if cfg is None:
        cfg = ControllerConfig()
    if not solver.adaptive:
        return fixed_grid_solve(solver, f, z0, ts, _as_tuple(args),
                                steps_per_interval=cfg.max_steps,
                                use_pallas=use_pallas)

    f, z0, unravel, use_pallas = maybe_flatten(f, z0, use_pallas)

    n_eval = ts.shape[0]
    tdt = ts.dtype
    budget = trial_budget if trial_budget is not None else (
        cfg.max_steps * cfg.max_trials)
    tiny = jnp.asarray(jnp.finfo(tdt).eps, tdt)
    targs = _as_tuple(args)
    karr = jnp.arange(n_eval)

    h_init = initial_stepsize(f, ts[0], z0, targs, solver.order, rtol,
                              atol) if h0 is None else h0

    ys0 = jax.tree.map(
        lambda l: jnp.zeros((n_eval,) + l.shape, l.dtype), z0)
    ys0 = jax.tree.map(lambda b, v: b.at[0].set(v), ys0, z0)

    failed0 = _nonfinite_any(
        (z0, jnp.asarray(h_init, tdt)))

    carry0 = dict(
        t=ts[0], z=z0, h=jnp.asarray(h_init, tdt),
        prev_ratio=jnp.asarray(1.0, jnp.float32),
        eval_idx=jnp.asarray(1, jnp.int32),
        n_acc=jnp.asarray(0, jnp.int32),
        failed=failed0, uflow=jnp.asarray(False),
        ys=ys0,
    )

    def body(c, _):
        # failed elements behave exactly like finished ones: frozen
        # state, discarded sliver trials until the budget runs out
        done = (c["eval_idx"] >= n_eval) | c["failed"]
        t, z, h = c["t"], c["z"], c["h"]
        t_target = ts[n_eval - 1] if interpolate_ts else \
            ts[jnp.minimum(c["eval_idx"], n_eval - 1)]
        h_min = 16.0 * tiny * jnp.maximum(jnp.abs(t), jnp.asarray(1.0, tdt))
        # done elements keep taking discarded sliver trials, but the
        # sliver is pinned to FLOAT32 eps regardless of the time dtype:
        # an ~eps(float64) step puts ratios of order eps/tol on the
        # tape, whose pow/sqrt jacobians overflow f32 and fuse into NaN
        # (a full-size h would instead evaluate f past ts[-1], where the
        # field may be singular).  In f32 time this is exactly h_min.
        h_done = 16.0 * jnp.asarray(jnp.finfo(jnp.float32).eps, tdt) \
            * jnp.maximum(jnp.abs(t), jnp.asarray(1.0, tdt))
        h_use = jnp.where(done, h_done,
                          jnp.clip(h, h_min,
                                   jnp.maximum(t_target - t, h_min)))

        # NOTE: no k0 caching here — the naive method re-records the whole
        # trial in the graph, including the first stage.
        res = rk_step(solver, f, t, z, h_use, targs,
                      use_pallas=use_pallas, err_scale=(rtol, atol),
                      dense=interpolate_ts)
        ratio = res.err_ratio if res.err_ratio is not None else \
            error_ratio(res.err, z, res.z_next, rtol, atol)
        railed = h_use <= h_min * (1 + 1e-3)
        # detection reads stop_gradiented values: the flags must not
        # add edges to the naive tape
        bad = _nonfinite_any(jax.lax.stop_gradient(res.z_next)) | \
            ~jnp.isfinite(jax.lax.stop_gradient(ratio))
        accept = (~done) & ((ratio <= 1.0) | railed) & ~bad
        fail_now = (~done) & bad & railed
        uflow_now = accept & railed & (ratio > 1.0)

        t_new = t + h_use
        hit = accept & (t_new >= t_target - 16.0 * tiny * jnp.maximum(
            jnp.abs(t_target), jnp.asarray(1.0, tdt)))

        if interpolate_ts:
            # interior eval times read off this trial's interpolant —
            # all on the tape, like everything else in the naive method
            k1 = res.k_last if solver.fsal else \
                f(t_new, res.z_next, *targs)
            ys, _, _, eval_advance = natural_grid_outputs(
                ts, karr, tiny, t, t_new, h_use, accept, hit,
                c["eval_idx"], c["ys"], z, res.z_next, res.k_first,
                k1, res.z_mid)
        else:
            ys = jax.tree.map(
                lambda b, v: b.at[c["eval_idx"]].set(
                    jnp.where(hit, v, b[jnp.minimum(c["eval_idx"],
                                                    n_eval - 1)])),
                c["ys"], res.z_next)
            eval_advance = hit.astype(jnp.int32)

        # differentiable stepsize chain: gradient flows through `ratio`
        # into h_next — the redundant graph the paper criticizes.  A
        # done element's h_next is discarded by the where below, but its
        # post-done h_min trials produce ratios ~eps(tdt)/tol whose
        # ratio^(-1/p) jacobian overflows f32 under x64 time grids and
        # XLA fusion can turn the masked inf into NaN — feed the
        # discarded computation a neutral ratio instead.  Non-finite
        # ratios get the same neutral treatment so the h chain cannot
        # absorb a NaN.
        ratio_h = jnp.where(done | bad, jnp.ones_like(ratio), ratio)
        h_next = propose_stepsize(cfg, h_use, ratio_h, c["prev_ratio"],
                                  solver.order).astype(tdt)

        c_new = dict(
            t=jnp.where(accept, t_new, t),
            z=jax.tree.map(lambda a, b: jnp.where(accept, a, b),
                           res.z_next, z),
            h=jnp.where(done, h, h_next),
            prev_ratio=jnp.where(accept, jnp.maximum(ratio, 1e-10),
                                 c["prev_ratio"]),
            eval_idx=c["eval_idx"] + eval_advance,
            n_acc=c["n_acc"] + accept.astype(jnp.int32),
            failed=c["failed"] | fail_now,
            uflow=c["uflow"] | uflow_now,
            ys=ys,
        )
        return c_new, None

    c, _ = jax.lax.scan(body, carry0, None, length=budget)
    # frozen solve: repeat the last accepted state into un-reached slots
    # (stop_gradiented — a failed element's cotangents stay off the fill)
    fill = c["failed"] & (karr >= c["eval_idx"])
    ys_filled = _freeze_fill(c["ys"], fill,
                             jax.lax.stop_gradient(c["z"]))
    ys_out = ys_filled if unravel is None else jax.vmap(unravel)(ys_filled)

    overflow = c["eval_idx"] < n_eval
    status = _compose_status(c["failed"], c["uflow"], ~overflow,
                             jnp.asarray(True))
    # interpolate mode on a non-FSAL pair pays one extra k1 eval/trial
    evals_per_trial = solver.stages + (
        1 if interpolate_ts and not solver.fsal else 0)
    stats = SolveStats(
        n_steps=jax.lax.stop_gradient(c["n_acc"]),
        n_trials=jnp.asarray(budget, jnp.int32),
        nfe=jnp.asarray(budget * evals_per_trial, jnp.int32),
        overflow=jax.lax.stop_gradient(overflow),
        status=jax.lax.stop_gradient(status),
    )
    return ys_out, stats


def odeint_naive_batched(
    f: Callable,
    z0: PyTree,
    ts: jnp.ndarray,
    args: PyTree = (),
    *,
    solver: Tableau,
    rtol: float = 1e-6,
    atol: float = 1e-6,
    cfg: Optional[ControllerConfig] = None,
    trial_budget: Optional[int] = None,
    use_pallas: bool = False,
    interpolate_ts: bool = False,
    h0: Optional[jnp.ndarray] = None,
) -> Tuple[PyTree, SolveStats]:
    """Per-sample batched naive method: ``odeint(..., batch_axis=0)``
    with direct backprop through the masked solver scan.

    ``z0`` leaves carry a leading batch dim B and ``f`` is per-sample.
    The bounded ``lax.scan`` advances every element each iteration with
    its own trial stepsize, accept/reject mask and differentiable
    stepsize chain; finished elements are where-frozen (they keep taking
    discarded h_min trials — a zero step's error norm would put sqrt(0)
    on the tape and NaN the backward pass), so reverse-mode AD through
    the scan yields each element's own discretize-then-optimize gradient —
    including the per-element stepsize-search graph the paper
    criticizes.  ``trial_budget`` bounds the scan length (shared across
    elements); defaults to cfg.max_steps * cfg.max_trials.
    ``interpolate_ts`` / ``h0`` / solve-health semantics (including the
    naive-tape gradient caveat after a fault) as in ``odeint_naive``,
    per element.
    """
    if cfg is None:
        cfg = ControllerConfig()
    if not solver.adaptive:
        raise ValueError(
            "odeint_naive_batched requires an embedded adaptive tableau; "
            "fixed grids batch losslessly through odeint_naive_fixed")

    f, z0, unravel, use_pallas = maybe_flatten_batched(f, z0, use_pallas)

    B = jax.tree.leaves(z0)[0].shape[0]
    rows = jnp.arange(B)
    n_eval = ts.shape[0]
    tdt = ts.dtype
    budget = trial_budget if trial_budget is not None else (
        cfg.max_steps * cfg.max_trials)
    tiny = jnp.asarray(jnp.finfo(tdt).eps, tdt)
    targs = _as_tuple(args)

    row_tol = _row_tolerances(rtol, atol, B)
    if h0 is None:
        if row_tol is not None:
            h_init = jax.vmap(lambda z, rt, at: initial_stepsize(
                f, ts[0], z, targs, solver.order, rt, at))(z0, *row_tol)
        else:
            h_init = jax.vmap(lambda z: initial_stepsize(
                f, ts[0], z, targs, solver.order, rtol, atol))(z0)
    else:
        h_init = jnp.broadcast_to(jnp.asarray(h0, tdt), (B,))

    ys0 = _buffer_set(_empty_buffer(z0, n_eval), 0, z0)

    failed0 = _nonfinite_rows((z0, jnp.asarray(h_init, tdt)))

    carry0 = dict(
        t=jnp.full((B,), ts[0], tdt), z=z0,
        h=jnp.asarray(h_init, tdt),
        prev_ratio=jnp.ones((B,), jnp.float32),
        eval_idx=jnp.ones((B,), jnp.int32),
        n_acc=jnp.zeros((B,), jnp.int32),
        failed=failed0, uflow=jnp.zeros((B,), bool),
        ys=ys0,
    )

    karr = jnp.arange(n_eval)

    def body(c, _):
        # failed rows behave exactly like finished ones: frozen state,
        # discarded sliver trials until the budget runs out
        done = (c["eval_idx"] >= n_eval) | c["failed"]      # (B,)
        t, z, h = c["t"], c["z"], c["h"]
        t_target = ts[n_eval - 1] if interpolate_ts else \
            ts[jnp.minimum(c["eval_idx"], n_eval - 1)]
        h_min = 16.0 * tiny * jnp.maximum(jnp.abs(t), jnp.asarray(1.0, tdt))
        # done elements keep taking discarded float32-eps sliver trials
        # (see odeint_naive): h = 0 would put sqrt(0) on the tape, an
        # ~eps(float64) sliver's ratio jacobian overflows f32, and a
        # full-size h would evaluate f past each element's ts[-1]
        h_done = 16.0 * jnp.asarray(jnp.finfo(jnp.float32).eps, tdt) \
            * jnp.maximum(jnp.abs(t), jnp.asarray(1.0, tdt))
        h_use = jnp.where(done, h_done,
                          jnp.clip(h, h_min,
                                   jnp.maximum(t_target - t, h_min)))

        # NOTE: no k0 caching here — the naive method re-records the whole
        # trial in the graph, including the first stage (per element).
        res = rk_step_batched(solver, f, t, z, h_use, targs,
                              use_pallas=use_pallas, err_scale=(rtol, atol),
                              dense=interpolate_ts)
        ratio = res.err_ratio                               # (B,)
        railed = h_use <= h_min * (1 + 1e-3)
        # detection reads stop_gradiented values: the flags must not
        # add edges to the naive tape (per element)
        bad = _nonfinite_rows(jax.lax.stop_gradient(res.z_next)) | \
            ~jnp.isfinite(jax.lax.stop_gradient(ratio))
        accept = (~done) & ((ratio <= 1.0) | railed) & ~bad
        fail_now = (~done) & bad & railed
        uflow_now = accept & railed & (ratio > 1.0)

        t_new = t + h_use
        hit = accept & (t_new >= t_target - 16.0 * tiny * jnp.maximum(
            jnp.abs(t_target), jnp.asarray(1.0, tdt)))

        if interpolate_ts:
            # per-element interior reads off each row's interpolant (all
            # on the tape); ts[-1] stays an exact landing per element
            if solver.fsal:
                k1 = res.k_last
            else:
                k1 = jax.vmap(lambda ti, zi: f(ti, zi, *targs))(
                    t_new, res.z_next)
            ys, _, _, eval_advance = natural_grid_outputs_batched(
                ts, karr, tiny, rows, t, t_new, h_use, accept, hit,
                c["eval_idx"], c["ys"], z, res.z_next, res.k_first,
                k1, res.z_mid)
        else:
            e_c = jnp.minimum(c["eval_idx"], n_eval - 1)
            ys = jax.tree.map(
                lambda b, v: b.at[e_c, rows].set(
                    _bwhere(hit, v, b[e_c, rows])),
                c["ys"], res.z_next)
            eval_advance = hit.astype(jnp.int32)

        # differentiable per-element stepsize chain: gradient flows
        # through each element's own `ratio` into its h_next.  done
        # rows get a neutral ratio (see odeint_naive: their h_next is
        # discarded, and the h_min-trial ratio's pow jacobian would
        # overflow f32 under x64 time grids).  Non-finite ratios get the
        # same neutral treatment so the h chain cannot absorb a NaN.
        ratio_h = jnp.where(done | bad, jnp.ones_like(ratio), ratio)
        h_next = propose_stepsize(cfg, h_use, ratio_h, c["prev_ratio"],
                                  solver.order).astype(tdt)

        c_new = dict(
            t=jnp.where(accept, t_new, t),
            z=jax.tree.map(lambda a, b: _bwhere(accept, a, b), res.z_next, z),
            h=jnp.where(done, h, h_next),
            prev_ratio=jnp.where(accept, jnp.maximum(ratio, 1e-10),
                                 c["prev_ratio"]),
            eval_idx=c["eval_idx"] + eval_advance,
            n_acc=c["n_acc"] + accept.astype(jnp.int32),
            failed=c["failed"] | fail_now,
            uflow=c["uflow"] | uflow_now,
            ys=ys,
        )
        return c_new, None

    c, _ = jax.lax.scan(body, carry0, None, length=budget)
    fill = c["failed"][None, :] & (karr[:, None] >= c["eval_idx"][None, :])
    ys_filled = _freeze_fill(c["ys"], fill,
                             jax.lax.stop_gradient(c["z"]))
    ys_out = ys_filled if unravel is None else \
        jax.vmap(jax.vmap(unravel))(ys_filled)

    overflow = c["eval_idx"] < n_eval
    status = _compose_status(c["failed"], c["uflow"], ~overflow,
                             jnp.ones((B,), bool))
    evals_per_trial = solver.stages + (
        1 if interpolate_ts and not solver.fsal else 0)
    stats = SolveStats(
        n_steps=jax.lax.stop_gradient(c["n_acc"]),
        n_trials=jnp.full((B,), budget, jnp.int32),
        nfe=jnp.full((B,), budget * evals_per_trial, jnp.int32),
        overflow=jax.lax.stop_gradient(overflow),
        status=jax.lax.stop_gradient(status),
    )
    return ys_out, stats


def odeint_naive_fixed(
    f: Callable,
    z0: PyTree,
    ts: jnp.ndarray,
    args: PyTree = (),
    *,
    solver: Tableau,
    steps_per_interval: int = 8,
    use_pallas: bool = False,
) -> Tuple[PyTree, SolveStats]:
    """Naive fixed-grid: plain reverse-mode AD through the scan (stores all
    stage intermediates — O(N_f · N_t) memory, no recompute)."""
    return fixed_grid_solve(solver, f, z0, ts, _as_tuple(args),
                            steps_per_interval, use_pallas=use_pallas)
