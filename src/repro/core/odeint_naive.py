"""The naive method — direct back-propagation through the ODE solver.

The paper's second baseline (Sec. 3.3): every solver operation, *including
the stepsize search*, stays on the differentiation path.  The stepsize
update chain  h_{i+1} = h_i · decay(ê_i)  is itself differentiated, so the
computation graph has depth O(N_f · N_t · m) and reverse-mode AD stores the
stage intermediates of every trial — the paper's memory blow-up, realized
in JAX as scan-carried residuals over the full trial budget.

JAX cannot reverse-differentiate a dynamic-trip-count ``while_loop``, so the
adaptive naive solver is a *bounded* ``lax.scan`` over the flattened
trial/accept loop with where-masking once integration finishes — the
standard fixed-budget encoding; the budget (max_steps × max_trials) plays
the role of the tape length.
"""

from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from .controller import ControllerConfig, initial_stepsize, propose_stepsize
from .integrate import (
    SolveStats,
    _buffer_set,
    _bwhere,
    _empty_buffer,
    fixed_grid_solve,
)
from .stepper import (
    error_ratio,
    maybe_flatten,
    maybe_flatten_batched,
    rk_step,
    rk_step_batched,
)
from .tableaus import Tableau

PyTree = Any


def _as_tuple(args) -> Tuple:
    return args if isinstance(args, tuple) else (args,)


def odeint_naive(
    f: Callable,
    z0: PyTree,
    ts: jnp.ndarray,
    args: PyTree = (),
    *,
    solver: Tableau,
    rtol: float = 1e-6,
    atol: float = 1e-6,
    cfg: Optional[ControllerConfig] = None,
    trial_budget: Optional[int] = None,
    use_pallas: bool = False,
) -> Tuple[PyTree, SolveStats]:
    """Differentiable adaptive solve (naive method).

    ``trial_budget`` bounds the total number of ψ trials (accepted or
    rejected); defaults to cfg.max_steps * cfg.max_trials.

    ``use_pallas`` runs every recorded trial (step + error norm) through
    the fused flat-state kernels over the raveled state; reverse-mode AD
    goes through their custom_vjp, including the stepsize chain via the
    fused ``ratio``.
    """
    if cfg is None:
        cfg = ControllerConfig()
    if not solver.adaptive:
        return fixed_grid_solve(solver, f, z0, ts, _as_tuple(args),
                                steps_per_interval=cfg.max_steps,
                                use_pallas=use_pallas)

    f, z0, unravel, use_pallas = maybe_flatten(f, z0, use_pallas)

    n_eval = ts.shape[0]
    tdt = ts.dtype
    budget = trial_budget if trial_budget is not None else (
        cfg.max_steps * cfg.max_trials)
    tiny = jnp.asarray(jnp.finfo(tdt).eps, tdt)
    targs = _as_tuple(args)

    h_init = initial_stepsize(f, ts[0], z0, targs, solver.order, rtol, atol)

    ys0 = jax.tree.map(
        lambda l: jnp.zeros((n_eval,) + l.shape, l.dtype), z0)
    ys0 = jax.tree.map(lambda b, v: b.at[0].set(v), ys0, z0)

    carry0 = dict(
        t=ts[0], z=z0, h=jnp.asarray(h_init, tdt),
        prev_ratio=jnp.asarray(1.0, jnp.float32),
        eval_idx=jnp.asarray(1, jnp.int32),
        n_acc=jnp.asarray(0, jnp.int32),
        ys=ys0,
    )

    def body(c, _):
        done = c["eval_idx"] >= n_eval
        t, z, h = c["t"], c["z"], c["h"]
        t_target = ts[jnp.minimum(c["eval_idx"], n_eval - 1)]
        h_min = 16.0 * tiny * jnp.maximum(jnp.abs(t), jnp.asarray(1.0, tdt))
        h_use = jnp.clip(h, h_min, jnp.maximum(t_target - t, h_min))

        # NOTE: no k0 caching here — the naive method re-records the whole
        # trial in the graph, including the first stage.
        res = rk_step(solver, f, t, z, h_use, targs,
                      use_pallas=use_pallas, err_scale=(rtol, atol))
        ratio = res.err_ratio if res.err_ratio is not None else \
            error_ratio(res.err, z, res.z_next, rtol, atol)
        accept = (~done) & ((ratio <= 1.0) | (h_use <= h_min * (1 + 1e-3)))

        t_new = t + h_use
        hit = accept & (t_new >= t_target - 16.0 * tiny * jnp.maximum(
            jnp.abs(t_target), jnp.asarray(1.0, tdt)))

        ys = jax.tree.map(
            lambda b, v: b.at[c["eval_idx"]].set(
                jnp.where(hit, v, b[jnp.minimum(c["eval_idx"],
                                                n_eval - 1)])),
            c["ys"], res.z_next)

        # differentiable stepsize chain: gradient flows through `ratio`
        # into h_next — the redundant graph the paper criticizes.
        h_next = propose_stepsize(cfg, h_use, ratio, c["prev_ratio"],
                                  solver.order).astype(tdt)

        c_new = dict(
            t=jnp.where(accept, t_new, t),
            z=jax.tree.map(lambda a, b: jnp.where(accept, a, b),
                           res.z_next, z),
            h=jnp.where(done, h, h_next),
            prev_ratio=jnp.where(accept, jnp.maximum(ratio, 1e-10),
                                 c["prev_ratio"]),
            eval_idx=c["eval_idx"] + hit.astype(jnp.int32),
            n_acc=c["n_acc"] + accept.astype(jnp.int32),
            ys=ys,
        )
        return c_new, None

    c, _ = jax.lax.scan(body, carry0, None, length=budget)
    ys_out = c["ys"] if unravel is None else jax.vmap(unravel)(c["ys"])

    stats = SolveStats(
        n_steps=jax.lax.stop_gradient(c["n_acc"]),
        n_trials=jnp.asarray(budget, jnp.int32),
        nfe=jnp.asarray(budget * solver.stages, jnp.int32),
        overflow=jax.lax.stop_gradient(c["eval_idx"] < n_eval),
    )
    return ys_out, stats


def odeint_naive_batched(
    f: Callable,
    z0: PyTree,
    ts: jnp.ndarray,
    args: PyTree = (),
    *,
    solver: Tableau,
    rtol: float = 1e-6,
    atol: float = 1e-6,
    cfg: Optional[ControllerConfig] = None,
    trial_budget: Optional[int] = None,
    use_pallas: bool = False,
) -> Tuple[PyTree, SolveStats]:
    """Per-sample batched naive method: ``odeint(..., batch_axis=0)``
    with direct backprop through the masked solver scan.

    ``z0`` leaves carry a leading batch dim B and ``f`` is per-sample.
    The bounded ``lax.scan`` advances every element each iteration with
    its own trial stepsize, accept/reject mask and differentiable
    stepsize chain; finished elements are where-frozen (they keep taking
    discarded h_min trials — a zero step's error norm would put sqrt(0)
    on the tape and NaN the backward pass), so reverse-mode AD through
    the scan yields each element's own discretize-then-optimize gradient —
    including the per-element stepsize-search graph the paper
    criticizes.  ``trial_budget`` bounds the scan length (shared across
    elements); defaults to cfg.max_steps * cfg.max_trials.
    """
    if cfg is None:
        cfg = ControllerConfig()
    if not solver.adaptive:
        raise ValueError(
            "odeint_naive_batched requires an embedded adaptive tableau; "
            "fixed grids batch losslessly through odeint_naive_fixed")

    f, z0, unravel, use_pallas = maybe_flatten_batched(f, z0, use_pallas)

    B = jax.tree.leaves(z0)[0].shape[0]
    rows = jnp.arange(B)
    n_eval = ts.shape[0]
    tdt = ts.dtype
    budget = trial_budget if trial_budget is not None else (
        cfg.max_steps * cfg.max_trials)
    tiny = jnp.asarray(jnp.finfo(tdt).eps, tdt)
    targs = _as_tuple(args)

    h_init = jax.vmap(lambda z: initial_stepsize(
        f, ts[0], z, targs, solver.order, rtol, atol))(z0)

    ys0 = _buffer_set(_empty_buffer(z0, n_eval), 0, z0)

    carry0 = dict(
        t=jnp.full((B,), ts[0], tdt), z=z0,
        h=jnp.asarray(h_init, tdt),
        prev_ratio=jnp.ones((B,), jnp.float32),
        eval_idx=jnp.ones((B,), jnp.int32),
        n_acc=jnp.zeros((B,), jnp.int32),
        ys=ys0,
    )

    def body(c, _):
        done = c["eval_idx"] >= n_eval                      # (B,)
        t, z, h = c["t"], c["z"], c["h"]
        t_target = ts[jnp.minimum(c["eval_idx"], n_eval - 1)]
        h_min = 16.0 * tiny * jnp.maximum(jnp.abs(t), jnp.asarray(1.0, tdt))
        # done elements keep stepping with h_min (their carry is frozen by
        # the where-masks below) rather than h = 0: a zero step has zero
        # error, and backprop through sqrt(0) in the error norm is NaN
        h_use = jnp.clip(h, h_min, jnp.maximum(t_target - t, h_min))

        # NOTE: no k0 caching here — the naive method re-records the whole
        # trial in the graph, including the first stage (per element).
        res = rk_step_batched(solver, f, t, z, h_use, targs,
                              use_pallas=use_pallas, err_scale=(rtol, atol))
        ratio = res.err_ratio                               # (B,)
        accept = (~done) & ((ratio <= 1.0) | (h_use <= h_min * (1 + 1e-3)))

        t_new = t + h_use
        hit = accept & (t_new >= t_target - 16.0 * tiny * jnp.maximum(
            jnp.abs(t_target), jnp.asarray(1.0, tdt)))

        e_c = jnp.minimum(c["eval_idx"], n_eval - 1)
        ys = jax.tree.map(
            lambda b, v: b.at[e_c, rows].set(_bwhere(hit, v, b[e_c, rows])),
            c["ys"], res.z_next)

        # differentiable per-element stepsize chain: gradient flows
        # through each element's own `ratio` into its h_next.
        h_next = propose_stepsize(cfg, h_use, ratio, c["prev_ratio"],
                                  solver.order).astype(tdt)

        c_new = dict(
            t=jnp.where(accept, t_new, t),
            z=jax.tree.map(lambda a, b: _bwhere(accept, a, b), res.z_next, z),
            h=jnp.where(done, h, h_next),
            prev_ratio=jnp.where(accept, jnp.maximum(ratio, 1e-10),
                                 c["prev_ratio"]),
            eval_idx=c["eval_idx"] + hit.astype(jnp.int32),
            n_acc=c["n_acc"] + accept.astype(jnp.int32),
            ys=ys,
        )
        return c_new, None

    c, _ = jax.lax.scan(body, carry0, None, length=budget)
    ys_out = c["ys"] if unravel is None else \
        jax.vmap(jax.vmap(unravel))(c["ys"])

    stats = SolveStats(
        n_steps=jax.lax.stop_gradient(c["n_acc"]),
        n_trials=jnp.full((B,), budget, jnp.int32),
        nfe=jnp.full((B,), budget * solver.stages, jnp.int32),
        overflow=jax.lax.stop_gradient(c["eval_idx"] < n_eval),
    )
    return ys_out, stats


def odeint_naive_fixed(
    f: Callable,
    z0: PyTree,
    ts: jnp.ndarray,
    args: PyTree = (),
    *,
    solver: Tableau,
    steps_per_interval: int = 8,
    use_pallas: bool = False,
) -> Tuple[PyTree, SolveStats]:
    """Naive fixed-grid: plain reverse-mode AD through the scan (stores all
    stage intermediates — O(N_f · N_t) memory, no recompute)."""
    return fixed_grid_solve(solver, f, z0, ts, _as_tuple(args),
                            steps_per_interval, use_pallas=use_pallas)
