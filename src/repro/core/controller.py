"""PI stepsize controller for adaptive embedded RK solvers.

Implements the standard proportional-integral controller (Hairer & Wanner,
"Solving ODEs II", IV.2) used by production solvers: the next stepsize is

    h_next = h * clip(safety * ratio^{-k_I} * prev_ratio^{k_P}, dfac, ifac)

with ratio the scaled error norm of the current trial.  This generalizes the
paper's ``h <- h * decay_factor(e_hat)`` (Algorithm 1): the pure-P controller
is recovered with pi_coeff=0.  Also provides the classical initial-stepsize
selection of Hairer I.4 (algorithm ``hinit``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    """PI stepsize-controller settings + the solve's step/trial budgets.

    ``max_steps`` bounds *accepted* steps (= checkpoint-buffer capacity,
    the paper's N_t); ``max_trials`` bounds the inner stepsize search per
    step (the paper's m), so one solve performs at most ``max_steps *
    max_trials`` ψ trials.
    """
    safety: float = 0.9
    min_factor: float = 0.2     # max shrink per retry
    max_factor: float = 10.0    # max growth after accept
    pi_coeff: float = 0.04      # k_P (integral-of-log smoothing); 0 = plain P
    max_steps: int = 256        # checkpoint-buffer capacity (paper's N_t bound)
    max_trials: int = 12        # bound on the paper's m (inner search)


def propose_stepsize(cfg: ControllerConfig, h, ratio, prev_ratio, order: int):
    """Next stepsize after a trial with scaled error ``ratio``.

    Used both for shrink-on-reject and grow-on-accept; the PI term uses the
    previous accepted step's ratio.
    """
    order = float(order)
    k_i = 1.0 / order
    k_p = cfg.pi_coeff
    # guard against ratio == 0 (exact solution) -> max growth
    ratio = jnp.maximum(ratio, 1e-10)
    prev_ratio = jnp.maximum(prev_ratio, 1e-10)
    factor = cfg.safety * ratio ** (-k_i) * prev_ratio ** k_p
    factor = jnp.clip(factor, cfg.min_factor, cfg.max_factor)
    return h * factor


def initial_stepsize(f, t0, z0, args, order: int, rtol: float, atol: float):
    """Hairer I.4 'starting step size' heuristic, pytree-valued states."""
    def _norm(x):
        leaves = jax.tree.leaves(x)
        sq = sum(jnp.sum((l.astype(jnp.float32)) ** 2) for l in leaves)
        n = sum(l.size for l in leaves)
        return jnp.sqrt(sq / n)

    scale = jax.tree.map(
        lambda z: atol + rtol * jnp.abs(z), z0)

    f0 = f(t0, z0, *args)
    d0 = _norm(jax.tree.map(lambda z, s: z / s, z0, scale))
    d1 = _norm(jax.tree.map(lambda g, s: g / s, f0, scale))
    h0 = jnp.where((d0 < 1e-5) | (d1 < 1e-5), 1e-6, 0.01 * d0 / d1)

    z1 = jax.tree.map(lambda z, g: z + h0 * g, z0, f0)
    f1 = f(t0 + h0, z1, *args)
    d2 = _norm(jax.tree.map(lambda a, b, s: (a - b) / s, f1, f0, scale)) / h0
    dmax = jnp.maximum(d1, d2)
    # Hairer I.4 step (f): h1 = (0.01 / max(d1, d2))^(1/(p+1)) — the
    # exponent is 1/(order + 1), matching the local error O(h^{p+1})
    h1 = jnp.where(
        dmax <= 1e-15,
        jnp.maximum(1e-6, h0 * 1e-3),
        (0.01 / dmax) ** (1.0 / (float(order) + 1.0)),
    )
    return jnp.minimum(100.0 * h0, h1)
