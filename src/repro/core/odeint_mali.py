"""MALI — reversible asynchronous-leapfrog gradients in O(1) state memory.

The fourth gradient method of the paper-family matrix (MALI, Zhuang et
al. 2021 — the ACA authors' successor; see also the symplectic-adjoint
variant of Matsubara et al. 2021):

Forward pass:
  * integrate with the asynchronous-leapfrog (ALF) pair stepper
    (``integrate.mali_adaptive_solve``): paired state (z, v), one field
    evaluation per ψ trial, the same adaptive stepsize search as the RK
    engines — structurally outside differentiation in the while_loop;
  * keep **no state checkpoints at all**: only the accepted scalar grid
    {t_i, h_i, out_idx_i} and the single terminal lattice pair
    (z_N, v_N) — memory O(N_t) *scalars* + O(dim), versus ACA's
    O(N_t · dim) trajectory checkpoint (segmented ACA's O(√N_t · dim)).

Backward pass:
  * walk the saved scalar grid in reverse; for each interval *invert*
    the accepted ALF step from the current pair
    (``stepper.alf_step_inverse``) — the pair is carried on a
    fixed-point integer lattice, so the reconstructed (z_i, v_i) is the
    forward pair **bitwise** (see the ALF section of ``stepper.py``);
  * back-propagate through the differentiable float twin
    ``alf_step_float`` linearized at the reconstructed pair with
    ``jax.vjp``, carrying the adjoint pair (λ_z, λ_v) and accumulating
    dL/dθ; output cotangents are injected where ``out_idx`` marks an
    eval-time landing;
  * close over the initial velocity: v_0 = f(t_0, z_0) routes λ_v's
    remainder into dL/dz_0 and dL/dθ through one last vjp of f.

Because the reverse reconstruction is exact, the gradient is the true
discretize-then-optimize gradient of the forward map (up to the
per-operation lattice quantum, which the straight-through float twin
treats as identity — at or below one float ulp at the state's scale),
with **no reverse-time re-integration drift** (the adjoint method's
Theorem 3.2 pathology) and no per-step state storage (ACA's memory
cost).  Each backward step costs one inverse ALF step plus one
vjp-replayed float step ≈ 3 field evaluations.

Sharding contract (relied on by ``odeint(..., mesh=...)``): the batched
reverse reconstruction inverts each row's own lattice pair along its
own scalar grid — no cross-element coupling — so the sweep runs
**shard-local** under ``shard_map``, with the shared-``args`` cotangent
psummed once by the transpose.  See ``docs/distributed.md``.

See ``docs/method-selection.md`` for where MALI wins and loses against
aca / aca+segments / adjoint / naive.
"""

from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from .controller import ControllerConfig
from .integrate import (
    MaliGrid,
    SolveStats,
    _as_tuple,
    _buffer_slot,
    _bwhere_tree,
    _mask_failed_cotangents,
    batched_mali_adaptive_solve,
    mali_adaptive_solve,
)
from .stepper import (
    alf_step_float,
    alf_step_float_batched,
    alf_step_inverse,
    alf_step_inverse_batched,
    lattice_decode,
    maybe_flatten,
    maybe_flatten_batched,
)

PyTree = Any


def _mali_backward_sweep(
    f: Callable,
    grid: MaliGrid,
    z0: PyTree,
    args: PyTree,
    g_ys: PyTree,
    ts: jnp.ndarray,
    use_pallas: bool = False,
):
    """Inverting reverse sweep from the terminal pair.

    Returns (dL/dz0, dL/dargs).  ``g_ys`` are the output cotangents, one
    slot per eval time, injected into λ_z where the grid's ``out_idx``
    marks the landing.  No state buffer is read — each (z_i, v_i) is
    reconstructed bitwise by ``alf_step_inverse`` before its local vjp.
    """
    targs = _as_tuple(args)
    n_steps = grid.n

    lam_z0 = jax.tree.map(jnp.zeros_like, _buffer_slot(g_ys, 0))
    lam_v0 = jax.tree.map(jnp.zeros_like, lam_z0)
    gargs0 = jax.tree.map(jnp.zeros_like, args)

    def body(j, carry):
        zq, vq, lam_z, lam_v, gargs = carry
        i = n_steps - 1 - j
        t_i, h_i, oi = grid.t[i], grid.h[i], grid.out_idx[i]

        # inject the cotangent of any output landing on this interval's
        # endpoint:  λ_z(t_{i+1}) += ∂J/∂y_k
        def add_out(lam):
            return jax.tree.map(lambda l, g: l + g[oi], lam, g_ys)

        lam_z = jax.lax.cond(oi >= 0, add_out, lambda l: l, lam_z)

        # exact reconstruction of the interval-start pair, then one
        # local float vjp linearized at it (the local graph is freed
        # each iteration — same depth profile as the ACA sweep)
        zq_p, vq_p = alf_step_inverse(f, t_i, h_i, zq, vq,
                                      grid.scale_exp, z0, targs)
        z_p = lattice_decode(zq_p, grid.scale_exp, z0)
        v_p = lattice_decode(vq_p, grid.scale_exp, z0)
        _, vjp_fn = jax.vjp(
            lambda z, v, a: alf_step_float(f, t_i, h_i, z, v,
                                           _as_tuple(a),
                                           use_pallas=use_pallas),
            z_p, v_p, args)
        dz, dv, da = vjp_fn((lam_z, lam_v))
        gargs = jax.tree.map(jnp.add, gargs, da)
        return (zq_p, vq_p, dz, dv, gargs)

    _, _, lam_z, lam_v, gargs = jax.lax.fori_loop(
        0, n_steps, body, (grid.zT, grid.vT, lam_z0, lam_v0, gargs0))

    # initial-velocity closure: v0 = f(t0, z0) is part of the forward
    # map, so λ_v's remainder flows into z0 and θ through f's vjp
    _, vjp0 = jax.vjp(lambda z, a: f(ts[0], z, *_as_tuple(a)), z0, args)
    dz_v, da_v = vjp0(lam_v)
    dz0 = jax.tree.map(lambda l, d, g: l + d + g[0], lam_z, dz_v, g_ys)
    gargs = jax.tree.map(jnp.add, gargs, da_v)
    return dz0, gargs


def _mali_backward_sweep_batched(
    f: Callable,
    grid: MaliGrid,
    z0: PyTree,
    args: PyTree,
    g_ys: PyTree,
    ts: jnp.ndarray,
    use_pallas: bool = False,
):
    """Per-element inverting reverse sweep: each batch element unwinds
    *its own* accepted grid from its own terminal pair.

    Scalar grids are (B, S) rows, ``g_ys`` leaves (n_eval, B, ...).  The
    shared ``fori_loop`` runs max(n_b) iterations; element b inverts its
    step n_b − 1 − j at iteration j and is frozen once j ≥ n_b.  An
    h = 0 ALF step is *not* the identity in v (the reflection still
    fires), so — unlike the RK sweeps — freezing is pure masking: the
    lattice pair is where-held (bit-stable integer select) and frozen
    rows' incoming cotangents are zeroed before the vjp, so their
    (finite) local Jacobians contribute exactly 0 to the shared dL/dθ.
    Returns (dL/dz0 (B, ...), dL/dargs summed over the batch).
    """
    targs = _as_tuple(args)
    n_steps = grid.n
    B = n_steps.shape[0]
    rows = jnp.arange(B)
    hdt = grid.h.dtype
    S = grid.t.shape[1]

    lam_z0 = jax.tree.map(jnp.zeros_like, _buffer_slot(g_ys, 0))    # (B, ...)
    lam_v0 = jax.tree.map(jnp.zeros_like, lam_z0)
    gargs0 = jax.tree.map(jnp.zeros_like, args)
    n_max = jnp.max(n_steps)

    def body(j, carry):
        zq, vq, lam_z, lam_v, gargs = carry
        i = n_steps - 1 - j                  # (B,), negative when done
        live = i >= 0
        i_c = jnp.clip(i, 0, S - 1)
        t_i = grid.t[rows, i_c]
        h_i = jnp.where(live, grid.h[rows, i_c], jnp.zeros((), hdt))
        oi = jnp.where(live, grid.out_idx[rows, i_c], -1)

        # per-element output-cotangent injection at eval-time landings
        oi_c = jnp.maximum(oi, 0)
        lam_z = jax.tree.map(
            lambda l, g: l + jnp.where(
                (oi >= 0).reshape((-1,) + (1,) * (l.ndim - 1)),
                g[oi_c, rows], jnp.zeros_like(l)),
            lam_z, g_ys)

        inv_z, inv_v = alf_step_inverse_batched(
            f, t_i, h_i, zq, vq, grid.scale_exp, z0, targs)
        zq = _bwhere_tree(live, inv_z, zq)
        vq = _bwhere_tree(live, inv_v, vq)

        z_p = lattice_decode(zq, grid.scale_exp, z0)
        v_p = lattice_decode(vq, grid.scale_exp, z0)
        # frozen rows: zero their incoming cotangents so the shared
        # dargs accumulates exactly 0 from them (vjp is linear in the
        # cotangent), then hold their λ through the write-back
        zmask = lambda l: _bwhere_tree(live, l, jax.tree.map(
            jnp.zeros_like, l))
        _, vjp_fn = jax.vjp(
            lambda z, v, a: alf_step_float_batched(
                f, t_i, h_i, z, v, _as_tuple(a), use_pallas=use_pallas),
            z_p, v_p, args)
        dz, dv, da = vjp_fn((zmask(lam_z), zmask(lam_v)))
        lam_z = _bwhere_tree(live, dz, lam_z)
        lam_v = _bwhere_tree(live, dv, lam_v)
        gargs = jax.tree.map(jnp.add, gargs, da)
        return (zq, vq, lam_z, lam_v, gargs)

    _, _, lam_z, lam_v, gargs = jax.lax.fori_loop(
        0, n_max, body, (grid.zT, grid.vT, lam_z0, lam_v0, gargs0))

    # initial-velocity closure, per element; args cotangent sums over
    # the batch (shared parameters)
    _, vjp0 = jax.vjp(
        lambda z, a: jax.vmap(
            lambda zi: f(ts[0], zi, *_as_tuple(a)))(z), z0, args)
    dz_v, da_v = vjp0(lam_v)
    dz0 = jax.tree.map(lambda l, d, g: l + d + g[0], lam_z, dz_v, g_ys)
    gargs = jax.tree.map(jnp.add, gargs, da_v)
    return dz0, gargs


def odeint_mali(
    f: Callable,
    z0: PyTree,
    ts: jnp.ndarray,
    args: PyTree = (),
    *,
    rtol: float = 1e-6,
    atol: float = 1e-6,
    cfg: Optional[ControllerConfig] = None,
    h0: Optional[jnp.ndarray] = None,
    use_pallas: bool = False,
) -> Tuple[PyTree, SolveStats]:
    """Solve dz/dt = f(t, z, *args) with MALI gradients (O(1) state
    memory, exact reverse reconstruction).

    Returns (ys, stats) with ys stacked over ``ts`` (ys[0] = z0).
    Differentiable w.r.t. ``z0`` and ``args``; ``ts`` is constant.  The
    integrator is the 2nd-order asynchronous-leapfrog pair stepper —
    there is no RK tableau to choose (``odeint`` exposes this as
    ``solver="alf"``, the only pairing ``grad_method="mali"`` accepts).

    ``use_pallas`` ravels the state once per solve (``maybe_flatten``
    fallback rules apply) and runs the backward replay's half-drifts
    through the fused ``rk_stage_increment`` kernel; the forward lattice
    updates are single-pass elementwise integer arithmetic either way.
    """
    if cfg is None:
        cfg = ControllerConfig()

    f, z0, unravel, use_pallas = maybe_flatten(f, z0, use_pallas)

    # ``ts`` threaded as an explicit custom_vjp argument (closures over
    # trace-time values are illegal inside scan/grad), as in odeint_aca.
    @jax.custom_vjp
    def solve(z0, args, ts):
        ys, _, stats = mali_adaptive_solve(
            f, z0, ts, _as_tuple(args), rtol, atol, cfg, h0=h0)
        return ys, stats

    def solve_fwd(z0, args, ts):
        ys, grid, stats = mali_adaptive_solve(
            f, z0, ts, _as_tuple(args), rtol, atol, cfg, h0=h0)
        return (ys, stats), (grid, z0, args, ts, stats.status)

    def solve_bwd(res, cot):
        grid, z0, args, ts, status = res
        g_ys, _g_stats = cot  # stats are integer outputs; cotangent ignored
        g_ys = _mask_failed_cotangents(g_ys, status)
        dz0, dargs = _mali_backward_sweep(
            f, grid, z0, args, g_ys, ts, use_pallas=use_pallas)
        return dz0, dargs, jnp.zeros_like(ts)

    solve.defvjp(solve_fwd, solve_bwd)
    ys, stats = solve(z0, args, ts)
    if unravel is not None:
        ys = jax.vmap(unravel)(ys)
    return ys, stats


def odeint_mali_batched(
    f: Callable,
    z0: PyTree,
    ts: jnp.ndarray,
    args: PyTree = (),
    *,
    rtol: float = 1e-6,
    atol: float = 1e-6,
    cfg: Optional[ControllerConfig] = None,
    h0: Optional[jnp.ndarray] = None,
    use_pallas: bool = False,
) -> Tuple[PyTree, SolveStats]:
    """Per-sample batched MALI: ``odeint(..., batch_axis=0,
    grad_method="mali")``.

    ``z0`` leaves carry a leading batch dim B and ``f`` is the
    per-sample vector field.  Forward: ``batched_mali_adaptive_solve``
    (per-element controllers, per-element scalar grids, per-element
    lattices).  Backward: each element's grid is unwound by inverting
    its own accepted steps from its own terminal pair — the per-element
    discretize-then-optimize property holds with zero per-step state
    storage, and outputs/grids match ``jax.vmap`` of the solo solver
    (bit-equal in the tested configurations).  Returns (ys, stats) with
    ys leaves (len(ts), B, ...) and per-element stats.
    """
    if cfg is None:
        cfg = ControllerConfig()

    f, z0, unravel, use_pallas = maybe_flatten_batched(f, z0, use_pallas)

    @jax.custom_vjp
    def solve(z0, args, ts):
        ys, _, stats = batched_mali_adaptive_solve(
            f, z0, ts, _as_tuple(args), rtol, atol, cfg, h0=h0)
        return ys, stats

    def solve_fwd(z0, args, ts):
        ys, grid, stats = batched_mali_adaptive_solve(
            f, z0, ts, _as_tuple(args), rtol, atol, cfg, h0=h0)
        return (ys, stats), (grid, z0, args, ts, stats.status)

    def solve_bwd(res, cot):
        grid, z0, args, ts, status = res
        g_ys, _g_stats = cot
        g_ys = _mask_failed_cotangents(g_ys, status, batched=True)
        dz0, dargs = _mali_backward_sweep_batched(
            f, grid, z0, args, g_ys, ts, use_pallas=use_pallas)
        return dz0, dargs, jnp.zeros_like(ts)

    solve.defvjp(solve_fwd, solve_bwd)
    ys, stats = solve(z0, args, ts)
    if unravel is not None:
        ys = jax.vmap(jax.vmap(unravel))(ys)
    return ys, stats
