"""Continuous-depth (NODE) block for model stacks.

The paper's ResNet→NODE transformation (Eq. 30 → Eq. 31): a residual block
``y = x + f(x, θ)`` becomes an ODE block ``z(1) = z(0) + ∫₀¹ f(z(t), θ) dt``
with the *same* parameter count.  Here ``f`` is any per-layer apply function
(a transformer block, conv block, ...) and the integral is solved with the
configured solver + gradient method — ACA by default.

For multi-pod lowering, NODE mode supports two regimes:

* ``adaptive`` — HeunEuler/RK23/RK45 with a dynamic (while_loop) trip
  count; legal under jit/pjit, used for single-host training exactly like
  the paper.
* ``fixed``   — a static grid (odeint_aca_fixed): static step count, the
  regime used for the 512-device dry-run and at pod scale where a static
  schedule keeps collectives deterministic across hosts (a straggler/
  determinism requirement, not a correctness one).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from .api import odeint_final
from .integrate import SolveStats

PyTree = Any


@dataclasses.dataclass(frozen=True)
class NodeConfig:
    """Solver/gradient configuration of one continuous-depth (NODE) block.

    Defaults follow the paper's training setup (HeunEuler, ACA,
    rtol=atol=1e-2).  ``regime`` picks dynamic adaptive stepping vs the
    static fixed grid used at pod scale; ``use_pallas`` enables the
    fused flat-state solver kernels; ``batch_axis`` turns on per-sample
    batched solving; ``checkpoint_segments`` bounds the ACA trajectory-
    checkpoint memory to K state snapshots per solve (see ``odeint``).

    ``grad_method="mali"`` switches the block to the reversible
    asynchronous-leapfrog integrator (O(1)-state-memory exact-reverse
    gradients — ``solver`` is then forced to ``"alf"``, the only legal
    pairing); it supports only the ``adaptive`` regime (the reversible
    pair stepper has no fixed-grid mode) and no ``checkpoint_segments``
    (there is nothing to segment).  See ``docs/method-selection.md``.
    """
    enabled: bool = False
    solver: str = "heun_euler"      # the paper trains with HeunEuler
    grad_method: str = "aca"
    rtol: float = 1e-2              # paper Appendix D: rtol=atol=1e-2
    atol: float = 1e-2
    max_steps: int = 32
    steps_per_interval: int = 4     # fixed-grid regime
    regime: str = "adaptive"        # adaptive | fixed
    # integration window [t0, t1]; t0 > t1 runs the block in REVERSE
    # time (odeint's descending-ts path) — e.g. inverting a flow or
    # stacking forward/backward blocks
    t0: float = 0.0
    t1: float = 1.0
    use_pallas: bool = False        # fused flat-state solver kernels
    # per-sample batched solving: axis of z0 carrying the batch (None =
    # lockstep).  With a batch axis every sample in the block's input
    # integrates on its own adaptive grid — see odeint(batch_axis=...).
    batch_axis: Optional[int] = None
    # segmented O(K)-state ACA checkpointing (adaptive regime, ACA
    # only): int K, "auto" (= ceil(sqrt(max_steps))) or None for the
    # classic full buffer.  Gradients are bit-identical either way —
    # this is purely a memory/recompute trade — see odeint()
    checkpoint_segments: Optional[Any] = None
    # solve-health policy: "status" (default, report via stats.status),
    # "warn" (jax.debug.print on failure) or "raise" (checkify check —
    # functionalize jitted callers with checkify.checkify); see
    # docs/robustness.md
    on_failure: str = "status"
    # jax.sharding.Mesh to shard the batch over (requires batch_axis):
    # the block's solve runs shard_map-ed over the mesh's data axes —
    # per-device adaptive trip counts, shard-local backward sweeps, one
    # psum on the shared-params cotangent.  See docs/distributed.md.
    mesh: Optional[Any] = None
    # AxisRules override for the mesh's batch-partition axes (None =
    # DEFAULT_TRAIN_RULES: "batch" -> ("pod", "data"))
    shard_rules: Optional[Any] = None


def node_block_apply(
    block_fn: Callable[[PyTree, PyTree, jnp.ndarray], PyTree],
    params: PyTree,
    z0: PyTree,
    cfg: NodeConfig,
) -> PyTree:
    """z(t1) = z(0) + ∫ f(z, t; θ) dt with ACA/adjoint/naive gradients.

    ``block_fn(params, z, t) -> dz/dt`` must preserve the shape/dtype of z.
    """

    def f(t, z, p):
        return block_fn(p, z, t)

    if cfg.grad_method == "mali" and cfg.regime == "fixed":
        raise ValueError(
            "NodeConfig(grad_method='mali', regime='fixed'): the "
            "reversible pair integrator is adaptive-only — use "
            "regime='adaptive', or a fixed RK grid with aca/adjoint/"
            "naive for static pod-scale schedules")

    if cfg.regime == "fixed":
        zT, _ = odeint_final(
            f, z0, cfg.t0, cfg.t1, (params,),
            solver=_fixed_solver_for(cfg.solver),
            grad_method=cfg.grad_method,
            steps_per_interval=cfg.steps_per_interval,
            use_pallas=cfg.use_pallas,
            batch_axis=cfg.batch_axis,
            # threaded so a segmented config on the fixed regime raises
            # the api's informative error instead of silently ignoring
            checkpoint_segments=cfg.checkpoint_segments,
            on_failure=cfg.on_failure,
            mesh=cfg.mesh, shard_rules=cfg.shard_rules,
        )
    else:
        zT, _ = odeint_final(
            f, z0, cfg.t0, cfg.t1, (params,),
            # mali pairs only with the ALF pair integrator; the RK
            # solver name in the config is a don't-care for that method
            solver="alf" if cfg.grad_method == "mali" else cfg.solver,
            grad_method=cfg.grad_method,
            rtol=cfg.rtol, atol=cfg.atol,
            max_steps=cfg.max_steps,
            use_pallas=cfg.use_pallas,
            batch_axis=cfg.batch_axis,
            checkpoint_segments=cfg.checkpoint_segments,
            on_failure=cfg.on_failure,
            mesh=cfg.mesh, shard_rules=cfg.shard_rules,
        )
    return zT


def _fixed_solver_for(name: str) -> str:
    """Map an adaptive pair to its advancing fixed-step method."""
    return {
        "heun_euler": "rk2",
        "heuneuler": "rk2",
        "bosh3": "rk2",
        "rk23": "rk2",
        "dopri5": "rk4",
        "rk45": "rk4",
    }.get(name.lower().replace("-", "_"), name)
