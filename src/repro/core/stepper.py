"""Generic explicit Runge-Kutta step  ψ_h(t, z)  over arbitrary pytrees.

One ``rk_step`` evaluates all stages of a tableau and returns the advanced
state plus (for embedded pairs) the local error estimate.  This is the ψ of
the paper's Algorithm 1; every gradient method (naive / adjoint / ACA) calls
the same stepper so forward trajectories are bit-identical across methods.

The stage accumulation  z + h·Σ a_ij k_j  is the memory-bound hot loop on
TPU; ``repro.kernels.rk_stage`` provides a fused Pallas kernel for the flat
(array) fast path, which this module dispatches to when enabled.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .tableaus import Tableau

PyTree = Any
VecField = Callable[..., PyTree]  # f(t, z, *args) -> dz/dt


def _tree_axpy(alpha, x: PyTree, y: PyTree) -> PyTree:
    """y + alpha * x elementwise over pytrees, preserving y's dtype
    (an f32 stepsize scalar must not upcast a bf16 model state)."""
    return jax.tree.map(
        lambda xi, yi: yi + (alpha * xi).astype(yi.dtype), x, y)


def _weighted_sum(ks: Tuple[PyTree, ...], ws) -> PyTree:
    """Σ_i ws[i] * ks[i] over pytrees, skipping exact-zero weights."""
    acc = None
    for w, k in zip(ws, ks):
        if isinstance(w, float) and w == 0.0:
            continue
        term = jax.tree.map(lambda ki: w * ki, k)
        acc = term if acc is None else jax.tree.map(jnp.add, acc, term)
    if acc is None:
        acc = jax.tree.map(jnp.zeros_like, ks[0])
    return acc


class StepResult(NamedTuple):
    z_next: PyTree
    err: Optional[PyTree]  # local error estimate (None for fixed-step)
    k_last: PyTree         # last stage derivative (FSAL reuse)


def rk_step(
    tab: Tableau,
    f: VecField,
    t,
    z: PyTree,
    h,
    args: Tuple = (),
    k0: Optional[PyTree] = None,
) -> StepResult:
    """One explicit RK step of ``tab`` from (t, z) with stepsize h.

    ``k0`` optionally supplies the first stage derivative (FSAL).
    Returns z_{n+1}, the embedded error estimate (h·Σ b_err_i k_i) and the
    final stage derivative for FSAL chaining.
    """
    ks = []
    for i in range(tab.stages):
        if i == 0:
            ki = k0 if k0 is not None else f(t, z, *args)
        else:
            zi = z
            incr = _weighted_sum(tuple(ks), tab.a[i])
            zi = _tree_axpy(h, incr, z)
            ki = f(t + tab.c[i] * h, zi, *args)
        ks.append(ki)
    ks = tuple(ks)

    z_next = _tree_axpy(h, _weighted_sum(ks, tab.b), z)

    err = None
    if tab.b_err is not None:
        err = jax.tree.map(lambda e: h * e, _weighted_sum(ks, tab.b_err))

    if tab.fsal:
        k_last = ks[-1]
    else:
        k_last = ks[0]
    return StepResult(z_next=z_next, err=err, k_last=k_last)


def error_ratio(err: PyTree, z0: PyTree, z1: PyTree, rtol: float,
                atol: float):
    """RMS norm of err scaled by atol + rtol*max(|z0|,|z1|) (Hairer I.4).

    Returns a scalar; an accepted step has ratio <= 1.
    """
    def _scaled_sq(e, a, b):
        scale = atol + rtol * jnp.maximum(jnp.abs(a), jnp.abs(b))
        r = (e / scale).astype(jnp.float32)
        return jnp.sum(r * r), r.size

    leaves_sq, sizes = zip(*(
        _scaled_sq(e, a, b)
        for e, a, b in zip(jax.tree.leaves(err), jax.tree.leaves(z0),
                           jax.tree.leaves(z1))
    ))
    total = sum(leaves_sq)
    n = sum(sizes)
    return jnp.sqrt(total / n)


def fixed_step_fn(tab: Tableau, f: VecField) -> Callable:
    """Returns step(t, z, h, args) -> z_next for fixed-grid integration."""
    def step(t, z, h, args=()):
        return rk_step(tab, f, t, z, h, args).z_next
    return step
