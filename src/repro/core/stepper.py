"""Generic explicit Runge-Kutta step  ψ_h(t, z)  over arbitrary pytrees.

One ``rk_step`` evaluates all stages of a tableau and returns the advanced
state plus (for embedded pairs) the local error estimate.  This is the ψ of
the paper's Algorithm 1; every gradient method (naive / adjoint / ACA) calls
the same stepper so forward trajectories are bit-identical across methods.

Two execution paths, selected per call:

* **Flat-array fast path** (``use_pallas=True`` *and* the state is a
  single 1-D inexact array): the stage accumulations  z + h·Σ a_ij k_j,
  the solution/error combine and — when ``err_scale=(rtol, atol)`` is
  given — the scaled error norm of ``error_ratio`` are each one fused
  Pallas kernel (``repro.kernels.rk_stage``), cutting the memory-bound
  traffic of the trial loop roughly in half.  The fused norm is returned
  as ``StepResult.err_ratio`` so the accept/reject loop skips its extra
  full-array pass.  The kernels are wrapped in custom_vjp (backward =
  the bit-matching jnp twin), so this path is differentiable and legal
  inside the ACA backward replay and the naive method's scan.
* **Pytree fallback** (default): pure ``jax.tree`` arithmetic over any
  state structure/dtype mix; ``err_ratio`` is None and callers compute
  ``error_ratio`` themselves.

``flatten_problem`` is the per-solve adapter: it ravels a pytree state
once (one ``ravel_pytree`` per solve, not per step), wraps the vector
field to operate on the flat vector, and hands back the unravel for the
outputs — solver loops then carry a single (N,) array, which also
shrinks the while_loop carry the checkpoint writer updates every trial.
States with mixed or non-inexact dtypes return None and stay on the
pytree path.

``rk_step_batched`` is the per-sample batched twin of ``rk_step`` for
``odeint(..., batch_axis=0)``: every leaf carries a leading batch dim B,
``t`` and ``h`` are (B,) — each element takes ψ with its *own* time and
trial stepsize — and ``err_ratio`` is (B,), one scaled error norm per
element.  ``maybe_flatten_batched`` is the matching fallback rule: the
fused path carries a (B, N) array through the batched Pallas kernels.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from .tableaus import Tableau

PyTree = Any
VecField = Callable[..., PyTree]  # f(t, z, *args) -> dz/dt


def _tree_axpy(alpha, x: PyTree, y: PyTree) -> PyTree:
    """y + alpha * x elementwise over pytrees, preserving y's dtype
    (an f32 stepsize scalar must not upcast a bf16 model state)."""
    return jax.tree.map(
        lambda xi, yi: yi + (alpha * xi).astype(yi.dtype), x, y)


def _weighted_sum(ks: Tuple[PyTree, ...], ws) -> PyTree:
    """Σ_i ws[i] * ks[i] over pytrees, skipping exact-zero weights."""
    acc = None
    for w, k in zip(ws, ks):
        if isinstance(w, float) and w == 0.0:
            continue
        term = jax.tree.map(lambda ki: w * ki, k)
        acc = term if acc is None else jax.tree.map(jnp.add, acc, term)
    if acc is None:
        acc = jax.tree.map(jnp.zeros_like, ks[0])
    return acc


class StepResult(NamedTuple):
    z_next: PyTree
    err: Optional[PyTree]  # local error estimate (None for fixed-step)
    k_last: PyTree         # last stage derivative (FSAL reuse)
    # scaled error norm from the fused kernel (flat fast path with
    # err_scale only); None -> caller computes error_ratio itself
    err_ratio: Optional[jnp.ndarray] = None
    # dense-output extras (``dense=True`` only): the first-stage
    # derivative actually used (k0 input or freshly computed) and — for
    # tableaus carrying ``b_mid`` — the step-midpoint solution
    # z + h·Σ b_mid_i k_i.  Feed ``interp_fit``.
    k_first: Optional[PyTree] = None
    z_mid: Optional[PyTree] = None


def _is_flat_array(z: PyTree) -> bool:
    return (isinstance(z, jax.Array) and z.ndim == 1
            and jnp.issubdtype(z.dtype, jnp.inexact))


def flatten_problem(f: VecField, z0: PyTree):
    """Per-solve flat-state adapter for the fused kernel path.

    Returns ``(f_flat, z0_flat, unravel)`` — the vector field over the
    raveled (N,) state, the raveled initial state, and the inverse map
    for outputs/checkpoints — or None when the state cannot be raveled
    losslessly (mixed dtypes would be promoted, non-inexact leaves have
    no kernel path); callers then fall back to the pytree path.
    """
    leaves = jax.tree.leaves(z0)
    if not leaves:
        return None
    try:
        dtypes = {jnp.result_type(leaf) for leaf in leaves}
    except TypeError:
        return None
    if len(dtypes) != 1 or not jnp.issubdtype(dtypes.pop(), jnp.inexact):
        return None
    z0_flat, unravel = ravel_pytree(z0)

    def f_flat(t, zf, *args):
        return ravel_pytree(f(t, unravel(zf), *args))[0]

    return f_flat, z0_flat, unravel


def maybe_flatten(f: VecField, z0: PyTree, use_pallas: bool):
    """Flag-gated ``flatten_problem``: the one fallback rule shared by
    every solver entry point.

    Returns ``(f, z0, unravel, use_pallas)`` — the flat problem with
    ``use_pallas=True`` when raveling is possible and requested, else
    the inputs unchanged with ``unravel=None`` and ``use_pallas=False``
    (pytree path).
    """
    flat = flatten_problem(f, z0) if use_pallas else None
    if flat is None:
        return f, z0, None, False
    f_flat, z0_flat, unravel = flat
    return f_flat, z0_flat, unravel, True


def _rk_step_flat(
    tab: Tableau,
    f: VecField,
    t,
    z: jnp.ndarray,
    h,
    args: Tuple,
    k0: Optional[jnp.ndarray],
    err_scale: Optional[Tuple[float, float]],
    dense: bool = False,
) -> StepResult:
    """Fused-kernel ψ over a flat (N,) state (see module docstring)."""
    # deferred: importing repro.kernels at module scope would cycle
    # through kernels.ref -> repro.models -> repro.core
    from repro.kernels import ops

    k0v = k0 if k0 is not None else f(t, z, *args)
    ks = jnp.zeros((tab.stages,) + z.shape, k0v.dtype).at[0].set(k0v)
    for i in range(1, tab.stages):
        zi = ops.rk_stage_increment(z, ks[:i], h, tab.a[i])
        ks = ks.at[i].set(f(t + tab.c[i] * h, zi, *args))

    ratio = None
    if tab.b_err is not None and err_scale is not None:
        rtol, atol = err_scale
        # with_err=False: the accept/reject loop reads only z_next and
        # the fused norm — the (N,) err buffer is never materialized
        z_next, err, sq_sum = ops.rk_stage_combine_err(
            z, ks, h, tab.b, tab.b_err, rtol, atol, with_err=False)
        ratio = jnp.sqrt(sq_sum / z.size)
    else:
        # no consumer for err here (fixed tableaus have none; the ACA
        # backward replay reads only z_next): the solution combine is
        # the increment kernel with the b row — skips the N-sized err
        # store on this memory-bound loop
        z_next = ops.rk_stage_increment(z, ks, h, tab.b)
        err = None
    k_last = ks[-1] if tab.fsal else ks[0]
    k_first = z_mid = None
    if dense:
        k_first = k0v
        if tab.b_mid is not None:
            # the midpoint combine is the increment kernel with b_mid
            z_mid = ops.rk_stage_increment(z, ks, h, tab.b_mid)
    return StepResult(z_next=z_next, err=err, k_last=k_last,
                      err_ratio=ratio, k_first=k_first, z_mid=z_mid)


def rk_step(
    tab: Tableau,
    f: VecField,
    t,
    z: PyTree,
    h,
    args: Tuple = (),
    k0: Optional[PyTree] = None,
    *,
    use_pallas: bool = False,
    err_scale: Optional[Tuple[float, float]] = None,
    dense: bool = False,
) -> StepResult:
    """One explicit RK step of ``tab`` from (t, z) with stepsize h.

    ``k0`` optionally supplies the first stage derivative (FSAL).
    Returns z_{n+1}, the embedded error estimate (h·Σ b_err_i k_i) and the
    final stage derivative for FSAL chaining.

    ``use_pallas=True`` dispatches to the fused Pallas kernels when the
    state is a single flat inexact array (see ``flatten_problem``);
    other states silently take the pytree path.  With ``err_scale=(rtol,
    atol)`` the fused path additionally returns the scaled error norm in
    ``StepResult.err_ratio``; *without* err_scale the fused path returns
    ``err=None`` even for embedded tableaus (the err buffer is not
    materialized — adaptive callers always pass err_scale).

    ``dense=True`` additionally returns the dense-output inputs of
    ``interp_fit``: ``k_first`` (the stage-0 derivative this step
    consumed) and, for tableaus with ``b_mid``, the midpoint solution
    ``z_mid = z + h·Σ b_mid_i k_i``.  The advancing arithmetic is
    untouched — z_next is bit-identical with and without ``dense``.
    """
    if use_pallas and _is_flat_array(z):
        return _rk_step_flat(tab, f, t, z, h, args, k0, err_scale,
                             dense=dense)
    ks = []
    for i in range(tab.stages):
        if i == 0:
            ki = k0 if k0 is not None else f(t, z, *args)
        else:
            zi = z
            incr = _weighted_sum(tuple(ks), tab.a[i])
            zi = _tree_axpy(h, incr, z)
            ki = f(t + tab.c[i] * h, zi, *args)
        ks.append(ki)
    ks = tuple(ks)

    z_next = _tree_axpy(h, _weighted_sum(ks, tab.b), z)

    err = None
    if tab.b_err is not None:
        err = jax.tree.map(lambda e: h * e, _weighted_sum(ks, tab.b_err))

    if tab.fsal:
        k_last = ks[-1]
    else:
        k_last = ks[0]
    k_first = z_mid = None
    if dense:
        k_first = ks[0]
        if tab.b_mid is not None:
            z_mid = _tree_axpy(h, _weighted_sum(ks, tab.b_mid), z)
    return StepResult(z_next=z_next, err=err, k_last=k_last,
                      k_first=k_first, z_mid=z_mid)


def _is_flat_batched(z: PyTree) -> bool:
    return (isinstance(z, jax.Array) and z.ndim == 2
            and jnp.issubdtype(z.dtype, jnp.inexact))


def maybe_flatten_batched(f: VecField, z0: PyTree, use_pallas: bool):
    """Batched twin of ``maybe_flatten``: ``z0`` leaves carry a leading
    batch dim B and ``f`` is the *per-sample* vector field.

    Returns ``(f, z0, unravel, use_pallas)``: on success ``f`` is the
    per-sample field over the raveled (N,) state, ``z0`` the (B, N)
    batch of raveled states and ``unravel`` the per-sample inverse map
    (vmap it over outputs); otherwise the inputs come back unchanged
    with ``unravel=None`` and ``use_pallas=False`` (same fallback rules
    as ``flatten_problem``: single inexact dtype or bust).
    """
    if not use_pallas:
        return f, z0, None, False
    sample = jax.tree.map(lambda l: l[0], z0)
    flat = flatten_problem(f, sample)
    if flat is None:
        return f, z0, None, False
    f_flat, _, unravel = flat
    z0_flat = jax.vmap(lambda z: ravel_pytree(z)[0])(z0)
    return f_flat, z0_flat, unravel, True


def _tree_baxpy(h, x: PyTree, y: PyTree) -> PyTree:
    """Per-row y + h_b * x over batch-leading pytrees, h of shape (B,)."""
    return jax.tree.map(
        lambda xi, yi: yi + (h.reshape((-1,) + (1,) * (xi.ndim - 1))
                             * xi).astype(yi.dtype), x, y)


def _rk_step_flat_batched(
    tab: Tableau,
    fb: Callable,
    t: jnp.ndarray,
    z: jnp.ndarray,
    h: jnp.ndarray,
    k0: Optional[jnp.ndarray],
    err_scale: Optional[Tuple[float, float]],
    dense: bool = False,
) -> StepResult:
    """Fused batched ψ over a (B, N) state: per-row stepsizes, per-row
    error norms.  ``fb`` maps ((B,), (B, N)) -> (B, N)."""
    from repro.kernels import ops

    k0v = k0 if k0 is not None else fb(t, z)
    ks = jnp.zeros((tab.stages,) + z.shape, k0v.dtype).at[0].set(k0v)
    for i in range(1, tab.stages):
        zi = ops.rk_stage_increment_batched(z, ks[:i], h, tab.a[i])
        ks = ks.at[i].set(fb(t + tab.c[i] * h, zi))

    ratio = None
    if tab.b_err is not None and err_scale is not None:
        rtol, atol = err_scale
        z_next, sq_sum = ops.rk_stage_combine_err_batched(
            z, ks, h, tab.b, tab.b_err, rtol, atol)
        ratio = jnp.sqrt(sq_sum / z.shape[-1])
        err = None
    else:
        z_next = ops.rk_stage_increment_batched(z, ks, h, tab.b)
        err = None
    k_last = ks[-1] if tab.fsal else ks[0]
    k_first = z_mid = None
    if dense:
        k_first = k0v
        if tab.b_mid is not None:
            z_mid = ops.rk_stage_increment_batched(z, ks, h, tab.b_mid)
    return StepResult(z_next=z_next, err=err, k_last=k_last,
                      err_ratio=ratio, k_first=k_first, z_mid=z_mid)


def rk_step_batched(
    tab: Tableau,
    f: VecField,
    t: jnp.ndarray,
    z: PyTree,
    h: jnp.ndarray,
    args: Tuple = (),
    k0: Optional[PyTree] = None,
    *,
    use_pallas: bool = False,
    err_scale: Optional[Tuple[float, float]] = None,
    dense: bool = False,
) -> StepResult:
    """One explicit RK step per batch element: ψ_{h_b}(t_b, z_b) for all
    b at once.

    ``f`` is the per-sample vector field (no batch dim); leaves of ``z``
    carry a leading batch dim B; ``t`` and ``h`` are (B,).  With
    ``err_scale=(rtol, atol)`` the result's ``err_ratio`` is the (B,)
    vector of per-element scaled error norms (then ``err`` is None — no
    consumer); ``rtol``/``atol`` may themselves be (B,) arrays, scaling
    each element's norm against its own tolerance (the per-request QoS
    path — equal-tolerance rows stay bitwise identical to the scalar
    form).  An element whose h_b is 0 passes through unchanged
    bit-exactly: the masking contract the batched adaptive loop and the
    ACA batched backward sweep use to freeze finished elements.

    ``use_pallas=True`` dispatches (B, N) inexact states to the batched
    fused kernels; other states take the vmapped pytree path.
    ``dense=True`` as in ``rk_step`` (per-row ``k_first`` / ``z_mid``).
    """
    fb = jax.vmap(lambda ti, zi: f(ti, zi, *args))
    if use_pallas and _is_flat_batched(z):
        return _rk_step_flat_batched(tab, fb, t, z, h, k0, err_scale,
                                     dense=dense)

    ks = []
    for i in range(tab.stages):
        if i == 0:
            ki = k0 if k0 is not None else fb(t, z)
        else:
            incr = _weighted_sum(tuple(ks), tab.a[i])
            zi = _tree_baxpy(h, incr, z)
            ki = fb(t + tab.c[i] * h, zi)
        ks.append(ki)
    ks = tuple(ks)

    z_next = _tree_baxpy(h, _weighted_sum(ks, tab.b), z)

    err = None
    ratio = None
    if tab.b_err is not None:
        err = jax.tree.map(
            lambda e: h.reshape((-1,) + (1,) * (e.ndim - 1)) * e,
            _weighted_sum(ks, tab.b_err))
        if err_scale is not None:
            rtol, atol = err_scale
            if jnp.ndim(rtol) > 0 or jnp.ndim(atol) > 0:
                # per-row tolerances (per-request QoS): each element's
                # error norm is scaled against its own (rtol, atol) —
                # same arithmetic per row as the scalar path, so
                # equal-tolerance rows stay bitwise identical
                bsz = h.shape[0]
                rt = jnp.broadcast_to(
                    jnp.asarray(rtol, jnp.float32), (bsz,))
                at = jnp.broadcast_to(
                    jnp.asarray(atol, jnp.float32), (bsz,))
                ratio = jax.vmap(error_ratio)(err, z, z_next, rt, at)
            else:
                ratio = jax.vmap(
                    lambda e, a, b: error_ratio(e, a, b, rtol, atol))(
                        err, z, z_next)
            err = None

    k_last = ks[-1] if tab.fsal else ks[0]
    k_first = z_mid = None
    if dense:
        k_first = ks[0]
        if tab.b_mid is not None:
            z_mid = _tree_baxpy(h, _weighted_sum(ks, tab.b_mid), z)
    return StepResult(z_next=z_next, err=err, k_last=k_last,
                      err_ratio=ratio, k_first=k_first, z_mid=z_mid)


def error_ratio(err: PyTree, z0: PyTree, z1: PyTree, rtol: float,
                atol: float):
    """RMS norm of err scaled by atol + rtol*max(|z0|,|z1|) (Hairer I.4).

    Returns a scalar; an accepted step has ratio <= 1.
    """
    def _scaled_sq(e, a, b):
        scale = atol + rtol * jnp.maximum(jnp.abs(a), jnp.abs(b))
        r = (e / scale).astype(jnp.float32)
        return jnp.sum(r * r), r.size

    leaves_sq, sizes = zip(*(
        _scaled_sq(e, a, b)
        for e, a, b in zip(jax.tree.leaves(err), jax.tree.leaves(z0),
                           jax.tree.leaves(z1))
    ))
    total = sum(leaves_sq)
    n = sum(sizes)
    return jnp.sqrt(total / n)


# --------------------------------------------------------------------------
# Dense output: per-step polynomial interpolants
# --------------------------------------------------------------------------
#
# Every accepted step carries enough information for a local polynomial
# z(t + θh) ≈ P(θ), θ ∈ [0, 1], built from quantities the solver loop
# already computed:
#
#   * cubic Hermite (any tableau): endpoints z0, z1 and endpoint
#     derivatives k0 = f(t, z0), k1 = f(t+h, z1) — both free: k0 is the
#     first stage, k1 is the FSAL last stage (or the post-accept k0'
#     recompute for non-FSAL pairs).  Local error O(h⁴).
#   * quartic fit (tableaus with ``b_mid``, i.e. Dopri5): adds the
#     midpoint solution z_mid = z0 + h·Σ b_mid_i k_i, giving the classic
#     4th-order dense output whose error tracks the pair's tolerance.
#
# Both are expressed as one coefficient 5-tuple (c4..c0) with
# P(θ) = (((c4·θ + c3)·θ + c2)·θ + c1)·θ + c0, so downstream code
# (interpolated eval-time reads, DenseSolution storage, the ACA backward
# sweep's interpolated-output vjp) handles one representation.  P(0) is
# z0 *bitwise* (c0 = z0); P(1) recovers z1 algebraically.


class InterpCoeffs(NamedTuple):
    """Polynomial coefficients of one step interpolant (pytrees, highest
    degree first): P(θ) = c4·θ⁴ + c3·θ³ + c2·θ² + c1·θ + c0."""
    c4: PyTree
    c3: PyTree
    c2: PyTree
    c1: PyTree
    c0: PyTree


def _hb(h, leaf):
    """Reshape h (scalar or (B,)) to broadcast against a state leaf,
    cast to the leaf dtype (a float64 time grid under JAX_ENABLE_X64
    must not upcast a float32 state — same rule as ``_tree_axpy``)."""
    h = jnp.asarray(h, leaf.dtype)
    return h.reshape(h.shape + (1,) * (leaf.ndim - h.ndim))


def interp_fit(z0: PyTree, z1: PyTree, k0: PyTree, k1: PyTree, h,
               z_mid: Optional[PyTree] = None) -> InterpCoeffs:
    """Fit the step interpolant from endpoint (and midpoint) data.

    ``h`` is the accepted stepsize — a scalar, or (B,) for batch-leading
    pytrees (per-row steps).  With ``z_mid`` (tableaus carrying
    ``b_mid``) this is the 4th-order quartic fit matching z0, z1, z_mid,
    k0 and k1; without it, the cubic Hermite through z0, z1, k0, k1
    (c4 = 0).  All arithmetic is plain jnp — differentiable everywhere,
    including under the ACA backward sweep's local vjp.
    """
    # h·k cast to the STATE leaf dtype (not k's): under x64 a float64
    # time can promote f's output, and the coefficients must match z —
    # the _tree_axpy convention
    hk0 = jax.tree.map(lambda k, z: (_hb(h, z) * k).astype(z.dtype),
                       k0, z0)
    hk1 = jax.tree.map(lambda k, z: (_hb(h, z) * k).astype(z.dtype),
                       k1, z0)
    if z_mid is None:
        c4 = jax.tree.map(jnp.zeros_like, z0)
        c3 = jax.tree.map(
            lambda a, b, p, q: 2.0 * (a - b) + p + q, z0, z1, hk0, hk1)
        c2 = jax.tree.map(
            lambda a, b, p, q: 3.0 * (b - a) - 2.0 * p - q,
            z0, z1, hk0, hk1)
    else:
        c4 = jax.tree.map(
            lambda p, q, a, b, m: 2.0 * (q - p) - 8.0 * (a + b)
            + 16.0 * m, hk0, hk1, z0, z1, z_mid)
        c3 = jax.tree.map(
            lambda p, q, a, b, m: 5.0 * p - 3.0 * q + 18.0 * a
            + 14.0 * b - 32.0 * m, hk0, hk1, z0, z1, z_mid)
        c2 = jax.tree.map(
            lambda p, q, a, b, m: q - 4.0 * p - 11.0 * a - 5.0 * b
            + 16.0 * m, hk0, hk1, z0, z1, z_mid)
    return InterpCoeffs(c4=c4, c3=c3, c2=c2, c1=hk0, c0=z0)


def interp_eval(coeffs: InterpCoeffs, theta: jnp.ndarray) -> PyTree:
    """Evaluate P at ``theta``, stacking theta's *leading* axis onto the
    output: theta (T,) over solo leaves (...) -> (T, ...); theta (T, B)
    over batch-leading leaves (B, ...) -> (T, B, ...)."""
    def ev(c4, c3, c2, c1, c0):
        th = theta.astype(c0.dtype).reshape(
            theta.shape + (1,) * (c0.ndim - (theta.ndim - 1)))
        return (((c4 * th + c3) * th + c2) * th + c1) * th + c0

    return jax.tree.map(ev, *coeffs)


def interp_eval_aligned(coeffs: InterpCoeffs,
                        theta: jnp.ndarray) -> PyTree:
    """Evaluate P elementwise: theta's axes align with the *leading*
    leaf axes (theta (T,) over leaves (T, ...) -> (T, ...)).  Used by
    ``DenseSolution.evaluate`` after gathering per-query coefficients."""
    def ev(c4, c3, c2, c1, c0):
        th = theta.astype(c0.dtype).reshape(
            theta.shape + (1,) * (c0.ndim - theta.ndim))
        return (((c4 * th + c3) * th + c2) * th + c1) * th + c0

    return jax.tree.map(ev, *coeffs)


def fixed_step_fn(tab: Tableau, f: VecField) -> Callable:
    """Returns step(t, z, h, args) -> z_next for fixed-grid integration."""
    def step(t, z, h, args=()):
        return rk_step(tab, f, t, z, h, args).z_next
    return step


# --------------------------------------------------------------------------
# Asynchronous-leapfrog (ALF) stepper — the reversible pair integrator
# behind ``odeint(..., grad_method="mali")``
# --------------------------------------------------------------------------
#
# One ALF step advances the paired state (z, v), v ≈ dz/dt (MALI, Zhuang
# et al. 2021):
#
#     u  = z + (h/2)·v           half-position drift
#     w  = f(t + h/2, u)         one midpoint field evaluation
#     v' = 2w − v                velocity reflection
#     z' = u + (h/2)·v'          half-position drift with the NEW velocity
#
# (algebraically z' = z + h·w — second order, ONE f-eval per trial).  The
# step is *algebraically* self-inverse: u = z' − (h/2)·v' recovers the
# midpoint from the advanced pair, so the same w can be recomputed and the
# whole step peeled off — the basis of MALI's O(1)-memory exact-reverse
# gradient.
#
# Floating-point addition, however, is lossy (fl(fl(a+b)−b) ≠ a in
# general: the map a ↦ fl(a+b) is not injective), so NO deterministic
# float implementation of the algebraic inverse can be bit-exact.  To make
# ψ⁻¹∘ψ the identity *bitwise* — the contract the MALI backward sweep is
# built on — the pair is carried on a **fixed-point integer lattice**
# (Levesque & Verlet 1993, "bit-reversible" integration): both z and v are
# stored as int32/int64 multiples of a per-solve quantum
# δ = 2^(scale_exp − frac), every drift/reflection update is a *wrapping
# integer add* of an increment recomputed identically on both sides, and
# integer addition is a bijection — the inverse subtracts the same
# integers and recovers the previous pair exactly, for any input
# (over/underflow included).  The field f is evaluated on the decoded
# (float) midpoint; determinism of f gives bit-equal w in both directions.
#
# The quantization costs one δ-rounding per f-eval: δ is the state scale
# × 2⁻²⁴ (f32/bf16 leaves, i32 lattice) or × 2⁻⁵² (f64 leaves, i64
# lattice) — at or below one float ulp at the state's scale, far below
# any solver tolerance this repo runs.  The differentiable twin
# ``alf_step_float`` (the function the MALI backward sweep takes
# ``jax.vjp`` of, linearized at the exactly-reconstructed states) treats
# the δ-rounding as identity — the standard straight-through convention.

ALF_ORDER = 2  # ALF is second order; embedded Euler comparator is order 1


def _lattice_frac(fdt) -> int:
    """Fractional bits of the lattice for a float leaf dtype: the quantum
    is δ = 2^(scale_exp − frac)."""
    return 52 if fdt == jnp.float64 else 24


def _lattice_int_dtype(fdt):
    return jnp.int64 if fdt == jnp.float64 else jnp.int32


def _lattice_clip_bound(fdt) -> float:
    # largest float of the lattice dtype that casts safely to the int
    # dtype (2^31 / 2^63 themselves would overflow the cast)
    return float(2 ** 62) if fdt == jnp.float64 else float(2 ** 31 - 128)


def alf_lattice_exponent(z0: PyTree, v0: PyTree) -> jnp.ndarray:
    """Per-solve lattice scale exponent: ⌈log₂ max(|z0|, |v0|, 1)⌉.

    One float32 scalar shared by every leaf (the quantum is
    δ_leaf = 2^(scale_exp − frac(dtype))); saved in the solve's grid so
    the backward sweep decodes on the identical lattice.  The i32
    lattice then spans ±128× the initial scale at a resolution of one
    f32 ulp at that scale — states wandering far beyond the initial
    scale wrap (deterministically; the error estimator rejects such
    steps long before).
    """
    def leaf_max(l):
        return jnp.max(jnp.abs(l.astype(jnp.float32))) if l.size else \
            jnp.float32(0.0)

    mx = jnp.asarray(1.0, jnp.float32)
    for leaf in jax.tree.leaves(z0) + jax.tree.leaves(v0):
        mx = jnp.maximum(mx, leaf_max(leaf))
    return jnp.ceil(jnp.log2(mx))


def alf_lattice_exponent_batched(z0: PyTree, v0: PyTree) -> jnp.ndarray:
    """Per-element lattice exponents (B,) over batch-leading leaves —
    the same reduction as ``alf_lattice_exponent`` restricted to each
    row, so a batched solve quantizes exactly like ``jax.vmap`` of the
    solo solve (per-row conditioning included)."""
    def leaf_max(l):
        flat = jnp.abs(l.astype(jnp.float32)).reshape(l.shape[0], -1)
        return jnp.max(flat, axis=1) if l.size else \
            jnp.zeros((l.shape[0],), jnp.float32)

    leaves = jax.tree.leaves(z0) + jax.tree.leaves(v0)
    mx = jnp.ones((leaves[0].shape[0],), jnp.float32)
    for leaf in leaves:
        mx = jnp.maximum(mx, leaf_max(leaf))
    return jnp.ceil(jnp.log2(mx))


def _se_b(scale_exp, leaf: jnp.ndarray) -> jnp.ndarray:
    """Reshape a scale exponent — scalar, or (B,) over batch-leading
    leaves — to broadcast against ``leaf`` (the ``_hb`` convention)."""
    se = jnp.asarray(scale_exp, jnp.float32)
    return se.reshape(se.shape + (1,) * (leaf.ndim - se.ndim))


def _lattice_quantize_leaf(x: jnp.ndarray, scale_exp) -> jnp.ndarray:
    """Round a float leaf to its integer lattice coordinate (the ONE
    quantization rule — forward and inverse must call exactly this)."""
    fdt = x.dtype
    inv_delta = jnp.exp2(
        jnp.asarray(_lattice_frac(fdt), jnp.float32) - _se_b(scale_exp, x)
    ).astype(fdt)
    q = jnp.round(x * inv_delta)
    lim = jnp.asarray(_lattice_clip_bound(fdt), fdt)
    return jnp.clip(q, -lim, lim).astype(_lattice_int_dtype(fdt))


def _lattice_decode_leaf(q: jnp.ndarray, scale_exp, fdt) -> jnp.ndarray:
    delta = jnp.exp2(
        _se_b(scale_exp, q) - jnp.asarray(_lattice_frac(fdt), jnp.float32)
    ).astype(fdt)
    return q.astype(fdt) * delta


def lattice_encode(x: PyTree, scale_exp) -> PyTree:
    """Float pytree -> integer-lattice pytree (i32 per f32/bf16 leaf,
    i64 per f64 leaf), quantum δ = 2^(scale_exp − frac)."""
    return jax.tree.map(lambda l: _lattice_quantize_leaf(l, scale_exp), x)


def lattice_decode(q: PyTree, scale_exp, proto: PyTree) -> PyTree:
    """Integer-lattice pytree -> float pytree with ``proto``'s leaf
    dtypes (the exact inverse scaling of ``lattice_encode``'s grid)."""
    return jax.tree.map(
        lambda ql, pl: _lattice_decode_leaf(ql, scale_exp, pl.dtype),
        q, proto)


def _drift_increment(h, v_float: PyTree, scale_exp) -> PyTree:
    """Quantized half-drift increment Q((h/2)·v), per leaf, as lattice
    integers.  ``h`` may be scalar or (B,) over batch-leading leaves;
    it is cast to each leaf's dtype (an x64 time grid must not promote
    an f32 state — the ``_tree_axpy`` convention)."""
    def leaf(v):
        hh = _hb(h, v) * jnp.asarray(0.5, v.dtype)
        return _lattice_quantize_leaf(hh * v, scale_exp)

    return jax.tree.map(leaf, v_float)


def _tree_iadd(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def _tree_isub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.subtract, a, b)


def _alf_midpoint_t(t, h):
    """t + h/2 — defined once so forward and inverse compute the same
    bits."""
    return t + 0.5 * h


class AlfResult(NamedTuple):
    """One ALF trial over the lattice pair.

    ``zq_next``/``vq_next`` are the advanced lattice coordinates (carry
    them); ``z_next`` the decoded float state (outputs / error scale);
    ``err`` the embedded error estimate h·(w − v) — the gap between the
    2nd-order midpoint update z + h·w and the 1st-order Euler predictor
    z + h·v, the zero-cost analog of an embedded RK pair.
    """
    zq_next: PyTree
    vq_next: PyTree
    z_next: PyTree
    err: PyTree


def alf_step(f: VecField, t, h, zq: PyTree, vq: PyTree, scale_exp,
             proto: PyTree, args: Tuple = ()) -> AlfResult:
    """One asynchronous-leapfrog step on the integer lattice.

    ``zq``/``vq`` are lattice pytrees (``lattice_encode``), ``proto`` a
    float pytree fixing the leaf dtypes, ``t``/``h`` scalars.  Every
    state update is a wrapping integer add, so
    ``alf_step_inverse(alf_step(s)) == s`` **bitwise** for any state —
    see the section comment.  Exactly one f evaluation.
    """
    vf = lattice_decode(vq, scale_exp, proto)
    uq = _tree_iadd(zq, _drift_increment(h, vf, scale_exp))
    uf = lattice_decode(uq, scale_exp, proto)
    w = f(_alf_midpoint_t(t, h), uf, *args)
    # velocity reflection v' = 2w − v on the lattice (Q(2w) exact int sub)
    vq_next = _tree_isub(
        jax.tree.map(
            lambda wl: _lattice_quantize_leaf(
                jnp.asarray(2.0, wl.dtype) * wl, scale_exp), w),
        vq)
    vf_next = lattice_decode(vq_next, scale_exp, proto)
    zq_next = _tree_iadd(uq, _drift_increment(h, vf_next, scale_exp))
    err = jax.tree.map(
        lambda wl, vl: _hb(h, vl) * (wl.astype(vl.dtype) - vl), w, vf)
    return AlfResult(zq_next=zq_next, vq_next=vq_next,
                     z_next=lattice_decode(zq_next, scale_exp, proto),
                     err=err)


def alf_step_inverse(f: VecField, t, h, zq_next: PyTree, vq_next: PyTree,
                     scale_exp, proto: PyTree,
                     args: Tuple = ()) -> Tuple[PyTree, PyTree]:
    """Exact inverse of ``alf_step``: recovers the pre-step pair bitwise.

    Mirrors the forward update in reverse: each quantized increment is
    recomputed from the side the inverse already knows (v' for the
    second drift, the recovered v for the first) and subtracted with the
    same wrapping integer arithmetic — ints in, identical ints out.
    """
    vf_next = lattice_decode(vq_next, scale_exp, proto)
    uq = _tree_isub(zq_next, _drift_increment(h, vf_next, scale_exp))
    uf = lattice_decode(uq, scale_exp, proto)
    w = f(_alf_midpoint_t(t, h), uf, *args)
    vq = _tree_isub(
        jax.tree.map(
            lambda wl: _lattice_quantize_leaf(
                jnp.asarray(2.0, wl.dtype) * wl, scale_exp), w),
        vq_next)
    vf = lattice_decode(vq, scale_exp, proto)
    zq = _tree_isub(uq, _drift_increment(h, vf, scale_exp))
    return zq, vq


def alf_step_float(f: VecField, t, h, z: PyTree, v: PyTree,
                   args: Tuple = (), *,
                   use_pallas: bool = False) -> Tuple[PyTree, PyTree]:
    """Differentiable float twin of ``alf_step`` (δ-rounding treated as
    identity — the straight-through convention).

    The MALI backward sweep takes ``jax.vjp`` of this map at the
    exactly-reconstructed (z_i, v_i); its primal differs from the
    lattice step by at most one quantum per operation.  With
    ``use_pallas`` and a flat (N,) state the two half-drifts reuse the
    fused ``rk_stage_increment`` kernel (a one-stage row with weight ½,
    already custom_vjp wrapped); the reflection is one cheap jnp axpy.
    """
    if use_pallas and _is_flat_array(z):
        from repro.kernels import ops
        u = ops.rk_stage_increment(z, v[None], h, (0.5,))
        w = f(_alf_midpoint_t(t, h), u, *args)
        v_next = 2.0 * w - v
        z_next = ops.rk_stage_increment(u, v_next[None], h, (0.5,))
        return z_next, v_next
    half = jax.tree.map(lambda vl: 0.5 * vl, v)
    u = _tree_axpy(h, half, z)
    w = f(_alf_midpoint_t(t, h), u, *args)
    v_next = jax.tree.map(lambda wl, vl: 2.0 * wl - vl, w, v)
    z_next = _tree_axpy(h, jax.tree.map(lambda vl: 0.5 * vl, v_next), u)
    return z_next, v_next


def alf_step_batched(f: VecField, t: jnp.ndarray, h: jnp.ndarray,
                     zq: PyTree, vq: PyTree, scale_exp, proto: PyTree,
                     args: Tuple = ()) -> AlfResult:
    """Per-sample batched ALF trial: leaves carry a leading batch dim B,
    ``t``/``h`` are (B,) — each element drifts with its own stepsize.

    Same lattice arithmetic as ``alf_step`` (the increments broadcast
    h per row), so per-row inversion is bitwise exact.  Callers gate the
    carry on per-row accept masks (integer ``where`` is bit-stable);
    a frozen row's trial is simply discarded — note the h = 0 ALF step
    is *not* the identity in v (the reflection still fires), so masking,
    not zero-stepping, is the freezing contract here.
    """
    fb = jax.vmap(lambda ti, zi: f(ti, zi, *args))
    vf = lattice_decode(vq, scale_exp, proto)
    uq = _tree_iadd(zq, _drift_increment(h, vf, scale_exp))
    uf = lattice_decode(uq, scale_exp, proto)
    w = fb(_alf_midpoint_t(t, h), uf)
    vq_next = _tree_isub(
        jax.tree.map(
            lambda wl: _lattice_quantize_leaf(
                jnp.asarray(2.0, wl.dtype) * wl, scale_exp), w),
        vq)
    vf_next = lattice_decode(vq_next, scale_exp, proto)
    zq_next = _tree_iadd(uq, _drift_increment(h, vf_next, scale_exp))
    err = jax.tree.map(
        lambda wl, vl: _hb(h, vl) * (wl.astype(vl.dtype) - vl), w, vf)
    return AlfResult(zq_next=zq_next, vq_next=vq_next,
                     z_next=lattice_decode(zq_next, scale_exp, proto),
                     err=err)


def alf_step_inverse_batched(
        f: VecField, t: jnp.ndarray, h: jnp.ndarray, zq_next: PyTree,
        vq_next: PyTree, scale_exp, proto: PyTree,
        args: Tuple = ()) -> Tuple[PyTree, PyTree]:
    """Batched twin of ``alf_step_inverse`` (per-row t/h)."""
    fb = jax.vmap(lambda ti, zi: f(ti, zi, *args))
    vf_next = lattice_decode(vq_next, scale_exp, proto)
    uq = _tree_isub(zq_next, _drift_increment(h, vf_next, scale_exp))
    uf = lattice_decode(uq, scale_exp, proto)
    w = fb(_alf_midpoint_t(t, h), uf)
    vq = _tree_isub(
        jax.tree.map(
            lambda wl: _lattice_quantize_leaf(
                jnp.asarray(2.0, wl.dtype) * wl, scale_exp), w),
        vq_next)
    vf = lattice_decode(vq, scale_exp, proto)
    zq = _tree_isub(uq, _drift_increment(h, vf, scale_exp))
    return zq, vq


def alf_step_float_batched(
        f: VecField, t: jnp.ndarray, h: jnp.ndarray, z: PyTree,
        v: PyTree, args: Tuple = (), *,
        use_pallas: bool = False) -> Tuple[PyTree, PyTree]:
    """Batched differentiable float twin (per-row t/h); with
    ``use_pallas`` and a (B, N) state the drifts reuse the fused
    ``rk_stage_increment_batched`` kernel."""
    fb = jax.vmap(lambda ti, zi: f(ti, zi, *args))
    if use_pallas and _is_flat_batched(z):
        from repro.kernels import ops
        u = ops.rk_stage_increment_batched(z, v[None], h, (0.5,))
        w = fb(_alf_midpoint_t(t, h), u)
        v_next = 2.0 * w - v
        z_next = ops.rk_stage_increment_batched(u, v_next[None], h, (0.5,))
        return z_next, v_next
    half = jax.tree.map(lambda vl: 0.5 * vl, v)
    u = _tree_baxpy(h, half, z)
    w = fb(_alf_midpoint_t(t, h), u)
    v_next = jax.tree.map(lambda wl, vl: 2.0 * wl - vl, w, v)
    z_next = _tree_baxpy(h, jax.tree.map(lambda vl: 0.5 * vl, v_next), u)
    return z_next, v_next
