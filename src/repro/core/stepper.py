"""Generic explicit Runge-Kutta step  ψ_h(t, z)  over arbitrary pytrees.

One ``rk_step`` evaluates all stages of a tableau and returns the advanced
state plus (for embedded pairs) the local error estimate.  This is the ψ of
the paper's Algorithm 1; every gradient method (naive / adjoint / ACA) calls
the same stepper so forward trajectories are bit-identical across methods.

Two execution paths, selected per call:

* **Flat-array fast path** (``use_pallas=True`` *and* the state is a
  single 1-D inexact array): the stage accumulations  z + h·Σ a_ij k_j,
  the solution/error combine and — when ``err_scale=(rtol, atol)`` is
  given — the scaled error norm of ``error_ratio`` are each one fused
  Pallas kernel (``repro.kernels.rk_stage``), cutting the memory-bound
  traffic of the trial loop roughly in half.  The fused norm is returned
  as ``StepResult.err_ratio`` so the accept/reject loop skips its extra
  full-array pass.  The kernels are wrapped in custom_vjp (backward =
  the bit-matching jnp twin), so this path is differentiable and legal
  inside the ACA backward replay and the naive method's scan.
* **Pytree fallback** (default): pure ``jax.tree`` arithmetic over any
  state structure/dtype mix; ``err_ratio`` is None and callers compute
  ``error_ratio`` themselves.

``flatten_problem`` is the per-solve adapter: it ravels a pytree state
once (one ``ravel_pytree`` per solve, not per step), wraps the vector
field to operate on the flat vector, and hands back the unravel for the
outputs — solver loops then carry a single (N,) array, which also
shrinks the while_loop carry the checkpoint writer updates every trial.
States with mixed or non-inexact dtypes return None and stay on the
pytree path.

``rk_step_batched`` is the per-sample batched twin of ``rk_step`` for
``odeint(..., batch_axis=0)``: every leaf carries a leading batch dim B,
``t`` and ``h`` are (B,) — each element takes ψ with its *own* time and
trial stepsize — and ``err_ratio`` is (B,), one scaled error norm per
element.  ``maybe_flatten_batched`` is the matching fallback rule: the
fused path carries a (B, N) array through the batched Pallas kernels.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from .tableaus import Tableau

PyTree = Any
VecField = Callable[..., PyTree]  # f(t, z, *args) -> dz/dt


def _tree_axpy(alpha, x: PyTree, y: PyTree) -> PyTree:
    """y + alpha * x elementwise over pytrees, preserving y's dtype
    (an f32 stepsize scalar must not upcast a bf16 model state)."""
    return jax.tree.map(
        lambda xi, yi: yi + (alpha * xi).astype(yi.dtype), x, y)


def _weighted_sum(ks: Tuple[PyTree, ...], ws) -> PyTree:
    """Σ_i ws[i] * ks[i] over pytrees, skipping exact-zero weights."""
    acc = None
    for w, k in zip(ws, ks):
        if isinstance(w, float) and w == 0.0:
            continue
        term = jax.tree.map(lambda ki: w * ki, k)
        acc = term if acc is None else jax.tree.map(jnp.add, acc, term)
    if acc is None:
        acc = jax.tree.map(jnp.zeros_like, ks[0])
    return acc


class StepResult(NamedTuple):
    z_next: PyTree
    err: Optional[PyTree]  # local error estimate (None for fixed-step)
    k_last: PyTree         # last stage derivative (FSAL reuse)
    # scaled error norm from the fused kernel (flat fast path with
    # err_scale only); None -> caller computes error_ratio itself
    err_ratio: Optional[jnp.ndarray] = None
    # dense-output extras (``dense=True`` only): the first-stage
    # derivative actually used (k0 input or freshly computed) and — for
    # tableaus carrying ``b_mid`` — the step-midpoint solution
    # z + h·Σ b_mid_i k_i.  Feed ``interp_fit``.
    k_first: Optional[PyTree] = None
    z_mid: Optional[PyTree] = None


def _is_flat_array(z: PyTree) -> bool:
    return (isinstance(z, jax.Array) and z.ndim == 1
            and jnp.issubdtype(z.dtype, jnp.inexact))


def flatten_problem(f: VecField, z0: PyTree):
    """Per-solve flat-state adapter for the fused kernel path.

    Returns ``(f_flat, z0_flat, unravel)`` — the vector field over the
    raveled (N,) state, the raveled initial state, and the inverse map
    for outputs/checkpoints — or None when the state cannot be raveled
    losslessly (mixed dtypes would be promoted, non-inexact leaves have
    no kernel path); callers then fall back to the pytree path.
    """
    leaves = jax.tree.leaves(z0)
    if not leaves:
        return None
    try:
        dtypes = {jnp.result_type(leaf) for leaf in leaves}
    except TypeError:
        return None
    if len(dtypes) != 1 or not jnp.issubdtype(dtypes.pop(), jnp.inexact):
        return None
    z0_flat, unravel = ravel_pytree(z0)

    def f_flat(t, zf, *args):
        return ravel_pytree(f(t, unravel(zf), *args))[0]

    return f_flat, z0_flat, unravel


def maybe_flatten(f: VecField, z0: PyTree, use_pallas: bool):
    """Flag-gated ``flatten_problem``: the one fallback rule shared by
    every solver entry point.

    Returns ``(f, z0, unravel, use_pallas)`` — the flat problem with
    ``use_pallas=True`` when raveling is possible and requested, else
    the inputs unchanged with ``unravel=None`` and ``use_pallas=False``
    (pytree path).
    """
    flat = flatten_problem(f, z0) if use_pallas else None
    if flat is None:
        return f, z0, None, False
    f_flat, z0_flat, unravel = flat
    return f_flat, z0_flat, unravel, True


def _rk_step_flat(
    tab: Tableau,
    f: VecField,
    t,
    z: jnp.ndarray,
    h,
    args: Tuple,
    k0: Optional[jnp.ndarray],
    err_scale: Optional[Tuple[float, float]],
    dense: bool = False,
) -> StepResult:
    """Fused-kernel ψ over a flat (N,) state (see module docstring)."""
    # deferred: importing repro.kernels at module scope would cycle
    # through kernels.ref -> repro.models -> repro.core
    from repro.kernels import ops

    k0v = k0 if k0 is not None else f(t, z, *args)
    ks = jnp.zeros((tab.stages,) + z.shape, k0v.dtype).at[0].set(k0v)
    for i in range(1, tab.stages):
        zi = ops.rk_stage_increment(z, ks[:i], h, tab.a[i])
        ks = ks.at[i].set(f(t + tab.c[i] * h, zi, *args))

    ratio = None
    if tab.b_err is not None and err_scale is not None:
        rtol, atol = err_scale
        # with_err=False: the accept/reject loop reads only z_next and
        # the fused norm — the (N,) err buffer is never materialized
        z_next, err, sq_sum = ops.rk_stage_combine_err(
            z, ks, h, tab.b, tab.b_err, rtol, atol, with_err=False)
        ratio = jnp.sqrt(sq_sum / z.size)
    else:
        # no consumer for err here (fixed tableaus have none; the ACA
        # backward replay reads only z_next): the solution combine is
        # the increment kernel with the b row — skips the N-sized err
        # store on this memory-bound loop
        z_next = ops.rk_stage_increment(z, ks, h, tab.b)
        err = None
    k_last = ks[-1] if tab.fsal else ks[0]
    k_first = z_mid = None
    if dense:
        k_first = k0v
        if tab.b_mid is not None:
            # the midpoint combine is the increment kernel with b_mid
            z_mid = ops.rk_stage_increment(z, ks, h, tab.b_mid)
    return StepResult(z_next=z_next, err=err, k_last=k_last,
                      err_ratio=ratio, k_first=k_first, z_mid=z_mid)


def rk_step(
    tab: Tableau,
    f: VecField,
    t,
    z: PyTree,
    h,
    args: Tuple = (),
    k0: Optional[PyTree] = None,
    *,
    use_pallas: bool = False,
    err_scale: Optional[Tuple[float, float]] = None,
    dense: bool = False,
) -> StepResult:
    """One explicit RK step of ``tab`` from (t, z) with stepsize h.

    ``k0`` optionally supplies the first stage derivative (FSAL).
    Returns z_{n+1}, the embedded error estimate (h·Σ b_err_i k_i) and the
    final stage derivative for FSAL chaining.

    ``use_pallas=True`` dispatches to the fused Pallas kernels when the
    state is a single flat inexact array (see ``flatten_problem``);
    other states silently take the pytree path.  With ``err_scale=(rtol,
    atol)`` the fused path additionally returns the scaled error norm in
    ``StepResult.err_ratio``; *without* err_scale the fused path returns
    ``err=None`` even for embedded tableaus (the err buffer is not
    materialized — adaptive callers always pass err_scale).

    ``dense=True`` additionally returns the dense-output inputs of
    ``interp_fit``: ``k_first`` (the stage-0 derivative this step
    consumed) and, for tableaus with ``b_mid``, the midpoint solution
    ``z_mid = z + h·Σ b_mid_i k_i``.  The advancing arithmetic is
    untouched — z_next is bit-identical with and without ``dense``.
    """
    if use_pallas and _is_flat_array(z):
        return _rk_step_flat(tab, f, t, z, h, args, k0, err_scale,
                             dense=dense)
    ks = []
    for i in range(tab.stages):
        if i == 0:
            ki = k0 if k0 is not None else f(t, z, *args)
        else:
            zi = z
            incr = _weighted_sum(tuple(ks), tab.a[i])
            zi = _tree_axpy(h, incr, z)
            ki = f(t + tab.c[i] * h, zi, *args)
        ks.append(ki)
    ks = tuple(ks)

    z_next = _tree_axpy(h, _weighted_sum(ks, tab.b), z)

    err = None
    if tab.b_err is not None:
        err = jax.tree.map(lambda e: h * e, _weighted_sum(ks, tab.b_err))

    if tab.fsal:
        k_last = ks[-1]
    else:
        k_last = ks[0]
    k_first = z_mid = None
    if dense:
        k_first = ks[0]
        if tab.b_mid is not None:
            z_mid = _tree_axpy(h, _weighted_sum(ks, tab.b_mid), z)
    return StepResult(z_next=z_next, err=err, k_last=k_last,
                      k_first=k_first, z_mid=z_mid)


def _is_flat_batched(z: PyTree) -> bool:
    return (isinstance(z, jax.Array) and z.ndim == 2
            and jnp.issubdtype(z.dtype, jnp.inexact))


def maybe_flatten_batched(f: VecField, z0: PyTree, use_pallas: bool):
    """Batched twin of ``maybe_flatten``: ``z0`` leaves carry a leading
    batch dim B and ``f`` is the *per-sample* vector field.

    Returns ``(f, z0, unravel, use_pallas)``: on success ``f`` is the
    per-sample field over the raveled (N,) state, ``z0`` the (B, N)
    batch of raveled states and ``unravel`` the per-sample inverse map
    (vmap it over outputs); otherwise the inputs come back unchanged
    with ``unravel=None`` and ``use_pallas=False`` (same fallback rules
    as ``flatten_problem``: single inexact dtype or bust).
    """
    if not use_pallas:
        return f, z0, None, False
    sample = jax.tree.map(lambda l: l[0], z0)
    flat = flatten_problem(f, sample)
    if flat is None:
        return f, z0, None, False
    f_flat, _, unravel = flat
    z0_flat = jax.vmap(lambda z: ravel_pytree(z)[0])(z0)
    return f_flat, z0_flat, unravel, True


def _tree_baxpy(h, x: PyTree, y: PyTree) -> PyTree:
    """Per-row y + h_b * x over batch-leading pytrees, h of shape (B,)."""
    return jax.tree.map(
        lambda xi, yi: yi + (h.reshape((-1,) + (1,) * (xi.ndim - 1))
                             * xi).astype(yi.dtype), x, y)


def _rk_step_flat_batched(
    tab: Tableau,
    fb: Callable,
    t: jnp.ndarray,
    z: jnp.ndarray,
    h: jnp.ndarray,
    k0: Optional[jnp.ndarray],
    err_scale: Optional[Tuple[float, float]],
    dense: bool = False,
) -> StepResult:
    """Fused batched ψ over a (B, N) state: per-row stepsizes, per-row
    error norms.  ``fb`` maps ((B,), (B, N)) -> (B, N)."""
    from repro.kernels import ops

    k0v = k0 if k0 is not None else fb(t, z)
    ks = jnp.zeros((tab.stages,) + z.shape, k0v.dtype).at[0].set(k0v)
    for i in range(1, tab.stages):
        zi = ops.rk_stage_increment_batched(z, ks[:i], h, tab.a[i])
        ks = ks.at[i].set(fb(t + tab.c[i] * h, zi))

    ratio = None
    if tab.b_err is not None and err_scale is not None:
        rtol, atol = err_scale
        z_next, sq_sum = ops.rk_stage_combine_err_batched(
            z, ks, h, tab.b, tab.b_err, rtol, atol)
        ratio = jnp.sqrt(sq_sum / z.shape[-1])
        err = None
    else:
        z_next = ops.rk_stage_increment_batched(z, ks, h, tab.b)
        err = None
    k_last = ks[-1] if tab.fsal else ks[0]
    k_first = z_mid = None
    if dense:
        k_first = k0v
        if tab.b_mid is not None:
            z_mid = ops.rk_stage_increment_batched(z, ks, h, tab.b_mid)
    return StepResult(z_next=z_next, err=err, k_last=k_last,
                      err_ratio=ratio, k_first=k_first, z_mid=z_mid)


def rk_step_batched(
    tab: Tableau,
    f: VecField,
    t: jnp.ndarray,
    z: PyTree,
    h: jnp.ndarray,
    args: Tuple = (),
    k0: Optional[PyTree] = None,
    *,
    use_pallas: bool = False,
    err_scale: Optional[Tuple[float, float]] = None,
    dense: bool = False,
) -> StepResult:
    """One explicit RK step per batch element: ψ_{h_b}(t_b, z_b) for all
    b at once.

    ``f`` is the per-sample vector field (no batch dim); leaves of ``z``
    carry a leading batch dim B; ``t`` and ``h`` are (B,).  With
    ``err_scale=(rtol, atol)`` the result's ``err_ratio`` is the (B,)
    vector of per-element scaled error norms (then ``err`` is None — no
    consumer).  An element whose h_b is 0 passes through unchanged
    bit-exactly: the masking contract the batched adaptive loop and the
    ACA batched backward sweep use to freeze finished elements.

    ``use_pallas=True`` dispatches (B, N) inexact states to the batched
    fused kernels; other states take the vmapped pytree path.
    ``dense=True`` as in ``rk_step`` (per-row ``k_first`` / ``z_mid``).
    """
    fb = jax.vmap(lambda ti, zi: f(ti, zi, *args))
    if use_pallas and _is_flat_batched(z):
        return _rk_step_flat_batched(tab, fb, t, z, h, k0, err_scale,
                                     dense=dense)

    ks = []
    for i in range(tab.stages):
        if i == 0:
            ki = k0 if k0 is not None else fb(t, z)
        else:
            incr = _weighted_sum(tuple(ks), tab.a[i])
            zi = _tree_baxpy(h, incr, z)
            ki = fb(t + tab.c[i] * h, zi)
        ks.append(ki)
    ks = tuple(ks)

    z_next = _tree_baxpy(h, _weighted_sum(ks, tab.b), z)

    err = None
    ratio = None
    if tab.b_err is not None:
        err = jax.tree.map(
            lambda e: h.reshape((-1,) + (1,) * (e.ndim - 1)) * e,
            _weighted_sum(ks, tab.b_err))
        if err_scale is not None:
            rtol, atol = err_scale
            ratio = jax.vmap(
                lambda e, a, b: error_ratio(e, a, b, rtol, atol))(
                    err, z, z_next)
            err = None

    k_last = ks[-1] if tab.fsal else ks[0]
    k_first = z_mid = None
    if dense:
        k_first = ks[0]
        if tab.b_mid is not None:
            z_mid = _tree_baxpy(h, _weighted_sum(ks, tab.b_mid), z)
    return StepResult(z_next=z_next, err=err, k_last=k_last,
                      err_ratio=ratio, k_first=k_first, z_mid=z_mid)


def error_ratio(err: PyTree, z0: PyTree, z1: PyTree, rtol: float,
                atol: float):
    """RMS norm of err scaled by atol + rtol*max(|z0|,|z1|) (Hairer I.4).

    Returns a scalar; an accepted step has ratio <= 1.
    """
    def _scaled_sq(e, a, b):
        scale = atol + rtol * jnp.maximum(jnp.abs(a), jnp.abs(b))
        r = (e / scale).astype(jnp.float32)
        return jnp.sum(r * r), r.size

    leaves_sq, sizes = zip(*(
        _scaled_sq(e, a, b)
        for e, a, b in zip(jax.tree.leaves(err), jax.tree.leaves(z0),
                           jax.tree.leaves(z1))
    ))
    total = sum(leaves_sq)
    n = sum(sizes)
    return jnp.sqrt(total / n)


# --------------------------------------------------------------------------
# Dense output: per-step polynomial interpolants
# --------------------------------------------------------------------------
#
# Every accepted step carries enough information for a local polynomial
# z(t + θh) ≈ P(θ), θ ∈ [0, 1], built from quantities the solver loop
# already computed:
#
#   * cubic Hermite (any tableau): endpoints z0, z1 and endpoint
#     derivatives k0 = f(t, z0), k1 = f(t+h, z1) — both free: k0 is the
#     first stage, k1 is the FSAL last stage (or the post-accept k0'
#     recompute for non-FSAL pairs).  Local error O(h⁴).
#   * quartic fit (tableaus with ``b_mid``, i.e. Dopri5): adds the
#     midpoint solution z_mid = z0 + h·Σ b_mid_i k_i, giving the classic
#     4th-order dense output whose error tracks the pair's tolerance.
#
# Both are expressed as one coefficient 5-tuple (c4..c0) with
# P(θ) = (((c4·θ + c3)·θ + c2)·θ + c1)·θ + c0, so downstream code
# (interpolated eval-time reads, DenseSolution storage, the ACA backward
# sweep's interpolated-output vjp) handles one representation.  P(0) is
# z0 *bitwise* (c0 = z0); P(1) recovers z1 algebraically.


class InterpCoeffs(NamedTuple):
    """Polynomial coefficients of one step interpolant (pytrees, highest
    degree first): P(θ) = c4·θ⁴ + c3·θ³ + c2·θ² + c1·θ + c0."""
    c4: PyTree
    c3: PyTree
    c2: PyTree
    c1: PyTree
    c0: PyTree


def _hb(h, leaf):
    """Reshape h (scalar or (B,)) to broadcast against a state leaf,
    cast to the leaf dtype (a float64 time grid under JAX_ENABLE_X64
    must not upcast a float32 state — same rule as ``_tree_axpy``)."""
    h = jnp.asarray(h, leaf.dtype)
    return h.reshape(h.shape + (1,) * (leaf.ndim - h.ndim))


def interp_fit(z0: PyTree, z1: PyTree, k0: PyTree, k1: PyTree, h,
               z_mid: Optional[PyTree] = None) -> InterpCoeffs:
    """Fit the step interpolant from endpoint (and midpoint) data.

    ``h`` is the accepted stepsize — a scalar, or (B,) for batch-leading
    pytrees (per-row steps).  With ``z_mid`` (tableaus carrying
    ``b_mid``) this is the 4th-order quartic fit matching z0, z1, z_mid,
    k0 and k1; without it, the cubic Hermite through z0, z1, k0, k1
    (c4 = 0).  All arithmetic is plain jnp — differentiable everywhere,
    including under the ACA backward sweep's local vjp.
    """
    # h·k cast to the STATE leaf dtype (not k's): under x64 a float64
    # time can promote f's output, and the coefficients must match z —
    # the _tree_axpy convention
    hk0 = jax.tree.map(lambda k, z: (_hb(h, z) * k).astype(z.dtype),
                       k0, z0)
    hk1 = jax.tree.map(lambda k, z: (_hb(h, z) * k).astype(z.dtype),
                       k1, z0)
    if z_mid is None:
        c4 = jax.tree.map(jnp.zeros_like, z0)
        c3 = jax.tree.map(
            lambda a, b, p, q: 2.0 * (a - b) + p + q, z0, z1, hk0, hk1)
        c2 = jax.tree.map(
            lambda a, b, p, q: 3.0 * (b - a) - 2.0 * p - q,
            z0, z1, hk0, hk1)
    else:
        c4 = jax.tree.map(
            lambda p, q, a, b, m: 2.0 * (q - p) - 8.0 * (a + b)
            + 16.0 * m, hk0, hk1, z0, z1, z_mid)
        c3 = jax.tree.map(
            lambda p, q, a, b, m: 5.0 * p - 3.0 * q + 18.0 * a
            + 14.0 * b - 32.0 * m, hk0, hk1, z0, z1, z_mid)
        c2 = jax.tree.map(
            lambda p, q, a, b, m: q - 4.0 * p - 11.0 * a - 5.0 * b
            + 16.0 * m, hk0, hk1, z0, z1, z_mid)
    return InterpCoeffs(c4=c4, c3=c3, c2=c2, c1=hk0, c0=z0)


def interp_eval(coeffs: InterpCoeffs, theta: jnp.ndarray) -> PyTree:
    """Evaluate P at ``theta``, stacking theta's *leading* axis onto the
    output: theta (T,) over solo leaves (...) -> (T, ...); theta (T, B)
    over batch-leading leaves (B, ...) -> (T, B, ...)."""
    def ev(c4, c3, c2, c1, c0):
        th = theta.astype(c0.dtype).reshape(
            theta.shape + (1,) * (c0.ndim - (theta.ndim - 1)))
        return (((c4 * th + c3) * th + c2) * th + c1) * th + c0

    return jax.tree.map(ev, *coeffs)


def interp_eval_aligned(coeffs: InterpCoeffs,
                        theta: jnp.ndarray) -> PyTree:
    """Evaluate P elementwise: theta's axes align with the *leading*
    leaf axes (theta (T,) over leaves (T, ...) -> (T, ...)).  Used by
    ``DenseSolution.evaluate`` after gathering per-query coefficients."""
    def ev(c4, c3, c2, c1, c0):
        th = theta.astype(c0.dtype).reshape(
            theta.shape + (1,) * (c0.ndim - theta.ndim))
        return (((c4 * th + c3) * th + c2) * th + c1) * th + c0

    return jax.tree.map(ev, *coeffs)


def fixed_step_fn(tab: Tableau, f: VecField) -> Callable:
    """Returns step(t, z, h, args) -> z_next for fixed-grid integration."""
    def step(t, z, h, args=()):
        return rk_step(tab, f, t, z, h, args).z_next
    return step
