"""Adaptive Checkpoint Adjoint (ACA) — the paper's contribution, in JAX.

Forward pass (paper Algorithm 2 / Appendix A):
  * integrate with the adaptive solver (``adaptive_while_solve``); the
    stepsize search happens inside a ``lax.while_loop`` and is therefore
    *structurally* excluded from differentiation — the JAX realization of
    "delete local computation graphs to search for optimal stepsize";
  * keep only the accepted discretization points {t_i}, stepsizes
    {h_i = t_{i+1} - t_i} and states {z_i} in a fixed-capacity trajectory
    checkpoint buffer:  memory O(N_f + N_t).

Backward pass:
  * initialize λ(T) = ∂J/∂z(T)  (Eq. 6; we carry +∂J/∂z, the sign
    convention of Appendix A's  λ = -∂J/∂z(T)  is folded into the update);
  * walk the saved grid in reverse; for each interval re-take ONE local
    step ψ(t_i, z_i, h_i) with the saved stepsize (no search — the paper's
    "m+1"-th evaluation), back-propagate through it with ``jax.vjp``, and
    update λ and dL/dθ (discretized Eq. 7 / Eq. 8);
  * the local graph is freed after each step: depth O(N_f), total
    computation O(N_f · N_t · (m+1)).

Because the reverse sweep replays the *forward* trajectory exactly, the
gradient equals the true gradient of the numerical solution
(discretize-then-optimize) — no reverse-time re-integration error
(Theorem 3.2's e_k pathology does not arise).

Memory-bounded mode (``checkpoint_segments=K``): the forward keeps only
K coarse state snapshots (the scalar grid still covers every step) and
the backward re-integrates each segment from its snapshot with the
*saved* stepsizes before replaying it in reverse — state memory drops
from O(N_f) to O(K + N_f/K) at ~1 extra ψ per step, with gradients
bit-identical to the full buffer (no re-search, so the replayed
trajectory is the forward trajectory).  See ``docs/memory.md``.

Sharding contract (relied on by ``odeint(..., mesh=...)``): the batched
engine's forward search, checkpoint buffer and backward replay touch
each batch row independently — no cross-element reduction anywhere —
so a batch shard replays **shard-local** under ``shard_map`` and the
only cross-device traffic is the psum of the shared-``args`` cotangent
inserted by the transpose.  See ``docs/distributed.md``.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .controller import ControllerConfig
from .integrate import (
    Checkpoints,
    SolveStats,
    SolveStatus,
    _as_tuple,
    _buffer_slot,
    _bwhere,
    _mask_failed_cotangents,
    _nonfinite_any,
    adaptive_while_solve,
    batched_adaptive_while_solve,
    make_fixed_grid,
    resolve_segmentation,
)
from .stepper import (
    interp_eval,
    interp_fit,
    maybe_flatten,
    maybe_flatten_batched,
    rk_step,
    rk_step_batched,
)
from .tableaus import Tableau

PyTree = Any


def _local_step_dense(tab, f, t_i, h_i, z_i, a, ts, use_pallas):
    """Replay one ψ with the saved stepsize AND rebuild its interpolant,
    evaluated at every eval time (natural-grid ACA backward).

    Returns (z_next, y_all) with ``y_all`` leaves (n_eval, ...): the
    interval's interpolant read at all of ``ts`` (θ clipped to [0, 1];
    out-of-interval slots get masked-zero cotangents by the caller, so
    their values are irrelevant but finite).  The recomputed k0/k1 are
    bit-identical to the forward's FSAL-chained carries, so the
    interpolant equals the forward interpolant bitwise.
    """
    targs = _as_tuple(a)
    res = rk_step(tab, f, t_i, z_i, h_i, targs, use_pallas=use_pallas,
                  dense=True)
    if tab.fsal:
        k1 = res.k_last
    else:
        k1 = f(t_i + h_i, res.z_next, *targs)
    coeffs = interp_fit(z_i, res.z_next, res.k_first, k1, h_i, res.z_mid)
    tiny = jnp.asarray(jnp.finfo(ts.dtype).eps, ts.dtype)
    theta = jnp.clip((ts - t_i) / jnp.maximum(h_i, tiny), 0.0, 1.0)
    return res.z_next, interp_eval(coeffs, theta)


def _mask_cotangents(g_ys: PyTree, mask: jnp.ndarray) -> PyTree:
    """Zero every g_ys slot outside ``mask`` (mask aligns with the
    leading eval axis — or (n_eval, B) for batched cotangents)."""
    return jax.tree.map(
        lambda g: jnp.where(
            mask.reshape(mask.shape + (1,) * (g.ndim - mask.ndim)),
            g, jnp.zeros((), g.dtype)),
        g_ys)


def _aca_backward_sweep(
    tab: Tableau,
    f: Callable,
    ckpts: Checkpoints,
    args: PyTree,
    g_ys: PyTree,
    n_steps,
    use_pallas: bool = False,
    ts: Optional[jnp.ndarray] = None,
):
    """Reverse sweep over the trajectory checkpoints.

    Returns (dL/dz0, dL/dargs).  ``g_ys`` are the output cotangents, one
    slot per eval time (g_ys[k] injected into λ when the sweep crosses
    eval time ts[k]).  ``use_pallas`` replays each local ψ through the
    fused flat-state kernels (their custom_vjp makes them legal under
    the jax.vjp below).

    Natural-grid checkpoints (``ckpts.ev_lo`` present; requires ``ts``)
    additionally route the cotangents of *interpolated* outputs through
    each interval's rebuilt interpolant: the local vjp differentiates
    (z_i, args) ↦ (z_next, interpolated y's), with g_ys masked to the
    interval's recorded [ev_lo, ev_hi) eval range.
    """
    interp = ckpts.ev_lo is not None

    def local_step(t_i, h_i, z_i, a):
        # one ψ with the SAVED stepsize; k0 recomputed so its gradient flows
        return rk_step(tab, f, t_i, z_i, h_i, _as_tuple(a),
                       use_pallas=use_pallas).z_next

    lam0 = jax.tree.map(jnp.zeros_like, _buffer_slot(g_ys, 0))
    gargs0 = jax.tree.map(jnp.zeros_like, args)
    karr = jnp.arange(jax.tree.leaves(g_ys)[0].shape[0])

    def body(j, carry):
        lam, gargs = carry
        i = n_steps - 1 - j
        t_i = ckpts.t[i]
        h_i = ckpts.h[i]
        z_i = jax.tree.map(lambda b: b[i], ckpts.z)
        oi = ckpts.out_idx[i]

        # inject the cotangent of any output that lands on this interval's
        # endpoint:  λ(t_{i+1}) += ∂J/∂y_k
        def add_out(lam):
            g_k = jax.tree.map(lambda g: g[oi], g_ys)
            return jax.tree.map(jnp.add, lam, g_k)

        lam = jax.lax.cond(oi >= 0, add_out, lambda l: l, lam)

        # local forward + local backward (paper Algorithm 2, backward-pass)
        if interp:
            mask = (karr >= ckpts.ev_lo[i]) & (karr < ckpts.ev_hi[i])
            _, vjp_fn = jax.vjp(
                lambda z, a: _local_step_dense(tab, f, t_i, h_i, z, a,
                                               ts, use_pallas), z_i, args)
            dlam, dargs = vjp_fn((lam, _mask_cotangents(g_ys, mask)))
        else:
            _, vjp_fn = jax.vjp(lambda z, a: local_step(t_i, h_i, z, a),
                                z_i, args)
            dlam, dargs = vjp_fn(lam)
        gargs = jax.tree.map(jnp.add, gargs, dargs)
        return (dlam, gargs)

    lam, gargs = jax.lax.fori_loop(0, n_steps, body, (lam0, gargs0))
    # cotangent of ys[0] = z0 (identity path)
    lam = jax.tree.map(lambda l, g: l + g[0], lam, g_ys)
    return lam, gargs


def _aca_backward_sweep_segmented(
    tab: Tableau,
    f: Callable,
    ckpts: Checkpoints,
    args: PyTree,
    g_ys: PyTree,
    n_steps,
    seg_len: int,
    use_pallas: bool = False,
    ts: Optional[jnp.ndarray] = None,
):
    """Segmented (O(K)-state) reverse sweep: ``checkpoint_segments=K``.

    ``ckpts.z`` holds only K coarse snapshots (slot s = state at
    accepted step ``s * seg_len``, with the matching first-stage
    derivative carry in ``ckpts.k0``); the scalar grids ``t``/``h``/
    ``out_idx`` still cover every accepted step.  Walking segments last
    to first, each segment is first re-integrated forward from its
    snapshot with the *saved* stepsizes and re-chained FSAL first-stage
    reuse (no stepsize search, same k0 carry — replayed ψ steps are
    bit-identical to the forward solve, so the discretize-then-optimize
    gradient is bit-identical to the full-buffer sweep), filling a
    ``seg_len``-slot local state buffer; then its local ψ steps are
    replayed in reverse exactly as in ``_aca_backward_sweep``.  Peak
    state memory is O(K + seg_len) = O(K + N_f/K) instead of O(N_f),
    for one extra ψ per accepted step.

    Natural-grid checkpoints (``ckpts.ev_lo`` present; requires ``ts``)
    route interpolated-output cotangents through each replayed
    interval's interpolant, exactly as in ``_aca_backward_sweep``.

    Returns (dL/dz0, dL/dargs).
    """
    interp = ckpts.ev_lo is not None

    def local_step(t_i, h_i, z_i, a):
        # one ψ with the SAVED stepsize; k0 recomputed so its gradient flows
        return rk_step(tab, f, t_i, z_i, h_i, _as_tuple(a),
                       use_pallas=use_pallas).z_next

    lam0 = jax.tree.map(jnp.zeros_like, _buffer_slot(g_ys, 0))
    gargs0 = jax.tree.map(jnp.zeros_like, args)
    karr = jnp.arange(jax.tree.leaves(g_ys)[0].shape[0])
    # the O(seg_len) replay buffer — the N_f/K term of the cost model
    zbuf0 = jax.tree.map(
        lambda b: jnp.zeros((seg_len,) + b.shape[1:], b.dtype), ckpts.z)
    n_segments = (n_steps + seg_len - 1) // seg_len
    targs = _as_tuple(args)

    def seg_body(jseg, carry):
        lam, gargs = carry
        s = n_segments - 1 - jseg
        i0 = s * seg_len
        i1 = jnp.minimum(i0 + seg_len, n_steps)
        cnt = i1 - i0

        # --- forward re-integration of segment s from its snapshot ----
        # the k0 carry chains exactly as in adaptive_while_solve (FSAL
        # reuse / post-accept recompute), so every replayed state is the
        # forward state bitwise
        z_start = _buffer_slot(ckpts.z, s)
        k0_start = _buffer_slot(ckpts.k0, s)

        def fwd_body(q, zc):
            z, k0, zbuf = zc
            i = i0 + q
            t_i, h_i = ckpts.t[i], ckpts.h[i]
            zbuf = jax.tree.map(lambda b, v: b.at[q].set(v), zbuf, z)
            res = rk_step(tab, f, t_i, z, h_i, targs, k0=k0,
                          use_pallas=use_pallas)
            if tab.fsal:
                k0_new = res.k_last
            else:
                k0_new = f(t_i + h_i, res.z_next, *targs)
            return (res.z_next, k0_new, zbuf)

        _, _, zbuf = jax.lax.fori_loop(
            0, cnt, fwd_body, (z_start, k0_start, zbuf0))

        # --- reverse replay of the segment's local ψ steps ------------
        def rev_body(r, carry):
            lam, gargs = carry
            i = i1 - 1 - r
            t_i = ckpts.t[i]
            h_i = ckpts.h[i]
            z_i = _buffer_slot(zbuf, i - i0)
            oi = ckpts.out_idx[i]

            def add_out(lam):
                g_k = jax.tree.map(lambda g: g[oi], g_ys)
                return jax.tree.map(jnp.add, lam, g_k)

            lam = jax.lax.cond(oi >= 0, add_out, lambda l: l, lam)
            if interp:
                mask = (karr >= ckpts.ev_lo[i]) & (karr < ckpts.ev_hi[i])
                _, vjp_fn = jax.vjp(
                    lambda z, a: _local_step_dense(tab, f, t_i, h_i, z,
                                                   a, ts, use_pallas),
                    z_i, args)
                dlam, dargs = vjp_fn((lam, _mask_cotangents(g_ys, mask)))
            else:
                _, vjp_fn = jax.vjp(
                    lambda z, a: local_step(t_i, h_i, z, a), z_i, args)
                dlam, dargs = vjp_fn(lam)
            gargs = jax.tree.map(jnp.add, gargs, dargs)
            return (dlam, gargs)

        return jax.lax.fori_loop(0, cnt, rev_body, (lam, gargs))

    lam, gargs = jax.lax.fori_loop(0, n_segments, seg_body, (lam0, gargs0))
    # cotangent of ys[0] = z0 (identity path)
    lam = jax.tree.map(lambda l, g: l + g[0], lam, g_ys)
    return lam, gargs


def _local_step_dense_batched(tab, f, t_i, h_i, z_i, a, ts, use_pallas):
    """Batched twin of ``_local_step_dense``: per-row saved stepsizes,
    returns (z_next (B, ...), y_all (n_eval, B, ...)).  Frozen rows
    (h = 0) produce finite garbage interpolants whose cotangents the
    caller masks to zero."""
    targs = _as_tuple(a)
    res = rk_step_batched(tab, f, t_i, z_i, h_i, targs,
                          use_pallas=use_pallas, dense=True)
    if tab.fsal:
        k1 = res.k_last
    else:
        k1 = jax.vmap(lambda ti, zi: f(ti, zi, *targs))(t_i + h_i,
                                                        res.z_next)
    coeffs = interp_fit(z_i, res.z_next, res.k_first, k1, h_i, res.z_mid)
    tiny = jnp.asarray(jnp.finfo(ts.dtype).eps, ts.dtype)
    theta = jnp.clip(
        (ts[:, None] - t_i[None, :])
        / jnp.maximum(h_i, tiny)[None, :], 0.0, 1.0)    # (n_eval, B)
    return res.z_next, interp_eval(coeffs, theta)


def _aca_backward_sweep_batched(
    tab: Tableau,
    f: Callable,
    ckpts: Checkpoints,
    args: PyTree,
    g_ys: PyTree,
    n_steps,
    use_pallas: bool = False,
    ts: Optional[jnp.ndarray] = None,
):
    """Per-element reverse sweep: each batch element replays *its own*
    accepted checkpoint grid.

    ``ckpts`` rows are per element (t/h/out_idx (B, S), z (B, S, ...),
    n (B,)); ``g_ys`` leaves are (n_eval, B, ...).  The shared
    ``fori_loop`` runs max(n_steps) iterations; element b replays slot
    n_b - 1 - j at iteration j and is frozen with h = 0 once j ≥ n_b —
    the h = 0 local ψ is the exact identity in z (and contributes a zero
    cotangent to args), so short trajectories finish early without
    touching their λ.  Returns (dL/dz0 (B, ...), dL/dargs summed over
    the batch — args are shared).

    Natural-grid checkpoints (``ckpts.ev_lo`` present; requires ``ts``)
    route interpolated-output cotangents through each element's rebuilt
    per-interval interpolant, masked to that element's recorded
    [ev_lo, ev_hi) eval range.
    """
    B = n_steps.shape[0]
    rows = jnp.arange(B)
    interp = ckpts.ev_lo is not None

    def local_step(t_i, h_i, z_i, a):
        # one batched ψ with each element's SAVED stepsize (no search);
        # k0 recomputed so its gradient flows
        return rk_step_batched(tab, f, t_i, z_i, h_i, _as_tuple(a),
                               use_pallas=use_pallas).z_next

    lam0 = jax.tree.map(jnp.zeros_like, _buffer_slot(g_ys, 0))  # (B, ...)
    gargs0 = jax.tree.map(jnp.zeros_like, args)
    n_max = jnp.max(n_steps)
    karr = jnp.arange(jax.tree.leaves(g_ys)[0].shape[0])

    def body(j, carry):
        lam, gargs = carry
        i = n_steps - 1 - j                  # (B,), negative when done
        live = i >= 0
        i_c = jnp.maximum(i, 0)
        t_i = ckpts.t[rows, i_c]
        h_i = jnp.where(live, ckpts.h[rows, i_c],
                        jnp.zeros((), ckpts.h.dtype))
        z_i = jax.tree.map(lambda b: b[rows, i_c], ckpts.z)
        oi = jnp.where(live, ckpts.out_idx[rows, i_c], -1)

        # inject each element's output cotangent where its interval's
        # endpoint landed on an eval time:  λ_b(t_{i+1}) += ∂J/∂y_{oi_b}
        oi_c = jnp.maximum(oi, 0)
        lam = jax.tree.map(
            lambda l, g: l + jnp.where(
                (oi >= 0).reshape((-1,) + (1,) * (l.ndim - 1)),
                g[oi_c, rows], jnp.zeros_like(l)),
            lam, g_ys)

        # batched local forward + local backward; frozen rows are the
        # identity, so dlam == lam and dargs == 0 for them exactly
        if interp:
            mask = (live[None, :]
                    & (karr[:, None] >= ckpts.ev_lo[rows, i_c][None, :])
                    & (karr[:, None] < ckpts.ev_hi[rows, i_c][None, :]))
            _, vjp_fn = jax.vjp(
                lambda z, a: _local_step_dense_batched(
                    tab, f, t_i, h_i, z, a, ts, use_pallas), z_i, args)
            dlam, dargs = vjp_fn((lam, _mask_cotangents(g_ys, mask)))
        else:
            _, vjp_fn = jax.vjp(lambda z, a: local_step(t_i, h_i, z, a),
                                z_i, args)
            dlam, dargs = vjp_fn(lam)
        gargs = jax.tree.map(jnp.add, gargs, dargs)
        return (dlam, gargs)

    lam, gargs = jax.lax.fori_loop(0, n_max, body, (lam0, gargs0))
    # cotangent of ys[0] = z0 (identity path)
    lam = jax.tree.map(lambda l, g: l + g[0], lam, g_ys)
    return lam, gargs


def _aca_backward_sweep_segmented_batched(
    tab: Tableau,
    f: Callable,
    ckpts: Checkpoints,
    args: PyTree,
    g_ys: PyTree,
    n_steps,
    seg_len: int,
    use_pallas: bool = False,
    ts: Optional[jnp.ndarray] = None,
):
    """Batched segmented reverse sweep (``checkpoint_segments`` +
    ``batch_axis``).

    Elements record different step counts n_b, so their segment
    boundaries don't align.  To keep the gradient *bit-identical* to the
    full-buffer batched sweep, the replay windows are **end-aligned per
    element**: at global reverse iteration J = j·seg_len + r, element b
    replays its step n_b − 1 − J — exactly the pairing (and therefore
    the cross-batch dargs summation order) of
    ``_aca_backward_sweep_batched``.  Every ``seg_len`` iterations each
    element refills its local state buffer by re-integrating from the
    nearest *start-aligned* snapshot at or before its window (≤ 2·seg_len
    saved-stepsize ψ steps, since a window can straddle one snapshot
    stride), with finished elements frozen at h = 0 as usual.  Peak
    state memory O(B · (K + seg_len)); the re-integration costs at most
    2 ψ per accepted step.

    Natural-grid checkpoints (``ckpts.ev_lo`` present; requires ``ts``)
    route interpolated-output cotangents through each element's rebuilt
    per-interval interpolant, as in ``_aca_backward_sweep_batched``.

    Returns (dL/dz0 (B, ...), dL/dargs summed over the batch).
    """
    B = n_steps.shape[0]
    rows = jnp.arange(B)
    S = ckpts.t.shape[1]
    n_snap = jax.tree.leaves(ckpts.z)[0].shape[1]
    hdt = ckpts.h.dtype
    interp = ckpts.ev_lo is not None
    karr = jnp.arange(jax.tree.leaves(g_ys)[0].shape[0])

    def local_step(t_i, h_i, z_i, a):
        # one batched ψ with each element's SAVED stepsize (no search);
        # k0 recomputed so its gradient flows
        return rk_step_batched(tab, f, t_i, z_i, h_i, _as_tuple(a),
                               use_pallas=use_pallas).z_next

    lam0 = jax.tree.map(jnp.zeros_like, _buffer_slot(g_ys, 0))  # (B, ...)
    gargs0 = jax.tree.map(jnp.zeros_like, args)
    zbuf0 = jax.tree.map(
        lambda b: jnp.zeros((B, seg_len) + b.shape[2:], b.dtype), ckpts.z)
    n_max = jnp.max(n_steps)
    n_outer = (n_max + seg_len - 1) // seg_len
    targs = _as_tuple(args)

    def outer(j, carry):
        lam, gargs = carry
        g_hi = n_steps - j * seg_len             # (B,) window end (excl.)
        g_lo = jnp.maximum(g_hi - seg_len, 0)    # (B,) window start

        # --- refill: re-integrate [snapshot .. g_hi) per element ------
        # the k0 carry chains exactly as in batched_adaptive_while_solve
        # (FSAL reuse / post-accept recompute), so every replayed state
        # is that element's forward state bitwise
        s = jnp.clip(g_lo // seg_len, 0, n_snap - 1)
        a0 = s * seg_len                         # snapshot's global step
        z = jax.tree.map(lambda b: b[rows, s], ckpts.z)
        k0 = jax.tree.map(lambda b: b[rows, s], ckpts.k0)

        def fwd_body(q, zc):
            z, k0, zbuf = zc
            i = a0 + q                           # (B,)
            live = i < g_hi                      # done rows: g_hi <= 0
            i_c = jnp.minimum(i, S - 1)
            t_i = ckpts.t[rows, i_c]
            h_i = jnp.where(live, ckpts.h[rows, i_c], jnp.zeros((), hdt))
            in_win = live & (i >= g_lo)
            slot = jnp.clip(i - g_lo, 0, seg_len - 1)
            zbuf = jax.tree.map(
                lambda b, v: b.at[rows, slot].set(
                    _bwhere(in_win, v, b[rows, slot])), zbuf, z)
            # h = 0 makes ψ the exact identity for rows outside their
            # window, so the carry stays bit-stable without extra masking
            res = rk_step_batched(tab, f, t_i, z, h_i, targs, k0=k0,
                                  use_pallas=use_pallas)
            if tab.fsal:
                k0_new = res.k_last
            else:
                k0_new = jax.vmap(
                    lambda ti, zi: f(ti, zi, *targs))(t_i + h_i,
                                                      res.z_next)
            return (res.z_next, k0_new, zbuf)

        _, _, zbuf = jax.lax.fori_loop(0, 2 * seg_len, fwd_body,
                                       (z, k0, zbuf0))

        # --- reverse replay, global iteration J = j*seg_len + r -------
        def rev_body(r, carry):
            lam, gargs = carry
            i = n_steps - 1 - (j * seg_len + r)  # (B,), < 0 when done
            live = i >= 0
            i_c = jnp.maximum(i, 0)
            t_i = ckpts.t[rows, i_c]
            h_i = jnp.where(live, ckpts.h[rows, i_c], jnp.zeros((), hdt))
            slot = jnp.clip(i - g_lo, 0, seg_len - 1)
            z_i = jax.tree.map(lambda b: b[rows, slot], zbuf)
            oi = jnp.where(live, ckpts.out_idx[rows, i_c], -1)

            oi_c = jnp.maximum(oi, 0)
            lam = jax.tree.map(
                lambda l, g: l + jnp.where(
                    (oi >= 0).reshape((-1,) + (1,) * (l.ndim - 1)),
                    g[oi_c, rows], jnp.zeros_like(l)),
                lam, g_ys)

            if interp:
                mask = (live[None, :]
                        & (karr[:, None]
                           >= ckpts.ev_lo[rows, i_c][None, :])
                        & (karr[:, None]
                           < ckpts.ev_hi[rows, i_c][None, :]))
                _, vjp_fn = jax.vjp(
                    lambda z, a: _local_step_dense_batched(
                        tab, f, t_i, h_i, z, a, ts, use_pallas),
                    z_i, args)
                dlam, dargs = vjp_fn((lam, _mask_cotangents(g_ys, mask)))
            else:
                _, vjp_fn = jax.vjp(
                    lambda z, a: local_step(t_i, h_i, z, a), z_i, args)
                dlam, dargs = vjp_fn(lam)
            # all-frozen trailing iterations leave gargs bit-untouched
            any_live = jnp.any(live)
            gargs = jax.tree.map(
                lambda g, d: jnp.where(any_live, g + d, g), gargs, dargs)
            return (dlam, gargs)

        return jax.lax.fori_loop(0, seg_len, rev_body, (lam, gargs))

    lam, gargs = jax.lax.fori_loop(0, n_outer, outer, (lam0, gargs0))
    # cotangent of ys[0] = z0 (identity path)
    lam = jax.tree.map(lambda l, g: l + g[0], lam, g_ys)
    return lam, gargs


def odeint_aca_batched(
    f: Callable,
    z0: PyTree,
    ts: jnp.ndarray,
    args: PyTree = (),
    *,
    solver: Tableau,
    rtol: float = 1e-6,
    atol: float = 1e-6,
    cfg: Optional[ControllerConfig] = None,
    h0: Optional[jnp.ndarray] = None,
    use_pallas: bool = False,
    checkpoint_segments=None,
    interpolate_ts: bool = False,
) -> Tuple[PyTree, SolveStats]:
    """Per-sample batched ACA: ``odeint(..., batch_axis=0)``'s adaptive
    ACA path.

    ``z0`` leaves carry a leading batch dim B and ``f`` is the
    per-sample vector field.  Forward: ``batched_adaptive_while_solve``
    — every element records its own checkpoint grid.  Backward: each
    element's grid is replayed in reverse (``_aca_backward_sweep_batched``),
    so the per-element discretize-then-optimize property of ACA is
    preserved exactly — gradients match ``jax.vmap`` of the unbatched
    solver.  Returns (ys, stats) with ys leaves (len(ts), B, ...) and
    per-element stats.

    ``checkpoint_segments`` (int, ``"auto"`` or None) bounds per-element
    state memory to K snapshots + one seg_len replay buffer; the
    end-aligned segmented sweep keeps gradients bit-identical to the
    full buffer (see ``_aca_backward_sweep_segmented_batched``).

    ``interpolate_ts`` advances every element on its own natural grid
    and reads interior eval times off per-step interpolants; the
    backward sweeps route those outputs' cotangents through the rebuilt
    interpolants (see ``odeint_aca``).
    """
    if cfg is None:
        cfg = ControllerConfig()
    if not solver.adaptive:
        raise ValueError(
            "odeint_aca_batched requires an embedded adaptive tableau; "
            "fixed-grid solvers batch losslessly through odeint_aca_fixed")
    n_seg, seg_len = resolve_segmentation(checkpoint_segments,
                                          cfg.max_steps)

    f, z0, unravel, use_pallas = maybe_flatten_batched(f, z0, use_pallas)

    @jax.custom_vjp
    def solve(z0, args, ts):
        ys, _, stats = batched_adaptive_while_solve(
            solver, f, z0, ts, _as_tuple(args), rtol, atol, cfg,
            h0=h0, use_pallas=use_pallas, checkpoint_segments=n_seg,
            interpolate_ts=interpolate_ts)
        return ys, stats

    def solve_fwd(z0, args, ts):
        ys, ckpts, stats = batched_adaptive_while_solve(
            solver, f, z0, ts, _as_tuple(args), rtol, atol, cfg,
            h0=h0, use_pallas=use_pallas, checkpoint_segments=n_seg,
            interpolate_ts=interpolate_ts)
        return (ys, stats), (ckpts, args, ts, stats.status)

    def solve_bwd(res, cot):
        ckpts, args, ts, status = res
        g_ys, _g_stats = cot  # stats are integer outputs; cotangent ignored
        # failed elements: frozen placeholder outputs carry no gradient
        g_ys = _mask_failed_cotangents(g_ys, status, batched=True)
        if n_seg is None:
            dz0, dargs = _aca_backward_sweep_batched(
                solver, f, ckpts, args, g_ys, ckpts.n,
                use_pallas=use_pallas, ts=ts)
        else:
            dz0, dargs = _aca_backward_sweep_segmented_batched(
                solver, f, ckpts, args, g_ys, ckpts.n, seg_len,
                use_pallas=use_pallas, ts=ts)
        return dz0, dargs, jnp.zeros_like(ts)

    solve.defvjp(solve_fwd, solve_bwd)
    ys, stats = solve(z0, args, ts)
    if unravel is not None:
        ys = jax.vmap(jax.vmap(unravel))(ys)
    return ys, stats


def odeint_aca(
    f: Callable,
    z0: PyTree,
    ts: jnp.ndarray,
    args: PyTree = (),
    *,
    solver: Tableau,
    rtol: float = 1e-6,
    atol: float = 1e-6,
    cfg: Optional[ControllerConfig] = None,
    h0: Optional[jnp.ndarray] = None,
    use_pallas: bool = False,
    checkpoint_segments=None,
    interpolate_ts: bool = False,
) -> Tuple[PyTree, SolveStats]:
    """Solve dz/dt = f(t, z, *args) with ACA gradients.

    Returns (ys, stats) with ys stacked over ``ts`` (ys[0] = z0).
    Differentiable w.r.t. ``z0`` and ``args``; ``ts`` is treated as
    constant (the paper differentiates neither t nor the accepted h).

    ``use_pallas`` ravels the state once per solve and runs the trial
    loop, the checkpoint buffer and the backward replay on the fused
    flat-state kernel path; the ravel/unravel sit *outside* the
    custom_vjp so cotangents flow through them as plain jnp reshapes.

    ``checkpoint_segments`` (int K, ``"auto"`` or None) bounds the state
    checkpoint memory: the forward stores K snapshots instead of every
    accepted state and the backward re-integrates each segment from its
    snapshot with the saved stepsizes before replaying it — gradients
    are bit-identical to the full buffer at ~1 extra ψ per step (see
    ``docs/memory.md``).

    ``interpolate_ts`` advances on the controller's natural grid and
    reads interior eval times off each accepted step's interpolant
    (``stepper.interp_fit``) instead of forcing step landings; the
    backward sweep replays each interval *and* its interpolant, so the
    gradient is still the exact discretize-then-optimize gradient of
    the interpolated solution map.  ``ys[0]``/``ys[-1]`` remain exact
    solver states.
    """
    if cfg is None:
        cfg = ControllerConfig()

    if not solver.adaptive:
        raise ValueError(
            "odeint_aca requires an embedded adaptive tableau; use "
            "odeint_aca_fixed for fixed-grid solvers")
    n_seg, seg_len = resolve_segmentation(checkpoint_segments,
                                          cfg.max_steps)

    f, z0, unravel, use_pallas = maybe_flatten(f, z0, use_pallas)

    # ``ts`` is threaded as an explicit custom_vjp argument (closures over
    # trace-time values are illegal inside scan/grad — e.g. NODE blocks
    # inside a scanned layer stack).
    @jax.custom_vjp
    def solve(z0, args, ts):
        ys, _, stats = adaptive_while_solve(
            solver, f, z0, ts, _as_tuple(args), rtol, atol, cfg, h0=h0,
            use_pallas=use_pallas, checkpoint_segments=n_seg,
            interpolate_ts=interpolate_ts)
        return ys, stats

    def solve_fwd(z0, args, ts):
        ys, ckpts, stats = adaptive_while_solve(
            solver, f, z0, ts, _as_tuple(args), rtol, atol, cfg, h0=h0,
            use_pallas=use_pallas, checkpoint_segments=n_seg,
            interpolate_ts=interpolate_ts)
        return (ys, stats), (ckpts, args, ts, stats.status)

    def solve_bwd(res, cot):
        ckpts, args, ts, status = res
        g_ys, _g_stats = cot  # stats are integer outputs; cotangent ignored
        # a frozen (NONFINITE_STATE) solve's placeholder outputs carry
        # no gradient: zero the cotangents before the replay sweep
        g_ys = _mask_failed_cotangents(g_ys, status)
        if n_seg is None:
            dz0, dargs = _aca_backward_sweep(
                solver, f, ckpts, args, g_ys, ckpts.n,
                use_pallas=use_pallas, ts=ts)
        else:
            dz0, dargs = _aca_backward_sweep_segmented(
                solver, f, ckpts, args, g_ys, ckpts.n, seg_len,
                use_pallas=use_pallas, ts=ts)
        return dz0, dargs, jnp.zeros_like(ts)

    solve.defvjp(solve_fwd, solve_bwd)
    ys, stats = solve(z0, args, ts)
    if unravel is not None:
        ys = jax.vmap(unravel)(ys)
    return ys, stats


def odeint_aca_fixed(
    f: Callable,
    z0: PyTree,
    ts: jnp.ndarray,
    args: PyTree = (),
    *,
    solver: Tableau,
    steps_per_interval: int = 8,
    use_pallas: bool = False,
) -> Tuple[PyTree, SolveStats]:
    """Fixed-grid ACA: checkpoint every grid state during the forward scan,
    replay one step at a time in the backward sweep.

    Versus naive AD through the scan this stores only {z_i} (not the stage
    intermediates), trading one extra ψ per step — the classic
    checkpoint-recompute profile, with the same discretize-then-optimize
    gradient.  Used by NODE-mode model stacks where a static step count is
    required for multi-pod lowering.  ``use_pallas`` as in ``odeint_aca``.
    """
    f, z0, unravel, use_pallas = maybe_flatten(f, z0, use_pallas)

    n_intervals = ts.shape[0] - 1
    n_steps = n_intervals * steps_per_interval
    # static (numpy!) index plans — a jnp array created here would be a
    # trace-local constant tracer and leak into the bwd closure
    out_idx = np.where(
        (np.arange(n_steps) + 1) % steps_per_interval == 0,
        (np.arange(n_steps) + 1) // steps_per_interval,
        -1).astype(np.int32)
    idx_clamped = np.minimum(
        np.arange(1, n_intervals + 1) * steps_per_interval, n_steps - 1)

    def _fwd(z0, args, t_grid, h_grid):
        def step_fn(z, th):
            t, h = th
            z_next = rk_step(solver, f, t, z, h, _as_tuple(args),
                             use_pallas=use_pallas).z_next
            return z_next, z  # checkpoint the START state of each step

        z_end, z_ckpt = jax.lax.scan(step_fn, z0, (t_grid, h_grid))
        # outputs at eval times: gather the step-start states of the steps
        # following each eval time + final state

        def gather(zc, zl_end, zl0):
            tail = zc[idx_clamped]
            tail = tail.at[-1].set(zl_end)
            return jnp.concatenate([zl0[None], tail], axis=0)

        ys = jax.tree.map(gather, z_ckpt, z_end, z0)
        return ys, z_ckpt

    # the time grid is threaded as an explicit custom_vjp argument
    # (closures over trace-time values are illegal under scan/grad)
    @jax.custom_vjp
    def solve(z0, args, t_grid, h_grid):
        ys, _ = _fwd(z0, args, t_grid, h_grid)
        return ys

    def solve_fwd(z0, args, t_grid, h_grid):
        ys, z_ckpt = _fwd(z0, args, t_grid, h_grid)
        return ys, (z_ckpt, args, t_grid, h_grid)

    def solve_bwd(res, g_ys):
        z_ckpt, args, t_grid, h_grid = res
        ckpts = Checkpoints(
            t=t_grid, h=h_grid, z=z_ckpt, out_idx=jnp.asarray(out_idx),
            n=jnp.asarray(n_steps, jnp.int32))
        dz0, dargs = _aca_backward_sweep(
            solver, f, ckpts, args, g_ys, n_steps, use_pallas=use_pallas)
        return dz0, dargs, jnp.zeros_like(t_grid), jnp.zeros_like(h_grid)

    solve.defvjp(solve_fwd, solve_bwd)
    t_grid, h_grid = make_fixed_grid(ts, steps_per_interval)
    ys = solve(z0, args, t_grid, h_grid)
    if unravel is not None:
        ys = jax.vmap(unravel)(ys)
    # fixed grids have no trial loop to guard: post-hoc finite check
    status = jnp.where(_nonfinite_any(jax.lax.stop_gradient(ys)),
                       SolveStatus.NONFINITE_STATE,
                       SolveStatus.OK).astype(jnp.int32)
    stats = SolveStats(
        n_steps=jnp.asarray(n_steps, jnp.int32),
        n_trials=jnp.asarray(n_steps, jnp.int32),
        nfe=jnp.asarray(n_steps * solver.stages, jnp.int32),
        overflow=jnp.asarray(False),
        status=status,
    )
    return ys, stats
