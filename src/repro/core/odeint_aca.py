"""Adaptive Checkpoint Adjoint (ACA) — the paper's contribution, in JAX.

Forward pass (paper Algorithm 2 / Appendix A):
  * integrate with the adaptive solver (``adaptive_while_solve``); the
    stepsize search happens inside a ``lax.while_loop`` and is therefore
    *structurally* excluded from differentiation — the JAX realization of
    "delete local computation graphs to search for optimal stepsize";
  * keep only the accepted discretization points {t_i}, stepsizes
    {h_i = t_{i+1} - t_i} and states {z_i} in a fixed-capacity trajectory
    checkpoint buffer:  memory O(N_f + N_t).

Backward pass:
  * initialize λ(T) = ∂J/∂z(T)  (Eq. 6; we carry +∂J/∂z, the sign
    convention of Appendix A's  λ = -∂J/∂z(T)  is folded into the update);
  * walk the saved grid in reverse; for each interval re-take ONE local
    step ψ(t_i, z_i, h_i) with the saved stepsize (no search — the paper's
    "m+1"-th evaluation), back-propagate through it with ``jax.vjp``, and
    update λ and dL/dθ (discretized Eq. 7 / Eq. 8);
  * the local graph is freed after each step: depth O(N_f), total
    computation O(N_f · N_t · (m+1)).

Because the reverse sweep replays the *forward* trajectory exactly, the
gradient equals the true gradient of the numerical solution
(discretize-then-optimize) — no reverse-time re-integration error
(Theorem 3.2's e_k pathology does not arise).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .controller import ControllerConfig
from .integrate import (
    Checkpoints,
    SolveStats,
    adaptive_while_solve,
    batched_adaptive_while_solve,
    make_fixed_grid,
)
from .stepper import (
    maybe_flatten,
    maybe_flatten_batched,
    rk_step,
    rk_step_batched,
)
from .tableaus import Tableau

PyTree = Any


def _aca_backward_sweep(
    tab: Tableau,
    f: Callable,
    ckpts: Checkpoints,
    args: PyTree,
    g_ys: PyTree,
    n_steps,
    use_pallas: bool = False,
):
    """Reverse sweep over the trajectory checkpoints.

    Returns (dL/dz0, dL/dargs).  ``g_ys`` are the output cotangents, one
    slot per eval time (g_ys[k] injected into λ when the sweep crosses
    eval time ts[k]).  ``use_pallas`` replays each local ψ through the
    fused flat-state kernels (their custom_vjp makes them legal under
    the jax.vjp below).
    """

    def local_step(t_i, h_i, z_i, a):
        # one ψ with the SAVED stepsize; k0 recomputed so its gradient flows
        return rk_step(tab, f, t_i, z_i, h_i, _as_tuple(a),
                       use_pallas=use_pallas).z_next

    lam0 = jax.tree.map(jnp.zeros_like, _buffer_slot(g_ys, 0))
    gargs0 = jax.tree.map(jnp.zeros_like, args)

    def body(j, carry):
        lam, gargs = carry
        i = n_steps - 1 - j
        t_i = ckpts.t[i]
        h_i = ckpts.h[i]
        z_i = jax.tree.map(lambda b: b[i], ckpts.z)
        oi = ckpts.out_idx[i]

        # inject the cotangent of any output that lands on this interval's
        # endpoint:  λ(t_{i+1}) += ∂J/∂y_k
        def add_out(lam):
            g_k = jax.tree.map(lambda g: g[oi], g_ys)
            return jax.tree.map(jnp.add, lam, g_k)

        lam = jax.lax.cond(oi >= 0, add_out, lambda l: l, lam)

        # local forward + local backward (paper Algorithm 2, backward-pass)
        _, vjp_fn = jax.vjp(lambda z, a: local_step(t_i, h_i, z, a), z_i,
                            args)
        dlam, dargs = vjp_fn(lam)
        gargs = jax.tree.map(jnp.add, gargs, dargs)
        return (dlam, gargs)

    lam, gargs = jax.lax.fori_loop(0, n_steps, body, (lam0, gargs0))
    # cotangent of ys[0] = z0 (identity path)
    lam = jax.tree.map(lambda l, g: l + g[0], lam, g_ys)
    return lam, gargs


def _buffer_slot(buf: PyTree, i) -> PyTree:
    return jax.tree.map(lambda b: b[i], buf)


def _aca_backward_sweep_batched(
    tab: Tableau,
    f: Callable,
    ckpts: Checkpoints,
    args: PyTree,
    g_ys: PyTree,
    n_steps,
    use_pallas: bool = False,
):
    """Per-element reverse sweep: each batch element replays *its own*
    accepted checkpoint grid.

    ``ckpts`` rows are per element (t/h/out_idx (B, S), z (B, S, ...),
    n (B,)); ``g_ys`` leaves are (n_eval, B, ...).  The shared
    ``fori_loop`` runs max(n_steps) iterations; element b replays slot
    n_b - 1 - j at iteration j and is frozen with h = 0 once j ≥ n_b —
    the h = 0 local ψ is the exact identity in z (and contributes a zero
    cotangent to args), so short trajectories finish early without
    touching their λ.  Returns (dL/dz0 (B, ...), dL/dargs summed over
    the batch — args are shared).
    """
    B = n_steps.shape[0]
    rows = jnp.arange(B)

    def local_step(t_i, h_i, z_i, a):
        # one batched ψ with each element's SAVED stepsize (no search);
        # k0 recomputed so its gradient flows
        return rk_step_batched(tab, f, t_i, z_i, h_i, _as_tuple(a),
                               use_pallas=use_pallas).z_next

    lam0 = jax.tree.map(jnp.zeros_like, _buffer_slot(g_ys, 0))  # (B, ...)
    gargs0 = jax.tree.map(jnp.zeros_like, args)
    n_max = jnp.max(n_steps)

    def body(j, carry):
        lam, gargs = carry
        i = n_steps - 1 - j                  # (B,), negative when done
        live = i >= 0
        i_c = jnp.maximum(i, 0)
        t_i = ckpts.t[rows, i_c]
        h_i = jnp.where(live, ckpts.h[rows, i_c],
                        jnp.zeros((), ckpts.h.dtype))
        z_i = jax.tree.map(lambda b: b[rows, i_c], ckpts.z)
        oi = jnp.where(live, ckpts.out_idx[rows, i_c], -1)

        # inject each element's output cotangent where its interval's
        # endpoint landed on an eval time:  λ_b(t_{i+1}) += ∂J/∂y_{oi_b}
        oi_c = jnp.maximum(oi, 0)
        lam = jax.tree.map(
            lambda l, g: l + jnp.where(
                (oi >= 0).reshape((-1,) + (1,) * (l.ndim - 1)),
                g[oi_c, rows], jnp.zeros_like(l)),
            lam, g_ys)

        # batched local forward + local backward; frozen rows are the
        # identity, so dlam == lam and dargs == 0 for them exactly
        _, vjp_fn = jax.vjp(lambda z, a: local_step(t_i, h_i, z, a), z_i,
                            args)
        dlam, dargs = vjp_fn(lam)
        gargs = jax.tree.map(jnp.add, gargs, dargs)
        return (dlam, gargs)

    lam, gargs = jax.lax.fori_loop(0, n_max, body, (lam0, gargs0))
    # cotangent of ys[0] = z0 (identity path)
    lam = jax.tree.map(lambda l, g: l + g[0], lam, g_ys)
    return lam, gargs


def odeint_aca_batched(
    f: Callable,
    z0: PyTree,
    ts: jnp.ndarray,
    args: PyTree = (),
    *,
    solver: Tableau,
    rtol: float = 1e-6,
    atol: float = 1e-6,
    cfg: Optional[ControllerConfig] = None,
    use_pallas: bool = False,
) -> Tuple[PyTree, SolveStats]:
    """Per-sample batched ACA: ``odeint(..., batch_axis=0)``'s adaptive
    ACA path.

    ``z0`` leaves carry a leading batch dim B and ``f`` is the
    per-sample vector field.  Forward: ``batched_adaptive_while_solve``
    — every element records its own checkpoint grid.  Backward: each
    element's grid is replayed in reverse (``_aca_backward_sweep_batched``),
    so the per-element discretize-then-optimize property of ACA is
    preserved exactly — gradients match ``jax.vmap`` of the unbatched
    solver.  Returns (ys, stats) with ys leaves (len(ts), B, ...) and
    per-element stats.
    """
    if cfg is None:
        cfg = ControllerConfig()
    if not solver.adaptive:
        raise ValueError(
            "odeint_aca_batched requires an embedded adaptive tableau; "
            "fixed-grid solvers batch losslessly through odeint_aca_fixed")

    f, z0, unravel, use_pallas = maybe_flatten_batched(f, z0, use_pallas)

    @jax.custom_vjp
    def solve(z0, args, ts):
        ys, _, stats = batched_adaptive_while_solve(
            solver, f, z0, ts, _as_tuple(args), rtol, atol, cfg,
            use_pallas=use_pallas)
        return ys, stats

    def solve_fwd(z0, args, ts):
        ys, ckpts, stats = batched_adaptive_while_solve(
            solver, f, z0, ts, _as_tuple(args), rtol, atol, cfg,
            use_pallas=use_pallas)
        return (ys, stats), (ckpts, args, ts)

    def solve_bwd(res, cot):
        ckpts, args, ts = res
        g_ys, _g_stats = cot  # stats are integer outputs; cotangent ignored
        dz0, dargs = _aca_backward_sweep_batched(
            solver, f, ckpts, args, g_ys, ckpts.n, use_pallas=use_pallas)
        return dz0, dargs, jnp.zeros_like(ts)

    solve.defvjp(solve_fwd, solve_bwd)
    ys, stats = solve(z0, args, ts)
    if unravel is not None:
        ys = jax.vmap(jax.vmap(unravel))(ys)
    return ys, stats


def odeint_aca(
    f: Callable,
    z0: PyTree,
    ts: jnp.ndarray,
    args: PyTree = (),
    *,
    solver: Tableau,
    rtol: float = 1e-6,
    atol: float = 1e-6,
    cfg: Optional[ControllerConfig] = None,
    h0: Optional[jnp.ndarray] = None,
    use_pallas: bool = False,
) -> Tuple[PyTree, SolveStats]:
    """Solve dz/dt = f(t, z, *args) with ACA gradients.

    Returns (ys, stats) with ys stacked over ``ts`` (ys[0] = z0).
    Differentiable w.r.t. ``z0`` and ``args``; ``ts`` is treated as
    constant (the paper differentiates neither t nor the accepted h).

    ``use_pallas`` ravels the state once per solve and runs the trial
    loop, the checkpoint buffer and the backward replay on the fused
    flat-state kernel path; the ravel/unravel sit *outside* the
    custom_vjp so cotangents flow through them as plain jnp reshapes.
    """
    if cfg is None:
        cfg = ControllerConfig()

    if not solver.adaptive:
        raise ValueError(
            "odeint_aca requires an embedded adaptive tableau; use "
            "odeint_aca_fixed for fixed-grid solvers")

    f, z0, unravel, use_pallas = maybe_flatten(f, z0, use_pallas)

    # ``ts`` is threaded as an explicit custom_vjp argument (closures over
    # trace-time values are illegal inside scan/grad — e.g. NODE blocks
    # inside a scanned layer stack).
    @jax.custom_vjp
    def solve(z0, args, ts):
        ys, _, stats = adaptive_while_solve(
            solver, f, z0, ts, _as_tuple(args), rtol, atol, cfg, h0=h0,
            use_pallas=use_pallas)
        return ys, stats

    def solve_fwd(z0, args, ts):
        ys, ckpts, stats = adaptive_while_solve(
            solver, f, z0, ts, _as_tuple(args), rtol, atol, cfg, h0=h0,
            use_pallas=use_pallas)
        return (ys, stats), (ckpts, args, ts)

    def solve_bwd(res, cot):
        ckpts, args, ts = res
        g_ys, _g_stats = cot  # stats are integer outputs; cotangent ignored
        dz0, dargs = _aca_backward_sweep(
            solver, f, ckpts, args, g_ys, ckpts.n, use_pallas=use_pallas)
        return dz0, dargs, jnp.zeros_like(ts)

    solve.defvjp(solve_fwd, solve_bwd)
    ys, stats = solve(z0, args, ts)
    if unravel is not None:
        ys = jax.vmap(unravel)(ys)
    return ys, stats


def odeint_aca_fixed(
    f: Callable,
    z0: PyTree,
    ts: jnp.ndarray,
    args: PyTree = (),
    *,
    solver: Tableau,
    steps_per_interval: int = 8,
    use_pallas: bool = False,
) -> Tuple[PyTree, SolveStats]:
    """Fixed-grid ACA: checkpoint every grid state during the forward scan,
    replay one step at a time in the backward sweep.

    Versus naive AD through the scan this stores only {z_i} (not the stage
    intermediates), trading one extra ψ per step — the classic
    checkpoint-recompute profile, with the same discretize-then-optimize
    gradient.  Used by NODE-mode model stacks where a static step count is
    required for multi-pod lowering.  ``use_pallas`` as in ``odeint_aca``.
    """
    f, z0, unravel, use_pallas = maybe_flatten(f, z0, use_pallas)

    n_intervals = ts.shape[0] - 1
    n_steps = n_intervals * steps_per_interval
    # static (numpy!) index plans — a jnp array created here would be a
    # trace-local constant tracer and leak into the bwd closure
    out_idx = np.where(
        (np.arange(n_steps) + 1) % steps_per_interval == 0,
        (np.arange(n_steps) + 1) // steps_per_interval,
        -1).astype(np.int32)
    idx_clamped = np.minimum(
        np.arange(1, n_intervals + 1) * steps_per_interval, n_steps - 1)

    stats = SolveStats(
        n_steps=jnp.asarray(n_steps, jnp.int32),
        n_trials=jnp.asarray(n_steps, jnp.int32),
        nfe=jnp.asarray(n_steps * solver.stages, jnp.int32),
        overflow=jnp.asarray(False),
    )

    def _fwd(z0, args, t_grid, h_grid):
        def step_fn(z, th):
            t, h = th
            z_next = rk_step(solver, f, t, z, h, _as_tuple(args),
                             use_pallas=use_pallas).z_next
            return z_next, z  # checkpoint the START state of each step

        z_end, z_ckpt = jax.lax.scan(step_fn, z0, (t_grid, h_grid))
        # outputs at eval times: gather the step-start states of the steps
        # following each eval time + final state

        def gather(zc, zl_end, zl0):
            tail = zc[idx_clamped]
            tail = tail.at[-1].set(zl_end)
            return jnp.concatenate([zl0[None], tail], axis=0)

        ys = jax.tree.map(gather, z_ckpt, z_end, z0)
        return ys, z_ckpt

    # the time grid is threaded as an explicit custom_vjp argument
    # (closures over trace-time values are illegal under scan/grad)
    @jax.custom_vjp
    def solve(z0, args, t_grid, h_grid):
        ys, _ = _fwd(z0, args, t_grid, h_grid)
        return ys

    def solve_fwd(z0, args, t_grid, h_grid):
        ys, z_ckpt = _fwd(z0, args, t_grid, h_grid)
        return ys, (z_ckpt, args, t_grid, h_grid)

    def solve_bwd(res, g_ys):
        z_ckpt, args, t_grid, h_grid = res
        ckpts = Checkpoints(
            t=t_grid, h=h_grid, z=z_ckpt, out_idx=jnp.asarray(out_idx),
            n=jnp.asarray(n_steps, jnp.int32))
        dz0, dargs = _aca_backward_sweep(
            solver, f, ckpts, args, g_ys, n_steps, use_pallas=use_pallas)
        return dz0, dargs, jnp.zeros_like(t_grid), jnp.zeros_like(h_grid)

    solve.defvjp(solve_fwd, solve_bwd)
    t_grid, h_grid = make_fixed_grid(ts, steps_per_interval)
    ys = solve(z0, args, t_grid, h_grid)
    if unravel is not None:
        ys = jax.vmap(unravel)(ys)
    return ys, stats


def _as_tuple(args) -> Tuple:
    return args if isinstance(args, tuple) else (args,)
