"""Unified odeint front-end:  solver × gradient-method dispatch.

    ys, stats = odeint(f, z0, ts, args,
                       solver="dopri5",          # tableau name, or "alf"
                       grad_method="aca",        # aca | adjoint | naive | mali
                       rtol=1e-6, atol=1e-6,
                       max_steps=256,            # checkpoint capacity
                       max_trials=12,            # stepsize trials per step
                       steps_per_interval=8,     # fixed-grid solvers
                       trial_budget=None,        # naive-method tape bound
                       use_pallas=False,         # fused flat-state kernels
                       batch_axis=None,          # per-sample batched solve
                       checkpoint_segments=None, # O(K)-state ACA memory
                       interpolate_ts=False,     # dense-output eval reads
                       h0=None,                  # initial-stepsize override
                       on_failure="status",      # solve-health policy
                       mesh=None,                # shard batch over a Mesh
                       shard_rules=None)         # AxisRules override

``f(t, z, *args) -> dz/dt`` over arbitrary pytrees; ``ts`` strictly
monotone — ascending for a forward solve, or *descending* for a
reverse-time solve (internally solved as the time-negated ascending
problem, so every gradient method — including ACA's bit-exact
checkpoint replay — works unchanged); ``ys[k] = z(ts[k])`` with
``ys[0] = z0``.  Gradients flow to ``z0`` and ``args`` under every
method; the methods differ exactly as the paper's Table 1 describes,
plus the paper-family successor ``grad_method="mali"`` (reversible
asynchronous-leapfrog: O(1) state memory, exact reverse reconstruction;
pairs with ``solver="alf"`` — see ``odeint_mali.py`` and
``docs/method-selection.md``).

With ``batch_axis=a``, leaves of ``z0`` carry a batch dimension at axis
``a`` and ``f`` stays *per-sample*: each batch element is integrated on
its own adaptive grid (own stepsize controller, own accept/reject, own
checkpoint buffer) instead of one lockstep decision for the whole batch —
the semantics of ``jax.vmap`` over the unbatched solver, in one fused
loop.  ``args`` are shared across the batch (their gradient is summed).

With ``mesh=...`` on top of ``batch_axis``, the batched solve is
``shard_map``-ed over the mesh's data-parallel axes: each device
integrates its own batch shard with its own while_loop trip count (a
stiff straggler no longer stalls the whole batch), forward/backward
sweeps of every gradient method run shard-local, and the one
cross-device collective is the psum of the shared-``args`` cotangent
that ``shard_map``'s transpose inserts.  See ``docs/distributed.md``.

``odeint_dense`` solves once over [t0, t1] and returns a
``DenseSolution`` carrying every accepted step's interpolant
coefficients — evaluate it post hoc at arbitrary times.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import checkify

from .controller import ControllerConfig
from .integrate import (
    SolveStats,
    SolveStatus,
    _as_tuple,
    adaptive_while_solve,
)
from .odeint_aca import odeint_aca, odeint_aca_batched, odeint_aca_fixed
from .odeint_adjoint import (
    odeint_adjoint,
    odeint_adjoint_batched,
    odeint_adjoint_fixed,
)
from .odeint_mali import odeint_mali, odeint_mali_batched
from .odeint_naive import (
    odeint_naive,
    odeint_naive_batched,
    odeint_naive_fixed,
)
from .stepper import InterpCoeffs, interp_eval_aligned, maybe_flatten
from .tableaus import Tableau, get_tableau

PyTree = Any

GRAD_METHODS = ("aca", "adjoint", "naive", "mali")

ON_FAILURE_POLICIES = ("status", "warn", "raise")


def _apply_on_failure(ys, stats, on_failure: str):
    """Apply the solve-health policy to a finished solve.

    ``"status"`` is a no-op (callers read ``stats.status``); ``"warn"``
    emits a ``jax.debug.print`` line when any element failed (works
    under jit — the print fires at run time, off the hot path behind a
    ``lax.cond``); ``"raise"`` inserts a functionalized
    ``checkify.check`` — eager callers get an immediate exception,
    jitted callers must functionalize with ``checkify.checkify`` (see
    ``odeint_checked``, which does exactly that).
    """
    if on_failure == "status":
        return ys, stats
    any_bad = jnp.any(stats.status != SolveStatus.OK)
    if on_failure == "warn":
        jax.lax.cond(
            any_bad,
            lambda s: jax.debug.print(
                "odeint: solve-health failure, status={s} "
                "(see repro.core.SolveStatus.describe)", s=s),
            lambda s: None,
            stats.status)
        return ys, stats
    checkify.check(
        ~any_bad,
        "odeint: solve failed, status={s} "
        "(see repro.core.SolveStatus.describe)", s=stats.status)
    return ys, stats


def _is_alf(solver) -> bool:
    """True when ``solver`` names the reversible asynchronous-leapfrog
    pair integrator (the only pairing ``grad_method='mali'`` accepts —
    ALF is not an RK tableau)."""
    return (isinstance(solver, str)
            and solver.lower().replace("-", "_") == "alf")


def _ts_direction(ts: jnp.ndarray) -> int:
    """Validate the ``ts`` monotonicity contract; return the direction.

    Returns +1 for strictly ascending, -1 for strictly descending;
    raises ValueError for anything else (repeated times included) —
    unsorted input used to silently produce garbage.  Traced ``ts``
    (inside jit with ts as an argument) cannot be inspected and is
    assumed ascending — pass concrete eval times to use reverse-time
    solving.
    """
    if isinstance(ts, jax.core.Tracer):
        return 1
    d = np.diff(np.asarray(ts))
    if bool((d > 0).all()):
        return 1
    if bool((d < 0).all()):
        return -1
    raise ValueError(
        "ts must be strictly monotone: ascending (forward solve) or "
        "descending (reverse-time solve); got neither — sort your eval "
        "times (and deduplicate repeats) before calling odeint")


def _negate_time(f: Callable) -> Callable:
    """The time-negated vector field: solving dz/ds = -f(-s, z) forward
    over ascending s = -t is exactly the reverse-time solve over
    descending t."""
    def f_neg(s, z, *a):
        return jax.tree.map(jnp.negative, f(-s, z, *a))

    return f_neg


def odeint(
    f: Callable,
    z0: PyTree,
    ts,
    args: PyTree = (),
    *,
    solver: Optional[Union[str, Tableau]] = None,
    grad_method: str = "aca",
    rtol: float = 1e-6,
    atol: float = 1e-6,
    max_steps: int = 256,
    max_trials: int = 12,
    steps_per_interval: int = 8,
    trial_budget: Optional[int] = None,
    use_pallas: bool = False,
    batch_axis: Optional[int] = None,
    checkpoint_segments: Optional[Union[int, str]] = None,
    interpolate_ts: bool = False,
    h0: Optional[Any] = None,
    on_failure: str = "status",
    mesh: Optional[Any] = None,
    shard_rules: Optional[Any] = None,
) -> Tuple[PyTree, SolveStats]:
    """See module docstring for the solver × grad-method matrix.

    Solve health: adaptive solves guard every trial against non-finite
    states — a poisoned element freezes at its last accepted state
    (finite outputs, zeroed cotangents) and ``stats.status`` carries a
    per-solve (per-element under ``batch_axis``) ``SolveStatus`` code.
    ``on_failure`` picks the policy: ``"status"`` (default — report
    only, bit-identical hot path), ``"warn"`` (``jax.debug.print`` on
    failure), ``"raise"`` (a ``checkify.check``; eager calls raise
    immediately, jitted callers use ``odeint_checked``).  ``h0``
    overrides the automatic initial-stepsize heuristic of adaptive
    solvers (scalar, or (B,) under ``batch_axis``) — the
    ``solve_with_fallback`` retry ladder uses it to re-attempt a failed
    solve with a tighter first step.  See ``docs/robustness.md``.

    Adaptive-solver budgets: ``max_steps`` caps the number of *accepted*
    steps (it is also the checkpoint-buffer capacity, the paper's N_t
    bound — ``stats.overflow`` is set when the solve runs out before the
    last eval time); ``max_trials`` bounds the paper's inner stepsize
    search m, so the total ψ-trial budget of one solve is ``max_steps *
    max_trials``.  ``trial_budget`` (naive method only) overrides that
    product as the length of the differentiable solver tape: reverse-mode
    AD stores residuals for every budgeted trial, so it is *the* memory
    knob of the naive method.

    ``use_pallas=True`` enables the fused flat-state fast path: the
    state pytree is raveled once per solve and every ψ trial (stage
    increments, solution/error combine, scaled error norm) runs as
    fused Pallas kernels — compiled on TPU, interpret-mode elsewhere
    (``repro.kernels.ops.set_interpret`` / REPRO_PALLAS_INTERPRET
    override).  The fused step computes the same f32 arithmetic in the
    same accumulation order as the pytree path (bit-identical in the
    tested configurations; only the error-norm reduction is tiled, so a
    trial whose scaled error sits within ~1 ulp of the accept threshold
    could in principle decide differently) and gradients flow through
    all four methods.  States whose leaves mix dtypes (or are not
    inexact) silently fall back to the pytree path.

    ``batch_axis=a`` enables the per-sample batched mode: every leaf of
    ``z0`` carries a batch dimension at axis ``a`` (one shared batch
    size B) while ``f`` remains the per-sample vector field.  Adaptive
    solvers then give every element its own stepsize-controller state,
    accept/reject mask and checkpoint row inside one fused while_loop —
    matching ``jax.vmap`` of the unbatched solver instead of degrading
    the stepsize search to one lockstep decision — and all four
    gradient methods replay/re-integrate/invert per element.  Outputs gain the
    leading time axis as usual: ``ys[k]`` has the shape of the batched
    ``z0`` (batch at axis ``a`` of each state leaf), and ``stats``
    fields become (B,) per-element counters; an element that has landed
    on its last ``ts[k]`` stops accumulating f-evals while stragglers
    finish.  Composes with ``use_pallas`` (batched fused kernels with
    per-row error norms); fixed-grid solvers share one exact grid, so
    batching is lossless there.

    Under ``batch_axis``, ``rtol``/``atol`` may additionally be (B,)
    arrays — **per-element tolerances**: every batch row's stepsize
    controller (initial-stepsize heuristic, per-trial error norm,
    accept/reject) targets that row's own (rtol, atol), so tight- and
    loose-tolerance problems share one fused solve without lockstep
    waste — the per-request quality-of-service knob of the serving
    engine (``repro.serve.NodeServeEngine``).  A row at tolerance τ is
    **bitwise identical** to the same row in an all-τ batch (rows never
    interact; the loaded per-row tolerance computes the same f32
    arithmetic as the baked scalar), on both the pytree and the fused
    Pallas path.  Requires an adaptive solver (or ``mali``); not yet
    composable with ``mesh`` (the tolerance rows would replicate, not
    shard).  Tolerances never carry gradient.  See ``docs/serving.md``.

    ``checkpoint_segments=K`` (adaptive ACA only) bounds the trajectory-
    checkpoint state memory: instead of every accepted state (O(N_f ·
    dim)), the forward stores K coarse snapshots plus the full *scalar*
    grid, and the ACA backward re-integrates each segment from its
    snapshot with the saved stepsizes before replaying it in reverse —
    memory O((K + N_f/K) · dim) at ~1 extra ψ per accepted step, with
    gradients **bit-identical** to the full buffer (the replay re-takes
    the exact saved steps; there is no re-search).  ``"auto"`` picks the
    memory-optimal K = ⌈√max_steps⌉.  Composes with ``use_pallas`` and
    ``batch_axis``; raises for other grad methods (they keep no state
    checkpoints to bound) and for fixed-grid solvers.  See
    ``docs/memory.md``.

    ``interpolate_ts=True`` (adaptive solvers only) decouples the eval
    grid from the step grid: the controller advances on its *natural*
    accepted steps, clamped only to the final time, and interior
    ``ts[k]`` are read off each accepted step's local interpolant
    (4th-order for Dopri5 via its ``b_mid`` dense output, cubic Hermite
    otherwise) — dense eval grids stop inflating the step count.
    ``ys[0]``/``ys[-1]`` stay exact solver states; interior outputs
    carry the interpolant's O(h⁴) error on top of the solve tolerance.
    Gradients flow through the interpolants under all three methods
    (ACA replays interval + interpolant exactly).  Default off: the
    forced-landing trajectories are bit-compatible with earlier
    releases.  Composes with ``batch_axis``, ``use_pallas``,
    ``checkpoint_segments`` and descending ``ts``.

    ``grad_method="mali"`` (paired with ``solver="alf"`` — the default
    when ``solver`` is omitted) integrates with the reversible
    asynchronous-leapfrog pair stepper and reconstructs the trajectory
    in the backward sweep by *inverting* accepted steps from the
    terminal state — bitwise, via the fixed-point lattice pair of
    ``stepper.alf_step`` — so no state checkpoint buffer exists at all:
    state memory is O(dim) regardless of step count (only the cheap
    scalar t/h grid is kept).  One field evaluation per ψ trial, 2nd
    order.  Composes with ``batch_axis``, ``use_pallas`` and descending
    ``ts``; rejects ``checkpoint_segments`` (nothing to segment) and
    ``interpolate_ts``.  See ``docs/method-selection.md``.

    Descending ``ts`` runs the whole solve in reverse time by negating
    the clock (``dz/ds = -f(-s, z)`` over ascending ``s = -t``): the
    forward trajectory is bit-identical to the negated-time ascending
    solve, and all gradient methods apply unchanged.

    ``mesh=...`` (requires ``batch_axis``) shards the batch over the
    mesh's data-parallel axes via ``shard_map``: ``z0`` (and a (B,)
    ``h0``) split along the batch dim, ``ts``/``args`` replicate, and
    each device runs the per-sample batched engine on its shard with an
    *independent* while_loop trip count — the forward trajectory, the
    per-element ``stats`` and the z0-cotangents are exactly the
    unsharded batched solve's, shard-local end to end, for all four
    gradient methods; the shared-``args`` gradient additionally crosses
    devices once (psum of per-shard partial sums, inserted by
    ``shard_map``'s transpose — associativity reordering can move
    args-grads by ~1 ulp under naive/mali).  The mesh's batch axes come
    from ``shard_rules`` (default ``DEFAULT_TRAIN_RULES``: "batch" →
    ("pod", "data") ∩ mesh axes); the batch size must divide evenly by
    the shard count.  ``repro.distributed.shard_mesh()`` builds the
    flat 1-D data mesh over all devices.  See ``docs/distributed.md``.
    """
    if grad_method not in GRAD_METHODS:
        raise ValueError(f"grad_method must be one of {GRAD_METHODS}")
    if on_failure not in ON_FAILURE_POLICIES:
        raise ValueError(
            f"on_failure must be one of {ON_FAILURE_POLICIES}; got "
            f"{on_failure!r}")
    if solver is None:
        # mali integrates with the reversible ALF pair stepper; every
        # other method defaults to the paper's Dopri5
        solver = "alf" if grad_method == "mali" else "dopri5"
    if grad_method == "mali" and not _is_alf(solver):
        name = solver if isinstance(solver, str) else solver.name
        raise ValueError(
            f"grad_method='mali' integrates with the reversible "
            f"asynchronous-leapfrog pair stepper (solver='alf'), not an "
            f"RK tableau (got {name!r}); drop the solver argument or "
            "pass solver='alf'")
    if _is_alf(solver) and grad_method != "mali":
        raise ValueError(
            f"solver='alf' is the reversible pair integrator whose "
            f"inverse IS the gradient method — it pairs only with "
            f"grad_method='mali' (got {grad_method!r})")
    mali = grad_method == "mali"
    tab = None if mali else (
        get_tableau(solver) if isinstance(solver, str) else solver)
    ts = jnp.asarray(ts)
    if ts.ndim != 1 or ts.shape[0] < 2:
        raise ValueError("ts must be a 1D array of at least 2 times")
    if checkpoint_segments is not None and mali:
        raise ValueError(
            "checkpoint_segments is meaningless with grad_method='mali': "
            "MALI keeps no state checkpoints at all — its backward sweep "
            "reconstructs every state by inverting steps from the "
            "terminal pair in O(1) memory; drop checkpoint_segments")
    if checkpoint_segments is not None and (
            grad_method != "aca" or not tab.adaptive):
        raise ValueError(
            "checkpoint_segments requires grad_method='aca' with an "
            f"adaptive solver (got {grad_method!r} / {tab.name!r}): only "
            "the ACA trajectory checkpoint stores per-step states to "
            "segment")
    if interpolate_ts and mali:
        raise ValueError(
            "interpolate_ts is not supported with grad_method='mali': "
            "the reversible backward sweep reconstructs exact step "
            "landings only (no interpolant cotangent routing); use "
            "grad_method='aca' for dense-output gradients")
    if interpolate_ts and not tab.adaptive:
        raise ValueError(
            "interpolate_ts requires an adaptive solver (got "
            f"{tab.name!r}): fixed grids land on every eval time by "
            "construction, there is no stepsize search to relieve")
    if h0 is not None and not mali and not tab.adaptive:
        raise ValueError(
            f"h0 overrides the adaptive initial-stepsize heuristic; "
            f"fixed-grid solver {tab.name!r} has no stepsize controller "
            "— use steps_per_interval to refine its grid instead")
    if mesh is not None and batch_axis is None:
        raise ValueError(
            "mesh requires batch_axis: sharding distributes the "
            "per-sample batched solve over the mesh's data axes, so the "
            "state must carry a batch dimension — pass batch_axis=a "
            "(or drop mesh for a single-sample solve)")
    row_tol = jnp.ndim(rtol) > 0 or jnp.ndim(atol) > 0
    if row_tol:
        if batch_axis is None:
            raise ValueError(
                "array rtol/atol are *per-element* tolerances and "
                "require batch_axis: each entry pairs with one batch "
                "row's stepsize controller — pass batch_axis=a, or a "
                "scalar tolerance for a single-sample solve")
        if mesh is not None:
            raise ValueError(
                "per-element rtol/atol do not compose with mesh yet: "
                "the (B,) tolerance rows are closure-captured by the "
                "engine custom_vjp and would replicate — not shard — "
                "across devices inside shard_map, silently mispairing "
                "tolerances with batch rows; drop mesh or use a scalar "
                "tolerance")
        if not mali and not tab.adaptive:
            raise ValueError(
                f"per-element rtol/atol require an adaptive solver (got "
                f"{tab.name!r}): fixed grids have no error control to "
                "point a tolerance at — use steps_per_interval instead")
        rtol = jnp.asarray(rtol, jnp.float32)
        atol = jnp.asarray(atol, jnp.float32)
        if rtol.ndim > 1 or atol.ndim > 1:
            raise ValueError(
                "per-element rtol/atol must be rank-1 (one tolerance "
                f"per batch row); got shapes {jnp.shape(rtol)} / "
                f"{jnp.shape(atol)}")
    if _ts_direction(ts) < 0:
        # reverse time: solve the time-negated problem over ascending -ts
        f, ts = _negate_time(f), -ts

    cfg = ControllerConfig(max_steps=max_steps, max_trials=max_trials)
    if h0 is not None:
        h0 = jnp.asarray(h0, ts.dtype)

    if batch_axis is not None:
        out = _odeint_batched(
            f, z0, ts, args, tab=tab, grad_method=grad_method,
            batch_axis=batch_axis, rtol=rtol, atol=atol, cfg=cfg,
            steps_per_interval=steps_per_interval,
            trial_budget=trial_budget, use_pallas=use_pallas,
            checkpoint_segments=checkpoint_segments,
            interpolate_ts=interpolate_ts, h0=h0,
            mesh=mesh, shard_rules=shard_rules)
    elif mali:
        out = odeint_mali(f, z0, ts, args, rtol=rtol, atol=atol,
                          cfg=cfg, h0=h0, use_pallas=use_pallas)
    elif tab.adaptive:
        if grad_method == "aca":
            out = odeint_aca(f, z0, ts, args, solver=tab, rtol=rtol,
                             atol=atol, cfg=cfg, h0=h0,
                             use_pallas=use_pallas,
                             checkpoint_segments=checkpoint_segments,
                             interpolate_ts=interpolate_ts)
        elif grad_method == "adjoint":
            out = odeint_adjoint(f, z0, ts, args, solver=tab, rtol=rtol,
                                 atol=atol, cfg=cfg, h0=h0,
                                 use_pallas=use_pallas,
                                 interpolate_ts=interpolate_ts)
        else:
            out = odeint_naive(f, z0, ts, args, solver=tab, rtol=rtol,
                               atol=atol, cfg=cfg, h0=h0,
                               trial_budget=trial_budget,
                               use_pallas=use_pallas,
                               interpolate_ts=interpolate_ts)
    elif grad_method == "aca":
        out = odeint_aca_fixed(f, z0, ts, args, solver=tab,
                               steps_per_interval=steps_per_interval,
                               use_pallas=use_pallas)
    elif grad_method == "adjoint":
        out = odeint_adjoint_fixed(f, z0, ts, args, solver=tab,
                                   steps_per_interval=steps_per_interval,
                                   use_pallas=use_pallas)
    else:
        out = odeint_naive_fixed(f, z0, ts, args, solver=tab,
                                 steps_per_interval=steps_per_interval,
                                 use_pallas=use_pallas)
    return _apply_on_failure(out[0], out[1], on_failure)


def _odeint_batched(
    f: Callable,
    z0: PyTree,
    ts: jnp.ndarray,
    args: PyTree,
    *,
    tab: Tableau,
    grad_method: str,
    batch_axis: int,
    rtol: float,
    atol: float,
    cfg: ControllerConfig,
    steps_per_interval: int,
    trial_budget: Optional[int],
    use_pallas: bool,
    checkpoint_segments: Optional[Union[int, str]] = None,
    interpolate_ts: bool = False,
    h0: Optional[jnp.ndarray] = None,
    mesh: Optional[Any] = None,
    shard_rules: Optional[Any] = None,
) -> Tuple[PyTree, SolveStats]:
    """Batched dispatch behind ``odeint(..., batch_axis=a)``.

    Normalizes the batch dim to axis 0, routes adaptive tableaus to the
    per-sample batched solvers and fixed grids to the (lossless) shared
    grid with a vmapped field, then restores the caller's batch axis in
    ``ys`` (which sits one axis deeper under the leading time axis).
    With ``mesh``, the whole dispatch runs inside one ``shard_map`` over
    the mesh's batch-partition axes — each shard solves its local batch
    rows independently (own while_loop trip counts, shard-local
    backward sweeps); only the shared-``args`` cotangent crosses
    devices, via the psum ``shard_map``'s transpose inserts for
    replicated inputs.
    """
    flat, _ = jax.tree_util.tree_flatten_with_path(z0)
    if not flat:
        raise ValueError("batch_axis requires a non-empty state")
    for path, leaf in flat:
        if jnp.ndim(leaf) == 0:
            raise ValueError(
                f"batch_axis={batch_axis} requires every state leaf to "
                f"carry a batch dimension, but leaf "
                f"{jax.tree_util.keystr(path) or '<root>'} is rank-0 "
                "(a scalar has no axis to batch over)")
    leaves = [leaf for _, leaf in flat]
    # normalize per leaf: leaves may have different ranks, and a negative
    # axis must resolve before the != 0 checks and the ys restore below
    axes = jax.tree.map(lambda l: batch_axis % l.ndim, z0)
    sizes = {l.shape[a] for l, a in zip(leaves, jax.tree.leaves(axes))}
    if len(sizes) != 1:
        raise ValueError(
            f"all state leaves must share one batch size at axis "
            f"{batch_axis}; got {sorted(sizes)}")
    B = sizes.pop()

    for tname, tol in (("rtol", rtol), ("atol", atol)):
        if jnp.ndim(tol) == 1 and jnp.shape(tol)[0] not in (1, B):
            raise ValueError(
                f"per-element {tname} must carry one entry per batch row "
                f"(B={B}) or a single broadcastable entry; got shape "
                f"{jnp.shape(tol)}")

    z0 = jax.tree.map(
        lambda l, a: jnp.moveaxis(l, a, 0) if a else l, z0, axes)

    if mesh is not None:
        # jax 0.4.x shard_map cannot carry rank-0 custom_vjp residuals
        # across the shard boundary (grad dies with a _SpecError), and
        # the engines save ``args`` verbatim in their residuals.  So
        # promote scalar args leaves to shape (1,) for the engines and
        # strip the axis again at each field call — user field code
        # still sees true scalars, and the promoting reshape sits
        # outside the shard_map so args cotangents come back rank-0.
        mask = jax.tree.map(lambda x: jnp.ndim(x) == 0, args)
        if any(jax.tree.leaves(mask)):
            args = jax.tree.map(
                lambda x, s: jnp.reshape(jnp.asarray(x), (1,)) if s
                else x, args, mask)
            tup_mask = _as_tuple(mask)
            inner_f = f

            def f(t, z, *a):
                a = tuple(
                    jax.tree.map(
                        lambda x, s: jnp.reshape(x, ()) if s else x,
                        ai, mi)
                    for ai, mi in zip(a, tup_mask))
                return inner_f(t, z, *a)

    def dispatch(z0, ts, args, h0):
        # batch leads axis 0 of every z0 leaf here; under a mesh this
        # body runs per shard on the shard-local rows
        if grad_method == "mali":  # tab is None: ALF pair integrator
            ys, stats = odeint_mali_batched(
                f, z0, ts, args, rtol=rtol, atol=atol, cfg=cfg, h0=h0,
                use_pallas=use_pallas)
        elif tab.adaptive:
            if grad_method == "aca":
                ys, stats = odeint_aca_batched(
                    f, z0, ts, args, solver=tab, rtol=rtol, atol=atol,
                    cfg=cfg, h0=h0, use_pallas=use_pallas,
                    checkpoint_segments=checkpoint_segments,
                    interpolate_ts=interpolate_ts)
            elif grad_method == "adjoint":
                ys, stats = odeint_adjoint_batched(
                    f, z0, ts, args, solver=tab, rtol=rtol, atol=atol,
                    cfg=cfg, h0=h0, use_pallas=use_pallas,
                    interpolate_ts=interpolate_ts)
            else:
                ys, stats = odeint_naive_batched(
                    f, z0, ts, args, solver=tab, rtol=rtol, atol=atol,
                    cfg=cfg, h0=h0, trial_budget=trial_budget,
                    use_pallas=use_pallas,
                    interpolate_ts=interpolate_ts)
        else:
            # fixed grids are identical for every element — lockstep IS
            # the per-sample grid; vmap the field over the batched state
            # and reuse the unbatched front-ends unchanged
            fb = lambda t, z, *a: jax.vmap(
                lambda zi: f(t, zi, *a), in_axes=0)(z)
            if grad_method == "aca":
                ys, stats = odeint_aca_fixed(
                    fb, z0, ts, args, solver=tab,
                    steps_per_interval=steps_per_interval,
                    use_pallas=use_pallas)
            elif grad_method == "adjoint":
                ys, stats = odeint_adjoint_fixed(
                    fb, z0, ts, args, solver=tab,
                    steps_per_interval=steps_per_interval,
                    use_pallas=use_pallas)
            else:
                ys, stats = odeint_naive_fixed(
                    fb, z0, ts, args, solver=tab,
                    steps_per_interval=steps_per_interval,
                    use_pallas=use_pallas)
            b = jax.tree.leaves(z0)[0].shape[0]  # shard-local under mesh
            stats = SolveStats(*(jnp.broadcast_to(s, (b,)) for s in stats))
        return ys, stats

    if mesh is None:
        ys, stats = dispatch(z0, ts, args, h0)
    else:
        ys, stats = _shard_map_solve(
            dispatch, mesh, shard_rules, z0, ts, args, h0, B)

    # ys leaves are (n_eval, B, ...): the batch dim sits one axis deeper
    # than it did in each z0 leaf, under the leading time axis
    ys = jax.tree.map(
        lambda l, a: jnp.moveaxis(l, 1, a + 1) if a else l, ys, axes)
    return ys, stats


def _shard_map_solve(dispatch, mesh, shard_rules, z0, ts, args, h0, B):
    """Wrap the batch-at-axis-0 dispatch in one ``shard_map``.

    Specs: ``z0`` (and a per-element ``h0``) split along dim 0 over the
    mesh's batch-partition axes; ``ts``/``args`` replicate; ``ys``
    leaves come back split along dim 1 (batch under the time axis) and
    ``stats`` fields along dim 0.  Replication checking is off (see
    ``shard_map_compat``) because the solver engines use ``custom_vjp``
    internally; the replicated-args cotangent psum is inserted by
    ``shard_map``'s transpose rule, so no collective appears in this
    forward code at all.
    """
    from jax.sharding import PartitionSpec

    from ..distributed.sharding import batch_partition_axes, \
        shard_map_compat

    axes = batch_partition_axes(mesh, shard_rules)
    if not axes:
        raise ValueError(
            f"mesh {tuple(mesh.shape.items())} has no data-parallel axis "
            "to shard the batch over (the sharding rules map 'batch' to "
            f"{('pod', 'data')}, none of which the mesh carries) — add a "
            "'data' axis, use repro.distributed.shard_mesh(), or pass "
            "shard_rules mapping 'batch' onto one of this mesh's axes")
    n_shard = 1
    for a in axes:
        n_shard *= mesh.shape[a]
    if B % n_shard:
        raise ValueError(
            f"batch size {B} does not divide evenly over the mesh's "
            f"{n_shard} batch shard(s) (axes {axes} of mesh "
            f"{tuple(mesh.shape.items())}): pad the batch to a multiple "
            f"of {n_shard} or drop devices from the mesh")
    dspec = axes[0] if len(axes) == 1 else axes
    bspec = PartitionSpec(dspec)   # batch-leading arrays: split dim 0
    rspec = PartitionSpec()        # replicated
    h0_spec = rspec if (h0 is None or jnp.ndim(h0) == 0) else bspec
    sharded = shard_map_compat(
        dispatch, mesh=mesh,
        in_specs=(bspec, rspec, rspec, h0_spec),
        out_specs=(PartitionSpec(None, dspec), bspec))
    return sharded(z0, ts, args, h0)


def _time_dtype(*times) -> jnp.dtype:
    """Float dtype for a time grid built from scalars: explicit dtypes
    win; weak Python floats resolve to the default float dtype, so
    ``JAX_ENABLE_X64`` solves get float64 endpoints instead of a
    silently-truncating hardcoded float32."""
    tdt = jnp.result_type(*times)
    if not jnp.issubdtype(tdt, jnp.floating):
        tdt = jnp.result_type(float)
    return tdt


def odeint_final(
    f: Callable,
    z0: PyTree,
    t0: float,
    t1: float,
    args: PyTree = (),
    **kw,
) -> Tuple[PyTree, SolveStats]:
    """Convenience: integrate [t0, t1], return only z(t1) (NODE block use).

    Accepts every ``odeint`` keyword, including ``batch_axis`` — the
    returned z(t1) then keeps the batch dimension where ``z0`` had it.
    ``t0 > t1`` runs the solve in reverse time (descending ``ts``).
    """
    ts = jnp.asarray([t0, t1], _time_dtype(t0, t1))
    ys, stats = odeint(f, z0, ts, args, **kw)
    return jax.tree.map(lambda y: y[-1], ys), stats


def odeint_checked(
    f: Callable,
    z0: PyTree,
    ts,
    args: PyTree = (),
    **kw,
) -> Tuple[PyTree, SolveStats]:
    """``odeint`` that *raises* on solve failure instead of returning a
    status code.

    Functionalizes ``odeint(..., on_failure="raise")`` with
    ``jax.experimental.checkify`` and throws the collected error on the
    host: a non-finite state, stepsize underflow, or budget exhaustion
    surfaces as ``checkify.JaxRuntimeError`` naming the failing status
    code(s).  Accepts every ``odeint`` keyword except ``on_failure``.

    Call it *outside* jit (the throw needs a concrete error value).  To
    keep the check inside your own jitted function, call
    ``odeint(..., on_failure="raise")`` there and wrap the whole
    function with ``checkify.checkify`` yourself.
    """
    kw.pop("on_failure", None)
    ts = jnp.asarray(ts)  # closed over: keeps reverse-time ts concrete

    def run(z0, args):
        return odeint(f, z0, ts, args, on_failure="raise", **kw)

    err, out = checkify.checkify(run, errors=checkify.user_checks)(
        z0, args)
    err.throw()
    return out


def default_fallback_ladder(ts, *, rtol: float = 1e-6,
                            atol: float = 1e-6) -> list:
    """The retry rungs ``solve_with_fallback`` tries after a failed
    solve, mildest first.

    Each rung is a dict of ``odeint`` keyword overrides (plus a
    ``"note"`` for the report): (1) tighten the initial step to
    span/1024 — recovers solves whose first trial overflowed before the
    controller found the stiff scale; (2) loosen rtol/atol 100× —
    trades accuracy for stability when the tolerance is unreachable;
    (3) drop to the lower-order ``bosh3`` pair (smaller stages, wider
    stability margin per unit error) with ACA gradients; (4) last
    resort: a fixed-grid ``rk4`` solve with a fine 64-step grid — no
    stepsize search left to fail, only non-finite states can remain.
    """
    span = abs(float(ts[-1]) - float(ts[0]))
    return [
        {"note": "tighten h0", "h0": span / 1024.0},
        {"note": "loosen tolerances 100x",
         "rtol": rtol * 100.0, "atol": atol * 100.0},
        {"note": "fall back to bosh3/aca",
         "solver": "bosh3", "grad_method": "aca"},
        {"note": "fixed rk4 grid", "solver": "rk4", "grad_method": "aca",
         "steps_per_interval": 64},
    ]


# odeint keywords that only adaptive solvers understand — dropped from a
# rung that falls back to a fixed-grid tableau
_ADAPTIVE_ONLY_KW = ("h0", "checkpoint_segments", "interpolate_ts",
                     "trial_budget")


def solve_with_fallback(
    f: Callable,
    z0: PyTree,
    ts,
    args: PyTree = (),
    *,
    ladder: Optional[list] = None,
    **kw,
) -> Tuple[PyTree, SolveStats, list]:
    """Host-level retry ladder around ``odeint``: re-attempt a failed
    solve under progressively more conservative configurations.

    Runs ``odeint(f, z0, ts, args, **kw)`` and reads ``stats.status``
    on the host; when any element is unhealthy, walks the ``ladder`` of
    keyword-override rungs (default: ``default_fallback_ladder`` —
    tighten h0, loosen tolerances, drop to bosh3, fixed rk4) until an
    attempt comes back all-OK with finite outputs.  Returns
    ``(ys, stats, report)`` where ``report`` is one dict per attempt
    (note, overrides, status codes, ok flag); if no rung recovers, the
    *original* attempt's (frozen, finite) outputs are returned and
    every report entry has ``ok=False``.

    Serving-layer tool: each rung is a fresh trace/compile and the
    status read is a host sync, so this is **not jittable** — call it
    from request handlers, not from inside a training step (there, use
    ``on_failure="status"`` + the train-loop skip-step guard).
    """
    kw.pop("on_failure", None)
    ts = jnp.asarray(ts)
    if ladder is None:
        ladder = default_fallback_ladder(
            ts, rtol=kw.get("rtol", 1e-6), atol=kw.get("atol", 1e-6))

    report: list = []
    first = None
    for rung in [{"note": "original"}] + list(ladder):
        over = {k: v for k, v in rung.items() if k != "note"}
        akw = {**kw, **over}
        solver = akw.get("solver")
        if solver is not None and not _is_alf(solver):
            tabl = get_tableau(solver) if isinstance(solver, str) \
                else solver
            if not tabl.adaptive:
                for k in _ADAPTIVE_ONLY_KW:
                    akw.pop(k, None)
        entry = {"note": rung.get("note", "attempt"), "overrides": over}
        try:
            ys, stats = odeint(f, z0, ts, args, **akw)
        except Exception as e:  # rung invalid for this configuration
            entry.update(error=repr(e), ok=False)
            report.append(entry)
            continue
        status = np.asarray(jax.device_get(stats.status))
        finite = all(
            bool(np.isfinite(np.asarray(leaf)).all())
            for leaf in jax.tree.leaves(jax.device_get(ys)))
        ok = bool((status == SolveStatus.OK).all()) and finite
        entry.update(
            status=status.tolist() if status.ndim else int(status),
            ok=ok)
        report.append(entry)
        if first is None:
            first = (ys, stats)
        if ok:
            return ys, stats, report
    if first is None:  # every attempt raised — nothing to return
        raise RuntimeError(
            f"solve_with_fallback: every attempt errored: {report}")
    ys, stats = first
    return ys, stats, report


class DenseSolution(NamedTuple):
    """A continuously-evaluable ODE solution (``odeint_dense``).

    Carries every accepted step's interpolant: ``t``/``h`` the interval
    start times and stepsizes *in internal (ascending) time*, ``coeffs``
    the fitted polynomial coefficients (``stepper.InterpCoeffs``; leaves
    lead with the step axis), ``n`` the number of valid steps and
    ``sign`` (+1/-1) mapping user time to internal time (-1 for a
    reverse-time solve over t1 < t0).  Slots past ``n`` are garbage.

    ``evaluate(t)`` interpolates at arbitrary times inside [t0, t1]
    (times outside clamp to the nearest endpoint); it is a pytree of
    plain jnp gathers + polynomial evaluation, so it jits/vmaps freely.
    The producing solve runs inside a ``lax.while_loop`` — treat the
    solution as *forward-only* (no gradients to z0/args through it; use
    ``odeint(..., interpolate_ts=True)`` when you need gradients at
    fixed eval times).
    """
    t: jnp.ndarray            # (max_steps,) interval start times
    h: jnp.ndarray            # (max_steps,) accepted stepsizes
    coeffs: Any               # InterpCoeffs, leaves (max_steps, ...)
    n: jnp.ndarray            # valid step count
    sign: jnp.ndarray         # +1.0 / -1.0 (user time = sign * internal)

    def evaluate(self, t) -> PyTree:
        """State at time(s) ``t`` — scalar or any-shape array; returned
        leaves lead with ``t``'s shape."""
        tdt = self.t.dtype
        tq = jnp.asarray(t, tdt) * self.sign
        qshape = tq.shape
        tq = tq.reshape(-1)
        # invalid slots -> +inf keeps the knot array sorted for the
        # bisection; clip lands every query on a valid interval
        slots = jnp.arange(self.t.shape[0])
        knots = jnp.where(slots < self.n, self.t,
                          jnp.asarray(jnp.inf, tdt))
        idx = jnp.clip(jnp.searchsorted(knots, tq, side="right") - 1,
                       0, jnp.maximum(self.n - 1, 0))
        t_i, h_i = self.t[idx], self.h[idx]
        tiny = jnp.asarray(jnp.finfo(tdt).eps, tdt)
        theta = jnp.clip((tq - t_i) / jnp.maximum(h_i, tiny), 0.0, 1.0)
        coeffs_q = jax.tree.map(lambda b: b[idx], self.coeffs)
        vals = interp_eval_aligned(InterpCoeffs(*coeffs_q), theta)
        return jax.tree.map(
            lambda v: v.reshape(qshape + v.shape[1:]), vals)


def odeint_dense(
    f: Callable,
    z0: PyTree,
    t0: float,
    t1: float,
    args: PyTree = (),
    *,
    solver: Union[str, Tableau] = "dopri5",
    rtol: float = 1e-6,
    atol: float = 1e-6,
    max_steps: int = 256,
    max_trials: int = 12,
    use_pallas: bool = False,
) -> Tuple[DenseSolution, SolveStats]:
    """Solve dz/dt = f(t, z, *args) over [t0, t1] once and return a
    ``DenseSolution`` for post-hoc evaluation at arbitrary times.

    The adaptive controller advances on its natural grid (no interior
    landings) and every accepted step's interpolant coefficients are
    stored — memory O(N_f · dim · 5) — so ``sol.evaluate(t)`` costs one
    bisection plus one polynomial evaluation per query, with the same
    accuracy contract as ``interpolate_ts``.  ``t1 < t0`` solves in
    reverse time; ``evaluate`` then takes user (descending-side) times.
    Forward/inference only — the producing while_loop is not
    reverse-differentiable.  ``stats.overflow`` set means the solve ran
    out of ``max_steps`` before reaching t1 (the solution is then only
    valid up to the last accepted step).
    """
    tab = get_tableau(solver) if isinstance(solver, str) else solver
    if not tab.adaptive:
        raise ValueError(
            f"odeint_dense requires an adaptive solver (got {tab.name!r})")
    tdt = _time_dtype(t0, t1)
    ts = jnp.asarray([t0, t1], tdt)
    if _ts_direction(ts) < 0:
        f, ts = _negate_time(f), -ts
        sign = jnp.asarray(-1.0, tdt)
    else:
        sign = jnp.asarray(1.0, tdt)

    cfg = ControllerConfig(max_steps=max_steps, max_trials=max_trials)
    f, z0, unravel, use_pallas = maybe_flatten(f, z0, use_pallas)
    _, ckpts, stats = adaptive_while_solve(
        tab, f, z0, ts, _as_tuple(args), rtol, atol, cfg,
        use_pallas=use_pallas, store_coeffs=True)
    coeffs = ckpts.coeffs
    if unravel is not None:
        coeffs = InterpCoeffs(*(jax.vmap(unravel)(c) for c in coeffs))
    sol = DenseSolution(t=ckpts.t, h=ckpts.h, coeffs=coeffs, n=ckpts.n,
                        sign=sign)
    return sol, stats
