"""Unified odeint front-end:  solver × gradient-method dispatch.

    ys, stats = odeint(f, z0, ts, args,
                       solver="dopri5",          # any tableau name
                       grad_method="aca",        # aca | adjoint | naive
                       rtol=1e-6, atol=1e-6,
                       max_steps=256,            # checkpoint capacity
                       steps_per_interval=8,     # fixed-grid solvers
                       use_pallas=False)         # fused flat-state kernels

``f(t, z, *args) -> dz/dt`` over arbitrary pytrees; ``ts`` sorted ascending,
``ys[k] = z(ts[k])`` with ``ys[0] = z0``.  Gradients flow to ``z0`` and
``args`` under every method; the methods differ exactly as the paper's
Table 1 describes.
"""

from __future__ import annotations

from typing import Any, Callable, Optional, Tuple, Union

import jax.numpy as jnp

from .controller import ControllerConfig
from .integrate import SolveStats
from .odeint_aca import odeint_aca, odeint_aca_fixed
from .odeint_adjoint import odeint_adjoint, odeint_adjoint_fixed
from .odeint_naive import odeint_naive, odeint_naive_fixed
from .tableaus import Tableau, get_tableau

PyTree = Any

GRAD_METHODS = ("aca", "adjoint", "naive")


def odeint(
    f: Callable,
    z0: PyTree,
    ts,
    args: PyTree = (),
    *,
    solver: Union[str, Tableau] = "dopri5",
    grad_method: str = "aca",
    rtol: float = 1e-6,
    atol: float = 1e-6,
    max_steps: int = 256,
    max_trials: int = 12,
    steps_per_interval: int = 8,
    trial_budget: Optional[int] = None,
    use_pallas: bool = False,
) -> Tuple[PyTree, SolveStats]:
    """See module docstring for the solver × grad-method matrix.

    ``use_pallas=True`` enables the fused flat-state fast path: the
    state pytree is raveled once per solve and every ψ trial (stage
    increments, solution/error combine, scaled error norm) runs as
    fused Pallas kernels — compiled on TPU, interpret-mode elsewhere
    (``repro.kernels.ops.set_interpret`` / REPRO_PALLAS_INTERPRET
    override).  The fused step computes the same f32 arithmetic in the
    same accumulation order as the pytree path (bit-identical in the
    tested configurations; only the error-norm reduction is tiled, so a
    trial whose scaled error sits within ~1 ulp of the accept threshold
    could in principle decide differently) and gradients flow through
    all three methods.  States whose leaves mix dtypes (or are not
    inexact) silently fall back to the pytree path.
    """
    tab = get_tableau(solver) if isinstance(solver, str) else solver
    ts = jnp.asarray(ts)
    if ts.ndim != 1 or ts.shape[0] < 2:
        raise ValueError("ts must be a 1D array of at least 2 times")
    if grad_method not in GRAD_METHODS:
        raise ValueError(f"grad_method must be one of {GRAD_METHODS}")

    cfg = ControllerConfig(max_steps=max_steps, max_trials=max_trials)

    if tab.adaptive:
        if grad_method == "aca":
            return odeint_aca(f, z0, ts, args, solver=tab, rtol=rtol,
                              atol=atol, cfg=cfg, use_pallas=use_pallas)
        if grad_method == "adjoint":
            return odeint_adjoint(f, z0, ts, args, solver=tab, rtol=rtol,
                                  atol=atol, cfg=cfg, use_pallas=use_pallas)
        return odeint_naive(f, z0, ts, args, solver=tab, rtol=rtol,
                            atol=atol, cfg=cfg, trial_budget=trial_budget,
                            use_pallas=use_pallas)

    if grad_method == "aca":
        return odeint_aca_fixed(f, z0, ts, args, solver=tab,
                                steps_per_interval=steps_per_interval,
                                use_pallas=use_pallas)
    if grad_method == "adjoint":
        return odeint_adjoint_fixed(f, z0, ts, args, solver=tab,
                                    steps_per_interval=steps_per_interval,
                                    use_pallas=use_pallas)
    return odeint_naive_fixed(f, z0, ts, args, solver=tab,
                              steps_per_interval=steps_per_interval,
                              use_pallas=use_pallas)


def odeint_final(
    f: Callable,
    z0: PyTree,
    t0: float,
    t1: float,
    args: PyTree = (),
    **kw,
) -> Tuple[PyTree, SolveStats]:
    """Convenience: integrate [t0, t1], return only z(t1) (NODE block use)."""
    import jax

    ys, stats = odeint(f, z0, jnp.asarray([t0, t1], jnp.float32), args, **kw)
    return jax.tree.map(lambda y: y[-1], ys), stats
