"""Butcher tableaus for explicit (embedded) Runge-Kutta solvers.

The paper (Sec. 4.2, Table 2) uses fixed-stepsize solvers Euler / RK2 / RK4
and adaptive embedded pairs HeunEuler 1(2), Bogacki-Shampine RK23 2(3) and
Dormand-Prince RK45 4(5).  Every solver is expressed as one immutable
tableau consumed by the generic stepper in ``stepper.py``.

A tableau of an ``s``-stage method holds

  * ``a``  — (s, s) strictly-lower-triangular stage coefficients,
  * ``b``  — (s,) solution weights (order ``order``),
  * ``b_err`` — (s,) difference b - b_hat against the embedded lower-order
    solution; ``None`` for fixed-step methods (no error estimate),
  * ``c``  — (s,) stage times,
  * ``order`` — the order p used by the stepsize controller exponent,
  * ``fsal`` — first-same-as-last: stage 0 of the next step equals the last
    stage of the accepted step (Dopri5, BS23), saving one f-evaluation.
  * ``b_mid`` — optional dense-output weights: ``z(t + h/2) ≈ z + h·Σ
    b_mid_i k_i`` evaluates the solution at the step midpoint from the
    already-computed stages (Dopri5 ships the classic Shampine
    coefficients).  The midpoint upgrades the step interpolant from the
    free cubic Hermite (z, f at both endpoints) to the 4th-order quartic
    fit used by ``interpolate_ts`` / ``odeint_dense`` — see
    ``stepper.interp_fit``.  Methods without ``b_mid`` interpolate with
    the cubic Hermite, which already matches their order for p ≤ 3.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

__all__ = [
    "Tableau",
    "EULER",
    "MIDPOINT",
    "HEUN2",
    "RK4",
    "HEUN_EULER",
    "BOGACKI_SHAMPINE",
    "DOPRI5",
    "get_tableau",
    "FIXED_SOLVERS",
    "ADAPTIVE_SOLVERS",
]


@dataclasses.dataclass(frozen=True)
class Tableau:
    """Immutable Butcher tableau of one explicit RK method.

    See the module docstring for the field semantics; ``adaptive`` is
    derived from the presence of embedded-error weights ``b_err``.
    """
    name: str
    a: Tuple[Tuple[float, ...], ...]
    b: Tuple[float, ...]
    c: Tuple[float, ...]
    order: int
    b_err: Optional[Tuple[float, ...]] = None
    fsal: bool = False
    b_mid: Optional[Tuple[float, ...]] = None

    @property
    def stages(self) -> int:
        return len(self.b)

    @property
    def adaptive(self) -> bool:
        return self.b_err is not None

    def a_matrix(self) -> np.ndarray:
        s = self.stages
        a = np.zeros((s, s), dtype=np.float64)
        for i, row in enumerate(self.a):
            a[i, : len(row)] = row
        return a

    def validate(self) -> None:
        """Consistency checks: row-sum = c, sum(b) = 1, explicitness.

        Raises ValueError on any violation — tableaus arrive from user
        code too (``odeint(solver=Tableau(...))``), so the checks must
        survive ``python -O`` and name what is wrong.
        """
        a = self.a_matrix()
        s = self.stages
        if a.shape != (s, s):
            raise ValueError(
                f"{self.name}: a-matrix shape {a.shape} != ({s}, {s})")
        # explicit: strictly lower triangular
        if not np.allclose(np.triu(a), 0.0):
            raise ValueError(f"{self.name}: tableau not explicit (nonzero "
                             "entries on/above the diagonal)")
        if not np.allclose(a.sum(axis=1), np.asarray(self.c), atol=1e-12):
            raise ValueError(f"{self.name}: row sums != c")
        if abs(sum(self.b) - 1.0) >= 1e-12:
            raise ValueError(f"{self.name}: sum(b) != 1")
        if self.b_err is not None:
            # embedded error weights must sum to zero (b and b_hat both sum to 1)
            if abs(sum(self.b_err)) >= 1e-12:
                raise ValueError(f"{self.name}: sum(b_err) != 0")
        if self.b_mid is not None:
            if len(self.b_mid) != s:
                raise ValueError(
                    f"{self.name}: b_mid has {len(self.b_mid)} weights, "
                    f"expected {s}")
            # consistency (dz/dt = 1): z + h·Σ b_mid must land at t + h/2
            if abs(sum(self.b_mid) - 0.5) >= 1e-12:
                raise ValueError(f"{self.name}: sum(b_mid) != 1/2")


# ----------------------------------------------------------------------------
# Fixed-step methods
# ----------------------------------------------------------------------------

EULER = Tableau(
    name="euler",
    a=((),),
    b=(1.0,),
    c=(0.0,),
    order=1,
)

MIDPOINT = Tableau(
    name="midpoint",
    a=((), (0.5,)),
    b=(0.0, 1.0),
    c=(0.0, 0.5),
    order=2,
)

# Explicit trapezoid / Heun's 2nd-order method — this is the paper's "RK2".
HEUN2 = Tableau(
    name="rk2",
    a=((), (1.0,)),
    b=(0.5, 0.5),
    c=(0.0, 1.0),
    order=2,
)

RK4 = Tableau(
    name="rk4",
    a=((), (0.5,), (0.0, 0.5), (0.0, 0.0, 1.0)),
    b=(1.0 / 6.0, 1.0 / 3.0, 1.0 / 3.0, 1.0 / 6.0),
    c=(0.0, 0.5, 0.5, 1.0),
    order=4,
)

# ----------------------------------------------------------------------------
# Adaptive embedded pairs
# ----------------------------------------------------------------------------

# Heun-Euler 1(2): advance with Heun (order 2), error against Euler (order 1).
# The paper trains NODE18 with this solver (Appendix D, rtol=atol=1e-2).
HEUN_EULER = Tableau(
    name="heun_euler",
    a=((), (1.0,)),
    b=(0.5, 0.5),
    b_err=(0.5 - 1.0, 0.5 - 0.0),  # b - b_hat with b_hat = (1, 0) (Euler)
    c=(0.0, 1.0),
    order=2,
)

# Bogacki-Shampine 2(3) — the paper's "RK23". FSAL.
BOGACKI_SHAMPINE = Tableau(
    name="bosh3",
    a=(
        (),
        (0.5,),
        (0.0, 0.75),
        (2.0 / 9.0, 1.0 / 3.0, 4.0 / 9.0),
    ),
    b=(2.0 / 9.0, 1.0 / 3.0, 4.0 / 9.0, 0.0),
    b_err=(
        2.0 / 9.0 - 7.0 / 24.0,
        1.0 / 3.0 - 1.0 / 4.0,
        4.0 / 9.0 - 1.0 / 3.0,
        0.0 - 1.0 / 8.0,
    ),
    c=(0.0, 0.5, 0.75, 1.0),
    order=3,
    fsal=True,
)

# Dormand-Prince 4(5) — the paper's "RK45" / "Dopri5". FSAL.
DOPRI5 = Tableau(
    name="dopri5",
    a=(
        (),
        (1.0 / 5.0,),
        (3.0 / 40.0, 9.0 / 40.0),
        (44.0 / 45.0, -56.0 / 15.0, 32.0 / 9.0),
        (19372.0 / 6561.0, -25360.0 / 2187.0, 64448.0 / 6561.0, -212.0 / 729.0),
        (9017.0 / 3168.0, -355.0 / 33.0, 46732.0 / 5247.0, 49.0 / 176.0,
         -5103.0 / 18656.0),
        (35.0 / 384.0, 0.0, 500.0 / 1113.0, 125.0 / 192.0, -2187.0 / 6784.0,
         11.0 / 84.0),
    ),
    b=(35.0 / 384.0, 0.0, 500.0 / 1113.0, 125.0 / 192.0, -2187.0 / 6784.0,
       11.0 / 84.0, 0.0),
    b_err=(
        35.0 / 384.0 - 5179.0 / 57600.0,
        0.0,
        500.0 / 1113.0 - 7571.0 / 16695.0,
        125.0 / 192.0 - 393.0 / 640.0,
        -2187.0 / 6784.0 + 92097.0 / 339200.0,
        11.0 / 84.0 - 187.0 / 2100.0,
        0.0 - 1.0 / 40.0,
    ),
    c=(0.0, 1.0 / 5.0, 3.0 / 10.0, 4.0 / 5.0, 8.0 / 9.0, 1.0, 1.0),
    order=5,
    fsal=True,
    # Shampine's dense-output midpoint: z(t + h/2) = z + h·Σ b_mid_i k_i
    # (the classic coefficients used by dopri5 dense output; feeds the
    # 4th-order quartic fit of stepper.interp_fit)
    b_mid=(
        6025192743.0 / 30085553152.0 / 2.0,
        0.0,
        51252292925.0 / 65400821598.0 / 2.0,
        -2691868925.0 / 45128329728.0 / 2.0,
        187940372067.0 / 1594534317056.0 / 2.0,
        -1776094331.0 / 19743644256.0 / 2.0,
        11237099.0 / 235043384.0 / 2.0,
    ),
)


_REGISTRY = {
    t.name: t
    for t in (EULER, MIDPOINT, HEUN2, RK4, HEUN_EULER, BOGACKI_SHAMPINE, DOPRI5)
}
# aliases matching the paper's naming
_REGISTRY["rk23"] = BOGACKI_SHAMPINE
_REGISTRY["bogacki_shampine"] = BOGACKI_SHAMPINE
_REGISTRY["rk45"] = DOPRI5
_REGISTRY["heuneuler"] = HEUN_EULER

# derived from the registry (aliases included) so these tuples — and the
# get_tableau error message built from them — cannot drift from what the
# lookup actually accepts (a hardcoded list once missed rk45/heuneuler)
FIXED_SOLVERS = tuple(sorted(
    n for n, t in _REGISTRY.items() if not t.adaptive))
ADAPTIVE_SOLVERS = tuple(sorted(
    n for n, t in _REGISTRY.items() if t.adaptive))


def get_tableau(name: str) -> Tableau:
    """Look up a registered tableau by case/dash-insensitive name.

    Accepted names are exactly ``FIXED_SOLVERS`` + ``ADAPTIVE_SOLVERS``
    (both derived from the registry, aliases included).  Raises KeyError
    enumerating them for unknown names.  The reversible pair integrator
    (``odeint(solver="alf", grad_method="mali")``) is not an RK tableau
    and is dispatched at the ``api`` level, not here.
    """
    key = name.lower().replace("-", "_")
    if key not in _REGISTRY:
        raise KeyError(
            f"unknown solver {name!r}; fixed-step: "
            f"{', '.join(FIXED_SOLVERS)}; adaptive: "
            f"{', '.join(ADAPTIVE_SOLVERS)} (the reversible pair "
            "integrator is solver='alf' with grad_method='mali')")
    return _REGISTRY[key]


for _t in (EULER, MIDPOINT, HEUN2, RK4, HEUN_EULER, BOGACKI_SHAMPINE, DOPRI5):
    _t.validate()
