"""repro.core — Adaptive Checkpoint Adjoint (ACA) gradient estimation.

Public API:
    odeint(f, z0, ts, args, solver=, grad_method="aca", ...)
        grad_method: "aca" | "adjoint" | "naive" | "mali"
    odeint_final(f, z0, t0, t1, args, ...)
    node_block_apply / NodeConfig — continuous-depth blocks for model stacks
    get_tableau / Tableau — explicit RK solvers (Euler..Dopri5);
        solver="alf" is the reversible pair integrator of "mali"
    SolveStatus / odeint_checked / solve_with_fallback — solve-health
        status codes, raising wrapper, host-level retry ladder
        (docs/robustness.md)
"""

from .api import (
    DenseSolution,
    GRAD_METHODS,
    default_fallback_ladder,
    odeint,
    odeint_checked,
    odeint_dense,
    odeint_final,
    solve_with_fallback,
)
from .controller import ControllerConfig
from .integrate import (
    Checkpoints,
    SolveStats,
    SolveStatus,
    adaptive_while_solve,
    batched_adaptive_while_solve,
    fixed_grid_solve,
)
from .node_block import NodeConfig, node_block_apply
from .odeint_aca import odeint_aca, odeint_aca_batched, odeint_aca_fixed
from .odeint_adjoint import (
    odeint_adjoint,
    odeint_adjoint_batched,
    odeint_adjoint_fixed,
)
from .odeint_mali import odeint_mali, odeint_mali_batched
from .odeint_naive import (
    odeint_naive,
    odeint_naive_batched,
    odeint_naive_fixed,
)
from .stepper import (
    alf_step,
    alf_step_inverse,
    rk_step,
    rk_step_batched,
)
from .tableaus import (
    ADAPTIVE_SOLVERS,
    FIXED_SOLVERS,
    Tableau,
    get_tableau,
)

__all__ = [
    "odeint", "odeint_final", "odeint_dense", "DenseSolution",
    "GRAD_METHODS",
    "odeint_checked", "solve_with_fallback", "default_fallback_ladder",
    "ControllerConfig", "SolveStats", "SolveStatus", "Checkpoints",
    "adaptive_while_solve", "batched_adaptive_while_solve",
    "fixed_grid_solve",
    "NodeConfig", "node_block_apply",
    "odeint_aca", "odeint_aca_batched", "odeint_aca_fixed",
    "odeint_adjoint", "odeint_adjoint_batched", "odeint_adjoint_fixed",
    "odeint_mali", "odeint_mali_batched",
    "odeint_naive", "odeint_naive_batched", "odeint_naive_fixed",
    "rk_step", "rk_step_batched", "alf_step", "alf_step_inverse",
    "Tableau", "get_tableau",
    "ADAPTIVE_SOLVERS", "FIXED_SOLVERS",
]
