"""Serving launcher: batched prefill + decode for any registry arch.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2_2_7b \
        --smoke --prompt-len 32 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.models import RunConfig, build_model
from repro.serve import ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else \
        get_config(args.arch)
    rcfg = RunConfig(compute_dtype=jnp.float32 if args.smoke
                     else jnp.bfloat16,
                     param_dtype=jnp.float32 if args.smoke
                     else jnp.bfloat16,
                     max_seq=args.prompt_len + args.new_tokens + 8)
    model = build_model(cfg, rcfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params,
                         ServeConfig(max_new_tokens=args.new_tokens,
                                     temperature=args.temperature))

    toks = jax.random.randint(jax.random.PRNGKey(1),
                              (args.batch, args.prompt_len), 0,
                              cfg.vocab, jnp.int32)
    t0 = time.monotonic()
    out = engine.generate(toks)
    dt = time.monotonic() - t0
    n_new = out["tokens"].shape[1] - args.prompt_len
    print(f"arch={cfg.name} generated {n_new} tokens x {args.batch} seqs "
          f"in {dt:.2f}s ({args.batch * n_new / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
