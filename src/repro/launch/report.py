"""Assemble EXPERIMENTS.md tables from results/dryrun/*.json.

    PYTHONPATH=src python -m repro.launch.report [--dir results/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def load_all(base: str) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(base, "*", "*.json"))):
        with open(path) as f:
            r = json.load(f)
        base = os.path.basename(path)[:-5]
        parts = base.split("__")
        r["_file"] = base
        r["_tag"] = parts[2] if len(parts) > 2 else ""
        rows.append(r)
    return rows


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def roofline_table(rows: List[Dict], mesh: str) -> str:
    hdr = ("| arch | shape | kind | t_comp (s) | t_mem (s) | t_coll (s) "
           "| dominant | useful/HLO | roofline frac | HBM/dev |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r.get("skipped") or r["mesh"] != mesh:
            continue
        roof = r["roofline"]
        mem = r.get("memory_analysis", {})
        hbm = (mem.get("argument_bytes") or 0) + \
            (mem.get("temp_bytes") or 0)
        tag = r["arch"] + (" (NODE)" if r.get("node_mode") else "") \
            + (f" [{r['_tag']}]" if r.get("_tag") else "")
        out.append(
            f"| {tag} | {r['shape']} | {r['kind']} "
            f"| {roof['t_compute']:.3e} | {roof['t_memory']:.3e} "
            f"| {roof['t_collective']:.3e} | {roof['dominant']} "
            f"| {roof['useful_flop_ratio']:.2f} "
            f"| {roof['roofline_fraction']:.3f} "
            f"| {fmt_bytes(hbm / r['n_devices'] if hbm else None)} |\n")
    return "".join(out)


def dryrun_table(rows: List[Dict], mesh: str) -> str:
    hdr = ("| arch | shape | compile (s) | HLO flops/dev | HLO bytes/dev "
           "| coll bytes/dev | top collectives |\n"
           "|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r.get("skipped") or r["mesh"] != mesh:
            continue
        roof = r["roofline"]
        coll = sorted(roof["coll_by_kind"].items(), key=lambda kv: -kv[1])
        cstr = ", ".join(f"{k}:{fmt_bytes(v)}" for k, v in coll[:2])
        tag = r["arch"] + (" (NODE)" if r.get("node_mode") else "") \
            + (f" [{r['_tag']}]" if r.get("_tag") else "")
        out.append(
            f"| {tag} | {r['shape']} | {r['compile_s']} "
            f"| {roof['flops_per_device']:.2e} "
            f"| {roof['bytes_per_device']:.2e} "
            f"| {roof['coll_bytes_per_device']:.2e} | {cstr} |\n")
    return "".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()
    rows = load_all(args.dir)
    for mesh in ("pod16x16", "pod2x16x16"):
        n = sum(1 for r in rows if not r.get("skipped")
                and r["mesh"] == mesh)
        print(f"\n## Mesh {mesh} — {n} cells\n")
        print(dryrun_table(rows, mesh))
        print(roofline_table(rows, mesh))


if __name__ == "__main__":
    main()
