"""Three-term roofline analysis from compiled dry-run artifacts.

    compute    = HLO_FLOPs_per_device            / peak_FLOP/s
    memory     = HLO_bytes_per_device            / HBM_bw
    collective = collective_bytes_per_device     / link_bw

HLO FLOPs / bytes come from ``compiled.cost_analysis()`` (the post-SPMD
per-device module).  Collective bytes are NOT in cost_analysis: they are
parsed from the compiled (or lowered) HLO text by summing buffer sizes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, scaled by the ring factor of the op kind
(all-reduce moves ≈2× its payload per device; gather/scatter/a2a ≈1×).
Per-device bytes over per-link bandwidth equals the assignment's
``collective_bytes / (chips × link_bw)`` with global bytes.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

``model_flops`` cross-checks compiled compute against the 6·N·D (train)
/ 2·N·D (inference) convention with N = active parameters; the ratio
exposes remat/recompute/padding waste.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

from repro.models.config import ModelConfig

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# result-or-operand type like  bf16[16,4096,5120]{2,1,0}
_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[\w\[\]{},.]+)?\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )

_FACTOR = {
    "all-reduce": 2.0,          # ring AR: 2(n-1)/n ≈ 2 payloads/device
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _type_bytes(type_str: str) -> int:
    m = _TYPE_RE.match(type_str)
    if not m:
        return 0
    dt, dims = m.groups()
    nb = _DTYPE_BYTES.get(dt)
    if nb is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nb


def collective_bytes_from_hlo(hlo_text: str) -> Tuple[float, Dict[str, float]]:
    """Per-device collective bytes (ring-factor scaled) by op kind.

    For each collective instruction, moved bytes ≈ factor × max(result
    bytes, operand bytes) — the max covers all-gather (big result) and
    reduce-scatter (big operand) symmetrically.  ``-done`` halves of
    async pairs are skipped (the ``-start`` carries the shapes).
    """
    per_kind: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        if "-done" in line:
            # async completion: shapes already counted at -start
            if re.search(r"(all-reduce|all-gather|reduce-scatter|"
                         r"all-to-all|collective-permute)-done", line):
                continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        types = _TYPE_RE.findall(line)
        # first type = result (lhs); operand types follow in the arg list
        sizes = []
        for dt, dims in types:
            nb = _DTYPE_BYTES.get(dt)
            if nb is None:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            sizes.append(n * nb)
        if not sizes:
            continue
        moved = _FACTOR[kind] * max(sizes)
        per_kind[kind] = per_kind.get(kind, 0.0) + moved
    return sum(per_kind.values()), per_kind


def active_params(cfg: ModelConfig) -> int:
    """Parameters touched per token (dense: all; MoE: shared + top-k)."""
    d = cfg.d_model
    dh = cfg.resolved_head_dim
    per_layer_attn = d * (cfg.n_heads + 2 * cfg.n_kv_heads) * dh \
        + cfg.n_heads * dh * d
    emb = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    if cfg.frontend != "none":
        emb = cfg.vocab * d           # lm head only
    if cfg.family == "moe":
        f = cfg.d_expert
        per_layer_ffn = (cfg.top_k + cfg.n_shared_experts) * 3 * d * f \
            + d * cfg.n_experts       # router
    elif cfg.family == "ssm":
        di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        per_layer_attn = 0
        per_layer_ffn = 2 * d * di + 2 * d * cfg.ssm_ngroups * n \
            + d * h + di * d
    elif cfg.family == "hybrid":
        dr = cfg.resolved_d_rnn
        n_attn = sum(1 for k in _kinds(cfg) if k == "attn")
        n_rec = cfg.n_layers - n_attn
        gated = 3 if cfg.act == "silu" else 2
        per_layer = (n_attn * (per_layer_attn + gated * d * cfg.d_ff)
                     + n_rec * (3 * d * dr + 2 * dr * dr // 16
                                + gated * d * cfg.d_ff)) // cfg.n_layers
        return emb + per_layer * cfg.n_layers
    else:
        gated = 3 if cfg.act == "silu" else 2
        per_layer_ffn = gated * d * cfg.d_ff
    return emb + cfg.n_layers * (per_layer_attn + per_layer_ffn)


def _kinds(cfg):
    from repro.models.transformer import layer_kinds
    return layer_kinds(cfg)


def model_flops(cfg: ModelConfig, kind: str, seq: int, batch: int) -> float:
    """Reference FLOPs (global): 6·N·tokens train, 2·N·tokens inference.

    decode processes 1 token per sequence (batch tokens total)."""
    n = active_params(cfg)
    if kind == "train":
        return 6.0 * n * seq * batch
    if kind == "prefill":
        return 2.0 * n * seq * batch
    return 2.0 * n * batch        # decode: one token per sequence


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_by_kind: Dict[str, float]
    n_devices: int
    model_flops_global: float
    # extras filled by analyze()
    bytes_all_per_device: float = 0.0   # pessimistic (no-fusion) bound
    xla_cost_flops: float = 0.0
    xla_cost_bytes: float = 0.0
    dynamic_whiles: int = 0
    breakdown: Optional[list] = None

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_device / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flop_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS (global) — remat/padding waste gauge."""
        hlo_global = self.flops_per_device * self.n_devices
        return self.model_flops_global / max(hlo_global, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """useful-FLOPs time at peak / bound time — the MFU-at-bound."""
        t_useful = (self.model_flops_global / self.n_devices) / PEAK_FLOPS
        return t_useful / max(self.bound_time, 1e-30)

    def to_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "coll_by_kind": self.coll_by_kind,
            "n_devices": self.n_devices,
            "model_flops_global": self.model_flops_global,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "dominant": self.dominant,
            "useful_flop_ratio": self.useful_flop_ratio,
            "roofline_fraction": self.roofline_fraction,
            "bytes_all_per_device": self.bytes_all_per_device,
            "xla_cost_flops": self.xla_cost_flops,
            "xla_cost_bytes": self.xla_cost_bytes,
            "dynamic_whiles": self.dynamic_whiles,
            "breakdown_top10": (self.breakdown or [])[:10],
        }


def analyze(compiled, cfg: ModelConfig, kind: str, seq: int, batch: int,
            n_devices: int, hlo_text: Optional[str] = None) -> Roofline:
    """Trip-count-aware terms from the compiled per-device HLO.

    ``compiled.cost_analysis()`` counts scan bodies once (≈L× under for
    scan-over-layers stacks), so the authoritative numbers come from
    ``repro.launch.hlo_cost.analyze_hlo``; XLA's own aggregate is kept
    in ``xla_cost_*`` fields for comparison.
    """
    from repro.launch.hlo_cost import analyze_hlo

    text = hlo_text if hlo_text is not None else compiled.as_text()
    hc = analyze_hlo(text)

    xla_flops = xla_bytes = 0.0
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        xla_flops = float(cost.get("flops", 0.0))
        xla_bytes = float(cost.get("bytes accessed", 0.0))
    except Exception:
        pass

    r = Roofline(
        flops_per_device=hc.flops,
        bytes_per_device=hc.bytes_min,
        coll_bytes_per_device=hc.coll_total(),
        coll_by_kind=dict(hc.coll),
        n_devices=n_devices,
        model_flops_global=model_flops(cfg, kind, seq, batch),
    )
    r.bytes_all_per_device = hc.bytes
    r.xla_cost_flops = xla_flops
    r.xla_cost_bytes = xla_bytes
    r.dynamic_whiles = hc.dynamic_whiles
    r.breakdown = hc.breakdown
    return r
