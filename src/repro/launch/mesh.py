"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant):
importing this module must not touch jax device state, because the
dry-run sets ``xla_force_host_platform_device_count`` before any jax
initialization and smoke tests must keep seeing 1 device.

Mesh layout:
  single-pod  (data=16, model=16)            — 256 chips (one v5e pod)
  multi-pod   (pod=2, data=16, model=16)     — 512 chips

The ``model`` axis carries TP / EP / decode sequence-parallelism; the
``data`` axis carries FSDP + batch DP; the ``pod`` axis carries pure DP
(parameters replicated across pods, one gradient all-reduce per step
over DCN — the only cross-pod traffic).  Elasticity: the mesh is a
function of the live device list, and every sharding is derived from
the mesh shape, so relaunching on (1|2|4, 16, 16) re-derives parameter
shardings and reuses checkpoints unchanged.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def elastic_mesh_shape(n_devices: int,
                       model_parallel: int = 16) -> Tuple[int, int, int]:
    """(pods, data, model) for ``n_devices`` live devices.

    Pure shape derivation (no jax device state) so it can be unit-tested
    at any device count.  The data-parallel product dp = n/model is
    split into pods×data targeting ~16 data shards per pod: pods is the
    largest divisor of dp not exceeding max(dp // 16, 1) (pods=1 in the
    worst case, data then absorbing all of dp), so pods·data·model ==
    n_devices holds exactly for every divisible count — the old
    derivation rounded twice and dropped devices (dp=33 gave 2×16=32).

    Raises ``ValueError`` (not an assert — asserts vanish under
    ``python -O``) when ``model_parallel`` does not divide the device
    count: an elastic relaunch must shrink the data axes, never the TP
    axis, because parameter shardings are derived from the model axis.
    """
    if n_devices <= 0:
        raise ValueError(
            f"elastic mesh needs at least one device (got {n_devices})")
    if n_devices % model_parallel:
        raise ValueError(
            f"elastic mesh: device count {n_devices} is not a multiple "
            f"of model_parallel={model_parallel} — the TP axis is fixed "
            "across relaunches (parameter shardings derive from it); "
            "adjust model_parallel or the device reservation")
    dp = n_devices // model_parallel
    pods = max(dp // 16, 1)
    while dp % pods:            # keep pods a divisor: pods*data == dp
        pods -= 1
    return pods, dp // pods, model_parallel


def make_elastic_mesh(devices: Optional[Sequence] = None,
                      model_parallel: int = 16):
    """Mesh over whatever devices are alive: (pod, data, model) with the
    pod×data product derived from the device count (elastic re-launch).
    Raises ``ValueError`` when model_parallel does not divide the device
    count — see ``elastic_mesh_shape``."""
    devices = list(devices if devices is not None else jax.devices())
    shape = elastic_mesh_shape(len(devices), model_parallel)
    return jax.make_mesh(shape, ("pod", "data", "model"),
                         devices=devices)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh for CPU tests (requires >= n_data*n_model devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
