"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant):
importing this module must not touch jax device state, because the
dry-run sets ``xla_force_host_platform_device_count`` before any jax
initialization and smoke tests must keep seeing 1 device.

Mesh layout:
  single-pod  (data=16, model=16)            — 256 chips (one v5e pod)
  multi-pod   (pod=2, data=16, model=16)     — 512 chips

The ``model`` axis carries TP / EP / decode sequence-parallelism; the
``data`` axis carries FSDP + batch DP; the ``pod`` axis carries pure DP
(parameters replicated across pods, one gradient all-reduce per step
over DCN — the only cross-pod traffic).  Elasticity: the mesh is a
function of the live device list, and every sharding is derived from
the mesh shape, so relaunching on (1|2|4, 16, 16) re-derives parameter
shardings and reuses checkpoints unchanged.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_elastic_mesh(devices: Optional[Sequence] = None,
                      model_parallel: int = 16):
    """Mesh over whatever devices are alive: (pod, data, model) with the
    pod×data product derived from the device count (elastic re-launch)."""
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    assert n % model_parallel == 0, (n, model_parallel)
    dp = n // model_parallel
    pods = max(dp // 16, 1)
    data = dp // pods
    return jax.make_mesh((pods, data, model_parallel),
                         ("pod", "data", "model"), devices=devices)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh for CPU tests (requires >= n_data*n_model devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
