"""HLO-text cost model: trip-count-aware FLOPs / bytes / collectives.

``compiled.cost_analysis()`` counts every computation ONCE — a
``lax.scan`` over 94 layers contributes its body a single time, so the
aggregate is ~L× too small (verified empirically on a scanned matmul).
The dry-run's roofline therefore walks the optimized HLO **text**:

  * per computation, a symbol table maps every instruction name to its
    result shape (operands are referenced by name in optimized HLO);
  * ``while`` bodies (+conditions) are scaled by the **trip count**,
    recovered from the loop bound constant in the condition computation
    (XLA counted-loop canonical form); dynamic-trip loops fall back to
    1 and are counted in ``dynamic_whiles``;
  * **flops**: every ``dot`` contributes 2 · |result| · Π(lhs
    contracting dims); fusion-internal dots count (they hit the MXU);
  * **bytes**: per materializing instruction, result + resolved operand
    bytes; fusion bodies are skipped (internal values stay in
    registers/VMEM) — the fusion call's own line carries its traffic;
  * **collectives**: moved bytes = ring-factor × max(result, operands)
    (all-reduce 2×, gather/scatter/a2a/permute 1×), trip-scaled.

The per-computation ``breakdown`` is the profiler the §Perf loop reads:
it names which loop body owns the dominant term.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8,
    "u64": 8, "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1,
    "f8e5m2": 1,
}

_TYPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\](?:\{[^}]*\})?")
_INSTR_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"([a-z][\w\-]*)\(")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")
_CALL_ATTR_RE = re.compile(r"(body|condition|to_apply|calls)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_DOT_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r"=\s*[su]\d+\[\]\s*constant\((\d+)\)")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter",
                "all-to-all", "collective-permute")
_COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0,
                "reduce-scatter": 1.0, "all-to-all": 1.0,
                "collective-permute": 1.0}

# ops that move no HBM bytes of their own (control flow passes buffers
# by reference — the body's instructions already carry the traffic)
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "partition-id", "replica-id",
             "opt-barrier", "custom-call", "while", "conditional",
             "call"}

# algorithm-intrinsic traffic: what even a perfectly-fusing compiler
# must move.  The CPU backend fuses far less than the TPU backend, so
# raw per-op bytes ("bytes_all") overstate TPU HBM traffic; `bytes_min`
# counts only these ops (incl. fusions' own in/out, which model TPU
# fusion-group traffic).  Truth on TPU lies in [bytes_min, bytes_all].
_ESSENTIAL_OPS = {"dot", "convolution", "fusion", "reduce",
                  "reduce-window", "scatter", "gather", "sort",
                  "dynamic-slice", "dynamic-update-slice",
                  "select-and-scatter", "cholesky", "triangular-solve",
                  "all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute"}


def _dims_of(type_str_dims: str) -> List[int]:
    return [int(d) for d in type_str_dims.split(",") if d]


@dataclasses.dataclass
class _Instr:
    name: str
    opcode: str
    result_dims: List[List[int]]       # list of typed shapes (tuples)
    result_bytes: int
    operands: List[str]
    line: str


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    bytes_min: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)
    children: List[Tuple[str, str, Optional[str]]] = dataclasses.field(
        default_factory=list)
    trip_hint: Optional[int] = None


def _parse_line(line: str) -> Optional[_Instr]:
    m = _INSTR_RE.match(line)
    if not m:
        return None
    name, rhs = m.groups()
    # strip metadata (shapes can appear inside op_name strings)
    rhs_clean = rhs.split(", metadata=")[0]
    om = _OPCODE_RE.search(rhs_clean)
    if not om:
        return None
    opcode = om.group(1)
    type_part = rhs_clean[:om.start()]
    dims, nbytes = [], 0
    for dt, dd in _TYPE_RE.findall(type_part):
        nb = _DTYPE_BYTES.get(dt)
        if nb is None:
            continue
        d = _dims_of(dd)
        dims.append(d)
        n = 1
        for x in d:
            n *= x
        nbytes += n * nb
    # operand names: inside the opcode parens (up to the attr list)
    paren = rhs_clean[om.end():]
    depth, end = 1, len(paren)
    for i, ch in enumerate(paren):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    operands = _OPERAND_RE.findall(paren[:end])
    return _Instr(name=name, opcode=opcode, result_dims=dims,
                  result_bytes=nbytes, operands=operands, line=rhs_clean)


def _parse_computations(hlo: str) -> Tuple[Dict[str, CompCost], str,
                                           Dict[str, Dict[str, _Instr]]]:
    comps: Dict[str, CompCost] = {}
    tables: Dict[str, Dict[str, _Instr]] = {}
    entry = ""
    cur: Optional[str] = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if not line:
            continue
        if line == "}":
            cur = None
            continue
        if line.endswith("{") and "=" not in line.split("(")[0]:
            hdr = _COMP_HDR_RE.match(line)
            if hdr:
                cur = hdr.group(2)
                comps[cur] = CompCost()
                tables[cur] = {}
                if hdr.group(1):
                    entry = cur
            continue
        if cur is None:
            continue
        ins = _parse_line(line)
        if ins is None:
            continue
        comps_c = comps[cur]
        tables[cur][ins.name] = ins

        # call-graph edges
        attrs = dict(_CALL_ATTR_RE.findall(ins.line))
        if "body" in attrs:
            comps_c.children.append(
                ("while", attrs["body"], attrs.get("condition")))
        elif "to_apply" in attrs and ins.opcode not in (
                "reduce", "reduce-window", "sort", "scatter", "map",
                "select-and-scatter", "all-reduce", "reduce-scatter"):
            comps_c.children.append(("apply", attrs["to_apply"], None))
        elif "calls" in attrs:
            comps_c.children.append(("fusion", attrs["calls"], None))
        bm = _BRANCHES_RE.search(ins.line)
        if bm:
            for nm in bm.group(1).split(","):
                comps_c.children.append(
                    ("branch", nm.strip().lstrip("%"), None))

        # trip-count hint
        tm = _TRIP_RE.search(line)
        if tm:
            val = int(tm.group(1))
            if comps_c.trip_hint is None or val > comps_c.trip_hint:
                comps_c.trip_hint = val
    return comps, entry, tables


def _operand_bytes(ins: _Instr, table: Dict[str, _Instr]) -> int:
    total = 0
    for op in ins.operands:
        t = table.get(op)
        if t is not None:
            total += t.result_bytes
    return total


def _slice_adjust(table: Dict[str, _Instr]) -> int:
    """Bytes over-charged to a fusion whose parameters are consumed only
    through dynamic-slice (the fusion reads slices, not whole buffers).

    Returns Σ over such params of (param_bytes − Σ 2·slice_bytes)."""
    uses: Dict[str, List[_Instr]] = {}
    for ins in table.values():
        for op in ins.operands:
            uses.setdefault(op, []).append(ins)
    adjust = 0
    for name, ins in table.items():
        if ins.opcode != "parameter":
            continue
        consumers = uses.get(name, [])
        if not consumers:
            continue
        if all(c.opcode == "dynamic-slice" and c.operands
               and c.operands[0] == name for c in consumers):
            sliced = sum(2 * c.result_bytes for c in consumers)
            if ins.result_bytes > sliced:
                adjust += ins.result_bytes - sliced
    return adjust


def _accumulate(comp: CompCost, table: Dict[str, _Instr],
                adjust: Dict[str, int]) -> None:
    for ins in table.values():
        # flops from dots (counted even inside fusions — MXU work)
        if ins.opcode == "dot":
            cm = _DOT_CONTRACT_RE.search(ins.line)
            result_elems = 0
            if ins.result_dims:
                n = 1
                for x in ins.result_dims[0]:
                    n *= x
                result_elems = n
            contract = 1
            if cm and ins.operands:
                lhs = table.get(ins.operands[0])
                if lhs is not None and lhs.result_dims:
                    ldims = lhs.result_dims[0]
                    for ci in (int(x) for x in cm.group(1).split(",")
                               if x):
                        if ci < len(ldims):
                            contract *= ldims[ci]
            comp.flops += 2.0 * result_elems * contract

        # collectives
        for k in _COLLECTIVES:
            if ins.opcode in (k, k + "-start"):
                moved = _COLL_FACTOR[k] * max(
                    ins.result_bytes, _operand_bytes(ins, table))
                comp.coll[k] = comp.coll.get(k, 0.0) + moved
                break

        # bytes
        if ins.opcode in _FREE_OPS or ins.opcode.endswith("-done"):
            continue
        # slicing ops touch only their slice, not the whole operand
        if ins.opcode in ("dynamic-slice", "gather"):
            moved = 2 * ins.result_bytes
        elif ins.opcode == "dynamic-update-slice":
            upd = table.get(ins.operands[1]) if len(ins.operands) > 1 \
                else None
            sl = upd.result_bytes if upd is not None else ins.result_bytes
            moved = 2 * sl          # read-modify-write of the slice region
        elif ins.opcode == "scatter":
            upd = table.get(ins.operands[-1]) if ins.operands else None
            sl = upd.result_bytes if upd is not None else ins.result_bytes
            moved = 3 * sl
        else:
            moved = ins.result_bytes + _operand_bytes(ins, table)
            if ins.opcode == "fusion":
                m = _CALL_ATTR_RE.search(ins.line)
                attrs = dict(_CALL_ATTR_RE.findall(ins.line))
                child = attrs.get("calls")
                if child in adjust:
                    moved = max(ins.result_bytes, moved - adjust[child])
        comp.bytes += moved
        base = ins.opcode[:-6] if ins.opcode.endswith("-start") \
            else ins.opcode
        if base in _ESSENTIAL_OPS:
            comp.bytes_min += moved


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes: float
    bytes_min: float
    coll: Dict[str, float]
    dynamic_whiles: int
    breakdown: List[Tuple[str, float, float, float, float]]
    # rows: (computation, multiplier, flops, bytes, coll_bytes)

    def coll_total(self) -> float:
        return sum(self.coll.values())


def analyze_hlo(hlo: str) -> HloCost:
    comps, entry, tables = _parse_computations(hlo)
    adjust = {name: _slice_adjust(t) for name, t in tables.items()}
    for name, comp in comps.items():
        _accumulate(comp, tables[name], adjust)

    dynamic = [0]
    rows: Dict[str, List[float]] = {}

    def walk(name: str, mult: float, in_fusion: bool,
             seen: Tuple[str, ...]) -> Tuple[float, float, Dict[str, float]]:
        if name not in comps or name in seen:
            return 0.0, 0.0, 0.0, {}
        c = comps[name]
        own_bytes = 0.0 if in_fusion else c.bytes
        own_min = 0.0 if in_fusion else c.bytes_min
        flops = c.flops * mult
        byts = own_bytes * mult
        bmin = own_min * mult
        coll = {k: v * mult for k, v in c.coll.items()}
        r = rows.setdefault(name, [0.0, 0.0, 0.0, 0.0])
        r[0] += mult
        r[1] += flops
        r[2] += own_bytes * mult
        r[3] += sum(coll.values())

        for kind, child, aux in c.children:
            child_mult = mult
            child_fusion = in_fusion
            extra = []
            if kind == "while":
                trip = None
                if aux and aux in comps and comps[aux].trip_hint:
                    trip = comps[aux].trip_hint
                if trip is None:
                    dynamic[0] += 1
                    trip = 1
                child_mult = mult * trip
                if aux:
                    extra.append((aux, child_mult, child_fusion))
            elif kind == "fusion":
                child_fusion = True
            f2, b2, m2, c2 = walk(child, child_mult, child_fusion,
                                  seen + (name,))
            flops += f2
            byts += b2
            bmin += m2
            for k, v in c2.items():
                coll[k] = coll.get(k, 0.0) + v
            for en, em, ef in extra:
                f3, b3, m3, c3 = walk(en, em, ef, seen + (name,))
                flops += f3
                byts += b3
                bmin += m3
                for k, v in c3.items():
                    coll[k] = coll.get(k, 0.0) + v
        return flops, byts, bmin, coll

    flops, byts, bmin, coll = walk(entry, 1.0, False, ())
    breakdown = sorted(
        ((n, v[0], v[1], v[2], v[3]) for n, v in rows.items()),
        key=lambda t: -(t[2] + t[3]))
    return HloCost(flops=flops, bytes=byts, bytes_min=bmin, coll=coll,
                   dynamic_whiles=dynamic[0], breakdown=breakdown[:40])
