"""Training launcher: ``--arch <id>`` selects any registry config.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2_72b \
        --smoke --steps 50 [--node] [--grad-method aca]

``--smoke`` uses the reduced same-family config (CPU-feasible); without
it the full config is built — on real hardware the mesh comes from
``make_elastic_mesh`` over the live device list, checkpoints are
written/resumed via the atomic CheckpointManager, and the step-indexed
pipeline makes restarts exact.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.core.node_block import NodeConfig
from repro.data import TokenPipeline
from repro.launch.mesh import make_elastic_mesh
from repro.models import RunConfig, build_model
from repro.models.frontends import frontend_batch_synthetic
from repro.optim import adamw, cosine_warmup
from repro.train import TrainLoop, TrainLoopConfig, make_train_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--node", action="store_true")
    ap.add_argument("--grad-method", default="aca",
                    choices=["aca", "adjoint", "naive"])
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--mesh", action="store_true",
                    help="build an elastic mesh over live devices")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else \
        get_config(args.arch)
    mesh = make_elastic_mesh(model_parallel=1) if args.mesh else None
    node = NodeConfig(enabled=args.node, regime="fixed", solver="rk2",
                      grad_method=args.grad_method, steps_per_interval=2)
    rcfg = RunConfig(mesh=mesh,
                     compute_dtype=jnp.float32 if args.smoke
                     else jnp.bfloat16, node=node)
    model = build_model(cfg, rcfg)
    print(f"arch={cfg.name} params={model.n_params()/1e6:.1f}M "
          f"node={args.node}")

    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq,
                         global_batch=args.batch)

    def batch_fn(step):
        if cfg.frontend != "none":
            return frontend_batch_synthetic(
                cfg, args.batch, args.seq, jax.random.PRNGKey(step),
                compute_dtype=rcfg.compute_dtype)
        return pipe.batch(step)

    opt = adamw(cosine_warmup(3e-4, 20, max(args.steps, 100)),
                weight_decay=0.1)
    lcfg = TrainLoopConfig(microbatches=args.microbatches,
                           compression=args.compression,
                           ckpt_dir=args.ckpt_dir, ckpt_every=100,
                           log_every=10)
    state = make_train_state(model, opt, jax.random.PRNGKey(0))
    loop = TrainLoop(model, opt, lcfg, state)
    loop.run(batch_fn, args.steps,
             log_cb=lambda s, m: print(
                 f"step {s:5d} loss {m['loss']:.4f} "
                 f"gnorm {m['grad_norm']:.2f}"))


if __name__ == "__main__":
    main()
