import os

if __name__ == "__main__":          # CLI: lock devices before jax init
    os.environ.setdefault(
        "XLA_FLAGS",
        "--xla_force_host_platform_device_count="
        + os.environ.get("REPRO_NODE_DRYRUN_DEVICES", "8"))

"""Mesh-sharded NODE solve dry-run: lower + compile + roofline verdict.

The production dry-run (``launch/dryrun.py``) costs transformer cells
from static HLO alone; a NODE cell cannot be costed that way because
its hot loop is a *dynamic-trip* ``while_loop`` — ``analyze_hlo``
counts the body once and reports the fact in ``dynamic_whiles``.  This
module therefore measures instead of guessing: it compiles the sharded
``odeint(..., mesh=...)`` train/serve cell, runs it ONCE on the small
forced-host-device arrays to read the real per-element trial counts
out of ``SolveStats``, scales the while-body compute terms by the
measured straggler trip count, and renders the three-term §Roofline —
asserting the solve stays compute-bound, not collective-bound (the one
cross-device collective is the shared-args cotangent psum).

    PYTHONPATH=src python -m repro.launch.node_dryrun \
        --kind train --grad-method adjoint [--batch 64] [--dim 32]

Unlike ``dryrun.py`` this module is import-safe (no device-count
mutation at import time): the XLA flag is set only when run as a
script, so tests can import ``run_node_cell`` under their own flag.

Each cell writes results/dryrun/node/<cell>.json with the measured
trip counts, static HLO costs, roofline terms and the verdict.
"""

import argparse
import json
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_cost import analyze_hlo
from repro.launch.roofline import Roofline

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun", "node")


def _field(t, z, w):
    """Benchmark NODE vector field: stiffness ladder + dense coupling.

    ``z[:-1]`` is the state, ``z[-1]`` a per-element log-stiffness
    (frozen: derivative 0) so a batch is stiffness-heterogeneous; ``w``
    is the shared (replicated) parameter whose cotangent is the one
    cross-device psum.  Per eval: one (d-1)×(d-1) matmul ≈ 2(d-1)²
    FLOPs per element.
    """
    x, logk = z[:-1], z[-1]
    dx = -jnp.exp(logk) * x + 0.5 * jnp.tanh(x @ w)
    return jnp.concatenate([dx, jnp.zeros((1,), z.dtype)])


def node_problem(batch: int, dim: int, seed: int = 0):
    """(z0, ts, w) for the benchmark cell — dim includes the stiffness
    slot, so the live state is dim-1 wide."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w = (jax.random.normal(k1, (dim - 1, dim - 1))
         * (0.3 / (dim - 1) ** 0.5)).astype(jnp.float32)
    x0 = (jax.random.normal(k2, (batch, dim - 1)) * 0.5).astype(jnp.float32)
    frac = jnp.arange(batch) / max(batch - 1.0, 1.0)
    logk = (0.5 + 3.0 * frac ** 2).astype(jnp.float32)
    z0 = jnp.concatenate([x0, logk[:, None]], axis=1)
    ts = jnp.array([0.0, 1.0], jnp.float32)
    return z0, ts, w


def field_flops_per_eval(batch: int, dim: int) -> float:
    """Analytic FLOPs of one batched field eval (matmul + elementwise)."""
    d = dim - 1
    return float(batch) * (2.0 * d * d + 6.0 * d)


def build_node_cell(kind: str, *, batch: int, dim: int, mesh,
                    grad_method: str = "aca", rtol: float = 1e-4,
                    atol: float = 1e-4, max_steps: int = 512):
    """The jitted sharded NODE cell: ``train`` = value_and_grad of a
    scalar loss w.r.t. (z0, w); ``serve`` = forward solve only.

    Returns ``(fn, (z0, ts, w))`` — ``fn(z0, w)`` ready to lower or run.
    """
    from repro.core import odeint

    z0, ts, w = node_problem(batch, dim)
    kw: Dict[str, Any] = dict(grad_method=grad_method, rtol=rtol,
                              atol=atol, max_steps=max_steps,
                              batch_axis=0, mesh=mesh)
    if grad_method != "mali":
        kw["solver"] = "dopri5"

    def solve(z0, w):
        return odeint(_field, z0, ts, (w,), **kw)

    if kind == "serve":
        fn = jax.jit(solve)
    else:
        def train(z0, w):
            def loss(z0, w):
                ys, stats = solve(z0, w)
                return jnp.sum(jax.tree.leaves(ys)[0] ** 2), stats
            (val, stats), grads = jax.value_and_grad(
                loss, argnums=(0, 1), has_aux=True)(z0, w)
            return val, grads, stats
        fn = jax.jit(train)
    return fn, (z0, ts, w)


def run_node_cell(kind: str = "train", *, batch: int = 64, dim: int = 32,
                  grad_method: str = "aca", n_devices: Optional[int] = None,
                  rtol: float = 1e-4, atol: float = 1e-4,
                  max_steps: int = 512, save: bool = True) -> Dict:
    """Compile, measure and roofline one sharded NODE cell.

    The compiled HLO is costed statically (``analyze_hlo``; the solve's
    while loops land in ``dynamic_whiles`` at trip 1), then the cell
    runs once and the *measured* straggler trip count — the max over
    shards of the shard's worst per-element trial count, which is what
    bounds SPMD wall time — scales the compute/memory terms.  The
    collective term is NOT scaled: the shared-args psum sits outside
    the while loop and fires once per call.  Hardware constants are the
    v5e roofline's — the verdict is about the *shape* of the cell
    (compute- vs collective-bound), not host-CPU wall time.
    """
    from repro.distributed.sharding import shard_mesh
    from repro.core.integrate import SolveStatus

    devs = jax.devices()
    n = len(devs) if n_devices is None else n_devices
    mesh = shard_mesh(devs[:n])
    fn, (z0, ts, w) = build_node_cell(
        kind, batch=batch, dim=dim, mesh=mesh, grad_method=grad_method,
        rtol=rtol, atol=atol, max_steps=max_steps)

    lowered = fn.lower(z0, w)
    compiled = lowered.compile()
    hlo_text = compiled.as_text()
    hc = analyze_hlo(hlo_text)

    out = fn(z0, w)
    stats = out[-1] if kind == "train" else out[1]
    trials = np.asarray(jax.device_get(stats.n_trials))
    nfe = np.asarray(jax.device_get(stats.nfe))
    status = np.asarray(jax.device_get(stats.status))
    per_shard = trials.reshape(n, batch // n)
    # SPMD wall time is the straggler shard's; its while trip count is
    # its own worst element (per-sample controllers run until the local
    # max-trial element lands)
    trips = int(per_shard.max(axis=1).max())

    flops_meas = hc.flops * trips
    bytes_meas = hc.bytes_min * trips
    # analytic model FLOPs: measured field evals × per-eval cost;
    # backward sweeps re-evaluate f (vjp ≈ 2× an eval) — ×3 for train
    evals = float(nfe.sum()) / batch * 1.0
    mult = 3.0 if kind == "train" else 1.0
    model_fl = field_flops_per_eval(batch, dim) * evals * mult

    r = Roofline(
        flops_per_device=flops_meas,
        bytes_per_device=bytes_meas,
        coll_bytes_per_device=hc.coll_total(),
        coll_by_kind=dict(hc.coll),
        n_devices=n,
        model_flops_global=model_fl,
    )
    r.dynamic_whiles = hc.dynamic_whiles
    r.breakdown = hc.breakdown

    report = {
        "cell": f"node_{kind}__{grad_method}__b{batch}d{dim}x{n}",
        "kind": kind,
        "grad_method": grad_method,
        "batch": batch,
        "dim": dim,
        "n_devices": n,
        "measured": {
            "while_trips_straggler": trips,
            "trials_per_element_min": int(trials.min()),
            "trials_per_element_max": int(trials.max()),
            "nfe_total": int(nfe.sum()),
            "all_ok": bool((status == SolveStatus.OK).all()),
        },
        "hlo_static": {
            "flops_body_once": hc.flops,
            "bytes_body_once": hc.bytes_min,
            "dynamic_whiles": hc.dynamic_whiles,
        },
        "roofline": r.to_dict(),
        "compute_bound": r.dominant == "compute",
        "collective_bound": r.dominant == "collective",
    }
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        path = os.path.join(RESULTS_DIR, report["cell"] + ".json")
        with open(path, "w") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)
        report["path"] = path
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--kind", default="train", choices=["train", "serve"])
    ap.add_argument("--grad-method", default="aca",
                    choices=["aca", "adjoint", "naive", "mali"])
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--devices", type=int, default=None)
    args = ap.parse_args()

    rep = run_node_cell(args.kind, batch=args.batch, dim=args.dim,
                        grad_method=args.grad_method,
                        n_devices=args.devices)
    rl = rep["roofline"]
    print(f"# {rep['cell']}: trips={rep['measured']['while_trips_straggler']}"
          f" flops/dev={rl['flops_per_device']:.3e}"
          f" bytes/dev={rl['bytes_per_device']:.3e}"
          f" coll/dev={rl['coll_bytes_per_device']:.3e}"
          f" dominant={rl['dominant']}")
    print(f"# wrote {rep.get('path')}")
    if rep["collective_bound"]:
        raise SystemExit(
            "node dry-run FAILED: the sharded solve is collective-bound "
            f"(t_coll={rl['t_collective']:.3e}s > t_comp="
            f"{rl['t_compute']:.3e}s) — the batch shards are too small "
            "for the args-psum they amortize")
    print("# verdict: solve is "
          + ("compute" if rep["compute_bound"] else "memory")
          + "-bound, not collective-bound")


if __name__ == "__main__":
    main()
