import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=" + \
    os.environ.get("REPRO_DRYRUN_DEVICES", "512")

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above run before ANY other import (jax locks the device
count at first init).  512 host-platform placeholder devices let
``jax.make_mesh`` build the production meshes on this CPU-only box; the
cells are lowered from ShapeDtypeStructs — no full-size array is ever
allocated.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2_72b \
        --shape train_4k [--multi-pod] [--node]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Each cell writes results/dryrun/<mesh>/<arch>__<shape>.json with
memory_analysis, cost_analysis and the §Roofline terms.
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config, shape_plan
from repro.core.node_block import NodeConfig
from repro.distributed.sharding import (DEFAULT_TRAIN_RULES, fit_specs,
                                         logical_to_spec)
from repro.models import RunConfig, build_model
from repro.models.frontends import frontend_batch_abstract
from repro.optim import adamw, cosine_warmup
from repro.optim.grad_utils import CompressionState
from repro.train.loop import TrainLoopConfig, build_train_step
from repro.train.state import abstract_train_state, train_state_specs
from repro.launch.mesh import make_production_mesh
from repro.launch import roofline as rl

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _ns(mesh, spec_tree, abstract_tree=None):
    if abstract_tree is not None:
        # jit in_shardings demand divisibility; drop axes that don't fit
        spec_tree = fit_specs(abstract_tree, spec_tree, mesh)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _batch_abstract(cfg, kind: str, seq: int, gb: int):
    if cfg.frontend != "none" and kind != "decode":
        b = frontend_batch_abstract(cfg, gb, seq)
        if kind == "prefill":
            b = {"embeds": b["embeds"]}
        return b
    if kind == "train":
        return {
            "tokens": jax.ShapeDtypeStruct((gb, seq), jnp.int32),
            "labels": jax.ShapeDtypeStruct((gb, seq), jnp.int32),
            "mask": jax.ShapeDtypeStruct((gb, seq), jnp.float32),
        }
    if kind == "prefill":
        return {"tokens": jax.ShapeDtypeStruct((gb, seq), jnp.int32)}
    # decode: one new token (frontend archs feed a 1-step embedding)
    if cfg.frontend != "none":
        return {"embeds": jax.ShapeDtypeStruct((gb, 1, cfg.d_model),
                                               jnp.bfloat16)}
    return {"tokens": jax.ShapeDtypeStruct((gb, 1), jnp.int32)}


def _batch_specs(cfg, kind: str, rules, mesh, batch):
    out = {}
    for k in batch:
        if k == "embeds":
            out[k] = logical_to_spec(("batch", "seq", "embed_act"),
                                     rules, mesh)
        else:
            out[k] = logical_to_spec(("batch", "seq"), rules, mesh)
    return out


def build_cell(arch: str, shape: str, mesh, *, node: bool = False,
               rules=None, remat: str = "block",
               microbatches: int = 1, node_steps: int = 2):
    """Returns (jitted_fn, abstract_args) for the cell, or None if skipped."""
    plan = shape_plan(arch, shape)
    if plan is None:
        return None
    seq, gb, kind = plan
    cfg = get_config(arch)
    rules = rules or DEFAULT_TRAIN_RULES
    node_cfg = NodeConfig(enabled=node, regime="fixed", grad_method="aca",
                          solver="rk2", steps_per_interval=node_steps) \
        if node else NodeConfig()
    rcfg = RunConfig(
        mesh=mesh, rules=rules,
        compute_dtype=jnp.bfloat16,
        param_dtype=jnp.float32 if kind == "train" else jnp.bfloat16,
        remat=remat if kind == "train" else "none",
        node=node_cfg,
        max_seq=seq,
    )
    model = build_model(cfg, rcfg)

    batch = _batch_abstract(cfg, kind, seq, gb)
    batch_sh = _ns(mesh, _batch_specs(cfg, kind, rules, mesh, batch),
                   batch)
    param_sh = _ns(mesh, model.specs(mesh), model.abstract())

    if kind == "train":
        opt = adamw(cosine_warmup(3e-4, 100, 10000), weight_decay=0.1)
        lcfg = TrainLoopConfig(microbatches=microbatches, clip_norm=1.0,
                               compression="none")
        step = build_train_step(model, opt, lcfg)
        state = abstract_train_state(model, opt)
        state_sh = _ns(mesh, train_state_specs(model, opt, mesh), state)
        comp = CompressionState(error=())
        fn = jax.jit(step, in_shardings=(state_sh, batch_sh, None),
                     donate_argnums=(0,))
        args = (state, batch, comp)
    elif kind == "prefill":
        fn = jax.jit(model.prefill, in_shardings=(param_sh, batch_sh))
        args = (model.abstract(), batch)
    else:  # decode
        caches = model.abstract_caches(gb, seq)
        cache_sh = _ns(mesh, model.cache_specs(gb, seq, mesh=mesh), caches)
        fn = jax.jit(model.decode_step,
                     in_shardings=(param_sh, batch_sh, cache_sh,
                                   NamedSharding(mesh, P())),
                     donate_argnums=(2,))
        args = (model.abstract(), batch,
                caches, jax.ShapeDtypeStruct((), jnp.int32))
    return fn, args, cfg, (seq, gb, kind)


def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             node: bool = False, rules=None, remat: str = "block",
             microbatches: int = 1, node_steps: int = 2,
             save: bool = True, tag: str = "") -> Optional[Dict[str, Any]]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    cell = build_cell(arch, shape, mesh, node=node, rules=rules,
                      remat=remat, microbatches=microbatches,
                      node_steps=node_steps)
    if cell is None:
        return {"arch": arch, "shape": shape, "skipped": True,
                "reason": "full-attention arch skips long_500k"}
    fn, args, cfg, (seq, gb, kind) = cell

    t0 = time.time()
    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        mem_info = {"error": str(e)}

    hlo = compiled.as_text()
    roof = rl.analyze(compiled, cfg, kind, seq, gb, n_dev, hlo_text=hlo)

    result = {
        "arch": arch, "shape": shape, "kind": kind,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "node_mode": node,
        "seq": seq, "global_batch": gb,
        "n_devices": n_dev,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem_info,
        "roofline": roof.to_dict(),
        "hlo_instr_count": hlo.count("\n"),
    }
    if save:
        mesh_name = result["mesh"]
        d = os.path.join(RESULTS_DIR, mesh_name)
        os.makedirs(d, exist_ok=True)
        suffix = f"__{tag}" if tag else ("__node" if node else "")
        with open(os.path.join(d, f"{arch}__{shape}{suffix}.json"),
                  "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--node", action="store_true",
                    help="continuous-depth (NODE/ACA) train mode")
    ap.add_argument("--remat", default="block", choices=["none", "block"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--tag", default="")
    ap.add_argument("--node-steps", type=int, default=2)
    ap.add_argument("--override", action="append", default=[],
                    help="logical=axis sharding-rule override, e.g. "
                         "res_seq=model or embed=none (repeatable)")
    args = ap.parse_args()

    rules = DEFAULT_TRAIN_RULES
    for ov in args.override:
        k, v = ov.split("=")
        val = None if v.lower() in ("none", "null") else \
            (tuple(v.split("+")) if "+" in v else v)
        rules = rules.override(**{k: val})

    cells = []
    if args.all:
        for arch in ARCHS:
            if arch == "node18_cifar":
                continue        # covered by the dedicated --node rows
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        if not (args.arch and args.shape):
            raise ValueError("dryrun: pass --arch and --shape, or --all")
        cells.append((args.arch, args.shape))

    n_fail = 0
    for arch, shape in cells:
        try:
            r = run_cell(arch, shape, multi_pod=args.multi_pod,
                         node=args.node, remat=args.remat, rules=rules,
                         microbatches=args.microbatches,
                         node_steps=args.node_steps, tag=args.tag)
            if r.get("skipped"):
                print(f"[skip] {arch} × {shape}: {r['reason']}")
                continue
            roof = r["roofline"]
            print(f"[ok]  {arch} × {shape} ({r['mesh']}): "
                  f"compile {r['compile_s']}s  "
                  f"t_comp={roof['t_compute']:.3e}s "
                  f"t_mem={roof['t_memory']:.3e}s "
                  f"t_coll={roof['t_collective']:.3e}s "
                  f"dom={roof['dominant']} "
                  f"frac={roof['roofline_fraction']:.2f}")
        except Exception:
            n_fail += 1
            print(f"[FAIL] {arch} × {shape}")
            traceback.print_exc()
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
