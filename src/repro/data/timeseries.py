"""Irregularly-sampled time-series data (Mujoco stand-in, paper Sec 4.3).

Trajectories are sampled from a latent 2nd-order linear ODE with
nonlinear readout (the same generative structure latent-ODE assumes),
observed at *irregular* per-sample time points — the setting where
RNNs fail and latent-ODE + ACA shines (paper Table 4).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


def irregular_series_batch(batch: int, n_obs: int, obs_dim: int = 8,
                           latent_dim: int = 4, t_max: float = 5.0,
                           seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Returns {ts (B, T) sorted, ys (B, T, D), mask (B, T)}.

    Latent dynamics: dz/dt = A z with A skew-symmetric + damping
    (oscillatory, well-conditioned); readout y = tanh(z W) + noise.
    """
    rng = np.random.default_rng(seed)
    skew = rng.normal(size=(latent_dim, latent_dim))
    a_mat = 0.8 * (skew - skew.T) - 0.15 * np.eye(latent_dim)
    w_out = rng.normal(size=(latent_dim, obs_dim)) / np.sqrt(latent_dim)

    ts = np.sort(rng.uniform(0, t_max, size=(batch, n_obs)), axis=1)
    ts[:, 0] = 0.0
    z0 = rng.normal(size=(batch, latent_dim))

    # exact solution via matrix exponential per observation time
    ys = np.zeros((batch, n_obs, obs_dim))
    for i in range(batch):
        for j in range(n_obs):
            m = _expm(a_mat * ts[i, j])
            z = m @ z0[i]
            ys[i, j] = np.tanh(z @ w_out)
    ys += rng.normal(scale=0.02, size=ys.shape)
    return {
        "ts": jnp.asarray(ts, jnp.float32),
        "ys": jnp.asarray(ys, jnp.float32),
        "mask": jnp.ones((batch, n_obs), jnp.float32),
    }


def _expm(a: np.ndarray) -> np.ndarray:
    """Scaling-and-squaring Padé-free matrix exponential (Taylor, scaled).

    scipy may be unavailable offline; 20-term Taylor after scaling by
    2^k so that ||A/2^k|| < 0.5 is accurate to ~1e-12 for these sizes.
    """
    norm = np.linalg.norm(a, ord=np.inf)
    k = max(0, int(np.ceil(np.log2(max(norm, 1e-30) / 0.5))))
    a_s = a / (2 ** k)
    m = np.eye(a.shape[0])
    term = np.eye(a.shape[0])
    for i in range(1, 21):
        term = term @ a_s / i
        m = m + term
    for _ in range(k):
        m = m @ m
    return m
