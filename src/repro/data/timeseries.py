"""Irregularly-sampled time-series data (Mujoco stand-in, paper Sec 4.3).

Trajectories are sampled from a latent 2nd-order linear ODE with
nonlinear readout (the same generative structure latent-ODE assumes),
observed at *irregular* per-sample time points — the setting where
RNNs fail and latent-ODE + ACA shines (paper Table 4).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


def irregular_series_batch(batch: int, n_obs: int, obs_dim: int = 8,
                           latent_dim: int = 4, t_max: float = 5.0,
                           seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Returns {ts (B, T) sorted, ys (B, T, D), mask (B, T)}.

    Latent dynamics: dz/dt = A z with A skew-symmetric + damping
    (oscillatory, well-conditioned); readout y = tanh(z W) + noise.
    """
    rng = np.random.default_rng(seed)
    skew = rng.normal(size=(latent_dim, latent_dim))
    a_mat = 0.8 * (skew - skew.T) - 0.15 * np.eye(latent_dim)
    w_out = rng.normal(size=(latent_dim, obs_dim)) / np.sqrt(latent_dim)

    ts = np.sort(rng.uniform(0, t_max, size=(batch, n_obs)), axis=1)
    ts[:, 0] = 0.0
    z0 = rng.normal(size=(batch, latent_dim))

    # exact solution via matrix exponential per observation time
    ys = np.zeros((batch, n_obs, obs_dim))
    for i in range(batch):
        for j in range(n_obs):
            m = _expm(a_mat * ts[i, j])
            z = m @ z0[i]
            ys[i, j] = np.tanh(z @ w_out)
    ys += rng.normal(scale=0.02, size=ys.shape)
    return {
        "ts": jnp.asarray(ts, jnp.float32),
        "ys": jnp.asarray(ys, jnp.float32),
        "mask": jnp.ones((batch, n_obs), jnp.float32),
    }


def merged_time_grid(ts) -> Dict[str, jnp.ndarray]:
    """Union eval grid over a batch of per-sample irregular time rows.

    ``ts`` (B, T), rows sorted ascending (``irregular_series_batch``'s
    layout).  Returns ``{"t_union": (M,), "idx": (B, T)}`` with
    ``t_union`` the strictly-increasing union of every observation time
    (duplicates removed — ``odeint`` rejects repeated eval times) and
    ``t_union[idx[b, j]] == ts[b, j]``; dtype is the default float
    (float64 under ``JAX_ENABLE_X64`` — no silent truncation).

    This is the latent-ODE dense-output path: instead of one solve per
    sample landing on its own T times, integrate the whole batch once
    through ``t_union`` with ``odeint(..., batch_axis=0,
    interpolate_ts=True)`` — M ≈ B·T eval points would inflate a
    forced-landing solve's step count by ~B×, but on the natural grid
    they are free interpolant reads — then gather sample b's outputs as
    ``ys[idx[b], b]``.
    """
    # cast to the grid dtype BEFORE deduplicating: times whose gap is
    # below that dtype's resolution must collapse into ONE knot here,
    # not into a repeat after a later cast (odeint's monotonicity check
    # rejects repeats).  The default float dtype keeps float64 inputs
    # exact under JAX_ENABLE_X64 instead of truncating them.
    tdt = np.dtype(jnp.result_type(float))
    tsn = np.asarray(ts, tdt)
    t_union, inv = np.unique(tsn.reshape(-1), return_inverse=True)
    return {
        "t_union": jnp.asarray(t_union, tdt),
        "idx": jnp.asarray(inv.reshape(tsn.shape), jnp.int32),
    }


def _expm(a: np.ndarray) -> np.ndarray:
    """Scaling-and-squaring Padé-free matrix exponential (Taylor, scaled).

    scipy may be unavailable offline; 20-term Taylor after scaling by
    2^k so that ||A/2^k|| < 0.5 is accurate to ~1e-12 for these sizes.
    """
    norm = np.linalg.norm(a, ord=np.inf)
    k = max(0, int(np.ceil(np.log2(max(norm, 1e-30) / 0.5))))
    a_s = a / (2 ** k)
    m = np.eye(a.shape[0])
    term = np.eye(a.shape[0])
    for i in range(1, 21):
        term = term @ a_s / i
        m = m + term
    for _ in range(k):
        m = m @ m
    return m
