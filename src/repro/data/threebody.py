"""Three-body gravitational system (paper Sec. 4.4).

State y = (r (3,3), v (3,3)); dynamics Eq. 32:

    r̈_i = -Σ_{j≠i} G m_j (r_i - r_j) / |r_i - r_j|³

``simulate_three_body`` generates ground-truth trajectories with our own
Dopri5 at tight tolerance (unequal masses, arbitrary initial conditions
— the setting Breen et al. could not handle, per the paper).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

G_CONST = 1.0  # normalized units (AU / yr / solar-mass style)


def three_body_rhs(t, state, masses):
    """state {"r": (3,3), "v": (3,3)}; masses (3,)."""
    r, v = state["r"], state["v"]
    diff = r[:, None, :] - r[None, :, :]                   # r_i - r_j
    dist3 = jnp.sum(diff ** 2, -1) ** 1.5
    dist3 = jnp.where(jnp.eye(3, dtype=bool), 1.0, dist3)  # mask self
    acc = -G_CONST * jnp.sum(
        jnp.where(jnp.eye(3, dtype=bool)[..., None], 0.0,
                  masses[None, :, None] * diff / dist3[..., None]),
        axis=1)
    return {"r": v, "v": acc}


def simulate_three_body(
    n_points: int = 1000,
    t_max: float = 2.0,
    masses: Tuple[float, float, float] = (1.0, 0.8, 1.2),
    seed: int = 0,
    rtol: float = 1e-8,
    atol: float = 1e-8,
):
    """Returns (ts (T,), rs (T, 3, 3), vs (T, 3, 3), masses (3,))."""
    from repro.core import odeint

    rng = np.random.default_rng(seed)
    # well-separated initial positions, mild random velocities
    r0 = np.array([[1.0, 0.1, -0.2], [-0.9, -0.4, 0.3], [0.1, 0.8, 0.1]])
    r0 += rng.normal(scale=0.05, size=r0.shape)
    v0 = rng.normal(scale=0.3, size=(3, 3))
    v0 -= v0.mean(0, keepdims=True)      # zero total momentum

    m = jnp.asarray(masses, jnp.float32)
    state0 = {"r": jnp.asarray(r0, jnp.float32),
              "v": jnp.asarray(v0, jnp.float32)}
    ts = jnp.linspace(0.0, t_max, n_points)
    ys, stats = odeint(three_body_rhs, state0, ts, (m,),
                       solver="dopri5", grad_method="aca",
                       rtol=rtol, atol=atol, max_steps=4096)
    return ts, ys["r"], ys["v"], m
