"""Synthetic token / classification pipelines (offline substitutes).

``TokenPipeline`` generates language-model batches with Zipfian token
statistics and a deterministic (seed, step) -> batch mapping; each host
materializes only its shard of the global batch (``host_slice``), which
is how the real-cluster input pipeline stays O(per-host).

``spiral_classification`` is the image-classification stand-in for the
paper's CIFAR experiments (same task structure: k-class classification
of points no linear model separates; NODE vs discrete-net comparisons
are preserved).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2

    def batch(self, step: int,
              host_slice: Optional[Tuple[int, int]] = None
              ) -> Dict[str, jnp.ndarray]:
        """Batch for ``step``; host_slice=(host_idx, n_hosts) selects the
        host-local rows of the global batch."""
        b = self.global_batch
        lo, hi = 0, b
        if host_slice is not None:
            idx, n = host_slice
            per = b // n
            lo, hi = idx * per, (idx + 1) * per
        # per-row seeding so a host materializes ONLY its rows yet gets
        # exactly the global batch's rows lo..hi
        rows = []
        for r in range(lo, hi):
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, step, r]))
            rows.append(rng.zipf(self.zipf_a, size=self.seq_len + 1))
        z = np.stack(rows)
        toks = np.minimum(z - 1, self.vocab - 1).astype(np.int32)
        return {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
            "mask": jnp.ones((hi - lo, self.seq_len), jnp.float32),
        }


def spiral_classification(n: int, n_classes: int = 3, noise: float = 0.15,
                          dim: int = 16, seed: int = 0,
                          lift_seed: int = 0
                          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """k-arm spiral classification, lifted to ``dim`` features.

    ``seed`` draws the points; ``lift_seed`` draws the (fixed) feature
    lift — train/test splits must share it.  Returns (x, y)."""
    rng = np.random.default_rng(seed)
    per = n // n_classes
    xs, ys = [], []
    for c in range(n_classes):
        t = np.linspace(0.3, 2.5 * np.pi, per)
        r = t / (2.5 * np.pi)
        ang = t + 2 * np.pi * c / n_classes
        pts = np.stack([r * np.cos(ang), r * np.sin(ang)], 1)
        pts += rng.normal(scale=noise * r[:, None], size=pts.shape)
        xs.append(pts)
        ys.append(np.full(per, c))
    x2 = np.concatenate(xs).astype(np.float32)
    y = np.concatenate(ys).astype(np.int32)
    # random fixed lift to `dim` features (keeps the task, adds width)
    lift_rng = np.random.default_rng(lift_seed)
    lift = lift_rng.normal(size=(2, dim)).astype(np.float32) / np.sqrt(2)
    x = x2 @ lift
    perm = rng.permutation(len(y))
    return jnp.asarray(x[perm]), jnp.asarray(y[perm])
