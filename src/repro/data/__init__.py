"""repro.data — deterministic synthetic data pipelines.

All pipelines are *step-indexed*: batch(step) is a pure function of
(seed, step), so a restarted job resumes mid-epoch without data-state
checkpointing — the fault-tolerance contract the train loop relies on.
"""

from .synthetic import TokenPipeline, spiral_classification
from .timeseries import irregular_series_batch, merged_time_grid
from .threebody import simulate_three_body, three_body_rhs

__all__ = [
    "TokenPipeline", "spiral_classification",
    "irregular_series_batch", "merged_time_grid",
    "simulate_three_body", "three_body_rhs",
]
