"""Atomic, resumable pytree checkpointing.

Fault-tolerance contract (1000+-node posture):

* **Atomicity** — a step's checkpoint is written to ``step_XXXX.tmp/``
  and ``os.rename``d to ``step_XXXX/`` only after every leaf + manifest
  hit disk and are fsync'd; a crash mid-write can never produce a
  half-readable "latest".
* **Monotonic naming + auto-resume** — ``latest_step`` scans for the
  highest *committed* step; ``restore_checkpoint`` validates the
  manifest (leaf count, shapes, dtypes, treedef hash) before use and
  falls back to the previous step if validation fails.
* **keep-K GC** — older committed checkpoints beyond ``keep`` are
  removed only after a newer one commits.
* **Sharded leaves** — every leaf is its own ``.npy`` file keyed by its
  pytree path, so a multi-host deployment writes disjoint files per
  host (per-host shard slices) into the same step directory; the
  manifest records the global tree.  Re-sharding on restore is the
  loader's job (parameters are placed via the run's current mesh).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any

_MANIFEST = "manifest.json"


def _leaf_key(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return "__".join(out) or "leaf"


def _treedef_hash(tree: PyTree) -> str:
    s = str(jax.tree.structure(tree))
    return hashlib.sha256(s.encode()).hexdigest()[:16]


def save_checkpoint(directory: str, step: int, tree: PyTree) -> str:
    """Atomic write of ``tree`` for ``step``.  Returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"step": step, "treedef": _treedef_hash(tree), "leaves": {}}
    for path, leaf in leaves:
        key = _leaf_key(path)
        arr = np.asarray(jax.device_get(leaf))
        fname = key + ".npy"
        with open(os.path.join(tmp, fname), "wb") as f:
            np.save(f, arr)
            f.flush()
            os.fsync(f.fileno())
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}

    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)    # the commit point
    return final


def _committed_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            full = os.path.join(directory, name, _MANIFEST)
            if os.path.exists(full):
                try:
                    steps.append(int(name[5:]))
                except ValueError:
                    continue
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = _committed_steps(directory)
    return steps[-1] if steps else None


def _validate_and_load(path: str, like: PyTree) -> PyTree:
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    if manifest["treedef"] != _treedef_hash(like):
        raise ValueError(f"{path}: treedef mismatch")
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for lpath, leaf in leaves:
        key = _leaf_key(lpath)
        meta = manifest["leaves"].get(key)
        if meta is None:
            raise ValueError(f"{path}: missing leaf {key}")
        arr = np.load(os.path.join(path, meta["file"]))
        want = tuple(np.shape(leaf)) if hasattr(leaf, "shape") else None
        if want is not None and tuple(arr.shape) != want:
            raise ValueError(
                f"{path}: leaf {key} shape {arr.shape} != {want}")
        out.append(arr)
    return jax.tree_util.tree_unflatten(
        jax.tree.structure(like), out)


def restore_checkpoint(directory: str, like: PyTree,
                       step: Optional[int] = None
                       ) -> Optional[tuple]:
    """Restore the given (or latest valid) step.  Returns (step, tree) or
    None.  A corrupt newest checkpoint falls back to the previous one."""
    steps = _committed_steps(directory)
    if step is not None:
        steps = [s for s in steps if s == step]
    for s in reversed(steps):
        path = os.path.join(directory, f"step_{s:010d}")
        try:
            return s, _validate_and_load(path, like)
        except Exception:
            continue    # corrupt/partial: try the previous committed step
    return None


class CheckpointManager:
    """save/restore with keep-K garbage collection."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep

    def save(self, step: int, tree: PyTree) -> str:
        path = save_checkpoint(self.directory, step, tree)
        self._gc()
        return path

    def restore(self, like: PyTree, step: Optional[int] = None):
        return restore_checkpoint(self.directory, like, step)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def _gc(self):
        steps = _committed_steps(self.directory)
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:010d}"),
                ignore_errors=True)
