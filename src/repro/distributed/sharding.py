"""Logical-axis sharding rules → ``PartitionSpec`` / ``NamedSharding``.

Tensors throughout the model code are annotated with *logical* axis names
("batch", "embed", "mlp", "heads", ...).  A rule table maps each logical
name to zero or more physical mesh axes.  This indirection is what lets the
same model definition run on

  * no mesh at all (CPU smoke tests — every rule resolves to ``None``),
  * the single-pod mesh  (data=16, model=16),
  * the multi-pod mesh   (pod=2, data=16, model=16),

and lets the perf loop change a sharding decision in exactly one place.

Two rule sets ship by default:

``DEFAULT_TRAIN_RULES``
    2-D weight sharding (FSDP x TP): weight ``embed``/``ffn-in`` dims shard
    over the data axis, head/mlp/vocab/expert dims over the model axis.
    XLA's SPMD partitioner materializes the FSDP all-gathers / reduce-
    scatters around each matmul — ZeRO-3-style memory scaling with
    overlap left to the XLA latency-hiding scheduler.

``DEFAULT_SERVE_RULES``
    Same 2-D weight layout (weight-gathered serving; large models do not
    fit TP-only on 16 chips) with the KV cache sequence dim sharded over
    the model axis for flash-decode.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# A rule value is a physical mesh axis name, a tuple of them, or None.
RuleValue = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Immutable logical→physical axis mapping."""

    rules: Tuple[Tuple[str, RuleValue], ...]

    def get(self, logical: Optional[str]) -> RuleValue:
        if logical is None:
            return None
        for k, v in self.rules:
            if k == logical:
                return v
        raise KeyError(f"no sharding rule for logical axis {logical!r}")

    def override(self, **kw: RuleValue) -> "AxisRules":
        """New rule set with some logical axes remapped (perf-loop hook)."""
        d = dict(self.rules)
        d.update(kw)
        return AxisRules(tuple(d.items()))


# "batch" resolves to every data-parallel axis present in the mesh; the
# helper below intersects rule values with the mesh's actual axis names so
# one table serves both single-pod and multi-pod meshes.
_COMMON: Dict[str, RuleValue] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,               # sequence dim of activations (unsharded)
    # residual-stream sequence dim: None = classic TP (activations
    # replicated over `model` between blocks); "model" = Megatron-style
    # sequence parallelism (norms/residual adds shard 16× further and
    # the TP all-reduce pair becomes all-gather + reduce-scatter)
    "res_seq": None,
    "embed_act": None,         # d_model dim of activations
    "heads_act": "model",      # per-head activation dim
    "kv_heads_act": None,      # kv heads are few; replicate (GQA-local attn)
    "mlp_act": "model",
    "vocab_act": "model",
    "kv_seq": "model",         # decode-time KV cache sequence dim (flash-decode)
    "expert_act": "model",
    # weights
    "embed": "data",           # d_model dim of weights  (FSDP axis)
    "heads": "model",          # q-head dim of weights   (TP axis)
    "kv_heads": None,
    "mlp": "model",            # d_ff dim of weights     (TP axis)
    "vocab": "model",          # vocab dim of embedding  (TP axis)
    "expert": "model",         # expert dim of MoE weights (EP axis)
    "layers": None,            # stacked-layer dim: replicated
    "conv": None,
    "stack": None,
}

DEFAULT_TRAIN_RULES = AxisRules(tuple(_COMMON.items()))

_SERVE = dict(_COMMON)
DEFAULT_SERVE_RULES = AxisRules(tuple(_SERVE.items()))


def _filter_axes(value: RuleValue, mesh: Optional[Mesh]) -> RuleValue:
    """Drop physical axes that are not present in the mesh."""
    if value is None or mesh is None:
        return None if mesh is None else value
    names = set(mesh.axis_names)
    if isinstance(value, str):
        return value if value in names else None
    kept = tuple(a for a in value if a in names)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def logical_to_spec(
    logical_axes: Sequence[Optional[str]],
    rules: AxisRules,
    mesh: Optional[Mesh] = None,
) -> P:
    """PartitionSpec for a tensor annotated with logical axis names."""
    parts = []
    for ax in logical_axes:
        v = rules.get(ax)
        if mesh is not None:
            v = _filter_axes(v, mesh)
        parts.append(v)
    # trailing Nones can be dropped but keeping them is harmless/explicit
    return P(*parts)


def shard(
    x: PyTree,
    logical_axes: Sequence[Optional[str]],
    rules: AxisRules,
    mesh: Optional[Mesh],
) -> PyTree:
    """``with_sharding_constraint`` if a mesh is active, else identity.

    Models call this at layer boundaries; on a mesh-less CPU run it
    disappears entirely.
    """
    if mesh is None or mesh.empty:
        return x
    spec = logical_to_spec(logical_axes, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def make_named_sharding(
    logical_axes: Sequence[Optional[str]],
    rules: AxisRules,
    mesh: Mesh,
) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(logical_axes, rules, mesh))


def spec_tree_for(defs: PyTree, rules: AxisRules,
                  mesh: Optional[Mesh]) -> PyTree:
    """Map a tree of ParamDef (anything with .logical) to PartitionSpecs."""
    return jax.tree.map(
        lambda d: logical_to_spec(d.logical, rules, mesh),
        defs,
        is_leaf=lambda d: hasattr(d, "logical"),
    )


def fit_spec_to_shape(shape: Tuple[int, ...], spec: P,
                      mesh: Mesh) -> P:
    """Drop mesh axes from dims they don't divide (jit ``in_shardings``
    demands exact divisibility; GSPMD-internal constraints don't).

    E.g. vocab=50280 over model=16 -> replicated; batch=1 over
    (pod,data) -> replicated.  Axes are dropped right-to-left so the
    leading (usually larger) axis survives when a partial product fits.
    """
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, val in zip(shape, parts):
        if val is None:
            out.append(None)
            continue
        axes = list(val) if isinstance(val, tuple) else [val]
        while axes:
            prod = 1
            for a in axes:
                prod *= mesh.shape[a]
            if dim % prod == 0:
                break
            axes.pop()          # drop the rightmost axis
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(tuple(axes))
    return P(*out)


def fit_specs(abstract_tree: PyTree, spec_tree: PyTree,
              mesh: Mesh) -> PyTree:
    """Apply ``fit_spec_to_shape`` leafwise over matching trees."""
    return jax.tree.map(
        lambda a, s: fit_spec_to_shape(tuple(a.shape), s, mesh),
        abstract_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def data_axis_names(mesh: Optional[Mesh]) -> Tuple[str, ...]:
    """The mesh axes that carry data parallelism."""
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_partition_axes(mesh: Optional[Mesh],
                         rules: Optional[AxisRules] = None
                         ) -> Tuple[str, ...]:
    """Physical mesh axes the logical ``"batch"`` axis shards over.

    The rule table's ``"batch"`` entry (``("pod", "data")`` by default)
    intersected with the mesh's actual axis names — empty when the mesh
    carries no data-parallel axis at all (e.g. a pure-TP mesh).
    """
    rules = DEFAULT_TRAIN_RULES if rules is None else rules
    v = _filter_axes(rules.get("batch"), mesh)
    if v is None:
        return ()
    return (v,) if isinstance(v, str) else tuple(v)


def batch_shard_count(mesh: Optional[Mesh],
                      rules: Optional[AxisRules] = None) -> int:
    """Number of batch shards ``odeint(..., mesh=...)`` splits into
    (the product of the mesh's batch-partition axis sizes; 1 when the
    mesh has no data axis or is None)."""
    n = 1
    for a in batch_partition_axes(mesh, rules):
        n *= mesh.shape[a]
    return n


def shard_mesh(devices: Optional[Sequence] = None) -> Mesh:
    """Flat 1-D ``("data",)`` mesh over all (or the given) devices.

    The simplest mesh ``odeint(..., mesh=...)`` accepts: every device is
    a batch shard, no model parallelism.  A function (never a constant)
    so importing this module touches no jax device state.
    """
    devices = list(devices if devices is not None else jax.devices())
    return jax.make_mesh((len(devices),), ("data",), devices=devices)


def shard_map_compat(fn, *, mesh: Mesh, in_specs, out_specs):
    """``shard_map`` across jax versions.

    Newer jax exposes ``jax.shard_map(..., check_vma=...)``; the pinned
    0.4.x line only has ``jax.experimental.shard_map.shard_map(...,
    check_rep=...)``.  Replication checking is disabled either way: the
    solver bodies run custom_vjp interiors the checker cannot see
    through, and the model shard_fns psum manually.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


def model_axis_size(mesh: Optional[Mesh]) -> int:
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return mesh.shape["model"]
