"""repro.distributed — mesh-aware sharding rules and collective helpers."""

from .sharding import (
    AxisRules,
    DEFAULT_TRAIN_RULES,
    DEFAULT_SERVE_RULES,
    batch_partition_axes,
    batch_shard_count,
    data_axis_names,
    logical_to_spec,
    shard,
    make_named_sharding,
    shard_map_compat,
    shard_mesh,
    spec_tree_for,
)

__all__ = [
    "AxisRules",
    "DEFAULT_TRAIN_RULES",
    "DEFAULT_SERVE_RULES",
    "batch_partition_axes",
    "batch_shard_count",
    "data_axis_names",
    "logical_to_spec",
    "shard",
    "make_named_sharding",
    "shard_map_compat",
    "shard_mesh",
    "spec_tree_for",
]
