"""repro.distributed — mesh-aware sharding rules and collective helpers."""

from .sharding import (
    AxisRules,
    DEFAULT_TRAIN_RULES,
    DEFAULT_SERVE_RULES,
    logical_to_spec,
    shard,
    make_named_sharding,
    spec_tree_for,
)

__all__ = [
    "AxisRules",
    "DEFAULT_TRAIN_RULES",
    "DEFAULT_SERVE_RULES",
    "logical_to_spec",
    "shard",
    "make_named_sharding",
    "spec_tree_for",
]
