#!/usr/bin/env python
"""AST repo lint CLI: ``python -m tools.solver_lint src/``.

Runs the solver-stack AST rules (shard-map-direct, bare-assert,
jit-host-leak, registry-drift) over the given files/directories and
exits nonzero on any finding not covered by the baseline file.  See
``docs/static-analysis.md`` for the rule catalog and suppression
workflow.
"""

from __future__ import annotations

import argparse
import os
import sys

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_BASELINE = os.path.join(_REPO_ROOT, "tools", "solver_lint_baseline.json")

try:
    import repro.analysis  # noqa: F401
except ImportError:  # running from a checkout without PYTHONPATH=src
    sys.path.insert(0, os.path.join(_REPO_ROOT, "src"))

from repro.analysis import Report, lint_paths, load_baseline


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.solver_lint",
        description="solver-stack AST lint over repo sources",
    )
    parser.add_argument(
        "paths", nargs="*", default=["src"], help="files or directories (default: src)"
    )
    parser.add_argument(
        "--baseline",
        default=DEFAULT_BASELINE,
        help="baseline/suppression JSON ('' disables)",
    )
    parser.add_argument(
        "--root", default=".", help="root for repo-relative finding paths"
    )
    parser.add_argument(
        "--report", default=None, help="also write the findings report to this file"
    )
    parser.add_argument(
        "-v", "--verbose", action="store_true", help="show suppressed findings too"
    )
    parser.add_argument(
        "--stale-baseline-check",
        action="store_true",
        help="also fail if baseline entries no longer match anything",
    )
    args = parser.parse_args(argv)

    baseline = ()
    if args.baseline and os.path.exists(args.baseline):
        baseline = load_baseline(args.baseline)

    paths = args.paths or ["src"]
    report = Report(baseline=baseline)
    report.extend(lint_paths(paths, root=args.root))

    text = report.render(verbose=args.verbose)
    print(text)
    if args.report:
        with open(args.report, "w", encoding="utf-8") as fh:
            fh.write(text + "\n")

    ok = report.ok
    if args.stale_baseline_check:
        stale = report.stale_baseline()
        for entry in stale:
            print(f"stale baseline entry: {entry.rule} {entry.path} {entry.match!r}")
        ok = ok and not stale
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
