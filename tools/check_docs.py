"""Docs health check, run by the CI docs job.

Four gates over ``README.md`` + ``docs/**/*.md``:

1. every relative link resolves to an existing file (anchors are
   stripped; absolute http(s)/mailto links are skipped);
2. every fenced ```python code block parses (``compile()`` smoke — no
   execution), so documented snippets cannot silently rot into syntax
   errors as the API evolves;
3. every public symbol exported by ``repro.core`` (its ``__all__``) has a
   real docstring — the auto-generated ``Name(field, ...)`` signature
   docstring of dataclasses/NamedTuples does not count;
4. every backticked ``repro.*`` dotted reference resolves against the
   live package (import the module prefix, getattr the rest), so prose
   cannot keep naming symbols a refactor renamed away.

Exits non-zero with one line per violation.

    PYTHONPATH=src python tools/check_docs.py
"""

from __future__ import annotations

import inspect
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

# [text](target) — excluding images' extra ! is fine (same rule applies)
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SKIP = ("http://", "https://", "mailto:")
# fenced python blocks; tolerate info-string suffixes like ``python doctest``
_PY_FENCE = re.compile(r"^```python[^\n]*\n(.*?)^```", re.M | re.S)


def _md_files() -> list:
    return [ROOT / "README.md"] + sorted((ROOT / "docs").rglob("*.md"))


def check_links() -> list:
    errors = []
    for md in _md_files():
        if not md.exists():
            errors.append(f"{md.relative_to(ROOT)}: file missing")
            continue
        for m in _LINK.finditer(md.read_text()):
            target = m.group(1)
            if target.startswith(_SKIP) or target.startswith("#"):
                continue
            path = target.split("#", 1)[0]
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(
                    f"{md.relative_to(ROOT)}: broken link -> {target}")
    return errors


def check_snippets() -> list:
    """Syntax-check every fenced ```python block (compile only — no
    execution, no imports resolved)."""
    errors = []
    for md in _md_files():
        if not md.exists():
            continue  # check_links already reports the missing file
        text = md.read_text()
        for m in _PY_FENCE.finditer(text):
            lineno = text.count("\n", 0, m.start()) + 2  # first code line
            where = f"{md.relative_to(ROOT)}:{lineno}"
            try:
                compile(m.group(1), where, "exec")
            except SyntaxError as e:
                errors.append(
                    f"{where}: python snippet does not parse "
                    f"(line {e.lineno} of block: {e.msg})")
    return errors


def _is_auto_doc(obj) -> bool:
    """Dataclass/NamedTuple auto docstrings look like 'Name(...)'."""
    doc = obj.__doc__ or ""
    name = getattr(obj, "__name__", "")
    return doc.strip().startswith(f"{name}(")


def check_docstrings() -> list:
    import repro.core as core

    errors = []
    for sym in core.__all__:
        obj = getattr(core, sym, None)
        if obj is None:
            errors.append(f"repro.core.{sym}: exported but missing")
            continue
        if not (inspect.isfunction(obj) or inspect.isclass(obj)
                or inspect.ismodule(obj)):
            continue  # plain data (tuples of names etc.)
        doc = inspect.getdoc(obj)
        if not doc or not doc.strip() or _is_auto_doc(obj):
            errors.append(f"repro.core.{sym}: missing docstring")
    return errors


# backticked dotted repro references: `repro.core.api.odeint`,
# `repro.distributed.shard_mesh()`; a trailing call suffix is stripped
_REPRO_REF = re.compile(r"`(repro(?:\.[A-Za-z_][A-Za-z0-9_]*)+)(?:\(\))?`")
_FENCE_LINE = re.compile(r"^\s*```")


def _resolve_repro_ref(dotted: str) -> bool:
    """True iff ``dotted`` names an importable module/attribute chain."""
    import importlib

    parts = dotted.split(".")
    for i in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:i]))
        except ImportError:
            continue
        try:
            for attr in parts[i:]:
                obj = getattr(obj, attr)
        except AttributeError:
            return False
        return True
    return False


def check_symbol_refs() -> list:
    """Resolve every backticked ``repro.*`` reference against the package."""
    errors = []
    checked = {}
    for md in _md_files():
        if not md.exists():
            continue
        in_fence = False
        for lineno, line in enumerate(md.read_text().splitlines(), start=1):
            if _FENCE_LINE.match(line):
                in_fence = not in_fence
                continue
            if in_fence:
                continue  # snippet gate owns fenced code
            for m in _REPRO_REF.finditer(line):
                dotted = m.group(1)
                if dotted not in checked:
                    checked[dotted] = _resolve_repro_ref(dotted)
                if not checked[dotted]:
                    errors.append(
                        f"{md.relative_to(ROOT)}:{lineno}: `{dotted}` does "
                        "not resolve against the live repro package")
    return errors


def main() -> int:
    errors = (check_links() + check_snippets() + check_docstrings()
              + check_symbol_refs())
    for e in errors:
        print(f"FAIL {e}")
    if errors:
        return 1
    print("docs check OK (links + python snippets + public docstrings "
          "+ repro.* symbol refs)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
