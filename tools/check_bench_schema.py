"""Validate bench JSON artifacts against the ``common.emit_json`` schema.

Every ``BENCH_*.json`` file under the artifact directory must hold one
JSON object per line of the exact shape

    {"bench": <non-empty str>, "metrics": {<str>: <int|float|str>, ...}}

with a non-empty metrics mapping, finite numbers (no NaN/inf — they
would round-trip through ``json`` but break downstream consumers) and
no extra top-level keys.  Run by the CI tier1 job right after the bench
smoke steps:

    python tools/check_bench_schema.py [bench-artifacts]

Exits non-zero with one line per violation, and fails when the
directory holds no ``BENCH_*.json`` at all (a silently-empty artifact
upload would otherwise look green).
"""

from __future__ import annotations

import json
import math
import pathlib
import sys


def check_line(where: str, line: str) -> list:
    errors = []
    try:
        obj = json.loads(line)
    except json.JSONDecodeError as e:
        return [f"{where}: not valid JSON ({e})"]
    if not isinstance(obj, dict) or set(obj) != {"bench", "metrics"}:
        return [f"{where}: top-level keys must be exactly "
                f"{{'bench', 'metrics'}}, got {sorted(obj)}"
                if isinstance(obj, dict) else f"{where}: not an object"]
    if not isinstance(obj["bench"], str) or not obj["bench"]:
        errors.append(f"{where}: 'bench' must be a non-empty string")
    metrics = obj["metrics"]
    if not isinstance(metrics, dict) or not metrics:
        return errors + [f"{where}: 'metrics' must be a non-empty object"]
    for key, val in metrics.items():
        if not isinstance(key, str) or not key:
            errors.append(f"{where}: metric name {key!r} is not a "
                          "non-empty string")
        # bools are ints in Python — exclude them explicitly
        if isinstance(val, bool) or not isinstance(val, (int, float, str)):
            errors.append(f"{where}: metric {key!r} has non-scalar value "
                          f"{val!r}")
        elif isinstance(val, float) and not math.isfinite(val):
            errors.append(f"{where}: metric {key!r} is not finite ({val})")
    return errors


def check_file(path: pathlib.Path) -> list:
    errors = []
    lines = [ln for ln in path.read_text().splitlines() if ln.strip()]
    if not lines:
        return [f"{path}: empty artifact file"]
    for lineno, line in enumerate(lines, 1):
        errors.extend(check_line(f"{path}:{lineno}", line))
    return errors


def main(argv) -> int:
    art_dir = pathlib.Path(argv[1] if len(argv) > 1 else "bench-artifacts")
    files = sorted(art_dir.glob("BENCH_*.json"))
    if not files:
        print(f"FAIL {art_dir}: no BENCH_*.json artifacts found")
        return 1
    errors = []
    for f in files:
        errors.extend(check_file(f))
    for e in errors:
        print(f"FAIL {e}")
    if errors:
        return 1
    print(f"bench schema OK ({len(files)} artifact files)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
